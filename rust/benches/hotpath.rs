//! Microbenchmarks of the L3 hot paths (hand-rolled: no criterion in the
//! vendored set). Reports ns/op medians over repeated runs; used by the
//! §Perf pass in EXPERIMENTS.md.

use ltp::proto::{run_single_flow, EarlyCloseCfg, LtpSender, SegmentMap};
use ltp::simnet::{LinkCfg, LossModel};
use ltp::wire::{LtpHeader, LTP_MSS};
use ltp::{MS, SEC};
use std::time::Instant;

fn bench<F: FnMut() -> u64>(name: &str, iters: u32, mut f: F) {
    let mut samples = Vec::with_capacity(iters as usize);
    let mut units = 0u64;
    for _ in 0..iters {
        let t0 = Instant::now();
        units = f();
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    samples.sort_unstable();
    let med = samples[samples.len() / 2];
    println!(
        "{name:<44} median {:>10} ns  ({:>8.1} ns/unit over {units} units)",
        med,
        med as f64 / units.max(1) as f64
    );
}

fn main() {
    println!("== L3 hot paths ==");

    bench("ltp header encode+decode", 50, || {
        let mut acc = 0u64;
        let n = 100_000;
        for i in 0..n {
            let h = LtpHeader::data(7, i as u32 & 0xFFFFF, ltp::wire::Importance::Normal);
            let b = h.encode();
            acc = acc.wrapping_add(LtpHeader::decode(&b).unwrap().seq as u64);
        }
        std::hint::black_box(acc);
        n
    });

    bench("sender window: poll_transmit+ack cycle", 20, || {
        let map = SegmentMap::new(50_000_000, LTP_MSS, vec![]);
        let mut s = LtpSender::new(1, map, ltp::wire::MTU);
        s.seed_cc(MS, 1_250_000_000);
        let mut now = 0;
        let mut sent = 0u64;
        // Drive a synthetic 1-RTT-lag ack stream.
        let mut pending = std::collections::VecDeque::new();
        while sent < 30_000 {
            while let Some(p) = s.poll_transmit(now) {
                sent += 1;
                pending.push_back((now + MS, p.hdr.seq));
            }
            while pending.front().map(|&(t, _)| t <= now).unwrap_or(false) {
                let (_, seq) = pending.pop_front().unwrap();
                s.handle(now, ltp::proto::ack_event(1, seq));
            }
            now += 50_000;
        }
        sent
    });

    bench("simnet: 1-flow 10MB over lossy link (events)", 10, || {
        let cfg = LinkCfg::dcn(10, 50).with_loss(LossModel::Bernoulli { p: 0.01 });
        let ec = EarlyCloseCfg { lt_threshold: 10 * MS, deadline: 100 * MS, pct: 0.8 };
        let (s, _r) = run_single_flow(10_000_000, vec![0], cfg, ec, 1, 60 * SEC);
        s.pkts_sent
    });

    bench("bubble fill 10MB, 30% loss", 20, || {
        let map = SegmentMap::new(10_000_000, 1460, vec![]);
        let src = vec![0xABu8; 10_000_000];
        let mut rec = ltp::util::Bitmap::new(map.n_segs as usize);
        let mut rng = ltp::util::Pcg64::seeded(3);
        for i in 0..map.n_segs as usize {
            if rng.chance(0.7) {
                rec.set(i);
            }
        }
        let out = ltp::grad::bubble_fill(&src, &map, &rec);
        std::hint::black_box(&out);
        map.n_segs as u64
    });
}
