//! Bench: regenerate Fig 12 (training throughput vs loss per protocol).

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    // jobs = 0: auto-shard the sweep across all cores (runtime::pool).
    let points = ltp::figures::fig12(true, 0);
    println!("fig12: {} points in {:?}", points.len(), t0.elapsed());
}
