//! Bench: regenerate Fig 12 (training throughput vs loss per protocol).

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let points = ltp::figures::fig12(true);
    println!("fig12: {} points in {:?}", points.len(), t0.elapsed());
}
