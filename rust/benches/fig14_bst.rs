//! Bench: regenerate Fig 14 (BST distributions normalized to LTP) plus
//! Fig 2/3/15 (they share the harness and are cheap).

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    // jobs = 0: auto-shard each sweep across all cores (runtime::pool).
    ltp::figures::fig2(true, 0);
    ltp::figures::fig3(true, 0);
    let rows = ltp::figures::fig14(true, 0);
    ltp::figures::fig15(true);
    println!("fig2+3+14+15: {} fig14 rows in {:?}", rows.len(), t0.elapsed());
}
