//! Bench: regenerate Fig 14 (BST distributions normalized to LTP) plus
//! Fig 2/3/15 (they share the harness and are cheap).

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    ltp::figures::fig2(true);
    ltp::figures::fig3(true);
    let rows = ltp::figures::fig14(true);
    ltp::figures::fig15(true);
    println!("fig2+3+14+15: {} fig14 rows in {:?}", rows.len(), t0.elapsed());
}
