//! Bench: regenerate the paper's Fig 4 table (goodput reduction vs loss)
//! and time the sweep. Run with `cargo bench --bench fig4_utilization`.

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    // jobs = 0: auto-shard the grid across all cores (runtime::pool).
    let cells = ltp::figures::fig4(true, 0);
    println!("fig4: {} cells in {:?}", cells.len(), t0.elapsed());
}
