//! Microbenchmarks of the event core (hand-rolled: no criterion in the
//! vendored set): the hierarchical timer wheel vs the `BinaryHeap` it
//! replaced, under the simulator's access patterns. Reports ns/op medians;
//! run with `cargo bench --bench eventcore` (the bench profile keeps debug
//! symbols, so `perf record` / flamegraphs attribute samples to source).

use ltp::simnet::EventQueue;
use ltp::util::Pcg64;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

fn bench<F: FnMut() -> u64>(name: &str, iters: u32, mut f: F) {
    let mut samples = Vec::with_capacity(iters as usize);
    let mut units = 0u64;
    for _ in 0..iters {
        let t0 = Instant::now();
        units = f();
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    samples.sort_unstable();
    let med = samples[samples.len() / 2];
    println!(
        "{name:<44} median {:>10} ns  ({:>8.1} ns/unit over {units} units)",
        med,
        med as f64 / units.max(1) as f64
    );
}

/// The simulator's steady-state pattern: pop the earliest event, schedule
/// a couple of successors a short (network-scale) delta ahead — with the
/// queue holding `depth` events in flight throughout.
fn churn_wheel(depth: u64, ops: u64) -> u64 {
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut rng = Pcg64::seeded(1);
    for i in 0..depth {
        q.schedule(rng.gen_range(1 << 20), i);
    }
    let mut processed = 0u64;
    while processed < ops {
        let (at, _, _) = q.pop().expect("queue stays populated");
        processed += 1;
        q.schedule(at + 1 + rng.gen_range(1 << 14), processed);
    }
    std::hint::black_box(q.len());
    processed
}

/// The same churn over the former `BinaryHeap<Reverse<(time, seq)>>` —
/// the baseline the wheel is measured against.
fn churn_heap(depth: u64, ops: u64) -> u64 {
    let mut heap: BinaryHeap<Reverse<(u64, u64)>> = BinaryHeap::new();
    let mut rng = Pcg64::seeded(1);
    let mut seq = 0u64;
    for _ in 0..depth {
        seq += 1;
        heap.push(Reverse((rng.gen_range(1 << 20), seq)));
    }
    let mut processed = 0u64;
    while processed < ops {
        let Reverse((at, _)) = heap.pop().expect("heap stays populated");
        processed += 1;
        seq += 1;
        heap.push(Reverse((at + 1 + rng.gen_range(1 << 14), seq)));
    }
    std::hint::black_box(heap.len());
    processed
}

fn main() {
    println!("== event core: timer wheel vs binary heap ==");
    for &depth in &[1_000u64, 100_000] {
        let ops = 1_000_000;
        bench(&format!("wheel churn, {depth} in flight"), 10, || churn_wheel(depth, ops));
        bench(&format!("heap churn, {depth} in flight"), 10, || churn_heap(depth, ops));
    }

    bench("wheel: same-instant burst drain (FIFO ties)", 10, || {
        let mut q: EventQueue<u64> = EventQueue::new();
        let n = 200_000u64;
        for i in 0..n {
            q.schedule(1000, i);
        }
        let mut acc = 0u64;
        while let Some((_, _, x)) = q.pop() {
            acc = acc.wrapping_add(x);
        }
        std::hint::black_box(acc);
        n
    });

    bench("wheel: far-future cascade sweep", 10, || {
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut rng = Pcg64::seeded(2);
        let n = 200_000u64;
        for i in 0..n {
            q.schedule(rng.gen_range(1 << 50), i);
        }
        let mut acc = 0u64;
        while let Some((at, _, _)) = q.pop() {
            acc = acc.wrapping_add(at);
        }
        std::hint::black_box(acc);
        n
    });
}
