//! Topology builders and traffic-generator nodes.
//!
//! The paper's testbed is a single-rack star (8 workers + 1 PS behind one
//! ToR switch); the scenario engine additionally needs an oversubscribed
//! two-rack fabric with an aggregation switch, plus background-traffic
//! generators that share a bottleneck with the training job.

use super::{Ctx, EntityId, LinkCfg, LinkId, Node, Packet, Sim};
use crate::trace::{ROLE_EDGE_DOWN, ROLE_EDGE_UP, ROLE_TRUNK_DOWN, ROLE_TRUNK_UP};
use crate::wire::PacketKind;
use crate::Nanos;

/// A star topology built around one switch. `hosts[0]` is conventionally
/// the PS in the training experiments.
pub struct StarTopology {
    pub switch: EntityId,
    pub hosts: Vec<EntityId>,
    /// `uplinks[i]`: host i → switch.
    pub uplinks: Vec<LinkId>,
    /// `downlinks[i]`: switch → host i.
    pub downlinks: Vec<LinkId>,
}

/// Build a star of `nodes.len()` hosts around a switch, all edge links
/// sharing `cfg`. The switch adds `fwd_delay` forwarding latency.
///
/// Scales to thousands of hosts: entity/link tables are pre-sized and the
/// per-hop route lookup is an indexed load, so an incast-degree-1024 star
/// builds (and forwards) without hashing or reallocation.
pub fn star(sim: &mut Sim, nodes: Vec<Box<dyn Node>>, cfg: LinkCfg, fwd_delay: Nanos) -> StarTopology {
    let cfgs = vec![cfg; nodes.len()];
    star_with(sim, nodes, &cfgs, fwd_delay)
}

/// [`star`] with one [`LinkCfg`] per host (`cfgs[i]` configures host i's
/// duplex edge) — the churn plane's heterogeneous-edge fabric. The entity
/// and link creation order is identical to [`star`], so a uniform `cfgs`
/// slice reproduces `star`'s RNG streams (and report bytes) exactly.
pub fn star_with(
    sim: &mut Sim,
    nodes: Vec<Box<dyn Node>>,
    cfgs: &[LinkCfg],
    fwd_delay: Nanos,
) -> StarTopology {
    let n = nodes.len();
    assert_eq!(cfgs.len(), n, "one LinkCfg per host");
    sim.reserve(n + 1, 2 * n);
    let switch = sim.add_switch(fwd_delay);
    let mut hosts = Vec::with_capacity(n);
    let mut uplinks = Vec::with_capacity(n);
    let mut downlinks = Vec::with_capacity(n);
    for (node, cfg) in nodes.into_iter().zip(cfgs) {
        let h = sim.add_host(node);
        let (up, down) = sim.add_duplex(h, switch, *cfg);
        sim.set_default_uplink(h, up);
        sim.note_link_meta(up, ROLE_EDGE_UP);
        sim.note_link_meta(down, ROLE_EDGE_DOWN);
        hosts.push(h);
        uplinks.push(up);
        downlinks.push(down);
    }
    StarTopology { switch, hosts, uplinks, downlinks }
}

/// An N-rack topology: one ToR switch per rack under one aggregation
/// switch. Cross-rack traffic funnels through the (typically
/// oversubscribed) ToR↔agg links; in-rack traffic stays under its ToR.
pub struct RackTopology {
    pub agg: EntityId,
    /// `tors[r]` is rack r's ToR switch.
    pub tors: Vec<EntityId>,
    /// All hosts in creation order (rack 0 first).
    pub hosts: Vec<EntityId>,
    /// `rack_of[i]` is the rack of `hosts[i]`.
    pub rack_of: Vec<usize>,
    /// `trunk_up[r]`: tor r → agg; `trunk_down[r]`: agg → tor r.
    pub trunk_up: Vec<LinkId>,
    pub trunk_down: Vec<LinkId>,
}

/// Build an N-rack fabric: `racks[r]` holds rack r's host nodes, every
/// edge link uses `edge`, every ToR↔agg trunk uses `trunk` (make
/// `trunk.rate_bps` smaller than the sum of edge rates for an
/// oversubscribed fabric). Switches add `fwd_delay` forwarding latency.
///
/// Entity-id layout (deterministic): agg, tor0, …, torN-1, then the hosts
/// of rack 0, rack 1, … in order.
pub fn n_rack(
    sim: &mut Sim,
    racks: Vec<Vec<Box<dyn Node>>>,
    edge: LinkCfg,
    trunk: LinkCfg,
    fwd_delay: Nanos,
) -> RackTopology {
    assert!(!racks.is_empty(), "a rack fabric needs at least one rack");
    let n_hosts: usize = racks.iter().map(|r| r.len()).sum();
    sim.reserve(n_hosts + racks.len() + 1, 2 * (n_hosts + racks.len()));
    let agg = sim.add_switch(fwd_delay);
    let tors: Vec<EntityId> = racks.iter().map(|_| sim.add_switch(fwd_delay)).collect();
    let mut trunk_up = Vec::with_capacity(tors.len());
    let mut trunk_down = Vec::with_capacity(tors.len());
    for &tor in &tors {
        let (up, down) = sim.add_duplex(tor, agg, trunk);
        trunk_up.push(up);
        trunk_down.push(down);
        // Cross-rack traffic leaves the ToR via its trunk by default.
        sim.set_default_uplink(tor, up);
        sim.note_link_meta(up, ROLE_TRUNK_UP);
        sim.note_link_meta(down, ROLE_TRUNK_DOWN);
    }
    let mut hosts = Vec::with_capacity(n_hosts);
    let mut rack_of = Vec::with_capacity(n_hosts);
    for (r, nodes) in racks.into_iter().enumerate() {
        for node in nodes {
            let h = sim.add_host(node);
            let (up, down) = sim.add_duplex(h, tors[r], edge);
            sim.set_default_uplink(h, up);
            sim.note_link_meta(up, ROLE_EDGE_UP);
            sim.note_link_meta(down, ROLE_EDGE_DOWN);
            // The agg switch reaches h through rack r's trunk; the ToR's
            // own (tor → h) exact route was installed by add_duplex.
            sim.set_route(agg, h, trunk_down[r]);
            hosts.push(h);
            rack_of.push(r);
        }
    }
    RackTopology { agg, tors, hosts, rack_of, trunk_up, trunk_down }
}

/// A two-rack topology — the `racks = 2` case of [`RackTopology`], kept
/// with fixed-size fields for the original scenario callers.
pub struct TwoRackTopology {
    pub agg: EntityId,
    /// `tors[r]` is rack r's ToR switch.
    pub tors: [EntityId; 2],
    /// All hosts in creation order (rack 0 first).
    pub hosts: Vec<EntityId>,
    /// `rack_of[i]` is the rack of `hosts[i]`.
    pub rack_of: Vec<usize>,
    /// `trunk_up[r]`: tor r → agg; `trunk_down[r]`: agg → tor r.
    pub trunk_up: [LinkId; 2],
    pub trunk_down: [LinkId; 2],
}

/// Build a two-rack fabric — [`n_rack`] with `racks = 2` (identical
/// entity-id layout and link creation order, so reports stay
/// byte-identical).
pub fn two_rack(
    sim: &mut Sim,
    racks: [Vec<Box<dyn Node>>; 2],
    edge: LinkCfg,
    trunk: LinkCfg,
    fwd_delay: Nanos,
) -> TwoRackTopology {
    let t = n_rack(sim, racks.into(), edge, trunk, fwd_delay);
    TwoRackTopology {
        agg: t.agg,
        tors: [t.tors[0], t.tors[1]],
        hosts: t.hosts,
        rack_of: t.rack_of,
        trunk_up: [t.trunk_up[0], t.trunk_up[1]],
        trunk_down: [t.trunk_down[0], t.trunk_down[1]],
    }
}

/// A constant-rate background datagram source (cross traffic). Emits
/// `pkt_size`-byte [`PacketKind::Raw`] packets toward `sink` at `rate_bps`
/// from `start` until `stop`, with optional exponential (Poisson-process)
/// spacing jitter drawn from the node's deterministic RNG stream.
///
/// The packets are fire-and-forget: no ACKs, no retransmission — pure load
/// on every link of the path, which is exactly what "background cross
/// traffic sharing the bottleneck" needs. Protocol endpoints ignore
/// `Raw` packets, so a training PS can itself be the sink (loading the
/// incast-direction bottleneck link).
pub struct CrossTraffic {
    pub sink: EntityId,
    pub rate_bps: u64,
    pub pkt_size: u32,
    pub start: Nanos,
    pub stop: Nanos,
    pub jitter: bool,
    /// Packets emitted so far.
    pub sent_pkts: u64,
    pub sent_bytes: u64,
}

impl CrossTraffic {
    pub fn new(sink: EntityId, rate_bps: u64, pkt_size: u32, stop: Nanos) -> CrossTraffic {
        assert!(rate_bps > 0 && pkt_size > 0);
        CrossTraffic {
            sink,
            rate_bps,
            pkt_size,
            start: 0,
            stop,
            jitter: true,
            sent_pkts: 0,
            sent_bytes: 0,
        }
    }

    pub fn with_start(mut self, at: Nanos) -> CrossTraffic {
        self.start = at;
        self
    }

    pub fn with_jitter(mut self, jitter: bool) -> CrossTraffic {
        self.jitter = jitter;
        self
    }

    /// Mean inter-packet gap at the configured rate.
    fn mean_gap(&self) -> Nanos {
        ((self.pkt_size as u128 * 8 * crate::SEC as u128) / self.rate_bps as u128).max(1) as Nanos
    }

    fn schedule_next(&mut self, ctx: &mut Ctx) {
        let gap = if self.jitter {
            let mean = self.mean_gap() as f64;
            (ctx.rng().exp(mean) as Nanos).max(1)
        } else {
            self.mean_gap()
        };
        let at = ctx.now() + gap;
        if at < self.stop {
            ctx.set_timer(at, 0);
        }
    }
}

impl Node for CrossTraffic {
    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn start(&mut self, ctx: &mut Ctx) {
        if self.start < self.stop {
            ctx.set_timer(self.start.max(1), 0);
        }
    }

    fn on_packet(&mut self, _ctx: &mut Ctx, _pkt: Packet) {}

    fn on_timer(&mut self, ctx: &mut Ctx, _token: u64) {
        if ctx.now() >= self.stop {
            return;
        }
        self.sent_pkts += 1;
        self.sent_bytes += self.pkt_size as u64;
        let pkt = Packet::new(ctx.me, self.sink, self.pkt_size, u64::MAX, PacketKind::Raw(0));
        ctx.send(pkt);
        self.schedule_next(ctx);
    }
}

/// A host that counts everything it receives (background-flow sink,
/// reachability probes).
#[derive(Default)]
pub struct CountingSink {
    pub pkts: u64,
    pub bytes: u64,
    /// Arrival time of the most recent packet.
    pub last_arrival: Nanos,
}

impl Node for CountingSink {
    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn on_packet(&mut self, ctx: &mut Ctx, pkt: Packet) {
        self.pkts += 1;
        self.bytes += pkt.size as u64;
        self.last_arrival = ctx.now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::{Ctx, Packet};
    use crate::wire::PacketKind;
    use crate::{MS, SEC};
    use std::cell::RefCell;
    use std::rc::Rc;

    struct Echo {
        seen: Rc<RefCell<usize>>,
    }
    impl Node for Echo {
    fn as_any(&mut self) -> &mut dyn std::any::Any { self }
        fn on_packet(&mut self, ctx: &mut Ctx, pkt: Packet) {
            *self.seen.borrow_mut() += 1;
            if let PacketKind::Raw(0) = pkt.kind {
                // bounce back once
                ctx.send(Packet::new(ctx.me, pkt.src, 100, 0, PacketKind::Raw(1)));
            }
        }
    }
    struct Pinger {
        target: EntityId,
        seen: Rc<RefCell<usize>>,
    }
    impl Node for Pinger {
    fn as_any(&mut self) -> &mut dyn std::any::Any { self }
        fn start(&mut self, ctx: &mut Ctx) {
            ctx.send(Packet::new(ctx.me, self.target, 100, 0, PacketKind::Raw(0)));
        }
        fn on_packet(&mut self, _ctx: &mut Ctx, _pkt: Packet) {
            *self.seen.borrow_mut() += 1;
        }
    }

    #[test]
    fn star_all_pairs_reachable() {
        let pong = Rc::new(RefCell::new(0));
        let echo_seen = Rc::new(RefCell::new(0));
        let mut sim = Sim::new(1);
        // hosts: 0 = echo target, 1..=4 pingers — ids assigned after switch.
        let mut nodes: Vec<Box<dyn Node>> = vec![Box::new(Echo { seen: echo_seen.clone() })];
        for _ in 0..4 {
            nodes.push(Box::new(Pinger { target: 1, seen: pong.clone() }));
        }
        // NOTE: `star` adds the switch first, so hosts[0] has entity id 1.
        let topo = star(&mut sim, nodes, LinkCfg::dcn(10, 2), 0);
        assert_eq!(topo.hosts[0], 1);
        sim.run();
        assert_eq!(*echo_seen.borrow(), 4);
        assert_eq!(*pong.borrow(), 4);
    }

    #[test]
    fn star_scales_to_thousands_of_hosts() {
        // 2000 pingers + 1 echo target around one switch: every host is
        // reachable through the dense route tables, and the whole build +
        // run stays well inside test budget.
        let pong = Rc::new(RefCell::new(0));
        let echo_seen = Rc::new(RefCell::new(0));
        let mut sim = Sim::new(9);
        let mut nodes: Vec<Box<dyn Node>> = vec![Box::new(Echo { seen: echo_seen.clone() })];
        for _ in 0..2000 {
            nodes.push(Box::new(Pinger { target: 1, seen: pong.clone() }));
        }
        let topo = star(&mut sim, nodes, LinkCfg::dcn(10, 2), 0);
        assert_eq!(topo.hosts.len(), 2001);
        assert_eq!(sim.entity_count(), 2002);
        sim.run();
        assert_eq!(*echo_seen.borrow(), 2000, "every pinger reaches the echo host");
        assert_eq!(*pong.borrow(), 2000, "every pinger gets its pong back");
    }

    #[test]
    fn two_rack_cross_and_in_rack_reachable() {
        let echo_seen = Rc::new(RefCell::new(0));
        let pong = Rc::new(RefCell::new(0));
        let mut sim = Sim::new(2);
        // Rack 0: the echo target + one in-rack pinger; rack 1: two
        // cross-rack pingers. Entity ids: agg 0, tor0 1, tor1 2, hosts 3…
        let rack0: Vec<Box<dyn Node>> = vec![
            Box::new(Echo { seen: echo_seen.clone() }),
            Box::new(Pinger { target: 3, seen: pong.clone() }),
        ];
        let rack1: Vec<Box<dyn Node>> = vec![
            Box::new(Pinger { target: 3, seen: pong.clone() }),
            Box::new(Pinger { target: 3, seen: pong.clone() }),
        ];
        let edge = LinkCfg::dcn(10, 2);
        let trunk = LinkCfg::dcn(10, 5);
        let topo = two_rack(&mut sim, [rack0, rack1], edge, trunk, 0);
        assert_eq!(topo.hosts[0], 3);
        assert_eq!(topo.rack_of, vec![0, 0, 1, 1]);
        sim.run();
        // All three pingers reach the echo host; all get their pong back.
        assert_eq!(*echo_seen.borrow(), 3);
        assert_eq!(*pong.borrow(), 3);
        // Cross-rack traffic used the trunks; in-rack did not need to.
        assert!(sim.link_stats(topo.trunk_up[1]).tx_pkts >= 2, "rack1 pings cross the trunk");
        assert!(sim.link_stats(topo.trunk_down[1]).tx_pkts >= 2, "pongs return over the trunk");
    }

    #[test]
    fn n_rack_three_racks_all_cross_rack_reachable() {
        let echo_seen = Rc::new(RefCell::new(0));
        let pong = Rc::new(RefCell::new(0));
        let mut sim = Sim::new(5);
        // Entity ids: agg 0, tors 1..=3, hosts 4… — the echo target is
        // rack 0's only host (id 4); one pinger per other rack.
        let racks: Vec<Vec<Box<dyn Node>>> = vec![
            vec![Box::new(Echo { seen: echo_seen.clone() })],
            vec![Box::new(Pinger { target: 4, seen: pong.clone() })],
            vec![Box::new(Pinger { target: 4, seen: pong.clone() })],
        ];
        let edge = LinkCfg::dcn(10, 2);
        let trunk = LinkCfg::dcn(10, 5);
        let topo = n_rack(&mut sim, racks, edge, trunk, 0);
        assert_eq!(topo.tors.len(), 3);
        assert_eq!(topo.hosts, vec![4, 5, 6]);
        assert_eq!(topo.rack_of, vec![0, 1, 2]);
        sim.run();
        assert_eq!(*echo_seen.borrow(), 2);
        assert_eq!(*pong.borrow(), 2);
        // Every ping crossed its rack's trunk and came back over rack 0's.
        for r in 1..3 {
            assert!(sim.link_stats(topo.trunk_up[r]).tx_pkts >= 1, "rack {r} uplink");
        }
        assert!(sim.link_stats(topo.trunk_down[0]).tx_pkts >= 2, "pings reach rack 0");
    }

    #[test]
    fn two_rack_trunk_oversubscription_queues_or_drops() {
        // 4 rack-1 blasters sending to one rack-0 sink through a trunk with
        // a quarter of the aggregate edge rate: the trunk must saturate.
        let mut sim = Sim::new(3);
        let rack0: Vec<Box<dyn Node>> = vec![Box::new(CountingSink::default())];
        let mut rack1: Vec<Box<dyn Node>> = Vec::new();
        for _ in 0..4 {
            // CrossTraffic at each host's full edge rate toward the sink.
            rack1.push(Box::new(CrossTraffic::new(3, 10_000_000_000, 1500, 20 * MS)));
        }
        let edge = LinkCfg::dcn(10, 2);
        let trunk = LinkCfg::dcn(10, 5).with_queue(64 * 1024);
        let topo = two_rack(&mut sim, [rack0, rack1], edge, trunk, 0);
        sim.run();
        let up = sim.link_stats(topo.trunk_up[1]);
        assert!(up.tx_pkts > 0);
        assert!(
            up.drops_queue > 0,
            "4:1 oversubscription at full edge rate must overflow the trunk queue: {up:?}"
        );
        let sink = sim.node_as::<CountingSink>(topo.hosts[0]);
        assert!(sink.pkts > 0, "some cross traffic must get through");
    }

    #[test]
    fn cross_traffic_rate_is_calibrated() {
        // 100 Mbps of 1500 B packets for 1 s ≈ 8333 packets (±10 % with
        // exponential jitter on a fixed seed).
        let mut sim = Sim::new(7);
        let nodes: Vec<Box<dyn Node>> = vec![
            Box::new(CountingSink::default()),
            Box::new(CrossTraffic::new(1, 100_000_000, 1500, SEC)),
        ];
        let topo = star(&mut sim, nodes, LinkCfg::dcn(10, 2), 0);
        sim.run();
        let sink = sim.node_as::<CountingSink>(topo.hosts[0]);
        let expect = 100_000_000.0 / (1500.0 * 8.0);
        let got = sink.pkts as f64;
        assert!(
            (got - expect).abs() < expect * 0.1,
            "rate off: got {got} pkts, expected ≈{expect}"
        );
    }

    #[test]
    fn cross_traffic_stops_at_stop_time() {
        let mut sim = Sim::new(8);
        let nodes: Vec<Box<dyn Node>> = vec![
            Box::new(CountingSink::default()),
            Box::new(CrossTraffic::new(1, 1_000_000_000, 1500, 10 * MS).with_jitter(false)),
        ];
        star(&mut sim, nodes, LinkCfg::dcn(10, 2), 0);
        let end = sim.run();
        // The last event is the final packet's arrival shortly after stop.
        assert!(end < 11 * MS, "sim must quiesce right after stop: ended at {end}");
    }

    #[test]
    fn cross_traffic_is_deterministic_per_seed() {
        let run = |seed| {
            let mut sim = Sim::new(seed);
            let nodes: Vec<Box<dyn Node>> = vec![
                Box::new(CountingSink::default()),
                Box::new(CrossTraffic::new(1, 500_000_000, 1200, 50 * MS)),
            ];
            let topo = star(&mut sim, nodes, LinkCfg::dcn(10, 2), 0);
            sim.run();
            let sink = sim.node_as::<CountingSink>(topo.hosts[0]);
            (sink.pkts, sink.last_arrival)
        };
        assert_eq!(run(11), run(11));
        assert_ne!(run(11), run(12));
    }
}
