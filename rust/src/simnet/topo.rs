//! Topology builders. The paper's testbed is a single-rack star: 8 workers
//! and 1 PS behind one ToR switch.

use super::{EntityId, LinkCfg, LinkId, Node, Sim};
use crate::Nanos;

/// A star topology built around one switch. `hosts[0]` is conventionally
/// the PS in the training experiments.
pub struct StarTopology {
    pub switch: EntityId,
    pub hosts: Vec<EntityId>,
    /// `uplinks[i]`: host i → switch.
    pub uplinks: Vec<LinkId>,
    /// `downlinks[i]`: switch → host i.
    pub downlinks: Vec<LinkId>,
}

/// Build a star of `nodes.len()` hosts around a switch, all edge links
/// sharing `cfg`. The switch adds `fwd_delay` forwarding latency.
pub fn star(sim: &mut Sim, nodes: Vec<Box<dyn Node>>, cfg: LinkCfg, fwd_delay: Nanos) -> StarTopology {
    let switch = sim.add_switch(fwd_delay);
    let mut hosts = Vec::new();
    let mut uplinks = Vec::new();
    let mut downlinks = Vec::new();
    for node in nodes {
        let h = sim.add_host(node);
        let (up, down) = sim.add_duplex(h, switch, cfg);
        sim.set_default_uplink(h, up);
        hosts.push(h);
        uplinks.push(up);
        downlinks.push(down);
    }
    StarTopology { switch, hosts, uplinks, downlinks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::{Ctx, Packet};
    use crate::wire::PacketKind;
    use std::cell::RefCell;
    use std::rc::Rc;

    struct Echo {
        seen: Rc<RefCell<usize>>,
    }
    impl Node for Echo {
    fn as_any(&mut self) -> &mut dyn std::any::Any { self }
        fn on_packet(&mut self, ctx: &mut Ctx, pkt: Packet) {
            *self.seen.borrow_mut() += 1;
            if let PacketKind::Raw(0) = pkt.kind {
                // bounce back once
                ctx.send(Packet::new(ctx.me, pkt.src, 100, 0, PacketKind::Raw(1)));
            }
        }
    }
    struct Pinger {
        target: EntityId,
        seen: Rc<RefCell<usize>>,
    }
    impl Node for Pinger {
    fn as_any(&mut self) -> &mut dyn std::any::Any { self }
        fn start(&mut self, ctx: &mut Ctx) {
            ctx.send(Packet::new(ctx.me, self.target, 100, 0, PacketKind::Raw(0)));
        }
        fn on_packet(&mut self, _ctx: &mut Ctx, _pkt: Packet) {
            *self.seen.borrow_mut() += 1;
        }
    }

    #[test]
    fn star_all_pairs_reachable() {
        let pong = Rc::new(RefCell::new(0));
        let echo_seen = Rc::new(RefCell::new(0));
        let mut sim = Sim::new(1);
        // hosts: 0 = echo target, 1..=4 pingers — ids assigned after switch.
        let mut nodes: Vec<Box<dyn Node>> = vec![Box::new(Echo { seen: echo_seen.clone() })];
        for _ in 0..4 {
            nodes.push(Box::new(Pinger { target: 1, seen: pong.clone() }));
        }
        // NOTE: `star` adds the switch first, so hosts[0] has entity id 1.
        let topo = star(&mut sim, nodes, LinkCfg::dcn(10, 2), 0);
        assert_eq!(topo.hosts[0], 1);
        sim.run();
        assert_eq!(*echo_seen.borrow(), 4);
        assert_eq!(*pong.borrow(), 4);
    }
}
