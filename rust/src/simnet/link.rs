//! Unidirectional link: serializer + drop-tail queue + propagation delay +
//! non-congestion loss model + optional ECN marking.

use super::{EntityId, Packet};
use crate::util::Pcg64;
use crate::Nanos;
use std::collections::VecDeque;

/// Non-congestion loss model applied to packets leaving the serializer.
/// This models corruption-style loss (optics, wireless, microbursts on
/// upstream devices) — orthogonal to drop-tail queue overflow, which the
/// link also models.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LossModel {
    None,
    /// Independent per-packet drop with probability `p`.
    Bernoulli { p: f64 },
    /// Two-state Gilbert–Elliott bursty loss.
    GilbertElliott {
        /// P(good → bad) per packet.
        p_gb: f64,
        /// P(bad → good) per packet.
        p_bg: f64,
        /// Loss probability in the good state.
        loss_good: f64,
        /// Loss probability in the bad state.
        loss_bad: f64,
    },
}

impl LossModel {
    /// Average loss rate implied by the model (steady state for GE).
    pub fn mean_rate(&self) -> f64 {
        match *self {
            LossModel::None => 0.0,
            LossModel::Bernoulli { p } => p,
            LossModel::GilbertElliott { p_gb, p_bg, loss_good, loss_bad } => {
                if p_gb + p_bg == 0.0 {
                    loss_good
                } else {
                    let frac_bad = p_gb / (p_gb + p_bg);
                    loss_good * (1.0 - frac_bad) + loss_bad * frac_bad
                }
            }
        }
    }
}

/// Static link configuration.
#[derive(Debug, Clone, Copy)]
pub struct LinkCfg {
    /// Serialization rate in bits per second.
    pub rate_bps: u64,
    /// One-way propagation delay.
    pub delay: Nanos,
    /// Drop-tail queue capacity in bytes (excludes the packet in
    /// serialization).
    pub queue_cap_bytes: u64,
    /// ECN marking threshold in queued bytes (DCTCP-style step marking),
    /// if enabled.
    pub ecn_thresh_bytes: Option<u64>,
    /// Non-congestion loss model.
    pub loss: LossModel,
}

impl LinkCfg {
    /// A typical data-center edge link: `rate_gbps` Gbps, `delay_us` µs,
    /// 256 KiB of buffer, no ECN, no random loss.
    pub fn dcn(rate_gbps: u64, delay_us: u64) -> LinkCfg {
        LinkCfg {
            rate_bps: rate_gbps * 1_000_000_000,
            delay: delay_us * crate::US,
            queue_cap_bytes: 256 * 1024,
            ecn_thresh_bytes: None,
            loss: LossModel::None,
        }
    }

    /// A WAN-ish link: `rate_mbps` Mbps, `delay_ms` ms, deeper buffer.
    pub fn wan(rate_mbps: u64, delay_ms: u64) -> LinkCfg {
        LinkCfg {
            rate_bps: rate_mbps * 1_000_000,
            delay: delay_ms * crate::MS,
            queue_cap_bytes: 2 * 1024 * 1024,
            ecn_thresh_bytes: None,
            loss: LossModel::None,
        }
    }

    pub fn with_loss(mut self, loss: LossModel) -> LinkCfg {
        self.loss = loss;
        self
    }

    pub fn with_queue(mut self, cap_bytes: u64) -> LinkCfg {
        self.queue_cap_bytes = cap_bytes;
        self
    }

    pub fn with_ecn(mut self, thresh_bytes: u64) -> LinkCfg {
        self.ecn_thresh_bytes = Some(thresh_bytes);
        self
    }

    /// Time to serialize `bytes` onto this link.
    #[inline]
    pub fn ser_time(&self, bytes: u32) -> Nanos {
        // bytes*8 bits / rate_bps seconds → ns. Use u128 to avoid overflow.
        ((bytes as u128 * 8 * 1_000_000_000) / self.rate_bps as u128) as Nanos
    }

    /// Bandwidth-delay product of this link in bytes (one-way delay).
    pub fn bdp_bytes(&self) -> u64 {
        (self.rate_bps as u128 * self.delay as u128 / 8 / 1_000_000_000) as u64
    }
}

/// Per-link counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct LinkStats {
    pub tx_pkts: u64,
    pub tx_bytes: u64,
    pub drops_queue: u64,
    pub drops_random: u64,
    pub ecn_marks: u64,
    /// Total busy (serializing) time, for utilization measurements.
    pub busy: Nanos,
}

/// Runtime state of a unidirectional link.
#[derive(Debug)]
pub struct Link {
    pub cfg: LinkCfg,
    pub src: EntityId,
    pub dst: EntityId,
    pub(crate) queue: VecDeque<Packet>,
    pub(crate) queued_bytes: u64,
    /// Whether the serializer currently holds a packet.
    pub(crate) busy: bool,
    pub stats: LinkStats,
    /// Gilbert–Elliott state: true = bad.
    pub(crate) ge_bad: bool,
    pub(crate) rng: Pcg64,
}

impl Link {
    pub fn new(cfg: LinkCfg, src: EntityId, dst: EntityId, rng: Pcg64) -> Link {
        Link {
            cfg,
            src,
            dst,
            queue: VecDeque::new(),
            queued_bytes: 0,
            busy: false,
            stats: LinkStats::default(),
            ge_bad: false,
            rng,
        }
    }

    /// Current queue occupancy in bytes.
    pub fn queue_bytes(&self) -> u64 {
        self.queued_bytes
    }

    /// Decide whether the departing packet is lost to the wire.
    pub(crate) fn wire_loss(&mut self) -> bool {
        match self.cfg.loss {
            LossModel::None => false,
            LossModel::Bernoulli { p } => self.rng.chance(p),
            LossModel::GilbertElliott { p_gb, p_bg, loss_good, loss_bad } => {
                // Transition, then sample loss in the new state.
                if self.ge_bad {
                    if self.rng.chance(p_bg) {
                        self.ge_bad = false;
                    }
                } else if self.rng.chance(p_gb) {
                    self.ge_bad = true;
                }
                let p = if self.ge_bad { loss_bad } else { loss_good };
                self.rng.chance(p)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ser_time_math() {
        let cfg = LinkCfg::dcn(10, 1); // 10 Gbps
        // 1500 B = 12000 bits @ 10 Gbps = 1.2 µs.
        assert_eq!(cfg.ser_time(1500), 1200);
        let g1 = LinkCfg::dcn(1, 1);
        assert_eq!(g1.ser_time(1500), 12_000);
    }

    #[test]
    fn bdp_math() {
        // 1 Gbps * 40 ms = 5 MB.
        let cfg = LinkCfg { delay: 40 * crate::MS, ..LinkCfg::dcn(1, 0) };
        assert_eq!(cfg.bdp_bytes(), 5_000_000);
    }

    #[test]
    fn bernoulli_loss_rate() {
        let cfg = LinkCfg::dcn(10, 1).with_loss(LossModel::Bernoulli { p: 0.05 });
        let mut link = Link::new(cfg, 0, 1, Pcg64::seeded(1));
        let n = 100_000;
        let losses = (0..n).filter(|_| link.wire_loss()).count();
        let rate = losses as f64 / n as f64;
        assert!((rate - 0.05).abs() < 0.005, "rate {rate}");
    }

    #[test]
    fn gilbert_elliott_mean_rate() {
        let loss = LossModel::GilbertElliott {
            p_gb: 0.01,
            p_bg: 0.1,
            loss_good: 0.0,
            loss_bad: 0.5,
        };
        // steady-state bad fraction = 0.01/0.11 ≈ 0.0909 → mean ≈ 0.0455
        assert!((loss.mean_rate() - 0.0455).abs() < 0.001);
        let cfg = LinkCfg::dcn(10, 1).with_loss(loss);
        let mut link = Link::new(cfg, 0, 1, Pcg64::seeded(2));
        let n = 200_000;
        let losses = (0..n).filter(|_| link.wire_loss()).count();
        let rate = losses as f64 / n as f64;
        assert!((rate - loss.mean_rate()).abs() < 0.01, "rate {rate}");
    }
}
