//! Deterministic packet-level discrete-event network simulator.
//!
//! This is the testbed substrate standing in for the paper's 9-machine
//! cluster (8 workers + 1 PS behind one ToR switch): unidirectional links
//! with a serialization rate, propagation delay, a drop-tail queue with
//! optional ECN marking, and a non-congestion loss model; switches that
//! forward between links; and protocol endpoints attached as [`Node`]s.
//!
//! Everything is driven from a single event queue keyed by `(time, seq)`
//! — a hierarchical timer wheel ([`eventq::EventQueue`]) with the exact
//! pop order of the binary heap it replaced — so runs are bit-reproducible
//! for a given seed: the property the paper-figure benches rely on.

pub mod eventq;
mod link;
pub mod pool;
mod sim;
mod topo;

pub use eventq::EventQueue;
pub use link::{Link, LinkCfg, LinkStats, LossModel};
pub use pool::{BufId, BufPool};
pub use sim::{Ctx, EntityId, Event, LinkId, Node, Sim};
pub use topo::{
    n_rack, star, star_with, two_rack, CountingSink, CrossTraffic, RackTopology, StarTopology,
    TwoRackTopology,
};

use crate::wire::PacketKind;

/// A packet on the wire. `size` is the total wire size in bytes (headers
/// included); `kind` carries the protocol payload.
#[derive(Debug, Clone)]
pub struct Packet {
    pub src: EntityId,
    pub dst: EntityId,
    pub size: u32,
    /// Flow tag for per-flow accounting (protocol-defined meaning).
    pub flow: u64,
    /// ECN Congestion-Experienced mark (set by queues past the threshold).
    pub ecn_ce: bool,
    pub kind: PacketKind,
}

impl Packet {
    pub fn new(src: EntityId, dst: EntityId, size: u32, flow: u64, kind: PacketKind) -> Packet {
        Packet { src, dst, size, flow, ecn_ce: false, kind }
    }
}
