//! The simulator's event queue: a hierarchical timer wheel with
//! heap-identical ordering.
//!
//! The original engine kept every pending event in a
//! `BinaryHeap<Reverse<Scheduled>>` ordered by `(time, seq)`. That is
//! O(log n) per schedule/pop with poor cache behavior once a
//! datacenter-scale incast keeps hundreds of thousands of events in
//! flight; the wheel replaces it with O(1) amortized schedule and pop.
//!
//! **Ordering contract** (the golden-byte contract of every scenario
//! report): events pop in strictly nondecreasing `(time, seq)` order, where
//! `seq` is the schedule-call counter — i.e. exactly the order the old
//! heap produced, including FIFO ties at the same instant. The equivalence
//! test `rust/tests/eventcore.rs` drives randomized workloads through this
//! wheel and a reference heap side by side and asserts identical pop
//! sequences.
//!
//! # Design
//!
//! Eleven levels of 64 slots each cover the full 64-bit nanosecond clock
//! (6 bits per level). An event at absolute time `at` lives at the level
//! of the highest 6-bit block in which `at` differs from the queue's
//! current time (`at == now` → level 0), in the slot indexed by that
//! block's value:
//!
//! ```text
//! level = highest_set_bit(at ^ now) / 6      (0 when at == now)
//! slot  = (at >> (6 * level)) & 63
//! ```
//!
//! Level 0 slots therefore hold exactly one timestamp each, so FIFO order
//! within a slot *is* seq order; higher-level slots hold whole time blocks
//! that **cascade** down (stably, preserving insertion order) as the clock
//! advances into them. A 64-bit occupancy bitmap per level finds the next
//! non-empty slot with `trailing_zeros` — no scanning, no comparisons.
//!
//! # Invariants
//!
//! * Every stored event's time `at` satisfies `at >= now`, and its digits
//!   above its level equal `now`'s (maintained by cascading exactly when
//!   the clock enters a slot's block).
//! * `schedule` requires `at >= now`. `now` advances only to popped event
//!   times and to slot starts `<= until` of a bounded pop — so inside the
//!   simulator, where scheduling only happens while an event is being
//!   dispatched (at which instant `now` equals that event's timestamp),
//!   the requirement holds by construction. Debug builds assert it.
//! * Slot vectors keep their capacity when drained (and the cascade
//!   scratch buffer is reused), so steady-state schedule/pop traffic
//!   performs **zero heap allocations** once the wheel has warmed up.
//!
//! Cancellation is tombstone-based: `cancel` marks the sequence number and
//! the entry is skipped (and the tombstone dropped) when its slot drains.
//! The simulator itself never cancels; the operation exists for the
//! equivalence test's workload and future protocol timer reuse.

use std::collections::{HashSet, VecDeque};

/// Bits per wheel level.
const BITS: u32 = 6;
/// Slots per level (`1 << BITS`).
const SLOTS: usize = 1 << BITS;
/// Levels needed to cover a 64-bit clock at 6 bits each.
const LEVELS: usize = 11;

struct Entry<T> {
    at: u64,
    seq: u64,
    item: T,
}

/// A hierarchical timer wheel ordered by `(time, seq)` — drop-in
/// replacement for the simulator's former binary heap (see module docs).
pub struct EventQueue<T> {
    /// The queue clock: the largest slot start / event time reached so
    /// far. All stored entries have `at >= now`.
    now: u64,
    /// Schedule-call counter; the next schedule gets `seq + 1`.
    seq: u64,
    /// Live (scheduled, not yet popped or cancelled) entries.
    len: usize,
    /// `LEVELS * SLOTS` slot vectors, level-major.
    slots: Vec<Vec<Entry<T>>>,
    /// Per-level occupancy bitmaps (bit `s` ⇔ slot `s` non-empty).
    occ: [u64; LEVELS],
    /// Entries of the level-0 slot currently being served (all share one
    /// timestamp, in seq order).
    ready: VecDeque<Entry<T>>,
    /// Scratch for stable cascades (capacity reused across cascades).
    cascade_buf: Vec<Entry<T>>,
    /// Tombstoned sequence numbers, consumed when their entry surfaces.
    cancelled: HashSet<u64>,
    /// Debug-only liveness tracking: catches cancels of already-delivered
    /// events (a contract violation that would corrupt `len`).
    #[cfg(debug_assertions)]
    live: HashSet<u64>,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        let mut slots = Vec::with_capacity(LEVELS * SLOTS);
        slots.resize_with(LEVELS * SLOTS, Vec::new);
        EventQueue {
            now: 0,
            seq: 0,
            len: 0,
            slots,
            occ: [0; LEVELS],
            ready: VecDeque::new(),
            cascade_buf: Vec::new(),
            cancelled: HashSet::new(),
            #[cfg(debug_assertions)]
            live: HashSet::new(),
        }
    }

    /// Live events (scheduled, not yet popped or cancelled).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The queue clock (see module docs); `schedule` requires `at >= now()`.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Schedule `item` at absolute time `at` (which must be `>= now()`;
    /// debug-asserted, clamped in release builds). Returns the event's
    /// sequence number — the FIFO tiebreaker, usable with [`cancel`].
    ///
    /// [`cancel`]: EventQueue::cancel
    pub fn schedule(&mut self, at: u64, item: T) -> u64 {
        debug_assert!(
            at >= self.now,
            "schedule in the past: at={at} < now={}",
            self.now
        );
        let at = at.max(self.now);
        self.seq += 1;
        let seq = self.seq;
        #[cfg(debug_assertions)]
        self.live.insert(seq);
        self.insert(Entry { at, seq, item });
        self.len += 1;
        seq
    }

    /// Cancel a pending event by its sequence number. Returns `true` if a
    /// tombstone was planted. Cancelling an already-delivered event is a
    /// caller bug (debug-asserted); the simulator itself never cancels.
    pub fn cancel(&mut self, seq: u64) -> bool {
        if seq == 0 || seq > self.seq || self.cancelled.contains(&seq) {
            return false;
        }
        #[cfg(debug_assertions)]
        {
            debug_assert!(
                self.live.contains(&seq),
                "cancel of an already-delivered event (seq {seq})"
            );
            self.live.remove(&seq);
        }
        self.cancelled.insert(seq);
        self.len -= 1;
        true
    }

    /// Pop the earliest event in `(time, seq)` order.
    pub fn pop(&mut self) -> Option<(u64, u64, T)> {
        self.pop_at_most(u64::MAX)
    }

    /// Pop the earliest event if its time is `<= until`; otherwise leave
    /// it pending and return `None`. (The clock may still advance up to
    /// `until` internally while cascading — never past it.)
    pub fn pop_at_most(&mut self, until: u64) -> Option<(u64, u64, T)> {
        loop {
            // Serve the level-0 slot currently in flight.
            while let Some(head) = self.ready.front() {
                if head.at > until {
                    return None;
                }
                let e = self.ready.pop_front().expect("front was Some");
                if self.cancelled.remove(&e.seq) {
                    continue; // tombstoned: skip, already uncounted
                }
                #[cfg(debug_assertions)]
                self.live.remove(&e.seq);
                self.len -= 1;
                return Some((e.at, e.seq, e.item));
            }
            if self.len == 0 {
                return None;
            }
            // Level 0: slots hold single timestamps within the current
            // 64 ns block; the lowest occupied one is the global minimum.
            if self.occ[0] != 0 {
                let s = self.occ[0].trailing_zeros() as usize;
                let t = (self.now & !(SLOTS as u64 - 1)) | s as u64;
                debug_assert!(t >= self.now, "stale level-0 slot at {t} (now {})", self.now);
                if t > until {
                    return None;
                }
                self.occ[0] &= !(1u64 << s);
                self.now = t;
                let slot = &mut self.slots[s];
                self.ready.extend(slot.drain(..)); // capacity stays in the slot
                continue;
            }
            // Higher levels: advance to the lowest occupied slot's block
            // start and cascade its entries down (stably).
            let lvl = (1..LEVELS)
                .find(|&l| self.occ[l] != 0)
                .expect("len > 0 but every wheel level is empty");
            let s = self.occ[lvl].trailing_zeros() as usize;
            let shift = BITS * lvl as u32;
            // Digits of `now` above this level, with the level digit set to
            // `s` and everything below zeroed = the slot's block start.
            let upper = if shift + BITS >= 64 {
                0
            } else {
                self.now & !((1u64 << (shift + BITS)) - 1)
            };
            let slot_start = upper | ((s as u64) << shift);
            debug_assert!(
                slot_start >= self.now,
                "stale level-{lvl} slot at {slot_start} (now {})",
                self.now
            );
            if slot_start > until {
                return None;
            }
            self.occ[lvl] &= !(1u64 << s);
            self.now = slot_start;
            let mut buf = std::mem::take(&mut self.cascade_buf);
            buf.extend(self.slots[lvl * SLOTS + s].drain(..));
            for e in buf.drain(..) {
                self.insert(e); // lands strictly below `lvl`
            }
            self.cascade_buf = buf;
        }
    }

    fn insert(&mut self, e: Entry<T>) {
        let lvl = if e.at == self.now {
            0
        } else {
            ((63 - (e.at ^ self.now).leading_zeros()) / BITS) as usize
        };
        let s = ((e.at >> (BITS * lvl as u32)) & (SLOTS as u64 - 1)) as usize;
        self.occ[lvl] |= 1u64 << s;
        self.slots[lvl * SLOTS + s].push(e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut EventQueue<u32>) -> Vec<(u64, u64, u32)> {
        let mut out = Vec::new();
        while let Some(e) = q.pop() {
            out.push(e);
        }
        out
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut q = EventQueue::new();
        q.schedule(300, 3);
        q.schedule(100, 1);
        q.schedule(200, 2);
        q.schedule(100, 10); // same instant: FIFO by insertion
        let got: Vec<(u64, u32)> = drain(&mut q).into_iter().map(|(t, _, x)| (t, x)).collect();
        assert_eq!(got, vec![(100, 1), (100, 10), (200, 2), (300, 3)]);
    }

    #[test]
    fn same_instant_ties_are_fifo_across_many_events() {
        let mut q = EventQueue::new();
        for i in 0..1000u32 {
            q.schedule(42, i);
        }
        let got: Vec<u32> = drain(&mut q).into_iter().map(|(_, _, x)| x).collect();
        assert_eq!(got, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn far_future_events_cascade_correctly() {
        let mut q = EventQueue::new();
        q.schedule(u64::MAX, 9);
        q.schedule(1 << 40, 4);
        q.schedule(5, 0);
        q.schedule((1 << 40) + 1, 5);
        let got: Vec<u64> = drain(&mut q).into_iter().map(|(t, _, _)| t).collect();
        assert_eq!(got, vec![5, 1 << 40, (1 << 40) + 1, u64::MAX]);
        assert!(q.is_empty());
    }

    #[test]
    fn bounded_pop_respects_the_horizon() {
        let mut q = EventQueue::new();
        q.schedule(100, 1);
        q.schedule(5000, 2);
        assert_eq!(q.pop_at_most(50), None);
        assert_eq!(q.pop_at_most(100).map(|(t, _, x)| (t, x)), Some((100, 1)));
        assert_eq!(q.pop_at_most(4999), None);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_at_most(5000).map(|(t, _, x)| (t, x)), Some((5000, 2)));
        assert!(q.pop().is_none());
    }

    #[test]
    fn bounded_pop_never_advances_past_until() {
        let mut q = EventQueue::new();
        // An event deep in a higher-level block: a bounded pop below its
        // slot start must not move the clock at all; one inside the block
        // may cascade but never past `until`.
        q.schedule(1_000_000, 7);
        assert_eq!(q.pop_at_most(400), None);
        assert_eq!(q.now(), 0);
        assert_eq!(q.pop_at_most(999_999), None);
        assert!(q.now() <= 999_999);
        assert_eq!(q.pop_at_most(1_000_000).map(|(t, _, _)| t), Some(1_000_000));
    }

    #[test]
    fn cancellation_skips_events_and_updates_len() {
        let mut q = EventQueue::new();
        let a = q.schedule(10, 1);
        let b = q.schedule(10, 2);
        let c = q.schedule(20, 3);
        assert!(q.cancel(b));
        assert!(!q.cancel(b), "double cancel is a no-op");
        assert!(!q.cancel(999), "unknown seq is a no-op");
        assert_eq!(q.len(), 2);
        let got: Vec<u64> = drain(&mut q).into_iter().map(|(_, s, _)| s).collect();
        assert_eq!(got, vec![a, c]);
        assert!(q.is_empty());
    }

    #[test]
    fn cancel_everything_leaves_an_empty_queue() {
        let mut q = EventQueue::new();
        let seqs: Vec<u64> = (0..10).map(|i| q.schedule(100 + i, i as u32)).collect();
        for s in seqs {
            assert!(q.cancel(s));
        }
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn interleaved_schedule_and_pop_keeps_order() {
        let mut q = EventQueue::new();
        q.schedule(10, 0);
        q.schedule(30, 1);
        assert_eq!(q.pop().map(|(t, _, x)| (t, x)), Some((10, 0)));
        // Scheduling at the current instant lands after nothing (queue has
        // only later events) but before them in time.
        q.schedule(10, 2);
        q.schedule(20, 3);
        let got: Vec<(u64, u32)> = drain(&mut q).into_iter().map(|(t, _, x)| (t, x)).collect();
        assert_eq!(got, vec![(10, 2), (20, 3), (30, 1)]);
    }

    #[test]
    fn seq_numbers_are_the_schedule_counter() {
        let mut q = EventQueue::new();
        assert_eq!(q.schedule(1, 0), 1);
        assert_eq!(q.schedule(1, 0), 2);
        assert_eq!(q.schedule(2, 0), 3);
    }
}

impl<T> std::fmt::Debug for EventQueue<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventQueue")
            .field("now", &self.now)
            .field("len", &self.len)
            .field("seq", &self.seq)
            .finish()
    }
}
