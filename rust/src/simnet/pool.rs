//! An arena/free-list pool for payload byte buffers.
//!
//! The real-socket UDP receive path used to allocate a fresh `Vec<u8>` per
//! reassembled data segment — tens of thousands of allocations per
//! gather round at incast degree 1024. [`BufPool`] recycles those buffers
//! instead: a `take` hands out a **cleared** buffer (stale payload bytes
//! from a previous flow never leak into the next — segments are
//! copy-extended, so a dirty buffer would be a silent correctness bug),
//! and a `recycle` returns it for reuse.
//!
//! Buffers are identified by [`BufId`] handles into the arena rather than
//! moved by value, so a double `recycle` is *detectable* — debug builds
//! assert on it (the test profile compiles with `debug-assertions = true`).
//!
//! The pool grows without bound under burst, but `recycle` drops the
//! capacity of any buffer beyond the `high_water` free-list cap, so a
//! one-off spike does not pin its peak memory forever.

/// Handle to a pooled buffer. Obtained from [`BufPool::take`]; the buffer
/// stays owned by the pool and is accessed via [`BufPool::get`]/[`get_mut`].
///
/// [`get_mut`]: BufPool::get_mut
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BufId(u32);

/// Arena of reusable byte buffers with a free list (see module docs).
pub struct BufPool {
    bufs: Vec<Vec<u8>>,
    free: Vec<u32>,
    /// `live[i]` ⇔ buffer `i` is checked out. Drives the double-free
    /// debug-assert and makes `recycle` idempotence violations visible.
    live: Vec<bool>,
    /// Max buffers kept on the free list with capacity intact; recycles
    /// beyond this release their allocation.
    high_water: usize,
}

impl BufPool {
    /// An empty pool keeping at most `high_water` spare buffers warm.
    pub fn new(high_water: usize) -> BufPool {
        BufPool { bufs: Vec::new(), free: Vec::new(), live: Vec::new(), high_water }
    }

    /// Check out a cleared (empty, possibly pre-allocated) buffer.
    pub fn take(&mut self) -> BufId {
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                let id = self.bufs.len() as u32;
                self.bufs.push(Vec::new());
                self.live.push(false);
                id
            }
        };
        debug_assert!(!self.live[id as usize], "free list handed out a live buffer");
        self.live[id as usize] = true;
        self.bufs[id as usize].clear();
        BufId(id)
    }

    /// Return a buffer to the pool. Its contents become invalid; the next
    /// [`take`] may hand the same (cleared) buffer to a different flow.
    /// Recycling a buffer twice is a caller bug (debug-asserted).
    ///
    /// [`take`]: BufPool::take
    pub fn recycle(&mut self, id: BufId) {
        let i = id.0 as usize;
        debug_assert!(self.live[i], "double recycle of pooled buffer {}", id.0);
        if !self.live[i] {
            return; // release builds: ignore rather than corrupt the free list
        }
        self.live[i] = false;
        if self.free.len() >= self.high_water {
            // Past the high-water cap: keep the slot but drop the memory.
            self.bufs[i] = Vec::new();
        }
        self.free.push(id.0);
    }

    pub fn get(&self, id: BufId) -> &Vec<u8> {
        debug_assert!(self.live[id.0 as usize], "access to a recycled buffer");
        &self.bufs[id.0 as usize]
    }

    pub fn get_mut(&mut self, id: BufId) -> &mut Vec<u8> {
        debug_assert!(self.live[id.0 as usize], "access to a recycled buffer");
        &mut self.bufs[id.0 as usize]
    }

    /// Buffers currently checked out.
    pub fn live_count(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    /// Total arena slots (checked out + free).
    pub fn capacity(&self) -> usize {
        self.bufs.len()
    }

    /// Free-list slots still holding allocated capacity (spare memory kept
    /// warm for the next burst).
    pub fn warm_spares(&self) -> usize {
        self.free.iter().filter(|&&i| self.bufs[i as usize].capacity() > 0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycled_buffers_come_back_cleared() {
        let mut pool = BufPool::new(8);
        let a = pool.take();
        pool.get_mut(a).extend_from_slice(b"stale payload bytes");
        pool.recycle(a);
        let b = pool.take();
        // Same arena slot, but no stale bytes leak across flows.
        assert!(pool.get(b).is_empty(), "recycled buffer not cleared");
        pool.get_mut(b).extend_from_slice(b"xy");
        assert_eq!(pool.get(b).as_slice(), b"xy");
    }

    #[test]
    fn pool_grows_under_burst_and_reuses_after() {
        let mut pool = BufPool::new(64);
        let burst: Vec<BufId> = (0..100).map(|_| pool.take()).collect();
        assert_eq!(pool.capacity(), 100);
        assert_eq!(pool.live_count(), 100);
        for id in burst {
            pool.recycle(id);
        }
        assert_eq!(pool.live_count(), 0);
        // A second burst reuses the arena: no new slots.
        let again: Vec<BufId> = (0..100).map(|_| pool.take()).collect();
        assert_eq!(pool.capacity(), 100);
        for id in again {
            pool.recycle(id);
        }
    }

    #[test]
    fn recycle_shrinks_to_the_high_water_cap() {
        let mut pool = BufPool::new(4);
        let ids: Vec<BufId> = (0..10).map(|_| pool.take()).collect();
        for &id in &ids {
            pool.get_mut(id).extend_from_slice(&[0u8; 4096]);
        }
        for id in ids {
            pool.recycle(id);
        }
        // First 4 recycles keep their capacity; the rest release it.
        assert_eq!(pool.warm_spares(), 4);
        assert_eq!(pool.capacity(), 10, "arena slots are kept, memory is not");
    }

    #[test]
    fn steady_state_take_recycle_allocates_nothing() {
        let mut pool = BufPool::new(8);
        let warm = pool.take();
        pool.get_mut(warm).reserve(2048);
        let warm_cap = pool.get(warm).capacity();
        pool.recycle(warm);
        for _ in 0..1000 {
            let id = pool.take();
            assert!(pool.get(id).capacity() >= warm_cap, "warm capacity was lost");
            pool.get_mut(id).extend_from_slice(&[7u8; 1024]);
            pool.recycle(id);
        }
        assert_eq!(pool.capacity(), 1, "steady state must not grow the arena");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "double recycle")]
    fn double_free_is_caught_in_debug_builds() {
        let mut pool = BufPool::new(8);
        let id = pool.take();
        pool.recycle(id);
        pool.recycle(id); // caller bug: debug-assert fires
    }
}
