//! The discrete-event engine: entities (hosts and switches), links between
//! them, and a `(time, seq)`-ordered event queue (a hierarchical timer
//! wheel, [`super::eventq::EventQueue`], which preserves the former binary
//! heap's exact pop order — including same-instant FIFO ties — at O(1)
//! amortized cost per event).
//!
//! Protocol endpoints implement [`Node`] and interact with the network only
//! through [`Ctx`], which exposes the clock, packet transmission, timers,
//! and a per-node RNG stream — the same surface the real-socket driver
//! provides, keeping protocol code sans-IO.

use super::eventq::EventQueue;
use super::link::{Link, LinkCfg};
use super::Packet;
use crate::trace::{Record, TraceSink};
use crate::util::Pcg64;
use crate::Nanos;

/// Index of a host or switch in the simulation.
pub type EntityId = usize;
/// Index of a unidirectional link.
pub type LinkId = usize;

/// A protocol endpoint (or application) attached to a host entity.
pub trait Node: std::any::Any {
    /// Called once when the simulation starts.
    fn start(&mut self, _ctx: &mut Ctx) {}
    /// A packet addressed to this host arrived.
    fn on_packet(&mut self, ctx: &mut Ctx, pkt: Packet);
    /// A timer set via [`Ctx::set_timer`] fired.
    fn on_timer(&mut self, _ctx: &mut Ctx, _token: u64) {}
    /// Downcast support, for extracting results after a run. Implement as
    /// `fn as_any(&mut self) -> &mut dyn std::any::Any { self }`.
    fn as_any(&mut self) -> &mut dyn std::any::Any;
}

/// Simulator events.
#[derive(Debug)]
pub enum Event {
    /// The serializer of `link` finished the packet at the head.
    Dequeue(LinkId),
    /// `pkt` finished propagation over `link` and arrives at its dst.
    /// If the high `VIRTUAL_FWD` bit is set in the link id, this is a
    /// delayed switch-forward enqueue instead.
    Arrive(LinkId, Packet),
    /// Timer for `entity` with an opaque token.
    Timer(EntityId, u64),
}

/// Marker bit: "arrival is actually a delayed switch-forward enqueue onto
/// the link in the low bits".
const VIRTUAL_FWD: usize = 1 << 62;

/// "No exact route" sentinel in the dense per-entity route rows.
const NO_ROUTE: u32 = u32::MAX;

enum Entity {
    Host,
    Switch { fwd_delay: Nanos },
}

/// Everything a [`Node`] may touch while handling an event.
pub struct Ctx<'a> {
    net: &'a mut NetState,
    /// The entity id of the node being called.
    pub me: EntityId,
}

impl<'a> Ctx<'a> {
    /// Current simulation time.
    #[inline]
    pub fn now(&self) -> Nanos {
        self.net.now
    }

    /// Transmit a packet from this node. Routing: a direct route to
    /// `pkt.dst` if one exists, otherwise the node's default uplink.
    /// Panics if the node has no way to reach the destination (a topology
    /// bug, not a runtime condition).
    pub fn send(&mut self, pkt: Packet) {
        let link = self
            .net
            .route(self.me, pkt.dst)
            .unwrap_or_else(|| panic!("no route from {} to {}", self.me, pkt.dst));
        self.net.enqueue(link, pkt);
    }

    /// Arm a timer at absolute time `at` (clamped to now) with a token.
    pub fn set_timer(&mut self, at: Nanos, token: u64) {
        let at = at.max(self.net.now);
        self.net.schedule(at, Event::Timer(self.me, token));
    }

    /// Arm a timer `delay` from now.
    pub fn set_timer_after(&mut self, delay: Nanos, token: u64) {
        self.net.schedule(self.net.now + delay, Event::Timer(self.me, token));
    }

    /// Deterministic per-node RNG stream.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.net.node_rngs[self.me]
    }

    /// Read-only view of a link's queue occupancy (instrumentation only).
    pub fn link_queue_bytes(&self, link: LinkId) -> u64 {
        self.net.links[link].queue_bytes()
    }

    /// True when a [`crate::trace`] capture scope is recording this
    /// simulation. Nodes guard record construction behind this so the
    /// disabled path costs one branch and builds nothing.
    #[inline]
    pub fn trace_on(&self) -> bool {
        self.net.trace.is_some()
    }

    /// Append a protocol-level record to this simulation's trace (no-op
    /// when tracing is off).
    pub fn trace(&mut self, rec: Record) {
        if let Some(t) = &self.net.trace {
            t.borrow_mut().record(rec);
        }
    }
}

/// Network-side state, split from the node list so nodes can be invoked
/// with `&mut` access to the network.
struct NetState {
    now: Nanos,
    queue: EventQueue<Event>,
    links: Vec<Link>,
    entities: Vec<Entity>,
    /// Exact routes as dense per-entity rows: `routes[src][dst]` is a link
    /// id or [`NO_ROUTE`]. An indexed load per hop instead of the former
    /// `HashMap<(EntityId, EntityId), LinkId>`'s SipHash per packet.
    routes: Vec<Vec<u32>>,
    /// Fallback uplink per entity.
    default_uplink: Vec<Option<LinkId>>,
    node_rngs: Vec<Pcg64>,
    events_processed: u64,
    /// The capture scope's sink, resolved once at `Sim::new`; `None`
    /// (tracing off) costs one branch per hook and nothing else.
    trace: Option<crate::trace::SharedSink>,
}

impl NetState {
    fn schedule(&mut self, at: Nanos, ev: Event) {
        self.queue.schedule(at, ev);
    }

    fn route(&self, at: EntityId, dst: EntityId) -> Option<LinkId> {
        self.routes[at]
            .get(dst)
            .copied()
            .filter(|&l| l != NO_ROUTE)
            .map(|l| l as LinkId)
            .or(self.default_uplink[at])
    }

    fn set_route_entry(&mut self, at: EntityId, dst: EntityId, link: LinkId) {
        debug_assert!((link as u64) < NO_ROUTE as u64, "link id overflows route table");
        let row = &mut self.routes[at];
        if row.len() <= dst {
            row.resize(dst + 1, NO_ROUTE);
        }
        row[dst] = link as u32;
    }

    /// Enqueue `pkt` on `link`: drop-tail + ECN + serializer start.
    fn enqueue(&mut self, link_id: LinkId, mut pkt: Packet) {
        let link = &mut self.links[link_id];
        if link.busy {
            if link.queued_bytes + pkt.size as u64 > link.cfg.queue_cap_bytes {
                link.stats.drops_queue += 1;
                if let Some(t) = &self.trace {
                    let rec =
                        Record::packet(crate::trace::KIND_DROP_QUEUE, self.now, link_id, &pkt);
                    t.borrow_mut().record(rec);
                }
                return;
            }
            if let Some(t) = link.cfg.ecn_thresh_bytes {
                if link.queued_bytes >= t {
                    pkt.ecn_ce = true;
                    link.stats.ecn_marks += 1;
                }
            }
            link.queued_bytes += pkt.size as u64;
            if let Some(t) = &self.trace {
                let rec = Record::packet(crate::trace::KIND_ENQUEUE, self.now, link_id, &pkt);
                t.borrow_mut().record(rec);
            }
            link.queue.push_back(pkt);
        } else {
            // Serializer idle: transmit immediately.
            link.busy = true;
            let ser = link.cfg.ser_time(pkt.size);
            link.stats.busy += ser;
            if let Some(t) = &self.trace {
                let rec = Record::packet(crate::trace::KIND_ENQUEUE, self.now, link_id, &pkt);
                t.borrow_mut().record(rec);
            }
            link.queue.push_front(pkt);
            self.schedule(self.now + ser, Event::Dequeue(link_id));
        }
    }

    /// Serializer finished: move the head packet into propagation and start
    /// the next one.
    fn dequeue(&mut self, link_id: LinkId) {
        let link = &mut self.links[link_id];
        let pkt = link.queue.pop_front().expect("dequeue on empty link queue");
        link.stats.tx_pkts += 1;
        link.stats.tx_bytes += pkt.size as u64;
        let lost = link.wire_loss();
        if lost {
            link.stats.drops_random += 1;
        }
        let delay = link.cfg.delay;
        // Start the next packet, if any.
        if let Some(next) = link.queue.front() {
            let ser = link.cfg.ser_time(next.size);
            link.stats.busy += ser;
            link.queued_bytes -= next.size as u64;
            self.schedule(self.now + ser, Event::Dequeue(link_id));
        } else {
            link.busy = false;
        }
        if let Some(t) = &self.trace {
            let mut sink = t.borrow_mut();
            sink.record(Record::packet(crate::trace::KIND_TX, self.now, link_id, &pkt));
            if lost {
                sink.record(Record::packet(crate::trace::KIND_DROP_WIRE, self.now, link_id, &pkt));
            }
        }
        if !lost {
            self.schedule(self.now + delay, Event::Arrive(link_id, pkt));
        }
    }
}

/// The simulation: entities + nodes + network state.
pub struct Sim {
    net: NetState,
    /// `nodes[i]` is `Some` iff entity `i` is a host.
    nodes: Vec<Option<Box<dyn Node>>>,
    started: bool,
    /// Safety valve against runaway simulations.
    pub max_events: u64,
    seed: u64,
}

impl Sim {
    pub fn new(seed: u64) -> Sim {
        let trace = crate::trace::active();
        if let Some(t) = &trace {
            t.borrow_mut().record(Record::sim_start(seed));
        }
        Sim {
            net: NetState {
                now: 0,
                queue: EventQueue::new(),
                links: Vec::new(),
                entities: Vec::new(),
                routes: Vec::new(),
                default_uplink: Vec::new(),
                node_rngs: Vec::new(),
                events_processed: 0,
                trace,
            },
            nodes: Vec::new(),
            started: false,
            max_events: u64::MAX,
            seed,
        }
    }

    /// Pre-size entity- and link-indexed tables for a large topology.
    /// Purely an allocation hint — behavior (and every RNG stream) is
    /// identical without it; the `topo` builders call this so thousand-host
    /// fabrics build without repeated reallocation.
    pub fn reserve(&mut self, entities: usize, links: usize) {
        self.net.entities.reserve(entities);
        self.net.routes.reserve(entities);
        self.net.default_uplink.reserve(entities);
        self.net.node_rngs.reserve(entities);
        self.nodes.reserve(entities);
        self.net.links.reserve(links);
    }

    /// Add a host entity driven by `node`.
    pub fn add_host(&mut self, node: Box<dyn Node>) -> EntityId {
        let id = self.net.entities.len();
        self.net.entities.push(Entity::Host);
        self.net.routes.push(Vec::new());
        self.net.default_uplink.push(None);
        self.net.node_rngs.push(Pcg64::new(self.seed, 1000 + id as u64));
        self.nodes.push(Some(node));
        id
    }

    /// Add a switch entity with the given store-and-forward delay.
    pub fn add_switch(&mut self, fwd_delay: Nanos) -> EntityId {
        let id = self.net.entities.len();
        self.net.entities.push(Entity::Switch { fwd_delay });
        self.net.routes.push(Vec::new());
        self.net.default_uplink.push(None);
        self.net.node_rngs.push(Pcg64::new(self.seed, 1000 + id as u64));
        self.nodes.push(None);
        id
    }

    /// Add a unidirectional link `src → dst`; installs the exact route
    /// `(src, dst) → link`.
    pub fn add_link(&mut self, src: EntityId, dst: EntityId, cfg: LinkCfg) -> LinkId {
        let id = self.net.links.len();
        let rng = Pcg64::new(self.seed, 2000 + id as u64);
        self.net.links.push(Link::new(cfg, src, dst, rng));
        self.net.set_route_entry(src, dst, id);
        id
    }

    /// Add links in both directions with the same config. Returns
    /// `(a→b, b→a)`.
    pub fn add_duplex(&mut self, a: EntityId, b: EntityId, cfg: LinkCfg) -> (LinkId, LinkId) {
        (self.add_link(a, b, cfg), self.add_link(b, a, cfg))
    }

    /// Set the default uplink (used when no exact route matches — e.g. a
    /// host whose traffic all goes through its ToR).
    pub fn set_default_uplink(&mut self, entity: EntityId, link: LinkId) {
        self.net.default_uplink[entity] = Some(link);
    }

    /// Record a link's static metadata (role, endpoints, rate, queue
    /// capacity) into the active trace so viz/diff can label it.
    /// Topology builders call this right after creating the link; no-op
    /// (one branch) when tracing is off.
    pub fn note_link_meta(&mut self, link: LinkId, role: u8) {
        if let Some(t) = &self.net.trace {
            let l = &self.net.links[link];
            let rec =
                Record::link_meta(link, role, l.src, l.dst, l.cfg.rate_bps, l.cfg.queue_cap_bytes);
            t.borrow_mut().record(rec);
        }
    }

    /// Install an exact route (used on switches: (switch, host) → downlink).
    pub fn set_route(&mut self, at: EntityId, dst: EntityId, link: LinkId) {
        self.net.set_route_entry(at, dst, link);
    }

    pub fn now(&self) -> Nanos {
        self.net.now
    }

    pub fn events_processed(&self) -> u64 {
        self.net.events_processed
    }

    pub fn link_stats(&self, link: LinkId) -> super::LinkStats {
        self.net.links[link].stats
    }

    pub fn link(&self, link: LinkId) -> &Link {
        &self.net.links[link]
    }

    /// True when no events remain — nothing can ever happen again.
    pub fn is_idle(&self) -> bool {
        self.net.queue.is_empty()
    }

    /// Sum of every link's counters (fabric-wide totals for reports).
    pub fn total_link_stats(&self) -> super::LinkStats {
        let mut t = super::LinkStats::default();
        for l in &self.net.links {
            t.tx_pkts += l.stats.tx_pkts;
            t.tx_bytes += l.stats.tx_bytes;
            t.drops_queue += l.stats.drops_queue;
            t.drops_random += l.stats.drops_random;
            t.ecn_marks += l.stats.ecn_marks;
            t.busy += l.stats.busy;
        }
        t
    }

    /// Number of entities (hosts + switches).
    pub fn entity_count(&self) -> usize {
        self.net.entities.len()
    }

    /// Typed access to a host's node (for extracting results after a run).
    /// Panics if `id` is a switch or the node is not a `T`.
    pub fn node_as<T: 'static>(&mut self, id: EntityId) -> &mut T {
        self.nodes[id]
            .as_deref_mut()
            .expect("entity is a switch")
            .as_any()
            .downcast_mut::<T>()
            .expect("node has a different concrete type")
    }

    fn start_nodes(&mut self) {
        for id in 0..self.nodes.len() {
            if let Some(mut node) = self.nodes[id].take() {
                let mut ctx = Ctx { net: &mut self.net, me: id };
                node.start(&mut ctx);
                self.nodes[id] = Some(node);
            }
        }
        self.started = true;
    }

    /// Run until the event queue is empty or the next event is past
    /// `until`. Returns the simulation time at exit.
    pub fn run_until(&mut self, until: Nanos) -> Nanos {
        if !self.started {
            self.start_nodes();
        }
        while let Some((at, _seq, ev)) = self.net.queue.pop_at_most(until) {
            self.net.now = at;
            self.net.events_processed += 1;
            assert!(
                self.net.events_processed <= self.max_events,
                "simulation exceeded max_events={}",
                self.max_events
            );
            match ev {
                Event::Dequeue(link) => self.net.dequeue(link),
                Event::Arrive(link, pkt) => {
                    if link & VIRTUAL_FWD != 0 {
                        self.net.enqueue(link & !VIRTUAL_FWD, pkt);
                    } else {
                        self.deliver(link, pkt);
                    }
                }
                Event::Timer(entity, token) => {
                    if let Some(t) = &self.net.trace {
                        t.borrow_mut().record(Record::timer(self.net.now, entity, token));
                    }
                    if let Some(mut node) = self.nodes[entity].take() {
                        let mut ctx = Ctx { net: &mut self.net, me: entity };
                        node.on_timer(&mut ctx, token);
                        self.nodes[entity] = Some(node);
                    }
                }
            }
        }
        self.net.now
    }

    /// Run until the event queue drains.
    pub fn run(&mut self) -> Nanos {
        self.run_until(Nanos::MAX)
    }

    fn deliver(&mut self, link: LinkId, pkt: Packet) {
        let dst = self.net.links[link].dst;
        match self.net.entities[dst] {
            Entity::Switch { fwd_delay } => {
                // Output-queued switch: no buffering beyond the egress link
                // queue; unroutable packets are a topology bug, drop.
                let out = match self.net.route(dst, pkt.dst) {
                    Some(l) => l,
                    None => return,
                };
                if fwd_delay == 0 {
                    self.net.enqueue(out, pkt);
                } else {
                    let now = self.net.now;
                    self.net.schedule(now + fwd_delay, Event::Arrive(VIRTUAL_FWD | out, pkt));
                }
            }
            Entity::Host => {
                if let Some(t) = &self.net.trace {
                    t.borrow_mut().record(Record::deliver(self.net.now, link, dst, &pkt));
                }
                if let Some(mut node) = self.nodes[dst].take() {
                    let mut ctx = Ctx { net: &mut self.net, me: dst };
                    node.on_packet(&mut ctx, pkt);
                    self.nodes[dst] = Some(node);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::LossModel;
    use crate::wire::PacketKind;
    use std::cell::RefCell;
    use std::rc::Rc;

    type GotLog = Rc<RefCell<Vec<(Nanos, u64)>>>;

    /// A node that sends `n` packets at start and records arrivals into a
    /// shared log.
    struct Blaster {
        peer: EntityId,
        n: u32,
        got: GotLog,
    }

    impl Node for Blaster {
    fn as_any(&mut self) -> &mut dyn std::any::Any { self }
        fn start(&mut self, ctx: &mut Ctx) {
            for i in 0..self.n {
                let pkt = Packet::new(ctx.me, self.peer, 1500, 0, PacketKind::Raw(i as u64));
                ctx.send(pkt);
            }
        }
        fn on_packet(&mut self, ctx: &mut Ctx, pkt: Packet) {
            if let PacketKind::Raw(id) = pkt.kind {
                self.got.borrow_mut().push((ctx.now(), id));
            }
        }
    }

    fn blaster_pair(seed: u64, cfg: LinkCfg, n: u32) -> (Sim, GotLog) {
        let got: GotLog = Rc::new(RefCell::new(vec![]));
        let mut sim = Sim::new(seed);
        let a = sim.add_host(Box::new(Blaster { peer: 1, n, got: Rc::new(RefCell::new(vec![])) }));
        let b = sim.add_host(Box::new(Blaster { peer: 0, n: 0, got: got.clone() }));
        sim.add_duplex(a, b, cfg);
        (sim, got)
    }

    #[test]
    fn pipe_delivers_in_order_with_correct_timing() {
        let cfg = LinkCfg::dcn(10, 5); // 10 Gbps, 5 µs
        let (mut sim, got) = blaster_pair(7, cfg, 3);
        sim.run();
        let got = got.borrow();
        assert_eq!(got.len(), 3);
        // 1500 B @ 10 Gbps = 1.2 µs serialization; back-to-back arrivals at
        // ser*(i+1) + 5 µs propagation.
        assert_eq!(got[0].0, 1200 + 5000);
        assert_eq!(got[1].0, 2 * 1200 + 5000);
        assert_eq!(got[2].0, 3 * 1200 + 5000);
        assert_eq!(got.iter().map(|g| g.1).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn queue_overflow_drops() {
        let cfg = LinkCfg::dcn(1, 5).with_queue(3000); // two packets fit behind the serializer
        let (mut sim, got) = blaster_pair(7, cfg, 10);
        sim.run();
        // 1 in serializer + 2 queued = 3 delivered.
        assert_eq!(got.borrow().len(), 3);
        assert_eq!(sim.link_stats(0).drops_queue, 7);
    }

    #[test]
    fn random_loss_drops_packets() {
        // Deep queue so only the wire-loss model drops packets.
        let cfg = LinkCfg::dcn(10, 5)
            .with_queue(10_000_000)
            .with_loss(LossModel::Bernoulli { p: 0.5 });
        let (mut sim, got) = blaster_pair(7, cfg, 2000);
        sim.run();
        let n = got.borrow().len();
        let rate = 1.0 - n as f64 / 2000.0;
        assert!((rate - 0.5).abs() < 0.05, "loss rate {rate}");
        assert_eq!(sim.link_stats(0).drops_random as usize, 2000 - n);
    }

    #[test]
    fn star_forwarding_through_switch() {
        let got: GotLog = Rc::new(RefCell::new(vec![]));
        let mut sim = Sim::new(1);
        let a = sim.add_host(Box::new(Blaster {
            peer: 2,
            n: 5,
            got: Rc::new(RefCell::new(vec![])),
        }));
        let sw = sim.add_switch(0);
        let b = sim.add_host(Box::new(Blaster { peer: 0, n: 0, got: got.clone() }));
        let cfg = LinkCfg::dcn(10, 2);
        let (a_up, _) = sim.add_duplex(a, sw, cfg);
        let (b_up, _) = sim.add_duplex(b, sw, cfg);
        sim.set_default_uplink(a, a_up);
        sim.set_default_uplink(b, b_up);
        sim.run();
        let got = got.borrow();
        assert_eq!(got.len(), 5);
        // Two serialization hops + two propagation delays.
        assert_eq!(got[0].0, 2 * 1200 + 2 * 2000);
    }

    #[test]
    fn switch_forward_delay_adds_latency() {
        let got: GotLog = Rc::new(RefCell::new(vec![]));
        let mut sim = Sim::new(1);
        let a = sim.add_host(Box::new(Blaster {
            peer: 2,
            n: 1,
            got: Rc::new(RefCell::new(vec![])),
        }));
        let sw = sim.add_switch(500); // 500 ns forwarding latency
        let b = sim.add_host(Box::new(Blaster { peer: 0, n: 0, got: got.clone() }));
        let cfg = LinkCfg::dcn(10, 2);
        let (a_up, _) = sim.add_duplex(a, sw, cfg);
        let (b_up, _) = sim.add_duplex(b, sw, cfg);
        sim.set_default_uplink(a, a_up);
        sim.set_default_uplink(b, b_up);
        sim.run();
        assert_eq!(got.borrow()[0].0, 2 * 1200 + 2 * 2000 + 500);
    }

    #[test]
    fn ecn_marks_past_threshold() {
        let cfg = LinkCfg::dcn(1, 5).with_ecn(1500).with_queue(1_000_000);
        let (mut sim, _got) = blaster_pair(7, cfg, 10);
        sim.run();
        assert!(sim.link_stats(0).ecn_marks > 0, "expected ECN marks");
    }

    #[test]
    fn timers_fire_in_order() {
        struct TimerNode {
            fired: GotLog,
        }
        impl Node for TimerNode {
    fn as_any(&mut self) -> &mut dyn std::any::Any { self }
            fn start(&mut self, ctx: &mut Ctx) {
                ctx.set_timer(300, 3);
                ctx.set_timer(100, 1);
                ctx.set_timer(200, 2);
                ctx.set_timer(100, 10); // same instant: FIFO by insertion
            }
            fn on_packet(&mut self, _ctx: &mut Ctx, _pkt: Packet) {}
            fn on_timer(&mut self, ctx: &mut Ctx, token: u64) {
                self.fired.borrow_mut().push((ctx.now(), token));
            }
        }
        let fired: GotLog = Rc::new(RefCell::new(vec![]));
        let mut sim = Sim::new(3);
        sim.add_host(Box::new(TimerNode { fired: fired.clone() }));
        sim.run();
        assert_eq!(*fired.borrow(), vec![(100, 1), (100, 10), (200, 2), (300, 3)]);
    }

    #[test]
    fn determinism_same_seed_same_trace() {
        let run = |seed| {
            let cfg = LinkCfg::dcn(10, 5).with_loss(LossModel::Bernoulli { p: 0.3 });
            let (mut sim, got) = blaster_pair(seed, cfg, 500);
            sim.run();
            let v = got.borrow().clone();
            v
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn run_until_stops_at_horizon() {
        let cfg = LinkCfg::wan(10, 50); // slow link, 50 ms delay
        let (mut sim, got) = blaster_pair(7, cfg, 100);
        sim.run_until(55 * crate::MS);
        let at_55ms = got.borrow().len();
        assert!(at_55ms > 0 && at_55ms < 100, "partial delivery: {at_55ms}");
        sim.run();
        assert_eq!(got.borrow().len(), 100);
    }
}
