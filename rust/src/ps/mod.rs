//! The Parameter-Server DML training system (paper Fig 1, §V-A2: "we
//! design our own PS-based DML framework").
//!
//! One PS and `W` workers train under BSP: each iteration every worker
//! computes a gradient (either *modeled* — a calibrated compute delay with
//! the paper's message sizes — or *real* — a PJRT execution of the AOT
//! transformer), **gathers** it to the PS over the configured transport
//! (LTP loss-tolerant, or TCP with a chosen congestion control), the PS
//! aggregates (masked-mean Pallas kernel for real compute) and
//! **broadcasts** the new model reliably, and the next iteration begins.
//!
//! LTP specifics (paper §III-B): gather flows run under Early Close with
//! per-link LT thresholds maintained by a [`crate::proto::ThresholdTracker`]
//! (init `1.5·RTprop + Size/BtlBw`, per-epoch update to the fastest full
//! transmission, deadline `max+C`); broadcast is always reliable.
//!
//! The transport underneath is **pluggable** (DESIGN.md §1.1): both nodes
//! drive boxed [`FlowTx`]/[`FlowRx`] endpoints produced by a
//! [`Transport`] factory, protocols are registered under string keys
//! ([`proto_registry`]) and instantiated from specs like `ltp`,
//! `ltp:pct=0.9,slack=100ms`, or `tcp:cc=cubic` ([`parse_proto`]), and runs
//! are assembled through the validated [`RunBuilder`].
//!
//! The aggregation *topology* is equally pluggable (DESIGN.md §1.2): an
//! [`Aggregation`] owns the fabric build, aggregator placement, and the
//! workers' (shard → aggregator) routing plans. Registered today:
//! `ps` (the single-PS star above, default), `sharded:n=N` (gradient
//! segment ranges across N PS nodes), and `hier[:racks=R]` (rack-local
//! aggregators under a root PS). Specs parse with [`parse_agg`] and
//! thread through [`RunBuilder::agg`] and the CLI's `--agg`.

mod agg;
mod blackboard;
mod builder;
mod data;
mod runner;
mod server;
pub(crate) mod spec;
mod transport;
mod worker;

pub use agg::{
    agg_registry, default_agg, parse_agg, AggDef, AggRun, AggSpec, Aggregation, BuildEnv,
    EndpointRole, Fabric, ShardObs, Topo, AGG_REGISTRY,
};
pub use blackboard::Blackboard;
pub use builder::RunBuilder;
pub use data::Corpus;
pub use runner::{
    run_training, run_training_session, run_with, BgFlow, BgKind, NetTotals, RealCompute,
    RealTraining, RunReport, ShardStat, TrainingCfg, XlaAggregate,
};
pub use server::{Aggregate, NullAggregate, PsFlowPlan, PsNode};
pub use spec::{
    baseline_matrix, parse_proto, proto_registry, registry_matrix, ProtoDef, ProtoSpec,
    PROTO_REGISTRY,
};
pub use transport::{FlowRx, FlowTx, RxCfg, Transport, TransportTuning, TxCfg};
pub use worker::{Compute, ModeledCompute, WorkerNode, WorkerRoute, WorkerStats};

use crate::proto::CloseReason;
use crate::Nanos;

/// One gather-flow close observed by the PS (LTP flows only — TCP gathers
/// always complete at 100 %). The scenario conformance tests assert the
/// paper invariant on these records: every non-deadline close delivered
/// all critical segments.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GatherClose {
    pub iter: u64,
    pub worker: usize,
    pub reason: CloseReason,
    pub criticals_ok: bool,
    /// Fraction of data segments delivered at close.
    pub delivered: f64,
}

/// Per-iteration record collected by the PS.
#[derive(Debug, Clone, Default)]
pub struct IterStats {
    /// Batch synchronization time: gather start → last broadcast delivered.
    pub bst: Nanos,
    /// Gather phase only (incast direction).
    pub gather_time: Nanos,
    /// Mean fraction of gradient data delivered across workers (1.0 = no
    /// loss-tolerant dropping).
    pub mean_delivered: f64,
    /// Mean tensor-priority-weighted delivered importance across workers
    /// ([`crate::codec::PriorityScheduler::delivered_importance`]); equals
    /// 1.0 for reliable transports and 0.0-weighted losses only under
    /// Early Close.
    pub mean_importance: f64,
    /// Training loss (real compute only).
    pub loss: Option<f32>,
    /// Wall-clock the iteration ended (sim time).
    pub end: Nanos,
}
