//! The pluggable **aggregation-topology** layer (DESIGN.md §1.2).
//!
//! Where the transport layer (DESIGN.md §1.1) makes the per-flow protocol
//! pluggable, this module makes the *shape* of the gather pluggable: an
//! [`Aggregation`]
//! owns the simnet topology of a training run, places one or more
//! aggregator endpoints, assigns every worker a (shard → aggregator)
//! routing plan over its gradient's segment ranges, and defines how the
//! per-aggregator iteration records merge into one BSP barrier (BST =
//! max over shards/levels). Aggregations are registered under string
//! keys and instantiated from specs reusing the transport grammar
//! (`key[:name=value,...]`, [`parse_agg`]):
//!
//! * `ps` — the paper's single parameter server (star or the scenario
//!   two-rack fabric); the default, byte-identical to the original runs;
//! * `sharded:n=N` — the gradient's segment space partitioned across `N`
//!   PS nodes behind one ToR (ATP-style multi-point aggregation): every
//!   worker opens one flow per shard, each shard runs its own Early
//!   Close, and the per-aggregator incast volume drops by `N`;
//! * `hier[:racks=R]` — `R` rack-local aggregators reduce their rack's
//!   gathers and forward **one** flow each to a root PS (MLfabric-style
//!   in-network aggregation over the [`crate::simnet::n_rack`] fabric),
//!   so only `R` flows cross the oversubscribed trunks.
//!
//! Naming note: an [`Aggregation`] is the *topology* of the gather; the
//! [`Aggregate`] trait (in `ps/server.rs`) is the *compute backend* one
//! aggregator endpoint runs when its gathers close.

use super::runner::TrainingCfg;
use super::server::{Aggregate, PsFlowPlan, PsNode};
use super::spec::{canonical, parse_params, unknown_param};
use super::transport::{FlowRx, FlowTx, RxCfg, TxCfg};
use super::worker::{Compute, WorkerNode, WorkerRoute};
use super::{GatherClose, IterStats};
use crate::churn::ChurnPlan;
use crate::grad::Manifest;
use crate::proto::{EarlyCloseCfg, ThresholdTracker};
use crate::simnet::{
    n_rack, star, star_with, two_rack, Ctx, EntityId, LinkCfg, LinkId, Node, Packet, Sim,
};
use crate::util::Bitmap;
use crate::wire::{PacketKind, LTP_MSS};
use crate::Nanos;
use anyhow::{bail, ensure, Result};
use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

/// Which fabric a `ps`-aggregation training run uses. Other aggregations
/// own their topology outright and reject an explicit two-rack override.
#[derive(Debug, Clone, Copy)]
pub enum Topo {
    /// A single ToR star — the paper's testbed.
    Star,
    /// Two racks under one aggregation switch. The PS and the first
    /// `rack0_workers` workers sit in rack 0, the remaining workers in
    /// rack 1; cross-rack gathers funnel through the `trunk` links
    /// (size `trunk` below the sum of edge rates for oversubscription).
    TwoRack { rack0_workers: usize, trunk: LinkCfg },
}

/// A parsed, validated aggregation spec: the handle stored in run
/// configurations and carried across worker threads by the sweep driver.
/// Clones share the underlying [`Aggregation`].
#[derive(Clone)]
pub struct AggSpec(Arc<dyn Aggregation>);

impl AggSpec {
    /// Canonical spec string — the aggregation's name everywhere (labels,
    /// JSON reports, bench records). Borrowed; no per-call allocation.
    pub fn name(&self) -> &str {
        self.0.name()
    }
}

impl std::ops::Deref for AggSpec {
    type Target = dyn Aggregation;

    fn deref(&self) -> &(dyn Aggregation + 'static) {
        &*self.0
    }
}

impl std::fmt::Display for AggSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::fmt::Debug for AggSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AggSpec({})", self.name())
    }
}

/// Two specs are equal iff their canonical names are.
impl PartialEq for AggSpec {
    fn eq(&self, other: &AggSpec) -> bool {
        self.name() == other.name()
    }
}

impl std::str::FromStr for AggSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<AggSpec> {
        parse_agg(s)
    }
}

/// Compute and aggregation-backend factories handed to
/// [`Aggregation::build`]: `make_compute(worker, cfg)` per worker,
/// `make_agg(endpoint)` per aggregator endpoint (endpoints are numbered
/// `0..n_aggregators`; for `hier` the racks come first, the root last).
pub struct BuildEnv<'a> {
    pub make_compute: &'a mut dyn FnMut(usize, &TrainingCfg) -> Box<dyn Compute>,
    pub make_agg: &'a mut dyn FnMut(usize) -> Box<dyn Aggregate>,
}

/// One aggregator endpoint's observation handles, shared with the nodes
/// placed by [`Aggregation::build`] and read back by the runner.
pub struct ShardObs {
    /// Deterministic label for the per-aggregator report breakdown
    /// (`ps`, `shard3`, `rack1`, `root`).
    pub label: String,
    /// This endpoint's per-iteration records.
    pub report: Rc<RefCell<Vec<IterStats>>>,
    /// This endpoint's gather-flow close records.
    pub closes: Rc<RefCell<Vec<GatherClose>>>,
    /// Gather bytes this endpoint absorbs per worker flow — the
    /// delivered-fraction weight in the barrier merge.
    pub weight: u64,
    /// Barrier members define the merged iteration records (max-BST
    /// rule); non-members (the `hier` root) only appear in the shard
    /// breakdown and multiply into the delivered fraction.
    pub in_barrier: bool,
}

/// The built fabric, kept by the runner to attach late (background) hosts.
pub enum Fabric {
    Star {
        switch: EntityId,
    },
    Racks {
        agg: EntityId,
        tors: Vec<EntityId>,
        trunk_down: Vec<LinkId>,
    },
}

impl Fabric {
    /// Attach one late host carrying `node` in `rack` (ignored on a star)
    /// over an `edge` link, wiring default uplink and switch routes.
    pub fn attach(
        &self,
        sim: &mut Sim,
        node: Box<dyn Node>,
        rack: usize,
        edge: LinkCfg,
    ) -> EntityId {
        let h = sim.add_host(node);
        match self {
            Fabric::Star { switch } => {
                let (up, _) = sim.add_duplex(h, *switch, edge);
                sim.set_default_uplink(h, up);
            }
            Fabric::Racks { agg, tors, trunk_down } => {
                let r = rack.min(tors.len() - 1);
                let (up, _) = sim.add_duplex(h, tors[r], edge);
                sim.set_default_uplink(h, up);
                sim.set_route(*agg, h, trunk_down[r]);
            }
        }
        h
    }
}

/// Everything [`Aggregation::build`] hands back to the runner: the nodes
/// are already inside `sim`; these are the observation handles.
pub struct AggRun {
    /// The background-traffic sink (the PS, shard 0, or the `hier` root).
    pub ps_id: EntityId,
    /// Worker host entities, in worker-index order.
    pub worker_ids: Vec<EntityId>,
    /// One entry per aggregator endpoint, in endpoint order.
    pub shards: Vec<ShardObs>,
    pub fabric: Fabric,
}

/// What one aggregator endpoint *is* to a compute backend (DESIGN.md
/// §1.3): terminal masked-mean endpoints own a gradient byte range;
/// `hier` rack relays and the root describe the two tiers of the
/// hierarchy. Roles are listed in endpoint order (matching the
/// `make_agg(endpoint)` numbering of [`BuildEnv`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EndpointRole {
    /// Terminal aggregator (single PS, or one shard): runs the masked
    /// mean + optimizer over the gradient bytes
    /// `[byte_offset, byte_offset + bytes)`, fed by every worker.
    Final { byte_offset: u64, bytes: u64 },
    /// A `hier` rack-local relay over the global workers
    /// `[first_worker, first_worker + n_workers)`; forwards one reduced
    /// flow to the root.
    Relay { first_worker: usize, n_workers: usize },
    /// The `hier` root, fed by one forward flow per rack (rack order).
    Root { racks: usize },
}

/// An aggregation topology: a named, thread-shareable strategy that owns
/// a training run's fabric, aggregator placement, worker routing plans,
/// and barrier-merge semantics. Registered under string keys in
/// [`AGG_REGISTRY`] and instantiated from CLI specs like `ps`,
/// `sharded:n=4`, or `hier:racks=2`.
pub trait Aggregation: Send + Sync {
    /// Canonical spec string — the aggregation's label everywhere.
    fn name(&self) -> &str;

    /// Aggregator endpoints a run with `workers` workers places.
    fn n_aggregators(&self, workers: usize) -> usize;

    /// Per-iteration flow-id stride of this topology's layout. LTP
    /// truncates flow ids to 16 bits on the wire; slot resolution
    /// (`flow % stride`) survives that truncation only while flows stay
    /// below 2¹⁶ — or for any run length when the stride is a power of
    /// two. [`super::RunBuilder::build`] enforces the corresponding
    /// iteration bound for loss-tolerant transports.
    fn flow_stride(&self, workers: usize) -> u64 {
        2 * workers as u64
    }

    /// Fail-fast validation against a run configuration (called by
    /// [`super::RunBuilder::build`] before any simulation starts).
    fn validate(&self, workers: usize, model_bytes: u64, topo: &Topo) -> Result<()>;

    /// The role of each aggregator endpoint, in endpoint order — how a
    /// compute backend knows which gradient range (or hierarchy tier)
    /// each `make_agg(endpoint)` call serves. Callers must [`Self::validate`]
    /// first; roles of an invalid (workers, model) combination are
    /// unspecified.
    fn endpoint_roles(&self, workers: usize, model_bytes: u64) -> Vec<EndpointRole>;

    /// Build the fabric inside `sim`, place aggregator and worker nodes,
    /// and return the observation handles.
    fn build(&self, sim: &mut Sim, cfg: &TrainingCfg, env: &mut BuildEnv<'_>) -> AggRun;
}

/// One registered aggregation family.
pub struct AggDef {
    /// Spec key (`--agg <key>[:params]`).
    pub key: &'static str,
    pub summary: &'static str,
    /// Accepted `name=value` parameters, for `ltp agg list`.
    pub params: &'static str,
    build: fn(&[(String, String)]) -> Result<AggSpec>,
}

/// The aggregation registry. Append entries here (and their strategies in
/// this module); the CLI (`--agg`, `ltp agg list`), the `agg_matrix`
/// scenario, and the conformance test (`rust/tests/agg.rs`) follow.
pub const AGG_REGISTRY: &[AggDef] = &[
    AggDef {
        key: "ps",
        summary: "single parameter server (the paper's star; default, byte-identical reports)",
        params: "",
        build: build_ps,
    },
    AggDef {
        key: "sharded",
        summary: "gradient segment ranges partitioned across N PS nodes, per-shard Early Close",
        params: "n=<shards> (required; must divide the worker count)",
        build: build_sharded,
    },
    AggDef {
        key: "hier",
        summary: "rack-local aggregators reduce locally, one flow per rack to a root PS",
        params: "racks=<racks> (default 2; must divide the worker count)",
        build: build_hier,
    },
];

/// The registry (function form, for iteration symmetry with the protocol
/// and scenario registries).
pub fn agg_registry() -> &'static [AggDef] {
    AGG_REGISTRY
}

/// Parse an aggregation spec (`ps`, `sharded:n=4`, `hier:racks=2`)
/// against the registry.
pub fn parse_agg(spec: &str) -> Result<AggSpec> {
    let spec = spec.trim();
    let (key, rest) = match spec.split_once(':') {
        Some((k, r)) => (k, Some(r)),
        None => (spec, None),
    };
    let key = key.to_ascii_lowercase();
    let Some(def) = AGG_REGISTRY.iter().find(|d| d.key == key) else {
        let known: Vec<&str> = AGG_REGISTRY.iter().map(|d| d.key).collect();
        bail!("unknown aggregation `{key}` in spec `{spec}` (known: {})", known.join(", "));
    };
    let params =
        parse_params(rest).map_err(|e| e.context(format!("in aggregation spec `{spec}`")))?;
    (def.build)(&params).map_err(|e| e.context(format!("in aggregation spec `{spec}`")))
}

/// The default aggregation: the single-PS star every pre-existing run and
/// report uses.
pub fn default_agg() -> AggSpec {
    parse_agg("ps").expect("registry default must parse")
}

// ---------------------------------------------------------------------------
// Spec builders.
// ---------------------------------------------------------------------------

fn build_ps(params: &[(String, String)]) -> Result<AggSpec> {
    if let Some((k, _)) = params.first() {
        return Err(unknown_param("ps", k, "none"));
    }
    Ok(AggSpec(Arc::new(PsAggregation { spec: "ps".to_string() })))
}

fn build_sharded(params: &[(String, String)]) -> Result<AggSpec> {
    let mut n = None;
    for (k, v) in params {
        match k.as_str() {
            "n" => {
                let x: usize = v
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad value for `n`: `{v}` ({e})"))?;
                if x == 0 {
                    bail!("`n=0`: a sharded deployment needs at least one shard");
                }
                n = Some(x);
            }
            _ => return Err(unknown_param("sharded", k, "n")),
        }
    }
    let Some(n) = n else {
        bail!("`sharded` needs a shard count: sharded:n=<shards>");
    };
    let spec = canonical("sharded", &[format!("n={n}")]);
    Ok(AggSpec(Arc::new(ShardedAggregation { n, spec })))
}

/// Default rack count for a bare `hier` spec.
const HIER_DEFAULT_RACKS: usize = 2;

fn build_hier(params: &[(String, String)]) -> Result<AggSpec> {
    let mut racks = None;
    for (k, v) in params {
        match k.as_str() {
            "racks" => {
                let x: usize = v
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad value for `racks`: `{v}` ({e})"))?;
                if x == 0 {
                    bail!("`racks=0`: a hierarchy needs at least one rack");
                }
                racks = Some(x);
            }
            _ => return Err(unknown_param("hier", k, "racks")),
        }
    }
    // Canonical form: the parameter renders only when given (a bare
    // `hier` stays `hier`), like transport-spec defaults.
    let parts: Vec<String> = racks.iter().map(|r| format!("racks={r}")).collect();
    let spec = canonical("hier", &parts);
    Ok(AggSpec(Arc::new(HierAggregation {
        racks: racks.unwrap_or(HIER_DEFAULT_RACKS),
        spec,
    })))
}

// ---------------------------------------------------------------------------
// Barrier merge.
// ---------------------------------------------------------------------------

/// Merge per-aggregator iteration records into the run's barrier view:
/// BST and gather time are the **max** over barrier members (an iteration
/// is synchronized only when its slowest shard/rack is), the delivered
/// fraction is their byte-weighted mean, further multiplied by the
/// non-barrier tiers' delivered fraction (the `hier` root can drop
/// forwarded data too). A single barrier member passes through verbatim.
pub(super) fn merge_iters(shards: &[ShardObs]) -> Vec<IterStats> {
    let barrier: Vec<&ShardObs> = shards.iter().filter(|s| s.in_barrier).collect();
    if barrier.len() == 1 && shards.len() == 1 {
        return barrier[0].report.borrow().clone();
    }
    let uppers: Vec<&ShardObs> = shards.iter().filter(|s| !s.in_barrier).collect();
    let n = barrier.iter().map(|s| s.report.borrow().len()).min().unwrap_or(0);
    let weight_sum: u64 = barrier.iter().map(|s| s.weight.max(1)).sum();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut merged = IterStats::default();
        let mut delivered = 0.0;
        let mut importance = 0.0;
        for s in &barrier {
            let rep = s.report.borrow();
            let rec = &rep[i];
            merged.bst = merged.bst.max(rec.bst);
            merged.gather_time = merged.gather_time.max(rec.gather_time);
            merged.end = merged.end.max(rec.end);
            if merged.loss.is_none() {
                merged.loss = rec.loss;
            }
            delivered += rec.mean_delivered * s.weight.max(1) as f64;
            importance += rec.mean_importance * s.weight.max(1) as f64;
        }
        merged.mean_delivered = delivered / weight_sum as f64;
        merged.mean_importance = importance / weight_sum as f64;
        for s in &uppers {
            let rep = s.report.borrow();
            if let Some(rec) = rep.get(i) {
                merged.mean_delivered *= rec.mean_delivered;
                merged.mean_importance *= rec.mean_importance;
            }
        }
        out.push(merged);
    }
    out
}

// ---------------------------------------------------------------------------
// `ps`: the single parameter server (star or scenario two-rack fabric).
// ---------------------------------------------------------------------------

struct PsAggregation {
    spec: String,
}

impl Aggregation for PsAggregation {
    fn name(&self) -> &str {
        &self.spec
    }

    fn n_aggregators(&self, _workers: usize) -> usize {
        1
    }

    fn validate(&self, _workers: usize, _model_bytes: u64, _topo: &Topo) -> Result<()> {
        Ok(())
    }

    fn endpoint_roles(&self, _workers: usize, model_bytes: u64) -> Vec<EndpointRole> {
        vec![EndpointRole::Final { byte_offset: 0, bytes: model_bytes }]
    }

    fn build(&self, sim: &mut Sim, cfg: &TrainingCfg, env: &mut BuildEnv<'_>) -> AggRun {
        let report: Rc<RefCell<Vec<IterStats>>> = Rc::new(RefCell::new(Vec::new()));
        let closes: Rc<RefCell<Vec<GatherClose>>> = Rc::new(RefCell::new(Vec::new()));
        let tracker = tracker_for(cfg, cfg.n_workers);
        // Codec wire image (DESIGN.md §1.4): gather flows carry the
        // encoded gradient; criticals and the priority order are reframed
        // onto the encoded segment map. For the identity codec this is
        // byte-for-byte the dense plumbing (enc == model_bytes, criticals
        // pass through, no reordering unless priority=on).
        let enc = cfg.codec.encoded_bytes(cfg.model_bytes);
        let payload = Manifest::aligned_payload(LTP_MSS);
        let probe = crate::proto::SegmentMap::new(enc, payload, vec![]);
        let wire_crit = cfg.codec.wire_critical(&cfg.critical, &probe);
        let wire_map = crate::proto::SegmentMap::new(enc, payload, wire_crit.clone());
        let nq_order = cfg.codec.nq_order(&wire_map);
        // Entity-id layout is deterministic per topology: switches first,
        // then the PS, then workers in index order (background hosts last).
        let first_host = match cfg.topo {
            Topo::Star => 1,           // switch 0
            Topo::TwoRack { .. } => 3, // agg 0, tor0 1, tor1 2
        };
        let ps_id: EntityId = first_host;
        let worker_ids: Vec<EntityId> =
            (0..cfg.n_workers).map(|w| first_host + 1 + w).collect();
        // Churn plan (DESIGN.md §1.5): the default spec takes the exact
        // pre-existing code paths — no membership attached, uniform links.
        let plan = churn_plan(cfg);
        let mut ps = PsNode::new(
            worker_ids.clone(),
            cfg.proto.clone(),
            cfg.model_bytes,
            wire_crit.clone(),
            PsFlowPlan::single(cfg.n_workers),
            (env.make_agg)(0),
            tracker,
            cfg.iters,
            cfg.batches_per_epoch,
            report.clone(),
            closes.clone(),
        )
        .with_gather_bytes(enc);
        if let Some(p) = &plan {
            ps = ps.with_membership(p.rows_for(0..cfg.n_workers));
        }
        let mut nodes: Vec<Box<dyn Node>> = vec![Box::new(ps)];
        for w in 0..cfg.n_workers {
            let mut route = WorkerRoute::single(
                ps_id,
                w,
                cfg.n_workers,
                cfg.model_bytes,
                wire_crit.clone(),
            );
            route.gather_bytes = enc;
            route.nq_order = nq_order.clone();
            let mut node = WorkerNode::new(
                w,
                vec![route],
                cfg.proto.clone(),
                (env.make_compute)(w, cfg),
                cfg.iters,
            );
            if let Some(p) = &plan {
                node = node.with_schedule(p.schedule(w));
            }
            nodes.push(Box::new(node));
        }
        let fabric = match cfg.topo {
            Topo::Star => {
                let topo = match &plan {
                    Some(p) if p.perturbs_links() => {
                        // PS keeps the base edge; each worker gets its
                        // planned per-worker link profile.
                        let mut cfgs = Vec::with_capacity(1 + cfg.n_workers);
                        cfgs.push(cfg.link);
                        cfgs.extend((0..cfg.n_workers).map(|w| p.edge_cfg(cfg.link, w)));
                        star_with(sim, nodes, &cfgs, cfg.switch_delay)
                    }
                    _ => star(sim, nodes, cfg.link, cfg.switch_delay),
                };
                debug_assert_eq!(topo.hosts[0], ps_id);
                Fabric::Star { switch: topo.switch }
            }
            Topo::TwoRack { rack0_workers, trunk } => {
                let rack0_n = rack0_workers.min(cfg.n_workers);
                let mut it = nodes.into_iter();
                let rack0: Vec<Box<dyn Node>> = it.by_ref().take(1 + rack0_n).collect();
                let rack1: Vec<Box<dyn Node>> = it.collect();
                let topo = two_rack(sim, [rack0, rack1], cfg.link, trunk, cfg.switch_delay);
                debug_assert_eq!(topo.hosts[0], ps_id);
                Fabric::Racks {
                    agg: topo.agg,
                    tors: topo.tors.to_vec(),
                    trunk_down: topo.trunk_down.to_vec(),
                }
            }
        };
        debug_assert!(worker_ids.last().map(|&w| w < sim.entity_count()).unwrap_or(true));
        AggRun {
            ps_id,
            worker_ids,
            shards: vec![ShardObs {
                label: "ps".to_string(),
                report,
                closes,
                weight: cfg.model_bytes,
                in_barrier: true,
            }],
            fabric,
        }
    }
}

// ---------------------------------------------------------------------------
// `sharded:n=N`: segment ranges partitioned across N PS nodes.
// ---------------------------------------------------------------------------

struct ShardedAggregation {
    n: usize,
    spec: String,
}

/// One shard's slice of the gradient: `(bytes, first segment id, segment
/// count)`. Partitioning is on segment boundaries, so shard flows keep
/// the wire segmentation (and the padding-bubble rule) intact.
fn shard_ranges(model_bytes: u64, n: usize) -> Vec<(u64, u64, u64)> {
    let seg = Manifest::aligned_payload(LTP_MSS) as u64;
    let n_segs = model_bytes.div_ceil(seg);
    let per = n_segs / n as u64;
    let rem = n_segs % n as u64;
    let mut out = Vec::with_capacity(n);
    let mut seg0 = 0u64;
    for i in 0..n as u64 {
        let count = per + u64::from(i < rem);
        let start_byte = seg0 * seg;
        let end_byte = ((seg0 + count) * seg).min(model_bytes);
        out.push((end_byte.saturating_sub(start_byte), seg0, count));
        seg0 += count;
    }
    out
}

/// The critical segment ids of `critical` that fall in the shard
/// `[seg0, seg0 + count)`, re-based to the shard's own segment space.
fn shard_criticals(critical: &[u32], seg0: u64, count: u64) -> Vec<u32> {
    critical
        .iter()
        .filter(|&&c| (c as u64) >= seg0 && (c as u64) < seg0 + count)
        .map(|&c| c - seg0 as u32)
        .collect()
}

impl Aggregation for ShardedAggregation {
    fn name(&self) -> &str {
        &self.spec
    }

    fn n_aggregators(&self, _workers: usize) -> usize {
        self.n
    }

    fn flow_stride(&self, workers: usize) -> u64 {
        2 * workers as u64 * self.n as u64
    }

    fn validate(&self, workers: usize, model_bytes: u64, topo: &Topo) -> Result<()> {
        ensure!(
            matches!(topo, Topo::Star),
            "`{}` builds its own star fabric; drop the two-rack topology override",
            self.spec
        );
        ensure!(
            workers % self.n == 0,
            "`{}`: worker count {workers} is not divisible across {} shards",
            self.spec,
            self.n
        );
        let seg = Manifest::aligned_payload(LTP_MSS) as u64;
        let n_segs = model_bytes.div_ceil(seg);
        ensure!(
            n_segs >= self.n as u64,
            "`{}`: the {model_bytes}-byte gradient has only {n_segs} segments — fewer than {} shards",
            self.spec,
            self.n
        );
        Ok(())
    }

    fn endpoint_roles(&self, _workers: usize, model_bytes: u64) -> Vec<EndpointRole> {
        let seg = Manifest::aligned_payload(LTP_MSS) as u64;
        shard_ranges(model_bytes, self.n)
            .into_iter()
            .map(|(bytes, seg0, _)| EndpointRole::Final { byte_offset: seg0 * seg, bytes })
            .collect()
    }

    fn build(&self, sim: &mut Sim, cfg: &TrainingCfg, env: &mut BuildEnv<'_>) -> AggRun {
        let w = cfg.n_workers;
        let nsh = self.n;
        let ranges = shard_ranges(cfg.model_bytes, nsh);
        let crits: Vec<Vec<u32>> = ranges
            .iter()
            .map(|&(_, seg0, count)| shard_criticals(&cfg.critical, seg0, count))
            .collect();
        // Flow space: iteration stride 2·W·N; shard s owns the bands
        // [s·2W, s·2W + W) (gathers) and [s·2W + W, (s+1)·2W) (broadcasts).
        // With N = 1 this is exactly the single-PS layout.
        let stride = (2 * w * nsh) as u64;
        // Entity-id layout: switch 0, shards 1..=N, then workers.
        let shard_ids: Vec<EntityId> = (0..nsh).map(|s| 1 + s).collect();
        let worker_ids: Vec<EntityId> = (0..w).map(|i| 1 + nsh + i).collect();
        // Every shard sees every worker, so each shard PS carries the
        // full membership matrix (DESIGN.md §1.5).
        let churn = churn_plan(cfg);
        let mut nodes: Vec<Box<dyn Node>> = Vec::with_capacity(nsh + w);
        let mut shards = Vec::with_capacity(nsh);
        for (s, &(bytes, _, _)) in ranges.iter().enumerate() {
            let report: Rc<RefCell<Vec<IterStats>>> = Rc::new(RefCell::new(Vec::new()));
            let closes: Rc<RefCell<Vec<GatherClose>>> = Rc::new(RefCell::new(Vec::new()));
            let plan = PsFlowPlan {
                gather_base: (s * 2 * w) as u64,
                bcast_base: (s * 2 * w + w) as u64,
                stride,
            };
            let mut ps = PsNode::new(
                worker_ids.clone(),
                cfg.proto.clone(),
                bytes,
                crits[s].clone(),
                plan,
                (env.make_agg)(s),
                tracker_for(cfg, w),
                cfg.iters,
                cfg.batches_per_epoch,
                report.clone(),
                closes.clone(),
            );
            if let Some(p) = &churn {
                ps = ps.with_membership(p.rows_for(0..w));
            }
            nodes.push(Box::new(ps));
            shards.push(ShardObs {
                label: format!("shard{s}"),
                report,
                closes,
                weight: bytes,
                in_barrier: true,
            });
        }
        for i in 0..w {
            let routes: Vec<WorkerRoute> = ranges
                .iter()
                .enumerate()
                .map(|(s, &(bytes, _, _))| WorkerRoute {
                    dst: shard_ids[s],
                    bytes,
                    gather_bytes: bytes,
                    critical: crits[s].clone(),
                    nq_order: None,
                    gather_slot: (s * 2 * w + i) as u64,
                    bcast_slot: (s * 2 * w + w + i) as u64,
                    stride,
                })
                .collect();
            let mut node = WorkerNode::new(
                i,
                routes,
                cfg.proto.clone(),
                (env.make_compute)(i, cfg),
                cfg.iters,
            );
            if let Some(p) = &churn {
                node = node.with_schedule(p.schedule(i));
            }
            nodes.push(Box::new(node));
        }
        let topo = match &churn {
            Some(p) if p.perturbs_links() => {
                let mut cfgs = vec![cfg.link; nsh];
                cfgs.extend((0..w).map(|i| p.edge_cfg(cfg.link, i)));
                star_with(sim, nodes, &cfgs, cfg.switch_delay)
            }
            _ => star(sim, nodes, cfg.link, cfg.switch_delay),
        };
        debug_assert_eq!(topo.hosts[0], shard_ids[0]);
        AggRun {
            ps_id: shard_ids[0],
            worker_ids,
            shards,
            fabric: Fabric::Star { switch: topo.switch },
        }
    }
}

// ---------------------------------------------------------------------------
// `hier[:racks=R]`: rack-local aggregators under a root PS.
// ---------------------------------------------------------------------------

struct HierAggregation {
    racks: usize,
    spec: String,
}

impl Aggregation for HierAggregation {
    fn name(&self) -> &str {
        &self.spec
    }

    fn n_aggregators(&self, _workers: usize) -> usize {
        self.racks + 1
    }

    fn flow_stride(&self, workers: usize) -> u64 {
        2 * workers as u64 + 2 * self.racks as u64
    }

    fn validate(&self, workers: usize, _model_bytes: u64, topo: &Topo) -> Result<()> {
        ensure!(
            matches!(topo, Topo::Star),
            "`{}` builds its own {}-rack fabric; drop the two-rack topology override",
            self.spec,
            self.racks
        );
        ensure!(
            workers % self.racks == 0 && workers >= self.racks,
            "`{}`: worker count {workers} is not divisible across {} racks",
            self.spec,
            self.racks
        );
        Ok(())
    }

    fn endpoint_roles(&self, workers: usize, _model_bytes: u64) -> Vec<EndpointRole> {
        let per = workers / self.racks.max(1);
        let mut roles: Vec<EndpointRole> = (0..self.racks)
            .map(|r| EndpointRole::Relay { first_worker: r * per, n_workers: per })
            .collect();
        roles.push(EndpointRole::Root { racks: self.racks });
        roles
    }

    fn build(&self, sim: &mut Sim, cfg: &TrainingCfg, env: &mut BuildEnv<'_>) -> AggRun {
        let w = cfg.n_workers;
        let r_n = self.racks;
        let per = w / r_n;
        // Flow space per iteration: worker gathers [0, W), worker
        // broadcasts [W, 2W), rack→root forwards [2W, 2W+R), root→rack
        // broadcasts [2W+R, 2W+2R).
        let stride = (2 * w + 2 * r_n) as u64;
        // Entity-id layout: agg switch 0, tors 1..=R, then rack-major
        // hosts (each rack: its relay first, then its workers), then the
        // root attached directly to the aggregation switch.
        let first_host = 1 + r_n;
        let relay_ids: Vec<EntityId> = (0..r_n).map(|r| first_host + r * (1 + per)).collect();
        let worker_ids: Vec<EntityId> = (0..w)
            .map(|i| first_host + (i / per) * (1 + per) + 1 + (i % per))
            .collect();
        let root_id: EntityId = first_host + r_n * (1 + per);
        // Membership churn only: relays stay in the root's barrier every
        // iteration (a zero-active rack forwards an empty partial), so the
        // root PS itself never carries a membership matrix. The builder
        // rejects link-perturbing churn for `hier`.
        let churn = churn_plan(cfg);
        let mut shards = Vec::with_capacity(r_n + 1);
        let mut racks: Vec<Vec<Box<dyn Node>>> = Vec::with_capacity(r_n);
        for r in 0..r_n {
            let report: Rc<RefCell<Vec<IterStats>>> = Rc::new(RefCell::new(Vec::new()));
            let closes: Rc<RefCell<Vec<GatherClose>>> = Rc::new(RefCell::new(Vec::new()));
            let rack_workers: Vec<EntityId> =
                worker_ids[r * per..(r + 1) * per].to_vec();
            let relay = RelayAggNode::new(RelayCfg {
                workers: rack_workers,
                worker_base: r * per,
                proto: cfg.proto.clone(),
                model_bytes: cfg.model_bytes,
                critical: cfg.critical.clone(),
                plan: PsFlowPlan {
                    gather_base: (r * per) as u64,
                    bcast_base: (w + r * per) as u64,
                    stride,
                },
                root: root_id,
                up_gather_slot: (2 * w + r) as u64,
                up_bcast_slot: (2 * w + r_n + r) as u64,
                agg: (env.make_agg)(r),
                tracker: tracker_for(cfg, per),
                iters: cfg.iters,
                batches_per_epoch: cfg.batches_per_epoch,
                report: report.clone(),
                closes: closes.clone(),
                membership: churn.as_ref().map(|p| p.rows_for(r * per..(r + 1) * per)),
            });
            let mut rack_nodes: Vec<Box<dyn Node>> = vec![Box::new(relay)];
            for j in 0..per {
                let i = r * per + j;
                let route = WorkerRoute {
                    dst: relay_ids[r],
                    bytes: cfg.model_bytes,
                    gather_bytes: cfg.model_bytes,
                    critical: cfg.critical.clone(),
                    nq_order: None,
                    gather_slot: i as u64,
                    bcast_slot: (w + i) as u64,
                    stride,
                };
                let mut node = WorkerNode::new(
                    i,
                    vec![route],
                    cfg.proto.clone(),
                    (env.make_compute)(i, cfg),
                    cfg.iters,
                );
                if let Some(p) = &churn {
                    node = node.with_schedule(p.schedule(i));
                }
                rack_nodes.push(Box::new(node));
            }
            racks.push(rack_nodes);
            shards.push(ShardObs {
                label: format!("rack{r}"),
                report,
                closes,
                weight: cfg.model_bytes,
                in_barrier: true,
            });
        }
        // The root is a plain PsNode whose "workers" are the rack relays;
        // its close records index the rack forward flows after the real
        // workers (`W + r`), keeping the run-wide close list unambiguous.
        let root_report: Rc<RefCell<Vec<IterStats>>> = Rc::new(RefCell::new(Vec::new()));
        let root_closes: Rc<RefCell<Vec<GatherClose>>> = Rc::new(RefCell::new(Vec::new()));
        let root = PsNode::new(
            relay_ids.clone(),
            cfg.proto.clone(),
            cfg.model_bytes,
            cfg.critical.clone(),
            PsFlowPlan {
                gather_base: (2 * w) as u64,
                bcast_base: (2 * w + r_n) as u64,
                stride,
            },
            (env.make_agg)(r_n),
            tracker_for(cfg, r_n),
            cfg.iters,
            cfg.batches_per_epoch,
            root_report.clone(),
            root_closes.clone(),
        )
        .with_worker_base(w);
        shards.push(ShardObs {
            label: "root".to_string(),
            report: root_report,
            closes: root_closes,
            weight: cfg.model_bytes,
            in_barrier: false,
        });
        // Rack trunks run at edge rate: hierarchical aggregation sends
        // only one flow per rack across them, which is the point.
        let topo = n_rack(sim, racks, cfg.link, cfg.link, cfg.switch_delay);
        debug_assert_eq!(topo.hosts.first().copied(), relay_ids.first().copied());
        let root_host = sim.add_host(Box::new(root));
        debug_assert_eq!(root_host, root_id);
        let (up, _down) = sim.add_duplex(root_host, topo.agg, cfg.link);
        sim.set_default_uplink(root_host, up);
        AggRun {
            ps_id: root_id,
            worker_ids,
            shards,
            fabric: Fabric::Racks {
                agg: topo.agg,
                tors: topo.tors,
                trunk_down: topo.trunk_down,
            },
        }
    }
}

/// The run's churn plan, or `None` for the default spec so that stable
/// runs take the exact pre-existing (membership-free) code paths and
/// stay byte-identical.
fn churn_plan(cfg: &TrainingCfg) -> Option<ChurnPlan> {
    (!cfg.churn.is_default())
        .then(|| cfg.churn.plan(cfg.n_workers, cfg.iters, cfg.batches_per_epoch, cfg.seed))
}

/// The run's threshold tracker for one aggregator endpoint over
/// `n_links` incoming gather links, honoring spec-level tuning overrides.
fn tracker_for(cfg: &TrainingCfg, n_links: usize) -> ThresholdTracker {
    let tuning = cfg.proto.tuning();
    ThresholdTracker::new(
        n_links,
        tuning.deadline_slack.unwrap_or(cfg.deadline_slack),
        tuning.pct_threshold.unwrap_or(cfg.pct_threshold),
    )
}

// ---------------------------------------------------------------------------
// The rack-local relay aggregator node.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RelayPhase {
    /// Receiving this rack's worker gathers (Early Close per flow).
    Gathering,
    /// Local reduce running (modeled duration).
    Reducing,
    /// Forwarding the reduced gradient to the root (one flow).
    Forwarding,
    /// Waiting for the root's reliable model broadcast.
    AwaitRoot,
    /// Re-broadcasting the model to this rack's workers (reliable).
    Broadcasting,
    Done,
}

const TOK_REDUCE_DONE: u64 = 1 << 41;
/// Cap on stashed ahead-of-iteration packets per worker.
const MAX_STASH: usize = 8192;

/// Constructor bundle for [`RelayAggNode`].
struct RelayCfg {
    workers: Vec<EntityId>,
    /// Global index of this rack's first worker (close records use
    /// run-global worker indices).
    worker_base: usize,
    proto: super::spec::ProtoSpec,
    model_bytes: u64,
    critical: Vec<u32>,
    plan: PsFlowPlan,
    root: EntityId,
    up_gather_slot: u64,
    up_bcast_slot: u64,
    agg: Box<dyn Aggregate>,
    tracker: ThresholdTracker,
    iters: u64,
    batches_per_epoch: u64,
    report: Rc<RefCell<Vec<IterStats>>>,
    closes: Rc<RefCell<Vec<GatherClose>>>,
    /// Rack-local membership rows (`[iter][local worker]`), or `None` for
    /// a stable rack. Mirrors `PsNode::membership` over this rack's
    /// columns; the relay itself always stays in the root's barrier.
    membership: Option<Vec<Vec<bool>>>,
}

/// A rack-local aggregator: PS-like toward its rack's workers (gather
/// under Early Close, reliable re-broadcast), worker-like toward the root
/// (one reliable-until-stopped forward flow per iteration, one reliable
/// model receive). The local reduce runs between the two tiers.
struct RelayAggNode {
    c: RelayCfg,
    iter: u64,
    phase: RelayPhase,
    /// Gather receiver per local worker for the current iteration.
    rx: Vec<Option<Box<dyn FlowRx>>>,
    /// Broadcast sender per local worker.
    tx_down: Vec<Option<Box<dyn FlowTx>>>,
    /// Forward sender toward the root.
    tx_up: Option<Box<dyn FlowTx>>,
    /// Model receiver from the root (reliable).
    rx_root: Option<Box<dyn FlowRx>>,
    /// Previous iteration's root receiver, kept to answer stragglers.
    rx_root_prev: Option<Box<dyn FlowRx>>,
    gather_done: Vec<bool>,
    gather_started: Vec<Option<Nanos>>,
    /// Early packets for the next iteration's worker gather flows.
    stash: Vec<Vec<Packet>>,
    gather_phase_done: Nanos,
    reduce_dur: Nanos,
    /// Path estimates for seeding the next forward flow.
    path_up: Option<(Nanos, u64)>,
    timer_gen: u64,
    arrivals: Vec<Option<(Bitmap, u64)>>,
    delivered_fractions: Vec<f64>,
    /// Per-flow tensor-priority-weighted delivered importance, parallel
    /// to `delivered_fractions` (mirrors `PsNode::importances`).
    importances: Vec<f64>,
    /// `delivered_fractions.len()` at the start of the current iteration —
    /// under churn fewer than `n` flows close per iteration, and the
    /// per-iteration means must not reach into earlier iterations.
    frac_mark: usize,
}

impl RelayAggNode {
    fn new(c: RelayCfg) -> RelayAggNode {
        let n = c.workers.len();
        RelayAggNode {
            c,
            iter: 0,
            phase: RelayPhase::Gathering,
            rx: (0..n).map(|_| None).collect(),
            tx_down: (0..n).map(|_| None).collect(),
            tx_up: None,
            rx_root: None,
            rx_root_prev: None,
            gather_done: vec![false; n],
            gather_started: vec![None; n],
            stash: vec![Vec::new(); n],
            gather_phase_done: 0,
            reduce_dur: 0,
            path_up: None,
            timer_gen: 0,
            arrivals: (0..n).map(|_| None).collect(),
            delivered_fractions: vec![],
            importances: vec![],
            frac_mark: 0,
        }
    }

    fn n(&self) -> usize {
        self.c.workers.len()
    }

    /// Is local worker `j` a member of the barrier at `iter`? Absent a
    /// membership matrix (stable rack) every worker always is.
    fn active_at(&self, iter: u64, j: usize) -> bool {
        self.c
            .membership
            .as_ref()
            .map_or(true, |m| m.get(iter as usize).map_or(true, |row| row[j]))
    }

    fn active_now(&self, j: usize) -> bool {
        self.active_at(self.iter, j)
    }

    fn expected_gather_flow(&self, j: usize, iter: u64) -> u64 {
        self.c
            .proto
            .wire_flow(iter * self.c.plan.stride + self.c.plan.gather_base + j as u64)
    }

    fn up_gather_flow(&self, iter: u64) -> u64 {
        iter * self.c.plan.stride + self.c.up_gather_slot
    }

    fn up_bcast_flow(&self, iter: u64) -> u64 {
        iter * self.c.plan.stride + self.c.up_bcast_slot
    }

    fn ec_cfg(&self, j: usize) -> EarlyCloseCfg {
        if !self.c.proto.is_loss_tolerant() {
            return EarlyCloseCfg::reliable();
        }
        self.c.tracker.cfg(j)
    }

    /// Route one worker gather packet: current-iteration flows go to the
    /// (possibly new) receiver; next-iteration flows are stashed.
    ///
    /// NOTE: this (and the gather arm of [`RelayAggNode::check_progress`])
    /// mirrors `PsNode::on_gather_packet` / `PsNode::check_progress` —
    /// the same threshold-init, Early-Close-open, stash/replay, and
    /// close-record rules over this node's [`PsFlowPlan`] band. A change
    /// to the PS gather path belongs in both places.
    fn on_gather_packet(&mut self, ctx: &mut Ctx, j: usize, pkt: Packet) {
        let now = ctx.now();
        let me = ctx.me;
        let cur = self.expected_gather_flow(j, self.iter);
        let next = self.expected_gather_flow(j, self.iter + 1);
        if pkt.flow == cur && self.phase == RelayPhase::Gathering {
            if self.rx[j].as_ref().map(|r| !r.flow_matches(pkt.flow)).unwrap_or(true) {
                // First packet of this iteration's flow: init thresholds
                // from the advertised estimates (paper §IV-A) and open the
                // receiver under the current Early Close config.
                if let PacketKind::Ltp(hdr) = &pkt.kind {
                    if self.c.proto.is_loss_tolerant()
                        && hdr.btlbw_mbps > 0
                        && (self.iter % self.c.batches_per_epoch == 0
                            || self.c.tracker.lt_threshold(j) == Nanos::MAX)
                    {
                        self.c.tracker.init_link(
                            j,
                            hdr.rtprop_us as Nanos * crate::US,
                            self.c.model_bytes,
                            hdr.btlbw_mbps as u64 * 1_000_000 / 8,
                        );
                    }
                }
                self.rx[j] = Some(self.c.proto.make_rx(RxCfg {
                    flow: pkt.flow,
                    bytes: self.c.model_bytes,
                    ec: self.ec_cfg(j),
                    critical: self.c.critical.clone(),
                    iter: self.iter,
                }));
                self.gather_started[j] = Some(now);
            }
            let mut outgoing = Vec::new();
            if let Some(rx) = &mut self.rx[j] {
                rx.handle(now, &pkt, me, &mut |p| outgoing.push(p));
            }
            for p in outgoing {
                crate::trace::note_ack(ctx, &p);
                ctx.send(p);
            }
        } else if pkt.flow == next {
            if self.stash[j].len() < MAX_STASH {
                self.stash[j].push(pkt);
            }
        } else if pkt.flow == cur {
            // Current flow while not gathering (late retransmissions after
            // close): let the existing receiver re-issue its Stop.
            let mut outgoing = Vec::new();
            if let Some(rx) = &mut self.rx[j] {
                if rx.flow_matches(pkt.flow) {
                    rx.handle(now, &pkt, me, &mut |p| outgoing.push(p));
                }
            }
            for p in outgoing {
                crate::trace::note_ack(ctx, &p);
                ctx.send(p);
            }
        }
        // Anything else: a stale flow — drop.
    }

    fn check_progress(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        if self.phase == RelayPhase::Gathering {
            for j in 0..self.n() {
                // Departed workers are pre-excluded from the barrier:
                // their gathers are never awaited and no delivered
                // fraction is pushed (bubble-filling, DESIGN.md §1.5).
                if self.gather_done[j] || !self.active_now(j) {
                    continue;
                }
                let done = self.rx[j].as_ref().map(|r| r.is_done()).unwrap_or(false);
                if done {
                    self.gather_done[j] = true;
                    let rx = self.rx[j].as_ref().unwrap();
                    let started = self.gather_started[j].unwrap_or(now);
                    self.c.tracker.record_flow(j, now - started, rx.reached_full());
                    self.delivered_fractions.push(rx.delivered_fraction());
                    if let Some((reason, criticals_ok, delivered)) = rx.close_info() {
                        crate::trace::note_close(
                            ctx,
                            self.c.worker_base + j,
                            self.expected_gather_flow(j, self.iter),
                            self.iter,
                            reason,
                            criticals_ok,
                            delivered,
                        );
                        self.c.closes.borrow_mut().push(GatherClose {
                            iter: self.iter,
                            worker: self.c.worker_base + j,
                            reason,
                            criticals_ok,
                            delivered,
                        });
                    }
                    self.arrivals[j] = rx.bitmap().map(|b| {
                        (b.clone(), rx.segment_map().map(|m| m.n_segs as u64).unwrap_or(0))
                    });
                    self.importances.push(match &self.arrivals[j] {
                        Some((bm, n_segs)) => {
                            crate::codec::PriorityScheduler::delivered_importance(
                                bm,
                                *n_segs as u32,
                            )
                        }
                        None => 1.0,
                    });
                }
            }
            if (0..self.n()).all(|j| self.gather_done[j] || !self.active_now(j)) {
                // A zero-active rack still reduces (over all-`None`
                // arrivals) and forwards an empty partial: the relay
                // itself never leaves the root's barrier.
                self.gather_phase_done = now;
                self.phase = RelayPhase::Reducing;
                let dur = self.c.agg.aggregate(self.iter, &self.arrivals);
                self.reduce_dur = dur;
                ctx.set_timer(now + dur, TOK_REDUCE_DONE | self.iter);
            }
        }
        if self.phase == RelayPhase::Forwarding
            && self.tx_up.as_ref().map(|t| t.is_complete()).unwrap_or(false)
        {
            self.phase = RelayPhase::AwaitRoot;
            self.path_up =
                self.tx_up.as_ref().and_then(|t| t.path_estimates()).or(self.path_up);
        }
        if self.phase == RelayPhase::AwaitRoot
            && self.rx_root.as_ref().map(|r| r.is_done()).unwrap_or(false)
        {
            self.begin_local_broadcast(ctx);
        }
        if self.phase == RelayPhase::Broadcasting {
            // Workers absent for this iteration (and not joining at the
            // next barrier) have no sender; vacuous-true when none exist.
            let all = self.tx_down.iter().flatten().all(|t| t.is_complete());
            if all {
                self.finish_iteration(ctx);
            }
        }
    }

    fn begin_forward(&mut self, ctx: &mut Ctx) {
        self.phase = RelayPhase::Forwarding;
        let (rt, bw) = self.path_up.unwrap_or((0, 0));
        self.tx_up = Some(self.c.proto.make_tx(TxCfg {
            flow: self.up_gather_flow(self.iter),
            bytes: self.c.model_bytes,
            critical: self.c.critical.clone(),
            seed_rtprop: rt,
            seed_btlbw_bytes: bw,
            nq_order: None,
        }));
        // The root's broadcast comes back reliably on this iteration's
        // down-slot; open the receiver now, like a worker does.
        self.rx_root = Some(self.c.proto.make_rx(RxCfg {
            flow: self.up_bcast_flow(self.iter),
            bytes: self.c.model_bytes,
            ec: EarlyCloseCfg::reliable(),
            critical: vec![],
            iter: self.iter,
        }));
        self.drain(ctx);
    }

    fn begin_local_broadcast(&mut self, ctx: &mut Ctx) {
        self.phase = RelayPhase::Broadcasting;
        for j in 0..self.n() {
            // Join push: a worker rejoining at the next barrier listens on
            // this iteration's broadcast flow to resynchronize its model
            // before computing (mirrors `PsNode::begin_broadcast`).
            let joins_next = self.iter + 1 < self.c.iters && self.active_at(self.iter + 1, j);
            if !self.active_now(j) && !joins_next {
                continue;
            }
            let flow = self.iter * self.c.plan.stride + self.c.plan.bcast_base + j as u64;
            // Rack-local broadcast is reliable, like every model push.
            self.tx_down[j] = Some(self.c.proto.make_tx(TxCfg {
                flow,
                bytes: self.c.model_bytes,
                critical: vec![],
                seed_rtprop: 0,
                seed_btlbw_bytes: 0,
                nq_order: None,
            }));
        }
        self.drain(ctx);
    }

    fn finish_iteration(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        // Zero-gather iterations (all rack workers departed) fall back to
        // the gather-phase close so the BST subtraction stays in range.
        let first_gather =
            self.gather_started.iter().flatten().min().copied().unwrap_or(self.gather_phase_done);
        // Under churn fewer than `n` flows closed this iteration; average
        // over exactly the flows pushed since the last barrier.
        let pushed = self.delivered_fractions.len() - self.frac_mark;
        let n = pushed.max(1) as f64;
        let recent: f64 = self.delivered_fractions.iter().rev().take(pushed).sum::<f64>() / n;
        let recent_imp: f64 = self.importances.iter().rev().take(pushed).sum::<f64>() / n;
        let stats = IterStats {
            // The whole synchronization span of this rack — local gather,
            // forward, root round-trip, local re-broadcast — minus this
            // rack's own reduce. The root's aggregation latency stays
            // inside the span: it is upper-tier synchronization the rack
            // must wait out, so hier BSTs carry that constant relative to
            // ps/sharded rows (within-topology comparisons, which the
            // conformance invariants use, are unaffected — DESIGN.md §1.2).
            bst: (now - first_gather).saturating_sub(self.reduce_dur),
            gather_time: self.gather_phase_done - first_gather,
            mean_delivered: recent,
            mean_importance: recent_imp,
            loss: self.c.agg.loss(self.iter),
            end: now,
        };
        self.c.report.borrow_mut().push(stats);
        let epoch_end = (self.iter + 1) % self.c.batches_per_epoch == 0;
        if self.c.proto.is_loss_tolerant() && epoch_end {
            self.c.tracker.end_epoch();
        }
        self.iter += 1;
        self.frac_mark = self.delivered_fractions.len();
        for j in 0..self.n() {
            self.rx[j] = None;
            self.tx_down[j] = None;
            self.gather_done[j] = false;
            self.gather_started[j] = None;
            self.arrivals[j] = None;
        }
        self.tx_up = None;
        self.rx_root_prev = self.rx_root.take();
        self.phase =
            if self.iter >= self.c.iters { RelayPhase::Done } else { RelayPhase::Gathering };
        // Replay any gather packets that arrived ahead of the barrier.
        if self.phase == RelayPhase::Gathering {
            let stashes: Vec<Vec<Packet>> =
                self.stash.iter_mut().map(std::mem::take).collect();
            for (j, pkts) in stashes.into_iter().enumerate() {
                for pkt in pkts {
                    self.on_gather_packet(ctx, j, pkt);
                }
            }
            // A zero-active iteration produces no gather packets to kick
            // the barrier; recheck now. Recursion is bounded: the check
            // only arms the aggregation timer (→ Reducing) and returns.
            if self.c.membership.is_some() && (0..self.n()).all(|j| !self.active_now(j)) {
                self.check_progress(ctx);
            }
        }
    }

    fn drain(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        let me = ctx.me;
        if let Some(tx) = &mut self.tx_up {
            while let Some(pkt) = tx.poll(now, me, self.c.root) {
                ctx.send(pkt);
            }
        }
        for j in 0..self.n() {
            if let Some(tx) = &mut self.tx_down[j] {
                while let Some(pkt) = tx.poll(now, me, self.c.workers[j]) {
                    ctx.send(pkt);
                }
            }
        }
        self.check_progress(ctx);
        // Timers: worker receivers' Early Close checks, the forward
        // sender's pacing/PTO, broadcast senders, the root receiver.
        self.timer_gen += 1;
        let mut wake: Option<Nanos> = None;
        for j in 0..self.n() {
            let rxw = self.rx[j].as_ref().and_then(|r| r.next_wakeup(now));
            let txw = self.tx_down[j].as_ref().and_then(|t| t.next_wakeup());
            for cand in [rxw, txw].into_iter().flatten() {
                wake = Some(wake.map_or(cand, |a: Nanos| a.min(cand)));
            }
        }
        let upw = self.tx_up.as_ref().and_then(|t| t.next_wakeup());
        let rootw = self.rx_root.as_ref().and_then(|r| r.next_wakeup(now));
        for cand in [upw, rootw].into_iter().flatten() {
            wake = Some(wake.map_or(cand, |a: Nanos| a.min(cand)));
        }
        if let Some(at) = wake {
            ctx.set_timer(at.max(now + 1), self.timer_gen);
        }
    }
}

impl Node for RelayAggNode {
    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn start(&mut self, ctx: &mut Ctx) {
        // If iteration 0 opens with every rack worker departed, no gather
        // packet will ever arrive to drive the barrier — kick it here.
        // Stable racks (no membership) keep the default no-op.
        if self.c.membership.is_some() && (0..self.n()).all(|j| !self.active_now(j)) {
            self.check_progress(ctx);
        }
    }

    fn on_packet(&mut self, ctx: &mut Ctx, pkt: Packet) {
        if matches!(pkt.kind, PacketKind::Raw(_)) {
            return; // background cross traffic: pure link load, no protocol
        }
        let now = ctx.now();
        let me = ctx.me;
        let slot = pkt.flow % self.c.plan.stride;
        let n = self.n() as u64;
        if slot >= self.c.plan.gather_base && slot < self.c.plan.gather_base + n {
            let j = (slot - self.c.plan.gather_base) as usize;
            self.on_gather_packet(ctx, j, pkt);
        } else if slot >= self.c.plan.bcast_base && slot < self.c.plan.bcast_base + n {
            // ACK/Stop for a rack-local broadcast flow.
            let j = (slot - self.c.plan.bcast_base) as usize;
            if let Some(tx) = &mut self.tx_down[j] {
                if tx.flow_matches(pkt.flow) {
                    tx.handle(now, &pkt);
                }
            }
        } else if slot == self.c.up_gather_slot {
            // ACK/Stop from the root for our forward flow.
            if let Some(tx) = &mut self.tx_up {
                tx.handle(now, &pkt);
            }
        } else if slot == self.c.up_bcast_slot {
            // Model data from the root — current flow, or a straggler
            // retransmission of the previous iteration's.
            let mut outgoing = Vec::new();
            let cur =
                self.rx_root.as_ref().map(|r| r.flow_matches(pkt.flow)).unwrap_or(false);
            if cur {
                if let Some(rx) = &mut self.rx_root {
                    rx.handle(now, &pkt, me, &mut |p| outgoing.push(p));
                }
            } else if let Some(rx) = &mut self.rx_root_prev {
                if rx.flow_matches(pkt.flow) {
                    rx.handle(now, &pkt, me, &mut |p| outgoing.push(p));
                }
            }
            for p in outgoing {
                crate::trace::note_ack(ctx, &p);
                ctx.send(p);
            }
        }
        self.drain(ctx);
    }

    fn on_timer(&mut self, ctx: &mut Ctx, token: u64) {
        if token & TOK_REDUCE_DONE != 0 {
            if token & !TOK_REDUCE_DONE == self.iter && self.phase == RelayPhase::Reducing {
                self.begin_forward(ctx);
            }
            return;
        }
        if token != self.timer_gen {
            return;
        }
        let now = ctx.now();
        let me = ctx.me;
        let mut outgoing = Vec::new();
        for j in 0..self.n() {
            let peer = self.c.workers[j];
            if let Some(rx) = &mut self.rx[j] {
                rx.on_wakeup(now);
                rx.drain(me, peer, &mut |p| outgoing.push(p));
            }
            if let Some(tx) = &mut self.tx_down[j] {
                tx.on_wakeup(now);
            }
        }
        if let Some(tx) = &mut self.tx_up {
            tx.on_wakeup(now);
        }
        if let Some(rx) = &mut self.rx_root {
            rx.on_wakeup(now);
            rx.drain(me, self.c.root, &mut |p| outgoing.push(p));
        }
        for p in outgoing {
            crate::trace::note_ack(ctx, &p);
            ctx.send(p);
        }
        self.drain(ctx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_parses_canonical_names() {
        for (spec, canon, aggs) in [
            ("ps", "ps", 1),
            ("PS", "ps", 1),
            ("sharded:n=1", "sharded:n=1", 1),
            ("sharded:n=4", "sharded:n=4", 4),
            ("SHARDED:N=8", "sharded:n=8", 8),
            ("hier", "hier", 3),
            ("hier:racks=2", "hier:racks=2", 3),
            ("hier:racks=4", "hier:racks=4", 5),
        ] {
            let a = parse_agg(spec).unwrap_or_else(|e| panic!("{spec}: {e:#}"));
            assert_eq!(a.name(), canon, "{spec}");
            assert_eq!(a.n_aggregators(8), aggs, "{spec}");
            // Canonical form is a fixed point of the grammar.
            assert_eq!(parse_agg(a.name()).unwrap().name(), canon);
        }
        assert_eq!(parse_agg("ps").unwrap(), default_agg());
    }

    #[test]
    fn malformed_specs_are_rejected() {
        for bad in [
            "mesh",            // unknown key
            "ps:n=2",          // ps takes no params
            "sharded",         // n is required
            "sharded:",        // empty param list
            "sharded:n=0",     // zero shards
            "sharded:n=two",   // non-numeric
            "sharded:m=2",     // unknown param
            "sharded:n=2,n=4", // duplicate param
            "hier:racks=0",    // zero racks
            "hier:n=2",        // unknown param
        ] {
            assert!(parse_agg(bad).is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn validation_enforces_divisibility_and_fabric() {
        let star = Topo::Star;
        let sharded4 = parse_agg("sharded:n=4").unwrap();
        assert!(sharded4.validate(8, 10_000_000, &star).is_ok());
        assert!(sharded4.validate(6, 10_000_000, &star).is_err(), "6 % 4 != 0");
        // Fewer segments than shards.
        assert!(sharded4.validate(4, 12, &star).is_err());
        let hier3 = parse_agg("hier:racks=3").unwrap();
        assert!(hier3.validate(6, 10_000_000, &star).is_ok());
        assert!(hier3.validate(8, 10_000_000, &star).is_err(), "8 % 3 != 0");
        // Aggregations that own their fabric reject a two-rack override.
        let two_rack = Topo::TwoRack {
            rack0_workers: 2,
            trunk: crate::simnet::LinkCfg::dcn(10, 2),
        };
        assert!(sharded4.validate(8, 10_000_000, &two_rack).is_err());
        assert!(hier3.validate(6, 10_000_000, &two_rack).is_err());
        assert!(parse_agg("ps").unwrap().validate(8, 10_000_000, &two_rack).is_ok());
    }

    #[test]
    fn shard_ranges_partition_the_segment_space() {
        let seg = Manifest::aligned_payload(LTP_MSS) as u64;
        let bytes = 10 * seg + 7; // 11 segments, last one partial
        let ranges = shard_ranges(bytes, 4);
        assert_eq!(ranges.len(), 4);
        let total_bytes: u64 = ranges.iter().map(|r| r.0).sum();
        let total_segs: u64 = ranges.iter().map(|r| r.2).sum();
        assert_eq!(total_bytes, bytes, "byte ranges must tile the gradient");
        assert_eq!(total_segs, 11);
        // Contiguous, in order.
        let mut next = 0;
        for &(_, seg0, count) in &ranges {
            assert_eq!(seg0, next);
            assert!(count >= 2, "11 segs over 4 shards: 3/3/3/2");
            next = seg0 + count;
        }
        // n = 1 is the whole message.
        let whole = shard_ranges(bytes, 1);
        assert_eq!(whole, vec![(bytes, 0, 11)]);
    }

    #[test]
    fn endpoint_roles_describe_every_topology() {
        let bytes = 1_000_000u64;
        assert_eq!(
            parse_agg("ps").unwrap().endpoint_roles(8, bytes),
            vec![EndpointRole::Final { byte_offset: 0, bytes }]
        );
        // Sharded roles tile the byte space contiguously.
        let roles = parse_agg("sharded:n=4").unwrap().endpoint_roles(8, bytes);
        assert_eq!(roles.len(), 4);
        let mut next = 0u64;
        let mut total = 0u64;
        for r in &roles {
            let EndpointRole::Final { byte_offset, bytes } = *r else {
                panic!("sharded endpoints are terminal: {r:?}");
            };
            assert_eq!(byte_offset, next);
            next = byte_offset + bytes;
            total += bytes;
        }
        assert_eq!(total, bytes);
        // Hier: racks first (partitioning the workers in order), root last.
        let roles = parse_agg("hier:racks=2").unwrap().endpoint_roles(8, bytes);
        assert_eq!(
            roles,
            vec![
                EndpointRole::Relay { first_worker: 0, n_workers: 4 },
                EndpointRole::Relay { first_worker: 4, n_workers: 4 },
                EndpointRole::Root { racks: 2 },
            ]
        );
        // Role counts always match the endpoint counts `build` numbers.
        for spec in ["ps", "sharded:n=2", "sharded:n=8", "hier", "hier:racks=4"] {
            let a = parse_agg(spec).unwrap();
            assert_eq!(a.endpoint_roles(8, bytes).len(), a.n_aggregators(8), "{spec}");
        }
    }

    #[test]
    fn shard_criticals_rebase_to_the_shard() {
        let critical = vec![0, 2, 5, 9];
        assert_eq!(shard_criticals(&critical, 0, 3), vec![0, 2]);
        assert_eq!(shard_criticals(&critical, 3, 3), vec![2]);
        assert_eq!(shard_criticals(&critical, 6, 5), vec![3]);
        assert_eq!(shard_criticals(&critical, 0, 11), critical);
    }
}
