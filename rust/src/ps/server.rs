//! The Parameter Server node: accepts one gather flow per worker per
//! iteration (loss-tolerant under Early Close for LTP), aggregates, and
//! broadcasts the updated model reliably.
//!
//! A [`PsNode`] serves one **aggregator endpoint** of an aggregation
//! topology (DESIGN.md §1.2): the classic single PS, one shard of a
//! sharded deployment, or the root of a hierarchical one. Its place in
//! the run's per-iteration flow-id space is described by a
//! [`PsFlowPlan`]; the single-PS plan reproduces the original layout
//! bit-for-bit.
//!
//! BSP pipelining race: a fast worker can finish its broadcast and start
//! the *next* gather while the PS is still broadcasting to stragglers.
//! Those early packets are stashed and replayed when the iteration
//! advances (a real PS would equally buffer them in its UDP socket).

use super::spec::ProtoSpec;
use super::transport::{FlowRx, FlowTx, RxCfg, TxCfg};
use super::{GatherClose, IterStats};
use crate::proto::{EarlyCloseCfg, ThresholdTracker};
use crate::simnet::{Ctx, EntityId, Node, Packet};
use crate::util::Bitmap;
use crate::wire::PacketKind;
use crate::Nanos;
use std::cell::RefCell;
use std::rc::Rc;

/// Aggregation backend. Called when all gathers of an iteration closed;
/// returns the simulated aggregation duration.
pub trait Aggregate {
    /// `arrivals[w]` is `Some((bitmap, n_segs))` for LTP flows (which
    /// segments arrived) and `None` for TCP (everything arrived).
    fn aggregate(&mut self, iter: u64, arrivals: &[Option<(Bitmap, u64)>]) -> Nanos;
    /// Mean worker training loss for this iteration, if known.
    fn loss(&mut self, _iter: u64) -> Option<f32> {
        None
    }
}

/// No-op aggregation with a fixed modeled duration.
pub struct NullAggregate(pub Nanos);

impl Aggregate for NullAggregate {
    fn aggregate(&mut self, _iter: u64, _arrivals: &[Option<(Bitmap, u64)>]) -> Nanos {
        self.0
    }
}

/// Where an aggregator endpoint's flows live inside the run's
/// per-iteration flow-id space. Iteration `i`'s flows for worker `w`
/// (local index) are `i * stride + gather_base + w` (gather direction)
/// and `i * stride + bcast_base + w` (broadcast direction); all
/// endpoints of one run share `stride`, so their flow spaces never
/// collide.
#[derive(Debug, Clone, Copy)]
pub struct PsFlowPlan {
    pub gather_base: u64,
    pub bcast_base: u64,
    pub stride: u64,
}

impl PsFlowPlan {
    /// The classic single-PS layout: gathers in `[0, W)`, broadcasts in
    /// `[W, 2W)`, stride `2W` — the original star run's numbering.
    pub fn single(n_workers: usize) -> PsFlowPlan {
        PsFlowPlan {
            gather_base: 0,
            bcast_base: n_workers as u64,
            stride: 2 * n_workers as u64,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Gathering,
    Aggregating,
    Broadcasting,
    Done,
}

const TOK_AGG_DONE: u64 = 1 << 41;
/// Cap on stashed ahead-of-iteration packets per worker.
const MAX_STASH: usize = 8192;

pub struct PsNode {
    workers: Vec<EntityId>,
    proto: ProtoSpec,
    model_bytes: u64,
    /// Bytes each gather flow actually carries on the wire — the codec's
    /// encoded image of `model_bytes` (DESIGN.md §1.4). Equal to
    /// `model_bytes` for the identity codec.
    gather_bytes: u64,
    critical: Vec<u32>,
    plan: PsFlowPlan,
    /// Offset added to local source indices in [`GatherClose::worker`], so
    /// every aggregator endpoint of a run reports in one namespace (the
    /// `hier` root's rack flows index after the workers).
    worker_base: usize,
    agg: Box<dyn Aggregate>,
    pub tracker: ThresholdTracker,
    iters: u64,
    iter: u64,
    phase: Phase,
    /// Gather receiver per worker for the *current* iteration.
    rx: Vec<Option<Box<dyn FlowRx>>>,
    /// Broadcast sender per worker.
    tx: Vec<Option<Box<dyn FlowTx>>>,
    gather_done: Vec<bool>,
    gather_started: Vec<Option<Nanos>>,
    /// Early packets for the next iteration's gather flows.
    stash: Vec<Vec<Packet>>,
    gather_phase_done: Nanos,
    bcast_started: Nanos,
    batches_per_epoch: u64,
    timer_gen: u64,
    /// Per-iteration membership rows from the churn plan
    /// (`membership[iter][w]`, local worker indices); `None` (the default)
    /// keeps the fixed-worker-set fast path bit-for-bit.
    membership: Option<Vec<Vec<bool>>>,
    /// `delivered_fractions`/`importances` length at the start of the
    /// current iteration — under churn only active workers push, so the
    /// per-iteration window is a count, not `n()`.
    frac_mark: usize,
    pub report: Rc<RefCell<Vec<IterStats>>>,
    arrivals: Vec<Option<(Bitmap, u64)>>,
    pub delivered_fractions: Vec<f64>,
    /// Per-flow tensor-priority-weighted delivered importance, parallel to
    /// `delivered_fractions` (reliable flows score 1.0).
    pub importances: Vec<f64>,
    /// Per-flow close records (LTP gathers only), across all iterations —
    /// shared with the runner, which merges every aggregator's records.
    pub closes: Rc<RefCell<Vec<GatherClose>>>,
}

impl PsNode {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        workers: Vec<EntityId>,
        proto: ProtoSpec,
        model_bytes: u64,
        critical: Vec<u32>,
        plan: PsFlowPlan,
        agg: Box<dyn Aggregate>,
        tracker: ThresholdTracker,
        iters: u64,
        batches_per_epoch: u64,
        report: Rc<RefCell<Vec<IterStats>>>,
        closes: Rc<RefCell<Vec<GatherClose>>>,
    ) -> PsNode {
        let w = workers.len();
        PsNode {
            workers,
            proto,
            model_bytes,
            gather_bytes: model_bytes,
            critical,
            plan,
            worker_base: 0,
            agg,
            tracker,
            iters,
            iter: 0,
            phase: Phase::Gathering,
            rx: (0..w).map(|_| None).collect(),
            tx: (0..w).map(|_| None).collect(),
            gather_done: vec![false; w],
            gather_started: vec![None; w],
            stash: vec![Vec::new(); w],
            gather_phase_done: 0,
            bcast_started: 0,
            batches_per_epoch,
            timer_gen: 0,
            membership: None,
            frac_mark: 0,
            report,
            arrivals: (0..w).map(|_| None).collect(),
            delivered_fractions: vec![],
            importances: vec![],
            closes,
        }
    }

    /// Report close records with source indices offset by `base` (the
    /// `hier` root numbers its rack forward flows after the workers, so
    /// the run-wide close list stays unambiguous).
    pub fn with_worker_base(mut self, base: usize) -> PsNode {
        self.worker_base = base;
        self
    }

    /// Serve gather flows whose wire image is `bytes` long (a sparsifying
    /// codec's encoded size — DESIGN.md §1.4). The broadcast direction
    /// keeps carrying the dense `model_bytes`.
    pub fn with_gather_bytes(mut self, bytes: u64) -> PsNode {
        self.gather_bytes = bytes;
        self
    }

    /// Attach the churn plan's membership rows (`active[iter][w]`, local
    /// worker indices). Absent workers are excluded from the barrier:
    /// their gathers are never awaited, they push no delivered fraction,
    /// and their `arrivals` slot stays `None` so the masked-mean
    /// denominator never counts them (bubble-filling semantics). Joiners
    /// are admitted at the next barrier via a join-push broadcast of the
    /// preceding iteration's model.
    pub fn with_membership(mut self, active: Vec<Vec<bool>>) -> PsNode {
        self.membership = Some(active);
        self
    }

    fn n(&self) -> usize {
        self.workers.len()
    }

    /// Is local worker `w` a barrier participant at `iter`?
    fn active_at(&self, iter: u64, w: usize) -> bool {
        self.membership
            .as_ref()
            .map_or(true, |m| m.get(iter as usize).map_or(true, |row| row[w]))
    }

    /// Is local worker `w` a participant of the current iteration?
    fn active_now(&self, w: usize) -> bool {
        self.active_at(self.iter, w)
    }

    fn expected_gather_flow(&self, w: usize, iter: u64) -> u64 {
        self.proto
            .wire_flow(iter * self.plan.stride + self.plan.gather_base + w as u64)
    }

    /// Resolve a flow id to `(local worker index, is_gather)`. Flows
    /// outside this endpoint's bands resolve to `(self.n(), _)`, which the
    /// caller drops. As before, the slot arithmetic assumes the wire's
    /// (possibly truncated) flow ids have not wrapped within a run.
    fn worker_of_flow(&self, flow: u64) -> (usize, bool) {
        let slot = flow % self.plan.stride;
        let n = self.n() as u64;
        if slot >= self.plan.gather_base && slot < self.plan.gather_base + n {
            ((slot - self.plan.gather_base) as usize, true)
        } else if slot >= self.plan.bcast_base && slot < self.plan.bcast_base + n {
            ((slot - self.plan.bcast_base) as usize, false)
        } else {
            (self.n(), true)
        }
    }

    fn ec_cfg(&self, w: usize) -> EarlyCloseCfg {
        if !self.proto.is_loss_tolerant() {
            return EarlyCloseCfg::reliable();
        }
        self.tracker.cfg(w)
    }

    /// Route one gather-direction packet: current-iteration flows go to the
    /// (possibly new) receiver; next-iteration flows are stashed.
    ///
    /// NOTE: the rack-local relay (`ps/agg.rs`, `RelayAggNode`) mirrors
    /// this gather machinery for its worker-facing side — a change here
    /// belongs there too.
    fn on_gather_packet(&mut self, ctx: &mut Ctx, w: usize, pkt: Packet) {
        let now = ctx.now();
        let me = ctx.me;
        let cur = self.expected_gather_flow(w, self.iter);
        let next = self.expected_gather_flow(w, self.iter + 1);
        if pkt.flow == cur && self.phase == Phase::Gathering {
            if self.rx[w].as_ref().map(|r| !r.flow_matches(pkt.flow)).unwrap_or(true) {
                // First packet of this iteration's flow: init thresholds
                // from the advertised estimates (paper §IV-A) and open the
                // receiver under the current Early Close config.
                if let PacketKind::Ltp(hdr) = &pkt.kind {
                    if self.proto.is_loss_tolerant()
                        && hdr.btlbw_mbps > 0
                        && (self.iter % self.batches_per_epoch == 0
                            || self.tracker.lt_threshold(w) == Nanos::MAX)
                    {
                        self.tracker.init_link(
                            w,
                            hdr.rtprop_us as Nanos * crate::US,
                            self.gather_bytes,
                            hdr.btlbw_mbps as u64 * 1_000_000 / 8,
                        );
                    }
                }
                self.rx[w] = Some(self.proto.make_rx(RxCfg {
                    flow: pkt.flow,
                    bytes: self.gather_bytes,
                    ec: self.ec_cfg(w),
                    critical: self.critical.clone(),
                    iter: self.iter,
                }));
                self.gather_started[w] = Some(now);
            }
            let mut outgoing = Vec::new();
            if let Some(rx) = &mut self.rx[w] {
                rx.handle(now, &pkt, me, &mut |p| outgoing.push(p));
            }
            for p in outgoing {
                crate::trace::note_ack(ctx, &p);
                ctx.send(p);
            }
        } else if pkt.flow == next {
            if self.stash[w].len() < MAX_STASH {
                self.stash[w].push(pkt);
            }
        } else if pkt.flow == cur {
            // Current flow while not gathering (late retransmissions after
            // close): let the existing receiver re-issue its Stop.
            let mut outgoing = Vec::new();
            if let Some(rx) = &mut self.rx[w] {
                if rx.flow_matches(pkt.flow) {
                    rx.handle(now, &pkt, me, &mut |p| outgoing.push(p));
                }
            }
            for p in outgoing {
                crate::trace::note_ack(ctx, &p);
                ctx.send(p);
            }
        }
        // Anything else: a stale flow — drop.
    }

    fn check_progress(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        match self.phase {
            Phase::Gathering => {
                for w in 0..self.n() {
                    if self.gather_done[w] || !self.active_now(w) {
                        continue;
                    }
                    let done = self.rx[w].as_ref().map(|r| r.is_done()).unwrap_or(false);
                    if done {
                        self.gather_done[w] = true;
                        let rx = self.rx[w].as_ref().unwrap();
                        let started = self.gather_started[w].unwrap_or(now);
                        self.tracker.record_flow(w, now - started, rx.reached_full());
                        self.delivered_fractions.push(rx.delivered_fraction());
                        if let Some((reason, criticals_ok, delivered)) = rx.close_info() {
                            crate::trace::note_close(
                                ctx,
                                self.worker_base + w,
                                self.expected_gather_flow(w, self.iter),
                                self.iter,
                                reason,
                                criticals_ok,
                                delivered,
                            );
                            self.closes.borrow_mut().push(GatherClose {
                                iter: self.iter,
                                worker: self.worker_base + w,
                                reason,
                                criticals_ok,
                                delivered,
                            });
                        }
                        self.arrivals[w] = rx.bitmap().map(|b| {
                            (b.clone(), rx.segment_map().map(|m| m.n_segs as u64).unwrap_or(0))
                        });
                        self.importances.push(match &self.arrivals[w] {
                            Some((bm, n_segs)) => {
                                crate::codec::PriorityScheduler::delivered_importance(
                                    bm,
                                    *n_segs as u32,
                                )
                            }
                            None => 1.0,
                        });
                    }
                }
                if (0..self.n()).all(|w| self.gather_done[w] || !self.active_now(w)) {
                    self.gather_phase_done = now;
                    self.phase = Phase::Aggregating;
                    let dur = self.agg.aggregate(self.iter, &self.arrivals);
                    ctx.set_timer(now + dur, TOK_AGG_DONE | self.iter);
                }
            }
            Phase::Broadcasting => {
                // Absent workers have no broadcast sender — completion is
                // over the senders that exist (vacuously true when a
                // zero-active iteration created none).
                let all = self.tx.iter().flatten().all(|t| t.is_complete());
                if all {
                    self.finish_iteration(ctx);
                }
            }
            _ => {}
        }
    }

    fn begin_broadcast(&mut self, ctx: &mut Ctx) {
        self.phase = Phase::Broadcasting;
        self.bcast_started = ctx.now();
        for w in 0..self.n() {
            // Broadcast to this iteration's participants, plus next
            // iteration's joiners (the join push: a rejoining worker waits
            // on this flow for the model it will compute from).
            let joins_next =
                self.iter + 1 < self.iters && self.active_at(self.iter + 1, w);
            if !self.active_now(w) && !joins_next {
                continue;
            }
            let flow = self.iter * self.plan.stride + self.plan.bcast_base + w as u64;
            // Broadcast is reliable; the sender retransmits until the
            // receiver confirms 100 % (no Early Close on this direction).
            self.tx[w] = Some(self.proto.make_tx(TxCfg {
                flow,
                bytes: self.model_bytes,
                critical: vec![],
                seed_rtprop: 0,
                seed_btlbw_bytes: 0,
                nq_order: None,
            }));
        }
        self.drain(ctx);
    }

    fn finish_iteration(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        // Zero-gather iterations (churn: every worker absent) fall back to
        // the gather-phase close time, keeping the BST math subtraction-safe.
        let first_gather = self
            .gather_started
            .iter()
            .flatten()
            .min()
            .copied()
            .unwrap_or(self.gather_phase_done);
        // The per-iteration window is what this iteration actually pushed:
        // `n()` for a stable membership, the active count under churn.
        let pushed = self.delivered_fractions.len() - self.frac_mark;
        let n = pushed.max(1) as f64;
        let recent: f64 = self.delivered_fractions.iter().rev().take(pushed).sum::<f64>() / n;
        let recent_imp: f64 = self.importances.iter().rev().take(pushed).sum::<f64>() / n;
        let stats = IterStats {
            bst: (self.gather_phase_done - first_gather) + (now - self.bcast_started),
            gather_time: self.gather_phase_done - first_gather,
            mean_delivered: recent,
            mean_importance: recent_imp,
            loss: self.agg.loss(self.iter),
            end: now,
        };
        self.report.borrow_mut().push(stats);
        if self.proto.is_loss_tolerant() && (self.iter + 1) % self.batches_per_epoch == 0 {
            self.tracker.end_epoch();
        }
        self.iter += 1;
        self.frac_mark = self.delivered_fractions.len();
        for w in 0..self.n() {
            self.rx[w] = None;
            self.tx[w] = None;
            self.gather_done[w] = false;
            self.gather_started[w] = None;
            self.arrivals[w] = None;
        }
        self.phase = if self.iter >= self.iters { Phase::Done } else { Phase::Gathering };
        // Replay any gather packets that arrived ahead of the barrier.
        if self.phase == Phase::Gathering {
            let stashes: Vec<Vec<Packet>> =
                self.stash.iter_mut().map(std::mem::take).collect();
            for (w, pkts) in stashes.into_iter().enumerate() {
                for pkt in pkts {
                    self.on_gather_packet(ctx, w, pkt);
                }
            }
            // A zero-active iteration (churn) has no gathers to wait for:
            // re-check so the vacuous barrier aggregates and moves on.
            // Bounded recursion — the check arms the aggregation timer.
            if self.membership.is_some() && (0..self.n()).all(|w| !self.active_now(w)) {
                self.check_progress(ctx);
            }
        }
    }

    fn drain(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        for w in 0..self.n() {
            if let Some(tx) = &mut self.tx[w] {
                let me = ctx.me;
                while let Some(pkt) = tx.poll(now, me, self.workers[w]) {
                    ctx.send(pkt);
                }
            }
        }
        self.check_progress(ctx);
        // Timers: receivers' early-close thresholds + senders' pacing/PTO.
        self.timer_gen += 1;
        let mut wake: Option<Nanos> = None;
        for w in 0..self.n() {
            let rxw = self.rx[w].as_ref().and_then(|r| r.next_wakeup(now));
            let txw = self.tx[w].as_ref().and_then(|t| t.next_wakeup());
            for cand in [rxw, txw].into_iter().flatten() {
                wake = Some(wake.map_or(cand, |a: Nanos| a.min(cand)));
            }
        }
        if let Some(at) = wake {
            ctx.set_timer(at.max(now + 1), self.timer_gen);
        }
    }

    pub fn iterations_done(&self) -> u64 {
        self.iter
    }

    pub fn is_done(&self) -> bool {
        self.phase == Phase::Done
    }
}

impl Node for PsNode {
    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn start(&mut self, ctx: &mut Ctx) {
        // A churn plan whose first iteration has no active workers must
        // aggregate the vacuous barrier immediately: nothing will arrive
        // to trigger progress otherwise.
        if self.membership.is_some() && (0..self.n()).all(|w| !self.active_now(w)) {
            self.check_progress(ctx);
        }
    }

    fn on_packet(&mut self, ctx: &mut Ctx, pkt: Packet) {
        if matches!(pkt.kind, PacketKind::Raw(_)) {
            return; // background cross traffic: pure link load, no protocol
        }
        let now = ctx.now();
        let (w, is_gather) = self.worker_of_flow(pkt.flow);
        if w >= self.n() {
            return;
        }
        if is_gather {
            self.on_gather_packet(ctx, w, pkt);
        } else if let Some(tx) = &mut self.tx[w] {
            // ACK/Stop for a broadcast flow.
            if tx.flow_matches(pkt.flow) {
                tx.handle(now, &pkt);
            }
        }
        self.drain(ctx);
    }

    fn on_timer(&mut self, ctx: &mut Ctx, token: u64) {
        if token & TOK_AGG_DONE != 0 {
            if token & !TOK_AGG_DONE == self.iter && self.phase == Phase::Aggregating {
                self.begin_broadcast(ctx);
            }
            return;
        }
        if token != self.timer_gen {
            return;
        }
        let now = ctx.now();
        let me = ctx.me;
        let mut outgoing = Vec::new();
        for w in 0..self.n() {
            let peer = self.workers[w];
            if let Some(rx) = &mut self.rx[w] {
                rx.on_wakeup(now);
                rx.drain(me, peer, &mut |p| outgoing.push(p));
            }
            if let Some(tx) = &mut self.tx[w] {
                tx.on_wakeup(now);
            }
        }
        for p in outgoing {
            crate::trace::note_ack(ctx, &p);
            ctx.send(p);
        }
        self.drain(ctx);
    }
}
