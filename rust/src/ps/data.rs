//! Synthetic training corpus: a deterministic token stream with enough
//! structure to be learnable (a noisy order-2 Markov chain over the vocab),
//! standing in for CIFAR-10 on this CPU testbed (DESIGN.md §2).

use crate::util::Pcg64;

/// Token corpus generator; every worker gets disjoint batches.
pub struct Corpus {
    vocab: u32,
    rng: Pcg64,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        Corpus { vocab: vocab as u32, rng: Pcg64::new(seed, 99) }
    }

    /// Next [batch, seq_len+1] token block, flattened row-major.
    ///
    /// The successor rule `x ← (5x + 7) mod V` is *global* (the same for
    /// every worker and batch) with 5 % random jumps: a dataset whose
    /// conditional entropy is low, so a few dozen SGD steps visibly reduce
    /// the LM loss — the property the convergence experiments rely on.
    pub fn next_batch(&mut self, batch: usize, seq_plus1: usize) -> Vec<i32> {
        const A: u32 = 5;
        const B: u32 = 7;
        let mut out = Vec::with_capacity(batch * seq_plus1);
        for _ in 0..batch {
            let mut x = self.rng.gen_range(self.vocab as u64) as u32;
            for _ in 0..seq_plus1 {
                out.push(x as i32);
                if self.rng.chance(0.05) {
                    x = self.rng.gen_range(self.vocab as u64) as u32;
                } else {
                    x = (A.wrapping_mul(x).wrapping_add(B)) % self.vocab;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_have_right_shape_and_range() {
        let mut c = Corpus::new(512, 1);
        let b = c.next_batch(4, 65);
        assert_eq!(b.len(), 4 * 65);
        assert!(b.iter().all(|&t| t >= 0 && t < 512));
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Corpus::new(512, 7);
        let mut b = Corpus::new(512, 7);
        assert_eq!(a.next_batch(2, 10), b.next_batch(2, 10));
        let mut c = Corpus::new(512, 8);
        assert_ne!(a.next_batch(2, 10), c.next_batch(2, 10));
    }

    #[test]
    fn sequences_are_compressible() {
        // The conditional entropy of the walk is far below log2(V): verify
        // the most frequent next-token given current token dominates.
        let mut c = Corpus::new(64, 3);
        let toks = c.next_batch(1, 2000);
        let mut follows = std::collections::HashMap::new();
        for w in toks.windows(2) {
            *follows.entry((w[0], w[1])).or_insert(0u32) += 1;
        }
        let mut best = std::collections::HashMap::new();
        for (&(a, _b), &n) in &follows {
            let e = best.entry(a).or_insert(0u32);
            *e = (*e).max(n);
        }
        let total: u32 = follows.values().sum();
        let captured: u32 = best.values().sum();
        assert!(
            captured as f64 / total as f64 > 0.5,
            "walk should be predictable: {}",
            captured as f64 / total as f64
        );
    }
}
