//! [`RunBuilder`] — the validated, fluent constructor for training runs.
//!
//! Replaces the bare 15-field [`TrainingCfg`] struct-literal plumbing:
//! defaults come from [`Workload`] / [`NetEnv`] presets, call sites
//! override only what their experiment varies, and [`RunBuilder::build`]
//! fails fast on inconsistent combinations (a rack holding more workers
//! than the run has, an Early Close threshold outside `(0, 1]`, a message
//! too large for LTP's 24-bit segment space, …) instead of letting them
//! surface as silent mis-simulations.

use super::agg::{default_agg, AggSpec, Topo};
use super::runner::{BgFlow, RunReport, TrainingCfg};
use super::spec::ProtoSpec;
use crate::churn::{default_churn, ChurnSpec};
use crate::codec::{default_codec, CodecSpec};
use crate::compute::BackendSpec;
use crate::config::{NetEnv, Workload};
use crate::grad::Manifest;
use crate::proto::MAX_SEGS;
use crate::simnet::{LinkCfg, LossModel};
use crate::wire::LTP_MSS;
use crate::{Nanos, MS, SEC};
use anyhow::{ensure, Result};

/// How the critical segment set is derived at [`RunBuilder::build`] time.
#[derive(Debug, Clone)]
enum Critical {
    /// A synthetic tensor manifest with `n` tensors over the final message
    /// size (the modeled-compute default).
    Synthetic(usize),
    /// An explicit segment list (real manifests, protocol tests).
    Explicit(Vec<u32>),
}

/// Fluent, validated builder for a [`TrainingCfg`].
///
/// ```no_run
/// use ltp::ps::{parse_proto, RunBuilder};
/// use ltp::config::{NetEnv, Workload};
/// use ltp::simnet::LossModel;
///
/// let report = RunBuilder::modeled(parse_proto("ltp")?, Workload::Micro, 8)
///     .iters(4)
///     .net_env(NetEnv::WanBursty)
///     .loss(LossModel::Bernoulli { p: 0.01 })
///     .run()?;
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Debug, Clone)]
pub struct RunBuilder {
    proto: ProtoSpec,
    workers: usize,
    iters: u64,
    model_bytes: u64,
    critical: Critical,
    compute_time: Nanos,
    agg_time: Nanos,
    link: LinkCfg,
    switch_delay: Nanos,
    pct_threshold: f64,
    deadline_slack: Nanos,
    batches_per_epoch: u64,
    seed: u64,
    horizon: Nanos,
    topo: Topo,
    bg: Vec<BgFlow>,
    agg: AggSpec,
    backend: Option<BackendSpec>,
    codec: CodecSpec,
    churn: ChurnSpec,
}

impl RunBuilder {
    /// A modeled-compute run with the workload's message size and
    /// calibrated compute time on the testbed rack — the same defaults
    /// [`TrainingCfg::modeled`] has always produced.
    pub fn modeled(proto: ProtoSpec, workload: Workload, workers: usize) -> RunBuilder {
        RunBuilder {
            proto,
            workers,
            iters: 10,
            model_bytes: workload.model_bytes(),
            critical: Critical::Synthetic(50),
            compute_time: workload.compute_time(),
            agg_time: 2 * MS,
            link: NetEnv::Rack.link(),
            switch_delay: 500,
            pct_threshold: 0.8,
            deadline_slack: NetEnv::Rack.deadline_slack(),
            batches_per_epoch: 10,
            seed: 1,
            horizon: 3600 * SEC,
            topo: Topo::Star,
            bg: vec![],
            agg: default_agg(),
            backend: None,
            codec: default_codec(),
            churn: default_churn(),
        }
    }

    pub fn iters(mut self, iters: u64) -> RunBuilder {
        self.iters = iters;
        self
    }

    /// Gradient bytes per worker per iteration. The synthetic critical set
    /// follows the new size; an [`RunBuilder::critical`] override does not.
    pub fn model_bytes(mut self, bytes: u64) -> RunBuilder {
        self.model_bytes = bytes;
        self
    }

    /// Derive criticals from a synthetic manifest with `n` tensors (the
    /// default uses 50).
    pub fn critical_tensors(mut self, n: usize) -> RunBuilder {
        self.critical = Critical::Synthetic(n);
        self
    }

    /// Explicit critical segment ids (e.g. from a real model manifest).
    pub fn critical(mut self, segments: Vec<u32>) -> RunBuilder {
        self.critical = Critical::Explicit(segments);
        self
    }

    pub fn compute_time(mut self, t: Nanos) -> RunBuilder {
        self.compute_time = t;
        self
    }

    pub fn agg_time(mut self, t: Nanos) -> RunBuilder {
        self.agg_time = t;
        self
    }

    /// Replace the edge-link configuration (drops any loss set earlier —
    /// call [`RunBuilder::loss`] after).
    pub fn link(mut self, link: LinkCfg) -> RunBuilder {
        self.link = link;
        self
    }

    /// Apply a network-environment preset: edge link *and* deadline slack.
    pub fn net_env(mut self, env: NetEnv) -> RunBuilder {
        self.link = env.link();
        self.deadline_slack = env.deadline_slack();
        self
    }

    /// Impose a loss model on the current edge link.
    pub fn loss(mut self, loss: LossModel) -> RunBuilder {
        self.link = self.link.with_loss(loss);
        self
    }

    /// The edge link as configured so far — for deriving related links
    /// (e.g. a trunk with a deeper queue).
    pub fn link_cfg(&self) -> LinkCfg {
        self.link
    }

    pub fn switch_delay(mut self, d: Nanos) -> RunBuilder {
        self.switch_delay = d;
        self
    }

    /// Early Close data-percentage threshold (paper Fig 7).
    pub fn pct_threshold(mut self, pct: f64) -> RunBuilder {
        self.pct_threshold = pct;
        self
    }

    /// Deadline slack C (paper §III-B1: 30 ms DCN / 100 ms WAN).
    pub fn deadline_slack(mut self, slack: Nanos) -> RunBuilder {
        self.deadline_slack = slack;
        self
    }

    pub fn batches_per_epoch(mut self, n: u64) -> RunBuilder {
        self.batches_per_epoch = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> RunBuilder {
        self.seed = seed;
        self
    }

    /// Wall-clock cap on the simulation.
    pub fn horizon(mut self, horizon: Nanos) -> RunBuilder {
        self.horizon = horizon;
        self
    }

    /// Two racks under one aggregation switch: the PS and `rack0_workers`
    /// workers in rack 0, the rest in rack 1 behind `trunk`.
    pub fn two_rack(mut self, rack0_workers: usize, trunk: LinkCfg) -> RunBuilder {
        self.topo = Topo::TwoRack { rack0_workers, trunk };
        self
    }

    /// Add a background flow sharing the fabric.
    pub fn bg(mut self, flow: BgFlow) -> RunBuilder {
        self.bg.push(flow);
        self
    }

    /// Choose the aggregation topology (`ps`, `sharded:n=4`,
    /// `hier:racks=2`, … — see [`super::parse_agg`]). The default is the
    /// single-PS star, whose reports are byte-identical to the
    /// pre-aggregation-API runs.
    pub fn agg(mut self, agg: AggSpec) -> RunBuilder {
        self.agg = agg;
        self
    }

    /// Attach a compute backend (`native`, `xla:preset=tiny`, … — see
    /// [`crate::compute::parse_backend`]). [`RunBuilder::build`] then
    /// derives the message size and critical set from the backend's model
    /// (overriding [`RunBuilder::model_bytes`]/[`RunBuilder::critical`]),
    /// checks the backend's preconditions fail-fast (the error names the
    /// actual missing dependency, e.g. `make artifacts` for `xla`), and
    /// the run's report gains a deterministic `train` block.
    pub fn backend(mut self, backend: BackendSpec) -> RunBuilder {
        self.backend = Some(backend);
        self
    }

    /// Choose the gradient codec (`dense`, `topk:pct=0.1`,
    /// `threshold:t=0.001`, … — see [`crate::codec::parse_codec`]). The
    /// default identity codec leaves every run byte-identical to the
    /// pre-codec plumbing; sparsifying codecs shrink the gather wire
    /// image and are validated against the aggregation/backend in
    /// [`RunBuilder::build`] (DESIGN.md §1.4).
    pub fn codec(mut self, codec: CodecSpec) -> RunBuilder {
        self.codec = codec;
        self
    }

    /// Choose the churn plane (`none`, `churn:rate=0.1,flap=2`, … — see
    /// [`crate::churn::parse_churn`]): a deterministic per-worker
    /// arrival/departure schedule plus optional per-worker link dynamics
    /// (stragglers, Gilbert–Elliott edges). The default `none` attaches
    /// no membership and leaves every run byte-identical to the pre-churn
    /// plumbing; link-perturbing specs are validated against the
    /// topology/aggregation in [`RunBuilder::build`] (DESIGN.md §1.5).
    pub fn churn(mut self, churn: ChurnSpec) -> RunBuilder {
        self.churn = churn;
        self
    }

    /// Validate and produce the run configuration.
    pub fn build(mut self) -> Result<TrainingCfg> {
        if let Some(b) = &self.backend {
            // The backend's own precondition first, so `fig5`/`ltp train`
            // errors name the actual missing dependency.
            b.check_ready()?;
            let info = b.model()?;
            self.model_bytes = info.wire_bytes;
            self.critical = Critical::Explicit(info.critical);
        }
        ensure!(self.workers >= 1, "a training run needs at least one worker");
        ensure!(self.iters >= 1, "a training run needs at least one iteration");
        ensure!(self.model_bytes > 0, "model_bytes must be positive");
        ensure!(self.batches_per_epoch >= 1, "batches_per_epoch must be at least 1");
        ensure!(
            self.pct_threshold > 0.0 && self.pct_threshold <= 1.0,
            "pct_threshold {} outside (0, 1]",
            self.pct_threshold
        );
        ensure!(self.horizon > 0, "the simulation horizon must be positive");
        validate_loss(&self.link.loss)?;
        if let Topo::TwoRack { rack0_workers, trunk } = &self.topo {
            ensure!(
                *rack0_workers <= self.workers,
                "rack 0 holds {rack0_workers} workers but the run has only {}",
                self.workers
            );
            validate_loss(&trunk.loss)?;
        }
        if self.proto.is_loss_tolerant() {
            let seg = Manifest::aligned_payload(LTP_MSS) as u64;
            let n_segs = self.model_bytes.div_ceil(seg);
            ensure!(
                n_segs <= MAX_SEGS as u64,
                "{} bytes need {n_segs} segments — beyond LTP's 24-bit segment space",
                self.model_bytes
            );
        }
        // The aggregation's own consistency rules: worker count divisible
        // across `hier` racks / `sharded` shards, fabric compatibility.
        self.agg.validate(self.workers, self.model_bytes, &self.topo)?;
        // Codec compatibility (DESIGN.md §1.4): the encoded wire image is
        // built per full-gradient gather flow, so anything beyond the bare
        // identity codec needs the single-PS aggregation, and sparsifying
        // codecs decode on the CPU aggregation path.
        if !self.codec.is_default() {
            ensure!(
                self.agg.name() == "ps",
                "codec `{}` requires the single-PS aggregation (got `{}`)",
                self.codec.name(),
                self.agg.name()
            );
        }
        if !self.codec.wire_identity() {
            if let Some(b) = &self.backend {
                ensure!(
                    b.name() != "xla" && !b.name().starts_with("xla:"),
                    "codec `{}` decodes on the CPU aggregation path; the `xla` \
                     backend's Pallas kernel consumes the dense wire image",
                    self.codec.name()
                );
            }
        }
        // Churn compatibility (DESIGN.md §1.5): per-worker link dynamics
        // replace the star's uniform worker edges, so they need a fabric
        // whose worker edges the builder owns — the star fabrics of the
        // `ps` and `sharded` aggregations. Membership-only churn (and the
        // default `none`) works everywhere.
        if self.churn.perturbs_links() {
            ensure!(
                matches!(self.topo, Topo::Star),
                "churn spec `{}` perturbs per-worker links; drop the two-rack \
                 topology override",
                self.churn.name()
            );
            ensure!(
                self.agg.name() != "hier" && !self.agg.name().starts_with("hier:"),
                "churn spec `{}` perturbs per-worker links; `{}` builds its own \
                 rack fabric with uniform edges",
                self.churn.name(),
                self.agg.name()
            );
        }
        // Can the backend serve this topology's endpoints at this worker
        // count? (The `xla` Pallas kernel spans the full model — single PS
        // only — and its artifact bakes in a worker capacity.)
        if let Some(b) = &self.backend {
            b.supports(self.workers, &self.agg.endpoint_roles(self.workers, self.model_bytes))?;
        }
        if self.proto.is_loss_tolerant() {
            // LTP truncates flow ids to 16 bits; slot resolution survives
            // the wrap only for power-of-two strides (the classic 2W
            // layouts), so other layouts must keep raw flow ids below 2¹⁶.
            let stride = self.agg.flow_stride(self.workers);
            ensure!(
                stride.is_power_of_two()
                    || self.iters.saturating_mul(stride).saturating_add(stride) <= 1 << 16,
                "`{}` at {} workers uses flow stride {stride}: {} iterations overflow \
                 LTP's 16-bit wire flow ids (max {})",
                self.agg.name(),
                self.workers,
                self.iters,
                (1u64 << 16) / stride - 1
            );
        }
        let critical = match self.critical {
            Critical::Explicit(segments) => segments,
            Critical::Synthetic(n) => Manifest::synthetic(self.model_bytes, n)
                .critical_segments(Manifest::aligned_payload(LTP_MSS)),
        };
        Ok(TrainingCfg {
            proto: self.proto,
            n_workers: self.workers,
            iters: self.iters,
            model_bytes: self.model_bytes,
            critical,
            compute_time: self.compute_time,
            agg_time: self.agg_time,
            link: self.link,
            switch_delay: self.switch_delay,
            pct_threshold: self.pct_threshold,
            deadline_slack: self.deadline_slack,
            batches_per_epoch: self.batches_per_epoch,
            seed: self.seed,
            horizon: self.horizon,
            topo: self.topo,
            bg: self.bg,
            agg: self.agg,
            backend: self.backend,
            codec: self.codec,
            churn: self.churn,
        })
    }

    /// Build and run the training simulation (modeled compute, or the
    /// attached backend's real compute when [`RunBuilder::backend`] was
    /// called).
    pub fn run(self) -> Result<RunReport> {
        Ok(super::runner::run_training(&self.build()?))
    }
}

fn validate_loss(loss: &LossModel) -> Result<()> {
    let frac = |name: &str, x: f64| -> Result<()> {
        ensure!((0.0..1.0).contains(&x), "loss model {name} {x} outside [0, 1)");
        Ok(())
    };
    match *loss {
        LossModel::None => Ok(()),
        LossModel::Bernoulli { p } => frac("p", p),
        LossModel::GilbertElliott { p_gb, p_bg, loss_good, loss_bad } => {
            frac("p_gb", p_gb)?;
            frac("p_bg", p_bg)?;
            frac("loss_good", loss_good)?;
            frac("loss_bad", loss_bad)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::parse_proto;

    fn ltp() -> ProtoSpec {
        parse_proto("ltp").unwrap()
    }

    #[test]
    fn modeled_builder_matches_legacy_defaults() {
        let cfg = RunBuilder::modeled(ltp(), Workload::Micro, 4).build().unwrap();
        let legacy = TrainingCfg::modeled(ltp(), Workload::Micro, 4);
        assert_eq!(cfg.n_workers, legacy.n_workers);
        assert_eq!(cfg.iters, legacy.iters);
        assert_eq!(cfg.model_bytes, legacy.model_bytes);
        assert_eq!(cfg.critical, legacy.critical);
        assert_eq!(cfg.compute_time, legacy.compute_time);
        assert_eq!(cfg.agg_time, legacy.agg_time);
        assert_eq!(cfg.pct_threshold, legacy.pct_threshold);
        assert_eq!(cfg.deadline_slack, legacy.deadline_slack);
        assert_eq!(cfg.batches_per_epoch, legacy.batches_per_epoch);
        assert_eq!(cfg.seed, legacy.seed);
        assert_eq!(cfg.horizon, legacy.horizon);
    }

    #[test]
    fn synthetic_criticals_follow_the_final_message_size() {
        let small = RunBuilder::modeled(ltp(), Workload::Micro, 4)
            .model_bytes(1_000_000)
            .build()
            .unwrap();
        let expected = Manifest::synthetic(1_000_000, 50)
            .critical_segments(Manifest::aligned_payload(LTP_MSS));
        assert_eq!(small.critical, expected);
        // …while an explicit set is preserved verbatim.
        let explicit = RunBuilder::modeled(ltp(), Workload::Micro, 4)
            .critical(vec![1, 5])
            .model_bytes(1_000_000)
            .build()
            .unwrap();
        assert_eq!(explicit.critical, vec![1, 5]);
    }

    #[test]
    fn inconsistent_combos_fail_fast() {
        let b = || RunBuilder::modeled(ltp(), Workload::Micro, 4);
        assert!(RunBuilder::modeled(ltp(), Workload::Micro, 0).build().is_err());
        assert!(b().iters(0).build().is_err());
        assert!(b().model_bytes(0).build().is_err());
        assert!(b().pct_threshold(0.0).build().is_err());
        assert!(b().pct_threshold(1.2).build().is_err());
        assert!(b().batches_per_epoch(0).build().is_err());
        assert!(b().horizon(0).build().is_err());
        assert!(b().loss(LossModel::Bernoulli { p: 1.5 }).build().is_err());
        // More workers in rack 0 than the run has.
        let trunk = b().link_cfg();
        assert!(b().two_rack(9, trunk).build().is_err());
        assert!(b().two_rack(2, trunk).build().is_ok());
        // A message beyond LTP's 24-bit segment space.
        assert!(b().model_bytes(30_000_000_000_000).build().is_err());
        // Worker count not divisible across shards / racks fails fast…
        let agg = |s: &str| crate::ps::parse_agg(s).unwrap();
        assert!(b().agg(agg("sharded:n=3")).build().is_err());
        assert!(b().agg(agg("hier:racks=3")).build().is_err());
        // …divisible combinations build.
        assert!(b().agg(agg("sharded:n=2")).build().is_ok());
        assert!(b().agg(agg("hier:racks=2")).build().is_ok());
        // Aggregations that own their fabric reject a two-rack override.
        assert!(b().two_rack(2, trunk).agg(agg("sharded:n=2")).build().is_err());
        assert!(b().two_rack(2, trunk).agg(agg("hier")).build().is_err());
        // Non-power-of-two flow strides must keep LTP's raw flow ids
        // within the 16-bit wire space (hier at 4 workers: stride 12 →
        // at most 5460 iterations); power-of-two strides are unbounded.
        assert!(b().agg(agg("hier")).iters(5000).build().is_ok());
        assert!(b().agg(agg("hier")).iters(6000).build().is_err());
        assert!(b().iters(1_000_000).build().is_ok(), "classic 2W stride never wraps wrong");
        // …and reliable transports are unaffected (full flow ids on the wire).
        let reno = crate::ps::parse_proto("reno").unwrap();
        assert!(RunBuilder::modeled(reno, Workload::Micro, 4)
            .agg(agg("hier"))
            .iters(6000)
            .build()
            .is_ok());
    }

    #[test]
    fn codec_gates_enforce_topology() {
        let b = || RunBuilder::modeled(ltp(), Workload::Micro, 4);
        let codec = |s: &str| crate::codec::parse_codec(s).unwrap();
        let agg = |s: &str| crate::ps::parse_agg(s).unwrap();
        // Any codec rides the single-PS aggregation.
        assert!(b().codec(codec("topk:pct=0.1")).build().is_ok());
        assert!(b().codec(codec("threshold:t=0.01")).build().is_ok());
        assert!(b().codec(codec("dense:priority=on")).build().is_ok());
        // Non-default codecs reject multi-endpoint aggregations…
        assert!(b().codec(codec("topk:pct=0.1")).agg(agg("sharded:n=2")).build().is_err());
        assert!(b().codec(codec("dense:priority=on")).agg(agg("hier")).build().is_err());
        // …while the bare identity codec stays unrestricted.
        assert!(b().codec(codec("dense")).agg(agg("sharded:n=2")).build().is_ok());
    }

    #[test]
    fn churn_gates_enforce_topology() {
        let b = || RunBuilder::modeled(ltp(), Workload::Micro, 4);
        let churn = |s: &str| crate::churn::parse_churn(s).unwrap();
        let agg = |s: &str| crate::ps::parse_agg(s).unwrap();
        let trunk = b().link_cfg();
        // Membership-only churn rides every topology and aggregation.
        assert!(b().churn(churn("churn:rate=0.1")).build().is_ok());
        assert!(b().churn(churn("churn:rate=0.1")).agg(agg("sharded:n=2")).build().is_ok());
        assert!(b().churn(churn("churn:rate=0.1")).agg(agg("hier")).build().is_ok());
        assert!(b().churn(churn("churn:rate=0.1")).two_rack(2, trunk).build().is_ok());
        // Link-perturbing churn needs a builder-owned star fabric…
        assert!(b().churn(churn("churn:rate=0,stragglers=0.5")).build().is_ok());
        assert!(b()
            .churn(churn("churn:rate=0,ge=on"))
            .agg(agg("sharded:n=2"))
            .build()
            .is_ok());
        // …and rejects fabrics whose worker edges it cannot own.
        assert!(b().churn(churn("churn:rate=0,ge=on")).agg(agg("hier")).build().is_err());
        assert!(b()
            .churn(churn("churn:rate=0,stragglers=0.5"))
            .two_rack(2, trunk)
            .build()
            .is_err());
    }

    #[test]
    fn backend_overrides_wire_layout_and_fails_fast() {
        let native = crate::compute::parse_backend("native").unwrap();
        let info = native.model().unwrap();
        let cfg = RunBuilder::modeled(ltp(), Workload::Micro, 4)
            .backend(native.clone())
            .build()
            .unwrap();
        assert_eq!(cfg.model_bytes, info.wire_bytes, "backend dictates the message size");
        assert_eq!(cfg.critical, info.critical, "…and the critical set");
        assert!(cfg.backend.is_some());
        // The native backend serves multi-endpoint aggregations too.
        let agg = |s: &str| crate::ps::parse_agg(s).unwrap();
        assert!(RunBuilder::modeled(ltp(), Workload::Micro, 4)
            .backend(native.clone())
            .agg(agg("sharded:n=2"))
            .build()
            .is_ok());
        assert!(RunBuilder::modeled(ltp(), Workload::Micro, 4)
            .backend(native)
            .agg(agg("hier"))
            .build()
            .is_ok());
        // `xla` without artifacts fails at build time, naming the actual
        // missing dependency (skip when someone has built them locally).
        if !crate::runtime::default_artifacts_dir().join("manifest_tiny.txt").exists() {
            let xla = crate::compute::parse_backend("xla").unwrap();
            let err = format!(
                "{:#}",
                RunBuilder::modeled(ltp(), Workload::Micro, 4)
                    .backend(xla)
                    .build()
                    .expect_err("no artifacts in this checkout")
            );
            assert!(err.contains("make artifacts"), "{err}");
        }
    }

    #[test]
    fn net_env_sets_link_and_slack_together() {
        let cfg = RunBuilder::modeled(ltp(), Workload::Micro, 4)
            .net_env(NetEnv::WanBursty)
            .build()
            .unwrap();
        assert_eq!(cfg.link.rate_bps, NetEnv::WanBursty.link().rate_bps);
        assert_eq!(cfg.deadline_slack, NetEnv::WanBursty.deadline_slack());
    }
}
