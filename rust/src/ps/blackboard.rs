//! In-process data plane. The simulator moves *accounted* bytes, not
//! payloads; actual gradient/parameter values move through this shared
//! blackboard, gated by the transport's delivery bitmaps — so the numerics
//! see exactly what a real wire would have delivered (bubbles included),
//! without copying 100-MB-class buffers through every simulated packet.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// Shared single-threaded store: worker gradients for the current
/// iteration, and the global parameters.
#[derive(Default)]
pub struct Store {
    /// (worker, iter) → flat gradient (padded).
    pub grads: HashMap<(usize, u64), Rc<Vec<f32>>>,
    /// Global flat parameters (updated by the PS, read by workers after a
    /// completed reliable broadcast).
    pub params: Rc<Vec<f32>>,
    /// Momentum buffer (PS-owned, kept here for inspection by tests).
    pub momentum: Rc<Vec<f32>>,
}

/// Cloneable handle.
#[derive(Clone, Default)]
pub struct Blackboard(Rc<RefCell<Store>>);

impl Blackboard {
    pub fn new(params: Vec<f32>) -> Blackboard {
        let momentum = vec![0.0; params.len()];
        Blackboard(Rc::new(RefCell::new(Store {
            grads: HashMap::new(),
            params: Rc::new(params),
            momentum: Rc::new(momentum),
        })))
    }

    pub fn put_grads(&self, worker: usize, iter: u64, grads: Vec<f32>) {
        self.0.borrow_mut().grads.insert((worker, iter), Rc::new(grads));
    }

    pub fn take_grads(&self, worker: usize, iter: u64) -> Option<Rc<Vec<f32>>> {
        self.0.borrow_mut().grads.remove(&(worker, iter))
    }

    pub fn params(&self) -> Rc<Vec<f32>> {
        self.0.borrow().params.clone()
    }

    pub fn set_params(&self, params: Vec<f32>) {
        self.0.borrow_mut().params = Rc::new(params);
    }

    pub fn momentum(&self) -> Rc<Vec<f32>> {
        self.0.borrow().momentum.clone()
    }

    pub fn set_momentum(&self, v: Vec<f32>) {
        self.0.borrow_mut().momentum = Rc::new(v);
    }

    /// Drop gradients older than `iter` (bounded memory across long runs).
    pub fn gc(&self, iter: u64) {
        self.0.borrow_mut().grads.retain(|&(_, i), _| i >= iter);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grads_roundtrip_and_gc() {
        let bb = Blackboard::new(vec![1.0, 2.0]);
        bb.put_grads(0, 5, vec![0.5]);
        bb.put_grads(1, 6, vec![0.7]);
        assert_eq!(bb.take_grads(0, 5).unwrap()[0], 0.5);
        assert!(bb.take_grads(0, 5).is_none());
        bb.gc(7);
        assert!(bb.take_grads(1, 6).is_none());
    }

    #[test]
    fn params_swap() {
        let bb = Blackboard::new(vec![1.0]);
        assert_eq!(bb.params()[0], 1.0);
        bb.set_params(vec![2.0]);
        assert_eq!(bb.params()[0], 2.0);
    }
}
