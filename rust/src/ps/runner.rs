//! Training-run orchestration: hand the fabric build to the run's
//! [`super::AggSpec`] (single PS, sharded multi-PS, or hierarchical
//! rack-local aggregation — DESIGN.md §1.2), attach any background flows,
//! run the BSP loop, and merge every aggregator endpoint's records into
//! one report. Supports modeled compute (paper message sizes + calibrated
//! compute times) and real compute through a pluggable backend
//! (DESIGN.md §1.3: the pure-Rust `native` trainer, or the `xla` PJRT
//! train_step + Pallas masked aggregation).

use super::agg::{merge_iters, BuildEnv, Topo};
use super::server::{Aggregate, NullAggregate};
use super::spec::ProtoSpec;
use super::worker::{Compute, ModeledCompute, WorkerNode};
use super::{AggSpec, Blackboard, Corpus, GatherClose, IterStats};
use crate::compute::{BackendSpec, RunCtx, TrainSession, TrainStats};
use crate::cc::CcAlgo;
use crate::config::ModelManifest;
use crate::grad::{element_mask, Manifest};
use crate::runtime::{literal_f32, literal_i32, to_f32, Artifact, Runtime};
use crate::simnet::{CrossTraffic, EntityId, LinkCfg, Sim};
use crate::tcp::{TcpReceiverNode, TcpSender, TcpSenderNode};
use crate::util::{Bitmap, Summary};
use crate::wire::{LTP_MSS, TCP_MSS};
use crate::{Nanos, MS, SEC};
use anyhow::Result;
use std::cell::RefCell;
use std::rc::Rc;

/// Fabric-wide link counters (summed over every link in the topology).
pub type NetTotals = crate::simnet::LinkStats;

/// A background flow sharing the fabric with the training job.
#[derive(Debug, Clone, Copy)]
pub enum BgKind {
    /// A reliable TCP bulk transfer between two dedicated hosts.
    TcpBulk { cc: CcAlgo, bytes: u64 },
    /// Constant-rate unreliable datagram cross traffic aimed at the PS —
    /// pure load on the incast-direction bottleneck (the PS ignores the
    /// packets; the links do not).
    UdpToPs { rate_bps: u64, pkt_size: u32, stop: Nanos },
}

#[derive(Debug, Clone, Copy)]
pub struct BgFlow {
    pub kind: BgKind,
    /// Source / destination rack on a [`Topo::TwoRack`] fabric (ignored on
    /// a star; `UdpToPs` uses only `src_rack`).
    pub src_rack: usize,
    pub dst_rack: usize,
    pub start: Nanos,
}

impl BgFlow {
    pub fn tcp_bulk(cc: CcAlgo, bytes: u64) -> BgFlow {
        BgFlow { kind: BgKind::TcpBulk { cc, bytes }, src_rack: 1, dst_rack: 0, start: 0 }
    }

    pub fn udp_to_ps(rate_bps: u64, stop: Nanos) -> BgFlow {
        BgFlow {
            kind: BgKind::UdpToPs { rate_bps, pkt_size: 1500, stop },
            src_rack: 1,
            dst_rack: 0,
            start: 0,
        }
    }
}

/// A training-run configuration. Prefer assembling one through
/// [`super::RunBuilder`], which fills these fields from workload/network
/// presets and validates the combination.
pub struct TrainingCfg {
    pub proto: ProtoSpec,
    pub n_workers: usize,
    pub iters: u64,
    pub model_bytes: u64,
    /// Critical segments (from the tensor manifest) for LTP gathers.
    pub critical: Vec<u32>,
    pub compute_time: Nanos,
    pub agg_time: Nanos,
    pub link: LinkCfg,
    pub switch_delay: Nanos,
    /// Early Close data-percentage threshold (paper Fig 7: e.g. 0.8).
    pub pct_threshold: f64,
    /// Deadline slack C (30 ms DCN / 100 ms WAN).
    pub deadline_slack: Nanos,
    pub batches_per_epoch: u64,
    pub seed: u64,
    /// Wall-clock cap on the simulation.
    pub horizon: Nanos,
    /// Fabric topology for the `ps` aggregation (star unless a scenario
    /// says otherwise); other aggregations own their topology.
    pub topo: Topo,
    /// Background flows sharing the fabric.
    pub bg: Vec<BgFlow>,
    /// Aggregation topology (`ps`, `sharded:n=4`, `hier:racks=2`, …).
    pub agg: AggSpec,
    /// Compute backend (`native`, `xla:preset=tiny`, … — DESIGN.md §1.3).
    /// `None` keeps modeled compute: fixed durations, no numerics, and a
    /// report without a `train` block (the original byte layout).
    pub backend: Option<BackendSpec>,
    /// Gradient codec (`dense`, `topk:pct=0.1`, … — DESIGN.md §1.4). The
    /// default identity codec keeps every run byte-identical to the
    /// pre-codec plumbing.
    pub codec: crate::codec::CodecSpec,
    /// Churn plane (`none`, `churn:rate=0.1,flap=2`, … — DESIGN.md §1.5):
    /// elastic membership and per-worker link dynamics. The default
    /// `none` attaches no membership and keeps every run byte-identical
    /// to the pre-churn plumbing.
    pub churn: crate::churn::ChurnSpec,
}

impl TrainingCfg {
    /// Modeled-compute defaults for a workload — shorthand for
    /// [`super::RunBuilder::modeled`] with no overrides.
    pub fn modeled(
        proto: ProtoSpec,
        workload: crate::config::Workload,
        n_workers: usize,
    ) -> TrainingCfg {
        super::RunBuilder::modeled(proto, workload, n_workers)
            .build()
            .expect("modeled defaults are a valid configuration")
    }
}

/// Per-aggregator distillation for the report's `shards` breakdown:
/// mean BST and mean delivered fraction of one shard / rack / root.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardStat {
    /// Deterministic endpoint label (`shard0`, `rack1`, `root`).
    pub label: String,
    /// Mean per-iteration BST of this endpoint, in nanoseconds.
    pub bst_ns: Nanos,
    /// Mean delivered fraction at this endpoint.
    pub delivered: f64,
}

/// The outcome of a run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub proto: String,
    /// Canonical aggregation spec the run used (`ps` by default).
    pub agg: String,
    /// Canonical gradient-codec spec the run used (`dense` by default).
    pub codec: String,
    /// Canonical churn spec the run used (`none` by default).
    pub churn: String,
    /// Fewest barrier members over the run's completed iterations
    /// (`n_workers` for a stable run).
    pub active_min: usize,
    /// Most barrier members over the run's completed iterations.
    pub active_max: usize,
    /// Gather-direction payload bytes put on the wire across the whole
    /// run under the codec's wire model: `encoded_bytes(model_bytes) ×
    /// workers × iterations` (DESIGN.md §1.4). Retransmissions and
    /// headers are excluded — this is the codec's size claim, the
    /// quantity compression ratios are quoted on.
    pub gather_wire_bytes: u64,
    /// Mean tensor-priority-weighted delivered importance over the run's
    /// iterations — present **only when a non-default codec is
    /// configured**, so classic reports keep their original byte layout.
    pub mean_importance: Option<f64>,
    pub iters: Vec<IterStats>,
    pub total_time: Nanos,
    /// Mean per-worker gather times (incast direction).
    pub gather_summary: Summary,
    /// Fabric-wide link counters (drops, marks, bytes — every link summed).
    pub net: NetTotals,
    /// Gather-direction packets retransmitted, summed over workers.
    pub retransmits: u64,
    /// Gather-direction packets sent, summed over workers (denominator
    /// for a cross-protocol retransmit rate).
    pub gather_pkts: u64,
    /// Per-flow LTP gather close records (empty for TCP runs).
    pub closes: Vec<GatherClose>,
    /// Per background flow: bytes delivered (TCP bulk) or injected (UDP).
    pub bg_bytes: Vec<u64>,
    /// Discrete events the simulator processed for this run — the
    /// deterministic work unit behind the bench reports' events/sec.
    pub sim_events: u64,
    /// Per-aggregator breakdown, in endpoint order. **Empty for
    /// single-aggregator runs**, so single-PS reports keep their original
    /// byte layout.
    pub shards: Vec<ShardStat>,
    /// Deterministic training outcome — present **only when a compute
    /// backend is attached**, so backend-less reports keep their original
    /// byte layout.
    pub train: Option<TrainStats>,
}

impl RunReport {
    /// Training throughput in images/sec given a per-worker batch size.
    /// Excludes the first iteration (threshold/estimator bootstrapping)
    /// when more than one completed — steady-state, like the paper's
    /// measurements over whole epochs.
    pub fn throughput(&self, n_workers: usize, batch_images: u64) -> f64 {
        if self.iters.is_empty() || self.total_time == 0 {
            return 0.0;
        }
        let (n, window) = if self.iters.len() > 1 {
            (self.iters.len() - 1, self.total_time - self.iters[0].end)
        } else {
            (1, self.total_time)
        };
        let images = n as u64 * n_workers as u64 * batch_images;
        images as f64 / (window.max(1) as f64 / SEC as f64)
    }

    pub fn mean_bst(&self) -> Nanos {
        if self.iters.is_empty() {
            return 0;
        }
        self.iters.iter().map(|i| i.bst).sum::<Nanos>() / self.iters.len() as u64
    }

    pub fn bst_values_ms(&self) -> Vec<f64> {
        self.iters.iter().map(|i| i.bst as f64 / MS as f64).collect()
    }

    pub fn mean_delivered(&self) -> f64 {
        if self.iters.is_empty() {
            return 1.0;
        }
        self.iters.iter().map(|i| i.mean_delivered).sum::<f64>() / self.iters.len() as f64
    }
}

/// Run a training simulation: modeled compute when no backend is
/// attached, otherwise one [`crate::compute::TrainSession`] of the
/// configured backend (real gradients each iteration, masked-mean
/// aggregation of real bytes, and a `train` block in the report).
pub fn run_training(cfg: &TrainingCfg) -> RunReport {
    if cfg.backend.is_some() {
        return run_training_session(cfg).0;
    }
    run_with(
        cfg,
        |_, _| Box::new(ModeledCompute(cfg.compute_time)),
        |_| Box::new(NullAggregate(cfg.agg_time)),
    )
}

/// Like [`run_training`] for a backend-attached configuration, but hands
/// the finished [`TrainSession`] back alongside the report — tests
/// inspect the final parameters through the same wiring production runs
/// use (`rust/tests/agg.rs` asserts cross-topology bit-identity on it).
///
/// Panics when no backend is attached. Preconditions were validated at
/// `RunBuilder::build` time (`check_ready`/`supports`); an open failure
/// here is a runtime defect of the backend itself, reported like any
/// other compute panic.
pub fn run_training_session(cfg: &TrainingCfg) -> (RunReport, Box<dyn TrainSession>) {
    let backend = cfg.backend.as_ref().expect("run_training_session needs a backend");
    let session = backend
        .open(&RunCtx {
            seed: cfg.seed,
            n_workers: cfg.n_workers,
            compute_time: cfg.compute_time,
            agg_time: cfg.agg_time,
            roles: cfg.agg.endpoint_roles(cfg.n_workers, cfg.model_bytes),
            codec: cfg.codec.clone(),
        })
        .unwrap_or_else(|e| panic!("backend `{}` failed to open: {e:#}", backend.name()));
    let session = RefCell::new(session);
    let mut report = run_with(
        cfg,
        |w, _| session.borrow_mut().make_compute(w),
        |e| session.borrow_mut().make_agg(e),
    );
    let session = session.into_inner();
    report.train = Some(session.stats(&report.iters));
    (report, session)
}

/// How a background flow is observed after the run.
enum BgHandle {
    Tcp { rx_host: EntityId, flow: u64 },
    Udp { src_host: EntityId },
}

/// Run with custom compute/aggregation backends (real training uses
/// this). `make_agg(endpoint)` is called once per aggregator endpoint of
/// the configured [`AggSpec`] — exactly once, with `0`, for the default
/// single-PS aggregation.
pub fn run_with(
    cfg: &TrainingCfg,
    mut make_compute: impl FnMut(usize, &TrainingCfg) -> Box<dyn Compute>,
    mut make_agg: impl FnMut(usize) -> Box<dyn Aggregate>,
) -> RunReport {
    let mut sim = Sim::new(cfg.seed);
    // The aggregation owns the topology: it builds the fabric, places the
    // aggregator endpoints and the workers' routing plans, and hands back
    // the observation handles.
    let run = {
        let mut env = BuildEnv { make_compute: &mut make_compute, make_agg: &mut make_agg };
        cfg.agg.build(&mut sim, cfg, &mut env)
    };
    let mut bg_handles: Vec<BgHandle> = Vec::new();
    for (i, bg) in cfg.bg.iter().enumerate() {
        match bg.kind {
            BgKind::TcpBulk { cc, bytes } => {
                // Flow ids far above the training range.
                let flow = 1_000_000 + i as u64;
                let rx_host = run.fabric.attach(
                    &mut sim,
                    Box::new(TcpReceiverNode::new()),
                    bg.dst_rack,
                    cfg.link,
                );
                let snd = TcpSender::new(flow, bytes, TCP_MSS, cc.build(TCP_MSS));
                let snd_node = TcpSenderNode::new(snd, rx_host).with_start(bg.start);
                run.fabric.attach(&mut sim, Box::new(snd_node), bg.src_rack, cfg.link);
                bg_handles.push(BgHandle::Tcp { rx_host, flow });
            }
            BgKind::UdpToPs { rate_bps, pkt_size, stop } => {
                let node = CrossTraffic::new(run.ps_id, rate_bps, pkt_size, stop)
                    .with_start(bg.start);
                let src_host =
                    run.fabric.attach(&mut sim, Box::new(node), bg.src_rack, cfg.link);
                bg_handles.push(BgHandle::Udp { src_host });
            }
        }
    }
    // Run in slices so the simulation stops as soon as training completes
    // (long-lived background flows would otherwise keep the event queue
    // busy until the horizon). The barrier is complete when every
    // barrier-member aggregator finished all iterations.
    let slice = 100 * MS;
    let mut until = slice;
    loop {
        sim.run_until(until.min(cfg.horizon));
        let done = run
            .shards
            .iter()
            .filter(|s| s.in_barrier)
            .all(|s| s.report.borrow().len() as u64 >= cfg.iters);
        if done || sim.is_idle() || until >= cfg.horizon {
            break;
        }
        until += slice;
    }
    // Merge the per-aggregator records into the barrier view (BST = max
    // over shards/levels; identity for a single aggregator).
    let iters = merge_iters(&run.shards);
    let total_time = iters.last().map(|i| i.end).unwrap_or(sim.now());
    let mut gathers = Vec::new();
    let mut retransmits = 0;
    let mut gather_pkts = 0;
    for &w in &run.worker_ids {
        let node = sim.node_as::<WorkerNode>(w);
        gathers.extend(node.stats.gather_times.iter().map(|&t| t as f64 / MS as f64));
        retransmits += node.stats.retransmissions;
        gather_pkts += node.stats.pkts_sent;
    }
    let mut closes = Vec::new();
    for s in &run.shards {
        closes.extend(s.closes.borrow().iter().copied());
    }
    let shards: Vec<ShardStat> = if run.shards.len() <= 1 {
        vec![] // single aggregator: keep the original report layout
    } else {
        run.shards
            .iter()
            .map(|s| {
                let rep = s.report.borrow();
                let n = rep.len().max(1) as u64;
                ShardStat {
                    label: s.label.clone(),
                    bst_ns: rep.iter().map(|i| i.bst).sum::<Nanos>() / n,
                    // An endpoint that closed no iteration delivered
                    // nothing (a horizon-truncated run), not everything.
                    delivered: if rep.is_empty() {
                        0.0
                    } else {
                        rep.iter().map(|i| i.mean_delivered).sum::<f64>() / rep.len() as f64
                    },
                }
            })
            .collect()
    };
    let bg_bytes: Vec<u64> = bg_handles
        .iter()
        .map(|h| match h {
            BgHandle::Tcp { rx_host, flow } => {
                sim.node_as::<TcpReceiverNode>(*rx_host).bytes_received(*flow)
            }
            BgHandle::Udp { src_host } => sim.node_as::<CrossTraffic>(*src_host).sent_bytes,
        })
        .collect();
    // Under churn the wire claim counts only barrier members: departed
    // workers send no gather (DESIGN.md §1.5).
    let churn_plan = (!cfg.churn.is_default()).then(|| {
        cfg.churn.plan(cfg.n_workers, cfg.iters, cfg.batches_per_epoch, cfg.seed)
    });
    let gather_wire_bytes = cfg.codec.encoded_bytes(cfg.model_bytes)
        * match &churn_plan {
            Some(p) => p.active_total(iters.len() as u64),
            None => cfg.n_workers as u64 * iters.len() as u64,
        };
    let (active_min, active_max) = match &churn_plan {
        Some(p) => p.active_bounds(iters.len() as u64),
        None => (cfg.n_workers, cfg.n_workers),
    };
    let mean_importance = if cfg.codec.is_default() || iters.is_empty() {
        None
    } else {
        Some(iters.iter().map(|i| i.mean_importance).sum::<f64>() / iters.len() as f64)
    };
    RunReport {
        proto: cfg.proto.name().to_string(),
        agg: cfg.agg.name().to_string(),
        codec: cfg.codec.name().to_string(),
        churn: cfg.churn.name().to_string(),
        active_min,
        active_max,
        gather_wire_bytes,
        mean_importance,
        iters,
        total_time,
        gather_summary: Summary::of(&gathers),
        net: sim.total_link_stats(),
        retransmits,
        gather_pkts,
        closes,
        bg_bytes,
        sim_events: sim.events_processed(),
        shards,
        train: None,
    }
}

// ---------------------------------------------------------------------------
// Real compute backends (PJRT).
// ---------------------------------------------------------------------------

/// Shared state for real training: runtime artifacts + blackboard.
pub struct RealTraining {
    pub manifest: ModelManifest,
    pub blackboard: Blackboard,
    train_step: Rc<Artifact>,
    eval: Rc<Artifact>,
    aggregate: Rc<Artifact>,
    /// Simulated duration of one train_step / one aggregation.
    pub sim_compute_time: Nanos,
    pub sim_agg_time: Nanos,
    pub lr: f32,
    pub losses: Rc<RefCell<Vec<(u64, f32)>>>,
}

impl RealTraining {
    pub fn new(rt: &Runtime, preset: &str, lr: f32) -> Result<Rc<RealTraining>> {
        let manifest = ModelManifest::load(crate::runtime::default_artifacts_dir(), preset)?;
        let init = rt.load(&format!("init_{preset}"))?;
        let params = to_f32(&init.run(&[])?[0])?;
        anyhow::ensure!(params.len() == manifest.padded_dim);
        Ok(Rc::new(RealTraining {
            manifest,
            blackboard: Blackboard::new(params),
            train_step: Rc::new(rt.load(&format!("train_step_{preset}"))?),
            eval: Rc::new(rt.load(&format!("eval_{preset}"))?),
            aggregate: Rc::new(rt.load(&format!("aggregate_{preset}"))?),
            sim_compute_time: 50 * MS,
            sim_agg_time: 5 * MS,
            lr,
            losses: Rc::new(RefCell::new(Vec::new())),
        }))
    }

    pub fn eval_loss(&self, tokens: &[i32]) -> Result<f32> {
        let cfg = &self.manifest;
        let p = literal_f32(&self.blackboard.params(), &[cfg.padded_dim as i64])?;
        let t = literal_i32(tokens, &[cfg.batch as i64, cfg.seq_len as i64 + 1])?;
        let out = self.eval.run(&[p, t])?;
        Ok(to_f32(&out[0])?[0])
    }
}

/// Worker-side real compute: runs train_step via PJRT, deposits gradients.
pub struct RealCompute {
    pub shared: Rc<RealTraining>,
    pub corpus: Corpus,
}

impl Compute for RealCompute {
    fn compute(&mut self, worker: usize, iter: u64) -> Nanos {
        let m = &self.shared.manifest;
        let tokens = self.corpus.next_batch(m.batch, m.seq_len + 1);
        let run = || -> Result<(Vec<f32>, f32)> {
            let p = literal_f32(&self.shared.blackboard.params(), &[m.padded_dim as i64])?;
            let t = literal_i32(&tokens, &[m.batch as i64, m.seq_len as i64 + 1])?;
            let out = self.shared.train_step.run(&[p, t])?;
            Ok((to_f32(&out[0])?, to_f32(&out[1])?[0]))
        };
        match run() {
            Ok((grads, loss)) => {
                self.shared.blackboard.put_grads(worker, iter, grads);
                self.shared.losses.borrow_mut().push((iter, loss));
            }
            Err(e) => panic!("train_step failed for worker {worker}: {e:#}"),
        }
        self.shared.sim_compute_time
    }
}

/// PS-side real aggregation: masked-mean Pallas kernel + momentum SGD.
pub struct XlaAggregate {
    pub shared: Rc<RealTraining>,
    pub n_workers: usize,
}

impl Aggregate for XlaAggregate {
    fn aggregate(&mut self, iter: u64, arrivals: &[Option<(Bitmap, u64)>]) -> Nanos {
        let m = &self.shared.manifest;
        let d = m.padded_dim;
        let aw = m.agg_workers;
        assert!(self.n_workers <= aw, "aggregate artifact supports ≤{aw} workers");
        let mut g = vec![0.0f32; aw * d];
        let mut mask = vec![0.0f32; aw * d];
        let seg_map = crate::proto::SegmentMap::new(
            d as u64 * 4,
            Manifest::aligned_payload(LTP_MSS),
            vec![],
        );
        for w in 0..self.n_workers {
            let Some(grads) = self.shared.blackboard.take_grads(w, iter) else {
                continue; // worker contributed nothing this round
            };
            let row_mask = match &arrivals[w] {
                Some((bitmap, _)) => element_mask(&seg_map, bitmap, d),
                None => vec![1.0f32; d], // TCP: everything arrived
            };
            // Bubble semantics: zero the lost elements of the gradient row.
            for i in 0..d {
                g[w * d + i] = grads[i] * row_mask[i];
            }
            mask[w * d..(w + 1) * d].copy_from_slice(&row_mask);
        }
        let run = || -> Result<()> {
            let p = literal_f32(&self.shared.blackboard.params(), &[d as i64])?;
            let v = literal_f32(&self.shared.blackboard.momentum(), &[d as i64])?;
            let gl = literal_f32(&g, &[aw as i64, d as i64])?;
            let ml = literal_f32(&mask, &[aw as i64, d as i64])?;
            let lr = literal_f32(&[self.shared.lr], &[1])?;
            let out = self.shared.aggregate.run(&[p, v, gl, ml, lr])?;
            self.shared.blackboard.set_params(to_f32(&out[0])?);
            self.shared.blackboard.set_momentum(to_f32(&out[1])?);
            Ok(())
        };
        if let Err(e) = run() {
            panic!("aggregation failed at iter {iter}: {e:#}");
        }
        self.shared.blackboard.gc(iter + 1);
        self.shared.sim_agg_time
    }

    fn loss(&mut self, iter: u64) -> Option<f32> {
        let losses = self.shared.losses.borrow();
        let vals: Vec<f32> =
            losses.iter().filter(|&&(i, _)| i == iter).map(|&(_, l)| l).collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f32>() / vals.len() as f32)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Workload;
    use crate::ps::parse_proto;
    use crate::simnet::LossModel;

    fn proto(spec: &str) -> ProtoSpec {
        parse_proto(spec).unwrap()
    }

    fn quick_cfg(proto: ProtoSpec) -> TrainingCfg {
        let mut cfg = TrainingCfg::modeled(proto, Workload::Micro, 4);
        cfg.iters = 3;
        cfg
    }

    #[test]
    fn modeled_ltp_completes_all_iterations() {
        let report = run_training(&quick_cfg(proto("ltp")));
        assert_eq!(report.iters.len(), 3, "all iterations must finish");
        assert!(report.mean_bst() > 0);
        // Even a "clean" network drops packets under incast congestion;
        // LTP legitimately early-closes those tails. Only a small fraction
        // may be dropped.
        assert!(
            report.mean_delivered() > 0.88,
            "delivered {}",
            report.mean_delivered()
        );
    }

    #[test]
    fn modeled_tcp_completes_all_iterations() {
        for cc in ["cubic", "bbr"] {
            let report = run_training(&quick_cfg(proto(cc)));
            assert_eq!(report.iters.len(), 3, "{cc}");
        }
    }

    #[test]
    fn ltp_delivers_partially_under_loss_but_tcp_fully() {
        let mut cfg = quick_cfg(proto("ltp"));
        cfg.link = cfg.link.with_loss(LossModel::Bernoulli { p: 0.02 });
        cfg.iters = 4;
        let ltp = run_training(&cfg);
        assert_eq!(ltp.iters.len(), 4);
        assert!(
            ltp.mean_delivered() < 1.0,
            "2% loss should trigger early closes: {}",
            ltp.mean_delivered()
        );
        assert!(ltp.mean_delivered() > 0.8);

        let mut cfg = quick_cfg(proto("bbr"));
        cfg.link = cfg.link.with_loss(LossModel::Bernoulli { p: 0.02 });
        cfg.iters = 2;
        let tcp = run_training(&cfg);
        assert_eq!(tcp.iters.len(), 2);
        assert!((tcp.mean_delivered() - 1.0).abs() < 1e-9, "TCP always delivers 100%");
    }

    #[test]
    fn ltp_beats_cubic_under_loss() {
        let loss = LossModel::Bernoulli { p: 0.01 };
        let mut l = quick_cfg(proto("ltp"));
        l.link = l.link.with_loss(loss);
        l.iters = 4;
        let mut c = quick_cfg(proto("cubic"));
        c.link = c.link.with_loss(loss);
        c.iters = 4;
        let ltp = run_training(&l);
        let cubic = run_training(&c);
        assert_eq!(ltp.iters.len(), 4);
        assert_eq!(cubic.iters.len(), 4);
        assert!(
            ltp.mean_bst() < cubic.mean_bst(),
            "LTP BST {} must beat cubic {}",
            ltp.mean_bst(),
            cubic.mean_bst()
        );
    }

    #[test]
    fn throughput_accounting() {
        let report = run_training(&quick_cfg(proto("ltp")));
        let tp = report.throughput(4, 32);
        assert!(tp > 0.0);
    }

    #[test]
    fn report_carries_net_totals_and_closes() {
        let mut cfg = quick_cfg(proto("ltp"));
        cfg.link = cfg.link.with_loss(LossModel::Bernoulli { p: 0.02 });
        let report = run_training(&cfg);
        assert_eq!(report.iters.len(), 3);
        assert!(report.net.tx_pkts > 0 && report.net.tx_bytes > 0);
        assert!(report.sim_events > report.net.tx_pkts, "every tx is ≥1 event");
        assert!(report.net.drops_random > 0, "2% wire loss must drop packets");
        // One close record per (worker, iteration) gather flow.
        assert_eq!(report.closes.len(), 4 * 3, "closes: {:?}", report.closes);
        assert!(report.retransmits > 0, "loss must force gather retransmissions");
        // TCP runs produce no LTP close records.
        let mut tcfg = quick_cfg(proto("reno"));
        tcfg.iters = 2;
        assert!(run_training(&tcfg).closes.is_empty());
    }

    #[test]
    fn two_rack_training_completes_over_oversubscribed_trunk() {
        let mut cfg = quick_cfg(proto("ltp"));
        // 2 workers in rack 0 with the PS, 2 in rack 1; the trunk carries
        // rack 1's gathers at the same rate as one edge (2:1 oversub).
        cfg.topo = Topo::TwoRack { rack0_workers: 2, trunk: cfg.link };
        let report = run_training(&cfg);
        assert_eq!(report.iters.len(), 3, "two-rack BSP must complete");
        assert!(report.mean_bst() > 0);
        assert!(report.mean_delivered() > 0.8);
    }

    #[test]
    fn udp_cross_traffic_slows_training_but_never_stalls_it() {
        let base = quick_cfg(proto("ltp"));
        let clean = run_training(&base);

        let mut cfg = quick_cfg(proto("ltp"));
        // 8 Gbps of background datagrams into the PS's 10 Gbps downlink.
        cfg.bg = vec![BgFlow::udp_to_ps(8_000_000_000, 10 * SEC)];
        let loaded = run_training(&cfg);
        assert_eq!(loaded.iters.len(), 3, "training must survive cross traffic");
        assert_eq!(loaded.bg_bytes.len(), 1);
        assert!(loaded.bg_bytes[0] > 0, "cross traffic must have flowed");
        assert!(
            loaded.mean_bst() > clean.mean_bst(),
            "background load must cost sync time: {} vs {}",
            loaded.mean_bst(),
            clean.mean_bst()
        );
    }

    #[test]
    fn tcp_bulk_background_flow_makes_progress() {
        let mut cfg = quick_cfg(proto("ltp"));
        cfg.topo = Topo::TwoRack { rack0_workers: 2, trunk: cfg.link };
        cfg.bg = vec![BgFlow::tcp_bulk(crate::cc::CcAlgo::Cubic, 50_000_000)];
        let report = run_training(&cfg);
        assert_eq!(report.iters.len(), 3);
        assert!(report.bg_bytes[0] > 0, "bulk flow must deliver bytes");
    }
}
