//! Training-run orchestration: build the star topology, attach PS and
//! workers, run the BSP loop, and collect the report. Supports modeled
//! compute (paper message sizes + calibrated compute times) and real
//! compute (PJRT train_step + Pallas masked aggregation).

use super::server::{Aggregate, NullAggregate, PsNode};
use super::transport::Proto;
use super::worker::{Compute, ModeledCompute, WorkerNode};
use super::{Blackboard, Corpus, IterStats};
use crate::config::ModelManifest;
use crate::grad::{element_mask, Manifest};
use crate::runtime::{literal_f32, literal_i32, to_f32, Artifact, Runtime};
use crate::simnet::{LinkCfg, Sim};
use crate::util::{Bitmap, Summary};
use crate::wire::LTP_MSS;
use crate::{Nanos, MS, SEC};
use anyhow::Result;
use std::cell::RefCell;
use std::rc::Rc;

/// A training-run configuration.
pub struct TrainingCfg {
    pub proto: Proto,
    pub n_workers: usize,
    pub iters: u64,
    pub model_bytes: u64,
    /// Critical segments (from the tensor manifest) for LTP gathers.
    pub critical: Vec<u32>,
    pub compute_time: Nanos,
    pub agg_time: Nanos,
    pub link: LinkCfg,
    pub switch_delay: Nanos,
    /// Early Close data-percentage threshold (paper Fig 7: e.g. 0.8).
    pub pct_threshold: f64,
    /// Deadline slack C (30 ms DCN / 100 ms WAN).
    pub deadline_slack: Nanos,
    pub batches_per_epoch: u64,
    pub seed: u64,
    /// Wall-clock cap on the simulation.
    pub horizon: Nanos,
}

impl TrainingCfg {
    pub fn modeled(proto: Proto, workload: crate::config::Workload, n_workers: usize) -> TrainingCfg {
        TrainingCfg {
            proto,
            n_workers,
            iters: 10,
            model_bytes: workload.model_bytes(),
            critical: Manifest::synthetic(workload.model_bytes(), 50)
                .critical_segments(Manifest::aligned_payload(LTP_MSS)),
            compute_time: workload.compute_time(),
            agg_time: 2 * MS,
            link: crate::config::NetEnv::Rack.link(),
            switch_delay: 500,
            pct_threshold: 0.8,
            deadline_slack: crate::config::NetEnv::Rack.deadline_slack(),
            batches_per_epoch: 10,
            seed: 1,
            horizon: 3600 * SEC,
        }
    }
}

/// The outcome of a run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub proto: String,
    pub iters: Vec<IterStats>,
    pub total_time: Nanos,
    /// Mean per-worker gather times (incast direction).
    pub gather_summary: Summary,
}

impl RunReport {
    /// Training throughput in images/sec given a per-worker batch size.
    /// Excludes the first iteration (threshold/estimator bootstrapping)
    /// when more than one completed — steady-state, like the paper's
    /// measurements over whole epochs.
    pub fn throughput(&self, n_workers: usize, batch_images: u64) -> f64 {
        if self.iters.is_empty() || self.total_time == 0 {
            return 0.0;
        }
        let (n, window) = if self.iters.len() > 1 {
            (self.iters.len() - 1, self.total_time - self.iters[0].end)
        } else {
            (1, self.total_time)
        };
        let images = n as u64 * n_workers as u64 * batch_images;
        images as f64 / (window.max(1) as f64 / SEC as f64)
    }

    pub fn mean_bst(&self) -> Nanos {
        if self.iters.is_empty() {
            return 0;
        }
        self.iters.iter().map(|i| i.bst).sum::<Nanos>() / self.iters.len() as u64
    }

    pub fn bst_values_ms(&self) -> Vec<f64> {
        self.iters.iter().map(|i| i.bst as f64 / MS as f64).collect()
    }

    pub fn mean_delivered(&self) -> f64 {
        if self.iters.is_empty() {
            return 1.0;
        }
        self.iters.iter().map(|i| i.mean_delivered).sum::<f64>() / self.iters.len() as f64
    }
}

/// Run a modeled-compute training simulation (no PJRT involved).
pub fn run_training(cfg: &TrainingCfg) -> RunReport {
    run_with(cfg, |_, _| Box::new(ModeledCompute(cfg.compute_time)), Box::new(NullAggregate(cfg.agg_time)))
}

/// Run with custom compute/aggregation backends (real training uses this).
pub fn run_with(
    cfg: &TrainingCfg,
    mut make_compute: impl FnMut(usize, &TrainingCfg) -> Box<dyn Compute>,
    agg: Box<dyn Aggregate>,
) -> RunReport {
    let report: Rc<RefCell<Vec<IterStats>>> = Rc::new(RefCell::new(Vec::new()));
    let mut sim = Sim::new(cfg.seed);
    let sw = sim.add_switch(cfg.switch_delay);
    // PS is entity 1 (first host), workers follow.
    let tracker = crate::proto::ThresholdTracker::new(
        cfg.n_workers,
        cfg.deadline_slack,
        cfg.pct_threshold,
    );
    let worker_ids: Vec<usize> = (0..cfg.n_workers).map(|w| 2 + w).collect();
    let ps = PsNode::new(
        worker_ids.clone(),
        cfg.proto,
        cfg.model_bytes,
        cfg.critical.clone(),
        agg,
        tracker,
        cfg.iters,
        cfg.batches_per_epoch,
        report.clone(),
    );
    let ps_id = sim.add_host(Box::new(ps));
    let (ps_up, _) = sim.add_duplex(ps_id, sw, cfg.link);
    sim.set_default_uplink(ps_id, ps_up);
    for w in 0..cfg.n_workers {
        let node = WorkerNode::new(
            w,
            ps_id,
            cfg.n_workers,
            cfg.proto,
            cfg.model_bytes,
            cfg.critical.clone(),
            make_compute(w, cfg),
            cfg.iters,
        );
        let id = sim.add_host(Box::new(node));
        debug_assert_eq!(id, worker_ids[w]);
        let (up, _) = sim.add_duplex(id, sw, cfg.link);
        sim.set_default_uplink(id, up);
    }
    sim.run_until(cfg.horizon);
    let total_time = report.borrow().last().map(|i| i.end).unwrap_or(sim.now());
    let mut gathers = Vec::new();
    for &w in &worker_ids {
        let node = sim.node_as::<WorkerNode>(w);
        gathers.extend(node.stats.gather_times.iter().map(|&t| t as f64 / MS as f64));
    }
    let iters = report.borrow().clone();
    RunReport {
        proto: cfg.proto.name(),
        iters,
        total_time,
        gather_summary: Summary::of(&gathers),
    }
}

// ---------------------------------------------------------------------------
// Real compute backends (PJRT).
// ---------------------------------------------------------------------------

/// Shared state for real training: runtime artifacts + blackboard.
pub struct RealTraining {
    pub manifest: ModelManifest,
    pub blackboard: Blackboard,
    train_step: Rc<Artifact>,
    eval: Rc<Artifact>,
    aggregate: Rc<Artifact>,
    /// Simulated duration of one train_step / one aggregation.
    pub sim_compute_time: Nanos,
    pub sim_agg_time: Nanos,
    pub lr: f32,
    pub losses: Rc<RefCell<Vec<(u64, f32)>>>,
}

impl RealTraining {
    pub fn new(rt: &Runtime, preset: &str, lr: f32) -> Result<Rc<RealTraining>> {
        let manifest = ModelManifest::load(crate::runtime::default_artifacts_dir(), preset)?;
        let init = rt.load(&format!("init_{preset}"))?;
        let params = to_f32(&init.run(&[])?[0])?;
        anyhow::ensure!(params.len() == manifest.padded_dim);
        Ok(Rc::new(RealTraining {
            manifest,
            blackboard: Blackboard::new(params),
            train_step: Rc::new(rt.load(&format!("train_step_{preset}"))?),
            eval: Rc::new(rt.load(&format!("eval_{preset}"))?),
            aggregate: Rc::new(rt.load(&format!("aggregate_{preset}"))?),
            sim_compute_time: 50 * MS,
            sim_agg_time: 5 * MS,
            lr,
            losses: Rc::new(RefCell::new(Vec::new())),
        }))
    }

    pub fn eval_loss(&self, tokens: &[i32]) -> Result<f32> {
        let cfg = &self.manifest;
        let p = literal_f32(&self.blackboard.params(), &[cfg.padded_dim as i64])?;
        let t = literal_i32(tokens, &[cfg.batch as i64, cfg.seq_len as i64 + 1])?;
        let out = self.eval.run(&[p, t])?;
        Ok(to_f32(&out[0])?[0])
    }
}

/// Worker-side real compute: runs train_step via PJRT, deposits gradients.
pub struct RealCompute {
    pub shared: Rc<RealTraining>,
    pub corpus: Corpus,
}

impl Compute for RealCompute {
    fn compute(&mut self, worker: usize, iter: u64) -> Nanos {
        let m = &self.shared.manifest;
        let tokens = self.corpus.next_batch(m.batch, m.seq_len + 1);
        let run = || -> Result<(Vec<f32>, f32)> {
            let p = literal_f32(&self.shared.blackboard.params(), &[m.padded_dim as i64])?;
            let t = literal_i32(&tokens, &[m.batch as i64, m.seq_len as i64 + 1])?;
            let out = self.shared.train_step.run(&[p, t])?;
            Ok((to_f32(&out[0])?, to_f32(&out[1])?[0]))
        };
        match run() {
            Ok((grads, loss)) => {
                self.shared.blackboard.put_grads(worker, iter, grads);
                self.shared.losses.borrow_mut().push((iter, loss));
            }
            Err(e) => panic!("train_step failed for worker {worker}: {e:#}"),
        }
        self.shared.sim_compute_time
    }
}

/// PS-side real aggregation: masked-mean Pallas kernel + momentum SGD.
pub struct XlaAggregate {
    pub shared: Rc<RealTraining>,
    pub n_workers: usize,
}

impl Aggregate for XlaAggregate {
    fn aggregate(&mut self, iter: u64, arrivals: &[Option<(Bitmap, u64)>]) -> Nanos {
        let m = &self.shared.manifest;
        let d = m.padded_dim;
        let aw = m.agg_workers;
        assert!(self.n_workers <= aw, "aggregate artifact supports ≤{aw} workers");
        let mut g = vec![0.0f32; aw * d];
        let mut mask = vec![0.0f32; aw * d];
        let seg_map = crate::proto::SegmentMap::new(
            d as u64 * 4,
            Manifest::aligned_payload(LTP_MSS),
            vec![],
        );
        for w in 0..self.n_workers {
            let Some(grads) = self.shared.blackboard.take_grads(w, iter) else {
                continue; // worker contributed nothing this round
            };
            let row_mask = match &arrivals[w] {
                Some((bitmap, _)) => element_mask(&seg_map, bitmap, d),
                None => vec![1.0f32; d], // TCP: everything arrived
            };
            // Bubble semantics: zero the lost elements of the gradient row.
            for i in 0..d {
                g[w * d + i] = grads[i] * row_mask[i];
            }
            mask[w * d..(w + 1) * d].copy_from_slice(&row_mask);
        }
        let run = || -> Result<()> {
            let p = literal_f32(&self.shared.blackboard.params(), &[d as i64])?;
            let v = literal_f32(&self.shared.blackboard.momentum(), &[d as i64])?;
            let gl = literal_f32(&g, &[aw as i64, d as i64])?;
            let ml = literal_f32(&mask, &[aw as i64, d as i64])?;
            let lr = literal_f32(&[self.shared.lr], &[1])?;
            let out = self.shared.aggregate.run(&[p, v, gl, ml, lr])?;
            self.shared.blackboard.set_params(to_f32(&out[0])?);
            self.shared.blackboard.set_momentum(to_f32(&out[1])?);
            Ok(())
        };
        if let Err(e) = run() {
            panic!("aggregation failed at iter {iter}: {e:#}");
        }
        self.shared.blackboard.gc(iter + 1);
        self.shared.sim_agg_time
    }

    fn loss(&mut self, iter: u64) -> Option<f32> {
        let losses = self.shared.losses.borrow();
        let vals: Vec<f32> =
            losses.iter().filter(|&&(i, _)| i == iter).map(|&(_, l)| l).collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f32>() / vals.len() as f32)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::CcAlgo;
    use crate::config::Workload;
    use crate::simnet::LossModel;

    fn quick_cfg(proto: Proto) -> TrainingCfg {
        let mut cfg = TrainingCfg::modeled(proto, Workload::Micro, 4);
        cfg.iters = 3;
        cfg
    }

    #[test]
    fn modeled_ltp_completes_all_iterations() {
        let report = run_training(&quick_cfg(Proto::Ltp));
        assert_eq!(report.iters.len(), 3, "all iterations must finish");
        assert!(report.mean_bst() > 0);
        // Even a "clean" network drops packets under incast congestion;
        // LTP legitimately early-closes those tails. Only a small fraction
        // may be dropped.
        assert!(
            report.mean_delivered() > 0.88,
            "delivered {}",
            report.mean_delivered()
        );
    }

    #[test]
    fn modeled_tcp_completes_all_iterations() {
        for cc in [CcAlgo::Cubic, CcAlgo::Bbr] {
            let report = run_training(&quick_cfg(Proto::Tcp(cc)));
            assert_eq!(report.iters.len(), 3, "{}", cc.name());
        }
    }

    #[test]
    fn ltp_delivers_partially_under_loss_but_tcp_fully() {
        let mut cfg = quick_cfg(Proto::Ltp);
        cfg.link = cfg.link.with_loss(LossModel::Bernoulli { p: 0.02 });
        cfg.iters = 4;
        let ltp = run_training(&cfg);
        assert_eq!(ltp.iters.len(), 4);
        assert!(
            ltp.mean_delivered() < 1.0,
            "2% loss should trigger early closes: {}",
            ltp.mean_delivered()
        );
        assert!(ltp.mean_delivered() > 0.8);

        let mut cfg = quick_cfg(Proto::Tcp(CcAlgo::Bbr));
        cfg.link = cfg.link.with_loss(LossModel::Bernoulli { p: 0.02 });
        cfg.iters = 2;
        let tcp = run_training(&cfg);
        assert_eq!(tcp.iters.len(), 2);
        assert!((tcp.mean_delivered() - 1.0).abs() < 1e-9, "TCP always delivers 100%");
    }

    #[test]
    fn ltp_beats_cubic_under_loss() {
        let loss = LossModel::Bernoulli { p: 0.01 };
        let mut l = quick_cfg(Proto::Ltp);
        l.link = l.link.with_loss(loss);
        l.iters = 4;
        let mut c = quick_cfg(Proto::Tcp(CcAlgo::Cubic));
        c.link = c.link.with_loss(loss);
        c.iters = 4;
        let ltp = run_training(&l);
        let cubic = run_training(&c);
        assert_eq!(ltp.iters.len(), 4);
        assert_eq!(cubic.iters.len(), 4);
        assert!(
            ltp.mean_bst() < cubic.mean_bst(),
            "LTP BST {} must beat cubic {}",
            ltp.mean_bst(),
            cubic.mean_bst()
        );
    }

    #[test]
    fn throughput_accounting() {
        let report = run_training(&quick_cfg(Proto::Ltp));
        let tp = report.throughput(4, 32);
        assert!(tp > 0.0);
    }
}
