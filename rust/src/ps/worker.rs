//! Worker node: compute → gather (loss-tolerant) → wait for the reliable
//! broadcast → next iteration (BSP).
//!
//! A worker's gather/broadcast traffic follows a **routing plan**
//! assigned by the run's aggregation topology (DESIGN.md §1.2): one
//! [`WorkerRoute`] per aggregator endpoint, each naming the destination,
//! the byte range of the gradient sent there, its critical segments, and
//! the flow-id slots used. The classic single-PS run is the one-route
//! case ([`WorkerRoute::single`]) and behaves bit-for-bit as before;
//! sharded runs fan one iteration's gather out over several concurrent
//! flows that share this worker's uplink.

use super::spec::ProtoSpec;
use super::transport::{FlowRx, FlowTx, RxCfg, TxCfg};
use crate::proto::EarlyCloseCfg;
use crate::simnet::{Ctx, EntityId, Node, Packet};
use crate::wire::PacketKind;
use crate::Nanos;

/// The local computation a worker performs each iteration. Returns the
/// simulated duration; real implementations also deposit gradients into
/// the [`super::Blackboard`].
pub trait Compute {
    fn compute(&mut self, worker: usize, iter: u64) -> Nanos;
}

/// Fixed-duration modeled compute (paper message-size experiments).
pub struct ModeledCompute(pub Nanos);

impl Compute for ModeledCompute {
    fn compute(&mut self, _worker: usize, _iter: u64) -> Nanos {
        self.0
    }
}

/// One (shard → aggregator) leg of a worker's per-iteration traffic: the
/// gradient byte range `bytes` goes to `dst` on flow
/// `iter * stride + gather_slot`, and the matching model broadcast comes
/// back on `iter * stride + bcast_slot`. Slots are unique fabric-wide
/// within an iteration, so concurrent legs never collide.
#[derive(Debug, Clone)]
pub struct WorkerRoute {
    pub dst: EntityId,
    /// Gradient bytes this leg carries (the aggregator's shard range).
    pub bytes: u64,
    /// Bytes actually put on the wire for the gather direction — the
    /// codec's encoded image of `bytes` (DESIGN.md §1.4). Equal to
    /// `bytes` for the identity codec; the broadcast leg always carries
    /// the dense `bytes`.
    pub gather_bytes: u64,
    /// Critical segment ids *within this leg's encoded range* (re-based
    /// to 0, in terms of the `gather_bytes` segment map).
    pub critical: Vec<u32>,
    /// Tensor-priority transmission order for the gather flow's normal
    /// segments; `None` keeps the sender's ascending default.
    pub nq_order: Option<Vec<u32>>,
    pub gather_slot: u64,
    pub bcast_slot: u64,
    pub stride: u64,
}

impl WorkerRoute {
    /// The classic single-PS route for worker `index` of `n_workers`:
    /// gather flow `iter·2W + index`, broadcast flow `iter·2W + W + index`
    /// — the original star run's numbering, bit-for-bit.
    pub fn single(
        ps: EntityId,
        index: usize,
        n_workers: usize,
        bytes: u64,
        critical: Vec<u32>,
    ) -> WorkerRoute {
        WorkerRoute {
            dst: ps,
            bytes,
            gather_bytes: bytes,
            critical,
            nq_order: None,
            gather_slot: index as u64,
            bcast_slot: (n_workers + index) as u64,
            stride: 2 * n_workers as u64,
        }
    }

    fn gather_flow(&self, iter: u64) -> u64 {
        iter * self.stride + self.gather_slot
    }

    fn bcast_flow(&self, iter: u64) -> u64 {
        iter * self.stride + self.bcast_slot
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Computing,
    Gathering,
    WaitBroadcast,
    /// Departed from the barrier (churn plane): waiting for the aggregator's
    /// join-push broadcast of the iteration before our next active one.
    JoinWait,
    Done,
}

const TOK_COMPUTE_DONE: u64 = 1 << 40;

/// Per-worker statistics.
#[derive(Debug, Clone, Default)]
pub struct WorkerStats {
    pub gathers_completed: u64,
    pub gather_times: Vec<Nanos>,
    pub broadcast_times: Vec<Nanos>,
    /// Packets retransmitted across all completed gather flows.
    pub retransmissions: u64,
    /// Packets sent across all completed gather flows.
    pub pkts_sent: u64,
}

pub struct WorkerNode {
    pub index: usize,
    routes: Vec<WorkerRoute>,
    proto: ProtoSpec,
    compute: Box<dyn Compute>,
    iters: u64,
    iter: u64,
    phase: Phase,
    /// One gather sender per route.
    txs: Vec<Option<Box<dyn FlowTx>>>,
    /// One broadcast receiver per route.
    rxs: Vec<Option<Box<dyn FlowRx>>>,
    /// Previous iteration's broadcast receivers, kept to answer straggler
    /// retransmissions (their final ACKs/Stops may have been lost; a
    /// silent worker would strand an aggregator's reliable broadcast).
    rx_prevs: Vec<Option<Box<dyn FlowRx>>>,
    gather_started: Nanos,
    bcast_started: Nanos,
    /// LTP path estimates carried across flows, per route (epoch
    /// threshold sharing).
    paths: Vec<Option<(Nanos, u64)>>,
    /// Per-iteration membership column from the churn plan; `None` (the
    /// default) keeps the always-active fast path bit-for-bit.
    schedule: Option<Vec<bool>>,
    timer_gen: u64,
    pub stats: WorkerStats,
}

impl WorkerNode {
    pub fn new(
        index: usize,
        routes: Vec<WorkerRoute>,
        proto: ProtoSpec,
        compute: Box<dyn Compute>,
        iters: u64,
    ) -> WorkerNode {
        assert!(!routes.is_empty(), "a worker needs at least one aggregator route");
        let n = routes.len();
        WorkerNode {
            index,
            routes,
            proto,
            compute,
            iters,
            iter: 0,
            phase: Phase::Computing,
            txs: (0..n).map(|_| None).collect(),
            rxs: (0..n).map(|_| None).collect(),
            rx_prevs: (0..n).map(|_| None).collect(),
            gather_started: 0,
            bcast_started: 0,
            paths: vec![None; n],
            schedule: None,
            timer_gen: 0,
            stats: WorkerStats::default(),
        }
    }

    /// Attach this worker's membership column (`schedule[iter]`: is the
    /// worker a barrier participant at `iter`?). Inactive iterations are
    /// skipped: the worker neither computes nor gathers, and resumes at
    /// its next active iteration after receiving the aggregator's
    /// join-push broadcast of the iteration before it.
    pub fn with_schedule(mut self, schedule: Vec<bool>) -> WorkerNode {
        self.schedule = Some(schedule);
        self
    }

    fn active_at(&self, iter: u64) -> bool {
        self.schedule
            .as_ref()
            .map_or(true, |s| s.get(iter as usize).copied().unwrap_or(true))
    }

    /// The first active iteration at or after `from`, if any remains.
    fn next_active(&self, from: u64) -> Option<u64> {
        (from..self.iters).find(|i| self.active_at(*i))
    }

    /// Enter the departed state until iteration `join` (which is active):
    /// open a reliable receiver per route for the join-push broadcast the
    /// aggregator sends on iteration `join - 1`'s broadcast flow.
    fn begin_join_wait(&mut self, join: u64) {
        debug_assert!(join > 0, "iteration 0 admissions go straight to compute");
        self.phase = Phase::JoinWait;
        self.iter = join;
        for (r, route) in self.routes.iter().enumerate() {
            self.txs[r] = None;
            self.rxs[r] = Some(self.proto.make_rx(RxCfg {
                flow: route.bcast_flow(join - 1),
                bytes: route.bytes,
                ec: EarlyCloseCfg::reliable(),
                critical: vec![],
                iter: join - 1,
            }));
        }
    }

    /// Advance past a finished iteration boundary (or the start of the
    /// run): begin computing at `from` if active there, park in
    /// [`Phase::JoinWait`] until the next active iteration, or finish.
    fn advance_from(&mut self, ctx: &mut Ctx, from: u64) -> bool {
        match self.next_active(from) {
            None => {
                self.iter = self.iters;
                self.phase = Phase::Done;
                false
            }
            Some(j) if j == from => {
                self.iter = from;
                self.begin_compute(ctx);
                true
            }
            Some(j) => {
                self.begin_join_wait(j);
                false
            }
        }
    }

    fn begin_compute(&mut self, ctx: &mut Ctx) {
        self.phase = Phase::Computing;
        let dur = self.compute.compute(self.index, self.iter);
        // Keyed by iteration — `timer_gen` churns with protocol timers.
        ctx.set_timer(ctx.now() + dur, TOK_COMPUTE_DONE | self.iter);
    }

    fn begin_gather(&mut self, ctx: &mut Ctx) {
        self.phase = Phase::Gathering;
        self.gather_started = ctx.now();
        for (r, route) in self.routes.iter().enumerate() {
            let (rt, bw) = self.paths[r].unwrap_or((0, 0));
            self.txs[r] = Some(self.proto.make_tx(TxCfg {
                flow: route.gather_flow(self.iter),
                bytes: route.gather_bytes,
                critical: route.critical.clone(),
                seed_rtprop: rt,
                seed_btlbw_bytes: bw,
                nq_order: route.nq_order.clone(),
            }));
            // Broadcast receiver for this iteration: always reliable.
            self.rxs[r] = Some(self.proto.make_rx(RxCfg {
                flow: route.bcast_flow(self.iter),
                bytes: route.bytes,
                ec: EarlyCloseCfg::reliable(),
                critical: vec![],
                iter: self.iter,
            }));
        }
        self.drain(ctx);
    }

    fn drain(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        let me = ctx.me;
        for (r, tx) in self.txs.iter_mut().enumerate() {
            if let Some(tx) = tx {
                while let Some(pkt) = tx.poll(now, me, self.routes[r].dst) {
                    ctx.send(pkt);
                }
            }
        }
        // Gather completion: every route's sender finished (ACKed in full
        // or stopped by its aggregator).
        if self.phase == Phase::Gathering
            && self.txs.iter().all(|t| t.as_ref().map(|t| t.is_complete()).unwrap_or(false))
        {
            self.phase = Phase::WaitBroadcast;
            self.bcast_started = now;
            self.stats.gathers_completed += 1;
            self.stats.gather_times.push(now - self.gather_started);
            for (r, tx) in self.txs.iter().enumerate() {
                let tx = tx.as_ref().expect("gather completed, so every tx exists");
                self.stats.retransmissions += tx.retransmissions();
                self.stats.pkts_sent += tx.pkts_sent();
                self.paths[r] = tx.path_estimates().or(self.paths[r]);
            }
        }
        // Broadcast completion check: every route's model shard arrived.
        let rx_done =
            self.rxs.iter().all(|r| r.as_ref().map(|r| r.is_done()).unwrap_or(false));
        if rx_done && self.phase == Phase::WaitBroadcast {
            self.stats.broadcast_times.push(now - self.bcast_started);
            for r in 0..self.routes.len() {
                self.txs[r] = None;
                self.rx_prevs[r] = self.rxs[r].take();
            }
            if self.advance_from(ctx, self.iter + 1) {
                return;
            }
        }
        // Join-push completion: the model of iteration `iter - 1` arrived
        // in full; rejoin the barrier by computing iteration `iter`.
        // (Doneness is recomputed — entering JoinWait above replaced the
        // receivers this turn's `rx_done` was measured over.)
        if self.phase == Phase::JoinWait
            && self.rxs.iter().all(|r| r.as_ref().map(|x| x.is_done()).unwrap_or(false))
        {
            for r in 0..self.routes.len() {
                self.rx_prevs[r] = self.rxs[r].take();
            }
            self.begin_compute(ctx);
            return;
        }
        // Re-arm protocol timers.
        self.timer_gen += 1;
        let mut wake: Option<Nanos> = None;
        for r in 0..self.routes.len() {
            let tx_wake = self.txs[r].as_ref().and_then(|t| t.next_wakeup());
            let rx_wake = self.rxs[r].as_ref().and_then(|x| x.next_wakeup(now));
            for cand in [tx_wake, rx_wake].into_iter().flatten() {
                wake = Some(wake.map_or(cand, |a: Nanos| a.min(cand)));
            }
        }
        if let Some(w) = wake {
            ctx.set_timer(w.max(now + 1), self.timer_gen);
        }
    }

    pub fn iterations_done(&self) -> u64 {
        self.iter
    }

    pub fn is_done(&self) -> bool {
        self.phase == Phase::Done
    }
}

impl Node for WorkerNode {
    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn start(&mut self, ctx: &mut Ctx) {
        self.advance_from(ctx, 0);
    }

    fn on_packet(&mut self, ctx: &mut Ctx, pkt: Packet) {
        if matches!(pkt.kind, PacketKind::Raw(_)) {
            return; // background cross traffic: pure link load, no protocol
        }
        let now = ctx.now();
        let me = ctx.me;
        for r in 0..self.routes.len() {
            let slot = pkt.flow % self.routes[r].stride;
            if slot == self.routes[r].gather_slot {
                // ACK/Stop for this route's gather flow (any iteration —
                // the sender itself ignores stale control traffic).
                if let Some(tx) = &mut self.txs[r] {
                    tx.handle(now, &pkt);
                }
                break;
            }
            if slot == self.routes[r].bcast_slot {
                // Broadcast data from the aggregator — current flow, or a
                // straggler retransmission of the previous iteration's.
                let mut outgoing = Vec::new();
                let cur =
                    self.rxs[r].as_ref().map(|x| x.flow_matches(pkt.flow)).unwrap_or(false);
                if cur {
                    if let Some(rx) = &mut self.rxs[r] {
                        rx.handle(now, &pkt, me, &mut |p| outgoing.push(p));
                    }
                } else if let Some(rx) = &mut self.rx_prevs[r] {
                    if rx.flow_matches(pkt.flow) {
                        rx.handle(now, &pkt, me, &mut |p| outgoing.push(p));
                    }
                }
                for p in outgoing {
                    crate::trace::note_ack(ctx, &p);
                    ctx.send(p);
                }
                break;
            }
        }
        self.drain(ctx);
    }

    fn on_timer(&mut self, ctx: &mut Ctx, token: u64) {
        if token & TOK_COMPUTE_DONE != 0 {
            if token & !TOK_COMPUTE_DONE == self.iter && self.phase == Phase::Computing {
                self.begin_gather(ctx);
            }
            return;
        }
        if token != self.timer_gen {
            return;
        }
        let now = ctx.now();
        for r in 0..self.routes.len() {
            if let Some(tx) = &mut self.txs[r] {
                tx.on_wakeup(now);
            }
            if let Some(rx) = &mut self.rxs[r] {
                rx.on_wakeup(now);
            }
        }
        self.drain(ctx);
    }
}
