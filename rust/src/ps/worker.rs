//! Worker node: compute → gather (loss-tolerant) → wait for the reliable
//! broadcast → next iteration (BSP).

use super::spec::ProtoSpec;
use super::transport::{FlowRx, FlowTx, RxCfg, TxCfg};
use crate::proto::EarlyCloseCfg;
use crate::simnet::{Ctx, EntityId, Node, Packet};
use crate::wire::PacketKind;
use crate::Nanos;

/// The local computation a worker performs each iteration. Returns the
/// simulated duration; real implementations also deposit gradients into
/// the [`super::Blackboard`].
pub trait Compute {
    fn compute(&mut self, worker: usize, iter: u64) -> Nanos;
}

/// Fixed-duration modeled compute (paper message-size experiments).
pub struct ModeledCompute(pub Nanos);

impl Compute for ModeledCompute {
    fn compute(&mut self, _worker: usize, _iter: u64) -> Nanos {
        self.0
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Computing,
    Gathering,
    WaitBroadcast,
    Done,
}

const TOK_COMPUTE_DONE: u64 = 1 << 40;

/// Per-worker statistics.
#[derive(Debug, Clone, Default)]
pub struct WorkerStats {
    pub gathers_completed: u64,
    pub gather_times: Vec<Nanos>,
    pub broadcast_times: Vec<Nanos>,
    /// Packets retransmitted across all completed gather flows.
    pub retransmissions: u64,
    /// Packets sent across all completed gather flows.
    pub pkts_sent: u64,
}

pub struct WorkerNode {
    pub index: usize,
    ps: EntityId,
    n_workers: usize,
    proto: ProtoSpec,
    model_bytes: u64,
    critical: Vec<u32>,
    compute: Box<dyn Compute>,
    iters: u64,
    iter: u64,
    phase: Phase,
    tx: Option<Box<dyn FlowTx>>,
    rx: Option<Box<dyn FlowRx>>,
    /// Previous iteration's broadcast receiver, kept to answer straggler
    /// retransmissions (its final ACKs/Stops may have been lost; a silent
    /// worker would strand the PS's reliable broadcast sender).
    rx_prev: Option<Box<dyn FlowRx>>,
    gather_started: Nanos,
    bcast_started: Nanos,
    /// LTP path estimates carried across flows (epoch threshold sharing).
    path: Option<(Nanos, u64)>,
    timer_gen: u64,
    pub stats: WorkerStats,
}

impl WorkerNode {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        index: usize,
        ps: EntityId,
        n_workers: usize,
        proto: ProtoSpec,
        model_bytes: u64,
        critical: Vec<u32>,
        compute: Box<dyn Compute>,
        iters: u64,
    ) -> WorkerNode {
        WorkerNode {
            index,
            ps,
            n_workers,
            proto,
            model_bytes,
            critical,
            compute,
            iters,
            iter: 0,
            phase: Phase::Computing,
            tx: None,
            rx: None,
            rx_prev: None,
            gather_started: 0,
            bcast_started: 0,
            path: None,
            timer_gen: 0,
            stats: WorkerStats::default(),
        }
    }

    fn gather_flow(&self, iter: u64) -> u64 {
        iter * (2 * self.n_workers as u64) + self.index as u64
    }

    fn bcast_flow(&self, iter: u64) -> u64 {
        iter * (2 * self.n_workers as u64) + self.n_workers as u64 + self.index as u64
    }

    fn begin_compute(&mut self, ctx: &mut Ctx) {
        self.phase = Phase::Computing;
        let dur = self.compute.compute(self.index, self.iter);
        // Keyed by iteration — `timer_gen` churns with protocol timers.
        ctx.set_timer(ctx.now() + dur, TOK_COMPUTE_DONE | self.iter);
    }

    fn begin_gather(&mut self, ctx: &mut Ctx) {
        self.phase = Phase::Gathering;
        self.gather_started = ctx.now();
        let (rt, bw) = self.path.unwrap_or((0, 0));
        self.tx = Some(self.proto.make_tx(TxCfg {
            flow: self.gather_flow(self.iter),
            bytes: self.model_bytes,
            critical: self.critical.clone(),
            seed_rtprop: rt,
            seed_btlbw_bytes: bw,
        }));
        // Broadcast receiver for this iteration: always reliable.
        self.rx = Some(self.proto.make_rx(RxCfg {
            flow: self.bcast_flow(self.iter),
            bytes: self.model_bytes,
            ec: EarlyCloseCfg::reliable(),
            critical: vec![],
            iter: self.iter,
        }));
        self.drain(ctx);
    }

    fn drain(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        let me = ctx.me;
        if let Some(tx) = &mut self.tx {
            while let Some(pkt) = tx.poll(now, me, self.ps) {
                ctx.send(pkt);
            }
            if tx.is_complete() && self.phase == Phase::Gathering {
                self.phase = Phase::WaitBroadcast;
                self.bcast_started = now;
                self.stats.gathers_completed += 1;
                self.stats.gather_times.push(now - self.gather_started);
                self.stats.retransmissions += tx.retransmissions();
                self.stats.pkts_sent += tx.pkts_sent();
                self.path = tx.path_estimates().or(self.path);
            }
        }
        // Broadcast completion check.
        let rx_done = self.rx.as_ref().map(|r| r.is_done()).unwrap_or(false);
        if rx_done && self.phase == Phase::WaitBroadcast {
            self.stats.broadcast_times.push(now - self.bcast_started);
            self.tx = None;
            self.rx_prev = self.rx.take();
            self.iter += 1;
            if self.iter >= self.iters {
                self.phase = Phase::Done;
            } else {
                self.begin_compute(ctx);
                return;
            }
        }
        // Re-arm protocol timers.
        self.timer_gen += 1;
        let tx_wake = self.tx.as_ref().and_then(|t| t.next_wakeup());
        let rx_wake = self.rx.as_ref().and_then(|r| r.next_wakeup(now));
        let wake = match (tx_wake, rx_wake) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        if let Some(w) = wake {
            ctx.set_timer(w.max(now + 1), self.timer_gen);
        }
    }

    pub fn iterations_done(&self) -> u64 {
        self.iter
    }

    pub fn is_done(&self) -> bool {
        self.phase == Phase::Done
    }
}

impl Node for WorkerNode {
    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn start(&mut self, ctx: &mut Ctx) {
        self.begin_compute(ctx);
    }

    fn on_packet(&mut self, ctx: &mut Ctx, pkt: Packet) {
        if matches!(pkt.kind, PacketKind::Raw(_)) {
            return; // background cross traffic: pure link load, no protocol
        }
        let now = ctx.now();
        let me = ctx.me;
        let per_iter = 2 * self.n_workers as u64;
        let slot = pkt.flow % per_iter;
        if slot < self.n_workers as u64 {
            // ACK/Stop for our gather flow.
            if let Some(tx) = &mut self.tx {
                tx.handle(now, &pkt);
            }
        } else {
            // Broadcast data from the PS — current flow, or a straggler
            // retransmission of the previous iteration's flow.
            let mut outgoing = Vec::new();
            let cur = self.rx.as_ref().map(|r| r.flow_matches(pkt.flow)).unwrap_or(false);
            if cur {
                if let Some(rx) = &mut self.rx {
                    rx.handle(now, &pkt, me, &mut |p| outgoing.push(p));
                }
            } else if let Some(rx) = &mut self.rx_prev {
                if rx.flow_matches(pkt.flow) {
                    rx.handle(now, &pkt, me, &mut |p| outgoing.push(p));
                }
            }
            for p in outgoing {
                ctx.send(p);
            }
        }
        self.drain(ctx);
    }

    fn on_timer(&mut self, ctx: &mut Ctx, token: u64) {
        if token & TOK_COMPUTE_DONE != 0 {
            if token & !TOK_COMPUTE_DONE == self.iter && self.phase == Phase::Computing {
                self.begin_gather(ctx);
            }
            return;
        }
        if token != self.timer_gen {
            return;
        }
        let now = ctx.now();
        if let Some(tx) = &mut self.tx {
            tx.on_wakeup(now);
        }
        if let Some(rx) = &mut self.rx {
            rx.on_wakeup(now);
        }
        self.drain(ctx);
    }
}
