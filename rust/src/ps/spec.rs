//! The **protocol registry** and spec grammar (DESIGN.md §Transport API):
//! protocols are data, not code. A spec string names a registered protocol
//! and optionally tunes it —
//!
//! ```text
//! spec   := key [':' param (',' param)*]
//! param  := name '=' value          e.g.  ltp:pct=0.9,slack=100ms
//! ```
//!
//! [`parse_proto`] resolves a spec against [`proto_registry`] (modeled on
//! the scenario registry) and returns a [`ProtoSpec`] — a cheap, cloneable,
//! thread-shareable handle to a [`Transport`] whose [`ProtoSpec::name`] is
//! the *canonical* spec string: parameters render in a fixed order and the
//! `tcp:cc=<name>` form normalizes to the bare cc name, so the default
//! matrix's labels (`ltp`, `reno`, …) are stable across the CLI, scenario
//! JSON, and bench reports.

use super::transport::{LtpAdaptiveTransport, LtpTransport, TcpTransport, Transport};
use crate::cc::CcAlgo;
use crate::{Nanos, MS, SEC, US};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// A parsed, validated protocol spec: the handle stored in run
/// configurations and carried across worker threads by the sweep driver.
/// Clones share the underlying [`Transport`].
#[derive(Clone)]
pub struct ProtoSpec(Arc<dyn Transport>);

impl ProtoSpec {
    /// Canonical spec string — the protocol's name everywhere (labels,
    /// JSON reports, bench records). Borrowed; no per-call allocation.
    pub fn name(&self) -> &str {
        self.0.name()
    }
}

impl std::ops::Deref for ProtoSpec {
    type Target = dyn Transport;

    fn deref(&self) -> &(dyn Transport + 'static) {
        &*self.0
    }
}

impl std::fmt::Display for ProtoSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::fmt::Debug for ProtoSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ProtoSpec({})", self.name())
    }
}

/// Two specs are equal iff their canonical names are (`tcp:cc=reno` thus
/// equals `reno`).
impl PartialEq for ProtoSpec {
    fn eq(&self, other: &ProtoSpec) -> bool {
        self.name() == other.name()
    }
}

impl std::str::FromStr for ProtoSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<ProtoSpec> {
        parse_proto(s)
    }
}

/// One registered protocol family.
pub struct ProtoDef {
    /// Spec key (`--proto <key>[:params]`).
    pub key: &'static str,
    pub summary: &'static str,
    /// Accepted `name=value` parameters, for `ltp proto list`.
    pub params: &'static str,
    /// Run (at default parameters) in the `proto_matrix` scenario sweep.
    pub in_matrix: bool,
    build: fn(&[(String, String)]) -> Result<ProtoSpec>,
}

/// The protocol registry. Append entries here (and their transports in
/// `ps/transport.rs`); the CLI, the `proto_matrix` scenario, and the
/// transport conformance test (`rust/tests/transport.rs`) follow.
pub const PROTO_REGISTRY: &[ProtoDef] = &[
    ProtoDef {
        key: "ltp",
        summary: "loss-tolerant transmission protocol (paper §III)",
        params: "pct=<0..1>, slack=<duration>",
        in_matrix: true,
        build: build_ltp,
    },
    ProtoDef {
        key: "ltp-adaptive",
        summary: "phase-aware LTP: Early-Close pct anneals start→end over the first `over` iterations",
        params: "start=<0..1>, end=<0..1>, over=<iters>, slack=<duration>",
        in_matrix: true,
        build: build_ltp_adaptive,
    },
    ProtoDef {
        key: "tcp",
        summary: "reliable byte stream with a chosen congestion control (canonical name = the cc)",
        params: "cc=<reno|cubic|dctcp|bbr> (required)",
        in_matrix: false, // the per-cc keys below cover the matrix
        build: build_tcp,
    },
    ProtoDef {
        key: "reno",
        summary: "TCP New Reno (kernel loss-based default) — alias of tcp:cc=reno",
        params: "",
        in_matrix: true,
        build: |p| build_tcp_named(CcAlgo::Reno, p),
    },
    ProtoDef {
        key: "cubic",
        summary: "TCP Cubic — alias of tcp:cc=cubic",
        params: "",
        in_matrix: true,
        build: |p| build_tcp_named(CcAlgo::Cubic, p),
    },
    ProtoDef {
        key: "dctcp",
        summary: "DCTCP (ECN-proportional backoff) — alias of tcp:cc=dctcp",
        params: "",
        in_matrix: true,
        build: |p| build_tcp_named(CcAlgo::Dctcp, p),
    },
    ProtoDef {
        key: "bbr",
        summary: "TCP BBR (model-based) — alias of tcp:cc=bbr",
        params: "",
        in_matrix: true,
        build: |p| build_tcp_named(CcAlgo::Bbr, p),
    },
];

/// The registry (function form, for iteration symmetry with the scenario
/// engine).
pub fn proto_registry() -> &'static [ProtoDef] {
    PROTO_REGISTRY
}

/// Parse a protocol spec (`ltp`, `ltp:pct=0.9,slack=100ms`, `tcp:cc=cubic`)
/// against the registry.
pub fn parse_proto(spec: &str) -> Result<ProtoSpec> {
    let spec = spec.trim();
    let (key, rest) = match spec.split_once(':') {
        Some((k, r)) => (k, Some(r)),
        None => (spec, None),
    };
    let key = key.to_ascii_lowercase();
    // Historical spellings accepted by the pre-registry CLI.
    let key = match key.as_str() {
        "newreno" | "new-reno" => "reno".to_string(),
        _ => key,
    };
    let Some(def) = PROTO_REGISTRY.iter().find(|d| d.key == key) else {
        let known: Vec<&str> = PROTO_REGISTRY.iter().map(|d| d.key).collect();
        bail!("unknown protocol `{key}` in spec `{spec}` (known: {})", known.join(", "));
    };
    let params = parse_params(rest).with_context(|| format!("in protocol spec `{spec}`"))?;
    (def.build)(&params).with_context(|| format!("in protocol spec `{spec}`"))
}

/// The paper's default two-protocol matrix: LTP vs the kernel-default
/// loss-based baseline (Reno).
pub fn baseline_matrix() -> Vec<ProtoSpec> {
    vec![
        parse_proto("ltp").expect("registry default"),
        parse_proto("reno").expect("registry default"),
    ]
}

/// Every matrix-flagged registry protocol at default parameters, in
/// registry order — the `proto_matrix` scenario's sweep set.
pub fn registry_matrix() -> Vec<ProtoSpec> {
    PROTO_REGISTRY
        .iter()
        .filter(|d| d.in_matrix)
        .map(|d| parse_proto(d.key).expect("registry defaults must parse"))
        .collect()
}

// ---------------------------------------------------------------------------
// Grammar helpers (shared with the aggregation registry in `ps/agg.rs` and
// the compute-backend registry in `crate::compute`, which reuse the same
// `key[:name=value,...]` spec grammar).
// ---------------------------------------------------------------------------

pub(crate) fn parse_params(rest: Option<&str>) -> Result<Vec<(String, String)>> {
    let Some(rest) = rest else { return Ok(Vec::new()) };
    if rest.trim().is_empty() {
        bail!("empty parameter list after `:`");
    }
    let mut out = Vec::new();
    for kv in rest.split(',') {
        let Some((k, v)) = kv.split_once('=') else {
            bail!("malformed parameter `{kv}` (expected `name=value`)");
        };
        let (k, v) = (k.trim().to_ascii_lowercase(), v.trim().to_string());
        if v.is_empty() {
            bail!("empty value for parameter `{k}`");
        }
        if out.iter().any(|(seen, _)| *seen == k) {
            bail!("duplicate parameter `{k}`");
        }
        out.push((k, v));
    }
    Ok(out)
}

/// Parse a duration literal: `100ms`, `30s`, `500us`, `250000ns`.
fn parse_duration(v: &str) -> Result<Nanos> {
    // Longest suffixes first: a bare `s` also terminates `ms`/`us`/`ns`.
    for (suffix, unit) in [("ms", MS), ("us", US), ("ns", 1), ("s", SEC)] {
        if let Some(num) = v.strip_suffix(suffix) {
            let n: u64 = num
                .parse()
                .with_context(|| format!("bad duration `{v}` (expected e.g. `100ms`)"))?;
            return n
                .checked_mul(unit)
                .with_context(|| format!("duration `{v}` overflows the nanosecond clock"));
        }
    }
    bail!("bad duration `{v}` (expected an integer with a ns/us/ms/s suffix)")
}

/// Render a duration in the largest unit that divides it evenly — the
/// canonical inverse of [`parse_duration`].
fn fmt_duration(n: Nanos) -> String {
    for (suffix, unit) in [("s", SEC), ("ms", MS), ("us", US)] {
        if n >= unit && n % unit == 0 {
            return format!("{}{suffix}", n / unit);
        }
    }
    format!("{n}ns")
}

pub(crate) fn parse_fraction(k: &str, v: &str) -> Result<f64> {
    let x: f64 = v.parse().with_context(|| format!("bad value for `{k}`: `{v}`"))?;
    if !(x > 0.0 && x <= 1.0) {
        bail!("`{k}={v}` out of range (need 0 < {k} <= 1)");
    }
    Ok(x)
}

pub(crate) fn unknown_param(key: &str, k: &str, accepted: &str) -> anyhow::Error {
    anyhow::anyhow!("unknown parameter `{k}` for `{key}` (accepted: {accepted})")
}

/// Canonical spec string: `key` alone, or `key:` + the given params.
pub(crate) fn canonical(key: &str, parts: &[String]) -> String {
    if parts.is_empty() {
        key.to_string()
    } else {
        format!("{key}:{}", parts.join(","))
    }
}

// ---------------------------------------------------------------------------
// Per-protocol builders.
// ---------------------------------------------------------------------------

fn build_ltp(params: &[(String, String)]) -> Result<ProtoSpec> {
    let mut pct = None;
    let mut slack = None;
    for (k, v) in params {
        match k.as_str() {
            "pct" => pct = Some(parse_fraction(k, v)?),
            "slack" => slack = Some(parse_duration(v).with_context(|| format!("parameter `{k}`"))?),
            _ => return Err(unknown_param("ltp", k, "pct, slack")),
        }
    }
    // Canonical order: pct, slack.
    let mut parts = Vec::new();
    if let Some(p) = pct {
        parts.push(format!("pct={p}"));
    }
    if let Some(s) = slack {
        parts.push(format!("slack={}", fmt_duration(s)));
    }
    Ok(ProtoSpec(Arc::new(LtpTransport { pct, slack, spec: canonical("ltp", &parts) })))
}

/// `ltp-adaptive` annealing defaults: tolerate 30 % loss while gradients
/// are coarse, tighten to 5 % as training refines.
const ADAPT_START: f64 = 0.7;
const ADAPT_END: f64 = 0.95;
const ADAPT_OVER: u64 = 16;

fn build_ltp_adaptive(params: &[(String, String)]) -> Result<ProtoSpec> {
    let (mut start, mut end, mut over, mut slack) = (None, None, None, None);
    for (k, v) in params {
        match k.as_str() {
            "start" => start = Some(parse_fraction(k, v)?),
            "end" => end = Some(parse_fraction(k, v)?),
            "over" => {
                let n: u64 = v.parse().with_context(|| format!("bad value for `over`: `{v}`"))?;
                if n == 0 {
                    bail!("`over=0`: the anneal window needs at least one iteration");
                }
                over = Some(n);
            }
            "slack" => slack = Some(parse_duration(v).with_context(|| format!("parameter `{k}`"))?),
            _ => return Err(unknown_param("ltp-adaptive", k, "start, end, over, slack")),
        }
    }
    // Canonical order: start, end, over, slack.
    let mut parts = Vec::new();
    if let Some(x) = start {
        parts.push(format!("start={x}"));
    }
    if let Some(x) = end {
        parts.push(format!("end={x}"));
    }
    if let Some(x) = over {
        parts.push(format!("over={x}"));
    }
    if let Some(s) = slack {
        parts.push(format!("slack={}", fmt_duration(s)));
    }
    Ok(ProtoSpec(Arc::new(LtpAdaptiveTransport {
        start: start.unwrap_or(ADAPT_START),
        end: end.unwrap_or(ADAPT_END),
        over: over.unwrap_or(ADAPT_OVER),
        slack,
        spec: canonical("ltp-adaptive", &parts),
    })))
}

fn build_tcp(params: &[(String, String)]) -> Result<ProtoSpec> {
    let mut cc = None;
    for (k, v) in params {
        match k.as_str() {
            "cc" => cc = Some(v.parse::<CcAlgo>().map_err(anyhow::Error::msg)?),
            _ => return Err(unknown_param("tcp", k, "cc")),
        }
    }
    let Some(cc) = cc else {
        bail!("`tcp` needs a congestion control: tcp:cc=<reno|cubic|dctcp|bbr>");
    };
    Ok(tcp_spec(cc))
}

fn build_tcp_named(cc: CcAlgo, params: &[(String, String)]) -> Result<ProtoSpec> {
    if let Some((k, _)) = params.first() {
        return Err(unknown_param(cc.name(), k, "none"));
    }
    Ok(tcp_spec(cc))
}

/// The canonical name of every TCP variant is the bare cc name, whichever
/// spelling built it — so `tcp:cc=reno` and `reno` label reports
/// identically.
fn tcp_spec(cc: CcAlgo) -> ProtoSpec {
    ProtoSpec(Arc::new(TcpTransport { cc, spec: cc.name().to_string() }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::EarlyCloseCfg;

    #[test]
    fn defaults_parse_with_canonical_names() {
        for (spec, canon, lt) in [
            ("ltp", "ltp", true),
            ("ltp-adaptive", "ltp-adaptive", true),
            ("reno", "reno", false),
            ("cubic", "cubic", false),
            ("dctcp", "dctcp", false),
            ("bbr", "bbr", false),
            ("tcp:cc=reno", "reno", false),
            ("tcp:cc=cubic", "cubic", false),
            ("TCP:cc=BBR", "bbr", false),
            // Historical CLI spellings keep working, normalized to `reno`.
            ("newreno", "reno", false),
            ("new-reno", "reno", false),
        ] {
            let p = parse_proto(spec).unwrap_or_else(|e| panic!("{spec}: {e:#}"));
            assert_eq!(p.name(), canon, "{spec}");
            assert_eq!(p.is_loss_tolerant(), lt, "{spec}");
        }
    }

    #[test]
    fn canonical_names_roundtrip() {
        for spec in [
            "ltp",
            "ltp:pct=0.9",
            "ltp:pct=0.9,slack=100ms",
            "ltp:slack=2s",
            "ltp-adaptive:start=0.6,end=0.9,over=8",
            "reno",
        ] {
            let once = parse_proto(spec).unwrap();
            let twice = parse_proto(once.name()).unwrap();
            assert_eq!(once.name(), twice.name(), "canonical form must be a fixed point");
        }
        // Parameter order normalizes.
        let p = parse_proto("ltp:slack=100ms,pct=0.9").unwrap();
        assert_eq!(p.name(), "ltp:pct=0.9,slack=100ms");
    }

    #[test]
    fn spec_equality_is_canonical() {
        assert_eq!(parse_proto("tcp:cc=reno").unwrap(), parse_proto("reno").unwrap());
        assert_ne!(parse_proto("ltp").unwrap(), parse_proto("ltp:pct=0.9").unwrap());
    }

    #[test]
    fn tuning_overrides_flow_from_params() {
        let p = parse_proto("ltp:pct=0.9,slack=100ms").unwrap();
        let t = p.tuning();
        assert_eq!(t.pct_threshold, Some(0.9));
        assert_eq!(t.deadline_slack, Some(100 * crate::MS));
        // Defaults stay inert so default runs are byte-identical.
        let d = parse_proto("ltp").unwrap().tuning();
        assert_eq!(d.pct_threshold, None);
        assert_eq!(d.deadline_slack, None);
    }

    #[test]
    fn adaptive_params_reach_the_receiver() {
        use crate::simnet::Packet;
        use crate::wire::{Importance, LtpHeader, PacketKind, HDR_BYTES, UDP_IP_OVERHEAD};
        let p = parse_proto("ltp-adaptive:start=0.6,end=0.6,over=1").unwrap();
        // With start == end the annealed pct is a constant 0.6 — lower than
        // the caller-supplied 0.99 — so a loss-tolerant receiver must
        // early-close at 60 % once past the LT threshold.
        let mut rx = p.make_rx(crate::ps::RxCfg {
            flow: 1,
            bytes: 10 * 1463,
            ec: EarlyCloseCfg { lt_threshold: crate::MS, deadline: crate::SEC, pct: 0.99 },
            critical: vec![],
            iter: 0,
        });
        let size = UDP_IP_OVERHEAD + HDR_BYTES as u32 + 1463;
        let mut sink = |_p: Packet| {};
        let pkt = |hdr| Packet::new(0, 1, size, 1, PacketKind::Ltp(hdr));
        rx.handle(0, &pkt(LtpHeader::registration(1, 10)), 1, &mut sink);
        for seq in 0..6 {
            rx.handle(1, &pkt(LtpHeader::data(1, seq, Importance::Normal)), 1, &mut sink);
        }
        assert!(!rx.is_done(), "60% before the LT threshold must wait");
        rx.on_wakeup(2 * crate::MS);
        assert!(rx.is_done(), "annealed pct=0.6 must early-close at 60%");
        assert!((rx.delivered_fraction() - 0.6).abs() < 1e-9);
    }

    #[test]
    fn malformed_specs_are_rejected() {
        for bad in [
            "nope",
            "ltp:",
            "ltp:pct",
            "ltp:pct=",
            "ltp:pct=1.5",
            "ltp:pct=0.9,pct=0.8",
            "ltp:slack=fast",
            "ltp:window=3",
            "ltp-adaptive:over=0",
            "ltp:slack=99999999999999s", // would overflow the ns clock
            "tcp",
            "tcp:cc=vegas",
            "reno:cc=reno",
        ] {
            assert!(parse_proto(bad).is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn duration_grammar_roundtrips() {
        assert_eq!(parse_duration("100ms").unwrap(), 100 * MS);
        assert_eq!(parse_duration("2s").unwrap(), 2 * SEC);
        assert_eq!(parse_duration("500us").unwrap(), 500 * US);
        assert_eq!(parse_duration("7ns").unwrap(), 7);
        for n in [100 * MS, 2 * SEC, 500 * US, 7, 1500 * US] {
            assert_eq!(parse_duration(&fmt_duration(n)).unwrap(), n);
        }
    }

    #[test]
    fn registry_matrix_covers_the_acceptance_set() {
        let names: Vec<String> =
            registry_matrix().iter().map(|p| p.name().to_string()).collect();
        for want in ["ltp", "ltp-adaptive", "reno", "cubic", "dctcp", "bbr"] {
            assert!(names.iter().any(|n| n == want), "matrix missing `{want}`: {names:?}");
        }
        assert!(names.len() >= 6);
        // The default matrix stays the paper's two-protocol baseline.
        let base: Vec<String> =
            baseline_matrix().iter().map(|p| p.name().to_string()).collect();
        assert_eq!(base, ["ltp", "reno"]);
    }
}
