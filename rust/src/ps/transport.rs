//! Protocol abstraction for the PS system: one gather/broadcast flow over
//! either LTP or TCP-with-a-chosen-cc, with a uniform poll surface so
//! [`super::PsNode`] and [`super::WorkerNode`] are protocol-agnostic.

use crate::cc::CcAlgo;
use crate::proto::{EarlyCloseCfg, LtpEvent, LtpReceiver, LtpSender, SegmentMap};
use crate::simnet::Packet;
use crate::tcp::{TcpReceiver, TcpSender};
use crate::util::Bitmap;
use crate::wire::{LtpType, PacketKind, HDR_BYTES, LTP_MSS, TCP_IP_OVERHEAD, TCP_MSS, UDP_IP_OVERHEAD};
use crate::Nanos;

/// Which transport a training run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Proto {
    Ltp,
    Tcp(CcAlgo),
}

impl Proto {
    pub fn name(self) -> String {
        match self {
            Proto::Ltp => "ltp".to_string(),
            Proto::Tcp(cc) => cc.name().to_string(),
        }
    }

    pub fn is_loss_tolerant(self) -> bool {
        matches!(self, Proto::Ltp)
    }
}

/// Sending side of one flow (worker gather, or PS broadcast).
pub enum GatherTx {
    Ltp(LtpSender),
    Tcp(TcpSender),
}

impl GatherTx {
    /// Create a sender for `bytes` with the given critical segments (LTP)
    /// or a plain byte stream (TCP). `seed_rtprop`/`seed_btlbw` prime LTP's
    /// estimators from path knowledge (previous epochs share thresholds).
    pub fn new(
        proto: Proto,
        flow: u64,
        bytes: u64,
        critical: Vec<u32>,
        seed_rtprop: Nanos,
        seed_btlbw_bytes: u64,
    ) -> GatherTx {
        match proto {
            Proto::Ltp => {
                let map = SegmentMap::new(bytes, crate::grad::Manifest::aligned_payload(LTP_MSS), critical);
                let mut s = LtpSender::new(flow as u16, map, crate::wire::MTU);
                if seed_btlbw_bytes > 0 {
                    s.seed_cc(seed_rtprop, seed_btlbw_bytes);
                }
                GatherTx::Ltp(s)
            }
            Proto::Tcp(cc) => GatherTx::Tcp(TcpSender::new(flow, bytes, TCP_MSS, cc.build(TCP_MSS))),
        }
    }

    pub fn handle(&mut self, now: Nanos, pkt: &Packet) {
        match (self, &pkt.kind) {
            (GatherTx::Ltp(s), PacketKind::Ltp(hdr)) => {
                s.handle(now, LtpEvent { hdr: *hdr, payload_len: 0 })
            }
            (GatherTx::Tcp(s), PacketKind::Tcp(seg)) if seg.is_ack => s.on_ack(now, *seg),
            _ => {}
        }
    }

    /// Next packet to transmit toward `dst`, or None.
    pub fn poll(&mut self, now: Nanos, me: usize, dst: usize) -> Option<Packet> {
        match self {
            GatherTx::Ltp(s) => s.poll_transmit(now).map(|out| {
                let size = UDP_IP_OVERHEAD + HDR_BYTES as u32 + out.payload_len;
                Packet::new(me, dst, size, s.flow() as u64, PacketKind::Ltp(out.hdr))
            }),
            GatherTx::Tcp(s) => s.poll_transmit(now).map(|seg| {
                Packet::new(me, dst, seg.len + TCP_IP_OVERHEAD, s.flow, PacketKind::Tcp(seg))
            }),
        }
    }

    pub fn next_wakeup(&self) -> Option<Nanos> {
        match self {
            GatherTx::Ltp(s) => s.next_wakeup(),
            GatherTx::Tcp(s) => s.next_wakeup(),
        }
    }

    pub fn on_wakeup(&mut self, now: Nanos) {
        match self {
            GatherTx::Ltp(s) => s.on_wakeup(now),
            GatherTx::Tcp(s) => s.on_wakeup(now),
        }
    }

    pub fn is_complete(&self) -> bool {
        match self {
            GatherTx::Ltp(s) => s.is_complete(),
            GatherTx::Tcp(s) => s.is_complete(),
        }
    }

    /// LTP congestion estimates for seeding the next flow on this path.
    pub fn path_estimates(&self) -> Option<(Nanos, u64)> {
        match self {
            GatherTx::Ltp(s) => Some((s.cc.rtprop_ns(), s.cc.btlbw_bytes_per_sec())),
            GatherTx::Tcp(_) => None,
        }
    }

    /// Retransmitted packets so far on this flow (either transport).
    pub fn retransmissions(&self) -> u64 {
        match self {
            GatherTx::Ltp(s) => s.stats.retransmissions,
            GatherTx::Tcp(s) => s.stats.retransmissions,
        }
    }

    /// Packets sent so far on this flow (either transport).
    pub fn pkts_sent(&self) -> u64 {
        match self {
            GatherTx::Ltp(s) => s.stats.pkts_sent,
            GatherTx::Tcp(s) => s.stats.pkts_sent,
        }
    }
}

/// Receiving side of one flow.
pub enum GatherRx {
    Ltp { rx: LtpReceiver, total_bytes: u64 },
    Tcp { rx: TcpReceiver, total_bytes: u64 },
}

impl GatherTx {
    /// Does an incoming packet's flow tag belong to this sender? (LTP flow
    /// ids are 16-bit on the wire.)
    pub fn flow_matches(&self, f: u64) -> bool {
        match self {
            GatherTx::Ltp(s) => s.flow() as u64 == (f & 0xFFFF),
            GatherTx::Tcp(s) => s.flow == f,
        }
    }
}

impl GatherRx {
    pub fn new(proto: Proto, flow: u64, bytes: u64, ec: EarlyCloseCfg, critical: Vec<u32>) -> GatherRx {
        match proto {
            Proto::Ltp => {
                GatherRx::Ltp { rx: LtpReceiver::new(flow as u16, ec, critical), total_bytes: bytes }
            }
            Proto::Tcp(_) => GatherRx::Tcp { rx: TcpReceiver::new(flow), total_bytes: bytes },
        }
    }

    /// Does an incoming packet's flow tag belong to this receiver?
    pub fn flow_matches(&self, f: u64) -> bool {
        match self {
            GatherRx::Ltp { rx, .. } => rx.flow() as u64 == (f & 0xFFFF),
            GatherRx::Tcp { rx, .. } => rx.flow == f,
        }
    }

    /// Handle an incoming data/control packet; pushes any responses
    /// (ACKs/stops) through `out`.
    pub fn handle(&mut self, now: Nanos, pkt: &Packet, me: usize, mut out: impl FnMut(Packet)) {
        match (self, &pkt.kind) {
            (GatherRx::Ltp { rx, .. }, PacketKind::Ltp(hdr)) => {
                if hdr.ty == LtpType::Ack {
                    return;
                }
                let payload_len = pkt.size.saturating_sub(UDP_IP_OVERHEAD + HDR_BYTES as u32);
                rx.handle(now, LtpEvent { hdr: *hdr, payload_len });
                while let Some(h) = rx.poll_transmit() {
                    let size = UDP_IP_OVERHEAD + HDR_BYTES as u32;
                    out(Packet::new(me, pkt.src, size, pkt.flow, PacketKind::Ltp(h)));
                }
            }
            (GatherRx::Tcp { rx, .. }, PacketKind::Tcp(seg)) => {
                if seg.is_ack {
                    return;
                }
                let ack = rx.on_data(*seg, pkt.ecn_ce);
                out(Packet::new(me, pkt.src, TCP_IP_OVERHEAD, pkt.flow, PacketKind::Tcp(ack)));
            }
            _ => {}
        }
    }

    pub fn next_wakeup(&self, now: Nanos) -> Option<Nanos> {
        match self {
            GatherRx::Ltp { rx, .. } => rx.next_wakeup(now),
            GatherRx::Tcp { .. } => None,
        }
    }

    pub fn on_wakeup(&mut self, now: Nanos, me: usize, _out: impl FnMut(Packet)) {
        if let GatherRx::Ltp { rx, .. } = self {
            rx.on_wakeup(now);
            let _ = me;
        }
    }

    /// Drain pending control responses (after a wakeup-triggered close).
    pub fn drain(&mut self, me: usize, peer: usize, mut out: impl FnMut(Packet)) {
        if let GatherRx::Ltp { rx, .. } = self {
            let flow = rx.flow() as u64;
            while let Some(h) = rx.poll_transmit() {
                let size = UDP_IP_OVERHEAD + HDR_BYTES as u32;
                out(Packet::new(me, peer, size, flow, PacketKind::Ltp(h)));
            }
        }
    }

    pub fn is_done(&self) -> bool {
        match self {
            GatherRx::Ltp { rx, .. } => rx.is_closed(),
            GatherRx::Tcp { rx, total_bytes } => rx.bytes_received >= *total_bytes,
        }
    }

    /// Fraction of the message delivered.
    pub fn delivered_fraction(&self) -> f64 {
        match self {
            GatherRx::Ltp { rx, .. } => rx.pct_received(),
            GatherRx::Tcp { rx, total_bytes } => {
                (rx.bytes_received as f64 / *total_bytes as f64).min(1.0)
            }
        }
    }

    /// Did the receiver observe a complete (100 %) transmission? Used by
    /// the LT-threshold epoch update rule.
    pub fn reached_full(&self) -> bool {
        self.delivered_fraction() >= 1.0 - 1e-12
    }

    /// LTP close record once the flow is done: `(reason, criticals_ok,
    /// delivered fraction)`. `None` for TCP flows or before close.
    pub fn close_info(&self) -> Option<(crate::proto::CloseReason, bool, f64)> {
        match self {
            GatherRx::Ltp { rx, .. } => {
                rx.close_reason().map(|r| (r, rx.stats.criticals_ok, rx.pct_received()))
            }
            GatherRx::Tcp { .. } => None,
        }
    }

    /// Arrival bitmap (LTP) for bubble-filling; None for TCP (everything
    /// arrived).
    pub fn bitmap(&self) -> Option<&Bitmap> {
        match self {
            GatherRx::Ltp { rx, .. } => Some(rx.received_bitmap()),
            GatherRx::Tcp { .. } => None,
        }
    }

    pub fn segment_map(&self) -> Option<SegmentMap> {
        match self {
            GatherRx::Ltp { total_bytes, .. } => Some(SegmentMap::new(
                *total_bytes,
                crate::grad::Manifest::aligned_payload(LTP_MSS),
                vec![],
            )),
            GatherRx::Tcp { .. } => None,
        }
    }
}
