//! The pluggable transport layer of the PS system (DESIGN.md §Transport
//! API).
//!
//! A [`Transport`] is a named factory: it stamps out boxed [`FlowTx`] /
//! [`FlowRx`] endpoints with the uniform on-packet / poll / close surface
//! that [`super::PsNode`] and [`super::WorkerNode`] drive, so the training
//! runtime is protocol-agnostic and new protocols plug in without touching
//! PS or worker code. Concrete transports live here — LTP, TCP with a
//! chosen congestion control, and `ltp-adaptive`, a phase-aware LTP variant
//! that anneals the Early-Close percentage threshold over BSP iterations.
//! The string-keyed registry and the `key:param=value,...` spec grammar
//! that instantiate them live in [`super::spec`].

use crate::cc::CcAlgo;
use crate::proto::{CloseReason, EarlyCloseCfg, LtpEvent, LtpReceiver, LtpSender, SegmentMap};
use crate::simnet::Packet;
use crate::tcp::{TcpReceiver, TcpSender};
use crate::util::Bitmap;
use crate::wire::{
    LtpType, PacketKind, HDR_BYTES, LTP_MSS, TCP_IP_OVERHEAD, TCP_MSS, UDP_IP_OVERHEAD,
};
use crate::Nanos;

/// Everything a transport needs to open the sending side of one flow
/// (worker gather, or PS broadcast).
#[derive(Debug, Clone)]
pub struct TxCfg {
    /// Training-layer flow id (the transport may truncate it on the wire —
    /// see [`Transport::wire_flow`]).
    pub flow: u64,
    /// Message size in bytes.
    pub bytes: u64,
    /// Critical segment ids (loss-tolerant transports deliver these
    /// reliably; reliable transports deliver everything anyway).
    pub critical: Vec<u32>,
    /// Path RTprop estimate from a previous flow on this path (0 = none).
    pub seed_rtprop: Nanos,
    /// Path bottleneck-bandwidth estimate in bytes/sec (0 = none).
    pub seed_btlbw_bytes: u64,
    /// Tensor-priority transmission order for normal segments
    /// ([`crate::codec::PriorityScheduler`]); `None` keeps the sender's
    /// ascending default. Reliable transports deliver everything anyway
    /// and ignore it.
    pub nq_order: Option<Vec<u32>>,
}

/// Everything a transport needs to open the receiving side of one flow.
#[derive(Debug, Clone)]
pub struct RxCfg {
    /// Wire-visible flow id of the incoming flow.
    pub flow: u64,
    /// Expected message size in bytes.
    pub bytes: u64,
    /// Early Close configuration supplied by the application (the PS's
    /// [`crate::proto::ThresholdTracker`], or
    /// [`EarlyCloseCfg::reliable`] for the broadcast direction). Adaptive
    /// transports may refine it (`ltp-adaptive` anneals `ec.pct`).
    pub ec: EarlyCloseCfg,
    /// Critical segment ids expected on this flow.
    pub critical: Vec<u32>,
    /// BSP iteration this flow belongs to — phase-aware transports adapt
    /// their loss tolerance to the training phase.
    pub iter: u64,
}

/// Application-level knobs a protocol spec may override (e.g.
/// `ltp:pct=0.9,slack=100ms`). `None` means "use the run configuration's
/// value", so default specs leave behavior bit-for-bit unchanged.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TransportTuning {
    /// Early Close data-percentage threshold override.
    pub pct_threshold: Option<f64>,
    /// Deadline slack C override.
    pub deadline_slack: Option<Nanos>,
}

/// Sending side of one flow: the uniform surface the PS and worker nodes
/// drive, whatever the protocol underneath.
pub trait FlowTx {
    /// Does an incoming packet's flow tag belong to this sender?
    fn flow_matches(&self, f: u64) -> bool;

    /// Feed an incoming control packet (ACK/Stop) to the sender.
    fn handle(&mut self, now: Nanos, pkt: &Packet);

    /// Next packet to transmit toward `dst`, or `None`.
    fn poll(&mut self, now: Nanos, me: usize, dst: usize) -> Option<Packet>;

    /// Next instant the sender needs a timer callback, if any.
    fn next_wakeup(&self) -> Option<Nanos>;

    fn on_wakeup(&mut self, now: Nanos);

    /// The flow is over from the sender's point of view (fully acked, or
    /// stopped by the receiver).
    fn is_complete(&self) -> bool;

    /// Path congestion estimates `(rtprop, btlbw_bytes_per_sec)` for
    /// seeding the next flow on this path (loss-tolerant transports share
    /// thresholds across epochs). `None` if the transport has none.
    fn path_estimates(&self) -> Option<(Nanos, u64)> {
        None
    }

    /// Packets retransmitted so far on this flow.
    fn retransmissions(&self) -> u64;

    /// Packets sent so far on this flow.
    fn pkts_sent(&self) -> u64;
}

/// Receiving side of one flow.
pub trait FlowRx {
    /// Does an incoming packet's flow tag belong to this receiver?
    fn flow_matches(&self, f: u64) -> bool;

    /// Handle an incoming data/control packet; pushes any responses
    /// (ACKs/stops) through `out`.
    fn handle(&mut self, now: Nanos, pkt: &Packet, me: usize, out: &mut dyn FnMut(Packet));

    /// Next instant a close decision could change, if any.
    fn next_wakeup(&self, now: Nanos) -> Option<Nanos>;

    /// Timer callback (Early Close threshold checks). Pending responses
    /// are pulled afterwards with [`FlowRx::drain`].
    fn on_wakeup(&mut self, now: Nanos);

    /// Drain pending control responses (after a wakeup-triggered close).
    fn drain(&mut self, me: usize, peer: usize, out: &mut dyn FnMut(Packet));

    /// The flow closed (possibly early for loss-tolerant transports).
    fn is_done(&self) -> bool;

    /// Fraction of the message delivered.
    fn delivered_fraction(&self) -> f64;

    /// Did the receiver observe a complete (100 %) transmission? Used by
    /// the LT-threshold epoch update rule.
    fn reached_full(&self) -> bool {
        self.delivered_fraction() >= 1.0 - 1e-12
    }

    /// Close record once the flow is done: `(reason, criticals_ok,
    /// delivered fraction)`. `None` for transports without Early Close
    /// semantics, or before close.
    fn close_info(&self) -> Option<(CloseReason, bool, f64)> {
        None
    }

    /// Arrival bitmap for bubble-filling; `None` when everything arrived
    /// by construction (reliable transports).
    fn bitmap(&self) -> Option<&Bitmap> {
        None
    }

    /// Segmentation of the received message (loss-tolerant transports).
    fn segment_map(&self) -> Option<SegmentMap> {
        None
    }
}

/// A transport protocol: a named, thread-shareable factory for flow
/// endpoints. Implementations are registered under string keys in
/// `ps/spec.rs` and instantiated from CLI specs like `ltp`,
/// `ltp:pct=0.9,slack=100ms`, or `tcp:cc=cubic`.
pub trait Transport: Send + Sync {
    /// Canonical spec string — the protocol's name everywhere (report
    /// labels, JSON, bench records). Borrowed, never re-allocated.
    fn name(&self) -> &str;

    /// Whether gathers over this transport may close before 100 % of the
    /// data arrived (drives Early Close threshold tracking on the PS).
    fn is_loss_tolerant(&self) -> bool;

    /// The wire-visible form of a training-layer flow id (LTP flow ids are
    /// 16-bit on the wire; byte-stream transports keep the full id).
    fn wire_flow(&self, flow: u64) -> u64 {
        flow
    }

    /// Spec-level overrides of run-configuration knobs.
    fn tuning(&self) -> TransportTuning {
        TransportTuning::default()
    }

    /// Open the sending side of one flow.
    fn make_tx(&self, cfg: TxCfg) -> Box<dyn FlowTx>;

    /// Open the receiving side of one flow.
    fn make_rx(&self, cfg: RxCfg) -> Box<dyn FlowRx>;
}

// ---------------------------------------------------------------------------
// LTP flows.
// ---------------------------------------------------------------------------

struct LtpFlowTx {
    s: LtpSender,
}

impl LtpFlowTx {
    fn open(cfg: TxCfg) -> Box<dyn FlowTx> {
        let map = SegmentMap::new(
            cfg.bytes,
            crate::grad::Manifest::aligned_payload(LTP_MSS),
            cfg.critical,
        );
        let mut s = LtpSender::new(cfg.flow as u16, map, crate::wire::MTU);
        if cfg.seed_btlbw_bytes > 0 {
            s.seed_cc(cfg.seed_rtprop, cfg.seed_btlbw_bytes);
        }
        if let Some(order) = &cfg.nq_order {
            s.set_nq_order(order);
        }
        Box::new(LtpFlowTx { s })
    }
}

impl FlowTx for LtpFlowTx {
    fn flow_matches(&self, f: u64) -> bool {
        self.s.flow() as u64 == (f & 0xFFFF)
    }

    fn handle(&mut self, now: Nanos, pkt: &Packet) {
        if let PacketKind::Ltp(hdr) = &pkt.kind {
            self.s.handle(now, LtpEvent { hdr: *hdr, payload_len: 0 });
        }
    }

    fn poll(&mut self, now: Nanos, me: usize, dst: usize) -> Option<Packet> {
        self.s.poll_transmit(now).map(|out| {
            let size = UDP_IP_OVERHEAD + HDR_BYTES as u32 + out.payload_len;
            Packet::new(me, dst, size, self.s.flow() as u64, PacketKind::Ltp(out.hdr))
        })
    }

    fn next_wakeup(&self) -> Option<Nanos> {
        self.s.next_wakeup()
    }

    fn on_wakeup(&mut self, now: Nanos) {
        self.s.on_wakeup(now);
    }

    fn is_complete(&self) -> bool {
        self.s.is_complete()
    }

    fn path_estimates(&self) -> Option<(Nanos, u64)> {
        Some((self.s.cc.rtprop_ns(), self.s.cc.btlbw_bytes_per_sec()))
    }

    fn retransmissions(&self) -> u64 {
        self.s.stats.retransmissions
    }

    fn pkts_sent(&self) -> u64 {
        self.s.stats.pkts_sent
    }
}

struct LtpFlowRx {
    rx: LtpReceiver,
    total_bytes: u64,
}

impl LtpFlowRx {
    fn open(cfg: RxCfg) -> Box<dyn FlowRx> {
        Box::new(LtpFlowRx {
            rx: LtpReceiver::new(cfg.flow as u16, cfg.ec, cfg.critical),
            total_bytes: cfg.bytes,
        })
    }
}

impl FlowRx for LtpFlowRx {
    fn flow_matches(&self, f: u64) -> bool {
        self.rx.flow() as u64 == (f & 0xFFFF)
    }

    fn handle(&mut self, now: Nanos, pkt: &Packet, me: usize, out: &mut dyn FnMut(Packet)) {
        let PacketKind::Ltp(hdr) = &pkt.kind else { return };
        if hdr.ty == LtpType::Ack {
            return;
        }
        let payload_len = pkt.size.saturating_sub(UDP_IP_OVERHEAD + HDR_BYTES as u32);
        self.rx.handle(now, LtpEvent { hdr: *hdr, payload_len });
        while let Some(h) = self.rx.poll_transmit() {
            let size = UDP_IP_OVERHEAD + HDR_BYTES as u32;
            out(Packet::new(me, pkt.src, size, pkt.flow, PacketKind::Ltp(h)));
        }
    }

    fn next_wakeup(&self, now: Nanos) -> Option<Nanos> {
        self.rx.next_wakeup(now)
    }

    fn on_wakeup(&mut self, now: Nanos) {
        self.rx.on_wakeup(now);
    }

    fn drain(&mut self, me: usize, peer: usize, out: &mut dyn FnMut(Packet)) {
        let flow = self.rx.flow() as u64;
        while let Some(h) = self.rx.poll_transmit() {
            let size = UDP_IP_OVERHEAD + HDR_BYTES as u32;
            out(Packet::new(me, peer, size, flow, PacketKind::Ltp(h)));
        }
    }

    fn is_done(&self) -> bool {
        self.rx.is_closed()
    }

    fn delivered_fraction(&self) -> f64 {
        self.rx.pct_received()
    }

    fn close_info(&self) -> Option<(CloseReason, bool, f64)> {
        self.rx
            .close_reason()
            .map(|r| (r, self.rx.stats.criticals_ok, self.rx.pct_received()))
    }

    fn bitmap(&self) -> Option<&Bitmap> {
        Some(self.rx.received_bitmap())
    }

    fn segment_map(&self) -> Option<SegmentMap> {
        Some(SegmentMap::new(
            self.total_bytes,
            crate::grad::Manifest::aligned_payload(LTP_MSS),
            vec![],
        ))
    }
}

// ---------------------------------------------------------------------------
// TCP flows.
// ---------------------------------------------------------------------------

struct TcpFlowTx {
    s: TcpSender,
}

impl FlowTx for TcpFlowTx {
    fn flow_matches(&self, f: u64) -> bool {
        self.s.flow == f
    }

    fn handle(&mut self, now: Nanos, pkt: &Packet) {
        if let PacketKind::Tcp(seg) = &pkt.kind {
            if seg.is_ack {
                self.s.on_ack(now, *seg);
            }
        }
    }

    fn poll(&mut self, now: Nanos, me: usize, dst: usize) -> Option<Packet> {
        self.s.poll_transmit(now).map(|seg| {
            Packet::new(me, dst, seg.len + TCP_IP_OVERHEAD, self.s.flow, PacketKind::Tcp(seg))
        })
    }

    fn next_wakeup(&self) -> Option<Nanos> {
        self.s.next_wakeup()
    }

    fn on_wakeup(&mut self, now: Nanos) {
        self.s.on_wakeup(now);
    }

    fn is_complete(&self) -> bool {
        self.s.is_complete()
    }

    fn retransmissions(&self) -> u64 {
        self.s.stats.retransmissions
    }

    fn pkts_sent(&self) -> u64 {
        self.s.stats.pkts_sent
    }
}

struct TcpFlowRx {
    rx: TcpReceiver,
    total_bytes: u64,
}

impl FlowRx for TcpFlowRx {
    fn flow_matches(&self, f: u64) -> bool {
        self.rx.flow == f
    }

    fn handle(&mut self, now: Nanos, pkt: &Packet, me: usize, out: &mut dyn FnMut(Packet)) {
        let _ = now;
        let PacketKind::Tcp(seg) = &pkt.kind else { return };
        if seg.is_ack {
            return;
        }
        let ack = self.rx.on_data(*seg, pkt.ecn_ce);
        out(Packet::new(me, pkt.src, TCP_IP_OVERHEAD, pkt.flow, PacketKind::Tcp(ack)));
    }

    fn next_wakeup(&self, _now: Nanos) -> Option<Nanos> {
        None
    }

    fn on_wakeup(&mut self, _now: Nanos) {}

    fn drain(&mut self, _me: usize, _peer: usize, _out: &mut dyn FnMut(Packet)) {}

    fn is_done(&self) -> bool {
        self.rx.bytes_received >= self.total_bytes
    }

    fn delivered_fraction(&self) -> f64 {
        (self.rx.bytes_received as f64 / self.total_bytes as f64).min(1.0)
    }
}

// ---------------------------------------------------------------------------
// Concrete transports.
// ---------------------------------------------------------------------------

/// LTP with optional spec-level overrides of the Early Close knobs
/// (`ltp:pct=0.9,slack=100ms`).
pub(super) struct LtpTransport {
    pub(super) pct: Option<f64>,
    pub(super) slack: Option<Nanos>,
    pub(super) spec: String,
}

impl Transport for LtpTransport {
    fn name(&self) -> &str {
        &self.spec
    }

    fn is_loss_tolerant(&self) -> bool {
        true
    }

    fn wire_flow(&self, flow: u64) -> u64 {
        flow & 0xFFFF // 16-bit on the LTP wire
    }

    fn tuning(&self) -> TransportTuning {
        TransportTuning { pct_threshold: self.pct, deadline_slack: self.slack }
    }

    fn make_tx(&self, cfg: TxCfg) -> Box<dyn FlowTx> {
        LtpFlowTx::open(cfg)
    }

    fn make_rx(&self, cfg: RxCfg) -> Box<dyn FlowRx> {
        LtpFlowRx::open(cfg)
    }
}

/// Phase-aware LTP (`ltp-adaptive`): anneals the Early-Close percentage
/// threshold linearly from `start` to `end` over the first `over` BSP
/// iterations — tolerate more loss while gradients are coarse, demand more
/// data as training refines (the DBLP-style per-phase bounded-loss rule).
/// Ships entirely through the [`Transport`] API: no PS or worker code knows
/// it exists.
pub(super) struct LtpAdaptiveTransport {
    pub(super) start: f64,
    pub(super) end: f64,
    pub(super) over: u64,
    pub(super) slack: Option<Nanos>,
    pub(super) spec: String,
}

impl LtpAdaptiveTransport {
    /// Annealed Early-Close percentage for BSP iteration `iter`.
    pub(super) fn pct_at(&self, iter: u64) -> f64 {
        let t = iter.min(self.over) as f64 / self.over as f64;
        self.start + (self.end - self.start) * t
    }
}

impl Transport for LtpAdaptiveTransport {
    fn name(&self) -> &str {
        &self.spec
    }

    fn is_loss_tolerant(&self) -> bool {
        true
    }

    fn wire_flow(&self, flow: u64) -> u64 {
        flow & 0xFFFF
    }

    fn tuning(&self) -> TransportTuning {
        TransportTuning { pct_threshold: None, deadline_slack: self.slack }
    }

    fn make_tx(&self, cfg: TxCfg) -> Box<dyn FlowTx> {
        LtpFlowTx::open(cfg)
    }

    fn make_rx(&self, mut cfg: RxCfg) -> Box<dyn FlowRx> {
        // Only loss-tolerant flows anneal: the reliable broadcast direction
        // (and iteration-0 gathers, still bootstrapping thresholds) keep
        // their caller-supplied configuration.
        if cfg.ec.is_loss_tolerant() {
            cfg.ec.pct = self.pct_at(cfg.iter);
        }
        LtpFlowRx::open(cfg)
    }
}

/// Reliable byte-stream transport with a chosen congestion control — the
/// kernel-TCP baselines the paper compares against.
pub(super) struct TcpTransport {
    pub(super) cc: CcAlgo,
    pub(super) spec: String,
}

impl Transport for TcpTransport {
    fn name(&self) -> &str {
        &self.spec
    }

    fn is_loss_tolerant(&self) -> bool {
        false
    }

    fn make_tx(&self, cfg: TxCfg) -> Box<dyn FlowTx> {
        Box::new(TcpFlowTx {
            s: TcpSender::new(cfg.flow, cfg.bytes, TCP_MSS, self.cc.build(TCP_MSS)),
        })
    }

    fn make_rx(&self, cfg: RxCfg) -> Box<dyn FlowRx> {
        Box::new(TcpFlowRx { rx: TcpReceiver::new(cfg.flow), total_bytes: cfg.bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::MS;

    #[test]
    fn adaptive_pct_anneals_linearly_and_saturates() {
        let t = LtpAdaptiveTransport {
            start: 0.7,
            end: 0.95,
            over: 10,
            slack: None,
            spec: "ltp-adaptive".to_string(),
        };
        assert!((t.pct_at(0) - 0.7).abs() < 1e-12);
        assert!((t.pct_at(5) - 0.825).abs() < 1e-12);
        assert!((t.pct_at(10) - 0.95).abs() < 1e-12);
        assert!((t.pct_at(1000) - 0.95).abs() < 1e-12, "holds at `end` past `over`");
    }

    #[test]
    fn adaptive_leaves_reliable_flows_reliable() {
        let t = LtpAdaptiveTransport {
            start: 0.7,
            end: 0.95,
            over: 10,
            slack: None,
            spec: "ltp-adaptive".to_string(),
        };
        // A reliable (broadcast-direction) receiver must not early-close
        // even late in training.
        let rx = t.make_rx(RxCfg {
            flow: 1,
            bytes: 100_000,
            ec: EarlyCloseCfg::reliable(),
            critical: vec![],
            iter: 50,
        });
        assert!(rx.next_wakeup(0).is_none(), "reliable flows schedule no close checks");
    }

    #[test]
    fn wire_flow_masks_only_for_ltp() {
        let ltp = LtpTransport { pct: None, slack: None, spec: "ltp".to_string() };
        let tcp = TcpTransport { cc: CcAlgo::Reno, spec: "reno".to_string() };
        assert_eq!(ltp.wire_flow(0x1_0005), 5);
        assert_eq!(tcp.wire_flow(0x1_0005), 0x1_0005);
    }

    #[test]
    fn tuning_defaults_are_inert() {
        let ltp = LtpTransport { pct: None, slack: None, spec: "ltp".to_string() };
        assert_eq!(ltp.tuning(), TransportTuning::default());
        let tuned = LtpTransport { pct: Some(0.9), slack: Some(100 * MS), spec: String::new() };
        assert_eq!(tuned.tuning().pct_threshold, Some(0.9));
        assert_eq!(tuned.tuning().deadline_slack, Some(100 * MS));
    }
}
