//! `ltp` — CLI entrypoint for the LTP reproduction.
//!
//! ```text
//! ltp scenario <name|list|all> [--json] [--seed N] [--quick]
//! ltp figure <fig2|fig3|fig4|fig5|fig12|fig13|fig14|fig15|all> [--quick]
//! ltp train [--preset tiny] [--workers 4] [--iters 50] [--loss 0.01]
//!           [--proto ltp|bbr|cubic|reno]
//! ltp bench-ltp [--bytes N] [--loss P]      one-flow protocol microbench
//! ```
//!
//! (Hand-rolled argument parsing: the vendored dependency set has no clap.)

use anyhow::{bail, Context, Result};
use ltp::cc::CcAlgo;
use ltp::ps::{run_with, Corpus, Proto, RealCompute, RealTraining, TrainingCfg, XlaAggregate};
use ltp::simnet::LossModel;
use ltp::{MS, SEC};

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::HashMap::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let val = if it.peek().map(|v| !v.starts_with("--")).unwrap_or(false) {
                it.next().unwrap()
            } else {
                "true".to_string()
            };
            flags.insert(name.to_string(), val);
        } else {
            positional.push(a);
        }
    }
    Args { positional, flags }
}

impl Args {
    fn flag<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{name} {v}: {e}")),
        }
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

fn proto_of(name: &str) -> Result<Proto> {
    Ok(match name {
        "ltp" => Proto::Ltp,
        other => Proto::Tcp(other.parse::<CcAlgo>().map_err(|e| anyhow::anyhow!(e))?),
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    let preset: String = args.flag("preset", "tiny".to_string())?;
    let workers: usize = args.flag("workers", 4)?;
    let iters: u64 = args.flag("iters", 50)?;
    let loss: f64 = args.flag("loss", 0.0)?;
    let lr: f32 = args.flag("lr", 0.08)?;
    let proto = proto_of(&args.flag("proto", "ltp".to_string())?)?;

    let rt = ltp::runtime::Runtime::cpu(ltp::runtime::default_artifacts_dir())
        .context("PJRT CPU client")?;
    println!("platform: {}", rt.platform());
    let shared = RealTraining::new(&rt, &preset, lr)?;
    println!(
        "model: preset={} params={} ({} on the wire/iteration)",
        preset,
        shared.manifest.param_count,
        ltp::util::fmt_bytes(shared.manifest.wire_bytes()),
    );
    let mut cfg = TrainingCfg::modeled(proto, ltp::config::Workload::Micro, workers);
    cfg.model_bytes = shared.manifest.wire_bytes();
    cfg.critical = shared
        .manifest
        .tensors
        .critical_segments(ltp::grad::Manifest::aligned_payload(ltp::wire::LTP_MSS));
    cfg.iters = iters;
    cfg.compute_time = 50 * MS;
    if loss > 0.0 {
        cfg.link = cfg.link.with_loss(LossModel::Bernoulli { p: loss });
    }
    cfg.horizon = 24 * 3600 * SEC;

    let shared2 = shared.clone();
    let t0 = std::time::Instant::now();
    let report = run_with(
        &cfg,
        move |w, _| {
            Box::new(RealCompute {
                shared: shared2.clone(),
                corpus: Corpus::new(shared2.manifest.vocab, 42 + w as u64),
            })
        },
        Box::new(XlaAggregate { shared: shared.clone(), n_workers: workers }),
    );
    println!("\n iter |   loss | BST(ms) | delivered | sim t(s)");
    for (i, it) in report.iters.iter().enumerate() {
        println!(
            " {:>4} | {:>6} | {:>7.2} | {:>8.1}% | {:>7.2}",
            i,
            it.loss.map(|l| format!("{l:.3}")).unwrap_or_else(|| "—".into()),
            it.bst as f64 / MS as f64,
            it.mean_delivered * 100.0,
            it.end as f64 / SEC as f64,
        );
    }
    println!(
        "\ncompleted {}/{} iterations | proto={} | loss rate {:.2}% | wall {:.1}s",
        report.iters.len(),
        iters,
        report.proto,
        loss * 100.0,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_bench_ltp(args: &Args) -> Result<()> {
    let bytes: u64 = args.flag("bytes", 10_000_000)?;
    let loss: f64 = args.flag("loss", 0.01)?;
    let cfg = ltp::simnet::LinkCfg::dcn(10, 50).with_loss(LossModel::Bernoulli { p: loss });
    let ec = ltp::proto::EarlyCloseCfg { lt_threshold: 10 * MS, deadline: 100 * MS, pct: 0.8 };
    let t0 = std::time::Instant::now();
    let (s, r) = ltp::proto::run_single_flow(bytes, vec![0], cfg, ec, 1, 60 * SEC);
    println!(
        "flow {} over 10G/50µs @ {:.2}% loss: close={:?} pct={:.2}% elapsed={} pkts={} retx={} wall={:?}",
        ltp::util::fmt_bytes(bytes),
        loss * 100.0,
        r.reason,
        r.pct_at_close * 100.0,
        ltp::util::fmt_nanos(r.elapsed),
        s.pkts_sent,
        s.retransmissions,
        t0.elapsed()
    );
    Ok(())
}

fn cmd_scenario(args: &Args) -> Result<()> {
    use ltp::scenarios::{self, ScenarioParams};
    let which = args.positional.get(1).map(String::as_str).unwrap_or("list");
    let params = ScenarioParams { seed: args.flag("seed", 1)?, quick: args.has("quick") };
    let json = args.has("json");
    let emit = |report: &ltp::scenarios::ScenarioReport| {
        if json {
            println!("{}", report.render_json());
        } else {
            report.print_table();
        }
    };
    match which {
        "list" => {
            println!("registered scenarios (run with `ltp scenario <name|all> [--json]`):\n");
            for s in scenarios::registry() {
                println!(
                    "  {:<18} {}{}",
                    s.name,
                    s.summary,
                    if s.incast_class { "  [incast-class]" } else { "" }
                );
            }
            Ok(())
        }
        "all" => {
            if json {
                // One well-formed JSON document: an array of reports.
                let arr = ltp::metrics::Json::Arr(
                    scenarios::registry().iter().map(|s| s.run(&params).to_json()).collect(),
                );
                println!("{}", arr.render_pretty());
            } else {
                for s in scenarios::registry() {
                    emit(&s.run(&params));
                }
            }
            Ok(())
        }
        name => match scenarios::find(name) {
            Some(s) => {
                emit(&s.run(&params));
                Ok(())
            }
            None => {
                let names: Vec<&str> =
                    scenarios::registry().iter().map(|s| s.name).collect();
                bail!("unknown scenario `{name}` (known: {})", names.join(", "));
            }
        },
    }
}

fn main() -> Result<()> {
    let args = parse_args();
    match args.positional.first().map(String::as_str) {
        Some("scenario") => cmd_scenario(&args),
        Some("figure") => {
            let which = args.positional.get(1).map(String::as_str).unwrap_or("all");
            ltp::figures::run(which, args.has("quick"))
        }
        Some("train") => cmd_train(&args),
        Some("bench-ltp") => cmd_bench_ltp(&args),
        _ => {
            eprintln!(
                "usage:\n  ltp scenario <name|list|all> [--json] [--seed N] [--quick]\n  \
                 ltp figure <fig2|fig3|fig4|fig5|fig12|fig13|fig14|fig15|all> [--quick]\n  \
                 ltp train [--preset tiny] [--workers N] [--iters N] [--loss P] [--proto ltp|bbr|cubic|reno]\n  \
                 ltp bench-ltp [--bytes N] [--loss P]"
            );
            bail!("missing or unknown subcommand");
        }
    }
}
