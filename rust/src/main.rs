//! `ltp` — CLI entrypoint for the LTP reproduction.
//!
//! ```text
//! ltp scenario <name|list|all> [--json] [--seed N | --seeds A..B] [--quick]
//!              [--jobs N] [--out FILE] [--bench [FILE]] [--proto SPEC]...
//!              [--agg SPEC]... [--codec SPEC]... [--churn SPEC]...
//! ltp figure <fig2|fig3|fig4|fig5|fig12|fig13|fig14|fig15|all> [--quick] [--jobs N]
//! ltp trace <scenario> --out FILE [--seed N | --seeds A..B] [--quick] [--jobs N]
//!           [--bench FILE]
//! ltp replay <trace> [--out FILE] [--breakdown [FILE]] [--stats [FILE]]
//!            [--viz FILE.svg|FILE.html] [--sim N]
//! ltp diff <a.trace> <b.trace> [--top K] [--json] [--out FILE]
//! ltp proto <list|parse SPEC>               protocol registry / spec grammar
//! ltp agg <list|parse SPEC>                 aggregation-topology registry
//! ltp backend <list|parse SPEC>             compute-backend registry
//! ltp codec <list|parse SPEC>               gradient-codec registry
//! ltp churn <list|parse SPEC>               churn-plane registry
//! ltp train [--backend native] [--workers 4] [--iters 50] [--loss 0.01]
//!           [--proto SPEC] [--agg SPEC] [--codec SPEC] [--churn SPEC]
//!           [--max-loss X]
//! ltp bench check --baseline FILE --current FILE [--scenario NAME|all]
//!                 [--max-regress-pct P]     CI events/sec regression gate
//! ltp bench-ltp [--bytes N] [--loss P]      one-flow protocol microbench
//! ```
//!
//! Protocol specs follow the registry grammar (`ltp proto list`):
//! `ltp`, `ltp:pct=0.9,slack=100ms`, `ltp-adaptive`, `tcp:cc=cubic`, …
//! Aggregation specs use the same grammar (`ltp agg list`): `ps`,
//! `sharded:n=4`, `hier:racks=2`. Compute backends too (`ltp backend
//! list`): `native`, `native:dim=64,fill=off`, `xla:preset=tiny`. And
//! gradient codecs (`ltp codec list`): `dense`, `topk:pct=0.1`,
//! `threshold:t=0.01,priority=on`. And churn specs (`ltp churn list`):
//! `none`, `churn:rate=0.1,flap=2`, `churn:rate=0,stragglers=0.25,ge=on`.
//!
//! (Hand-rolled argument parsing: the vendored dependency set has no clap.)

use anyhow::{bail, Context, Result};
use ltp::churn::{churn_registry, parse_churn, ChurnSpec};
use ltp::codec::{codec_registry, parse_codec, CodecSpec};
use ltp::compute::{backend_registry, parse_backend};
use ltp::ps::{
    agg_registry, parse_agg, parse_proto, proto_registry, run_training, AggSpec, ProtoSpec,
    RunBuilder,
};
use ltp::simnet::LossModel;
use ltp::{MS, SEC};

struct Args {
    positional: Vec<String>,
    /// Flags in command-line order; repeatable flags (`--proto`) keep every
    /// occurrence, single-valued lookups take the last.
    flags: Vec<(String, String)>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = Vec::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let val = if it.peek().map(|v| !v.starts_with("--")).unwrap_or(false) {
                it.next().unwrap()
            } else {
                "true".to_string()
            };
            flags.push((name.to_string(), val));
        } else {
            positional.push(a);
        }
    }
    Args { positional, flags }
}

impl Args {
    /// Last occurrence of `--name`, if any.
    fn get(&self, name: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    /// Every occurrence of `--name`, in order.
    fn all(&self, name: &str) -> Vec<&str> {
        self.flags.iter().filter(|(n, _)| n == name).map(|(_, v)| v.as_str()).collect()
    }

    fn flag<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{name} {v}: {e}")),
        }
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    /// Parse every `--proto SPEC` against the protocol registry; `None`
    /// when the flag was not given.
    fn protos(&self) -> Result<Option<Vec<ProtoSpec>>> {
        let specs = self.all("proto");
        if specs.is_empty() {
            return Ok(None);
        }
        let mut out = Vec::with_capacity(specs.len());
        for s in specs {
            anyhow::ensure!(s != "true", "--proto requires a spec (see `ltp proto list`)");
            out.push(parse_proto(s).with_context(|| format!("--proto {s}"))?);
        }
        Ok(Some(out))
    }

    /// Parse every `--agg SPEC` against the aggregation registry; `None`
    /// when the flag was not given.
    fn aggs(&self) -> Result<Option<Vec<AggSpec>>> {
        let specs = self.all("agg");
        if specs.is_empty() {
            return Ok(None);
        }
        let mut out = Vec::with_capacity(specs.len());
        for s in specs {
            anyhow::ensure!(s != "true", "--agg requires a spec (see `ltp agg list`)");
            out.push(parse_agg(s).with_context(|| format!("--agg {s}"))?);
        }
        Ok(Some(out))
    }

    /// Parse every `--codec SPEC` against the gradient-codec registry;
    /// `None` when the flag was not given.
    fn codecs(&self) -> Result<Option<Vec<CodecSpec>>> {
        let specs = self.all("codec");
        if specs.is_empty() {
            return Ok(None);
        }
        let mut out = Vec::with_capacity(specs.len());
        for s in specs {
            anyhow::ensure!(s != "true", "--codec requires a spec (see `ltp codec list`)");
            out.push(parse_codec(s).with_context(|| format!("--codec {s}"))?);
        }
        Ok(Some(out))
    }

    /// Parse every `--churn SPEC` against the churn registry; `None` when
    /// the flag was not given.
    fn churns(&self) -> Result<Option<Vec<ChurnSpec>>> {
        let specs = self.all("churn");
        if specs.is_empty() {
            return Ok(None);
        }
        let mut out = Vec::with_capacity(specs.len());
        for s in specs {
            anyhow::ensure!(s != "true", "--churn requires a spec (see `ltp churn list`)");
            out.push(parse_churn(s).with_context(|| format!("--churn {s}"))?);
        }
        Ok(Some(out))
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    // These pre-compute-plane flags moved into the backend spec; reject
    // them loudly rather than silently training something else.
    anyhow::ensure!(
        !args.has("preset"),
        "--preset moved into the backend spec: use `--backend xla:preset=<name>`"
    );
    anyhow::ensure!(
        !args.has("lr"),
        "--lr moved into the backend spec: use `--backend native:lr=<rate>` \
         (or `xla:lr=<rate>`)"
    );
    let workers: usize = args.flag("workers", 4)?;
    let iters: u64 = args.flag("iters", 50)?;
    let loss: f64 = args.flag("loss", 0.0)?;
    let proto = parse_proto(&args.flag("proto", "ltp".to_string())?)?;
    let agg = parse_agg(&args.flag("agg", "ps".to_string())?)?;
    let codec = parse_codec(&args.flag("codec", "dense".to_string())?)?;
    let churn = parse_churn(&args.flag("churn", "none".to_string())?)?;
    // The compute backend (DESIGN.md §1.3). `native` is the default: it
    // needs no artifacts, so `ltp train` works out of the box; `--backend
    // xla[:preset=..]` selects the PJRT path and fails fast with the
    // artifacts message when `make artifacts` has not run.
    let backend_spec: String = args.flag("backend", "native".to_string())?;
    anyhow::ensure!(
        backend_spec != "true",
        "--backend requires a spec (see `ltp backend list`)"
    );
    let backend = parse_backend(&backend_spec)?;
    // Optional CI assertion: fail (exit non-zero) unless the final eval
    // loss lands at or below the bound.
    let max_loss: f64 = args.flag("max-loss", f64::INFINITY)?;

    let info = backend.model().map_err(|e| e.context(format!("backend `{}`", backend.name())))?;
    println!(
        "backend: {} ({} on the wire/iteration)",
        backend.name(),
        ltp::util::fmt_bytes(info.wire_bytes),
    );
    let mut b = RunBuilder::modeled(proto, ltp::config::Workload::Micro, workers)
        .backend(backend.clone())
        .iters(iters)
        .compute_time(50 * MS)
        .horizon(24 * 3600 * SEC)
        .agg(agg)
        .codec(codec)
        .churn(churn);
    if loss > 0.0 {
        b = b.loss(LossModel::Bernoulli { p: loss });
    }
    let cfg = b.build()?;

    let t0 = std::time::Instant::now();
    let report = run_training(&cfg);
    println!("\n iter |   loss | BST(ms) | delivered | sim t(s)");
    for (i, it) in report.iters.iter().enumerate() {
        println!(
            " {:>4} | {:>6} | {:>7.2} | {:>8.1}% | {:>7.2}",
            i,
            it.loss.map(|l| format!("{l:.3}")).unwrap_or_else(|| "—".into()),
            it.bst as f64 / MS as f64,
            it.mean_delivered * 100.0,
            it.end as f64 / SEC as f64,
        );
    }
    let train = report.train.expect("a backend is always attached to `ltp train`");
    if report.codec != "dense" {
        println!(
            "\ncodec: {} | gather bytes on wire {} | mean delivered importance {}",
            report.codec,
            ltp::util::fmt_bytes(report.gather_wire_bytes),
            report
                .mean_importance
                .map(|i| format!("{i:.4}"))
                .unwrap_or_else(|| "—".to_string()),
        );
    }
    if report.churn != "none" {
        println!(
            "\nchurn: {} | active workers {}..{} of {workers} per iteration",
            report.churn, report.active_min, report.active_max,
        );
    }
    println!(
        "\ncompleted {}/{} iterations | proto={} | loss rate {:.2}% | wall {:.1}s",
        report.iters.len(),
        iters,
        report.proto,
        loss * 100.0,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "train: final eval loss {:.4} | accuracy {:.2}% | iters-to-target {}",
        train.final_loss,
        train.accuracy * 100.0,
        train
            .iters_to_target
            .map(|n| n.to_string())
            .unwrap_or_else(|| "—".to_string()),
    );
    anyhow::ensure!(
        (train.final_loss as f64) <= max_loss,
        "final eval loss {:.4} exceeds --max-loss {max_loss}",
        train.final_loss
    );
    Ok(())
}

fn cmd_bench_ltp(args: &Args) -> Result<()> {
    let bytes: u64 = args.flag("bytes", 10_000_000)?;
    let loss: f64 = args.flag("loss", 0.01)?;
    let cfg = ltp::simnet::LinkCfg::dcn(10, 50).with_loss(LossModel::Bernoulli { p: loss });
    let ec = ltp::proto::EarlyCloseCfg { lt_threshold: 10 * MS, deadline: 100 * MS, pct: 0.8 };
    let t0 = std::time::Instant::now();
    let (s, r) = ltp::proto::run_single_flow(bytes, vec![0], cfg, ec, 1, 60 * SEC);
    println!(
        "flow {} over 10G/50µs @ {:.2}% loss: close={:?} pct={:.2}% elapsed={} pkts={} retx={} wall={:?}",
        ltp::util::fmt_bytes(bytes),
        loss * 100.0,
        r.reason,
        r.pct_at_close * 100.0,
        ltp::util::fmt_nanos(r.elapsed),
        s.pkts_sent,
        s.retransmissions,
        t0.elapsed()
    );
    Ok(())
}

/// Seeds to sweep: `--seeds A..B` (inclusive; `A..=B` also accepted) or a
/// single `--seed N` (default 1).
fn parse_seeds(args: &Args) -> Result<Vec<u64>> {
    match args.get("seeds") {
        None => Ok(vec![args.flag("seed", 1)?]),
        Some(spec) => {
            anyhow::ensure!(
                !args.has("seed"),
                "--seed conflicts with --seeds {spec}; pass exactly one"
            );
            let (a, b) = match spec.split_once("..") {
                Some((a, b)) => (a, b.strip_prefix('=').unwrap_or(b)),
                None => (spec, spec),
            };
            let lo: u64 =
                a.trim().parse().map_err(|e| anyhow::anyhow!("--seeds {spec}: {e}"))?;
            let hi: u64 =
                b.trim().parse().map_err(|e| anyhow::anyhow!("--seeds {spec}: {e}"))?;
            anyhow::ensure!(lo <= hi, "--seeds {spec}: empty range (need A <= B)");
            anyhow::ensure!(hi - lo < 4096, "--seeds {spec}: range too large (max 4096)");
            Ok((lo..=hi).collect())
        }
    }
}

fn cmd_scenario(args: &Args) -> Result<()> {
    use ltp::scenarios::{self, sweep};
    let which = args.positional.get(1).map(String::as_str).unwrap_or("list");
    // Validate the report/bench flags up front — a flag mistake must fail
    // instantly, not after a multi-minute sweep (and a bare `--bench`
    // placed before the scenario name must not swallow it silently).
    let json = args.has("json");
    let out_path = args.get("out").map(str::to_string);
    if let Some(p) = &out_path {
        // The hand-rolled parser maps a bare flag to "true" — reject it
        // rather than write the report to a file literally named `true`.
        anyhow::ensure!(p != "true", "--out requires a file path");
        anyhow::ensure!(json, "--out writes the machine-readable report; pass --json too");
    }
    let bench_path = match args.get("bench") {
        None => None,
        // Bare `--bench` picks the conventional artifact name.
        Some("true") => Some("BENCH_scenarios.json".to_string()),
        Some(v) if v.ends_with(".json") => Some(v.to_string()),
        Some(v) => bail!(
            "--bench {v}: expected a .json path (bare --bench writes BENCH_scenarios.json)"
        ),
    };
    // Protocol, aggregation, codec, and churn specs fail fast too, before
    // any simulation runs.
    let protos = args.protos()?;
    let aggs = args.aggs()?;
    let codecs = args.codecs()?;
    let churns = args.churns()?;
    if which == "list" {
        println!("registered scenarios (run with `ltp scenario <name|all> [--json]`):\n");
        for s in scenarios::registry() {
            println!(
                "  {:<18} {}{}",
                s.name,
                s.summary,
                if s.incast_class { "  [incast-class]" } else { "" }
            );
        }
        return Ok(());
    }
    let n_jobs: usize = args.flag("jobs", 1)?;
    let seeds = parse_seeds(args)?;
    let indices: Vec<usize> = if which == "all" {
        (0..scenarios::registry().len()).collect()
    } else {
        match scenarios::registry().iter().position(|s| s.name == which) {
            Some(i) => vec![i],
            None => {
                let names: Vec<&str> =
                    scenarios::registry().iter().map(|s| s.name).collect();
                bail!("unknown scenario `{which}` (known: {})", names.join(", "));
            }
        }
    };
    let jobs =
        sweep::sweep_jobs(&indices, &seeds, args.has("quick"), protos, aggs, codecs, churns);
    let result = sweep::run_sweep(jobs, n_jobs);
    // A scenario skips (agg, degree) combinations its aggregations
    // reject; if that leaves a report empty, say so rather than emit a
    // silent `cases: []` (stderr, so the JSON byte contract holds).
    for r in &result.reports {
        if r.cases.is_empty() {
            eprintln!(
                "warning: scenario `{}` produced no cases — no --agg/--proto spec was \
                 compatible with its worker degrees (see `ltp agg list`)",
                r.name
            );
        }
    }
    if let Some(path) = &out_path {
        std::fs::write(path, result.render_json())
            .with_context(|| format!("writing {path}"))?;
        eprintln!("wrote {path} ({} report(s))", result.reports.len());
    } else if json {
        println!("{}", result.render_json());
    } else {
        for r in &result.reports {
            r.print_table();
        }
    }
    if let Some(path) = &bench_path {
        std::fs::write(path, result.bench.render_json())
            .with_context(|| format!("writing {path}"))?;
        let b = &result.bench;
        eprintln!(
            "bench: {} job(s) on {} worker(s) in {:.2}s ({:.1}x vs serial) -> {path}",
            b.per_job.len(),
            b.n_jobs,
            b.wall_secs,
            if b.wall_secs > 0.0 { b.cpu_secs / b.wall_secs } else { 1.0 },
        );
    }
    Ok(())
}

/// `ltp trace <scenario>` — run a named scenario sweep under trace
/// capture and write the deterministic packet/event trace (`ltp replay`
/// re-drives it; `tests/trace.rs` and the CI `trace-determinism` job
/// hold the byte contracts).
fn cmd_trace(args: &Args) -> Result<()> {
    use ltp::scenarios::{self, sweep};
    let usage = "usage: ltp trace <scenario> --out FILE [--seed N | --seeds A..B] \
                 [--quick] [--jobs N] [--bench FILE]";
    let which = args.positional.get(1).map(String::as_str).context(usage)?;
    anyhow::ensure!(
        which != "all" && which != "list",
        "ltp trace records one named scenario, not `{which}` (see `ltp scenario list`)"
    );
    anyhow::ensure!(
        !args.has("proto") && !args.has("agg") && !args.has("codec") && !args.has("churn"),
        "ltp trace runs scenario defaults — the trace header has no field for \
         --proto/--agg/--codec/--churn overrides, so a replay could not reproduce them"
    );
    let out = args.get("out").context(usage)?;
    anyhow::ensure!(out != "true", "--out requires a file path");
    let index = scenarios::registry()
        .iter()
        .position(|s| s.name == which)
        .with_context(|| {
            let names: Vec<&str> = scenarios::registry().iter().map(|s| s.name).collect();
            format!("unknown scenario `{which}` (known: {})", names.join(", "))
        })?;
    let quick = args.has("quick");
    let n_jobs: usize = args.flag("jobs", 1)?;
    let seeds = parse_seeds(args)?;
    let jobs = sweep::sweep_jobs(&[index], &seeds, quick, None, None, None, None);
    let n = jobs.len();
    let (result, records) = sweep::run_sweep_traced(jobs, n_jobs, true);
    let records = records.expect("traced sweep returns records");
    ltp::trace::write_file(out, which, quick, n as u32, &records).map_err(|e| anyhow::anyhow!(e))?;
    eprintln!("wrote {out}: {} record(s) from {n} job(s) of `{which}`", records.len());
    if let Some(bp) = args.get("bench") {
        anyhow::ensure!(bp != "true", "--bench requires a file path under `ltp trace`");
        let mut bench = result.bench;
        bench.trace = Some(out.to_string());
        std::fs::write(bp, bench.render_json()).with_context(|| format!("writing {bp}"))?;
        eprintln!("wrote {bp} (trace provenance: {out})");
    }
    Ok(())
}

/// `ltp replay <trace>` — re-drive a recorded run, verify it reproduces
/// the trace byte-for-byte, and emit the regenerated report
/// (byte-identical to the recorded run's `ltp scenario --json` output),
/// the per-iteration BST breakdown (`--breakdown`), the per-link/flow
/// stats report (`--stats`), or a link-occupancy timeline (`--viz`).
fn cmd_replay(args: &Args) -> Result<()> {
    let path = args.positional.get(1).context(
        "usage: ltp replay <trace> [--out FILE] [--breakdown [FILE]] [--stats [FILE]] \
         [--viz FILE.svg|FILE.html] [--sim N]",
    )?;
    let file = ltp::trace::read_file(path).map_err(|e| anyhow::anyhow!(e))?;
    let outcome = ltp::trace::replay(&file).map_err(|e| anyhow::anyhow!(e))?;
    eprintln!(
        "replayed {path}: `{}` reproduced exactly ({} record(s), {} job(s))",
        file.header.scenario, outcome.records, outcome.jobs
    );
    match args.get("out") {
        Some("true") => bail!("--out requires a file path"),
        // fs::write, no trailing newline: the bytes must cmp-equal an
        // `ltp scenario --json --out` report of the same run.
        Some(p) => {
            std::fs::write(p, &outcome.report_json).with_context(|| format!("writing {p}"))?;
            eprintln!("wrote {p}");
        }
        None => {
            if !args.has("breakdown") && !args.has("stats") && !args.has("viz") {
                println!("{}", outcome.report_json);
            }
        }
    }
    if let Some(bd) = args.get("breakdown") {
        let json = ltp::trace::breakdown(&file).render_pretty();
        if bd == "true" {
            println!("{json}");
        } else {
            std::fs::write(bd, json).with_context(|| format!("writing {bd}"))?;
            eprintln!("wrote {bd}");
        }
    }
    if let Some(sp) = args.get("stats") {
        let json = ltp::trace::stats_json(&file).render_pretty();
        if sp == "true" {
            println!("{json}");
        } else {
            std::fs::write(sp, json).with_context(|| format!("writing {sp}"))?;
            eprintln!("wrote {sp}");
        }
    }
    if let Some(vz) = args.get("viz") {
        anyhow::ensure!(vz != "true", "--viz requires an output path (.svg or .html)");
        let sim: usize = args.flag("sim", 0)?;
        let rendered = if vz.ends_with(".html") {
            ltp::trace::render_html(&file, sim)
        } else {
            ltp::trace::render_svg(&file, sim)
        }
        .map_err(|e| anyhow::anyhow!(e))?;
        std::fs::write(vz, rendered).with_context(|| format!("writing {vz}"))?;
        eprintln!("wrote {vz} (sim {sim})");
    }
    Ok(())
}

/// `ltp diff <a.trace> <b.trace>` — align two recorded runs by
/// (sim, link, iteration) and rank the cells by BST-contribution delta:
/// the one-command localization of a BST/bench regression to a link and
/// iteration.
fn cmd_diff(args: &Args) -> Result<()> {
    let usage = "usage: ltp diff <a.trace> <b.trace> [--top K] [--json] [--out FILE]";
    let a_path = args.positional.get(1).context(usage)?;
    let b_path = args.positional.get(2).context(usage)?;
    let a = ltp::trace::read_file(a_path).map_err(|e| anyhow::anyhow!(e))?;
    let b = ltp::trace::read_file(b_path).map_err(|e| anyhow::anyhow!(e))?;
    let top: usize = args.flag("top", 10)?;
    let d = ltp::trace::diff(&a, &b, top);
    match args.get("out") {
        Some("true") => bail!("--out requires a file path"),
        Some(p) => {
            std::fs::write(p, ltp::trace::diff_json(&d).render_pretty())
                .with_context(|| format!("writing {p}"))?;
            eprintln!("wrote {p}");
        }
        None => {
            if args.has("json") {
                println!("{}", ltp::trace::diff_json(&d).render_pretty());
            } else {
                print!("{}", ltp::trace::render_diff_table(&d));
            }
        }
    }
    Ok(())
}

/// `ltp bench check` — the CI perf gate: compare a freshly written bench
/// report against the committed snapshot and fail (exit non-zero) when
/// the scenario's events/sec regresses beyond the threshold.
/// `--scenario all` gates every scenario the baseline covers; a baseline
/// scenario missing from the current report is a hard error, not a pass.
fn cmd_bench(args: &Args) -> Result<()> {
    use ltp::scenarios::sweep;
    match args.positional.get(1).map(String::as_str) {
        Some("check") => {
            let baseline_path =
                args.get("baseline").context("usage: ltp bench check --baseline FILE --current FILE")?;
            let current_path =
                args.get("current").context("usage: ltp bench check --baseline FILE --current FILE")?;
            anyhow::ensure!(
                baseline_path != "true" && current_path != "true",
                "--baseline/--current require file paths"
            );
            let scenario: String = args.flag("scenario", "incast_sweep".to_string())?;
            let max_regress_pct: f64 = args.flag("max-regress-pct", 20.0)?;
            let baseline = std::fs::read_to_string(baseline_path)
                .with_context(|| format!("reading {baseline_path}"))?;
            let current = std::fs::read_to_string(current_path)
                .with_context(|| format!("reading {current_path}"))?;
            let checks = if scenario == "all" {
                sweep::check_regression_all(&baseline, &current, max_regress_pct)
                    .map_err(|e| anyhow::anyhow!(e))?
            } else {
                let one =
                    sweep::check_regression(&baseline, &current, &scenario, max_regress_pct)
                        .map_err(|e| anyhow::anyhow!(e))?;
                vec![one]
            };
            let mut seen_notes: Vec<&String> = Vec::new();
            for check in &checks {
                for note in &check.notes {
                    if !seen_notes.contains(&note) {
                        seen_notes.push(note);
                        eprintln!("note: {note}");
                    }
                }
                println!(
                    "bench check `{}`: baseline {:.0} ev/s, current {:.0} ev/s ({:+.1}%, threshold -{}%)",
                    check.scenario,
                    check.baseline_eps,
                    check.current_eps,
                    check.delta_pct,
                    check.max_regress_pct,
                );
            }
            let failed: Vec<String> = checks
                .iter()
                .filter(|c| !c.ok)
                .map(|c| format!("`{}` {:.1}%", c.scenario, -c.delta_pct))
                .collect();
            if let Some(first) = checks.iter().find(|c| !c.ok) {
                let sc = &first.scenario;
                bail!(
                    "events/sec regressed more than {max_regress_pct}% on: {}\n\
                     localize it — capture a trace at the baseline commit and here, then diff:\n\
                     \x20 ltp trace {sc} --quick --out baseline.ltt   # at the baseline commit\n\
                     \x20 ltp trace {sc} --quick --out current.ltt    # at this commit\n\
                     \x20 ltp diff baseline.ltt current.ltt           # top (link, iteration) BST deltas",
                    failed.join(", ")
                );
            }
            Ok(())
        }
        other => bail!(
            "unknown bench subcommand `{}` (check) — the sweep itself is \
             `ltp scenario ... --bench [FILE]`",
            other.unwrap_or("")
        ),
    }
}

/// `ltp proto list` — the registry; `ltp proto parse <spec>` — echo a
/// spec's canonical form (handy for checking what a `--proto` flag means).
fn cmd_proto(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str).unwrap_or("list") {
        "list" => {
            println!(
                "registered protocols (use with `--proto <key>[:name=value,...]`):\n"
            );
            for d in proto_registry() {
                println!("  {:<14} {}", d.key, d.summary);
                if !d.params.is_empty() {
                    println!("  {:<14}   params: {}", "", d.params);
                }
            }
            println!("\nthe `proto_matrix` scenario sweeps every matrix-flagged protocol.");
            Ok(())
        }
        "parse" => {
            let spec = args
                .positional
                .get(2)
                .context("usage: ltp proto parse <spec>")?;
            let p = parse_proto(spec)?;
            println!(
                "{} -> canonical `{}` ({})",
                spec,
                p.name(),
                if p.is_loss_tolerant() { "loss-tolerant" } else { "reliable" }
            );
            Ok(())
        }
        other => bail!("unknown proto subcommand `{other}` (list|parse)"),
    }
}

/// `ltp agg list` — the aggregation registry; `ltp agg parse <spec>` —
/// echo a spec's canonical form and endpoint count.
fn cmd_agg(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str).unwrap_or("list") {
        "list" => {
            println!(
                "registered aggregation topologies (use with `--agg <key>[:name=value,...]`):\n"
            );
            for d in agg_registry() {
                println!("  {:<10} {}", d.key, d.summary);
                if !d.params.is_empty() {
                    println!("  {:<10}   params: {}", "", d.params);
                }
            }
            println!("\nthe `agg_matrix` scenario sweeps ps, sharded:n∈{{2,4,8}}, and hier.");
            Ok(())
        }
        "parse" => {
            let spec = args.positional.get(2).context("usage: ltp agg parse <spec>")?;
            let a = parse_agg(spec)?;
            // Endpoint counts can depend on the worker count; report for
            // the paper's 8-worker testbed.
            println!(
                "{} -> canonical `{}` ({} aggregator endpoint(s) at 8 workers)",
                spec,
                a.name(),
                a.n_aggregators(8)
            );
            Ok(())
        }
        other => bail!("unknown agg subcommand `{other}` (list|parse)"),
    }
}

/// `ltp backend list` — the compute-backend registry; `ltp backend parse
/// <spec>` — echo a spec's canonical form and readiness (whether its
/// dependencies — e.g. the AOT artifacts for `xla` — are present).
fn cmd_backend(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str).unwrap_or("list") {
        "list" => {
            println!(
                "registered compute backends (use with `--backend <key>[:name=value,...]`):\n"
            );
            for d in backend_registry() {
                println!("  {:<8} {}", d.key, d.summary);
                if !d.params.is_empty() {
                    println!("  {:<8}   params: {}", "", d.params);
                }
            }
            println!("\nthe `accuracy_matrix` scenario trains the native backend across loss rates.");
            Ok(())
        }
        "parse" => {
            let spec =
                args.positional.get(2).context("usage: ltp backend parse <spec>")?;
            let b = parse_backend(spec)?;
            let ready = match b.check_ready() {
                Ok(()) => "ready".to_string(),
                Err(e) => format!("unavailable: {e:#}"),
            };
            println!("{} -> canonical `{}` ({ready})", spec, b.name());
            Ok(())
        }
        other => bail!("unknown backend subcommand `{other}` (list|parse)"),
    }
}

/// `ltp codec list` — the gradient-codec registry; `ltp codec parse
/// <spec>` — echo a spec's canonical form and its wire footprint for the
/// default native model.
fn cmd_codec(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str).unwrap_or("list") {
        "list" => {
            println!(
                "registered gradient codecs (use with `--codec <key>[:name=value,...]`):\n"
            );
            for d in codec_registry() {
                println!("  {:<10} {}", d.key, d.summary);
                if !d.params.is_empty() {
                    println!("  {:<10}   params: {}", "", d.params);
                }
            }
            println!(
                "\nthe `compression_matrix` scenario sweeps dense and topk:pct∈{{0.1,0.01}} \
                 across protocols and loss rates."
            );
            Ok(())
        }
        "parse" => {
            let spec = args.positional.get(2).context("usage: ltp codec parse <spec>")?;
            let c = parse_codec(spec)?;
            // Wire footprint can depend on the model size; report for the
            // default native backend's gradient.
            let dense = parse_backend("native")?.model()?.wire_bytes;
            println!(
                "{} -> canonical `{}` ({} of {} on the wire for `native`{})",
                spec,
                c.name(),
                ltp::util::fmt_bytes(c.encoded_bytes(dense)),
                ltp::util::fmt_bytes(dense),
                if c.priority() { ", tensor-priority scheduling on" } else { "" }
            );
            Ok(())
        }
        other => bail!("unknown codec subcommand `{other}` (list|parse)"),
    }
}

/// `ltp churn list` — the churn-plane registry; `ltp churn parse <spec>`
/// — echo a spec's canonical form and which planes it perturbs.
fn cmd_churn(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str).unwrap_or("list") {
        "list" => {
            println!(
                "registered churn models (use with `--churn <key>[:name=value,...]`):\n"
            );
            for d in churn_registry() {
                println!("  {:<7} {}", d.key, d.summary);
                if !d.params.is_empty() {
                    println!("  {:<7}   params: {}", "", d.params);
                }
            }
            println!(
                "\nthe `churn_matrix` scenario sweeps rate∈{{0,0.05,0.1}} across protocols, \
                 stragglers off/on."
            );
            Ok(())
        }
        "parse" => {
            let spec = args.positional.get(2).context("usage: ltp churn parse <spec>")?;
            let c = parse_churn(spec)?;
            let planes = match (c.perturbs_membership(), c.perturbs_links()) {
                (false, false) => "stable membership, pristine links",
                (true, false) => "elastic membership",
                (false, true) => "per-worker link dynamics",
                (true, true) => "elastic membership + per-worker link dynamics",
            };
            println!("{} -> canonical `{}` ({planes})", spec, c.name());
            Ok(())
        }
        other => bail!("unknown churn subcommand `{other}` (list|parse)"),
    }
}

fn main() -> Result<()> {
    let args = parse_args();
    match args.positional.first().map(String::as_str) {
        Some("scenario") => cmd_scenario(&args),
        Some("figure") => {
            let which = args.positional.get(1).map(String::as_str).unwrap_or("all");
            ltp::figures::run(which, args.has("quick"), args.flag("jobs", 1)?)
        }
        Some("trace") => cmd_trace(&args),
        Some("replay") => cmd_replay(&args),
        Some("diff") => cmd_diff(&args),
        Some("proto") => cmd_proto(&args),
        Some("agg") => cmd_agg(&args),
        Some("backend") => cmd_backend(&args),
        Some("codec") => cmd_codec(&args),
        Some("churn") => cmd_churn(&args),
        Some("train") => cmd_train(&args),
        Some("bench") => cmd_bench(&args),
        Some("bench-ltp") => cmd_bench_ltp(&args),
        _ => {
            eprintln!(
                "usage:\n  ltp scenario <name|list|all> [--json] [--seed N | --seeds A..B] [--quick]\n  \
                 \x20            [--jobs N] [--out FILE] [--bench [FILE]] [--proto SPEC]... [--agg SPEC]...\n  \
                 \x20            [--codec SPEC]... [--churn SPEC]...\n  \
                 ltp figure <fig2|fig3|fig4|fig5|fig12|fig13|fig14|fig15|all> [--quick] [--jobs N]\n  \
                 ltp trace <scenario> --out FILE [--seed N | --seeds A..B] [--quick] [--jobs N] [--bench FILE]\n  \
                 ltp replay <trace> [--out FILE] [--breakdown [FILE]] [--stats [FILE]]\n  \
                 \x20          [--viz FILE.svg|FILE.html] [--sim N]\n  \
                 ltp diff <a.trace> <b.trace> [--top K] [--json] [--out FILE]\n  \
                 ltp proto <list|parse SPEC>\n  \
                 ltp agg <list|parse SPEC>\n  \
                 ltp backend <list|parse SPEC>\n  \
                 ltp codec <list|parse SPEC>\n  \
                 ltp churn <list|parse SPEC>\n  \
                 ltp train [--backend SPEC] [--workers N] [--iters N] [--loss P] [--proto SPEC]\n  \
                 \x20        [--agg SPEC] [--codec SPEC] [--churn SPEC] [--max-loss X]\n  \
                 ltp bench check --baseline FILE --current FILE [--scenario NAME|all] [--max-regress-pct P]\n  \
                 ltp bench-ltp [--bytes N] [--loss P]"
            );
            bail!("missing or unknown subcommand");
        }
    }
}
