//! The `native` backend: a deterministic, dependency-free trainer that
//! makes accuracy-vs-loss measurable everywhere (DESIGN.md §1.3).
//!
//! * **Data** — a seeded synthetic classification corpus: each class `c`
//!   draws a mean vector `μ_c ~ N(0, 3²)` per feature (seeded from the
//!   run seed), samples are `x = (μ_y + N(0, 1)) / √dim` (normalized so
//!   activations stay O(1) at any width). Every worker owns a disjoint
//!   deterministic stream; a fixed held-out eval set measures final
//!   loss/accuracy.
//! * **Model** — a dense f32 MLP (`dim → hidden×layers → classes`,
//!   leaky-ReLU, softmax cross-entropy) with a hand-written backward
//!   pass. Parameters live in one flat vector whose tensor layout also
//!   yields the wire manifest (critical segments = tensor boundaries).
//! * **Aggregation** — the masked mean the Pallas kernel implements:
//!   per element, `mean = Σ_w g_w·m_w / Σ_w m_w` with `m` from
//!   [`crate::grad::element_mask`] over the transport's delivery bitmap
//!   (bubbles are zeros with zero weight — unbiased), then momentum SGD
//!   (`v ← 0.9·v + mean`, `p ← p − lr·v`). With `fill=off` the masks
//!   still zero the lost bytes (that is what the wire delivered) but the
//!   denominator counts every contributing worker — the biased estimate a
//!   receiver without bubble filling would compute; the `accuracy_matrix`
//!   scenario sweeps both.
//!
//! Gradient values never ride simulated packets: workers deposit into a
//! shared in-process store and aggregators read it gated by the
//! transport's delivery bitmaps (the [`crate::ps::Blackboard`] pattern),
//! so the numerics see exactly what the wire delivered. Summation is in
//! worker order at every endpoint, which makes `ps`, `sharded:n=N`, and
//! `hier` aggregation **bit-identical** at zero loss (asserted by
//! `rust/tests/agg.rs`).

use super::{
    parse_count, parse_rate, parse_switch, Backend, BackendSpec, ModelInfo, RunCtx,
    TrainSession, TrainStats,
};
use crate::grad::{element_mask, ErrorFeedback, Manifest};
use crate::proto::SegmentMap;
use crate::ps::spec::{canonical, unknown_param};
use crate::ps::{Aggregate, Compute, EndpointRole, IterStats};
use crate::util::{Bitmap, Pcg64};
use crate::wire::LTP_MSS;
use crate::Nanos;
use anyhow::Result;
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

/// Per-worker minibatch size (matches the paper's batch-32 workloads).
const BATCH: usize = 32;
/// Held-out eval set size.
const EVAL_SAMPLES: usize = 256;
/// Momentum coefficient — the same the Pallas aggregate kernel uses.
const MOMENTUM: f32 = 0.9;
/// Leaky-ReLU negative slope (avoids dead units under any seed).
const LEAK: f32 = 0.01;
/// Class-mean spread vs unit sample noise: well-separated blobs, so a
/// few dozen SGD steps reach high accuracy — the property the
/// accuracy-under-loss experiments measure degradation against.
const MEAN_SPREAD: f64 = 3.0;

// Deterministic RNG stream ids (disjoint from the simulator's).
const STREAM_TASK: u64 = 0xD474;
const STREAM_INIT: u64 = 0x1417;
const STREAM_EVAL: u64 = 0xE7A1;
const STREAM_WORKER0: u64 = 0x10_0000;

/// Immutable model/optimizer configuration (the parsed spec).
#[derive(Debug, Clone)]
pub struct NativeBackend {
    dim: usize,
    layers: usize,
    hidden: usize,
    classes: usize,
    lr: f32,
    /// Bubble filling: masked-mean denominators count only delivered
    /// elements (`true`, the paper's kernel) or every contributor
    /// (`false`, the ablation).
    fill: bool,
    /// Training-loss target for `iters_to_target`.
    target: f32,
    spec: String,
}

pub(super) fn build_native(params: &[(String, String)]) -> Result<BackendSpec> {
    let (mut dim, mut layers, mut hidden, mut classes) = (None, None, None, None);
    let (mut lr, mut fill, mut target) = (None, None, None);
    for (k, v) in params {
        match k.as_str() {
            "dim" => dim = Some(parse_count(k, v)?),
            "layers" => layers = Some(parse_count(k, v)?),
            "hidden" => hidden = Some(parse_count(k, v)?),
            "classes" => classes = Some(parse_count(k, v)?),
            "lr" => lr = Some(parse_rate(k, v)?),
            "fill" => fill = Some(parse_switch(k, v)?),
            "target" => target = Some(parse_rate(k, v)?),
            _ => {
                return Err(unknown_param(
                    "native",
                    k,
                    "dim, layers, hidden, classes, lr, fill, target",
                ))
            }
        }
    }
    // Canonical order: dim, layers, hidden, classes, lr, fill, target —
    // parameters render only when given, so a bare `native` stays `native`.
    let mut parts = Vec::new();
    if let Some(x) = dim {
        parts.push(format!("dim={x}"));
    }
    if let Some(x) = layers {
        parts.push(format!("layers={x}"));
    }
    if let Some(x) = hidden {
        parts.push(format!("hidden={x}"));
    }
    if let Some(x) = classes {
        parts.push(format!("classes={x}"));
    }
    if let Some(x) = lr {
        parts.push(format!("lr={x}"));
    }
    if let Some(x) = fill {
        parts.push(format!("fill={}", if x { "on" } else { "off" }));
    }
    if let Some(x) = target {
        parts.push(format!("target={x}"));
    }
    Ok(BackendSpec(Arc::new(NativeBackend {
        dim: dim.unwrap_or(64),
        layers: layers.unwrap_or(2),
        hidden: hidden.unwrap_or(64),
        classes: classes.unwrap_or(8),
        lr: lr.unwrap_or(0.15),
        fill: fill.unwrap_or(true),
        target: target.unwrap_or(0.3),
        spec: canonical("native", &parts),
    })))
}

impl NativeBackend {
    /// The tensor layout of the flat parameter vector, in order: per
    /// hidden layer a weight matrix and a bias, then the output head.
    fn manifest(&self) -> Manifest {
        let mut tensors: Vec<(String, usize)> = Vec::new();
        let mut fan_in = self.dim;
        for l in 0..self.layers {
            tensors.push((format!("layer{l}.w"), fan_in * self.hidden));
            tensors.push((format!("layer{l}.b"), self.hidden));
            fan_in = self.hidden;
        }
        tensors.push(("head.w".to_string(), self.hidden * self.classes));
        tensors.push(("head.b".to_string(), self.classes));
        Manifest {
            tensors: tensors
                .into_iter()
                .map(|(name, numel)| crate::grad::TensorSpec { name, numel })
                .collect(),
        }
    }

    fn param_count(&self) -> usize {
        self.manifest().total_elems()
    }

    /// Draw one labeled sample: `x = (μ_y + N(0,1)) / √dim` (the 1/√dim
    /// scale keeps activations O(1) at any width), `y` uniform. One code
    /// path serves the worker streams and the eval set, so their
    /// distributions can never drift apart.
    fn sample(&self, means: &[f32], rng: &mut Pcg64, x: &mut [f32]) -> usize {
        let y = rng.gen_range(self.classes as u64) as usize;
        let inv = 1.0 / (self.dim as f32).sqrt();
        for (d, xd) in x.iter_mut().enumerate() {
            *xd = (means[y * self.dim + d] + rng.normal() as f32) * inv;
        }
        y
    }

    /// Deterministic initial parameters: `N(0, 1/fan_in)` weights, zero
    /// biases, seeded from the run seed.
    fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, STREAM_INIT);
        let mut params = Vec::with_capacity(self.param_count());
        let mut fan_in = self.dim;
        for _ in 0..self.layers {
            let scale = 1.0 / (fan_in as f64).sqrt();
            for _ in 0..fan_in * self.hidden {
                params.push((rng.normal() * scale) as f32);
            }
            params.resize(params.len() + self.hidden, 0.0);
            fan_in = self.hidden;
        }
        let scale = 1.0 / (self.hidden as f64).sqrt();
        for _ in 0..self.hidden * self.classes {
            params.push((rng.normal() * scale) as f32);
        }
        params.resize(params.len() + self.classes, 0.0);
        params
    }

    /// Forward pass; returns `(loss, predicted class)` and, when `grads`
    /// is given, accumulates `d loss / d params` into it (both per
    /// sample; callers average over the batch).
    fn forward_backward(
        &self,
        params: &[f32],
        x: &[f32],
        label: usize,
        mut grads: Option<&mut [f32]>,
    ) -> (f32, usize) {
        let (h, c, l_n) = (self.hidden, self.classes, self.layers);
        // Activations per hidden layer (post-nonlinearity), kept for the
        // backward pass.
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(l_n);
        let mut pre: Vec<Vec<f32>> = Vec::with_capacity(l_n);
        let mut off = 0usize;
        let mut offsets = Vec::with_capacity(l_n);
        for l in 0..l_n {
            let fan_in = if l == 0 { self.dim } else { h };
            offsets.push(off);
            let w = &params[off..off + fan_in * h];
            let b = &params[off + fan_in * h..off + fan_in * h + h];
            let mut z = vec![0.0f32; h];
            {
                let below: &[f32] = if l == 0 { x } else { &acts[l - 1] };
                for (i, &xi) in below.iter().enumerate() {
                    let row = &w[i * h..(i + 1) * h];
                    for j in 0..h {
                        z[j] += xi * row[j];
                    }
                }
            }
            for j in 0..h {
                z[j] += b[j];
            }
            let a: Vec<f32> = z.iter().map(|&v| if v > 0.0 { v } else { LEAK * v }).collect();
            off += fan_in * h + h;
            pre.push(z);
            acts.push(a);
        }
        let w_out = &params[off..off + h * c];
        let b_out = &params[off + h * c..off + h * c + c];
        let top = acts.last().expect("at least one hidden layer");
        let mut logits = vec![0.0f32; c];
        for (i, &ai) in top.iter().enumerate() {
            let row = &w_out[i * c..(i + 1) * c];
            for k in 0..c {
                logits[k] += ai * row[k];
            }
        }
        for k in 0..c {
            logits[k] += b_out[k];
        }
        // Softmax cross-entropy (max-shifted for stability).
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = logits.iter().map(|&v| (v - max).exp()).collect();
        let sum: f32 = exps.iter().sum();
        let probs: Vec<f32> = exps.iter().map(|&e| e / sum).collect();
        let loss = -(probs[label].max(1e-12)).ln();
        let mut best = 0usize;
        for k in 1..c {
            if logits[k] > logits[best] {
                best = k;
            }
        }
        let Some(grads) = grads.as_deref_mut() else {
            return (loss, best);
        };
        // Backward: d logits.
        let mut dlogit = probs;
        dlogit[label] -= 1.0;
        // Head gradients.
        let g_w_out = off;
        for (i, &ai) in top.iter().enumerate() {
            let row = &mut grads[g_w_out + i * c..g_w_out + (i + 1) * c];
            for k in 0..c {
                row[k] += ai * dlogit[k];
            }
        }
        for k in 0..c {
            grads[g_w_out + h * c + k] += dlogit[k];
        }
        // d top activation.
        let mut d_act = vec![0.0f32; h];
        for (i, d) in d_act.iter_mut().enumerate() {
            let row = &w_out[i * c..(i + 1) * c];
            let mut s = 0.0f32;
            for k in 0..c {
                s += row[k] * dlogit[k];
            }
            *d = s;
        }
        // Hidden layers, last to first.
        for l in (0..l_n).rev() {
            let z = &pre[l];
            let mut dz = vec![0.0f32; h];
            for j in 0..h {
                let slope = if z[j] > 0.0 { 1.0 } else { LEAK };
                dz[j] = d_act[j] * slope;
            }
            let below: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            let fan_in = below.len();
            let w_off = offsets[l];
            for (i, &xi) in below.iter().enumerate() {
                let row = &mut grads[w_off + i * h..w_off + (i + 1) * h];
                for j in 0..h {
                    row[j] += xi * dz[j];
                }
            }
            for j in 0..h {
                grads[w_off + fan_in * h + j] += dz[j];
            }
            if l > 0 {
                let w = &params[w_off..w_off + fan_in * h];
                let mut d_below = vec![0.0f32; fan_in];
                for (i, d) in d_below.iter_mut().enumerate() {
                    let row = &w[i * h..(i + 1) * h];
                    let mut s = 0.0f32;
                    for j in 0..h {
                        s += row[j] * dz[j];
                    }
                    *d = s;
                }
                d_act = d_below;
            }
        }
        (loss, best)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        &self.spec
    }

    fn check_ready(&self) -> Result<()> {
        Ok(()) // pure Rust: no artifacts, no external runtime
    }

    fn model(&self) -> Result<ModelInfo> {
        let m = self.manifest();
        Ok(ModelInfo {
            wire_bytes: m.total_bytes(),
            critical: m.critical_segments(Manifest::aligned_payload(LTP_MSS)),
        })
    }

    fn open(&self, run: &RunCtx) -> Result<Box<dyn TrainSession>> {
        let cfg = Arc::new(self.clone());
        // The task (class means) and the held-out eval set derive from the
        // run seed: same seed ⇒ same task across protocols/topologies.
        let mut task_rng = Pcg64::new(run.seed, STREAM_TASK);
        let means: Vec<f32> = (0..cfg.classes * cfg.dim)
            .map(|_| (task_rng.normal() * MEAN_SPREAD) as f32)
            .collect();
        let mut eval_rng = Pcg64::new(run.seed, STREAM_EVAL);
        let mut eval_x = vec![0.0f32; EVAL_SAMPLES * cfg.dim];
        let mut eval_y = Vec::with_capacity(EVAL_SAMPLES);
        for s in 0..EVAL_SAMPLES {
            let y =
                cfg.sample(&means, &mut eval_rng, &mut eval_x[s * cfg.dim..(s + 1) * cfg.dim]);
            eval_y.push(y);
        }
        let params = cfg.init_params(run.seed);
        let momentum = vec![0.0f32; params.len()];
        Ok(Box::new(NativeSession {
            cfg,
            task: Rc::new(Task { means, eval_x, eval_y }),
            state: Rc::new(RefCell::new(NativeState {
                params,
                momentum,
                grads: HashMap::new(),
                masks: HashMap::new(),
                losses: Vec::new(),
            })),
            run: run.clone(),
        }))
    }
}

/// The shared classification task: class means plus the held-out eval set.
struct Task {
    means: Vec<f32>,
    eval_x: Vec<f32>,
    eval_y: Vec<usize>,
}

/// Single-threaded per-run training state, shared between the workers'
/// [`Compute`] objects and the aggregator endpoints (the in-process data
/// plane; the simulator only accounts bytes).
struct NativeState {
    params: Vec<f32>,
    momentum: Vec<f32>,
    /// (worker, iter) → flat gradient as computed (pre-masking).
    grads: HashMap<(usize, u64), Vec<f32>>,
    /// (worker, iter) → per-element delivery mask accumulated by relay
    /// tiers (`hier` racks); terminal endpoints multiply their own masks
    /// on top.
    masks: HashMap<(usize, u64), Vec<f32>>,
    /// (iter, mean batch loss), one entry per worker compute, in
    /// simulation order.
    losses: Vec<(u64, f32)>,
}

impl NativeState {
    /// Drop per-iteration buffers older than `iter` (every endpoint of an
    /// iteration reads before any endpoint reaches `iter + 1` under BSP;
    /// `mean_loss` is only ever queried for the current iteration, so the
    /// loss log is prunable too — without this, long runs would rescan an
    /// ever-growing vector on every endpoint's `loss()` call).
    fn gc(&mut self, iter: u64) {
        self.grads.retain(|&(_, i), _| i >= iter);
        self.masks.retain(|&(_, i), _| i >= iter);
        self.losses.retain(|&(i, _)| i >= iter);
    }

    fn mean_loss(&self, iter: u64) -> Option<f32> {
        let vals: Vec<f32> =
            self.losses.iter().filter(|&&(i, _)| i == iter).map(|&(_, l)| l).collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f32>() / vals.len() as f32)
        }
    }
}

pub(super) struct NativeSession {
    cfg: Arc<NativeBackend>,
    task: Rc<Task>,
    state: Rc<RefCell<NativeState>>,
    run: RunCtx,
}

impl TrainSession for NativeSession {
    fn make_compute(&mut self, worker: usize) -> Box<dyn Compute> {
        Box::new(NativeCompute {
            cfg: self.cfg.clone(),
            task: self.task.clone(),
            state: self.state.clone(),
            rng: Pcg64::new(self.run.seed, STREAM_WORKER0 + worker as u64),
            compute_time: self.run.compute_time,
        })
    }

    fn make_agg(&mut self, endpoint: usize) -> Box<dyn Aggregate> {
        let role = self.run.roles.get(endpoint).copied().unwrap_or_else(|| {
            panic!("endpoint {endpoint} beyond the aggregation's {} roles", self.run.roles.len())
        });
        let payload = Manifest::aligned_payload(LTP_MSS);
        let model_bytes = self.cfg.param_count() as u64 * 4;
        match role {
            EndpointRole::Final { byte_offset, bytes } => Box::new(NativeAggregate {
                cfg: self.cfg.clone(),
                state: self.state.clone(),
                elem0: (byte_offset / 4) as usize,
                numel: (bytes / 4) as usize,
                seg_map: SegmentMap::new(
                    self.run.codec.encoded_bytes(bytes),
                    payload,
                    vec![],
                ),
                codec: self.run.codec.clone(),
                residuals: HashMap::new(),
                workers: (0, self.run.n_workers),
                agg_time: self.run.agg_time,
            }),
            EndpointRole::Relay { first_worker, n_workers } => Box::new(NativeRelay {
                state: self.state.clone(),
                first_worker,
                n_workers,
                numel: self.cfg.param_count(),
                seg_map: SegmentMap::new(model_bytes, payload, vec![]),
                agg_time: self.run.agg_time,
            }),
            EndpointRole::Root { racks } => Box::new(NativeRoot {
                cfg: self.cfg.clone(),
                state: self.state.clone(),
                racks,
                per_rack: self.run.n_workers / racks.max(1),
                seg_map: SegmentMap::new(model_bytes, payload, vec![]),
                agg_time: self.run.agg_time,
            }),
        }
    }

    fn params(&self) -> Vec<f32> {
        self.state.borrow().params.clone()
    }

    fn stats(&self, iters: &[IterStats]) -> TrainStats {
        let state = self.state.borrow();
        let cfg = &self.cfg;
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;
        for (s, &y) in self.task.eval_y.iter().enumerate() {
            let x = &self.task.eval_x[s * cfg.dim..(s + 1) * cfg.dim];
            let (loss, pred) = cfg.forward_backward(&state.params, x, y, None);
            loss_sum += loss as f64;
            if pred == y {
                correct += 1;
            }
        }
        let n = self.task.eval_y.len().max(1);
        TrainStats {
            final_loss: (loss_sum / n as f64) as f32,
            accuracy: correct as f64 / n as f64,
            iters_to_target: iters
                .iter()
                .position(|i| i.loss.map(|l| l <= cfg.target).unwrap_or(false))
                .map(|i| i as u64 + 1),
        }
    }
}

/// Worker-side compute: draw a batch from this worker's stream, run
/// forward/backward over the current global parameters, deposit the
/// gradient.
struct NativeCompute {
    cfg: Arc<NativeBackend>,
    task: Rc<Task>,
    state: Rc<RefCell<NativeState>>,
    rng: Pcg64,
    compute_time: Nanos,
}

impl Compute for NativeCompute {
    fn compute(&mut self, worker: usize, iter: u64) -> Nanos {
        let cfg = &self.cfg;
        let params = self.state.borrow().params.clone();
        let mut grads = vec![0.0f32; params.len()];
        let mut loss_sum = 0.0f32;
        let mut x = vec![0.0f32; cfg.dim];
        for _ in 0..BATCH {
            let y = cfg.sample(&self.task.means, &mut self.rng, &mut x);
            let (loss, _) = cfg.forward_backward(&params, &x, y, Some(&mut grads));
            loss_sum += loss;
        }
        let scale = 1.0 / BATCH as f32;
        for g in grads.iter_mut() {
            *g *= scale;
        }
        let mut st = self.state.borrow_mut();
        st.grads.insert((worker, iter), grads);
        st.losses.push((iter, loss_sum * scale));
        self.compute_time
    }
}

/// Per-element mask of one gather flow's delivery bitmap (`None` = a
/// reliable transport delivered everything).
fn flow_mask(seg_map: &SegmentMap, arrival: &Option<(Bitmap, u64)>, numel: usize) -> Vec<f32> {
    match arrival {
        Some((bitmap, _)) => element_mask(seg_map, bitmap, numel),
        None => vec![1.0f32; numel],
    }
}

/// Terminal masked-mean + momentum-SGD endpoint over the element range
/// `[elem0, elem0 + numel)` — the single PS or one shard. Matches the
/// Pallas `aggregate` kernel's semantics element for element.
struct NativeAggregate {
    cfg: Arc<NativeBackend>,
    state: Rc<RefCell<NativeState>>,
    elem0: usize,
    numel: usize,
    /// Segmentation of *this endpoint's* gather flows — the codec's
    /// *encoded* image of the shard bytes.
    seg_map: SegmentMap,
    /// The gradient codec shaping the gather wire image (DESIGN.md §1.4);
    /// identity codecs reproduce the pre-codec decode path bit for bit.
    codec: crate::codec::CodecSpec,
    /// Per-worker error-feedback residuals for sparsifying codecs: the
    /// coordinates a codec drops accumulate here and re-enter later
    /// selections, keeping sparsified SGD convergent.
    residuals: HashMap<usize, ErrorFeedback>,
    /// Global worker range feeding this endpoint (`(first, count)`).
    workers: (usize, usize),
    agg_time: Nanos,
}

/// The shared update rule: masked mean over `rows` (each `(grad, mask)`
/// already positioned at the endpoint's element range, in worker order),
/// then momentum SGD on `params[elem0..elem0+numel]`.
fn masked_mean_sgd(
    state: &mut NativeState,
    fill: bool,
    lr: f32,
    elem0: usize,
    numel: usize,
    rows: &[(&[f32], &[f32])],
) {
    for i in 0..numel {
        let mut sum = 0.0f64;
        let mut cnt = 0.0f64;
        for (g, m) in rows {
            let mi = m[i];
            sum += (g[i] * mi) as f64;
            cnt += mi as f64;
        }
        let denom = if fill { cnt.max(1.0) } else { (rows.len() as f64).max(1.0) };
        // Clamp as an optimizer safety net (inactive at these scales; the
        // clamp is part of the update rule, so it is identical at every
        // endpoint and cross-topology bit-identity holds).
        let mean = (sum / denom).clamp(-10.0, 10.0) as f32;
        let p = elem0 + i;
        let v = MOMENTUM * state.momentum[p] + mean;
        state.momentum[p] = v;
        state.params[p] -= lr * v;
    }
}

impl Aggregate for NativeAggregate {
    fn aggregate(&mut self, iter: u64, arrivals: &[Option<(Bitmap, u64)>]) -> Nanos {
        let state = &mut *self.state.borrow_mut();
        let (first, count) = self.workers;
        // Collect (effective grad, mask) rows in global worker order;
        // workers that deposited nothing this round contribute nothing.
        let mut rows: Vec<(Vec<f32>, Vec<f32>)> = Vec::with_capacity(count);
        // Split borrows: grads are read, params/momentum written below.
        let grads = std::mem::take(&mut state.grads);
        for w in first..first + count {
            let Some(g) = grads.get(&(w, iter)) else { continue };
            let slice = &g[self.elem0..self.elem0 + self.numel];
            let arrival = arrivals[w - first].as_ref().map(|(bm, _)| bm);
            if self.codec.wire_identity() {
                let mask = self.codec.element_mask(slice, &self.seg_map, arrival);
                rows.push((slice.to_vec(), mask));
            } else {
                // Error feedback: the worker sends grad + residual, the
                // unsent remainder becomes the next residual.
                let ef = self
                    .residuals
                    .entry(w)
                    .or_insert_with(|| ErrorFeedback::new(self.numel));
                let mut eff = slice.to_vec();
                ef.compensate(&mut eff);
                let mask = self.codec.element_mask(&eff, &self.seg_map, arrival);
                let post: Vec<f32> =
                    eff.iter().zip(&mask).map(|(&g, &m)| g * m).collect();
                ef.absorb(&eff, &post);
                rows.push((eff, mask));
            }
        }
        let views: Vec<(&[f32], &[f32])> =
            rows.iter().map(|(g, m)| (g.as_slice(), m.as_slice())).collect();
        masked_mean_sgd(state, self.cfg.fill, self.cfg.lr, self.elem0, self.numel, &views);
        drop(views);
        drop(rows);
        state.grads = grads;
        state.gc(iter);
        self.agg_time
    }

    fn loss(&mut self, iter: u64) -> Option<f32> {
        self.state.borrow().mean_loss(iter)
    }
}

/// A `hier` rack relay: records each rack worker's delivery mask (what
/// the rack-local wire actually delivered); the root multiplies its own
/// trunk masks on top and runs the update. The relay performs no
/// parameter math, mirroring how the in-network reduce only combines
/// already-masked data.
struct NativeRelay {
    state: Rc<RefCell<NativeState>>,
    first_worker: usize,
    n_workers: usize,
    numel: usize,
    seg_map: SegmentMap,
    agg_time: Nanos,
}

impl Aggregate for NativeRelay {
    fn aggregate(&mut self, iter: u64, arrivals: &[Option<(Bitmap, u64)>]) -> Nanos {
        let mut state = self.state.borrow_mut();
        for j in 0..self.n_workers {
            let mask = flow_mask(&self.seg_map, &arrivals[j], self.numel);
            state.masks.insert((self.first_worker + j, iter), mask);
        }
        self.agg_time
    }

    fn loss(&mut self, iter: u64) -> Option<f32> {
        self.state.borrow().mean_loss(iter)
    }
}

/// The `hier` root: combines every worker's rack-tier mask with the
/// rack→root trunk delivery mask, then runs the same masked-mean SGD as a
/// single PS — in global worker order, so zero-loss runs are bit-identical
/// to the `ps` topology.
struct NativeRoot {
    cfg: Arc<NativeBackend>,
    state: Rc<RefCell<NativeState>>,
    racks: usize,
    per_rack: usize,
    seg_map: SegmentMap,
    agg_time: Nanos,
}

impl Aggregate for NativeRoot {
    fn aggregate(&mut self, iter: u64, arrivals: &[Option<(Bitmap, u64)>]) -> Nanos {
        let numel = self.cfg.param_count();
        let state = &mut *self.state.borrow_mut();
        let trunk_masks: Vec<Vec<f32>> = (0..self.racks)
            .map(|r| flow_mask(&self.seg_map, &arrivals[r], numel))
            .collect();
        let grads = std::mem::take(&mut state.grads);
        let masks = std::mem::take(&mut state.masks);
        let mut rows: Vec<(&[f32], Vec<f32>)> = Vec::with_capacity(self.racks * self.per_rack);
        for w in 0..self.racks * self.per_rack {
            let Some(g) = grads.get(&(w, iter)) else { continue };
            let trunk = &trunk_masks[w / self.per_rack.max(1)];
            let mask: Vec<f32> = match masks.get(&(w, iter)) {
                Some(rack_mask) => {
                    rack_mask.iter().zip(trunk).map(|(&a, &b)| a * b).collect()
                }
                None => trunk.clone(),
            };
            rows.push((g.as_slice(), mask));
        }
        let views: Vec<(&[f32], &[f32])> =
            rows.iter().map(|&(g, ref m)| (g, m.as_slice())).collect();
        masked_mean_sgd(state, self.cfg.fill, self.cfg.lr, 0, numel, &views);
        drop(views);
        drop(rows);
        state.grads = grads;
        state.masks = masks;
        state.gc(iter);
        self.agg_time
    }

    fn loss(&mut self, iter: u64) -> Option<f32> {
        self.state.borrow().mean_loss(iter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::parse_backend;

    fn native(spec: &str) -> BackendSpec {
        parse_backend(spec).unwrap()
    }

    fn open(spec: &str, seed: u64, workers: usize) -> Box<dyn TrainSession> {
        let b = native(spec);
        let info = b.model().unwrap();
        let roles = vec![EndpointRole::Final { byte_offset: 0, bytes: info.wire_bytes }];
        b.open(&RunCtx {
            seed,
            n_workers: workers,
            compute_time: crate::MS,
            agg_time: crate::MS,
            roles,
            codec: crate::codec::default_codec(),
        })
        .unwrap()
    }

    /// Drive a bare BSP loop with full delivery (no simulator): compute on
    /// every worker, aggregate, repeat.
    fn train_inline(session: &mut Box<dyn TrainSession>, workers: usize, iters: u64) -> Vec<f32> {
        let mut computes: Vec<Box<dyn Compute>> =
            (0..workers).map(|w| session.make_compute(w)).collect();
        let mut agg = session.make_agg(0);
        let arrivals: Vec<Option<(Bitmap, u64)>> = (0..workers).map(|_| None).collect();
        let mut losses = Vec::new();
        for iter in 0..iters {
            for (w, c) in computes.iter_mut().enumerate() {
                c.compute(w, iter);
            }
            agg.aggregate(iter, &arrivals);
            losses.push(agg.loss(iter).expect("losses recorded"));
        }
        losses
    }

    #[test]
    fn inline_training_reduces_loss_and_reaches_high_accuracy() {
        let workers = 4;
        let mut s = open("native", 7, workers);
        let losses = train_inline(&mut s, workers, 12);
        let first = losses[0];
        let last = *losses.last().unwrap();
        assert!(
            last < first * 0.5,
            "loss must drop under full delivery: {first} → {last} ({losses:?})"
        );
        let stats = s.stats(&[]);
        assert!(
            stats.accuracy > 0.97,
            "separable blobs must classify: accuracy {}",
            stats.accuracy
        );
        assert!(stats.final_loss < 0.5, "eval loss {}", stats.final_loss);
    }

    #[test]
    fn same_seed_is_bit_identical_and_seeds_differ() {
        let run = |seed| {
            let mut s = open("native", seed, 2);
            let losses = train_inline(&mut s, 2, 4);
            (losses, s.params())
        };
        let (l1, p1) = run(3);
        let (l2, p2) = run(3);
        assert_eq!(l1, l2, "same seed must replay bit-identically");
        assert_eq!(p1, p2);
        let (l3, _) = run(4);
        assert_ne!(l1, l3, "a different seed must change the run");
    }

    /// Open a session, run one compute step on each of two workers, then
    /// aggregate with the given arrival bitmaps and return the parameters.
    fn one_step(b: &BackendSpec, arrivals: &[Option<(Bitmap, u64)>]) -> Vec<f32> {
        let info = b.model().unwrap();
        let mut s = b
            .open(&RunCtx {
                seed: 9,
                n_workers: 2,
                compute_time: crate::MS,
                agg_time: crate::MS,
                roles: vec![EndpointRole::Final { byte_offset: 0, bytes: info.wire_bytes }],
                codec: crate::codec::default_codec(),
            })
            .unwrap();
        let mut cs: Vec<Box<dyn Compute>> = (0..2).map(|w| s.make_compute(w)).collect();
        for (w, c) in cs.iter_mut().enumerate() {
            c.compute(w, 0);
        }
        let mut agg = s.make_agg(0);
        agg.aggregate(0, arrivals);
        s.params()
    }

    #[test]
    fn bubbled_elements_are_driven_by_delivering_workers_alone() {
        // Masking property, asserted bit-for-bit: wherever worker 0's mask
        // is zero, the masked-mean update must equal the update of a run
        // where worker 0 delivered *nothing* — those elements see only
        // worker 1's gradient. The model must span ≥2 wire segments so
        // "lost segment 0" differs from "lost everything": 676 params =
        // 2704 bytes = two 1460-byte segments.
        let b = native("native:dim=16,layers=1,hidden=32,classes=4");
        let info = b.model().unwrap();
        assert!(info.wire_bytes > 1460 && info.wire_bytes <= 2 * 1460, "{}", info.wire_bytes);
        let map =
            SegmentMap::new(info.wire_bytes, Manifest::aligned_payload(LTP_MSS), vec![]);
        let numel = (info.wire_bytes / 4) as usize;
        // Worker 0 lost segment 0; worker 1 (reliable) delivered all.
        let mut bm = Bitmap::new(map.n_segs as usize);
        for seg in 1..map.n_segs as usize {
            bm.set(seg);
        }
        let partial = one_step(&b, &[Some((bm.clone(), map.n_segs as u64)), None]);
        // Worker 0 lost everything.
        let empty = Bitmap::new(map.n_segs as usize);
        let solo = one_step(&b, &[Some((empty, map.n_segs as u64)), None]);
        let m0 = element_mask(&map, &bm, numel);
        assert!(m0.iter().any(|&m| m == 0.0) && m0.iter().any(|&m| m == 1.0));
        for i in 0..numel {
            if m0[i] == 0.0 {
                assert_eq!(
                    partial[i], solo[i],
                    "elem {i}: a bubbled element must be driven by the delivering worker alone"
                );
            }
        }
        // Elsewhere worker 0 contributed, so the runs differ…
        assert_ne!(partial, solo);
        // …and both moved off the (seed-identical) initial parameters.
        let full = one_step(&b, &[None, None]);
        assert_ne!(partial, full, "losing a segment must change the update");
    }

    #[test]
    fn fill_off_biases_the_update_toward_zero() {
        // One worker, half the segments lost: with bubble filling the
        // delivered elements update at full magnitude; without it the same
        // elements update identically (n=1 either way) but *lost* elements
        // pull momentum toward zero in both. The observable difference
        // needs ≥2 workers: worker 0 lost, worker 1 delivered — fill=on
        // averages over 1 contributor, fill=off over 2.
        let mk = |spec: &str| {
            let b = native(spec);
            let info = b.model().unwrap();
            let mut s = b
                .open(&RunCtx {
                    seed: 21,
                    n_workers: 2,
                    compute_time: crate::MS,
                    agg_time: crate::MS,
                    roles: vec![EndpointRole::Final {
                        byte_offset: 0,
                        bytes: info.wire_bytes,
                    }],
                    codec: crate::codec::default_codec(),
                })
                .unwrap();
            let mut cs: Vec<Box<dyn Compute>> = (0..2).map(|w| s.make_compute(w)).collect();
            for (w, c) in cs.iter_mut().enumerate() {
                c.compute(w, 0);
            }
            let map = SegmentMap::new(
                info.wire_bytes,
                Manifest::aligned_payload(LTP_MSS),
                vec![],
            );
            let empty = Bitmap::new(map.n_segs as usize);
            let mut agg = s.make_agg(0);
            agg.aggregate(0, &[Some((empty, map.n_segs as u64)), None]);
            s.params()
        };
        let p_fill = mk("native:dim=8,layers=1,hidden=8,classes=2");
        let p_nofill = mk("native:dim=8,layers=1,hidden=8,classes=2,fill=off");
        assert_ne!(p_fill, p_nofill, "the ablation must change the update");
    }

    #[test]
    fn stats_report_iters_to_target() {
        let s = open("native:target=1", 7, 2);
        let iters: Vec<IterStats> = [2.0f32, 1.4, 0.9, 0.5]
            .iter()
            .map(|&l| IterStats { loss: Some(l), ..Default::default() })
            .collect();
        assert_eq!(s.stats(&iters).iters_to_target, Some(3));
        let never: Vec<IterStats> =
            vec![IterStats { loss: Some(5.0), ..Default::default() }];
        assert_eq!(s.stats(&never).iters_to_target, None);
    }
}
