//! The `xla` backend: PJRT execution of the AOT-compiled JAX/Pallas
//! artifacts (`train_step`/`aggregate`/`eval` HLO, `make artifacts`),
//! wrapping the pre-existing [`RealTraining`]/[`RealCompute`]/
//! [`XlaAggregate`] machinery behind the [`Backend`] trait so its
//! preconditions fail fast with a message that names the actual missing
//! dependency (the artifacts, or the PJRT runtime itself in offline
//! builds that vendor the stub `xla` crate).

use super::{parse_rate, Backend, BackendSpec, ModelInfo, RunCtx, TrainSession, TrainStats};
use crate::config::ModelManifest;
use crate::grad::Manifest;
use crate::ps::spec::{canonical, unknown_param};
use crate::ps::{
    Aggregate, Compute, Corpus, EndpointRole, IterStats, RealCompute, RealTraining,
    XlaAggregate,
};
use crate::runtime::{default_artifacts_dir, Runtime};
use crate::wire::LTP_MSS;
use anyhow::{ensure, Context, Result};
use std::rc::Rc;
use std::sync::Arc;

/// Base stream id for the per-worker training corpora (mixed with the
/// run seed, so seed sweeps actually vary the data; the model *init*
/// comes from the AOT `init` artifact and is necessarily seed-fixed).
const WORKER_CORPUS_BASE: u64 = 1000;
/// Base stream id for the held-out eval batch.
const EVAL_CORPUS_SEED: u64 = 4242;

/// Mix the run seed into a corpus stream id (splitmix-style odd
/// multiplier keeps distinct (seed, stream) pairs distinct).
fn corpus_seed(run_seed: u64, stream: u64) -> u64 {
    run_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ stream
}

#[derive(Debug, Clone)]
pub struct XlaBackend {
    preset: String,
    lr: f32,
    /// Training-loss target for `iters_to_target` (fig 13 uses 4.8).
    target: f32,
    spec: String,
}

pub(super) fn build_xla(params: &[(String, String)]) -> Result<BackendSpec> {
    let (mut preset, mut lr, mut target) = (None, None, None);
    for (k, v) in params {
        match k.as_str() {
            "preset" => {
                ensure!(!v.is_empty(), "empty preset name");
                preset = Some(v.to_ascii_lowercase());
            }
            "lr" => lr = Some(parse_rate(k, v)?),
            "target" => target = Some(parse_rate(k, v)?),
            _ => return Err(unknown_param("xla", k, "preset, lr, target")),
        }
    }
    // Canonical order: preset, lr, target (rendered only when given).
    let mut parts = Vec::new();
    if let Some(p) = &preset {
        parts.push(format!("preset={p}"));
    }
    if let Some(x) = lr {
        parts.push(format!("lr={x}"));
    }
    if let Some(x) = target {
        parts.push(format!("target={x}"));
    }
    Ok(BackendSpec(Arc::new(XlaBackend {
        preset: preset.unwrap_or_else(|| "tiny".to_string()),
        lr: lr.unwrap_or(0.08),
        target: target.unwrap_or(4.8),
        spec: canonical("xla", &parts),
    })))
}

impl Backend for XlaBackend {
    fn name(&self) -> &str {
        &self.spec
    }

    fn check_ready(&self) -> Result<()> {
        let manifest = default_artifacts_dir().join(format!("manifest_{}.txt", self.preset));
        ensure!(
            manifest.exists(),
            "backend `xla` needs the AOT artifacts ({} missing) — run `make artifacts` \
             first, or use `--backend native` which needs none",
            manifest.display()
        );
        Ok(())
    }

    fn model(&self) -> Result<ModelInfo> {
        self.check_ready()?;
        let m = ModelManifest::load(default_artifacts_dir(), &self.preset)?;
        Ok(ModelInfo {
            wire_bytes: m.wire_bytes(),
            critical: m.tensors.critical_segments(Manifest::aligned_payload(LTP_MSS)),
        })
    }

    fn supports(&self, workers: usize, roles: &[EndpointRole]) -> Result<()> {
        ensure!(
            roles.len() == 1 && matches!(roles[0], EndpointRole::Final { byte_offset: 0, .. }),
            "backend `xla` aggregates the full model on a single PS (its Pallas kernel \
             spans the whole gradient); use `--agg ps`, or `--backend native` for \
             sharded/hierarchical aggregation"
        );
        // Worker capacity is baked into the aggregate artifact; check it at
        // build time when the manifest is readable (`check_ready` has
        // already failed the build otherwise).
        if let Ok(m) = ModelManifest::load(default_artifacts_dir(), &self.preset) {
            ensure!(
                workers <= m.agg_workers,
                "backend `xla` (preset `{}`): the aggregate artifact supports ≤{} workers, \
                 the run has {workers}",
                self.preset,
                m.agg_workers
            );
        }
        Ok(())
    }

    fn open(&self, run: &RunCtx) -> Result<Box<dyn TrainSession>> {
        self.check_ready()?;
        self.supports(run.n_workers, &run.roles)?;
        let rt = Runtime::cpu(default_artifacts_dir()).context("PJRT CPU client")?;
        let shared = RealTraining::new(&rt, &self.preset, self.lr)?;
        ensure!(
            run.n_workers <= shared.manifest.agg_workers,
            "aggregate artifact supports ≤{} workers, run has {}",
            shared.manifest.agg_workers,
            run.n_workers
        );
        Ok(Box::new(XlaSession {
            // The runtime owns the PJRT client; the loaded executables keep
            // it alive for the session's lifetime.
            _rt: rt,
            shared,
            n_workers: run.n_workers,
            seed: run.seed,
            target: self.target,
        }))
    }
}

struct XlaSession {
    _rt: Runtime,
    shared: Rc<RealTraining>,
    n_workers: usize,
    seed: u64,
    target: f32,
}

impl TrainSession for XlaSession {
    fn make_compute(&mut self, worker: usize) -> Box<dyn Compute> {
        Box::new(RealCompute {
            shared: self.shared.clone(),
            corpus: Corpus::new(
                self.shared.manifest.vocab,
                corpus_seed(self.seed, WORKER_CORPUS_BASE + worker as u64),
            ),
        })
    }

    fn make_agg(&mut self, _endpoint: usize) -> Box<dyn Aggregate> {
        Box::new(XlaAggregate { shared: self.shared.clone(), n_workers: self.n_workers })
    }

    fn params(&self) -> Vec<f32> {
        self.shared.blackboard.params().to_vec()
    }

    fn stats(&self, iters: &[IterStats]) -> TrainStats {
        let m = &self.shared.manifest;
        let tokens = Corpus::new(m.vocab, corpus_seed(self.seed, EVAL_CORPUS_SEED))
            .next_batch(m.batch, m.seq_len + 1);
        let final_loss = self
            .shared
            .eval_loss(&tokens)
            .unwrap_or_else(|e| panic!("eval artifact failed: {e:#}"));
        TrainStats {
            final_loss,
            // Per-token probability proxy for an LM: exp(-loss) is the
            // geometric-mean probability of the correct token.
            accuracy: (-(final_loss as f64)).exp(),
            iters_to_target: iters
                .iter()
                .position(|i| i.loss.map(|l| l <= self.target).unwrap_or(false))
                .map(|i| i as u64 + 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::parse_backend;

    #[test]
    fn xla_defaults_and_canonical_params() {
        let b = parse_backend("xla").unwrap();
        assert_eq!(b.name(), "xla");
        let b = parse_backend("xla:lr=0.05,preset=tiny").unwrap();
        assert_eq!(b.name(), "xla:preset=tiny,lr=0.05");
    }

    #[test]
    fn xla_rejects_multi_endpoint_roles() {
        let b = parse_backend("xla").unwrap();
        let single = [EndpointRole::Final { byte_offset: 0, bytes: 4096 }];
        assert!(b.supports(4, &single).is_ok());
        let sharded = [
            EndpointRole::Final { byte_offset: 0, bytes: 2048 },
            EndpointRole::Final { byte_offset: 2048, bytes: 2048 },
        ];
        let err = format!("{:#}", b.supports(4, &sharded).unwrap_err());
        assert!(err.contains("single PS"), "{err}");
        let hier = [
            EndpointRole::Relay { first_worker: 0, n_workers: 2 },
            EndpointRole::Relay { first_worker: 2, n_workers: 2 },
            EndpointRole::Root { racks: 2 },
        ];
        assert!(b.supports(4, &hier).is_err());
        // Worker capacity enforcement needs the manifest; with artifacts
        // present a run beyond `agg_workers` must fail at build time.
        if ltp_manifest_present() {
            let m = ModelManifest::load(default_artifacts_dir(), "tiny").unwrap();
            assert!(b.supports(m.agg_workers + 1, &single).is_err());
            assert!(b.supports(m.agg_workers, &single).is_ok());
        }
    }

    fn ltp_manifest_present() -> bool {
        default_artifacts_dir().join("manifest_tiny.txt").exists()
    }

    #[test]
    fn xla_check_ready_names_the_artifacts() {
        let b = parse_backend("xla:preset=definitely_not_built").unwrap();
        let err = format!("{:#}", b.check_ready().expect_err("preset never exists"));
        assert!(err.contains("make artifacts"), "{err}");
        assert!(err.contains("definitely_not_built"), "{err}");
    }

    #[test]
    fn corpus_streams_are_seed_and_worker_disjoint() {
        // Worker 0's corpus differs from worker 1's, from the eval stream,
        // and across run seeds (a seed sweep must actually vary the data).
        let mut a = Corpus::new(512, corpus_seed(1, WORKER_CORPUS_BASE));
        let mut b = Corpus::new(512, corpus_seed(1, WORKER_CORPUS_BASE + 1));
        let mut c = Corpus::new(512, corpus_seed(2, WORKER_CORPUS_BASE));
        let mut e = Corpus::new(512, corpus_seed(1, EVAL_CORPUS_SEED));
        let ba = a.next_batch(2, 8);
        assert_ne!(ba, b.next_batch(2, 8));
        assert_ne!(ba, c.next_batch(2, 8));
        assert_ne!(ba, e.next_batch(2, 8));
    }
}
