//! The pluggable **compute plane** (DESIGN.md §1.3) — the third pluggable
//! layer after transports (§1.1) and aggregation topologies (§1.2).
//!
//! A [`Backend`] supplies the *numerics* of a training run: what each
//! worker computes every iteration ([`crate::ps::Compute`]) and what each
//! aggregator endpoint does when its gathers close
//! ([`crate::ps::Aggregate`]). Backends are registered under string keys
//! and instantiated from specs reusing the transport/aggregation grammar
//! (`key[:name=value,...]`, [`parse_backend`]):
//!
//! * `native` — a deterministic pure-Rust trainer (seeded synthetic
//!   classification corpus, dense f32 MLP with a hand-written backward
//!   pass, momentum SGD, and a masked-mean aggregation that consumes
//!   [`crate::grad::element_mask`] exactly like the Pallas kernel). Runs
//!   everywhere, no artifacts needed — this is what makes the paper's
//!   accuracy-under-loss claims CI-assertable.
//! * `xla` — the PJRT/AOT path (`train_step`/`aggregate`/`eval` HLO
//!   artifacts produced by `make artifacts`); fails fast with an
//!   artifacts message when the AOT step has not run.
//!
//! A backend is thread-shareable configuration; each simulated run opens
//! its own single-threaded [`TrainSession`] (seeded from the run), so
//! sweep jobs stay pure functions of their inputs and `--jobs N` reports
//! remain byte-identical to serial ones. With a backend attached, a
//! [`crate::ps::RunReport`] carries a deterministic [`TrainStats`] block
//! (`final_loss`, `accuracy`, `iters_to_target`); with none attached the
//! report keeps its original byte layout.

mod native;
mod xla;

pub use native::NativeBackend;
pub use xla::XlaBackend;

use crate::ps::spec::parse_params;
use crate::ps::{Aggregate, Compute, EndpointRole, IterStats};
use crate::Nanos;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Deterministic training outcome of a backend-attached run, emitted into
/// the run report (and the scenario JSON) **only when a backend is
/// attached**, so default reports keep their golden bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainStats {
    /// Loss of the final parameters on the backend's held-out eval set.
    pub final_loss: f32,
    /// Accuracy of the final parameters on the held-out eval set
    /// (fraction correct for `native`; a per-token probability proxy,
    /// `exp(-loss)`, for the `xla` language model).
    pub accuracy: f64,
    /// 1-based count of BSP iterations until the mean training loss first
    /// reached the backend's `target`; `None` if it never did.
    pub iters_to_target: Option<u64>,
}

/// Wire-layout facts a backend derives deterministically from its
/// configuration: the run's message size and critical segment set.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    /// Gradient bytes on the wire per worker per iteration.
    pub wire_bytes: u64,
    /// Critical segment ids (tensor-boundary segments, paper §III-E).
    pub critical: Vec<u32>,
}

/// Per-run context handed to [`Backend::open`]: everything a session
/// needs to seed its corpus/init and to build one [`Aggregate`] per
/// aggregator endpoint of the run's topology.
#[derive(Debug, Clone)]
pub struct RunCtx {
    /// The run's master seed (task, init, and corpus streams derive from
    /// it).
    pub seed: u64,
    pub n_workers: usize,
    /// Simulated duration of one worker compute step.
    pub compute_time: Nanos,
    /// Simulated duration of one aggregation.
    pub agg_time: Nanos,
    /// One role per aggregator endpoint, in endpoint order (from
    /// [`crate::ps::Aggregation::endpoint_roles`]).
    pub roles: Vec<EndpointRole>,
    /// The run's gradient codec (DESIGN.md §1.4). `dense` for classic
    /// runs; sparsifying codecs shrink the wire image and make the
    /// aggregator decode with loss-mask awareness.
    pub codec: crate::codec::CodecSpec,
}

/// A training backend: thread-shareable, registered under a string key,
/// instantiated from CLI specs like `native:dim=64,lr=0.1` or
/// `xla:preset=tiny`.
pub trait Backend: Send + Sync {
    /// Canonical spec string — the backend's label everywhere.
    fn name(&self) -> &str;

    /// Fail-fast precondition check, run at [`crate::ps::RunBuilder::build`]
    /// time. The error must name the backend's *actual* missing dependency
    /// (the `xla` backend needs `make artifacts`; `native` needs nothing).
    fn check_ready(&self) -> Result<()>;

    /// Deterministic wire layout of this backend's gradient.
    fn model(&self) -> Result<ModelInfo>;

    /// Can this backend serve a run with `workers` workers over the given
    /// aggregation-endpoint roles? The default accepts everything; `xla`
    /// restricts to a single full-model endpoint within its artifact's
    /// baked-in worker capacity.
    fn supports(&self, _workers: usize, _roles: &[EndpointRole]) -> Result<()> {
        Ok(())
    }

    /// Open a per-run, single-threaded training session.
    fn open(&self, run: &RunCtx) -> Result<Box<dyn TrainSession>>;
}

/// One run's training state: produces the per-worker [`Compute`] and
/// per-endpoint [`Aggregate`] objects wired into the simulation, and
/// distills the outcome afterwards. Sessions are single-threaded (they
/// live inside one simulated run) and deterministic in the run seed.
pub trait TrainSession {
    fn make_compute(&mut self, worker: usize) -> Box<dyn Compute>;

    /// Build the aggregation backend for endpoint `endpoint` (indexing
    /// [`RunCtx::roles`]).
    fn make_agg(&mut self, endpoint: usize) -> Box<dyn Aggregate>;

    /// The current flat parameter vector (tests assert cross-topology
    /// bit-identity on this).
    fn params(&self) -> Vec<f32>;

    /// Distill the run's deterministic training outcome from the merged
    /// iteration records.
    fn stats(&self, iters: &[IterStats]) -> TrainStats;
}

/// A parsed, validated backend spec: the handle stored in run
/// configurations and carried across worker threads by the sweep driver.
/// Clones share the underlying [`Backend`].
#[derive(Clone)]
pub struct BackendSpec(Arc<dyn Backend>);

impl BackendSpec {
    /// Canonical spec string — the backend's name everywhere (labels,
    /// JSON reports, bench records). Borrowed; no per-call allocation.
    pub fn name(&self) -> &str {
        self.0.name()
    }
}

impl std::ops::Deref for BackendSpec {
    type Target = dyn Backend;

    fn deref(&self) -> &(dyn Backend + 'static) {
        &*self.0
    }
}

impl std::fmt::Display for BackendSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::fmt::Debug for BackendSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BackendSpec({})", self.name())
    }
}

/// Two specs are equal iff their canonical names are.
impl PartialEq for BackendSpec {
    fn eq(&self, other: &BackendSpec) -> bool {
        self.name() == other.name()
    }
}

impl std::str::FromStr for BackendSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<BackendSpec> {
        parse_backend(s)
    }
}

/// One registered backend family.
pub struct BackendDef {
    /// Spec key (`--backend <key>[:params]`).
    pub key: &'static str,
    pub summary: &'static str,
    /// Accepted `name=value` parameters, for `ltp backend list`.
    pub params: &'static str,
    build: fn(&[(String, String)]) -> Result<BackendSpec>,
}

/// The backend registry. Append entries here (and their implementations
/// in this module); the CLI (`--backend`, `ltp backend list`), the
/// `accuracy_matrix` scenario, and the conformance tests follow.
pub const BACKEND_REGISTRY: &[BackendDef] = &[
    BackendDef {
        key: "native",
        summary: "deterministic pure-Rust MLP trainer (synthetic corpus, masked-mean SGD)",
        params: "dim=<features>, layers=<hidden>, hidden=<width>, classes=<C>, lr=<rate>, \
                 fill=<on|off>, target=<loss>",
        build: native::build_native,
    },
    BackendDef {
        key: "xla",
        summary: "PJRT execution of the AOT-compiled JAX/Pallas artifacts (needs `make artifacts`)",
        params: "preset=<name>, lr=<rate>, target=<loss>",
        build: xla::build_xla,
    },
];

/// The registry (function form, for iteration symmetry with the protocol,
/// aggregation, and scenario registries).
pub fn backend_registry() -> &'static [BackendDef] {
    BACKEND_REGISTRY
}

/// Parse a backend spec (`native`, `native:dim=64,fill=off`,
/// `xla:preset=tiny`) against the registry.
pub fn parse_backend(spec: &str) -> Result<BackendSpec> {
    let spec = spec.trim();
    let (key, rest) = match spec.split_once(':') {
        Some((k, r)) => (k, Some(r)),
        None => (spec, None),
    };
    let key = key.to_ascii_lowercase();
    let Some(def) = BACKEND_REGISTRY.iter().find(|d| d.key == key) else {
        let known: Vec<&str> = BACKEND_REGISTRY.iter().map(|d| d.key).collect();
        bail!("unknown backend `{key}` in spec `{spec}` (known: {})", known.join(", "));
    };
    let params = parse_params(rest).with_context(|| format!("in backend spec `{spec}`"))?;
    (def.build)(&params).with_context(|| format!("in backend spec `{spec}`"))
}

// ---------------------------------------------------------------------------
// Shared parameter-value helpers for the backend builders.
// ---------------------------------------------------------------------------

fn parse_count(key: &str, v: &str) -> Result<usize> {
    let n: usize = v.parse().with_context(|| format!("bad value for `{key}`: `{v}`"))?;
    if n == 0 {
        bail!("`{key}=0`: need at least one");
    }
    Ok(n)
}

fn parse_rate(key: &str, v: &str) -> Result<f32> {
    let x: f32 = v.parse().with_context(|| format!("bad value for `{key}`: `{v}`"))?;
    if !(x > 0.0 && x.is_finite()) {
        bail!("`{key}={v}` out of range (need a positive finite value)");
    }
    Ok(x)
}

pub(crate) fn parse_switch(key: &str, v: &str) -> Result<bool> {
    match v.to_ascii_lowercase().as_str() {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        _ => bail!("bad value for `{key}`: `{v}` (expected on|off)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_parse_with_canonical_names() {
        for (spec, canon) in [
            ("native", "native"),
            ("NATIVE", "native"),
            ("native:dim=64", "native:dim=64"),
            ("native:fill=off", "native:fill=off"),
            ("xla", "xla"),
            ("XLA:preset=tiny", "xla:preset=tiny"),
        ] {
            let b = parse_backend(spec).unwrap_or_else(|e| panic!("{spec}: {e:#}"));
            assert_eq!(b.name(), canon, "{spec}");
            // Canonical form is a fixed point of the grammar.
            assert_eq!(parse_backend(b.name()).unwrap().name(), canon, "{spec}");
        }
    }

    #[test]
    fn parameter_order_normalizes() {
        let b = parse_backend("native:lr=0.2,dim=16").unwrap();
        assert_eq!(b.name(), "native:dim=16,lr=0.2");
    }

    #[test]
    fn spec_equality_is_canonical() {
        assert_eq!(parse_backend("native").unwrap(), parse_backend("NATIVE").unwrap());
        assert_ne!(
            parse_backend("native").unwrap(),
            parse_backend("native:dim=16").unwrap()
        );
    }

    #[test]
    fn malformed_specs_are_rejected() {
        for bad in [
            "torch",                 // unknown backend
            "native:",               // empty parameter list
            "native:dim",            // malformed parameter
            "native:dim=",           // empty value
            "native:dim=0",          // zero
            "native:dim=x",          // non-numeric
            "native:dim=8,dim=9",    // duplicate parameter
            "native:lr=-1",          // out of range
            "native:lr=nope",        // non-numeric
            "native:fill=maybe",     // bad switch
            "native:window=3",       // unknown parameter
            "xla:foo=1",             // unknown parameter
            "xla:lr=0",              // out of range
        ] {
            assert!(parse_backend(bad).is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn registry_is_well_formed() {
        let mut keys: Vec<&str> = BACKEND_REGISTRY.iter().map(|d| d.key).collect();
        assert!(keys.contains(&"native") && keys.contains(&"xla"));
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), BACKEND_REGISTRY.len(), "backend keys must be unique");
    }

    #[test]
    fn native_is_ready_everywhere() {
        parse_backend("native").unwrap().check_ready().unwrap();
    }

    #[test]
    fn native_model_info_is_deterministic() {
        let b = parse_backend("native").unwrap();
        let a = b.model().unwrap();
        let c = b.model().unwrap();
        assert_eq!(a.wire_bytes, c.wire_bytes);
        assert_eq!(a.critical, c.critical);
        assert!(a.wire_bytes > 0 && a.wire_bytes % 4 == 0);
        assert!(!a.critical.is_empty(), "tensor boundaries produce criticals");
    }
}
