//! The tensor manifest: the model layout both ends of a DML flow share.
//! It determines the message size, the float32-aligned segment payload
//! (padding bubbles), and which segments are critical (tensor-boundary
//! bytes, paper §III-E).

use crate::proto::SegmentMap;

/// Gradient element alignment in bytes (float32). Segment payloads are a
/// multiple of this, so a lost packet can never split an element — the
/// *padding bubble* rule of paper Fig 8(b).
pub const ALIGN: u32 = 4;

/// One named tensor of `numel` float32 elements.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub numel: usize,
}

/// Ordered tensor list; the flattened gradient is their concatenation.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub tensors: Vec<TensorSpec>,
}

impl Manifest {
    pub fn new(tensors: Vec<(&str, usize)>) -> Manifest {
        Manifest {
            tensors: tensors
                .into_iter()
                .map(|(n, e)| TensorSpec { name: n.to_string(), numel: e })
                .collect(),
        }
    }

    /// A synthetic manifest of `total_bytes` split into roughly equal
    /// "layers" — used for modeled workloads (ResNet50 = 98 MB, VGG16 =
    /// 528 MB) where only the wire size matters.
    pub fn synthetic(total_bytes: u64, n_layers: usize) -> Manifest {
        let total_elems = (total_bytes / ALIGN as u64) as usize;
        let per = total_elems / n_layers.max(1);
        let mut tensors = Vec::new();
        let mut left = total_elems;
        for i in 0..n_layers {
            let n = if i + 1 == n_layers { left } else { per };
            tensors.push(TensorSpec { name: format!("layer{i}"), numel: n });
            left -= n;
        }
        Manifest { tensors }
    }

    pub fn total_elems(&self) -> usize {
        self.tensors.iter().map(|t| t.numel).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_elems() as u64 * ALIGN as u64
    }

    /// Largest float32-aligned payload that fits in `mss` bytes.
    pub fn aligned_payload(mss: u32) -> u32 {
        (mss / ALIGN) * ALIGN
    }

    /// Byte offset where each tensor starts.
    pub fn tensor_offsets(&self) -> Vec<u64> {
        let mut offs = Vec::with_capacity(self.tensors.len());
        let mut off = 0u64;
        for t in &self.tensors {
            offs.push(off);
            off += t.numel as u64 * ALIGN as u64;
        }
        offs
    }

    /// Critical segment ids for a given segment payload: the first and last
    /// segment of every tensor's byte range (the paper marks "several bytes
    /// on the first and last part of the matrix bitstream" as critical).
    pub fn critical_segments(&self, seg_payload: u32) -> Vec<u32> {
        assert_eq!(seg_payload % ALIGN, 0, "segment payload must be f32-aligned");
        let mut crit = Vec::new();
        let mut off = 0u64;
        for t in &self.tensors {
            let bytes = t.numel as u64 * ALIGN as u64;
            if bytes == 0 {
                continue;
            }
            let first = off / seg_payload as u64;
            let last = (off + bytes - 1) / seg_payload as u64;
            crit.push(first as u32);
            crit.push(last as u32);
            off += bytes;
        }
        crit.sort_unstable();
        crit.dedup();
        crit
    }

    /// Build the transport segmentation for this manifest.
    pub fn segment_map(&self, mss: u32) -> SegmentMap {
        let payload = Self::aligned_payload(mss);
        SegmentMap::new(self.total_bytes(), payload, self.critical_segments(payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_payload_is_multiple_of_four() {
        assert_eq!(Manifest::aligned_payload(1463), 1460);
        assert_eq!(Manifest::aligned_payload(1460), 1460);
        assert_eq!(Manifest::aligned_payload(7), 4);
    }

    #[test]
    fn synthetic_manifest_sizes() {
        let m = Manifest::synthetic(98 * 1_000_000, 50);
        assert_eq!(m.total_bytes(), 98 * 1_000_000);
        assert_eq!(m.tensors.len(), 50);
    }

    #[test]
    fn critical_segments_cover_tensor_boundaries() {
        // Two tensors: 1000 and 500 elements = 4000 B + 2000 B.
        let m = Manifest::new(vec![("a", 1000), ("b", 500)]);
        let crit = m.critical_segments(1460);
        // Tensor a: bytes [0,4000) → segs 0..=2; tensor b: [4000,6000) →
        // segs 2..=4.
        assert_eq!(crit, vec![0, 2, 4]);
    }

    #[test]
    fn segment_map_matches_total() {
        let m = Manifest::new(vec![("a", 730), ("b", 365)]);
        let map = m.segment_map(1463);
        assert_eq!(map.total_bytes(), m.total_bytes());
        assert_eq!(map.seg_payload % ALIGN, 0);
        assert!(map.is_critical(0));
    }

    #[test]
    fn offsets_accumulate() {
        let m = Manifest::new(vec![("a", 10), ("b", 20), ("c", 30)]);
        assert_eq!(m.tensor_offsets(), vec![0, 40, 120]);
    }
}
