//! Bubble-filling (paper §III-C): reconstruct a gradient buffer from the
//! segments that actually arrived, zero-filling the holes (*packet
//! bubbles*), and derive the per-element arrival mask the PS aggregation
//! kernel divides by.

use super::ALIGN;
use crate::proto::SegmentMap;
use crate::util::Bitmap;

/// Reassemble a message: bytes of received segments are copied from `src`
/// (the sender's flattened gradient — in-process transfer), missing
/// segments become zeros.
pub fn bubble_fill(src: &[u8], map: &SegmentMap, received: &Bitmap) -> Vec<u8> {
    let mut out = vec![0u8; map.total_bytes() as usize];
    bubble_fill_into(src, map, received, &mut out);
    out
}

/// [`bubble_fill`] into a caller-provided buffer (hot path: the PS reuses
/// one buffer per worker). `out` must be `map.total_bytes()` long and is
/// fully overwritten.
pub fn bubble_fill_into(src: &[u8], map: &SegmentMap, received: &Bitmap, out: &mut [u8]) {
    assert_eq!(out.len() as u64, map.total_bytes());
    assert_eq!(src.len() as u64, map.total_bytes());
    for seg in 0..map.n_segs {
        let (a, b) = map.byte_range(seg);
        let (a, b) = (a as usize, b as usize);
        if received.get(seg as usize) {
            out[a..b].copy_from_slice(&src[a..b]);
        } else {
            out[a..b].fill(0);
        }
    }
}

/// Per-element arrival mask (1.0 = element arrived, 0.0 = bubble), fed to
/// the masked-mean aggregation kernel. `numel` = total f32 elements.
pub fn element_mask(map: &SegmentMap, received: &Bitmap, numel: usize) -> Vec<f32> {
    assert_eq!(map.seg_payload % ALIGN, 0, "padding-bubble invariant violated");
    let mut mask = vec![0.0f32; numel];
    let per_seg = (map.seg_payload / ALIGN) as usize;
    for seg in 0..map.n_segs as usize {
        if received.get(seg) {
            let a = seg * per_seg;
            let b = (a + (map.payload_len(seg as u32) / ALIGN) as usize).min(numel);
            mask[a..b].fill(1.0);
        }
    }
    mask
}

/// Demonstration of paper Fig 8(a): what goes wrong *without* padding
/// bubbles. Splits a float across a packet boundary, zero-fills one half,
/// and returns `(aligned_value, corrupted_value)` for the affected element.
pub fn misaligned_corruption_demo(value: f32) -> (f32, f32) {
    let bytes = value.to_le_bytes();
    // Aligned loss: the whole element is zeroed → 0.0 (a harmless bubble).
    let aligned = 0.0f32;
    // Misaligned loss: the packet boundary falls mid-element; the first two
    // bytes survive, the last two are zero-filled.
    let corrupted = f32::from_le_bytes([bytes[0], bytes[1], 0, 0]);
    (aligned, corrupted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn map_of(bytes: u64) -> SegmentMap {
        SegmentMap::new(bytes, 1460, vec![])
    }

    fn full_bitmap(n: u32) -> Bitmap {
        let mut b = Bitmap::new(n as usize);
        for i in 0..n as usize {
            b.set(i);
        }
        b
    }

    #[test]
    fn full_reception_is_identity() {
        let src: Vec<u8> = (0..5000u32).map(|i| (i % 251) as u8).collect();
        let map = map_of(5000);
        let out = bubble_fill(&src, &map, &full_bitmap(map.n_segs));
        assert_eq!(out, src);
    }

    #[test]
    fn missing_segment_becomes_zeros() {
        let src = vec![0xABu8; 4380]; // 3 segments
        let map = map_of(4380);
        let mut rec = full_bitmap(map.n_segs);
        rec = {
            let mut b = Bitmap::new(3);
            b.set(0);
            b.set(2);
            let _ = rec;
            b
        };
        let out = bubble_fill(&src, &map, &rec);
        assert!(out[..1460].iter().all(|&b| b == 0xAB));
        assert!(out[1460..2920].iter().all(|&b| b == 0));
        assert!(out[2920..].iter().all(|&b| b == 0xAB));
    }

    #[test]
    fn element_mask_matches_segments() {
        let map = map_of(2920); // 2 segs × 365 floats
        let mut rec = Bitmap::new(2);
        rec.set(1);
        let mask = element_mask(&map, &rec, 730);
        assert!(mask[..365].iter().all(|&m| m == 0.0));
        assert!(mask[365..].iter().all(|&m| m == 1.0));
    }

    #[test]
    fn bubbles_zero_whole_floats_only() {
        // Fill src with a known pattern of floats; lose a segment; every
        // reconstructed float must be either its original value or exactly
        // 0.0 — never a bit-mangled hybrid (the Fig 8 property).
        let numel = 1460 / 4 * 3;
        let src_f: Vec<f32> = (0..numel).map(|i| (i as f32 + 0.5) * 1.25e-3).collect();
        let src: Vec<u8> = src_f.iter().flat_map(|f| f.to_le_bytes()).collect();
        let map = map_of(src.len() as u64);
        let mut rec = full_bitmap(map.n_segs);
        let _ = rec.set(0); // make mutable use consistent
        let mut partial = Bitmap::new(map.n_segs as usize);
        partial.set(0);
        partial.set(2);
        let out = bubble_fill(&src, &map, &partial);
        for (i, orig) in src_f.iter().enumerate() {
            let v = f32::from_le_bytes(out[i * 4..i * 4 + 4].try_into().unwrap());
            assert!(
                v == *orig || v == 0.0,
                "element {i} is a hybrid: {v} (orig {orig})"
            );
        }
    }

    #[test]
    fn misalignment_demo_shows_corruption() {
        let (aligned, corrupted) = misaligned_corruption_demo(1.0e10);
        assert_eq!(aligned, 0.0);
        assert_ne!(corrupted, 0.0);
        assert_ne!(corrupted, 1.0e10);
    }

    #[test]
    fn prop_bubble_oracle_random_maps_and_masks() {
        // `bubble_fill` + `element_mask` against a brute-force per-byte /
        // per-element reference, over random segment maps (random aligned
        // payload sizes, partial final-segment tails) and random loss
        // masks — with the all-lost and all-received edges forced so they
        // are exercised every run, not just when the dice land there.
        check("bubble oracle", |rng| {
            // Aligned payload: 1..=64 f32 elements per segment.
            let per_seg = 1 + rng.gen_range(64) as usize;
            let payload = (per_seg * ALIGN as usize) as u32;
            // 4-aligned totals (gradients are f32-flat); the tail segment
            // is partial unless numel happens to divide evenly.
            let numel = 1 + rng.gen_range(3000) as usize;
            let bytes = (numel * ALIGN as usize) as u64;
            let map = SegmentMap::new(bytes, payload, vec![]);
            let mut rec = Bitmap::new(map.n_segs as usize);
            // mode 0: all lost; mode 1: all received; otherwise random.
            let mode = rng.gen_range(4);
            for s in 0..map.n_segs as usize {
                let keep = match mode {
                    0 => false,
                    1 => true,
                    _ => rng.chance(0.5),
                };
                if keep {
                    rec.set(s);
                }
            }
            let src: Vec<u8> = (0..bytes).map(|_| rng.next_u32() as u8).collect();
            let out = bubble_fill(&src, &map, &rec);
            assert_eq!(out.len() as u64, bytes);
            for (b, (&got, &want_src)) in out.iter().zip(&src).enumerate() {
                let seg = b / payload as usize;
                let want = if rec.get(seg) { want_src } else { 0 };
                assert_eq!(got, want, "byte {b} of segment {seg} (mode {mode})");
            }
            let mask = element_mask(&map, &rec, numel);
            assert_eq!(mask.len(), numel);
            for (i, &m) in mask.iter().enumerate() {
                // Brute force: an element arrived iff the segment holding
                // its 4 bytes did (the padding-bubble rule guarantees the
                // element cannot straddle two segments).
                let seg = (i * ALIGN as usize) / payload as usize;
                let want = if rec.get(seg) { 1.0 } else { 0.0 };
                assert_eq!(m, want, "elem {i} in segment {seg} (mode {mode})");
            }
            match mode {
                0 => {
                    assert!(out.iter().all(|&b| b == 0), "all-lost fills zeros");
                    assert!(mask.iter().all(|&m| m == 0.0));
                }
                1 => {
                    assert_eq!(out, src, "all-received is the identity");
                    assert!(mask.iter().all(|&m| m == 1.0));
                }
                _ => {}
            }
        });
    }

    #[test]
    fn prop_bubble_fill_roundtrip_arbitrary_loss() {
        check("bubble fill", |rng| {
            let bytes = 400 + rng.gen_range(20_000);
            let map = SegmentMap::new(bytes, 1460, vec![]);
            let src: Vec<u8> = (0..bytes).map(|_| rng.next_u32() as u8).collect();
            let mut rec = Bitmap::new(map.n_segs as usize);
            for s in 0..map.n_segs as usize {
                if rng.chance(0.7) {
                    rec.set(s);
                }
            }
            let out = bubble_fill(&src, &map, &rec);
            assert_eq!(out.len() as u64, bytes);
            for seg in 0..map.n_segs {
                let (a, b) = map.byte_range(seg);
                let (a, b) = (a as usize, b as usize);
                if rec.get(seg as usize) {
                    assert_eq!(&out[a..b], &src[a..b]);
                } else {
                    assert!(out[a..b].iter().all(|&x| x == 0));
                }
            }
            // Mask agrees with bitmap at float granularity.
            let numel = (bytes / 4) as usize;
            let mask = element_mask(&map, &rec, numel);
            for (i, &m) in mask.iter().enumerate() {
                let seg = (i * 4) as u64 / map.seg_payload as u64;
                assert_eq!(m == 1.0, rec.get(seg as usize), "elem {i} seg {seg}");
            }
        });
    }
}
