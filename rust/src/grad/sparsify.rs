//! Gradient sparsification references (paper §II-C): Random-k and Top-k,
//! plus error feedback (residual accumulation — the standard companion
//! that keeps sparsified SGD convergent). These are the CPU references for
//! the Fig 5 experiment; the L1 Pallas kernels implement the same math.

use crate::util::Pcg64;

/// Keep a random `k` fraction of elements (zero the rest). Returns the
/// number of kept elements.
pub fn random_k(grad: &mut [f32], k: f64, rng: &mut Pcg64) -> usize {
    let n = grad.len();
    let keep = ((n as f64 * k).round() as usize).min(n);
    if keep == n {
        return n;
    }
    // Zero everything, then restore a random subset: done in-place by
    // sampling the keep-set and zeroing the complement via a mark pass.
    let keep_idx = rng.sample_indices(n, keep);
    let mut marks = vec![false; n];
    for &i in &keep_idx {
        marks[i] = true;
    }
    for (g, m) in grad.iter_mut().zip(&marks) {
        if !m {
            *g = 0.0;
        }
    }
    keep
}

/// Keep the `k` fraction with the largest |value| (zero the rest). Returns
/// the number of kept elements.
pub fn top_k(grad: &mut [f32], k: f64) -> usize {
    let n = grad.len();
    let keep = ((n as f64 * k).round() as usize).min(n);
    if keep == n || keep == 0 {
        if keep == 0 {
            grad.fill(0.0);
        }
        return keep;
    }
    // Threshold via select_nth on |g| (O(n) average).
    let mut mags: Vec<f32> = grad.iter().map(|g| g.abs()).collect();
    let nth = n - keep;
    mags.select_nth_unstable_by(nth, |a, b| a.partial_cmp(b).unwrap());
    let thresh = mags[nth];
    // Keep strictly-above first, then fill ties up to `keep`.
    let mut kept = grad.iter().filter(|g| g.abs() > thresh).count();
    let mut ties_allowed = keep.saturating_sub(kept);
    for g in grad.iter_mut() {
        let a = g.abs();
        if a > thresh {
            continue;
        }
        if a == thresh && ties_allowed > 0 {
            ties_allowed -= 1;
            kept += 1;
            continue;
        }
        *g = 0.0;
    }
    kept
}

/// Indices of the `keep` elements with the largest |value|, sorted
/// ascending — the index plane of a top-k (index, value) wire packing.
/// Selection matches [`top_k`] exactly (strictly-above-threshold elements
/// first, then threshold ties in ascending index order), so zeroing every
/// index *not* returned reproduces `top_k`'s output bit-for-bit.
pub fn top_k_indices(grad: &[f32], keep: usize) -> Vec<u32> {
    let n = grad.len();
    if keep == 0 {
        return Vec::new();
    }
    if keep >= n {
        return (0..n as u32).collect();
    }
    let mut mags: Vec<f32> = grad.iter().map(|g| g.abs()).collect();
    let nth = n - keep;
    mags.select_nth_unstable_by(nth, |a, b| a.partial_cmp(b).unwrap());
    let thresh = mags[nth];
    let mut idx = Vec::with_capacity(keep);
    let mut ties = Vec::new();
    for (i, g) in grad.iter().enumerate() {
        let a = g.abs();
        if a > thresh {
            idx.push(i as u32);
        } else if a == thresh {
            ties.push(i as u32);
        }
    }
    let room = keep - idx.len();
    idx.extend(ties.into_iter().take(room));
    idx.sort_unstable();
    idx
}

/// Error feedback: carries the un-transmitted residual into the next
/// iteration (`g ← g + residual; residual ← g − sparsified(g)`).
#[derive(Debug, Clone)]
pub struct ErrorFeedback {
    residual: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(numel: usize) -> ErrorFeedback {
        ErrorFeedback { residual: vec![0.0; numel] }
    }

    /// Add the carried residual into `grad` (call before sparsifying).
    pub fn compensate(&self, grad: &mut [f32]) {
        for (g, r) in grad.iter_mut().zip(&self.residual) {
            *g += r;
        }
    }

    /// Record what was dropped: `residual = pre_sparsify − post_sparsify`.
    pub fn absorb(&mut self, pre: &[f32], post: &[f32]) {
        for ((r, p), q) in self.residual.iter_mut().zip(pre).zip(post) {
            *r = p - q;
        }
    }

    pub fn residual_l2(&self) -> f64 {
        self.residual.iter().map(|&r| (r as f64) * (r as f64)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_k_keeps_expected_count() {
        let mut rng = Pcg64::seeded(1);
        let mut g: Vec<f32> = (1..=1000).map(|i| i as f32).collect();
        let kept = random_k(&mut g, 0.3, &mut rng);
        assert_eq!(kept, 300);
        assert_eq!(g.iter().filter(|&&x| x != 0.0).count(), 300);
    }

    #[test]
    fn random_k_full_keep_is_noop() {
        let mut rng = Pcg64::seeded(2);
        let mut g = vec![1.0f32; 64];
        assert_eq!(random_k(&mut g, 1.0, &mut rng), 64);
        assert!(g.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn top_k_keeps_largest_magnitudes() {
        let mut g = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 1.0];
        let kept = top_k(&mut g, 0.5);
        assert_eq!(kept, 3);
        assert_eq!(g, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0]);
    }

    #[test]
    fn top_k_handles_ties() {
        let mut g = vec![1.0f32; 10];
        let kept = top_k(&mut g, 0.4);
        assert_eq!(kept, 4);
        assert_eq!(g.iter().filter(|&&x| x != 0.0).count(), 4);
    }

    #[test]
    fn top_k_zero_keeps_nothing() {
        let mut g = vec![1.0f32, 2.0];
        assert_eq!(top_k(&mut g, 0.0), 0);
        assert!(g.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn top_k_indices_agree_with_top_k() {
        let g = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 1.0];
        assert_eq!(top_k_indices(&g, 3), vec![1, 3, 5]);
        assert_eq!(top_k_indices(&g, 0), Vec::<u32>::new());
        assert_eq!(top_k_indices(&g, 6), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(top_k_indices(&g, 99), vec![0, 1, 2, 3, 4, 5]);
        // Ties resolve in ascending index order, like `top_k`.
        let ones = vec![1.0f32; 10];
        assert_eq!(top_k_indices(&ones, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn prop_top_k_indices_match_in_place_top_k() {
        // The index plane and the in-place reference must pick the exact
        // same element set for every (values, keep) — the decode side of
        // the topk codec relies on this equivalence.
        crate::util::proptest::check("top_k index/in-place agreement", |rng| {
            let n = 1 + rng.gen_range(500) as usize;
            let g: Vec<f32> = (0..n)
                .map(|_| {
                    // Coarse quantization forces frequent magnitude ties.
                    let v = (rng.gen_range(41) as f32 - 20.0) / 8.0;
                    if rng.chance(0.5) {
                        -v
                    } else {
                        v
                    }
                })
                .collect();
            let keep = rng.gen_range(n as u64 + 1) as usize;
            let idx = top_k_indices(&g, keep);
            assert_eq!(idx.len(), keep.min(n));
            assert!(idx.windows(2).all(|w| w[0] < w[1]), "sorted, unique");
            let mut dense = g.clone();
            let kept = top_k(&mut dense, keep as f64 / n as f64);
            // `top_k` rounds its fraction; only compare when the counts
            // agree (they do whenever keep/n survives the round-trip).
            if kept == idx.len() {
                let mut from_idx = vec![0.0f32; n];
                for &i in &idx {
                    from_idx[i as usize] = g[i as usize];
                }
                assert_eq!(from_idx, dense, "index plane must reproduce top_k");
            }
        });
    }

    #[test]
    fn error_feedback_conserves_mass() {
        // With error feedback, dropped gradient mass reappears next round.
        let mut ef = ErrorFeedback::new(4);
        let mut g = vec![1.0f32, 2.0, 3.0, 4.0];
        let pre = g.clone();
        top_k(&mut g, 0.5); // keeps 3.0, 4.0
        ef.absorb(&pre, &g);
        assert!((ef.residual_l2() - (1.0f64 + 4.0).sqrt()).abs() < 1e-6);
        let mut g2 = vec![0.0f32; 4];
        ef.compensate(&mut g2);
        assert_eq!(g2, vec![1.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn prop_random_k_distribution_is_uniform() {
        // Each index should be kept ≈ k of the time.
        let mut rng = Pcg64::seeded(77);
        let n = 200;
        let trials = 2000;
        let mut counts = vec![0u32; n];
        for _ in 0..trials {
            let mut g = vec![1.0f32; n];
            random_k(&mut g, 0.25, &mut rng);
            for (c, v) in counts.iter_mut().zip(&g) {
                if *v != 0.0 {
                    *c += 1;
                }
            }
        }
        for (i, &c) in counts.iter().enumerate() {
            let rate = c as f64 / trials as f64;
            assert!((rate - 0.25).abs() < 0.06, "index {i} kept at rate {rate}");
        }
    }
}
