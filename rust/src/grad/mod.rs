//! Gradient plumbing between the model and the transport: the tensor
//! manifest shared by workers and PS, float32-aligned packetization
//! (*padding bubbles*, paper §III-C Fig 8), receiver-side zero filling
//! (*packet bubbles*), per-element arrival masks for the PS aggregation
//! kernel, and the gradient-sparsification reference algorithms (Random-k /
//! Top-k, paper §II-C Fig 5) with optional error feedback.

mod bubble;
mod manifest;
mod sparsify;

pub use bubble::{bubble_fill, bubble_fill_into, element_mask, misaligned_corruption_demo};
pub use manifest::{Manifest, TensorSpec, ALIGN};
pub use sparsify::{random_k, top_k, top_k_indices, ErrorFeedback};
