//! Tensor-priority segment scheduling, co-designed with Early Close
//! (paper §III-B + Domain-specific Communication Optimization, PAPERS.md).
//!
//! The flat gradient is laid out shallow→deep (`layer0.w, layer0.b, …,
//! head.w, head.b`), and per-element magnitude skews heavily toward the
//! classifier head at the tail — so a flow's *later* segments carry the
//! most update mass. The default LTP sender transmits normals in
//! ascending order, which means Early Close sheds exactly the wrong
//! (high-importance) tail. [`PriorityScheduler`] inverts that: normals go
//! out deepest-first, so whatever Early Close truncates is the
//! low-importance head, and the delivered-importance score of a closed
//! gather strictly improves.
//!
//! Importance is scored with the same model the scheduler sorts by:
//! segment `s` weighs `s + 1` (linear proxy for the tail-heavy magnitude
//! skew). The weights are integers summed exactly, so the score is
//! deterministic across platforms.

use crate::proto::SegmentMap;
use crate::util::Bitmap;

/// Orders a flow's normal segments by tensor priority and scores partial
/// deliveries against the same weight model.
pub struct PriorityScheduler;

impl PriorityScheduler {
    /// Importance weight of segment `s`: deeper (higher-index) segments
    /// carry more update mass.
    pub fn weight(seg: u32) -> u64 {
        seg as u64 + 1
    }

    /// The normal-queue transmission order: every non-critical segment,
    /// deepest first. Criticals are excluded — they ride the reliable
    /// critical queue ahead of all normals regardless of scheduling.
    pub fn order(map: &SegmentMap) -> Vec<u32> {
        (0..map.n_segs).rev().filter(|&s| !map.is_critical(s)).collect()
    }

    /// Delivered importance of a (possibly early-closed) flow: the
    /// weight-sum of arrived segments over the weight-sum of all
    /// `n_segs` segments. `1.0` for a full delivery; reliable transports
    /// (no arrival bitmap) score `1.0` by construction.
    pub fn delivered_importance(received: &Bitmap, n_segs: u32) -> f64 {
        if n_segs == 0 {
            return 1.0;
        }
        let total = (n_segs as u64 * (n_segs as u64 + 1)) / 2;
        let mut got = 0u64;
        for s in 0..n_segs {
            if received.get(s as usize) {
                got += Self::weight(s);
            }
        }
        got as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_is_deepest_first_without_criticals() {
        let map = SegmentMap::new(6 * 1460, 1460, vec![0, 5]);
        assert_eq!(PriorityScheduler::order(&map), vec![4, 3, 2, 1]);
        let no_crit = SegmentMap::new(3 * 1460, 1460, vec![]);
        assert_eq!(PriorityScheduler::order(&no_crit), vec![2, 1, 0]);
    }

    #[test]
    fn importance_weighs_the_tail_heavier() {
        let n = 4u32; // weights 1+2+3+4 = 10
        let mut head = Bitmap::new(4);
        head.set(0);
        head.set(1);
        let mut tail = Bitmap::new(4);
        tail.set(2);
        tail.set(3);
        let hi = PriorityScheduler::delivered_importance(&head, n);
        let ti = PriorityScheduler::delivered_importance(&tail, n);
        assert!((hi - 0.3).abs() < 1e-12);
        assert!((ti - 0.7).abs() < 1e-12);
        assert!(ti > hi, "same count, but the tail must score higher");
    }

    #[test]
    fn importance_edges() {
        let mut all = Bitmap::new(3);
        for s in 0..3 {
            all.set(s);
        }
        assert_eq!(PriorityScheduler::delivered_importance(&all, 3), 1.0);
        let none = Bitmap::new(3);
        assert_eq!(PriorityScheduler::delivered_importance(&none, 3), 0.0);
        assert_eq!(PriorityScheduler::delivered_importance(&none, 0), 1.0);
    }
}
