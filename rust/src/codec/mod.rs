//! The pluggable **gradient codec plane** (DESIGN.md §1.4) — the fourth
//! pluggable layer after transports (§1.1), aggregation topologies (§1.2),
//! and compute backends (§1.3).
//!
//! A [`GradCodec`] decides *which bytes a gather flow actually carries*:
//! it maps a worker's dense f32 gradient range to a (usually smaller) wire
//! image, and — on the PS side — decodes a partial arrival back into the
//! per-element mask the masked-mean aggregation kernel divides by. Codecs
//! are registered under string keys and instantiated from specs reusing
//! the transport/aggregation/backend grammar (`key[:name=value,...]`,
//! [`parse_codec`]):
//!
//! * `dense` — the identity codec: the wire image is the flat f32 buffer,
//!   byte-for-byte. This is the default, and default runs keep their
//!   golden report bytes.
//! * `topk` — Top-k sparsification (`grad/sparsify.rs`): the wire image
//!   is `kept` (index, value) pairs of 8 bytes each, packed in ascending
//!   index order, for the `kept` largest-|g| elements.
//! * `threshold` — magnitude-threshold sparsification under a provisioned
//!   wire budget: elements with `|g| ≥ t`, largest magnitudes first, up to
//!   `cap` of the dense element count.
//!
//! Any codec can additionally enable **tensor-priority scheduling**
//! (`priority=on`): the flow's normal segments are handed to the LTP
//! sender in [`PriorityScheduler`] order (deepest layers — the
//! largest-magnitude tail of the flat gradient — first), so Early Close
//! sheds only the low-importance head instead of whatever happened to be
//! queued last. Delivered importance is scored per gather flow and
//! surfaced as `mean_importance` in run reports.
//!
//! Wire-size accounting is deterministic: [`GradCodec::encoded_bytes`] is
//! a pure function of the dense byte count, so modeled (backend-free)
//! runs size their simnet flows without ever materializing gradients, and
//! `--jobs N` sweeps stay byte-identical to serial ones.

mod priority;

pub use priority::PriorityScheduler;

use crate::grad::top_k_indices;
use crate::proto::SegmentMap;
use crate::ps::spec::{canonical, parse_fraction, parse_params, unknown_param};
use crate::util::Bitmap;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Bytes of one (index: u32 LE, value: f32 LE) pair on the wire.
pub const PAIR_BYTES: u64 = 8;

/// A gradient codec: thread-shareable, registered under a string key,
/// instantiated from CLI specs like `topk:pct=0.1` or
/// `dense:priority=on`.
pub trait GradCodec: Send + Sync {
    /// Canonical spec string — the codec's label everywhere.
    fn name(&self) -> &str;

    /// Wire bytes carried for a `dense_bytes`-byte f32 gradient range.
    /// Pure in `dense_bytes` (never data-dependent): flow sizing must be
    /// known before any gradient exists, and must replay byte-identically.
    fn encoded_bytes(&self, dense_bytes: u64) -> u64;

    /// Does the wire image equal the dense buffer byte-for-byte? Identity
    /// codecs keep every dense decode path (and golden report) untouched.
    fn wire_identity(&self) -> bool;

    /// Is tensor-priority segment scheduling enabled for gather flows?
    fn priority(&self) -> bool;

    /// Decode a (possibly partial) arrival into the per-element mask the
    /// masked-mean kernel divides by: `mask[i] == 1.0` iff element `i`
    /// was selected by the codec for `grad` *and* every wire segment
    /// carrying its pair arrived. `wire_map` segments the encoded image
    /// ([`Self::encoded_bytes`] of `4 * grad.len()`); `arrival == None`
    /// means a reliable transport delivered the whole image.
    fn element_mask(
        &self,
        grad: &[f32],
        wire_map: &SegmentMap,
        arrival: Option<&Bitmap>,
    ) -> Vec<f32>;
}

/// A parsed, validated codec spec: the handle stored in run
/// configurations and carried across worker threads by the sweep driver.
/// Clones share the underlying [`GradCodec`].
#[derive(Clone)]
pub struct CodecSpec(Arc<dyn GradCodec>);

impl CodecSpec {
    /// Canonical spec string — the codec's name everywhere (labels, JSON
    /// reports, bench records). Borrowed; no per-call allocation.
    pub fn name(&self) -> &str {
        self.0.name()
    }

    /// Is this the bare default (`dense`, no parameters)? Default runs
    /// must keep their report bytes golden, so reporting layers emit
    /// codec fields only when this is false.
    pub fn is_default(&self) -> bool {
        self.name() == "dense"
    }

    /// The critical segment set of the *encoded* gather flow. Identity
    /// codecs keep the model's tensor-boundary criticals; sparsifying
    /// codecs re-derive them for the packed image (first and last wire
    /// segments: the index plane's framing must survive Early Close).
    pub fn wire_critical(&self, dense_critical: &[u32], wire_map: &SegmentMap) -> Vec<u32> {
        if self.wire_identity() {
            return dense_critical.to_vec();
        }
        if wire_map.n_segs <= 1 {
            vec![0]
        } else {
            vec![0, wire_map.n_segs - 1]
        }
    }

    /// The normal-queue transmission order for a gather flow, or `None`
    /// when priority scheduling is off (the sender keeps its ascending
    /// default, byte-identical to pre-codec builds).
    pub fn nq_order(&self, wire_map: &SegmentMap) -> Option<Vec<u32>> {
        if self.priority() {
            Some(PriorityScheduler::order(wire_map))
        } else {
            None
        }
    }
}

impl std::ops::Deref for CodecSpec {
    type Target = dyn GradCodec;

    fn deref(&self) -> &(dyn GradCodec + 'static) {
        &*self.0
    }
}

impl std::fmt::Display for CodecSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::fmt::Debug for CodecSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CodecSpec({})", self.name())
    }
}

/// Two specs are equal iff their canonical names are.
impl PartialEq for CodecSpec {
    fn eq(&self, other: &CodecSpec) -> bool {
        self.name() == other.name()
    }
}

impl std::str::FromStr for CodecSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<CodecSpec> {
        parse_codec(s)
    }
}

/// One registered codec family.
pub struct CodecDef {
    /// Spec key (`--codec <key>[:params]`).
    pub key: &'static str,
    pub summary: &'static str,
    /// Accepted `name=value` parameters, for `ltp codec list`.
    pub params: &'static str,
    build: fn(&[(String, String)]) -> Result<CodecSpec>,
}

/// The codec registry. Append entries here (and their implementations in
/// this module); the CLI (`--codec`, `ltp codec list`), the
/// `compression_matrix` scenario, and the conformance tests follow.
pub const CODEC_REGISTRY: &[CodecDef] = &[
    CodecDef {
        key: "dense",
        summary: "identity codec: the dense f32 buffer is the wire image (the default)",
        params: "priority=<on|off>",
        build: build_dense,
    },
    CodecDef {
        key: "topk",
        summary: "top-k sparsification: (index, value) pairs for the largest-|g| elements",
        params: "k=<count> | pct=<0..1> (exactly one), priority=<on|off>",
        build: build_topk,
    },
    CodecDef {
        key: "threshold",
        summary: "magnitude-threshold sparsification under a provisioned wire budget",
        params: "t=<abs threshold>, cap=<0..1>, priority=<on|off>",
        build: build_threshold,
    },
];

/// The registry (function form, for iteration symmetry with the protocol,
/// aggregation, backend, and scenario registries).
pub fn codec_registry() -> &'static [CodecDef] {
    CODEC_REGISTRY
}

/// Parse a codec spec (`dense`, `topk:pct=0.1`, `threshold:t=0.01`,
/// `topk:pct=0.1,priority=on`) against the registry.
pub fn parse_codec(spec: &str) -> Result<CodecSpec> {
    let spec = spec.trim();
    let (key, rest) = match spec.split_once(':') {
        Some((k, r)) => (k, Some(r)),
        None => (spec, None),
    };
    let key = key.to_ascii_lowercase();
    let Some(def) = CODEC_REGISTRY.iter().find(|d| d.key == key) else {
        let known: Vec<&str> = CODEC_REGISTRY.iter().map(|d| d.key).collect();
        bail!("unknown codec `{key}` in spec `{spec}` (known: {})", known.join(", "));
    };
    let params = parse_params(rest).with_context(|| format!("in codec spec `{spec}`"))?;
    (def.build)(&params).with_context(|| format!("in codec spec `{spec}`"))
}

/// The default codec: bare `dense` (identity wire image, no scheduling).
pub fn default_codec() -> CodecSpec {
    parse_codec("dense").expect("registry default")
}

// ---------------------------------------------------------------------------
// Wire packing of a top-k selection: the byte-level encode/decode pair the
// UDP path carries and the proptest oracle round-trips. (The simulator
// models sizes only, but sizes are derived from exactly this layout.)
// ---------------------------------------------------------------------------

/// Encode the `keep` largest-|g| elements of `grad` as little-endian
/// (index: u32, value: f32) pairs in ascending index order.
pub fn pack_topk(grad: &[f32], keep: usize) -> Vec<u8> {
    let idx = top_k_indices(grad, keep);
    let mut out = Vec::with_capacity(idx.len() * PAIR_BYTES as usize);
    for &i in &idx {
        out.extend_from_slice(&i.to_le_bytes());
        out.extend_from_slice(&grad[i as usize].to_le_bytes());
    }
    out
}

/// Decode a [`pack_topk`] image back into a dense `numel`-element buffer
/// (unsent elements are zero — the packet-bubble convention).
pub fn unpack_topk(bytes: &[u8], numel: usize) -> Result<Vec<f32>> {
    if bytes.len() % PAIR_BYTES as usize != 0 {
        bail!("topk image length {} is not a multiple of {PAIR_BYTES}", bytes.len());
    }
    let mut out = vec![0.0f32; numel];
    for pair in bytes.chunks_exact(PAIR_BYTES as usize) {
        let i = u32::from_le_bytes(pair[..4].try_into().unwrap()) as usize;
        if i >= numel {
            bail!("topk pair index {i} out of range (numel {numel})");
        }
        out[i] = f32::from_le_bytes(pair[4..].try_into().unwrap());
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Codec implementations.
// ---------------------------------------------------------------------------

/// Mask the elements whose (index, value) pairs fully arrived. Pair `j`
/// occupies encoded bytes `[8j, 8j+8)`; it is delivered iff every wire
/// segment overlapping that range arrived.
fn pair_mask(
    idx: &[u32],
    numel: usize,
    wire_map: &SegmentMap,
    arrival: Option<&Bitmap>,
) -> Vec<f32> {
    let mut mask = vec![0.0f32; numel];
    for (j, &i) in idx.iter().enumerate() {
        let delivered = match arrival {
            None => true,
            Some(bm) => {
                let a = j as u64 * PAIR_BYTES;
                let b = a + PAIR_BYTES;
                let s0 = a / wire_map.seg_payload as u64;
                let s1 = (b - 1) / wire_map.seg_payload as u64;
                (s0..=s1).all(|s| s < wire_map.n_segs as u64 && bm.get(s as usize))
            }
        };
        if delivered {
            mask[i as usize] = 1.0;
        }
    }
    mask
}

struct DenseCodec {
    priority: bool,
    spec: String,
}

impl GradCodec for DenseCodec {
    fn name(&self) -> &str {
        &self.spec
    }

    fn encoded_bytes(&self, dense_bytes: u64) -> u64 {
        dense_bytes
    }

    fn wire_identity(&self) -> bool {
        true
    }

    fn priority(&self) -> bool {
        self.priority
    }

    fn element_mask(
        &self,
        grad: &[f32],
        wire_map: &SegmentMap,
        arrival: Option<&Bitmap>,
    ) -> Vec<f32> {
        match arrival {
            Some(bm) => crate::grad::element_mask(wire_map, bm, grad.len()),
            None => vec![1.0; grad.len()],
        }
    }
}

struct TopkCodec {
    /// Exactly one of `k` (absolute count) and `pct` (fraction) is set.
    k: Option<usize>,
    pct: Option<f64>,
    priority: bool,
    spec: String,
}

impl TopkCodec {
    /// Elements kept of a `numel`-element range: `k` capped to `numel`,
    /// or `round(numel · pct)` — matching [`crate::grad::top_k`]'s
    /// rounding — clamped to at least one (a flow must carry bytes).
    fn kept(&self, numel: usize) -> usize {
        let raw = match (self.k, self.pct) {
            (Some(k), _) => k,
            (None, Some(p)) => (numel as f64 * p).round() as usize,
            (None, None) => unreachable!("builder enforces k xor pct"),
        };
        raw.clamp(1, numel.max(1))
    }
}

impl GradCodec for TopkCodec {
    fn name(&self) -> &str {
        &self.spec
    }

    fn encoded_bytes(&self, dense_bytes: u64) -> u64 {
        let numel = dense_bytes.div_ceil(4) as usize;
        self.kept(numel) as u64 * PAIR_BYTES
    }

    fn wire_identity(&self) -> bool {
        false
    }

    fn priority(&self) -> bool {
        self.priority
    }

    fn element_mask(
        &self,
        grad: &[f32],
        wire_map: &SegmentMap,
        arrival: Option<&Bitmap>,
    ) -> Vec<f32> {
        let idx = top_k_indices(grad, self.kept(grad.len()));
        pair_mask(&idx, grad.len(), wire_map, arrival)
    }
}

/// Default absolute-magnitude threshold (`t`) and provisioned wire budget
/// (`cap`, fraction of the dense element count) for `threshold`.
const THRESHOLD_T: f32 = 0.001;
const THRESHOLD_CAP: f64 = 0.25;

struct ThresholdCodec {
    t: f32,
    cap: f64,
    priority: bool,
    spec: String,
}

impl ThresholdCodec {
    fn budget(&self, numel: usize) -> usize {
        ((numel as f64 * self.cap).round() as usize).clamp(1, numel.max(1))
    }
}

impl GradCodec for ThresholdCodec {
    fn name(&self) -> &str {
        &self.spec
    }

    /// The wire carries the provisioned budget: threshold selection is
    /// data-dependent, so the flow is sized for the worst case `cap`
    /// admits (sizes must be pure in `dense_bytes` — see the trait doc).
    fn encoded_bytes(&self, dense_bytes: u64) -> u64 {
        let numel = dense_bytes.div_ceil(4) as usize;
        self.budget(numel) as u64 * PAIR_BYTES
    }

    fn wire_identity(&self) -> bool {
        false
    }

    fn priority(&self) -> bool {
        self.priority
    }

    fn element_mask(
        &self,
        grad: &[f32],
        wire_map: &SegmentMap,
        arrival: Option<&Bitmap>,
    ) -> Vec<f32> {
        // Largest magnitudes first up to the budget, then the threshold
        // trims the data-dependent tail below `t`.
        let mut idx = top_k_indices(grad, self.budget(grad.len()));
        idx.retain(|&i| grad[i as usize].abs() >= self.t);
        pair_mask(&idx, grad.len(), wire_map, arrival)
    }
}

// ---------------------------------------------------------------------------
// Per-codec builders.
// ---------------------------------------------------------------------------

fn fmt_switch(on: bool) -> &'static str {
    if on {
        "on"
    } else {
        "off"
    }
}

fn build_dense(params: &[(String, String)]) -> Result<CodecSpec> {
    let mut priority = None;
    for (k, v) in params {
        match k.as_str() {
            "priority" => priority = Some(crate::compute::parse_switch(k, v)?),
            _ => return Err(unknown_param("dense", k, "priority")),
        }
    }
    let mut parts = Vec::new();
    if let Some(p) = priority {
        parts.push(format!("priority={}", fmt_switch(p)));
    }
    Ok(CodecSpec(Arc::new(DenseCodec {
        priority: priority.unwrap_or(false),
        spec: canonical("dense", &parts),
    })))
}

fn build_topk(params: &[(String, String)]) -> Result<CodecSpec> {
    let (mut k, mut pct, mut priority) = (None, None, None);
    for (key, v) in params {
        match key.as_str() {
            "k" => {
                let n: usize =
                    v.parse().with_context(|| format!("bad value for `k`: `{v}`"))?;
                if n == 0 {
                    bail!("`k=0`: need at least one kept element");
                }
                k = Some(n);
            }
            "pct" => pct = Some(parse_fraction(key, v)?),
            "priority" => priority = Some(crate::compute::parse_switch(key, v)?),
            _ => return Err(unknown_param("topk", key, "k, pct, priority")),
        }
    }
    match (k, pct) {
        (None, None) => bail!("`topk` needs a budget: topk:k=<count> or topk:pct=<0..1>"),
        (Some(_), Some(_)) => bail!("`topk` takes `k` or `pct`, not both"),
        _ => {}
    }
    // Canonical order: k, pct, priority.
    let mut parts = Vec::new();
    if let Some(n) = k {
        parts.push(format!("k={n}"));
    }
    if let Some(p) = pct {
        parts.push(format!("pct={p}"));
    }
    if let Some(p) = priority {
        parts.push(format!("priority={}", fmt_switch(p)));
    }
    Ok(CodecSpec(Arc::new(TopkCodec {
        k,
        pct,
        priority: priority.unwrap_or(false),
        spec: canonical("topk", &parts),
    })))
}

fn build_threshold(params: &[(String, String)]) -> Result<CodecSpec> {
    let (mut t, mut cap, mut priority) = (None, None, None);
    for (k, v) in params {
        match k.as_str() {
            "t" => {
                let x: f32 =
                    v.parse().with_context(|| format!("bad value for `t`: `{v}`"))?;
                if !(x > 0.0 && x.is_finite()) {
                    bail!("`t={v}` out of range (need a positive finite threshold)");
                }
                t = Some(x);
            }
            "cap" => cap = Some(parse_fraction(k, v)?),
            "priority" => priority = Some(crate::compute::parse_switch(k, v)?),
            _ => return Err(unknown_param("threshold", k, "t, cap, priority")),
        }
    }
    // Canonical order: t, cap, priority.
    let mut parts = Vec::new();
    if let Some(x) = t {
        parts.push(format!("t={x}"));
    }
    if let Some(x) = cap {
        parts.push(format!("cap={x}"));
    }
    if let Some(p) = priority {
        parts.push(format!("priority={}", fmt_switch(p)));
    }
    Ok(CodecSpec(Arc::new(ThresholdCodec {
        t: t.unwrap_or(THRESHOLD_T),
        cap: cap.unwrap_or(THRESHOLD_CAP),
        priority: priority.unwrap_or(false),
        spec: canonical("threshold", &parts),
    })))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn defaults_parse_with_canonical_names() {
        for (spec, canon) in [
            ("dense", "dense"),
            ("DENSE", "dense"),
            ("dense:priority=on", "dense:priority=on"),
            ("dense:priority=off", "dense:priority=off"),
            ("topk:pct=0.1", "topk:pct=0.1"),
            ("topk:k=100", "topk:k=100"),
            ("TOPK:PCT=0.01", "topk:pct=0.01"),
            ("topk:priority=on,pct=0.1", "topk:pct=0.1,priority=on"),
            ("threshold", "threshold"),
            ("threshold:t=0.01", "threshold:t=0.01"),
            ("threshold:cap=0.5,t=0.01", "threshold:t=0.01,cap=0.5"),
        ] {
            let c = parse_codec(spec).unwrap_or_else(|e| panic!("{spec}: {e:#}"));
            assert_eq!(c.name(), canon, "{spec}");
            // Canonical form is a fixed point of the grammar.
            assert_eq!(parse_codec(c.name()).unwrap().name(), canon, "{spec}");
        }
    }

    #[test]
    fn spec_equality_is_canonical() {
        assert_eq!(parse_codec("dense").unwrap(), parse_codec("DENSE").unwrap());
        assert_ne!(parse_codec("dense").unwrap(), parse_codec("dense:priority=on").unwrap());
        assert!(default_codec().is_default());
        assert!(!parse_codec("dense:priority=on").unwrap().is_default());
        assert!(!parse_codec("topk:pct=0.1").unwrap().is_default());
    }

    #[test]
    fn malformed_specs_are_rejected() {
        for bad in [
            "gzip",                    // unknown codec
            "topk",                    // missing budget
            "topk:",                   // empty parameter list
            "topk:pct",                // malformed parameter
            "topk:pct=",               // empty value
            "topk:pct=0",              // out of range
            "topk:pct=1.5",            // out of range
            "topk:k=0",                // zero
            "topk:k=10,pct=0.1",       // both budgets
            "topk:pct=0.1,pct=0.2",    // duplicate parameter
            "topk:window=3",           // unknown parameter
            "dense:pct=0.1",           // unknown parameter
            "dense:priority=maybe",    // bad switch
            "threshold:t=0",           // out of range
            "threshold:t=-1",          // out of range
            "threshold:cap=2",         // out of range
        ] {
            assert!(parse_codec(bad).is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn registry_is_well_formed() {
        let mut keys: Vec<&str> = CODEC_REGISTRY.iter().map(|d| d.key).collect();
        assert!(keys.contains(&"dense") && keys.contains(&"topk") && keys.contains(&"threshold"));
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), CODEC_REGISTRY.len(), "codec keys must be unique");
    }

    #[test]
    fn wire_sizes_are_deterministic_and_reduced() {
        let dense = default_codec();
        assert_eq!(dense.encoded_bytes(35360), 35360);
        assert!(dense.wire_identity());
        // 8840 elements at pct=0.1 → 884 pairs → 7072 bytes: exactly 5×.
        let topk = parse_codec("topk:pct=0.1").unwrap();
        assert_eq!(topk.encoded_bytes(35360), 7072);
        assert!(!topk.wire_identity());
        // pct=0.01 → round(88.4) = 88 pairs.
        let topk1 = parse_codec("topk:pct=0.01").unwrap();
        assert_eq!(topk1.encoded_bytes(35360), 88 * PAIR_BYTES);
        // Absolute k caps at numel; tiny ranges still carry one pair.
        let k = parse_codec("topk:k=1000000").unwrap();
        assert_eq!(k.encoded_bytes(40), 10 * PAIR_BYTES);
        let tiny = parse_codec("topk:pct=0.001").unwrap();
        assert_eq!(tiny.encoded_bytes(40), PAIR_BYTES);
        // threshold sizes by its provisioned cap, not by data.
        let th = parse_codec("threshold:t=0.01,cap=0.5").unwrap();
        assert_eq!(th.encoded_bytes(800), 100 * PAIR_BYTES);
    }

    #[test]
    fn wire_critical_reframes_for_sparse_codecs() {
        let dense = default_codec();
        let map = SegmentMap::new(10_000, 1460, vec![]);
        assert_eq!(dense.wire_critical(&[0, 3, 6], &map), vec![0, 3, 6]);
        let topk = parse_codec("topk:pct=0.1").unwrap();
        assert_eq!(topk.wire_critical(&[0, 3, 6], &map), vec![0, map.n_segs - 1]);
        let one = SegmentMap::new(8, 1460, vec![]);
        assert_eq!(topk.wire_critical(&[0, 3, 6], &one), vec![0]);
    }

    #[test]
    fn nq_order_follows_the_priority_switch() {
        let map = SegmentMap::new(4 * 1460, 1460, vec![0]);
        assert_eq!(default_codec().nq_order(&map), None);
        let prio = parse_codec("dense:priority=on").unwrap();
        assert_eq!(prio.nq_order(&map), Some(vec![3, 2, 1]));
    }

    #[test]
    fn dense_mask_matches_bubble_mask() {
        let grad = vec![1.0f32; 730];
        let map = SegmentMap::new(2920, 1460, vec![]);
        let mut bm = Bitmap::new(2);
        bm.set(1);
        let mask = default_codec().element_mask(&grad, &map, Some(&bm));
        assert_eq!(mask, crate::grad::element_mask(&map, &bm, 730));
        let full = default_codec().element_mask(&grad, &map, None);
        assert!(full.iter().all(|&m| m == 1.0));
    }

    #[test]
    fn topk_mask_keeps_selected_delivered_elements() {
        // 400 elements, keep 25% = 100 pairs = 800 bytes = 2 wire segments
        // of 400 bytes (50 pairs each). Lose segment 1: only the first 50
        // kept indices survive.
        let codec = parse_codec("topk:pct=0.25").unwrap();
        let grad: Vec<f32> = (0..400).map(|i| i as f32).collect();
        let map = SegmentMap::new(codec.encoded_bytes(1600), 400, vec![]);
        assert_eq!(map.n_segs, 2);
        let mut bm = Bitmap::new(2);
        bm.set(0);
        let mask = codec.element_mask(&grad, &map, Some(&bm));
        // Kept indices are 300..400 (largest values), ascending; the
        // arrived first segment carries pairs 0..50 → indices 300..350.
        for (i, &m) in mask.iter().enumerate() {
            let want = if (300..350).contains(&i) { 1.0 } else { 0.0 };
            assert_eq!(m, want, "elem {i}");
        }
        // Reliable delivery masks the whole selection.
        let full = codec.element_mask(&grad, &map, None);
        assert_eq!(full.iter().filter(|&&m| m == 1.0).count(), 100);
    }

    #[test]
    fn threshold_mask_trims_below_t() {
        let codec = parse_codec("threshold:t=0.5,cap=0.5").unwrap();
        let grad = vec![0.1f32, -2.0, 0.3, 0.9, 0.2, -0.4, 0.6, 0.05];
        let map = SegmentMap::new(codec.encoded_bytes(32), 1460, vec![]);
        let mask = codec.element_mask(&grad, &map, None);
        // Budget = 4 largest magnitudes {1, 3, 6, 7→no: |0.05|} → top 4 are
        // indices 1 (2.0), 3 (0.9), 6 (0.6), 5 (0.4); threshold 0.5 trims
        // index 5.
        assert_eq!(mask, vec![0.0, 1.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn prop_pack_unpack_roundtrips_against_brute_force_oracle() {
        // encode→decode must equal a brute-force top-k reference (full
        // sort by |g| descending, index-ascending tie-break) — mirroring
        // `grad/bubble.rs`'s oracle style.
        check("topk pack/unpack oracle", |rng| {
            let n = 1 + rng.gen_range(300) as usize;
            let g: Vec<f32> = (0..n)
                .map(|_| {
                    let v = (rng.gen_range(33) as f32 - 16.0) / 4.0;
                    if rng.chance(0.5) {
                        -v
                    } else {
                        v
                    }
                })
                .collect();
            let keep = rng.gen_range(n as u64 + 1) as usize;
            let bytes = pack_topk(&g, keep);
            assert_eq!(bytes.len(), keep.min(n) * PAIR_BYTES as usize);
            let decoded = unpack_topk(&bytes, n).unwrap();
            // Brute-force oracle.
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| {
                g[b].abs().partial_cmp(&g[a].abs()).unwrap().then(a.cmp(&b))
            });
            let mut want = vec![0.0f32; n];
            for &i in order.iter().take(keep) {
                want[i] = g[i];
            }
            assert_eq!(decoded, want);
        });
    }

    #[test]
    fn unpack_rejects_malformed_images() {
        assert!(unpack_topk(&[0u8; 7], 4).is_err(), "ragged length");
        let mut pair = Vec::new();
        pair.extend_from_slice(&9u32.to_le_bytes());
        pair.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(unpack_topk(&pair, 4).is_err(), "index out of range");
    }
}
