//! TCP segment representation for the baseline protocols (simulator-only;
//! the baselines model kernel TCP behaviour, they are not a wire-compatible
//! TCP implementation).

/// Number of SACK blocks carried per ACK (like real TCP's option space).
pub const SACK_BLOCKS: usize = 3;

/// A TCP segment or ACK. Sequence numbers are byte offsets (no wraparound:
/// 64-bit, flows in these experiments stay well below 2^64 bytes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TcpSeg {
    /// Flow identifier (connection id).
    pub flow: u64,
    /// First payload byte carried by this segment.
    pub seq: u64,
    /// Payload length (0 for pure ACKs).
    pub len: u32,
    /// Cumulative ACK: next byte expected by the receiver.
    pub ack: u64,
    /// Set on ACK segments.
    pub is_ack: bool,
    /// ECN echo.
    pub ece: bool,
    /// FIN: sender finished.
    pub fin: bool,
    /// SACK blocks `[start, end)`; `(0, 0)` = unused. The block containing
    /// the segment that triggered this ACK comes first (RFC 2018).
    pub sack: [(u64, u64); SACK_BLOCKS],
}

impl TcpSeg {
    pub fn data(flow: u64, seq: u64, len: u32) -> TcpSeg {
        TcpSeg {
            flow,
            seq,
            len,
            ack: 0,
            is_ack: false,
            ece: false,
            fin: false,
            sack: [(0, 0); SACK_BLOCKS],
        }
    }

    pub fn ack(flow: u64, ack: u64, ece: bool) -> TcpSeg {
        TcpSeg {
            flow,
            seq: 0,
            len: 0,
            ack,
            is_ack: true,
            ece,
            fin: false,
            sack: [(0, 0); SACK_BLOCKS],
        }
    }
}
