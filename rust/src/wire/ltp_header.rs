//! The 9-byte LTP packet header (paper Fig 10): bit-packed encode/decode
//! for the UDP driver plus the structured form used on the simulator hot
//! path.

/// Encoded header size in bytes (68 bits rounded up).
pub const HDR_BYTES: usize = 9;

/// Packet importance (2-bit field). The paper defines two levels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Importance {
    /// 0b00 — droppable gradient payload.
    Normal = 0b00,
    /// 0b11 — must be delivered (registration, tensor-boundary bytes, end).
    Critical = 0b11,
}

impl Importance {
    pub fn from_bits(b: u8) -> Importance {
        if b == 0b11 {
            Importance::Critical
        } else {
            Importance::Normal
        }
    }
}

/// Packet type (2-bit field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum LtpType {
    /// 0b00 — flow registration: payload carries the total segment count.
    Registration = 0b00,
    /// 0b01 — data segment.
    Data = 0b01,
    /// 0b10 — per-packet ACK (out-of-order).
    Ack = 0b10,
    /// 0b11 — end / stop. Sender→receiver: "all queues drained".
    /// Receiver→sender: Early Close "stop" broadcast.
    End = 0b11,
}

impl LtpType {
    pub fn from_bits(b: u8) -> LtpType {
        match b & 0b11 {
            0b00 => LtpType::Registration,
            0b01 => LtpType::Data,
            0b10 => LtpType::Ack,
            _ => LtpType::End,
        }
    }
}

/// Quantization granularity of the 12-bit RTprop field: 16 µs units give a
/// 0–65.5 ms range covering both DCN and most WAN paths.
pub const RTPROP_UNIT_US: u32 = 16;
/// Quantization granularity of the 12-bit BtlBw field: 16 Mbps units give a
/// 0–65.5 Gbps range.
pub const BTLBW_UNIT_MBPS: u32 = 16;

/// Structured LTP header. Field widths follow paper Fig 10; `payload_len`
/// and `total_segs` describe the UDP payload that follows the header
/// (registration packets carry `total_segs`, data packets carry
/// `payload_len` bytes of gradient data).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LtpHeader {
    /// 16-bit flow id. One synchronization round (per direction, per peer)
    /// is one flow.
    pub flow: u16,
    /// 24-bit data-segment sequence id (also: the seq being ACKed for Ack
    /// packets).
    pub seq: u32,
    pub importance: Importance,
    pub ty: LtpType,
    /// Sender's RTprop estimate in microseconds (quantized on the wire).
    pub rtprop_us: u32,
    /// Sender's BtlBw estimate in Mbps (quantized on the wire).
    pub btlbw_mbps: u32,
}

impl LtpHeader {
    pub fn data(flow: u16, seq: u32, importance: Importance) -> LtpHeader {
        LtpHeader { flow, seq, importance, ty: LtpType::Data, rtprop_us: 0, btlbw_mbps: 0 }
    }

    pub fn ack(flow: u16, seq: u32) -> LtpHeader {
        LtpHeader {
            flow,
            seq,
            importance: Importance::Normal,
            ty: LtpType::Ack,
            rtprop_us: 0,
            btlbw_mbps: 0,
        }
    }

    pub fn registration(flow: u16, total_segs: u32) -> LtpHeader {
        // Registration reuses the seq field for the segment count (the
        // payload also carries it in full width for the UDP driver).
        LtpHeader {
            flow,
            seq: total_segs,
            importance: Importance::Critical,
            ty: LtpType::Registration,
            rtprop_us: 0,
            btlbw_mbps: 0,
        }
    }

    pub fn end(flow: u16) -> LtpHeader {
        LtpHeader {
            flow,
            seq: 0,
            importance: Importance::Critical,
            ty: LtpType::End,
            rtprop_us: 0,
            btlbw_mbps: 0,
        }
    }

    /// Pack into the 9-byte wire form.
    ///
    /// Layout (big-endian bit order):
    /// `flow[16] | seq[24] | imp[2] | type[2] | rtprop[12] | btlbw[12] | pad[4]`.
    pub fn encode(&self) -> [u8; HDR_BYTES] {
        let rt = (self.rtprop_us / RTPROP_UNIT_US).min(0xFFF);
        let bw = (self.btlbw_mbps / BTLBW_UNIT_MBPS).min(0xFFF);
        debug_assert!(self.seq < (1 << 24), "seq exceeds 24-bit wire field");
        let mut bits: u128 = 0;
        bits |= (self.flow as u128) << (68 - 16);
        bits |= ((self.seq & 0xFF_FFFF) as u128) << (68 - 40);
        bits |= ((self.importance as u8 & 0b11) as u128) << (68 - 42);
        bits |= ((self.ty as u8 & 0b11) as u128) << (68 - 44);
        bits |= ((rt & 0xFFF) as u128) << (68 - 56);
        bits |= ((bw & 0xFFF) as u128) << (68 - 68);
        // Left-align the 68 bits in 72 (9 bytes).
        bits <<= 4;
        let mut out = [0u8; HDR_BYTES];
        for (i, b) in out.iter_mut().enumerate() {
            *b = ((bits >> (64 - 8 * i as u32)) & 0xFF) as u8;
        }
        out
    }

    /// Decode the 9-byte wire form. Quantized fields come back rounded down
    /// to their unit. Returns `None` for malformed input: a buffer shorter
    /// than [`HDR_BYTES`], or nonzero reserved pad bits (the encoder always
    /// zeroes them, so a set pad bit means corruption or a foreign packet).
    pub fn decode(buf: &[u8]) -> Option<LtpHeader> {
        if buf.len() < HDR_BYTES {
            return None;
        }
        let mut bits: u128 = 0;
        for (i, &b) in buf[..HDR_BYTES].iter().enumerate() {
            bits |= (b as u128) << (64 - 8 * i as u32);
        }
        if bits & 0xF != 0 {
            return None; // reserved pad bits must be zero
        }
        bits >>= 4; // drop the pad
        let flow = ((bits >> (68 - 16)) & 0xFFFF) as u16;
        let seq = ((bits >> (68 - 40)) & 0xFF_FFFF) as u32;
        let imp = ((bits >> (68 - 42)) & 0b11) as u8;
        let ty = ((bits >> (68 - 44)) & 0b11) as u8;
        let rt = ((bits >> (68 - 56)) & 0xFFF) as u32;
        let bw = (bits & 0xFFF) as u32;
        Some(LtpHeader {
            flow,
            seq,
            importance: Importance::from_bits(imp),
            ty: LtpType::from_bits(ty),
            rtprop_us: rt * RTPROP_UNIT_US,
            btlbw_mbps: bw * BTLBW_UNIT_MBPS,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn roundtrip_basic() {
        let h = LtpHeader {
            flow: 0xBEEF,
            seq: 0x123456,
            importance: Importance::Critical,
            ty: LtpType::Data,
            rtprop_us: 400 * 16,
            btlbw_mbps: 625 * 16,
        };
        let d = LtpHeader::decode(&h.encode()).unwrap();
        assert_eq!(d, h);
    }

    #[test]
    fn header_is_nine_bytes() {
        assert_eq!(LtpHeader::ack(1, 2).encode().len(), 9);
    }

    #[test]
    fn decode_short_buffer_is_none() {
        assert!(LtpHeader::decode(&[0u8; 8]).is_none());
    }

    #[test]
    fn quantization_rounds_down() {
        let h = LtpHeader {
            flow: 1,
            seq: 1,
            importance: Importance::Normal,
            ty: LtpType::Ack,
            rtprop_us: 100, // not a multiple of 16
            btlbw_mbps: 9_999,
        };
        let d = LtpHeader::decode(&h.encode()).unwrap();
        assert_eq!(d.rtprop_us, 96);
        assert_eq!(d.btlbw_mbps, 9_984);
    }

    #[test]
    fn saturating_fields() {
        let h = LtpHeader {
            flow: 1,
            seq: 1,
            importance: Importance::Normal,
            ty: LtpType::Ack,
            rtprop_us: 10_000_000,  // > 12-bit range
            btlbw_mbps: 99_000_000, // > 12-bit range
        };
        let d = LtpHeader::decode(&h.encode()).unwrap();
        assert_eq!(d.rtprop_us, 0xFFF * RTPROP_UNIT_US);
        assert_eq!(d.btlbw_mbps, 0xFFF * BTLBW_UNIT_MBPS);
    }

    #[test]
    fn decode_rejects_malformed_input() {
        // Empty and truncated buffers.
        assert!(LtpHeader::decode(&[]).is_none());
        for n in 1..HDR_BYTES {
            assert!(LtpHeader::decode(&vec![0xFFu8; n]).is_none(), "len {n} must be rejected");
        }
        // Nonzero reserved pad bits (low 4 bits of the last byte).
        let mut buf = LtpHeader::ack(7, 9).encode();
        assert!(LtpHeader::decode(&buf).is_some());
        buf[HDR_BYTES - 1] |= 0x01;
        assert!(LtpHeader::decode(&buf).is_none(), "set pad bit must be rejected");
        buf[HDR_BYTES - 1] |= 0x0F;
        assert!(LtpHeader::decode(&buf).is_none());
    }

    #[test]
    fn decode_ignores_trailing_payload_bytes() {
        // A real datagram is header + payload; decode must read exactly the
        // first HDR_BYTES and not be confused by what follows.
        let h = LtpHeader::data(3, 1234, Importance::Critical);
        let mut datagram = h.encode().to_vec();
        datagram.extend_from_slice(&[0xAB; 100]);
        assert_eq!(LtpHeader::decode(&datagram).unwrap(), h);
    }

    #[test]
    fn prop_roundtrip_random_headers() {
        check("ltp header roundtrip", |rng| {
            let h = LtpHeader {
                flow: rng.gen_range(1 << 16) as u16,
                seq: rng.gen_range(1 << 24) as u32,
                importance: if rng.chance(0.5) { Importance::Critical } else { Importance::Normal },
                ty: LtpType::from_bits(rng.gen_range(4) as u8),
                rtprop_us: rng.gen_range(0xFFF) as u32 * RTPROP_UNIT_US,
                btlbw_mbps: rng.gen_range(0xFFF) as u32 * BTLBW_UNIT_MBPS,
            };
            let d = LtpHeader::decode(&h.encode()).unwrap();
            assert_eq!(d, h);
        });
    }
}
