//! LTP wire format (paper Fig 10) and the simulator's packet payload types.
//!
//! The LTP header is 68 bits ≈ 9 bytes on the wire, carried over UDP:
//!
//! ```text
//!  bits  field
//!  16    flow id        — one gather/broadcast round = one flow
//!  24    sequence id    — index of the data segment ("jigsaw piece")
//!   2    importance     — 0b11 critical, 0b00 normal
//!   2    type           — 0b00 registration, 0b01 data, 0b10 ack, 0b11 end
//!  12    rtprop         — sender's RTprop estimate, 16 µs units
//!  12    btlbw          — sender's BtlBw estimate, 16 Mbps units
//!  ────
//!  68    total (padded to 9 bytes; top 4 bits of byte 8 reserved)
//! ```
//!
//! The same structured form ([`LtpHeader`]) is used by the simulator
//! (no byte packing on the hot path) and by the real-socket UDP driver
//! (packed via [`LtpHeader::encode`] / [`LtpHeader::decode`]).

mod ltp_header;
mod tcp_seg;

pub use ltp_header::{Importance, LtpHeader, LtpType, HDR_BYTES};
pub use tcp_seg::{TcpSeg, SACK_BLOCKS};

/// Maximum transmission unit used throughout (matches the paper's testbed).
pub const MTU: u32 = 1500;
/// UDP/IP overhead assumed for LTP packets (IPv4 20 B + UDP 8 B).
pub const UDP_IP_OVERHEAD: u32 = 28;
/// TCP/IP overhead assumed for baseline packets (IPv4 20 B + TCP 20 B).
pub const TCP_IP_OVERHEAD: u32 = 40;
/// Usable LTP payload per MTU-sized packet.
pub const LTP_MSS: u32 = MTU - UDP_IP_OVERHEAD - HDR_BYTES as u32;
/// Usable TCP payload per MTU-sized packet.
pub const TCP_MSS: u32 = MTU - TCP_IP_OVERHEAD;

/// Protocol payload of a simulated packet.
#[derive(Debug, Clone)]
pub enum PacketKind {
    /// An LTP packet (header-only in the simulator; data segments carry
    /// `payload_len` accounted bytes whose contents live app-side).
    Ltp(LtpHeader),
    /// A TCP segment for the baseline protocols.
    Tcp(TcpSeg),
    /// Opaque test payload.
    Raw(u64),
}

impl PacketKind {
    pub fn as_ltp(&self) -> Option<&LtpHeader> {
        match self {
            PacketKind::Ltp(h) => Some(h),
            _ => None,
        }
    }

    pub fn as_tcp(&self) -> Option<&TcpSeg> {
        match self {
            PacketKind::Tcp(s) => Some(s),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mss_accounting() {
        assert_eq!(LTP_MSS, 1500 - 28 - 9);
        assert_eq!(TCP_MSS, 1460);
    }
}
