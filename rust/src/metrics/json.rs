//! A minimal, dependency-free JSON value with **deterministic** rendering:
//! object keys keep insertion order, numbers render via Rust's shortest
//! roundtrip formatting, and no timestamps or map iteration order can
//! sneak in — so the scenario engine's promise "same seed → byte-identical
//! report" holds down to the serialized bytes.

use std::fmt::Write as _;

/// A JSON value. Build with the `From` impls and [`Json::obj`]/[`Json::arr`].
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Finite floats only; non-finite values render as `null`.
    Num(f64),
    UInt(u64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered key/value pairs (deterministic serialization).
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Serialize compactly (no whitespace).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with two-space indentation.
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::UInt(n) => {
                let _ = write!(out, "{n}");
            }
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        // Integral floats render without a fractional part (and without
        // the `-0` wart).
        let _ = write!(out, "{}", x.trunc() as i64);
    } else {
        // Rust's shortest-roundtrip float formatting is deterministic and
        // never uses exponent notation for this range.
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::UInt(n)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::UInt(n as u64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_structure() {
        let j = Json::obj(vec![
            ("name", "incast".into()),
            ("seed", 7u64.into()),
            ("ok", true.into()),
            ("cases", Json::arr([Json::Num(1.5), Json::Null])),
        ]);
        assert_eq!(
            j.render(),
            r#"{"name":"incast","seed":7,"ok":true,"cases":[1.5,null]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(Json::Str("a\"b\\c\nd".into()).render(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::Str("\u{1}".into()).render(), "\"\\u0001\"");
    }

    #[test]
    fn number_edge_cases() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
        assert_eq!(Json::Num(3.0).render(), "3");
        assert_eq!(Json::Num(-0.0).render(), "0");
        assert_eq!(Json::Num(0.25).render(), "0.25");
    }

    #[test]
    fn object_key_order_is_insertion_order() {
        let a = Json::obj(vec![("z", 1u64.into()), ("a", 2u64.into())]);
        assert_eq!(a.render(), r#"{"z":1,"a":2}"#);
    }

    #[test]
    fn pretty_and_compact_agree_on_content() {
        let j = Json::obj(vec![("xs", Json::arr([Json::UInt(1), Json::UInt(2)]))]);
        let compact = j.render();
        let pretty: String = j.render_pretty().chars().filter(|c| !c.is_whitespace()).collect();
        assert_eq!(compact.replace(": ", ":"), pretty);
    }
}
