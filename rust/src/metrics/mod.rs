//! Result presentation: markdown tables (the figure runners print the same
//! rows/series the paper reports), a deterministic JSON value for the
//! scenario engine's machine-readable reports, and small series helpers.

mod json;

pub use json::Json;

use std::fmt::Write as _;

/// A simple markdown table builder.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |out: &mut String, cells: &[String]| {
            out.push('|');
            for i in 0..ncols {
                let _ = write!(out, " {:>w$} |", cells[i], w = widths[i]);
            }
            out.push('\n');
        };
        line(&mut out, &self.header);
        out.push('|');
        for w in &widths {
            let _ = write!(out, "{}|", "-".repeat(w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Print to stdout and write to `results/<name>.md` (or
    /// `$LTP_RESULTS_DIR/<name>.md`).
    pub fn emit(&self, name: &str, title: &str) {
        let md = format!("## {title}\n\n{}\n", self.to_markdown());
        println!("{md}");
        let dir = std::env::var("LTP_RESULTS_DIR").unwrap_or_else(|_| "results".into());
        if std::fs::create_dir_all(&dir).is_ok() {
            let _ = std::fs::write(format!("{dir}/{name}.md"), md);
        }
    }
}

/// Format a ratio like `1.26x`.
pub fn ratio(a: f64, b: f64) -> String {
    if b == 0.0 {
        "inf".into()
    } else {
        format!("{:.2}x", a / b)
    }
}

/// Format a percentage delta like `-48.58%` (paper Fig 4 style).
pub fn pct_delta(value: f64, baseline: f64) -> String {
    if baseline == 0.0 {
        return "n/a".into();
    }
    format!("{:+.2}%", (value - baseline) / baseline * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new(vec!["proto", "bst"]);
        t.row(vec!["ltp", "1.0"]).row(vec!["cubic", "30.4"]);
        let md = t.to_markdown();
        assert!(md.contains("| proto |"));
        assert!(md.contains("| cubic |"));
        assert_eq!(md.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new(vec!["a", "b"]).row(vec!["only-one"]);
    }

    #[test]
    fn deltas() {
        assert_eq!(pct_delta(51.42, 100.0), "-48.58%");
        assert_eq!(ratio(30.0, 1.0), "30.00x");
    }
}
