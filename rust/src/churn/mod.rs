//! The **churn plane** (DESIGN.md §1.5) — the fifth pluggable layer after
//! transports (§1.1), aggregation topologies (§1.2), compute backends
//! (§1.3), and gradient codecs (§1.4).
//!
//! A [`ChurnModel`] decides *who is training and over what link*: it maps a
//! run configuration to a deterministic [`ChurnPlan`] holding a per-iteration
//! membership schedule (which workers are active at each barrier) and a
//! per-worker link profile (straggler bandwidth/latency multipliers and an
//! independent Gilbert–Elliott loss process per worker edge). Models are
//! registered under string keys and instantiated from specs reusing the
//! transport/aggregation/backend/codec grammar (`key[:name=value,...]`,
//! [`parse_churn`]):
//!
//! * `none` — the identity model: every worker is present for every
//!   iteration and every worker edge uses the fabric's shared [`LinkCfg`].
//!   This is the default, and default runs keep their golden report bytes.
//! * `churn` — seeded per-worker departure/rejoin processes drawn at epoch
//!   boundaries (`rate=<0..1>` departure probability per worker per epoch,
//!   `flap=<iters>` absence length, `min=<count>` active-set floor) plus
//!   optional link heterogeneity (`stragglers=<0..1>` straggler fraction,
//!   `slow=<mult>` bandwidth/latency multiplier, `ge=<on|off>` independent
//!   per-worker Gilbert–Elliott loss).
//!
//! Determinism is per-worker, not per-run: worker `w`'s membership process
//! draws from PCG stream [`MEMBERSHIP_STREAM`]` + w` and its link profile
//! from [`LINK_STREAM`]` + w`, so worker 3's schedule in an 8-worker run is
//! byte-identical to worker 3's schedule in a 16-worker run at the same
//! seed, and `--jobs N` sweeps reproduce serial plans exactly. The plan is
//! a pure function of `(spec, workers, iters, batches_per_epoch, seed)` —
//! nothing is drawn at simulation time.

pub mod coexist;

use crate::ps::spec::{canonical, parse_params, unknown_param};
use crate::simnet::{LinkCfg, LossModel};
use crate::util::Pcg64;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// PCG stream base for worker membership processes: worker `w` draws its
/// departure/rejoin schedule from stream `MEMBERSHIP_STREAM + w`. High
/// above the simnet's node (`1000 + entity`) and link (`2000 + link_id`)
/// stream ranges so churn draws never collide with wire randomness.
pub const MEMBERSHIP_STREAM: u64 = 1 << 32;

/// PCG stream base for worker link profiles: worker `w` draws its
/// straggler flag and Gilbert–Elliott parameters from `LINK_STREAM + w`.
pub const LINK_STREAM: u64 = 1 << 33;

/// A churn model: thread-shareable, registered under a string key,
/// instantiated from CLI specs like `churn:rate=0.1,flap=2`.
pub trait ChurnModel: Send + Sync {
    /// Canonical spec string — the model's label everywhere.
    fn name(&self) -> &str;

    /// Can any worker ever be absent from a barrier? `false` means the
    /// plan's schedule is all-true and the runner may keep the fixed
    /// worker-set fast path.
    fn perturbs_membership(&self) -> bool;

    /// Does any worker edge deviate from the fabric's shared [`LinkCfg`]?
    /// `false` means [`ChurnPlan::edge_cfg`] is the identity.
    fn perturbs_links(&self) -> bool;

    /// Materialize the deterministic plan for a run shape. Pure in its
    /// arguments: same inputs, same plan, on any thread.
    fn plan(&self, workers: usize, iters: u64, batches_per_epoch: u64, seed: u64) -> ChurnPlan;
}

/// A parsed, validated churn spec: the handle stored in run configurations
/// and carried across worker threads by the sweep driver. Clones share the
/// underlying [`ChurnModel`].
#[derive(Clone)]
pub struct ChurnSpec(Arc<dyn ChurnModel>);

impl ChurnSpec {
    /// Canonical spec string — the model's name everywhere (labels, JSON
    /// reports, bench records). Borrowed; no per-call allocation.
    pub fn name(&self) -> &str {
        self.0.name()
    }

    /// Is this the bare default (`none`)? Default runs must keep their
    /// report bytes golden, so reporting layers emit churn fields only
    /// when this is false.
    pub fn is_default(&self) -> bool {
        self.name() == "none"
    }
}

impl std::ops::Deref for ChurnSpec {
    type Target = dyn ChurnModel;

    fn deref(&self) -> &(dyn ChurnModel + 'static) {
        &*self.0
    }
}

impl std::fmt::Display for ChurnSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::fmt::Debug for ChurnSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ChurnSpec({})", self.name())
    }
}

/// Two specs are equal iff their canonical names are.
impl PartialEq for ChurnSpec {
    fn eq(&self, other: &ChurnSpec) -> bool {
        self.name() == other.name()
    }
}

impl std::str::FromStr for ChurnSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<ChurnSpec> {
        parse_churn(s)
    }
}

/// One worker edge's link profile: divisors/multipliers applied to the
/// fabric's shared [`LinkCfg`] plus an optional per-worker loss process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkerLink {
    /// Bandwidth divisor (stragglers get `rate_bps / rate_div`).
    pub rate_div: u64,
    /// Propagation-delay multiplier.
    pub delay_mult: u64,
    /// Per-worker loss process; `None` keeps the fabric's shared model.
    pub loss: Option<LossModel>,
}

impl WorkerLink {
    /// The identity profile: the worker edge equals the fabric default.
    pub fn identity() -> WorkerLink {
        WorkerLink { rate_div: 1, delay_mult: 1, loss: None }
    }
}

/// A materialized churn plan: the per-iteration membership schedule and the
/// per-worker link profiles for one run. Pure data — builders slice it into
/// node-local views, the simnet never sees it.
#[derive(Debug, Clone)]
pub struct ChurnPlan {
    /// `active[iter][worker]`: is `worker` a barrier participant at `iter`?
    pub active: Vec<Vec<bool>>,
    /// Per-worker link profiles, indexed by global worker index.
    pub links: Vec<WorkerLink>,
}

impl ChurnPlan {
    /// An all-present, identity-link plan (what `none` materializes).
    pub fn stable(workers: usize, iters: u64) -> ChurnPlan {
        ChurnPlan {
            active: vec![vec![true; workers]; iters as usize],
            links: vec![WorkerLink::identity(); workers],
        }
    }

    /// Number of workers the plan was materialized for.
    pub fn workers(&self) -> usize {
        self.links.len()
    }

    /// Is `worker` a barrier participant at `iter`? Out-of-range iterations
    /// read as active (the run is over; nothing consults them).
    pub fn is_active(&self, iter: u64, worker: usize) -> bool {
        self.active.get(iter as usize).map_or(true, |row| row[worker])
    }

    /// One worker's membership column across all iterations.
    pub fn schedule(&self, worker: usize) -> Vec<bool> {
        self.active.iter().map(|row| row[worker]).collect()
    }

    /// The schedule rows restricted to a contiguous worker range — the
    /// node-local view a rack relay or shard PS indexes by local slot.
    pub fn rows_for(&self, range: std::ops::Range<usize>) -> Vec<Vec<bool>> {
        self.active.iter().map(|row| row[range.clone()].to_vec()).collect()
    }

    /// How many workers are active at `iter`?
    pub fn active_count(&self, iter: u64) -> usize {
        self.active
            .get(iter as usize)
            .map_or(self.workers(), |row| row.iter().filter(|a| **a).count())
    }

    /// `(min, max)` active-set size over the first `n_iters` iterations;
    /// `(workers, workers)` when no iteration ran.
    pub fn active_bounds(&self, n_iters: u64) -> (usize, usize) {
        let n = (n_iters as usize).min(self.active.len());
        if n == 0 {
            return (self.workers(), self.workers());
        }
        let mut lo = usize::MAX;
        let mut hi = 0;
        for iter in 0..n {
            let c = self.active_count(iter as u64);
            lo = lo.min(c);
            hi = hi.max(c);
        }
        (lo, hi)
    }

    /// Total worker-iterations over the first `n_iters` iterations — the
    /// denominator-aware replacement for `workers * iters` in wire-byte
    /// accounting.
    pub fn active_total(&self, n_iters: u64) -> u64 {
        let n = (n_iters as usize).min(self.active.len());
        (0..n).map(|i| self.active_count(i as u64) as u64).sum()
    }

    /// Does any worker miss any of the first `n_iters` barriers?
    pub fn perturbs_membership(&self, n_iters: u64) -> bool {
        let n = (n_iters as usize).min(self.active.len());
        (0..n).any(|i| self.active_count(i as u64) < self.workers())
    }

    /// Does any worker edge deviate from the fabric default?
    pub fn perturbs_links(&self) -> bool {
        self.links.iter().any(|l| *l != WorkerLink::identity())
    }

    /// Worker `w`'s edge config: the fabric `base` with this worker's
    /// profile applied. Queue and ECN provisioning stay the fabric's.
    pub fn edge_cfg(&self, base: LinkCfg, w: usize) -> LinkCfg {
        let wl = self.links[w];
        let mut cfg = base;
        cfg.rate_bps = (base.rate_bps / wl.rate_div).max(1);
        cfg.delay = base.delay.saturating_mul(wl.delay_mult);
        if let Some(loss) = wl.loss {
            cfg.loss = loss;
        }
        cfg
    }
}

/// One registered churn model family.
pub struct ChurnDef {
    /// Spec key (`--churn <key>[:params]`).
    pub key: &'static str,
    pub summary: &'static str,
    /// Accepted `name=value` parameters, for `ltp churn list`.
    pub params: &'static str,
    build: fn(&[(String, String)]) -> Result<ChurnSpec>,
}

/// The churn registry. Append entries here; the CLI (`ltp churn list`),
/// `--churn` flags, and the `churn_matrix` scenario follow.
pub const CHURN_REGISTRY: &[ChurnDef] = &[
    ChurnDef {
        key: "none",
        summary: "stable membership on the shared fabric link (default; golden bytes)",
        params: "",
        build: build_none,
    },
    ChurnDef {
        key: "churn",
        summary: "seeded per-worker departure/rejoin at epoch boundaries, optional stragglers and per-worker GE loss",
        params: "rate=<0..1> (required), flap=<iters>, min=<count>, stragglers=<0..1>, slow=<mult>, ge=<on|off>",
        build: build_churn,
    },
];

/// The registry (function form, for iteration symmetry with the scenario
/// engine).
pub fn churn_registry() -> &'static [ChurnDef] {
    CHURN_REGISTRY
}

/// Parse a churn spec (`none`, `churn:rate=0.1,flap=2`) against the
/// registry.
pub fn parse_churn(spec: &str) -> Result<ChurnSpec> {
    let spec = spec.trim();
    let (key, rest) = match spec.split_once(':') {
        Some((k, r)) => (k, Some(r)),
        None => (spec, None),
    };
    let key = key.to_ascii_lowercase();
    let Some(def) = CHURN_REGISTRY.iter().find(|d| d.key == key) else {
        let known: Vec<&str> = CHURN_REGISTRY.iter().map(|d| d.key).collect();
        bail!("unknown churn model `{key}` in spec `{spec}` (known: {})", known.join(", "));
    };
    let params = parse_params(rest).with_context(|| format!("in churn spec `{spec}`"))?;
    (def.build)(&params).with_context(|| format!("in churn spec `{spec}`"))
}

/// The default spec: stable membership, shared fabric link.
pub fn default_churn() -> ChurnSpec {
    parse_churn("none").expect("registry default")
}

// ---------------------------------------------------------------------------
// Registered models.
// ---------------------------------------------------------------------------

/// The identity model behind `none`.
struct NoChurn;

impl ChurnModel for NoChurn {
    fn name(&self) -> &str {
        "none"
    }

    fn perturbs_membership(&self) -> bool {
        false
    }

    fn perturbs_links(&self) -> bool {
        false
    }

    fn plan(&self, workers: usize, iters: u64, _bpe: u64, _seed: u64) -> ChurnPlan {
        ChurnPlan::stable(workers, iters)
    }
}

fn build_none(params: &[(String, String)]) -> Result<ChurnSpec> {
    if let Some((k, _)) = params.first() {
        return Err(unknown_param("none", k, "none"));
    }
    Ok(ChurnSpec(Arc::new(NoChurn)))
}

/// Straggler `slow` default: a 4× slower worker, the classic tail-latency
/// regime.
const DEFAULT_SLOW: u64 = 4;
/// Flap default: a departed worker rejoins after 2 iterations.
const DEFAULT_FLAP: u64 = 2;

/// The seeded process behind `churn:rate=...`.
struct ChurnProcess {
    spec: String,
    /// Per-worker departure probability at each epoch boundary.
    rate: f64,
    /// Iterations a departed worker stays away; 0 = departed forever.
    flap: u64,
    /// Active-set floor: departures that would drop below it are vetoed.
    min: usize,
    /// Fraction of workers drawn as stragglers.
    stragglers: f64,
    /// Straggler bandwidth divisor / delay multiplier.
    slow: u64,
    /// Give every worker an independent Gilbert–Elliott loss process?
    ge: bool,
}

impl ChurnModel for ChurnProcess {
    fn name(&self) -> &str {
        &self.spec
    }

    fn perturbs_membership(&self) -> bool {
        self.rate > 0.0
    }

    fn perturbs_links(&self) -> bool {
        self.stragglers > 0.0 || self.ge
    }

    fn plan(&self, workers: usize, iters: u64, batches_per_epoch: u64, seed: u64) -> ChurnPlan {
        let bpe = batches_per_epoch.max(1);
        // Membership: worker w draws only from its own stream, and draws
        // *unconditionally* at every epoch boundary — the stream position
        // depends on the epoch count alone, never on other workers or on
        // the worker's own history, so w's column is invariant under the
        // total worker count and the draw order.
        let mut rngs: Vec<Pcg64> =
            (0..workers).map(|w| Pcg64::new(seed, MEMBERSHIP_STREAM + w as u64)).collect();
        let mut active_now = vec![true; workers];
        let mut rejoin_at = vec![0u64; workers];
        let mut active = Vec::with_capacity(iters as usize);
        for iter in 0..iters {
            // Admissions first: a flapped worker rejoins at its barrier.
            for w in 0..workers {
                if !active_now[w] && rejoin_at[w] <= iter {
                    active_now[w] = true;
                }
            }
            if iter > 0 && iter % bpe == 0 {
                for w in 0..workers {
                    let departs = rngs[w].chance(self.rate);
                    let n_active = active_now.iter().filter(|a| **a).count();
                    if departs && active_now[w] && n_active > self.min {
                        active_now[w] = false;
                        rejoin_at[w] = if self.flap == 0 { u64::MAX } else { iter + self.flap };
                    }
                }
            }
            active.push(active_now.clone());
        }
        // Link profiles: again one stream per worker, with a fixed draw
        // order (straggler flag, then the four GE parameters) so enabling
        // `ge` never shifts the straggler draw and vice versa.
        let links = (0..workers)
            .map(|w| {
                let mut rng = Pcg64::new(seed, LINK_STREAM + w as u64);
                let straggler = rng.chance(self.stragglers);
                let p_gb = 0.001 + 0.009 * rng.next_f64();
                let p_bg = 0.02 + 0.08 * rng.next_f64();
                let loss_good = 0.005 * rng.next_f64();
                let loss_bad = 0.05 + 0.20 * rng.next_f64();
                WorkerLink {
                    rate_div: if straggler { self.slow } else { 1 },
                    delay_mult: if straggler { self.slow } else { 1 },
                    loss: self.ge.then_some(LossModel::GilbertElliott {
                        p_gb,
                        p_bg,
                        loss_good,
                        loss_bad,
                    }),
                }
            })
            .collect();
        ChurnPlan { active, links }
    }
}

fn build_churn(params: &[(String, String)]) -> Result<ChurnSpec> {
    let (mut rate, mut flap, mut min, mut stragglers, mut slow, mut ge) =
        (None, None, None, None, None, None);
    for (k, v) in params {
        match k.as_str() {
            "rate" => rate = Some(parse_rate(k, v, false)?),
            "flap" => {
                let n: u64 =
                    v.parse().with_context(|| format!("bad value for `flap`: `{v}`"))?;
                flap = Some(n);
            }
            "min" => {
                let n: usize =
                    v.parse().with_context(|| format!("bad value for `min`: `{v}`"))?;
                if n == 0 {
                    bail!("`min=0`: the active set needs at least one worker");
                }
                min = Some(n);
            }
            "stragglers" => stragglers = Some(parse_rate(k, v, true)?),
            "slow" => {
                let n: u64 =
                    v.parse().with_context(|| format!("bad value for `slow`: `{v}`"))?;
                if n == 0 {
                    bail!("`slow=0`: the straggler multiplier must be >= 1");
                }
                slow = Some(n);
            }
            "ge" => ge = Some(crate::compute::parse_switch(k, v)?),
            _ => {
                return Err(unknown_param("churn", k, "rate, flap, min, stragglers, slow, ge"))
            }
        }
    }
    let Some(rate) = rate else {
        bail!("`churn` needs a departure rate: churn:rate=<0..1> (rate=0 keeps membership stable)");
    };
    // Canonical order: rate, flap, min, stragglers, slow, ge. `rate` always
    // renders (it is required); the rest only when explicitly given, so the
    // canonical form is a fixed point of the parser.
    let mut parts = vec![format!("rate={rate}")];
    if let Some(x) = flap {
        parts.push(format!("flap={x}"));
    }
    if let Some(x) = min {
        parts.push(format!("min={x}"));
    }
    if let Some(x) = stragglers {
        parts.push(format!("stragglers={x}"));
    }
    if let Some(x) = slow {
        parts.push(format!("slow={x}"));
    }
    if let Some(x) = ge {
        parts.push(format!("ge={}", if x { "on" } else { "off" }));
    }
    Ok(ChurnSpec(Arc::new(ChurnProcess {
        spec: canonical("churn", &parts),
        rate,
        flap: flap.unwrap_or(DEFAULT_FLAP),
        min: min.unwrap_or(1),
        stragglers: stragglers.unwrap_or(0.0),
        slow: slow.unwrap_or(DEFAULT_SLOW),
        ge: ge.unwrap_or(false),
    })))
}

/// Parse a probability in `[0, 1)` (or `[0, 1]` when `inclusive`): unlike
/// `spec::parse_fraction`, zero is legal — `rate=0` is the stable-membership
/// control row of the churn matrix.
fn parse_rate(k: &str, v: &str, inclusive: bool) -> Result<f64> {
    let x: f64 = v.parse().with_context(|| format!("bad value for `{k}`: `{v}`"))?;
    let ok = if inclusive { (0.0..=1.0).contains(&x) } else { (0.0..1.0).contains(&x) };
    if !ok {
        let hi = if inclusive { "<=" } else { "<" };
        bail!("`{k}={v}` out of range (need 0 <= {k} {hi} 1)");
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_parse_with_canonical_names() {
        let none = parse_churn("none").unwrap();
        assert_eq!(none.name(), "none");
        assert!(none.is_default());
        assert!(!none.perturbs_membership() && !none.perturbs_links());

        let c = parse_churn("churn:rate=0.1,flap=2").unwrap();
        assert_eq!(c.name(), "churn:rate=0.1,flap=2");
        assert!(!c.is_default());
        assert!(c.perturbs_membership() && !c.perturbs_links());

        let s = parse_churn("churn:rate=0,stragglers=0.25,slow=3,ge=on").unwrap();
        assert_eq!(s.name(), "churn:rate=0,stragglers=0.25,slow=3,ge=on");
        assert!(!s.perturbs_membership());
        assert!(s.perturbs_links());
    }

    #[test]
    fn canonical_names_are_fixed_points() {
        for spec in [
            "churn:rate=0.1",
            "churn:rate=0.1,flap=4,min=2",
            "churn:rate=0,stragglers=0.5,slow=8,ge=off",
            "churn:rate=0.05,flap=2,min=1,stragglers=0.25,slow=4,ge=on",
        ] {
            let once = parse_churn(spec).unwrap();
            let twice = parse_churn(once.name()).unwrap();
            assert_eq!(once.name(), twice.name(), "canonical form must be a fixed point");
        }
        // Parameter order normalizes.
        let c = parse_churn("churn:flap=3,rate=0.2").unwrap();
        assert_eq!(c.name(), "churn:rate=0.2,flap=3");
    }

    #[test]
    fn malformed_specs_are_rejected() {
        for bad in [
            "nope",
            "none:rate=0.1",
            "churn",
            "churn:",
            "churn:rate",
            "churn:rate=",
            "churn:rate=1",
            "churn:rate=-0.1",
            "churn:rate=0.1,rate=0.2",
            "churn:flap=2", // rate is required
            "churn:rate=0.1,min=0",
            "churn:rate=0.1,slow=0",
            "churn:rate=0.1,stragglers=1.5",
            "churn:rate=0.1,ge=maybe",
            "churn:rate=0.1,window=3",
        ] {
            assert!(parse_churn(bad).is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn stable_plan_is_the_identity() {
        let plan = default_churn().plan(4, 6, 2, 7);
        assert!(!plan.perturbs_membership(6));
        assert!(!plan.perturbs_links());
        assert_eq!(plan.active_bounds(6), (4, 4));
        assert_eq!(plan.active_total(6), 24);
        let base = LinkCfg::dcn(10, 5);
        let cfg = plan.edge_cfg(base, 0);
        assert_eq!(cfg.rate_bps, base.rate_bps);
        assert_eq!(cfg.delay, base.delay);
        assert_eq!(cfg.loss, base.loss);
    }

    #[test]
    fn plans_are_seed_reproducible() {
        let c = parse_churn("churn:rate=0.3,flap=2,stragglers=0.5,ge=on").unwrap();
        let a = c.plan(8, 20, 2, 42);
        let b = c.plan(8, 20, 2, 42);
        assert_eq!(a.active, b.active);
        assert_eq!(a.links, b.links);
        let other = c.plan(8, 20, 2, 43);
        assert!(
            other.active != a.active || other.links != a.links,
            "different seeds should perturb differently"
        );
    }

    #[test]
    fn worker_columns_are_independent_of_worker_count() {
        // Worker w draws only from its own streams, so its schedule and
        // link profile are identical whether the run has 8 or 16 workers.
        // (The min-floor veto is the only cross-worker coupling; at
        // rate=0.15 with flap=2 absences never accumulate, so the floor
        // of 1 cannot bind in either plan.)
        let c = parse_churn("churn:rate=0.15,flap=2,min=1,stragglers=0.5,ge=on").unwrap();
        let small = c.plan(8, 24, 2, 9);
        let big = c.plan(16, 24, 2, 9);
        for w in 0..8 {
            assert_eq!(small.links[w], big.links[w], "link profile for worker {w}");
            assert_eq!(small.schedule(w), big.schedule(w), "membership column for worker {w}");
        }
        assert!(small.perturbs_membership(24), "seed 9 should produce at least one departure");
    }

    #[test]
    fn min_floor_is_honored() {
        let c = parse_churn("churn:rate=0.9,flap=0,min=2").unwrap();
        let plan = c.plan(8, 40, 2, 5);
        for iter in 0..40 {
            assert!(plan.active_count(iter) >= 2, "floor violated at iter {iter}");
        }
        let (lo, _hi) = plan.active_bounds(40);
        assert!(lo >= 2);
    }

    #[test]
    fn flap_brings_workers_back() {
        // flap=1 with bpe=2: a departure at boundary k rejoins at k+1,
        // which is not a boundary, so no redraw can extend the absence —
        // every absent run is exactly one iteration.
        let c = parse_churn("churn:rate=0.5,flap=1").unwrap();
        let plan = c.plan(8, 30, 2, 3);
        let mut departures = 0;
        for w in 0..8 {
            let col = plan.schedule(w);
            let mut absent_run = 0;
            for active in &col {
                if *active {
                    absent_run = 0;
                } else {
                    absent_run += 1;
                    departures += 1;
                    assert!(absent_run <= 1, "flap=1 worker {w} absent too long");
                }
            }
        }
        assert!(departures > 0, "rate=0.5 over 14 boundaries should produce departures");
    }

    #[test]
    fn straggler_profiles_divide_bandwidth() {
        let c = parse_churn("churn:rate=0,stragglers=1,slow=3").unwrap();
        let plan = c.plan(4, 4, 2, 11);
        let base = LinkCfg::dcn(10, 5);
        for w in 0..4 {
            let cfg = plan.edge_cfg(base, w);
            assert_eq!(cfg.rate_bps, base.rate_bps / 3);
            assert_eq!(cfg.delay, base.delay * 3);
            assert_eq!(cfg.loss, base.loss, "no ge => fabric loss model");
        }
    }

    #[test]
    fn ge_profiles_are_heterogeneous() {
        let c = parse_churn("churn:rate=0,ge=on").unwrap();
        let plan = c.plan(8, 4, 2, 13);
        let mut rates: Vec<u64> = Vec::new();
        for wl in &plan.links {
            let Some(LossModel::GilbertElliott { p_gb, p_bg, loss_good, loss_bad }) = wl.loss
            else {
                panic!("ge=on must give every worker a GE process");
            };
            assert!((0.001..0.010).contains(&p_gb));
            assert!((0.02..0.10).contains(&p_bg));
            assert!((0.0..0.005).contains(&loss_good));
            assert!((0.05..0.25).contains(&loss_bad));
            rates.push((loss_bad * 1e9) as u64);
        }
        rates.dedup();
        assert!(rates.len() > 1, "workers must draw distinct GE processes");
    }

    #[test]
    fn registry_is_well_formed() {
        assert_eq!(churn_registry()[0].key, "none");
        for def in churn_registry() {
            assert!(!def.summary.is_empty());
        }
        // Every registry key parses at its minimal spec.
        assert!(parse_churn("none").is_ok());
        assert!(parse_churn("churn:rate=0").is_ok());
    }
}
