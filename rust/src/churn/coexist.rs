//! Multi-job coexistence: several independent BSP training jobs sharing
//! one fabric (DESIGN.md §1.5).
//!
//! The jobs are placed on a two-rack topology whose inter-rack trunk runs
//! at a single edge rate: every parameter server sits in rack 0, every
//! worker in rack 1, so all gather incasts and model broadcasts contend
//! on the trunk. Each job keeps its own [`PsNode`] endpoint, flow space,
//! and per-iteration report; cross-job isolation comes from entity-level
//! routing (a PS only ever sees packets addressed to it), so the jobs
//! interact exactly one way — queueing on the shared links.
//!
//! Coexistence runs are modeled-compute only (no backend, dense codec);
//! each job's churn spec still applies — membership rows and schedules
//! are attached per job — but per-worker link dynamics are not, because
//! the shared fabric's edges are common property of all jobs.

use crate::proto::ThresholdTracker;
use crate::ps::{
    IterStats, ModeledCompute, NullAggregate, PsFlowPlan, PsNode, TrainingCfg, WorkerNode,
    WorkerRoute,
};
use crate::simnet::{two_rack, EntityId, Node, Sim};
use crate::util::jain_fairness;
use crate::{Nanos, MS, SEC};
use std::cell::RefCell;
use std::rc::Rc;

/// One job's outcome after a shared-fabric run.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub label: String,
    /// Iterations the job's barrier completed before the horizon.
    pub iters_done: u64,
    pub mean_bst_ms: f64,
    pub mean_delivered: f64,
    /// Nominal synchronization goodput: `iters × workers × model_bytes`
    /// over the job's own completion span, in Mbit/s. This is the
    /// quantity the fairness index is computed on.
    pub goodput_mbps: f64,
}

/// The outcome of a coexistence run.
#[derive(Debug, Clone)]
pub struct CoexistReport {
    /// Per-job outcomes, in submission order.
    pub jobs: Vec<JobOutcome>,
    /// Jain fairness index over the jobs' goodputs (1.0 = perfectly
    /// even sharing of the trunk).
    pub jain: f64,
    /// Simulated time when the last barrier finished (or the horizon).
    pub total_time: Nanos,
}

/// Run `jobs` concurrently on one shared two-rack fabric and report
/// per-job results plus the Jain fairness index of their goodputs.
///
/// The fabric seed, edge link, and switch delay come from the first job;
/// the trunk runs at one edge rate so the jobs genuinely contend.
///
/// # Panics
///
/// Panics when `jobs` is empty.
pub fn run_coexist(jobs: &[(String, TrainingCfg)]) -> CoexistReport {
    assert!(!jobs.is_empty(), "a coexistence run needs at least one job");
    let base = &jobs[0].1;
    let mut sim = Sim::new(base.seed);
    // Entity-id layout mirrors `two_rack`: agg 0, tor0 1, tor1 2, then
    // rack-0 hosts (one PS per job), then rack-1 hosts (workers,
    // job-major).
    let n_jobs = jobs.len();
    let mut rack0: Vec<Box<dyn Node>> = Vec::with_capacity(n_jobs);
    let mut rack1: Vec<Box<dyn Node>> = Vec::new();
    let mut reports: Vec<Rc<RefCell<Vec<IterStats>>>> = Vec::with_capacity(n_jobs);
    let mut worker_off = 0usize;
    for (j, (_label, cfg)) in jobs.iter().enumerate() {
        let ps_id: EntityId = 3 + j;
        let report: Rc<RefCell<Vec<IterStats>>> = Rc::new(RefCell::new(Vec::new()));
        let closes = Rc::new(RefCell::new(Vec::new()));
        let tuning = cfg.proto.tuning();
        let tracker = ThresholdTracker::new(
            cfg.n_workers,
            tuning.deadline_slack.unwrap_or(cfg.deadline_slack),
            tuning.pct_threshold.unwrap_or(cfg.pct_threshold),
        );
        let plan = (!cfg.churn.is_default()).then(|| {
            cfg.churn.plan(cfg.n_workers, cfg.iters, cfg.batches_per_epoch, cfg.seed)
        });
        let worker_ids: Vec<EntityId> =
            (0..cfg.n_workers).map(|w| 3 + n_jobs + worker_off + w).collect();
        let mut ps = PsNode::new(
            worker_ids,
            cfg.proto.clone(),
            cfg.model_bytes,
            cfg.critical.clone(),
            PsFlowPlan::single(cfg.n_workers),
            Box::new(NullAggregate(cfg.agg_time)),
            tracker,
            cfg.iters,
            cfg.batches_per_epoch,
            report.clone(),
            closes,
        );
        if let Some(p) = &plan {
            ps = ps.with_membership(p.rows_for(0..cfg.n_workers));
        }
        rack0.push(Box::new(ps));
        for w in 0..cfg.n_workers {
            let route = WorkerRoute::single(
                ps_id,
                w,
                cfg.n_workers,
                cfg.model_bytes,
                cfg.critical.clone(),
            );
            let mut node = WorkerNode::new(
                w,
                vec![route],
                cfg.proto.clone(),
                Box::new(ModeledCompute(cfg.compute_time)),
                cfg.iters,
            );
            if let Some(p) = &plan {
                node = node.with_schedule(p.schedule(w));
            }
            rack1.push(Box::new(node));
        }
        reports.push(report);
        worker_off += cfg.n_workers;
    }
    let topo = two_rack(&mut sim, [rack0, rack1], base.link, base.link, base.switch_delay);
    debug_assert_eq!(topo.hosts.first().copied(), Some(3));
    // Same sliced loop as `run_with`: stop as soon as every job's barrier
    // has finished all its iterations.
    let horizon = jobs.iter().map(|(_, c)| c.horizon).max().unwrap();
    let slice = 100 * MS;
    let mut until = slice;
    loop {
        sim.run_until(until.min(horizon));
        let done = jobs
            .iter()
            .zip(&reports)
            .all(|((_, c), r)| r.borrow().len() as u64 >= c.iters);
        if done || sim.is_idle() || until >= horizon {
            break;
        }
        until += slice;
    }
    let mut outs = Vec::with_capacity(n_jobs);
    let mut total_time = 0;
    for ((label, cfg), report) in jobs.iter().zip(&reports) {
        let rep = report.borrow();
        let iters_done = rep.len() as u64;
        let span = rep.last().map(|i| i.end).unwrap_or(sim.now()).max(1);
        total_time = total_time.max(span);
        let n = rep.len().max(1) as f64;
        let bits = iters_done * cfg.n_workers as u64 * cfg.model_bytes * 8;
        outs.push(JobOutcome {
            label: label.clone(),
            iters_done,
            mean_bst_ms: rep.iter().map(|i| i.bst as f64).sum::<f64>() / n / MS as f64,
            mean_delivered: if rep.is_empty() {
                1.0
            } else {
                rep.iter().map(|i| i.mean_delivered).sum::<f64>() / n
            },
            goodput_mbps: bits as f64 / (span as f64 / SEC as f64) / 1e6,
        });
    }
    let goodputs: Vec<f64> = outs.iter().map(|o| o.goodput_mbps).collect();
    CoexistReport { jobs: outs, jain: jain_fairness(&goodputs), total_time }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Workload;
    use crate::ps::parse_proto;

    fn quick_job(label: &str, iters: u64) -> (String, TrainingCfg) {
        let mut cfg =
            TrainingCfg::modeled(parse_proto("ltp").unwrap(), Workload::Micro, 2);
        cfg.iters = iters;
        (label.to_string(), cfg)
    }

    #[test]
    fn identical_jobs_share_the_trunk_fairly() {
        let jobs = vec![quick_job("a", 2), quick_job("b", 2)];
        let r = run_coexist(&jobs);
        assert_eq!(r.jobs.len(), 2);
        for j in &r.jobs {
            assert_eq!(j.iters_done, 2, "{}: barrier must complete", j.label);
            assert!(j.goodput_mbps > 0.0, "{}", j.label);
        }
        assert!(r.jain >= 0.8, "identical jobs must share evenly: jain {}", r.jain);
        assert!(r.total_time > 0);
    }

    #[test]
    fn coexisting_jobs_cost_each_other_sync_time() {
        let solo = run_coexist(&[quick_job("solo", 2)]);
        assert!((solo.jain - 1.0).abs() < 1e-9, "single job is trivially fair");
        let pair = run_coexist(&[quick_job("a", 2), quick_job("b", 2)]);
        assert!(
            pair.jobs[0].mean_bst_ms >= solo.jobs[0].mean_bst_ms,
            "trunk contention cannot make a job faster: {} vs {}",
            pair.jobs[0].mean_bst_ms,
            solo.jobs[0].mean_bst_ms
        );
    }

    #[test]
    fn churned_job_coexists_with_a_stable_one() {
        let stable = quick_job("stable", 3);
        let mut churned = quick_job("churned", 3);
        churned.1.batches_per_epoch = 1;
        churned.1.churn = crate::churn::parse_churn("churn:rate=0.5,flap=1").unwrap();
        let r = run_coexist(&[stable, churned]);
        for j in &r.jobs {
            assert_eq!(j.iters_done, 3, "{}: barrier must complete", j.label);
        }
        assert!(r.jain > 0.0);
    }
}
