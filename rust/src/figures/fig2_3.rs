//! Paper Fig 2 (DML scaling: epoch time vs workers, comm/comp ratio) and
//! Fig 3 (long-tail FCT distribution under 8→1 incast).

use crate::config::Workload;
use crate::metrics::Table;
use crate::ps::{parse_proto, RunBuilder};
use crate::runtime::pool;
use crate::simnet::{LinkCfg, Sim};
use crate::tcp::{FctLog, TcpReceiverNode, TcpSender, TcpSenderNode};
use crate::util::{Histogram, Summary};
use crate::wire::TCP_MSS;
use crate::{MS, SEC};
use std::cell::RefCell;
use std::rc::Rc;

#[derive(Debug, Clone)]
pub struct Fig2Row {
    pub workers: usize,
    pub iter_time_ms: f64,
    pub comm_ratio: f64,
}

/// Fig 2: ResNet50-sized training on 1/2/4/8 workers over kernel-default
/// TCP. Epoch time per worker shrinks, but the communication share grows —
/// the scalability problem motivating LTP.
pub fn fig2(quick: bool, jobs: usize) -> Vec<Fig2Row> {
    let iters = if quick { 2 } else { 5 };
    // One job per worker-count sweep point; rendering happens post-merge.
    let points = pool::run_jobs(jobs, vec![1usize, 2, 4, 8], |_, w| {
        let report = RunBuilder::modeled(
            parse_proto("cubic").expect("registered spec"),
            Workload::Resnet50,
            w,
        )
        .iters(iters)
        .run()
        .expect("fig2 configurations are valid");
        let iter_time =
            report.total_time as f64 / report.iters.len().max(1) as f64 / MS as f64;
        let comp_ms = Workload::Resnet50.compute_time() as f64 / MS as f64;
        let comm_ratio = (iter_time - comp_ms).max(0.0) / iter_time.max(1e-9);
        let samples = report.throughput(w, Workload::Resnet50.batch_images());
        (w, iter_time, comp_ms, comm_ratio, samples)
    });
    let mut rows = Vec::new();
    let mut table = Table::new(vec![
        "workers",
        "iter time (ms)",
        "compute (ms)",
        "comm share",
        "samples/s (total)",
    ]);
    for (w, iter_time, comp_ms, comm_ratio, samples) in points {
        table.row(vec![
            w.to_string(),
            format!("{iter_time:.1}"),
            format!("{comp_ms:.1}"),
            format!("{:.1}%", comm_ratio * 100.0),
            format!("{samples:.1}"),
        ]);
        rows.push(Fig2Row { workers: w, iter_time_ms: iter_time, comm_ratio });
    }
    table.emit("fig2", "Fig 2 — scaling: iteration time and communication share vs workers");
    rows
}

/// Fig 3: FCT probability density of an 8→1 incast with fixed-size
/// messages under TCP — most flows bunch together, stragglers form the
/// long tail that stalls BSP.
pub fn fig3(quick: bool, jobs: usize) -> (Summary, Histogram) {
    let bytes: u64 = 10_000_000;
    let rounds = if quick { 3 } else { 10 };
    // One job per incast round; each round is an independent seeded sim.
    let per_round: Vec<Vec<f64>> = pool::run_jobs(jobs, (0..rounds).collect(), |_, round| {
        let log: FctLog = Rc::new(RefCell::new(vec![]));
        let mut sim = Sim::new(100 + round);
        let sw = sim.add_switch(500);
        let rcv = sim.add_host(Box::new(TcpReceiverNode::new()));
        // Shallow per-port buffer (the regime where incast stragglers form:
        // a synchronized burst overflows the queue and an unlucky flow eats
        // a 200 ms min-RTO).
        let edge = LinkCfg::dcn(10, 5).with_queue(64 * 1024);
        let (r_up, _) = sim.add_duplex(rcv, sw, edge);
        sim.set_default_uplink(rcv, r_up);
        for i in 0..8u64 {
            let snd =
                TcpSender::new(i, bytes, TCP_MSS, crate::cc::CcAlgo::Reno.build(TCP_MSS));
            let h = sim.add_host(Box::new(TcpSenderNode::new(snd, rcv).with_log(log.clone())));
            let (up, _) = sim.add_duplex(h, sw, edge);
            sim.set_default_uplink(h, up);
        }
        sim.run_until(120 * SEC);
        log.borrow().iter().map(|&(_, t, _)| t as f64 / MS as f64).collect::<Vec<f64>>()
    });
    let mut fcts_ms: Vec<f64> = Vec::new();
    for round in per_round {
        fcts_ms.extend(round);
    }
    let summary = Summary::of(&fcts_ms);
    let mut hist = Histogram::new(0.0, summary.max * 1.05 + 1e-9, 20);
    for &f in &fcts_ms {
        hist.add(f);
    }
    let mut table = Table::new(vec!["FCT bin (ms)", "density"]);
    for (i, d) in hist.density().iter().enumerate() {
        table.row(vec![format!("{:.1}", hist.center(i)), format!("{d:.3}")]);
    }
    table.emit("fig3", "Fig 3 — FCT distribution of 8→1 incast (TCP Reno)");
    println!(
        "fig3: n={} p50={:.1} ms p99={:.1} ms max={:.1} ms tail(max/p50)={:.2}x\n",
        summary.count,
        summary.p50,
        summary.p99,
        summary.max,
        summary.max / summary.p50.max(1e-9)
    );
    (summary, hist)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_comm_share_grows_with_workers() {
        let rows = fig2(true, 2);
        assert_eq!(rows.len(), 4);
        // The defining shape: more workers → larger communication share.
        assert!(
            rows[3].comm_ratio > rows[0].comm_ratio,
            "comm share must grow: {:?}",
            rows.iter().map(|r| r.comm_ratio).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fig3_has_a_long_tail() {
        let (s, _h) = fig3(true, 2);
        assert_eq!(s.count, 24);
        assert!(s.max > 1.05 * s.p50, "incast must produce stragglers: max {} p50 {}", s.max, s.p50);
    }
}
