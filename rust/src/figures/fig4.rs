//! Paper Fig 4 (table): bandwidth-utilization reduction of TCP congestion
//! controls under non-congestion loss, on a 1 Gbps/40 ms WAN path and a
//! 10 Gbps/1 ms DCN path. Each cc is normalized against its own clean-link
//! goodput — exactly the paper's presentation.

use crate::cc::CcAlgo;
use crate::metrics::{pct_delta, Table};
use crate::runtime::pool;
use crate::simnet::{LinkCfg, LossModel, Sim};
use crate::tcp::{FctLog, TcpReceiverNode, TcpSender, TcpSenderNode};
use crate::wire::TCP_MSS;
use crate::{Nanos, SEC};
use std::cell::RefCell;
use std::rc::Rc;

#[derive(Debug, Clone)]
pub struct Fig4Cell {
    pub env: &'static str,
    pub cc: CcAlgo,
    pub loss: f64,
    pub goodput_bps: f64,
    /// Relative to the same cc's clean-link goodput.
    pub reduction: f64,
}

fn one_flow(cc: CcAlgo, bytes: u64, link: LinkCfg, seed: u64, horizon: Nanos) -> f64 {
    let log: FctLog = Rc::new(RefCell::new(vec![]));
    let mut sim = Sim::new(seed);
    let snd = TcpSender::new(1, bytes, TCP_MSS, cc.build(TCP_MSS));
    let a = sim.add_host(Box::new(TcpSenderNode::new(snd, 1).with_log(log.clone())));
    let b = sim.add_host(Box::new(TcpReceiverNode::new()));
    sim.add_duplex(a, b, link);
    sim.run_until(horizon);
    let done = log.borrow().first().copied();
    match done {
        Some((_, fct, total)) => total as f64 * 8.0 / (fct as f64 / SEC as f64),
        None => {
            // Did not complete within the horizon: estimate from progress.
            let node = sim.node_as::<TcpSenderNode>(a);
            node.sender.bytes_acked() as f64 * 8.0 / (horizon as f64 / SEC as f64)
        }
    }
}

/// Run the Fig 4 sweep; returns the full grid.
pub fn fig4(quick: bool, jobs: usize) -> Vec<Fig4Cell> {
    let loss_rates: &[f64] =
        if quick { &[0.0, 0.001, 0.01, 0.05] } else { &super::FIG4_LOSS_RATES };
    // The loss==0 grid point doubles as the clean baseline every other
    // point in its (env, cc) row is normalized against — enforce in
    // release too, or a reordered loss table silently skews every cell.
    assert_eq!(loss_rates[0], 0.0, "fig4 loss sweep must start at the clean baseline");
    let envs: [(&'static str, LinkCfg, u64, Nanos); 2] = [
        (
            "1Gbps/40ms",
            LinkCfg::wan(1000, 20), // 20 ms one-way → 40 ms RTT
            if quick { 20_000_000 } else { 100_000_000 },
            if quick { 60 * SEC } else { 120 * SEC },
        ),
        (
            "10Gbps/1ms",
            LinkCfg::dcn(10, 500).with_queue(2 * 1024 * 1024), // 0.5 ms one-way
            if quick { 50_000_000 } else { 250_000_000 },
            if quick { 60 * SEC } else { 120 * SEC },
        ),
    ];
    // One job per (env, cc, loss) grid point, enumerated row-major so the
    // merged slice reads back in table order.
    let mut grid: Vec<(usize, CcAlgo, f64)> = Vec::new();
    for env_idx in 0..envs.len() {
        for cc in CcAlgo::ALL {
            for &p in loss_rates {
                grid.push((env_idx, cc, p));
            }
        }
    }
    let goodputs = pool::run_jobs(jobs, grid, |_, (env_idx, cc, p)| {
        let (_, link, bytes, horizon) = envs[env_idx];
        let cfg = if p == 0.0 { link } else { link.with_loss(LossModel::Bernoulli { p }) };
        one_flow(cc, bytes, cfg, 42, horizon)
    });
    let n_loss = loss_rates.len();
    let mut cells = Vec::new();
    let mut at = 0;
    for (env, _, _, _) in envs {
        let mut table = Table::new(
            std::iter::once("cc".to_string())
                .chain(loss_rates.iter().map(|l| format!("{:.2}%", l * 100.0)))
                .collect::<Vec<_>>(),
        );
        for cc in CcAlgo::ALL {
            let row_goodputs = &goodputs[at..at + n_loss];
            let clean = row_goodputs[0];
            let mut row = vec![cc.name().to_string()];
            for (li, &p) in loss_rates.iter().enumerate() {
                let goodput = row_goodputs[li];
                row.push(pct_delta(goodput, clean));
                cells.push(Fig4Cell {
                    env,
                    cc,
                    loss: p,
                    goodput_bps: goodput,
                    reduction: (goodput - clean) / clean,
                });
            }
            at += n_loss;
            table.row(row);
        }
        table.emit(
            &format!("fig4_{}", env.replace('/', "_")),
            &format!("Fig 4 — goodput change vs non-congestion loss ({env})"),
        );
    }
    cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_shapes_match_paper() {
        let cells = fig4(true, 2);
        let get = |env: &str, cc: CcAlgo, loss: f64| -> f64 {
            cells
                .iter()
                .find(|c| c.env == env && c.cc == cc && (c.loss - loss).abs() < 1e-12)
                .unwrap()
                .reduction
        };
        // DCN row: loss-based ccs collapse hard at 1 % loss…
        assert!(
            get("10Gbps/1ms", CcAlgo::Cubic, 0.01) < -0.60,
            "cubic@1% {}",
            get("10Gbps/1ms", CcAlgo::Cubic, 0.01)
        );
        assert!(get("10Gbps/1ms", CcAlgo::Reno, 0.01) < -0.60);
        // …while BBR degrades far less (paper: −18.5 % at 1 %).
        let bbr = get("10Gbps/1ms", CcAlgo::Bbr, 0.01);
        assert!(bbr > -0.55, "bbr@1% degraded too much: {bbr}");
        assert!(
            bbr > get("10Gbps/1ms", CcAlgo::Cubic, 0.01),
            "bbr must beat cubic under loss"
        );
        // WAN row: our loss-based ccs follow the Mathis bound and collapse
        // well before the paper's testbed row does (EXPERIMENTS.md Fig 4
        // note); BBR must still dominate them there.
        assert!(
            get("1Gbps/40ms", CcAlgo::Bbr, 0.01) > get("1Gbps/40ms", CcAlgo::Cubic, 0.01)
        );
    }
}
