//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §4). Each runner prints the same rows/series the paper
//! reports and returns structured data for tests and benches.
//!
//! The `quick` flag shrinks message counts/iterations so the benches stay
//! fast; shapes (who wins, by roughly what factor) are preserved.

mod fig12;
mod fig2_3;
mod fig4;
mod fig5_13;
mod fig15;

pub use fig12::{fig12, fig14, Fig12Point};
pub use fig2_3::{fig2, fig3, Fig2Row};
pub use fig4::{fig4, Fig4Cell};
pub use fig5_13::{fig13, fig5};
pub use fig15::{fig15, Fig15Result};

/// Loss rates used across the evaluation (paper §V-B, from ATP's eval).
pub const LOSS_RATES: [f64; 5] = [0.0, 0.0001, 0.001, 0.005, 0.01];

/// Fig 4's wider loss-rate sweep.
pub const FIG4_LOSS_RATES: [f64; 7] = [0.0, 0.0001, 0.001, 0.005, 0.01, 0.03, 0.05];

/// Run a figure by name ("fig2" … "fig15", or "all").
///
/// `jobs` shards each figure's independent sweep points (incast degree,
/// loss rate, worker count, …) across worker threads via
/// [`crate::runtime::pool`]; results merge in sweep order, so the printed
/// tables of the simulation-driven figures (fig2/3/4/12/13/14/15) are
/// byte-identical for any job count (0 = auto, 1 = serial) — fig13 now
/// trains the deterministic `native` backend (DESIGN.md §1.3). fig5's
/// table embeds wall-clock kernel-cost columns that vary run to run — it
/// is outside the byte-identity contract regardless of `--jobs`, and it
/// still needs the `xla` backend's artifacts (`make artifacts`).
pub fn run(name: &str, quick: bool, jobs: usize) -> anyhow::Result<()> {
    match name {
        "fig2" => {
            fig2(quick, jobs);
        }
        "fig3" => {
            fig3(quick, jobs);
        }
        "fig4" => {
            fig4(quick, jobs);
        }
        "fig5" => fig5(quick, jobs)?,
        "fig12" => {
            fig12(quick, jobs);
        }
        "fig13" => fig13(quick, jobs)?,
        "fig14" => {
            fig14(quick, jobs);
        }
        "fig15" => {
            fig15(quick);
        }
        "all" => {
            fig2(quick, jobs);
            fig3(quick, jobs);
            fig4(quick, jobs);
            fig12(quick, jobs);
            // Native-backend training figure: runs everywhere.
            fig13(quick, jobs)?;
            fig14(quick, jobs);
            fig15(quick);
            // The Pallas-kernel figure last (needs `make artifacts`).
            fig5(quick, jobs)?;
        }
        other => anyhow::bail!("unknown figure `{other}` (fig2|fig3|fig4|fig5|fig12|fig13|fig14|fig15|all)"),
    }
    Ok(())
}
