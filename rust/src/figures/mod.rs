//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §4). Each runner prints the same rows/series the paper
//! reports and returns structured data for tests and benches.
//!
//! The `quick` flag shrinks message counts/iterations so the benches stay
//! fast; shapes (who wins, by roughly what factor) are preserved.

mod fig12;
mod fig2_3;
mod fig4;
mod fig5_13;
mod fig15;

pub use fig12::{fig12, fig14, Fig12Point};
pub use fig2_3::{fig2, fig3, Fig2Row};
pub use fig4::{fig4, Fig4Cell};
pub use fig5_13::{fig13, fig5};
pub use fig15::{fig15, Fig15Result};

/// Loss rates used across the evaluation (paper §V-B, from ATP's eval).
pub const LOSS_RATES: [f64; 5] = [0.0, 0.0001, 0.001, 0.005, 0.01];

/// Fig 4's wider loss-rate sweep.
pub const FIG4_LOSS_RATES: [f64; 7] = [0.0, 0.0001, 0.001, 0.005, 0.01, 0.03, 0.05];

/// Run a figure by name ("fig2" … "fig15", or "all").
pub fn run(name: &str, quick: bool) -> anyhow::Result<()> {
    match name {
        "fig2" => {
            fig2(quick);
        }
        "fig3" => {
            fig3(quick);
        }
        "fig4" => {
            fig4(quick);
        }
        "fig5" => fig5(quick)?,
        "fig12" => {
            fig12(quick);
        }
        "fig13" => fig13(quick)?,
        "fig14" => {
            fig14(quick);
        }
        "fig15" => {
            fig15(quick);
        }
        "all" => {
            fig2(quick);
            fig3(quick);
            fig4(quick);
            fig12(quick);
            fig14(quick);
            fig15(quick);
            // Real-compute figures last (need artifacts).
            fig5(quick)?;
            fig13(quick)?;
        }
        other => anyhow::bail!("unknown figure `{other}` (fig2|fig3|fig4|fig5|fig12|fig13|fig14|fig15|all)"),
    }
    Ok(())
}
