//! Paper Fig 12 (training throughput vs non-congestion loss rate, for LTP
//! and the TCP baselines, on ResNet50- and VGG16-sized workloads) and
//! Fig 14 (per-batch synchronization time distributions, normalized to
//! LTP).

use crate::config::Workload;
use crate::metrics::{ratio, Table};
use crate::ps::{parse_proto, ProtoSpec, RunBuilder, RunReport};
use crate::runtime::pool;
use crate::simnet::LossModel;
use crate::util::Summary;

/// The four-protocol sweep the paper's throughput figures compare, as
/// registry specs (LTP leads — fig14's normalizer depends on it).
pub fn protos() -> Vec<ProtoSpec> {
    ["ltp", "bbr", "cubic", "reno"]
        .iter()
        .map(|s| parse_proto(s).expect("registered spec"))
        .collect()
}

#[derive(Debug, Clone)]
pub struct Fig12Point {
    pub workload: Workload,
    pub proto: String,
    pub loss: f64,
    pub throughput: f64,
    pub report: RunReport,
}

fn one_run(
    workload: Workload,
    proto: ProtoSpec,
    loss: f64,
    iters: u64,
    workers: usize,
    quick: bool,
) -> Fig12Point {
    let name = proto.name().to_string();
    let mut b = RunBuilder::modeled(proto, workload, workers)
        .iters(iters)
        .batches_per_epoch(iters.max(2) / 2) // exercise one epoch update
        // TCP under heavy loss can crawl: cap the horizon so a point costs
        // bounded time; throughput then reflects completed iterations.
        .horizon(if quick { 120 * crate::SEC } else { 900 * crate::SEC });
    if quick {
        // 1/8-scale messages (and proportionally shorter compute) keep the
        // quick sweep interactive; protocol ordering is preserved.
        b = b
            .model_bytes(workload.model_bytes() / 8)
            .compute_time(workload.compute_time() / 8);
    }
    if loss > 0.0 {
        b = b.loss(LossModel::Bernoulli { p: loss });
    }
    let report = b.run().expect("fig12 sweep points are valid configurations");
    let tp = if report.iters.is_empty() {
        // Nothing finished within the horizon — effectively zero.
        report.iters.len() as f64
    } else {
        report.throughput(workers, workload.batch_images())
    };
    Fig12Point { workload, proto: name, loss, throughput: tp, report }
}

/// Fig 12: images/sec for every (workload, protocol, loss-rate).
pub fn fig12(quick: bool, jobs: usize) -> Vec<Fig12Point> {
    let workers = 8;
    let loss_rates: &[f64] = if quick { &[0.0, 0.001, 0.01] } else { &super::LOSS_RATES };
    let workloads: &[(Workload, u64)] = if quick {
        &[(Workload::Resnet50, 3)]
    } else {
        &[(Workload::Resnet50, 5), (Workload::Vgg16, 3)]
    };
    let protos = protos();
    // One job per (workload, proto, loss) sweep point, row-major so the
    // merged vector reads back in table order.
    let mut sweep: Vec<(Workload, u64, ProtoSpec, f64)> = Vec::new();
    for &(workload, iters) in workloads {
        for proto in &protos {
            for &loss in loss_rates {
                sweep.push((workload, iters, proto.clone(), loss));
            }
        }
    }
    let points = pool::run_jobs(jobs, sweep, |_, (workload, iters, proto, loss)| {
        one_run(workload, proto, loss, iters, workers, quick)
    });
    let n_loss = loss_rates.len();
    for (wi, &(workload, _)) in workloads.iter().enumerate() {
        let mut table = Table::new(
            std::iter::once("proto".to_string())
                .chain(loss_rates.iter().map(|l| format!("{:.2}%", l * 100.0)))
                .chain(std::iter::once("vs cubic@max-loss".to_string()))
                .collect::<Vec<_>>(),
        );
        let base = wi * protos.len() * n_loss;
        let tp = |pi: usize, li: usize| points[base + pi * n_loss + li].throughput;
        for (pi, proto) in protos.iter().enumerate() {
            let mut row = vec![proto.name().to_string()];
            for li in 0..n_loss {
                row.push(format!("{:.1}", tp(pi, li)));
            }
            // Headline ratio: this proto vs cubic at the worst loss rate.
            let cubic_worst = tp(2, n_loss - 1);
            row.push(ratio(tp(pi, n_loss - 1), cubic_worst));
            table.row(row);
        }
        table.emit(
            &format!("fig12_{}", workload.name()),
            &format!(
                "Fig 12 — training throughput (images/s) vs loss rate, {} ({} workers)",
                workload.name(),
                workers
            ),
        );
    }
    points
}

/// Fig 14: BST distributions normalized to LTP's mean, per loss rate
/// (paper shows box plots; we print the five-number summaries).
pub fn fig14(quick: bool, jobs: usize) -> Vec<(f64, String, Summary)> {
    let workers = 8;
    let iters = if quick { 3 } else { 6 };
    let loss_rates: &[f64] = if quick { &[0.0, 0.01] } else { &[0.0, 0.0001, 0.001, 0.005, 0.01] };
    // One job per (loss, proto) point, loss-major with LTP leading each
    // group so the normalizer is available when its group renders —
    // enforce the ordering the merge loop depends on.
    let protos = protos();
    assert!(
        protos[0].is_loss_tolerant(),
        "fig14 normalizer expects the loss-tolerant protocol first"
    );
    let mut sweep: Vec<(f64, ProtoSpec)> = Vec::new();
    for &loss in loss_rates {
        for proto in &protos {
            sweep.push((loss, proto.clone()));
        }
    }
    let runs = pool::run_jobs(jobs, sweep, |_, (loss, proto)| {
        let p = one_run(Workload::Resnet50, proto.clone(), loss, iters, workers, quick);
        (loss, proto, Summary::of(&p.report.bst_values_ms()))
    });
    let mut out = Vec::new();
    let mut table = Table::new(vec![
        "loss", "proto", "p25/ltp", "p50/ltp", "p75/ltp", "max/ltp", "mean(ms)",
    ]);
    let mut ltp_mean = 1.0;
    for (loss, proto, bst) in runs {
        if proto.is_loss_tolerant() {
            ltp_mean = bst.mean.max(1e-9);
        }
        table.row(vec![
            format!("{:.2}%", loss * 100.0),
            proto.name().to_string(),
            format!("{:.2}", bst.p25 / ltp_mean),
            format!("{:.2}", bst.p50 / ltp_mean),
            format!("{:.2}", bst.p75 / ltp_mean),
            format!("{:.2}", bst.max / ltp_mean),
            format!("{:.1}", bst.mean),
        ]);
        out.push((loss, proto.name().to_string(), bst));
    }
    table.emit("fig14", "Fig 14 — BST distribution normalized to LTP (ResNet50, 8 workers)");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's headline shapes, on the quick configuration.
    #[test]
    fn fig12_ltp_wins_under_loss() {
        let points = fig12(true, 2);
        let tp = |proto: &str, loss: f64| -> f64 {
            points
                .iter()
                .find(|p| p.proto == proto && (p.loss - loss).abs() < 1e-12)
                .unwrap()
                .throughput
        };
        // The robust shapes at quick scale (1/8 messages, 3 iterations —
        // see EXPERIMENTS.md for the full-scale numbers):
        // LTP ≫ loss-based TCP at 1 % loss (paper: up to ~30x)…
        assert!(
            tp("ltp", 0.01) > 2.0 * tp("cubic", 0.01),
            "ltp {} vs cubic {}",
            tp("ltp", 0.01),
            tp("cubic", 0.01)
        );
        assert!(tp("ltp", 0.01) > 2.0 * tp("reno", 0.01));
        // …and LTP's own throughput is only mildly dented by loss.
        assert!(
            tp("ltp", 0.01) > 0.5 * tp("ltp", 0.0),
            "ltp@1% {} vs clean {}",
            tp("ltp", 0.01),
            tp("ltp", 0.0)
        );
    }
}
