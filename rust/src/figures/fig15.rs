//! Paper Fig 15 — fairness: an LTP flow and a BBR flow sharing one
//! bottleneck. The paper reports LTP consuming ≈97 % of what BBR does.

use crate::cc::CcAlgo;
use crate::metrics::Table;
use crate::proto::{EarlyCloseCfg, LtpReceiver, LtpSender, LtpSenderNode, LtpReceiverNode, SegmentMap};
use crate::simnet::{LinkCfg, Sim};
use crate::tcp::{TcpReceiverNode, TcpSender, TcpSenderNode};
use crate::util::jain_fairness;
use crate::wire::{LTP_MSS, TCP_MSS};
use crate::SEC;

#[derive(Debug, Clone)]
pub struct Fig15Result {
    pub ltp_bytes: u64,
    pub bbr_bytes: u64,
    pub share: f64,
    pub jain: f64,
}

/// Two long-running flows (LTP vs BBR) share a 1 Gbps bottleneck for a
/// fixed interval; report delivered-byte shares.
pub fn fig15(quick: bool) -> Fig15Result {
    let duration = if quick { 3 * SEC } else { 10 * SEC };
    let bytes: u64 = 4_000_000_000; // effectively unbounded for the window
    let mut sim = Sim::new(77);
    let sw = sim.add_switch(500);
    // Shared bottleneck: both receivers behind the same 1 Gbps downlink.
    let edge = LinkCfg::wan(1000, 2);

    // LTP pair.
    let map = SegmentMap::new(bytes, crate::grad::Manifest::aligned_payload(LTP_MSS), vec![]);
    let mut ltp_snd = LtpSender::new(1, map, crate::wire::MTU);
    ltp_snd.seed_cc(8 * crate::MS, 125_000_000);
    let ltp_rx = LtpReceiver::new(1, EarlyCloseCfg::reliable(), vec![]);

    let sink = sim.add_host(Box::new(SinkPair::default()));
    let (down, _) = sim.add_duplex(sink, sw, edge);
    sim.set_default_uplink(sink, down);
    let _ = down;

    // Both senders on their own uplinks; both receivers co-located on one
    // host behind the shared bottleneck.
    let ltp_a = sim.add_host(Box::new(LtpSenderNode::new(ltp_snd, sink)));
    let (up1, _) = sim.add_duplex(ltp_a, sw, edge);
    sim.set_default_uplink(ltp_a, up1);

    let bbr = TcpSender::new(2, bytes, TCP_MSS, CcAlgo::Bbr.build(TCP_MSS));
    let tcp_a = sim.add_host(Box::new(TcpSenderNode::new(bbr, sink)));
    let (up2, _) = sim.add_duplex(tcp_a, sw, edge);
    sim.set_default_uplink(tcp_a, up2);

    // Attach the receivers to the sink.
    {
        let node = sim.node_as::<SinkPair>(sink);
        node.ltp = Some(LtpReceiverNode::new(ltp_rx));
        node.tcp = Some(TcpReceiverNode::new());
    }

    sim.run_until(duration);

    let node = sim.node_as::<SinkPair>(sink);
    let ltp_bytes = node
        .ltp
        .as_ref()
        .map(|n| {
            let rx = &n.receiver;
            rx.received_bitmap().count_ones() as u64 * 1460
        })
        .unwrap_or(0);
    let bbr_bytes = node.tcp.as_ref().map(|n| n.bytes_received(2)).unwrap_or(0);
    let share = ltp_bytes as f64 / bbr_bytes.max(1) as f64;
    let jain = jain_fairness(&[ltp_bytes as f64, bbr_bytes as f64]);
    let mut table = Table::new(vec!["flow", "delivered (MB)", "share of BBR", "Jain index"]);
    table
        .row(vec![
            "ltp".to_string(),
            format!("{:.1}", ltp_bytes as f64 / 1e6),
            format!("{:.1}%", share * 100.0),
            format!("{jain:.4}"),
        ])
        .row(vec![
            "bbr".to_string(),
            format!("{:.1}", bbr_bytes as f64 / 1e6),
            "100.0%".to_string(),
            format!("{jain:.4}"),
        ]);
    table.emit("fig15", "Fig 15 — fairness of LTP vs BBR on one bottleneck");
    Fig15Result { ltp_bytes, bbr_bytes, share, jain }
}

/// A host carrying both an LTP receiver and a TCP receiver (the shared
/// destination behind the bottleneck).
#[derive(Default)]
struct SinkPair {
    ltp: Option<LtpReceiverNode>,
    tcp: Option<TcpReceiverNode>,
}

impl crate::simnet::Node for SinkPair {
    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn on_packet(&mut self, ctx: &mut crate::simnet::Ctx, pkt: crate::simnet::Packet) {
        match pkt.kind {
            crate::wire::PacketKind::Ltp(_) => {
                if let Some(n) = &mut self.ltp {
                    n.on_packet(ctx, pkt);
                }
            }
            crate::wire::PacketKind::Tcp(_) => {
                if let Some(n) = &mut self.tcp {
                    n.on_packet(ctx, pkt);
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, ctx: &mut crate::simnet::Ctx, token: u64) {
        if let Some(n) = &mut self.ltp {
            n.on_timer(ctx, token);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig15_shares_are_comparable() {
        let r = fig15(true);
        assert!(r.ltp_bytes > 0 && r.bbr_bytes > 0);
        // Paper: ≈97 % of BBR; accept a generous band (0.6–1.7) — the
        // shape claim is "neither flow starves the other".
        assert!(
            r.share > 0.6 && r.share < 1.7,
            "share {} out of band",
            r.share
        );
        assert!(r.jain > 0.9, "jain {}", r.jain);
    }
}
