//! Real-compute figures:
//!
//! * Fig 5 — Top-k vs Random-k: final loss (accuracy proxy) and relative
//!   throughput as a function of the kept fraction k, using the L1 Pallas
//!   sparsification kernels on the transformer workload (substituting the
//!   paper's ResNet18/CIFAR-10 — DESIGN.md §2). Needs the `xla` backend's
//!   artifacts (`make artifacts`); the precondition check routes through
//!   the [`crate::compute::Backend`] trait so the error names them.
//! * Fig 13 — time-to-accuracy: sim-time until the training loss reaches a
//!   target, per protocol and loss rate, with real gradients flowing
//!   through the transports (drops are *actual* bubbles). Runs the
//!   `native` backend (DESIGN.md §1.3), so it needs no artifacts and its
//!   table is fully deterministic.

use crate::compute::parse_backend;
use crate::metrics::Table;
use crate::ps::{parse_proto, Corpus, ProtoSpec, RealTraining, RunBuilder, XlaAggregate};
use crate::runtime::{default_artifacts_dir, literal_f32, pool, to_f32, Runtime};
use crate::simnet::LossModel;
use crate::util::Pcg64;
use crate::SEC;
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

/// Fail-fast precondition of the PJRT figures, routed through the `xla`
/// backend so the error names the actual missing dependency.
fn ensure_artifacts() -> Result<()> {
    parse_backend("xla")?.check_ready()
}

fn require_runtime() -> Result<Runtime> {
    ensure_artifacts()?;
    Runtime::cpu(default_artifacts_dir()).context("PJRT CPU client")
}

thread_local! {
    /// One PJRT runtime per thread. Serial sweeps (`--jobs 1`) reuse a
    /// single client across every point; parallel sweeps get one client
    /// per pool worker (PJRT clients are not assumed thread-safe). Worker
    /// threads are scoped per figure, so caches drop with them.
    static THREAD_RT: RefCell<Option<Rc<Runtime>>> = const { RefCell::new(None) };
}

/// Run `f` against this thread's cached runtime, creating it on first use.
fn with_runtime<T>(f: impl FnOnce(&Runtime) -> Result<T>) -> Result<T> {
    let rt = THREAD_RT.with(|cell| -> Result<Rc<Runtime>> {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            *slot = Some(Rc::new(require_runtime()?));
        }
        Ok(slot.as_ref().expect("just initialized").clone())
    })?;
    f(&rt)
}

/// One sparsified training run: every worker gradient is pushed through
/// `sparsify` before aggregation (transport lossless, isolating the
/// sparsifier's effect — paper Fig 5 methodology).
fn sparsified_run(
    rt: &Runtime,
    iters: u64,
    sparsify: &dyn Fn(&Runtime, &mut Vec<f32>, &mut Pcg64) -> Result<f64>,
) -> Result<(f32, f64)> {
    let shared = RealTraining::new(rt, "tiny", 0.08)?;
    let d = shared.manifest.padded_dim;
    let mut rng = Pcg64::seeded(11);
    let mut corpus = Corpus::new(shared.manifest.vocab, 1);
    let step = rt.load("train_step_tiny")?;
    let mut last_loss = f32::NAN;
    let mut sparsify_secs = 0.0;
    for iter in 0..iters {
        // Single-worker equivalent loop (Fig 5 isolates compression cost,
        // not incast): compute → sparsify → aggregate.
        let tokens = corpus.next_batch(shared.manifest.batch, shared.manifest.seq_len + 1);
        let p = literal_f32(&shared.blackboard.params(), &[d as i64])?;
        let t = crate::runtime::literal_i32(
            &tokens,
            &[shared.manifest.batch as i64, shared.manifest.seq_len as i64 + 1],
        )?;
        let out = step.run(&[p, t])?;
        let mut grads = to_f32(&out[0])?;
        last_loss = to_f32(&out[1])?[0];
        sparsify_secs += sparsify(rt, &mut grads, &mut rng)?;
        shared.blackboard.put_grads(0, iter, grads);
        let mut agg = XlaAggregate { shared: shared.clone(), n_workers: 1 };
        use crate::ps::Aggregate as _;
        agg.aggregate(iter, &[None]);
    }
    Ok((last_loss, sparsify_secs))
}

/// One Fig-5 sweep point: `(randk_loss, topk_loss, randk_secs, topk_secs)`
/// at keep fraction `k`% — self-contained, so the pool can run points on
/// any thread.
fn fig5_point(k: u32, iters: u64) -> Result<(f32, f32, f64, f64)> {
    with_runtime(|rt| {
        // Random-k: the keep mask is drawn host-side (cheap) and applied by
        // the randk Pallas kernel.
        let randk = |rt: &Runtime, grads: &mut Vec<f32>, rng: &mut Pcg64| -> Result<f64> {
            let d = grads.len();
            let kernel = rt.load("randk_tiny")?;
            let t0 = Instant::now();
            // Bernoulli keep mask — Random-k's whole point is that the
            // selection is trivial (this is also exactly what random wire
            // loss does); the mask draw is part of the measured cost.
            let frac = k as f64 / 100.0;
            let mut mask = vec![0.0f32; d];
            for m in mask.iter_mut() {
                if rng.chance(frac) {
                    *m = 1.0;
                }
            }
            let out = kernel.run(&[
                literal_f32(grads, &[d as i64])?,
                literal_f32(&mask, &[d as i64])?,
            ])?;
            *grads = to_f32(&out[0])?;
            Ok(t0.elapsed().as_secs_f64())
        };
        // Top-k: the per-block bisection kernel (CUDA-topk's TPU rethink).
        let topk = |rt: &Runtime, grads: &mut Vec<f32>, _rng: &mut Pcg64| -> Result<f64> {
            let d = grads.len();
            let kernel = rt.load(&format!("topk_tiny_k{k}"))?;
            let t0 = Instant::now();
            let out = kernel.run(&[literal_f32(grads, &[d as i64])?])?;
            *grads = to_f32(&out[0])?;
            Ok(t0.elapsed().as_secs_f64())
        };
        let (loss_r, cost_r) = sparsified_run(rt, iters, &randk)?;
        let (loss_t, cost_t) = sparsified_run(rt, iters, &topk)?;
        Ok((loss_r, loss_t, cost_r, cost_t))
    })
}

/// Fig 5: Random-k vs Top-k across k ∈ {5..40} %.
pub fn fig5(quick: bool, jobs: usize) -> Result<()> {
    ensure_artifacts()?; // fail fast before spawning jobs (no client built here)
    let iters = if quick { 6 } else { 20 };
    let ks: &[u32] = if quick { &[5, 20, 40] } else { &[5, 10, 15, 20, 25, 30, 35, 40] };
    // One job per k; serial runs share this thread's cached runtime,
    // parallel runs get one runtime per worker thread.
    let rows = pool::run_jobs(jobs, ks.to_vec(), |_, k| fig5_point(k, iters));
    let mut table =
        Table::new(vec!["k%", "random-k loss", "top-k loss", "randk cost(s)", "topk cost(s)", "throughput gain"]);
    for (&k, row) in ks.iter().zip(rows) {
        let (loss_r, loss_t, cost_r, cost_t) = row?;
        table.row(vec![
            k.to_string(),
            format!("{loss_r:.3}"),
            format!("{loss_t:.3}"),
            format!("{cost_r:.3}"),
            format!("{cost_t:.3}"),
            format!("{:.2}x", cost_t / cost_r.max(1e-9)),
        ]);
    }
    table.emit("fig5", "Fig 5 — Random-k vs Top-k: final training loss and sparsification cost");
    Ok(())
}

/// Fig 13: sim-time to reach a target training loss, per protocol × loss
/// rate, with real gradients and real (bubble-filled) aggregation on the
/// `native` backend — no artifacts needed, and (unlike the wall-clock
/// columns of Fig 5) the whole table is byte-deterministic for any
/// `--jobs` count.
pub fn fig13(quick: bool, jobs: usize) -> Result<()> {
    let workers = 4;
    // One constant drives both the backend's iters-to-target computation
    // and the emitted caption, so they can never drift apart.
    const TARGET: f64 = 0.3;
    let backend = parse_backend(&format!("native:target={TARGET}"))?;
    let max_iters = if quick { 16 } else { 40 };
    let specs: &[&str] =
        if quick { &["ltp", "cubic"] } else { &["ltp", "bbr", "cubic", "reno"] };
    let protos: Vec<ProtoSpec> =
        specs.iter().map(|s| parse_proto(s).expect("registered spec")).collect();
    let loss_rates: &[f64] = if quick { &[0.0, 0.01] } else { &[0.0, 0.001, 0.01] };
    // One job per (proto, loss) point; each job owns its training session
    // (seeded from the run), so runs stay independent and deterministic.
    let mut sweep: Vec<(ProtoSpec, f64)> = Vec::new();
    for proto in &protos {
        for &p in loss_rates {
            sweep.push((proto.clone(), p));
        }
    }
    let backend_spec = backend.clone();
    let rows = pool::run_jobs(jobs, sweep, move |_, (proto, p)| -> Result<Vec<String>> {
        let name = proto.name().to_string();
        let mut b = RunBuilder::modeled(proto, crate::config::Workload::Micro, workers)
            .backend(backend_spec.clone())
            .iters(max_iters)
            .seed(13)
            .batches_per_epoch(4)
            .horizon(3600 * SEC);
        if p > 0.0 {
            b = b.loss(LossModel::Bernoulli { p });
        }
        let report = b.run()?;
        let train = report.train.expect("backend attached");
        let tta = train
            .iters_to_target
            .and_then(|n| report.iters.get(n as usize - 1))
            .map(|i| format!("{:.2}", i.end as f64 / SEC as f64))
            .unwrap_or_else(|| "—".into());
        Ok(vec![
            name,
            format!("{:.2}%", p * 100.0),
            tta,
            format!("{:.3}", train.final_loss),
            format!("{:.1}%", train.accuracy * 100.0),
            format!("{:.1}%", report.mean_delivered() * 100.0),
        ])
    });
    let mut table = Table::new(vec![
        "proto",
        "net loss",
        "TTA (sim s)",
        "final loss",
        "accuracy",
        "delivered",
    ]);
    for row in rows {
        table.row(row?);
    }
    table.emit(
        "fig13",
        &format!("Fig 13 — time to loss ≤ {TARGET} (native backend, {workers} workers)"),
    );
    Ok(())
}
