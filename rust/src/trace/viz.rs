//! Link-occupancy timeline rendering: hand-written SVG (and an HTML
//! wrapper with inline pan/zoom), zero external dependencies.
//!
//! One horizontal lane per link (labeled via [`super::KIND_LINK_META`]
//! when the trace carries it, `link<N>` otherwise), TX serialization
//! spans colored by flow, drop ticks (red = queue, orange = wire), a
//! close-marker strip colored by close reason, and dashed vertical
//! iteration-barrier lines at each iteration's last close.
//!
//! **Determinism contract** (DESIGN.md §4.7): the output is a pure
//! function of the decoded trace and the selected sim index — integer
//! pixel math, `BTreeMap` ordering, no timestamps, no randomness — so
//! serial and `--jobs N` captures of the same run render byte-identical
//! SVG (CI compares hashes).

use super::reader::TraceFile;
use super::stats::{link_label, LinkMeta};
use super::{
    reason_name, Record, KIND_CLOSE, KIND_DROP_QUEUE, KIND_DROP_WIRE, KIND_ENQUEUE,
    KIND_LINK_META, KIND_SIM_START, KIND_TX,
};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Label gutter width (px).
const LABEL_W: u64 = 150;
/// Plot area width (px).
const PLOT_W: u64 = 1100;
/// Lane height (px).
const LANE_H: u64 = 12;
/// Vertical stride between lanes (px).
const LANE_STRIDE: u64 = 16;
/// Y of the first lane.
const LANES_Y: u64 = 52;
/// Height reserved under the lanes for the time axis.
const AXIS_H: u64 = 30;

/// Flow color palette (12 entries, keyed `flow % 12`).
const PALETTE: [&str; 12] = [
    "#1f77b4", "#ff7f0e", "#2ca02c", "#d62728", "#9467bd", "#8c564b", "#e377c2", "#7f7f7f",
    "#bcbd22", "#17becf", "#aec7e8", "#ffbb78",
];

#[derive(Default)]
struct Lane {
    /// Merged TX spans in px: (x0, x1, flow).
    spans: Vec<(u64, u64, u64)>,
    /// Drop tick px positions: (x, is_wire).
    drops: Vec<(u64, bool)>,
}

struct SimView<'a> {
    seed: u64,
    records: Vec<&'a Record>,
}

/// Slice out one simulation's records (and count the total).
fn select_sim(file: &TraceFile, sim_index: usize) -> Result<SimView<'_>, String> {
    let mut sims = 0usize;
    let mut view: Option<SimView> = None;
    for rec in &file.records {
        if rec.kind == KIND_SIM_START {
            if sims == sim_index {
                view = Some(SimView { seed: rec.flow, records: Vec::new() });
            } else if sims > sim_index {
                break;
            }
            sims += 1;
        } else if sims == sim_index + 1 {
            if let Some(v) = view.as_mut() {
                v.records.push(rec);
            }
        }
    }
    match view {
        Some(v) => Ok(v),
        None => Err(format!(
            "trace contains {sims} simulation(s); --sim {sim_index} is out of range"
        )),
    }
}

fn fmt_time(ns: u64) -> String {
    if ns >= 1_000_000 {
        // ms with one decimal, integer math.
        format!("{}.{}ms", ns / 1_000_000, (ns / 100_000) % 10)
    } else if ns >= 1_000 {
        format!("{}us", ns / 1_000)
    } else {
        format!("{ns}ns")
    }
}

/// Render one simulation of a trace as a link-occupancy timeline SVG.
pub fn render_svg(file: &TraceFile, sim_index: usize) -> Result<String, String> {
    let view = select_sim(file, sim_index)?;
    let t_end = view.records.iter().map(|r| r.t).max().unwrap_or(0);
    let t_max = t_end.max(1);
    let x_of = |t: u64| LABEL_W + (t as u128 * PLOT_W as u128 / t_max as u128) as u64;

    // Per-link accumulation: FIFO pairing for serialization spans (same
    // discipline as the stats pass), plus drop ticks and metadata.
    let mut metas: BTreeMap<u32, LinkMeta> = BTreeMap::new();
    let mut lanes: BTreeMap<u32, Lane> = BTreeMap::new();
    let mut pending: BTreeMap<u32, std::collections::VecDeque<u64>> = BTreeMap::new();
    let mut last_tx: BTreeMap<u32, u64> = BTreeMap::new();
    let mut closes: Vec<(u64, u32, u8)> = Vec::new();
    let mut barriers: BTreeMap<u64, u64> = BTreeMap::new();
    for rec in &view.records {
        match rec.kind {
            KIND_LINK_META => {
                metas.insert(rec.a, LinkMeta::from_record(rec));
                lanes.entry(rec.a).or_default();
            }
            KIND_ENQUEUE => {
                pending.entry(rec.a).or_default().push_back(rec.t);
                lanes.entry(rec.a).or_default();
            }
            KIND_TX => {
                let t_enq = pending.entry(rec.a).or_default().pop_front().unwrap_or(rec.t);
                let prev = last_tx.get(&rec.a).copied().unwrap_or(0);
                let x0 = x_of(t_enq.max(prev));
                let x1 = x_of(rec.t).max(x0 + 1);
                let lane = lanes.entry(rec.a).or_default();
                match lane.spans.last_mut() {
                    // Sub-pixel span already covered by the previous one.
                    Some(&mut (_, px1, _)) if x1 <= px1 => {}
                    // Same flow, touching: extend.
                    Some(s) if s.2 == rec.flow && x0 <= s.1 => s.1 = x1,
                    _ => lane.spans.push((x0, x1, rec.flow)),
                }
                last_tx.insert(rec.a, rec.t);
            }
            KIND_DROP_QUEUE | KIND_DROP_WIRE => {
                let x = x_of(rec.t);
                let wire = rec.kind == KIND_DROP_WIRE;
                let lane = lanes.entry(rec.a).or_default();
                if lane.drops.last() != Some(&(x, wire)) {
                    lane.drops.push((x, wire));
                }
            }
            KIND_CLOSE => {
                closes.push((rec.t, rec.a, (rec.c & 0xff) as u8));
                let iter = rec.c >> 8;
                let e = barriers.entry(iter).or_default();
                *e = (*e).max(rec.t);
            }
            _ => {}
        }
    }

    let n_links = lanes.len() as u64;
    let width = LABEL_W + PLOT_W + 10;
    let height = LANES_Y + n_links * LANE_STRIDE + AXIS_H;
    let lanes_bottom = LANES_Y + n_links * LANE_STRIDE;
    let mut svg = String::new();
    let _ = writeln!(
        svg,
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{width}\" height=\"{height}\" \
         viewBox=\"0 0 {width} {height}\" font-family=\"monospace\" font-size=\"10\">"
    );
    let _ = writeln!(
        svg,
        "<rect x=\"0\" y=\"0\" width=\"{width}\" height=\"{height}\" fill=\"#ffffff\"/>"
    );
    let _ = writeln!(
        svg,
        "<text x=\"4\" y=\"14\" font-size=\"12\">{} · sim {} (seed {}) · {} links · t_end {}</text>",
        xml_escape(&file.header.scenario),
        sim_index,
        view.seed,
        n_links,
        fmt_time(t_end)
    );

    // Close-marker strip (one dot per gather close, colored by reason).
    let _ = writeln!(svg, "<text x=\"4\" y=\"38\" fill=\"#666666\">closes</text>");
    for &(t, worker, reason) in &closes {
        let color = match reason {
            0 => "#2ca02c",
            1 => "#1f77b4",
            _ => "#d62728",
        };
        let _ = writeln!(
            svg,
            "<circle cx=\"{}\" cy=\"35\" r=\"3\" fill=\"{color}\"><title>w{worker} {} @ {}</title></circle>",
            x_of(t),
            reason_name(reason),
            fmt_time(t)
        );
    }

    // Lanes: background, label, TX spans, drop ticks.
    for (i, (&link, lane)) in lanes.iter().enumerate() {
        let y = LANES_Y + i as u64 * LANE_STRIDE;
        let _ = writeln!(
            svg,
            "<rect x=\"{LABEL_W}\" y=\"{y}\" width=\"{PLOT_W}\" height=\"{LANE_H}\" fill=\"#f4f4f4\"/>"
        );
        let _ = writeln!(
            svg,
            "<text x=\"4\" y=\"{}\">{}</text>",
            y + LANE_H - 2,
            xml_escape(&link_label(link, metas.get(&link)))
        );
        for &(x0, x1, flow) in &lane.spans {
            let _ = writeln!(
                svg,
                "<rect x=\"{x0}\" y=\"{y}\" width=\"{}\" height=\"{LANE_H}\" fill=\"{}\"/>",
                x1 - x0,
                PALETTE[(flow % 12) as usize]
            );
        }
        for &(x, wire) in &lane.drops {
            let color = if wire { "#ff9900" } else { "#d62728" };
            let _ = writeln!(
                svg,
                "<line x1=\"{x}\" y1=\"{}\" x2=\"{x}\" y2=\"{}\" stroke=\"{color}\" stroke-width=\"1\" class=\"drop\"/>",
                y.saturating_sub(2),
                y + LANE_H + 2
            );
        }
    }

    // Iteration barrier lines at each iteration's last close.
    for (&iter, &t) in &barriers {
        let x = x_of(t);
        let _ = writeln!(
            svg,
            "<line x1=\"{x}\" y1=\"44\" x2=\"{x}\" y2=\"{lanes_bottom}\" stroke=\"#555555\" \
             stroke-width=\"1\" stroke-dasharray=\"4 3\"/>"
        );
        let _ = writeln!(svg, "<text x=\"{}\" y=\"50\" fill=\"#555555\">i{iter}</text>", x + 3);
    }

    // Time axis.
    let axis_y = lanes_bottom + 12;
    let _ = writeln!(
        svg,
        "<line x1=\"{LABEL_W}\" y1=\"{axis_y}\" x2=\"{}\" y2=\"{axis_y}\" stroke=\"#333333\"/>",
        LABEL_W + PLOT_W
    );
    for tick in 0..=5u64 {
        let t = t_end * tick / 5;
        let x = x_of(t);
        let _ = writeln!(
            svg,
            "<line x1=\"{x}\" y1=\"{axis_y}\" x2=\"{x}\" y2=\"{}\" stroke=\"#333333\"/>",
            axis_y + 4
        );
        let _ = writeln!(svg, "<text x=\"{x}\" y=\"{}\">{}</text>", axis_y + 15, fmt_time(t));
    }
    svg.push_str("</svg>\n");
    Ok(svg)
}

/// [`render_svg`] wrapped in a self-contained HTML page with inline
/// wheel-zoom and drag-pan (no external dependencies).
pub fn render_html(file: &TraceFile, sim_index: usize) -> Result<String, String> {
    let svg = render_svg(file, sim_index)?;
    let title = xml_escape(&file.header.scenario);
    Ok(format!(
        "<!doctype html>\n<html><head><meta charset=\"utf-8\">\
         <title>ltp trace · {title} · sim {sim_index}</title>\
         <style>body{{margin:8px;background:#ffffff;font-family:monospace}}</style>\
         </head><body>\n{svg}\
         <script>\n\
         (function () {{\n\
           var svg = document.querySelector('svg');\n\
           var vb = svg.viewBox.baseVal;\n\
           var drag = null;\n\
           svg.addEventListener('wheel', function (ev) {{\n\
             ev.preventDefault();\n\
             var k = ev.deltaY < 0 ? 0.85 : 1.18;\n\
             var pt = svg.createSVGPoint();\n\
             pt.x = ev.clientX; pt.y = ev.clientY;\n\
             var p = pt.matrixTransform(svg.getScreenCTM().inverse());\n\
             vb.x = p.x - (p.x - vb.x) * k;\n\
             vb.y = p.y - (p.y - vb.y) * k;\n\
             vb.width *= k; vb.height *= k;\n\
           }});\n\
           svg.addEventListener('mousedown', function (ev) {{ drag = [ev.clientX, ev.clientY]; }});\n\
           window.addEventListener('mouseup', function () {{ drag = null; }});\n\
           window.addEventListener('mousemove', function (ev) {{\n\
             if (!drag) return;\n\
             var scale = vb.width / svg.clientWidth;\n\
             vb.x -= (ev.clientX - drag[0]) * scale;\n\
             vb.y -= (ev.clientY - drag[1]) * scale;\n\
             drag = [ev.clientX, ev.clientY];\n\
           }});\n\
         }})();\n\
         </script></body></html>\n"
    ))
}

fn xml_escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}
