//! Per-iteration BST breakdown from a recorded trace: where did each
//! LTP gather flow's time go — queueing (+ serialization), retransmit,
//! or Early-Close wait?
//!
//! Definitions (per gather flow, i.e. per [`super::KIND_CLOSE`] record):
//!
//! * **queueing_ns** — Σ over the flow's data packets of (serializer
//!   start − enqueue), paired FIFO per link. Includes time behind other
//!   packets in drop-tail queues on every hop; zero on an idle link.
//! * **retransmit_ns** — Σ over data sequence ids of (last − first
//!   transmission) on the flow's first hop: the extra wall-clock each
//!   lost segment spent being re-sent (0 when nothing was lost).
//! * **early_close_wait_ns** — close decision − last data delivery: how
//!   long the receiver held the flow open past its final arrival
//!   (threshold/deadline wait — the time Early Close exists to bound).
//!
//! All maps are `BTreeMap`s, so the report is deterministic and renders
//! byte-identically for the same trace.

use super::reader::TraceFile;
use super::{
    reason_name, Record, KIND_CLOSE, KIND_DELIVER, KIND_ENQUEUE, KIND_JOB_START,
    KIND_SIM_START, KIND_TX, PTYPE_LTP_DATA,
};
use crate::metrics::Json;
use std::collections::{BTreeMap, VecDeque};

/// Per-link FIFO of pending (flow, ptype, enqueue time) awaiting TX.
type EnqFifo = VecDeque<(u64, u8, u64)>;

#[derive(Debug, Clone, Copy)]
struct CloseInfo {
    worker: u32,
    iter: u64,
    reason: u8,
    criticals_ok: bool,
    delivered_ppm: u64,
    t: u64,
}

#[derive(Default)]
struct FlowAcc {
    queueing: u64,
    first_hop: Option<u32>,
    /// seq → (first TX, last TX) on the flow's first hop.
    tx_seq: BTreeMap<u64, (u64, u64)>,
    last_deliver: Option<u64>,
    close: Option<CloseInfo>,
}

struct SimAcc {
    index: usize,
    seed: u64,
    enq: BTreeMap<u32, EnqFifo>,
    flows: BTreeMap<u64, FlowAcc>,
}

impl SimAcc {
    fn new(index: usize, seed: u64) -> SimAcc {
        SimAcc { index, seed, enq: BTreeMap::new(), flows: BTreeMap::new() }
    }

    fn observe(&mut self, rec: &Record) {
        match rec.kind {
            KIND_ENQUEUE => {
                self.enq.entry(rec.a).or_default().push_back((rec.flow, rec.ptype, rec.t));
                if rec.ptype == PTYPE_LTP_DATA {
                    let f = self.flows.entry(rec.flow).or_default();
                    f.first_hop.get_or_insert(rec.a);
                }
            }
            KIND_TX => {
                let popped = self.enq.entry(rec.a).or_default().pop_front();
                if let Some((flow, ptype, t_enq)) = popped {
                    if ptype == PTYPE_LTP_DATA {
                        let f = self.flows.entry(flow).or_default();
                        f.queueing += rec.t.saturating_sub(t_enq);
                        if f.first_hop == Some(rec.a) {
                            let e = f.tx_seq.entry(rec.c).or_insert((rec.t, rec.t));
                            e.1 = rec.t;
                        }
                    }
                }
            }
            KIND_DELIVER => {
                if rec.ptype == PTYPE_LTP_DATA {
                    self.flows.entry(rec.flow).or_default().last_deliver = Some(rec.t);
                }
            }
            KIND_CLOSE => {
                self.flows.entry(rec.flow).or_default().close = Some(CloseInfo {
                    worker: rec.a,
                    iter: rec.c >> 8,
                    reason: (rec.c & 0xff) as u8,
                    criticals_ok: rec.ptype != 0,
                    delivered_ppm: rec.d,
                    t: rec.t,
                });
            }
            _ => {}
        }
    }

    fn finish(self) -> Json {
        let mut flow_rows = Vec::new();
        let mut iters: BTreeMap<u64, [u64; 4]> = BTreeMap::new();
        for (flow, f) in &self.flows {
            let Some(close) = f.close else { continue };
            let retransmit: u64 = f.tx_seq.values().map(|(first, last)| last - first).sum();
            let wait = f.last_deliver.map(|d| close.t.saturating_sub(d)).unwrap_or(0);
            flow_rows.push(Json::obj(vec![
                ("flow", (*flow).into()),
                ("worker", (close.worker as u64).into()),
                ("iter", close.iter.into()),
                ("reason", reason_name(close.reason).into()),
                ("criticals_ok", close.criticals_ok.into()),
                ("delivered_ppm", close.delivered_ppm.into()),
                ("queueing_ns", f.queueing.into()),
                ("retransmit_ns", retransmit.into()),
                ("early_close_wait_ns", wait.into()),
            ]));
            let e = iters.entry(close.iter).or_default();
            e[0] += 1;
            e[1] += f.queueing;
            e[2] += retransmit;
            e[3] += wait;
        }
        let iter_rows: Vec<Json> = iters
            .into_iter()
            .map(|(iter, [flows, q, rtx, wait])| {
                Json::obj(vec![
                    ("iter", iter.into()),
                    ("flows", flows.into()),
                    ("queueing_ns", q.into()),
                    ("retransmit_ns", rtx.into()),
                    ("early_close_wait_ns", wait.into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("sim", self.index.into()),
            ("seed", self.seed.into()),
            ("flows", Json::Arr(flow_rows)),
            ("iterations", Json::Arr(iter_rows)),
        ])
    }
}

/// Distill a trace into the per-flow/per-iteration BST breakdown report
/// (schema `ltp-trace-breakdown-v1`).
pub fn breakdown(file: &TraceFile) -> Json {
    let mut sims = Vec::new();
    let mut cur: Option<SimAcc> = None;
    let mut next_index = 0usize;
    for rec in &file.records {
        match rec.kind {
            KIND_JOB_START => {
                if let Some(sim) = cur.take() {
                    sims.push(sim.finish());
                }
            }
            KIND_SIM_START => {
                if let Some(sim) = cur.take() {
                    sims.push(sim.finish());
                }
                cur = Some(SimAcc::new(next_index, rec.flow));
                next_index += 1;
            }
            _ => {
                if let Some(sim) = cur.as_mut() {
                    sim.observe(rec);
                }
            }
        }
    }
    if let Some(sim) = cur.take() {
        sims.push(sim.finish());
    }
    Json::obj(vec![
        ("schema", "ltp-trace-breakdown-v1".into()),
        ("scenario", file.header.scenario.as_str().into()),
        ("quick", file.header.quick.into()),
        ("sims", Json::Arr(sims)),
    ])
}
