//! Per-iteration BST breakdown from a recorded trace: where did each
//! LTP gather flow's time go — queueing (+ serialization), retransmit,
//! or Early-Close wait?
//!
//! Definitions (per gather flow, i.e. per [`super::KIND_CLOSE`] record):
//!
//! * **queueing_ns** — Σ over the flow's data packets of (serializer
//!   start − enqueue), paired FIFO per link. Includes time behind other
//!   packets in drop-tail queues on every hop; zero on an idle link.
//! * **retransmit_ns** — Σ over data sequence ids of (last − first
//!   transmission) on the flow's first hop: the extra wall-clock each
//!   lost segment spent being re-sent (0 when nothing was lost).
//! * **early_close_wait_ns** — close decision − last data delivery: how
//!   long the receiver held the flow open past its final arrival
//!   (threshold/deadline wait — the time Early Close exists to bound).
//!
//! The pairing logic runs once, into intermediate [`SimTable`]s
//! ([`breakdown_table`]) that also keep the per-link queueing split and
//! per-sequence retransmit detail the stats/diff tools need;
//! [`breakdown`] renders the classic `ltp-trace-breakdown-v1` report
//! from it. All maps are `BTreeMap`s, so both are deterministic and the
//! report renders byte-identically for the same trace.

use super::reader::TraceFile;
use super::{
    reason_name, Record, KIND_CLOSE, KIND_DELIVER, KIND_DROP_QUEUE, KIND_DROP_WIRE, KIND_ENQUEUE,
    KIND_JOB_START, KIND_SIM_START, KIND_TX, PTYPE_LTP_DATA,
};
use crate::metrics::Json;
use std::collections::{BTreeMap, VecDeque};

/// Per-link FIFO of pending (flow, ptype, enqueue time) awaiting TX.
type EnqFifo = VecDeque<(u64, u8, u64)>;

/// One retransmitted data sequence of a gather flow: first/last
/// transmission on the flow's first hop, and the link that last dropped
/// it (if any drop was recorded — an abandoned non-critical segment may
/// retransmit without a drop on the first hop).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeqRetx {
    /// Data sequence id.
    pub seq: u64,
    /// First transmission time on the flow's first hop (ns).
    pub first_tx_ns: u64,
    /// Last transmission time on the flow's first hop (ns).
    pub last_tx_ns: u64,
    /// Transmissions observed on the first hop (≥ 2 for entries kept).
    pub tx_count: u64,
    /// Link that last dropped this sequence (queue or wire), if any.
    pub drop_link: Option<u32>,
}

/// One closed gather flow's breakdown row (the intermediate form behind
/// the `flows` array of `ltp-trace-breakdown-v1`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlowRow {
    /// Flow id.
    pub flow: u64,
    /// Worker index from the close record.
    pub worker: u32,
    /// Training iteration from the close record.
    pub iter: u64,
    /// Close-reason wire code (see [`super::reason_name`]).
    pub reason: u8,
    /// Whether all critical segments had arrived at close time.
    pub criticals_ok: bool,
    /// Delivered fraction at close, in parts per million.
    pub delivered_ppm: u64,
    /// Close decision time (ns).
    pub close_ns: u64,
    /// First link the flow's data was enqueued on (its access link).
    pub first_hop: Option<u32>,
    /// First data enqueue time (ns) — the flow's start-of-activity.
    pub first_enqueue_ns: Option<u64>,
    /// Last data delivery time (ns).
    pub last_deliver_ns: Option<u64>,
    /// Queueing (+ serialization wait) split per link, link-id order.
    pub queueing_by_link: Vec<(u32, u64)>,
    /// Σ of [`FlowRow::queueing_by_link`] — the report's `queueing_ns`.
    pub queueing_ns: u64,
    /// Σ over sequences of (last − first TX) — the report's
    /// `retransmit_ns`.
    pub retransmit_ns: u64,
    /// Close − last delivery — the report's `early_close_wait_ns`.
    pub early_close_wait_ns: u64,
    /// Sequences transmitted more than once, sequence order.
    pub retx: Vec<SeqRetx>,
}

/// One simulation's table of closed gather flows, flow-id order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimTable {
    /// Simulation index within the trace (creation order).
    pub index: usize,
    /// The simulation's seed.
    pub seed: u64,
    /// End of recorded activity: the largest record time seen (ns).
    pub t_end_ns: u64,
    /// Closed gather flows, flow-id order.
    pub flows: Vec<FlowRow>,
}

#[derive(Debug, Clone, Copy)]
struct CloseInfo {
    worker: u32,
    iter: u64,
    reason: u8,
    criticals_ok: bool,
    delivered_ppm: u64,
    t: u64,
}

#[derive(Default)]
struct FlowAcc {
    /// link → Σ (serializer start − enqueue) for the flow's data packets.
    queueing: BTreeMap<u32, u64>,
    first_hop: Option<u32>,
    first_enqueue: Option<u64>,
    /// seq → (first TX, last TX, TX count) on the flow's first hop.
    tx_seq: BTreeMap<u64, (u64, u64, u64)>,
    /// seq → link that last dropped it (queue or wire).
    drop_link: BTreeMap<u64, u32>,
    last_deliver: Option<u64>,
    close: Option<CloseInfo>,
}

struct SimAcc {
    index: usize,
    seed: u64,
    t_end: u64,
    enq: BTreeMap<u32, EnqFifo>,
    flows: BTreeMap<u64, FlowAcc>,
}

impl SimAcc {
    fn new(index: usize, seed: u64) -> SimAcc {
        SimAcc { index, seed, t_end: 0, enq: BTreeMap::new(), flows: BTreeMap::new() }
    }

    fn observe(&mut self, rec: &Record) {
        self.t_end = self.t_end.max(rec.t);
        match rec.kind {
            KIND_ENQUEUE => {
                self.enq.entry(rec.a).or_default().push_back((rec.flow, rec.ptype, rec.t));
                if rec.ptype == PTYPE_LTP_DATA {
                    let f = self.flows.entry(rec.flow).or_default();
                    f.first_hop.get_or_insert(rec.a);
                    f.first_enqueue.get_or_insert(rec.t);
                }
            }
            KIND_TX => {
                let popped = self.enq.entry(rec.a).or_default().pop_front();
                if let Some((flow, ptype, t_enq)) = popped {
                    if ptype == PTYPE_LTP_DATA {
                        let f = self.flows.entry(flow).or_default();
                        *f.queueing.entry(rec.a).or_default() += rec.t.saturating_sub(t_enq);
                        if f.first_hop == Some(rec.a) {
                            let e = f.tx_seq.entry(rec.c).or_insert((rec.t, rec.t, 0));
                            e.1 = rec.t;
                            e.2 += 1;
                        }
                    }
                }
            }
            KIND_DROP_QUEUE | KIND_DROP_WIRE => {
                if rec.ptype == PTYPE_LTP_DATA {
                    let f = self.flows.entry(rec.flow).or_default();
                    f.drop_link.insert(rec.c, rec.a);
                }
            }
            KIND_DELIVER => {
                if rec.ptype == PTYPE_LTP_DATA {
                    self.flows.entry(rec.flow).or_default().last_deliver = Some(rec.t);
                }
            }
            KIND_CLOSE => {
                self.flows.entry(rec.flow).or_default().close = Some(CloseInfo {
                    worker: rec.a,
                    iter: rec.c >> 8,
                    reason: (rec.c & 0xff) as u8,
                    criticals_ok: rec.ptype != 0,
                    delivered_ppm: rec.d,
                    t: rec.t,
                });
            }
            _ => {}
        }
    }

    fn finish(self) -> SimTable {
        let mut rows = Vec::new();
        for (flow, f) in self.flows {
            let Some(close) = f.close else { continue };
            let queueing_ns: u64 = f.queueing.values().sum();
            let retransmit_ns: u64 = f.tx_seq.values().map(|(first, last, _)| last - first).sum();
            let wait = f.last_deliver.map(|d| close.t.saturating_sub(d)).unwrap_or(0);
            let retx = f
                .tx_seq
                .iter()
                .filter(|(_, (_, _, count))| *count > 1)
                .map(|(&seq, &(first, last, count))| SeqRetx {
                    seq,
                    first_tx_ns: first,
                    last_tx_ns: last,
                    tx_count: count,
                    drop_link: f.drop_link.get(&seq).copied(),
                })
                .collect();
            rows.push(FlowRow {
                flow,
                worker: close.worker,
                iter: close.iter,
                reason: close.reason,
                criticals_ok: close.criticals_ok,
                delivered_ppm: close.delivered_ppm,
                close_ns: close.t,
                first_hop: f.first_hop,
                first_enqueue_ns: f.first_enqueue,
                last_deliver_ns: f.last_deliver,
                queueing_by_link: f.queueing.into_iter().collect(),
                queueing_ns,
                retransmit_ns,
                early_close_wait_ns: wait,
                retx,
            });
        }
        SimTable { index: self.index, seed: self.seed, t_end_ns: self.t_end, flows: rows }
    }
}

/// Distill a trace into per-sim tables of closed gather flows — the
/// shared intermediate the breakdown/stats/diff tools all render from.
/// Sims are segmented on job/sim markers, as in [`breakdown`].
pub fn breakdown_table(file: &TraceFile) -> Vec<SimTable> {
    let mut sims = Vec::new();
    let mut cur: Option<SimAcc> = None;
    let mut next_index = 0usize;
    for rec in &file.records {
        match rec.kind {
            KIND_JOB_START => {
                if let Some(sim) = cur.take() {
                    sims.push(sim.finish());
                }
            }
            KIND_SIM_START => {
                if let Some(sim) = cur.take() {
                    sims.push(sim.finish());
                }
                cur = Some(SimAcc::new(next_index, rec.flow));
                next_index += 1;
            }
            _ => {
                if let Some(sim) = cur.as_mut() {
                    sim.observe(rec);
                }
            }
        }
    }
    if let Some(sim) = cur.take() {
        sims.push(sim.finish());
    }
    sims
}

fn render_sim(table: &SimTable) -> Json {
    let mut flow_rows = Vec::new();
    let mut iters: BTreeMap<u64, [u64; 4]> = BTreeMap::new();
    for f in &table.flows {
        flow_rows.push(Json::obj(vec![
            ("flow", f.flow.into()),
            ("worker", (f.worker as u64).into()),
            ("iter", f.iter.into()),
            ("reason", reason_name(f.reason).into()),
            ("criticals_ok", f.criticals_ok.into()),
            ("delivered_ppm", f.delivered_ppm.into()),
            ("queueing_ns", f.queueing_ns.into()),
            ("retransmit_ns", f.retransmit_ns.into()),
            ("early_close_wait_ns", f.early_close_wait_ns.into()),
        ]));
        let e = iters.entry(f.iter).or_default();
        e[0] += 1;
        e[1] += f.queueing_ns;
        e[2] += f.retransmit_ns;
        e[3] += f.early_close_wait_ns;
    }
    let iter_rows: Vec<Json> = iters
        .into_iter()
        .map(|(iter, [flows, q, rtx, wait])| {
            Json::obj(vec![
                ("iter", iter.into()),
                ("flows", flows.into()),
                ("queueing_ns", q.into()),
                ("retransmit_ns", rtx.into()),
                ("early_close_wait_ns", wait.into()),
            ])
        })
        .collect();
    Json::obj(vec![
        ("sim", table.index.into()),
        ("seed", table.seed.into()),
        ("flows", Json::Arr(flow_rows)),
        ("iterations", Json::Arr(iter_rows)),
    ])
}

/// Distill a trace into the per-flow/per-iteration BST breakdown report
/// (schema `ltp-trace-breakdown-v1`).
pub fn breakdown(file: &TraceFile) -> Json {
    let sims = breakdown_table(file).iter().map(render_sim).collect();
    Json::obj(vec![
        ("schema", "ltp-trace-breakdown-v1".into()),
        ("scenario", file.header.scenario.as_str().into()),
        ("quick", file.header.quick.into()),
        ("sims", Json::Arr(sims)),
    ])
}
