//! Replay: re-drive a recorded run from its trace and verify that both
//! the record stream and the report bytes reproduce exactly.
//!
//! A trace does not carry enough state to *play back* a simulation — it
//! carries enough to *re-run* it: the scenario (header), and one
//! [`super::KIND_JOB_START`] record per sweep job naming the scenario
//! registry index, seed, and quick flag. Replay rebuilds that job list,
//! runs it serially under a fresh capture, and compares the regenerated
//! record stream against the recorded one byte-for-byte. Any divergence
//! (a code change, a registry reorder, a nondeterminism bug) fails with
//! the first diverging record's index, byte offset, and decoded
//! contents. On success the regenerated report **is** the recorded
//! run's report — the CI `trace-determinism` job diffs it against the
//! live `ltp scenario --json` output.

use super::reader::TraceFile;
use super::writer::HEADER_BYTES;
use super::{Record, KIND_JOB_START, KIND_LINK_META, RECORD_BYTES};
use crate::scenarios::{registry, sweep};

/// A successful replay.
#[derive(Debug)]
pub struct ReplayOutcome {
    /// The regenerated (== recorded) sweep report JSON.
    pub report_json: String,
    /// Records verified identical.
    pub records: usize,
    /// Sweep jobs re-driven.
    pub jobs: usize,
}

/// Re-drive `file`'s recorded run and verify it reproduces the trace.
pub fn replay(file: &TraceFile) -> Result<ReplayOutcome, String> {
    let starts: Vec<&Record> = file.records.iter().filter(|r| r.kind == KIND_JOB_START).collect();
    if starts.is_empty() {
        return Err("trace has no job-start records; nothing to replay".to_string());
    }
    let n_scenarios = registry().len();
    let mut jobs = Vec::with_capacity(starts.len());
    for r in &starts {
        let idx = r.a as usize;
        if idx >= n_scenarios {
            return Err(format!(
                "job-start names scenario index {idx}, but this build registers \
                 {n_scenarios} scenarios — the trace was written by an incompatible build"
            ));
        }
        jobs.push(sweep::SweepJob {
            scenario_index: idx,
            seed: r.flow,
            quick: r.d & 1 == 1,
            protos: None,
            aggs: None,
            codecs: None,
            churns: None,
        });
    }
    // Cross-check the header's scenario name against the registry: a
    // reordered registry would otherwise replay the wrong scenario.
    let resolved = registry()[jobs[0].scenario_index].name;
    if resolved != file.header.scenario {
        return Err(format!(
            "header names scenario `{}`, but job-start index {} resolves to `{resolved}` — \
             the scenario registry changed since capture",
            file.header.scenario, jobs[0].scenario_index
        ));
    }
    let n_jobs = jobs.len();
    let (result, regen) = sweep::run_sweep_traced(jobs, 1, true);
    let mut regen = regen.expect("traced sweep returns records");
    if file.header.version < 2 {
        // v1 traces predate link metadata: this build emits it, the
        // recording build didn't, so strip it before comparing streams.
        regen.retain(|r| r.kind != KIND_LINK_META);
    }
    if regen != file.records {
        let i = regen
            .iter()
            .zip(file.records.iter())
            .position(|(a, b)| a != b)
            .unwrap_or_else(|| regen.len().min(file.records.len()));
        let offset = HEADER_BYTES + i * RECORD_BYTES;
        return Err(format!(
            "replay diverged at record {i} (byte offset {offset}): recorded {:?}, \
             regenerated {:?} ({} records recorded, {} regenerated)",
            file.records.get(i),
            regen.get(i),
            file.records.len(),
            regen.len()
        ));
    }
    Ok(ReplayOutcome { report_json: result.render_json(), records: regen.len(), jobs: n_jobs })
}
