//! Per-link / per-flow / per-iteration statistics over a recorded trace
//! (schema `ltp-trace-stats-v1`, DESIGN.md §4.7).
//!
//! A single linear pass over each simulation's records accumulates the
//! link-level view — bytes transmitted, serializer busy time (and the
//! utilization it implies), drops by kind, and drop-tail queue depth
//! over time (bucketed maxima) — while the flow and iteration sections
//! are re-rendered from the shared [`breakdown_table`] so the pairing
//! logic lives in one place. Everything is keyed through `BTreeMap`s
//! and integer time math, so the JSON is a pure function of the trace:
//! serial and `--jobs N` captures of the same run render byte-identical
//! stats.

use super::breakdown::{breakdown_table, SimTable};
use super::reader::TraceFile;
use super::{
    KIND_DROP_QUEUE, KIND_DROP_WIRE, KIND_ENQUEUE, KIND_JOB_START, KIND_LINK_META,
    KIND_SIM_START, KIND_TX, ROLE_EDGE_DOWN, ROLE_EDGE_UP, ROLE_TRUNK_DOWN, ROLE_TRUNK_UP,
};
use crate::metrics::Json;
use std::collections::{BTreeMap, VecDeque};

/// Time buckets in each link's queue-depth-over-time series.
pub const DEPTH_BUCKETS: usize = 32;

/// Static link metadata decoded from a [`super::KIND_LINK_META`] record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkMeta {
    /// One of the `ROLE_*` constants in [`crate::trace`].
    pub role: u8,
    /// Source entity id.
    pub src: u32,
    /// Destination entity id.
    pub dst: u32,
    /// Serialization rate in bits per second.
    pub rate_bps: u64,
    /// Drop-tail queue capacity in bytes.
    pub queue_cap_bytes: u64,
}

impl LinkMeta {
    /// Decode from a [`super::KIND_LINK_META`] record.
    pub fn from_record(rec: &super::Record) -> LinkMeta {
        LinkMeta {
            role: rec.ptype,
            src: (rec.flow >> 32) as u32,
            dst: (rec.flow & 0xffff_ffff) as u32,
            rate_bps: rec.c,
            queue_cap_bytes: rec.d,
        }
    }
}

/// Human label for a link: role-aware when metadata is present
/// (`h3.up`, `h1.down`, `tor2.trunk_up`, …), `link<N>` otherwise — the
/// v1-trace fallback.
pub fn link_label(link: u32, meta: Option<&LinkMeta>) -> String {
    match meta {
        Some(m) if m.role == ROLE_EDGE_UP => format!("h{}.up", m.src),
        Some(m) if m.role == ROLE_EDGE_DOWN => format!("h{}.down", m.dst),
        Some(m) if m.role == ROLE_TRUNK_UP => format!("tor{}.trunk_up", m.src),
        Some(m) if m.role == ROLE_TRUNK_DOWN => format!("tor{}.trunk_down", m.dst),
        _ => format!("link{link}"),
    }
}

/// All link metadata in a trace, keyed `(sim index, link id)`.
pub fn link_meta_map(file: &TraceFile) -> BTreeMap<(usize, u32), LinkMeta> {
    let mut map = BTreeMap::new();
    let mut sim: Option<usize> = None;
    let mut next = 0usize;
    for rec in &file.records {
        match rec.kind {
            KIND_SIM_START => {
                sim = Some(next);
                next += 1;
            }
            KIND_LINK_META => {
                if let Some(s) = sim {
                    map.insert((s, rec.a), LinkMeta::from_record(rec));
                }
            }
            _ => {}
        }
    }
    map
}

/// One link's traffic statistics within one simulation.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LinkUse {
    /// Static metadata, when the trace carries it (format v2+).
    pub meta: Option<LinkMeta>,
    /// Packets that finished serialization (entered the wire).
    pub tx_pkts: u64,
    /// Bytes that finished serialization.
    pub tx_bytes: u64,
    /// Drop-tail rejections (full queue).
    pub drops_queue: u64,
    /// Wire losses after serialization.
    pub drops_wire: u64,
    /// Total serializer-busy time (ns).
    pub busy_ns: u64,
    /// Peak queued packets awaiting serialization.
    pub peak_queue_pkts: u64,
    /// Peak queued bytes awaiting serialization.
    pub peak_queue_bytes: u64,
    /// Max queued bytes per time bucket ([`DEPTH_BUCKETS`] buckets over
    /// `[0, t_end]`) — the queue-depth-over-time series.
    pub queue_depth_bytes: Vec<u64>,
}

/// One simulation's stats: the link table plus the flow table the
/// breakdown pass produced.
#[derive(Debug, Clone, PartialEq)]
pub struct SimStats {
    /// Simulation index within the trace (creation order).
    pub index: usize,
    /// The simulation's seed.
    pub seed: u64,
    /// End of recorded activity (largest record time, ns).
    pub t_end_ns: u64,
    /// Per-link statistics, link-id order.
    pub links: BTreeMap<u32, LinkUse>,
    /// Closed gather flows (see [`breakdown_table`]).
    pub table: SimTable,
}

/// A whole trace's statistics (one [`SimStats`] per simulation).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceStats {
    /// Scenario name from the trace header.
    pub scenario: String,
    /// Quick flag from the trace header.
    pub quick: bool,
    /// Trace format version the stats were derived from.
    pub version: u32,
    /// Per-simulation statistics.
    pub sims: Vec<SimStats>,
}

#[derive(Default)]
struct LinkAcc {
    meta: Option<LinkMeta>,
    tx_pkts: u64,
    tx_bytes: u64,
    drops_queue: u64,
    drops_wire: u64,
    busy_ns: u64,
    last_tx: u64,
    /// Pending (enqueue time, size) awaiting TX, FIFO per link.
    pending: VecDeque<(u64, u64)>,
    queued_bytes: u64,
    peak_pkts: u64,
    peak_bytes: u64,
    /// (time, signed byte delta) queue-depth events in record order.
    depth_events: Vec<(u64, i64)>,
}

impl LinkAcc {
    fn finish(self, t_end: u64) -> LinkUse {
        let mut buckets = vec![0u64; DEPTH_BUCKETS];
        let mut depth: i64 = 0;
        let mut cur = 0usize;
        for &(t, delta) in &self.depth_events {
            let b = bucket_of(t, t_end);
            // Carry the standing depth across buckets with no events.
            while cur < b {
                cur += 1;
                buckets[cur] = buckets[cur].max(depth.max(0) as u64);
            }
            depth += delta;
            buckets[b] = buckets[b].max(depth.max(0) as u64);
        }
        LinkUse {
            meta: self.meta,
            tx_pkts: self.tx_pkts,
            tx_bytes: self.tx_bytes,
            drops_queue: self.drops_queue,
            drops_wire: self.drops_wire,
            busy_ns: self.busy_ns,
            peak_queue_pkts: self.peak_pkts,
            peak_queue_bytes: self.peak_bytes,
            queue_depth_bytes: buckets,
        }
    }
}

fn bucket_of(t: u64, t_end: u64) -> usize {
    let b = (t as u128 * DEPTH_BUCKETS as u128) / (t_end as u128 + 1);
    (b as usize).min(DEPTH_BUCKETS - 1)
}

struct LinkPass {
    links: BTreeMap<u32, LinkAcc>,
    t_end: u64,
}

impl LinkPass {
    fn new() -> LinkPass {
        LinkPass { links: BTreeMap::new(), t_end: 0 }
    }

    fn observe(&mut self, rec: &super::Record) {
        self.t_end = self.t_end.max(rec.t);
        match rec.kind {
            KIND_LINK_META => {
                self.links.entry(rec.a).or_default().meta = Some(LinkMeta::from_record(rec));
            }
            KIND_ENQUEUE => {
                let l = self.links.entry(rec.a).or_default();
                l.pending.push_back((rec.t, rec.d));
                l.queued_bytes += rec.d;
                l.peak_bytes = l.peak_bytes.max(l.queued_bytes);
                l.peak_pkts = l.peak_pkts.max(l.pending.len() as u64);
                l.depth_events.push((rec.t, rec.d as i64));
            }
            KIND_TX => {
                let l = self.links.entry(rec.a).or_default();
                if let Some((t_enq, size)) = l.pending.pop_front() {
                    l.busy_ns += rec.t.saturating_sub(t_enq.max(l.last_tx));
                    l.queued_bytes = l.queued_bytes.saturating_sub(size);
                    l.depth_events.push((rec.t, -(size as i64)));
                }
                l.last_tx = rec.t;
                l.tx_pkts += 1;
                l.tx_bytes += rec.d;
            }
            KIND_DROP_QUEUE => {
                self.links.entry(rec.a).or_default().drops_queue += 1;
            }
            KIND_DROP_WIRE => {
                self.links.entry(rec.a).or_default().drops_wire += 1;
            }
            _ => {}
        }
    }

    fn finish(self) -> (BTreeMap<u32, LinkUse>, u64) {
        let t_end = self.t_end;
        (self.links.into_iter().map(|(id, acc)| (id, acc.finish(t_end))).collect(), t_end)
    }
}

/// Compute a trace's per-link / per-flow / per-iteration statistics.
pub fn trace_stats(file: &TraceFile) -> TraceStats {
    // Link-level pass, segmented on job/sim markers exactly like the
    // breakdown pass so the two sim lists align index-for-index.
    let mut link_sims: Vec<(BTreeMap<u32, LinkUse>, u64)> = Vec::new();
    let mut cur: Option<LinkPass> = None;
    for rec in &file.records {
        match rec.kind {
            KIND_JOB_START => {
                if let Some(p) = cur.take() {
                    link_sims.push(p.finish());
                }
            }
            KIND_SIM_START => {
                if let Some(p) = cur.take() {
                    link_sims.push(p.finish());
                }
                cur = Some(LinkPass::new());
            }
            _ => {
                if let Some(p) = cur.as_mut() {
                    p.observe(rec);
                }
            }
        }
    }
    if let Some(p) = cur.take() {
        link_sims.push(p.finish());
    }
    let tables = breakdown_table(file);
    debug_assert_eq!(link_sims.len(), tables.len());
    let sims = tables
        .into_iter()
        .zip(link_sims)
        .map(|(table, (links, t_end))| SimStats {
            index: table.index,
            seed: table.seed,
            t_end_ns: t_end.max(table.t_end_ns),
            links,
            table,
        })
        .collect();
    TraceStats {
        scenario: file.header.scenario.clone(),
        quick: file.header.quick,
        version: file.header.version,
        sims,
    }
}

impl TraceStats {
    /// Render as the deterministic `ltp-trace-stats-v1` JSON.
    pub fn to_json(&self) -> Json {
        let sims = self.sims.iter().map(render_sim).collect();
        Json::obj(vec![
            ("schema", "ltp-trace-stats-v1".into()),
            ("scenario", self.scenario.as_str().into()),
            ("quick", self.quick.into()),
            ("trace_version", (self.version as u64).into()),
            ("sims", Json::Arr(sims)),
        ])
    }
}

fn render_sim(sim: &SimStats) -> Json {
    let links: Vec<Json> = sim
        .links
        .iter()
        .map(|(&id, l)| {
            let mut kv: Vec<(&str, Json)> = vec![
                ("link", (id as u64).into()),
                ("label", link_label(id, l.meta.as_ref()).into()),
            ];
            if let Some(m) = &l.meta {
                kv.push(("src", (m.src as u64).into()));
                kv.push(("dst", (m.dst as u64).into()));
                kv.push(("rate_bps", m.rate_bps.into()));
                kv.push(("queue_cap_bytes", m.queue_cap_bytes.into()));
            }
            let util = if sim.t_end_ns > 0 {
                l.busy_ns as f64 / sim.t_end_ns as f64
            } else {
                0.0
            };
            kv.push(("tx_pkts", l.tx_pkts.into()));
            kv.push(("tx_bytes", l.tx_bytes.into()));
            kv.push(("drops_queue", l.drops_queue.into()));
            kv.push(("drops_wire", l.drops_wire.into()));
            kv.push(("busy_ns", l.busy_ns.into()));
            kv.push(("utilization", util.into()));
            kv.push(("peak_queue_pkts", l.peak_queue_pkts.into()));
            kv.push(("peak_queue_bytes", l.peak_queue_bytes.into()));
            let depth = l.queue_depth_bytes.iter().map(|&b| b.into()).collect();
            kv.push(("queue_depth_bytes", Json::Arr(depth)));
            Json::obj(kv)
        })
        .collect();
    let flows: Vec<Json> = sim
        .table
        .flows
        .iter()
        .map(|f| {
            let extra_tx: u64 = f.retx.iter().map(|r| r.tx_count - 1).sum();
            Json::obj(vec![
                ("flow", f.flow.into()),
                ("worker", (f.worker as u64).into()),
                ("iter", f.iter.into()),
                ("reason", super::reason_name(f.reason).into()),
                ("delivered_ppm", f.delivered_ppm.into()),
                ("queueing_ns", f.queueing_ns.into()),
                ("retransmit_ns", f.retransmit_ns.into()),
                ("early_close_wait_ns", f.early_close_wait_ns.into()),
                ("retransmitted_seqs", f.retx.len().into()),
                ("extra_tx", extra_tx.into()),
            ])
        })
        .collect();
    // Iteration phase spans: first data enqueue → last close (the BSP
    // barrier for that iteration).
    let mut iters: BTreeMap<u64, IterAcc> = BTreeMap::new();
    for f in &sim.table.flows {
        let e = iters.entry(f.iter).or_default();
        e.flows += 1;
        let start = f.first_enqueue_ns.unwrap_or(f.close_ns);
        e.start = Some(e.start.map_or(start, |s: u64| s.min(start)));
        e.first_close = Some(e.first_close.map_or(f.close_ns, |c: u64| c.min(f.close_ns)));
        e.barrier = e.barrier.max(f.close_ns);
        e.queueing += f.queueing_ns;
        e.retransmit += f.retransmit_ns;
        e.wait += f.early_close_wait_ns;
    }
    let iterations: Vec<Json> = iters
        .into_iter()
        .map(|(iter, e)| {
            let start = e.start.unwrap_or(0);
            Json::obj(vec![
                ("iter", iter.into()),
                ("flows", e.flows.into()),
                ("start_ns", start.into()),
                ("first_close_ns", e.first_close.unwrap_or(0).into()),
                ("barrier_ns", e.barrier.into()),
                ("span_ns", e.barrier.saturating_sub(start).into()),
                ("queueing_ns", e.queueing.into()),
                ("retransmit_ns", e.retransmit.into()),
                ("early_close_wait_ns", e.wait.into()),
            ])
        })
        .collect();
    Json::obj(vec![
        ("sim", sim.index.into()),
        ("seed", sim.seed.into()),
        ("t_end_ns", sim.t_end_ns.into()),
        ("links", Json::Arr(links)),
        ("flows", Json::Arr(flows)),
        ("iterations", Json::Arr(iterations)),
    ])
}

#[derive(Default)]
struct IterAcc {
    flows: u64,
    start: Option<u64>,
    first_close: Option<u64>,
    barrier: u64,
    queueing: u64,
    retransmit: u64,
    wait: u64,
}

/// [`trace_stats`] rendered straight to JSON.
pub fn stats_json(file: &TraceFile) -> Json {
    trace_stats(file).to_json()
}
