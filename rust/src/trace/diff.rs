//! Trace diff: align two recorded runs by (sim, link, iteration) and
//! rank the cells by BST-contribution delta, localizing a regression to
//! a link and iteration in one command (DESIGN.md §4.7).
//!
//! A cell's BST contribution is the queueing time the iteration's
//! gather flows spent on that link plus the retransmit spans attributed
//! to it. Retransmit attribution: each re-sent sequence's (last − first
//! TX) span is charged to the link that last dropped it, falling back
//! to the flow's first hop when no drop was recorded — so under loss
//! the bottleneck where drops concentrate ranks first. Both sides come
//! from the shared [`breakdown_table`] pairing pass; diffing a trace
//! against itself therefore yields no cells at all.

use super::breakdown::breakdown_table;
use super::reader::TraceFile;
use super::stats::{link_label, link_meta_map};
use crate::metrics::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One (sim, link, iteration) cell of a trace diff.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DiffCell {
    /// Simulation index within both traces.
    pub sim: usize,
    /// Link id.
    pub link: u32,
    /// Training iteration.
    pub iter: u64,
    /// Human link label (metadata-aware, `link<N>` fallback).
    pub label: String,
    /// Trace A's BST contribution on this cell (ns).
    pub a_ns: u64,
    /// Trace B's BST contribution on this cell (ns).
    pub b_ns: u64,
    /// `b_ns − a_ns`.
    pub delta_ns: i64,
    /// Queueing part of `a_ns`.
    pub a_queueing_ns: u64,
    /// Queueing part of `b_ns`.
    pub b_queueing_ns: u64,
    /// Retransmit part of `a_ns`.
    pub a_retransmit_ns: u64,
    /// Retransmit part of `b_ns`.
    pub b_retransmit_ns: u64,
}

/// Result of diffing two traces: nonzero cells ranked by |delta|.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceDiff {
    /// Trace A's scenario name.
    pub a_scenario: String,
    /// Trace B's scenario name.
    pub b_scenario: String,
    /// Σ BST contribution over all of A's cells (ns).
    pub a_total_ns: u64,
    /// Σ BST contribution over all of B's cells (ns).
    pub b_total_ns: u64,
    /// Cells in the union of both traces' keys.
    pub cells_considered: usize,
    /// Nonzero-delta cells, |delta| descending (ties: key order),
    /// truncated to the requested top-K.
    pub cells: Vec<DiffCell>,
}

/// Per-trace cell extraction: (sim, link, iter) → (queueing, retransmit).
type CellMap = BTreeMap<(usize, u32, u64), (u64, u64)>;

fn cells_of(file: &TraceFile) -> CellMap {
    let mut cells = CellMap::new();
    for table in breakdown_table(file) {
        for row in &table.flows {
            for &(link, q) in &row.queueing_by_link {
                cells.entry((table.index, link, row.iter)).or_default().0 += q;
            }
            for r in &row.retx {
                let Some(link) = r.drop_link.or(row.first_hop) else { continue };
                let span = r.last_tx_ns - r.first_tx_ns;
                cells.entry((table.index, link, row.iter)).or_default().1 += span;
            }
        }
    }
    cells
}

/// Diff two traces, keeping the top-K cells by |BST-contribution delta|.
pub fn diff(a: &TraceFile, b: &TraceFile, top: usize) -> TraceDiff {
    let ca = cells_of(a);
    let cb = cells_of(b);
    let meta_a = link_meta_map(a);
    let meta_b = link_meta_map(b);
    let mut keys: Vec<(usize, u32, u64)> = ca.keys().chain(cb.keys()).copied().collect();
    keys.sort_unstable();
    keys.dedup();
    let cells_considered = keys.len();
    let mut a_total = 0u64;
    let mut b_total = 0u64;
    let mut cells = Vec::new();
    for key in keys {
        let (sim, link, iter) = key;
        let (aq, artx) = ca.get(&key).copied().unwrap_or((0, 0));
        let (bq, brtx) = cb.get(&key).copied().unwrap_or((0, 0));
        let a_ns = aq + artx;
        let b_ns = bq + brtx;
        a_total += a_ns;
        b_total += b_ns;
        if a_ns == b_ns {
            continue;
        }
        let meta = meta_b.get(&(sim, link)).or_else(|| meta_a.get(&(sim, link)));
        cells.push(DiffCell {
            sim,
            link,
            iter,
            label: link_label(link, meta),
            a_ns,
            b_ns,
            delta_ns: b_ns as i64 - a_ns as i64,
            a_queueing_ns: aq,
            b_queueing_ns: bq,
            a_retransmit_ns: artx,
            b_retransmit_ns: brtx,
        });
    }
    cells.sort_by(|x, y| {
        y.delta_ns
            .unsigned_abs()
            .cmp(&x.delta_ns.unsigned_abs())
            .then((x.sim, x.link, x.iter).cmp(&(y.sim, y.link, y.iter)))
    });
    cells.truncate(top);
    TraceDiff {
        a_scenario: a.header.scenario.clone(),
        b_scenario: b.header.scenario.clone(),
        a_total_ns: a_total,
        b_total_ns: b_total,
        cells_considered,
        cells,
    }
}

/// Render a [`TraceDiff`] as the deterministic `ltp-trace-diff-v1` JSON.
pub fn diff_json(d: &TraceDiff) -> Json {
    let top: Vec<Json> = d
        .cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("sim", c.sim.into()),
                ("link", (c.link as u64).into()),
                ("iter", c.iter.into()),
                ("label", c.label.as_str().into()),
                ("a_ns", c.a_ns.into()),
                ("b_ns", c.b_ns.into()),
                ("delta_ns", (c.delta_ns as f64).into()),
                ("a_queueing_ns", c.a_queueing_ns.into()),
                ("b_queueing_ns", c.b_queueing_ns.into()),
                ("a_retransmit_ns", c.a_retransmit_ns.into()),
                ("b_retransmit_ns", c.b_retransmit_ns.into()),
            ])
        })
        .collect();
    let delta_total = d.b_total_ns as i64 - d.a_total_ns as i64;
    Json::obj(vec![
        ("schema", "ltp-trace-diff-v1".into()),
        ("a_scenario", d.a_scenario.as_str().into()),
        ("b_scenario", d.b_scenario.as_str().into()),
        ("a_total_ns", d.a_total_ns.into()),
        ("b_total_ns", d.b_total_ns.into()),
        ("delta_total_ns", (delta_total as f64).into()),
        ("cells_considered", d.cells_considered.into()),
        ("top", Json::Arr(top)),
    ])
}

fn fmt_signed_ms(ns: i64) -> String {
    let sign = if ns < 0 { "-" } else { "+" };
    let abs = ns.unsigned_abs();
    format!("{sign}{}.{:03}ms", abs / 1_000_000, (abs / 1_000) % 1_000)
}

fn fmt_ms(ns: u64) -> String {
    format!("{}.{:03}ms", ns / 1_000_000, (ns / 1_000) % 1_000)
}

/// Render a [`TraceDiff`] as a human-readable table.
pub fn render_diff_table(d: &TraceDiff) -> String {
    let mut out = String::new();
    let delta_total = d.b_total_ns as i64 - d.a_total_ns as i64;
    let _ = writeln!(out, "a: {:24} BST contribution {}", d.a_scenario, fmt_ms(d.a_total_ns));
    let _ = writeln!(
        out,
        "b: {:24} BST contribution {}  (delta {})",
        d.b_scenario,
        fmt_ms(d.b_total_ns),
        fmt_signed_ms(delta_total)
    );
    if d.cells.is_empty() {
        let _ = writeln!(
            out,
            "no differing (sim, link, iteration) cells across {} considered — runs are identical",
            d.cells_considered
        );
        return out;
    }
    let _ = writeln!(
        out,
        "top {} of {} (sim, link, iteration) cells by |BST delta|:",
        d.cells.len(),
        d.cells_considered
    );
    let _ = writeln!(
        out,
        "  {:>3} {:>4} {:>4}  {:<18} {:>12} {:>14} {:>14}",
        "sim", "iter", "link", "label", "delta", "queueing", "retransmit"
    );
    for c in &d.cells {
        let _ = writeln!(
            out,
            "  {:>3} {:>4} {:>4}  {:<18} {:>12} {:>14} {:>14}",
            c.sim,
            c.iter,
            c.link,
            c.label,
            fmt_signed_ms(c.delta_ns),
            fmt_signed_ms(c.b_queueing_ns as i64 - c.a_queueing_ns as i64),
            fmt_signed_ms(c.b_retransmit_ns as i64 - c.a_retransmit_ns as i64)
        );
    }
    out
}
