//! Trace file encoding: a 64-byte versioned header followed by packed
//! 40-byte little-endian [`Record`]s.
//!
//! Header layout (all little-endian):
//!
//! ```text
//! offset  size  field
//!      0     8  magic  b"LTPTRACE"
//!      8     4  format version (2)
//!     12     4  record size in bytes (40)
//!     16     4  quick flag (0/1)
//!     20     4  job count (number of KIND_JOB_START records)
//!     24    32  scenario name, NUL-padded UTF-8
//!     56     8  record count
//!     64     …  records (record_count × 40 bytes)
//! ```
//!
//! Version history: v1 had no link-metadata records; v2 adds
//! [`super::KIND_LINK_META`] (same header and record layout). The reader
//! accepts both; tools label links `link<N>` when metadata is absent.

use super::{Record, RECORD_BYTES};

/// Trace file magic bytes.
pub const MAGIC: [u8; 8] = *b"LTPTRACE";
/// Current trace format version (v2 = v1 + link-metadata records).
pub const VERSION: u32 = 2;
/// Size of the file header.
pub const HEADER_BYTES: usize = 64;
/// Width of the NUL-padded scenario-name field.
pub const SCENARIO_FIELD: usize = 32;

/// Decoded trace file header.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceHeader {
    /// Format version ([`VERSION`] for files this build writes).
    pub version: u32,
    /// Whether the recorded sweep ran with `--quick`.
    pub quick: bool,
    /// Number of sweep jobs captured (seeds × scenarios).
    pub jobs: u32,
    /// Scenario name the trace was recorded from.
    pub scenario: String,
    /// Number of records following the header.
    pub record_count: u64,
}

/// Encode a header + record stream into the on-disk byte layout.
pub fn encode(
    scenario: &str,
    quick: bool,
    jobs: u32,
    records: &[Record],
) -> Result<Vec<u8>, String> {
    if scenario.len() >= SCENARIO_FIELD {
        return Err(format!(
            "scenario name `{scenario}` is {} bytes, max {} (header field is NUL-terminated)",
            scenario.len(),
            SCENARIO_FIELD - 1
        ));
    }
    let mut out = Vec::with_capacity(HEADER_BYTES + records.len() * RECORD_BYTES);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(RECORD_BYTES as u32).to_le_bytes());
    out.extend_from_slice(&(quick as u32).to_le_bytes());
    out.extend_from_slice(&jobs.to_le_bytes());
    let mut name = [0u8; SCENARIO_FIELD];
    name[..scenario.len()].copy_from_slice(scenario.as_bytes());
    out.extend_from_slice(&name);
    out.extend_from_slice(&(records.len() as u64).to_le_bytes());
    debug_assert_eq!(out.len(), HEADER_BYTES);
    for r in records {
        out.extend_from_slice(&r.encode());
    }
    Ok(out)
}

/// Encode and write a trace file to `path`.
pub fn write_file(
    path: &str,
    scenario: &str,
    quick: bool,
    jobs: u32,
    records: &[Record],
) -> Result<(), String> {
    let bytes = encode(scenario, quick, jobs, records)?;
    std::fs::write(path, bytes).map_err(|e| format!("writing {path}: {e}"))
}
