//! Deterministic packet/event trace capture and replay (DESIGN.md §4.6).
//!
//! An opt-in observation layer over the simulator core: when a capture
//! scope is active ([`capture`]), every [`crate::simnet::Sim`] created on
//! the thread appends fixed-width [`Record`]s to the scope's
//! [`TraceSink`] — link enqueue/transmit/drop/deliver, timer dispatch,
//! and the protocol-level LTP close and ACK decisions noted by the PS
//! nodes ([`note_close`], [`note_ack`]). The stream is a pure function of
//! the simulation's seed, so it is byte-identical across runs and across
//! `--jobs N` (per-job captures are merged in job order by
//! [`crate::scenarios::sweep::run_sweep_traced`]).
//!
//! **Zero cost when disabled.** The simulator holds an
//! `Option<`[`SharedSink`]`>` resolved once at `Sim::new`; with no scope
//! active every hook is a single `None` branch, no record is built, and
//! no RNG stream is touched — the golden report bytes
//! (`tests/golden/scenario_hashes.txt`) hold with tracing compiled in.
//!
//! On-disk format: a 64-byte versioned header followed by packed 40-byte
//! little-endian records ([`encode`], [`decode`]). `ltp trace` records a
//! scenario run, `ltp replay` re-drives it from the trace and must
//! reproduce both the record stream and the original report bytes
//! ([`replay()`]), and `ltp replay --breakdown` distills the per-flow
//! BST split ([`breakdown()`]).
//!
//! The observability layer (DESIGN.md §4.7) builds on the same stream:
//! [`trace_stats`] distills per-link/per-flow/per-iteration statistics,
//! [`render_svg`]/[`render_html`] draw a link-occupancy timeline, and
//! [`diff`] aligns two traces by (sim, link, iteration) to localize a
//! BST regression. Topology builders label links for these tools via
//! [`Record::link_meta`] records (format v2; v1 traces still read, with
//! `link<N>` fallback labels).

mod breakdown;
mod diff;
mod reader;
mod replay;
mod stats;
mod viz;
mod writer;

pub use breakdown::{breakdown, breakdown_table, FlowRow, SeqRetx, SimTable};
pub use diff::{diff, diff_json, render_diff_table, DiffCell, TraceDiff};
pub use reader::{decode, read_file, TraceFile};
pub use replay::{replay, ReplayOutcome};
pub use stats::{
    link_label, link_meta_map, stats_json, trace_stats, LinkMeta, LinkUse, SimStats, TraceStats,
};
pub use viz::{render_html, render_svg};
pub use writer::{encode, write_file, TraceHeader, HEADER_BYTES, MAGIC, SCENARIO_FIELD, VERSION};

use crate::proto::CloseReason;
use crate::simnet::{Ctx, Packet};
use crate::wire::{LtpType, PacketKind};
use crate::Nanos;
use std::cell::RefCell;
use std::rc::Rc;

/// Size of one encoded [`Record`] on disk.
pub const RECORD_BYTES: usize = 40;

/// Job boundary in a sweep capture: `a` = scenario registry index,
/// `flow` = seed, `d` = quick flag. Emitted before the job's first sim.
pub const KIND_JOB_START: u8 = 0;
/// A `Sim::new` under the capture scope; `flow` = the sim's seed.
pub const KIND_SIM_START: u8 = 1;
/// Packet accepted onto a link queue (`a` = link id, `d` = size).
pub const KIND_ENQUEUE: u8 = 2;
/// Packet finished serialization and entered the wire (`a` = link id).
pub const KIND_TX: u8 = 3;
/// Drop-tail: packet rejected by a full link queue (`a` = link id).
pub const KIND_DROP_QUEUE: u8 = 4;
/// Wire loss: packet lost by the link's loss model after serialization.
pub const KIND_DROP_WIRE: u8 = 5;
/// Packet delivered to a host node (`a` = link id, `d` = dst entity).
pub const KIND_DELIVER: u8 = 6;
/// Timer dispatched to a node (`a` = entity, `c` = token).
pub const KIND_TIMER: u8 = 7;
/// LTP gather close decision (`a` = worker, `c` = `iter << 8 | reason`,
/// `d` = delivered ppm, `ptype` = criticals-ok flag).
pub const KIND_CLOSE: u8 = 8;
/// PS emitted an ACK/Stop packet for a gather flow (`a` = entity,
/// `c` = acked seq).
pub const KIND_ACK: u8 = 9;
/// Static link metadata emitted by topology builders right after the
/// sim-start marker (format v2+): `a` = link id, `ptype` = one of the
/// `ROLE_*` constants, `flow` = `src << 32 | dst` entity ids, `c` = rate
/// (bits/s), `d` = queue capacity (bytes). Lets viz/diff label real
/// links instead of bare ids; traces without it fall back to `link<N>`.
pub const KIND_LINK_META: u8 = 10;
/// Highest valid record kind (decode rejects beyond this).
pub const KIND_MAX: u8 = KIND_LINK_META;
/// Highest record kind a format-v1 trace may carry.
pub const KIND_MAX_V1: u8 = KIND_ACK;

/// Link-meta role: host edge uplink (host → switch/ToR).
pub const ROLE_EDGE_UP: u8 = 1;
/// Link-meta role: host edge downlink (switch/ToR → host).
pub const ROLE_EDGE_DOWN: u8 = 2;
/// Link-meta role: rack trunk uplink (ToR → aggregation).
pub const ROLE_TRUNK_UP: u8 = 3;
/// Link-meta role: rack trunk downlink (aggregation → ToR).
pub const ROLE_TRUNK_DOWN: u8 = 4;

/// `ptype` for records that carry no packet.
pub const PTYPE_NONE: u8 = 0;
/// LTP data segment.
pub const PTYPE_LTP_DATA: u8 = 1;
/// LTP per-packet ACK.
pub const PTYPE_LTP_ACK: u8 = 2;
/// LTP end/stop.
pub const PTYPE_LTP_END: u8 = 3;
/// LTP flow registration.
pub const PTYPE_LTP_REG: u8 = 4;
/// TCP segment (baseline protocols).
pub const PTYPE_TCP: u8 = 5;
/// Opaque test/background payload.
pub const PTYPE_RAW: u8 = 6;

/// One fixed-width trace record (40 bytes little-endian on disk). Field
/// meaning depends on `kind` — see the `KIND_*` constants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Record {
    /// Simulation time (ns); 0 for job/sim markers.
    pub t: Nanos,
    /// One of the `KIND_*` constants.
    pub kind: u8,
    /// One of the `PTYPE_*` constants (criticals-ok flag for closes).
    pub ptype: u8,
    /// Link id, entity id, worker index, or scenario index (per kind).
    pub a: u32,
    /// Flow id (or seed for job/sim markers).
    pub flow: u64,
    /// Sequence id, timer token, or `iter << 8 | close reason`.
    pub c: u64,
    /// Packet size, destination entity, delivered ppm, or quick flag.
    pub d: u64,
}

/// `(ptype, seq)` of a packet's payload, for packet-carrying records.
fn packet_meta(pkt: &Packet) -> (u8, u64) {
    match &pkt.kind {
        PacketKind::Ltp(h) => {
            let p = match h.ty {
                LtpType::Registration => PTYPE_LTP_REG,
                LtpType::Data => PTYPE_LTP_DATA,
                LtpType::Ack => PTYPE_LTP_ACK,
                LtpType::End => PTYPE_LTP_END,
            };
            (p, h.seq as u64)
        }
        PacketKind::Tcp(s) => (PTYPE_TCP, s.seq),
        PacketKind::Raw(id) => (PTYPE_RAW, *id),
    }
}

/// Close-reason wire code (`Complete`=0, `EarlyPct`=1, `Deadline`=2).
pub fn reason_code(r: CloseReason) -> u8 {
    match r {
        CloseReason::Complete => 0,
        CloseReason::EarlyPct => 1,
        CloseReason::Deadline => 2,
    }
}

/// Human name for a close-reason wire code (breakdown reports).
pub fn reason_name(code: u8) -> &'static str {
    match code {
        0 => "complete",
        1 => "early_pct",
        2 => "deadline",
        _ => "unknown",
    }
}

impl Record {
    /// Job boundary marker for a sweep job (see [`KIND_JOB_START`]).
    pub fn job_start(scenario_index: usize, seed: u64, quick: bool) -> Record {
        Record {
            t: 0,
            kind: KIND_JOB_START,
            ptype: PTYPE_NONE,
            a: scenario_index as u32,
            flow: seed,
            c: 0,
            d: quick as u64,
        }
    }

    /// Sim construction marker (see [`KIND_SIM_START`]).
    pub fn sim_start(seed: u64) -> Record {
        Record { t: 0, kind: KIND_SIM_START, ptype: PTYPE_NONE, a: 0, flow: seed, c: 0, d: 0 }
    }

    /// Packet record on a link (enqueue/tx/drop kinds; `d` = size).
    pub fn packet(kind: u8, t: Nanos, link: usize, pkt: &Packet) -> Record {
        let (ptype, seq) = packet_meta(pkt);
        Record { t, kind, ptype, a: link as u32, flow: pkt.flow, c: seq, d: pkt.size as u64 }
    }

    /// Host delivery record (`d` = destination entity).
    pub fn deliver(t: Nanos, link: usize, dst: usize, pkt: &Packet) -> Record {
        let (ptype, seq) = packet_meta(pkt);
        Record {
            t,
            kind: KIND_DELIVER,
            ptype,
            a: link as u32,
            flow: pkt.flow,
            c: seq,
            d: dst as u64,
        }
    }

    /// Static link metadata record (see [`KIND_LINK_META`]). `t` is 0:
    /// the topology is built before the first event fires.
    pub fn link_meta(
        link: usize,
        role: u8,
        src: usize,
        dst: usize,
        rate_bps: u64,
        queue_cap_bytes: u64,
    ) -> Record {
        Record {
            t: 0,
            kind: KIND_LINK_META,
            ptype: role,
            a: link as u32,
            flow: ((src as u64) << 32) | (dst as u64 & 0xffff_ffff),
            c: rate_bps,
            d: queue_cap_bytes,
        }
    }

    /// Timer dispatch record.
    pub fn timer(t: Nanos, entity: usize, token: u64) -> Record {
        Record { t, kind: KIND_TIMER, ptype: PTYPE_NONE, a: entity as u32, flow: 0, c: token, d: 0 }
    }

    /// Encode as the on-disk 40-byte little-endian layout (bytes 10–11
    /// are reserved padding, always zero).
    pub fn encode(&self) -> [u8; RECORD_BYTES] {
        let mut b = [0u8; RECORD_BYTES];
        b[0..8].copy_from_slice(&self.t.to_le_bytes());
        b[8] = self.kind;
        b[9] = self.ptype;
        b[12..16].copy_from_slice(&self.a.to_le_bytes());
        b[16..24].copy_from_slice(&self.flow.to_le_bytes());
        b[24..32].copy_from_slice(&self.c.to_le_bytes());
        b[32..40].copy_from_slice(&self.d.to_le_bytes());
        b
    }

    /// Decode the on-disk layout (the inverse of [`Record::encode`]).
    pub fn decode(b: &[u8; RECORD_BYTES]) -> Record {
        Record {
            t: u64::from_le_bytes(b[0..8].try_into().unwrap()),
            kind: b[8],
            ptype: b[9],
            a: u32::from_le_bytes(b[12..16].try_into().unwrap()),
            flow: u64::from_le_bytes(b[16..24].try_into().unwrap()),
            c: u64::from_le_bytes(b[24..32].try_into().unwrap()),
            d: u64::from_le_bytes(b[32..40].try_into().unwrap()),
        }
    }
}

/// Where records go while a capture scope is active. The simulator holds
/// a shared handle and appends through this trait, so alternative sinks
/// (counting, streaming) can replace the in-memory buffer.
pub trait TraceSink {
    /// Append one record.
    fn record(&mut self, rec: Record);
}

/// The default sink: an in-memory record buffer.
#[derive(Default)]
pub struct TraceBuf {
    /// Records in emission order.
    pub records: Vec<Record>,
}

impl TraceSink for TraceBuf {
    fn record(&mut self, rec: Record) {
        self.records.push(rec);
    }
}

/// Shared sink handle stored by each `Sim` created under a scope.
pub type SharedSink = Rc<RefCell<dyn TraceSink>>;

thread_local! {
    /// The thread's active capture scope, if any. Thread-local (not
    /// global) so each sweep-pool worker captures its own jobs.
    static SCOPE: RefCell<Option<SharedSink>> = const { RefCell::new(None) };
}

/// An active capture scope: every `Sim::new` on this thread until
/// [`Capture::finish`] (or drop) records into the scope's buffer.
pub struct Capture {
    buf: Rc<RefCell<TraceBuf>>,
    prev: Option<SharedSink>,
    restored: bool,
}

/// Open a capture scope on the current thread (restores any previously
/// active scope when it ends).
pub fn capture() -> Capture {
    let buf = Rc::new(RefCell::new(TraceBuf::default()));
    let sink: SharedSink = buf.clone();
    let prev = SCOPE.with(|s| s.borrow_mut().replace(sink));
    Capture { buf, prev, restored: false }
}

impl Capture {
    fn restore(&mut self) {
        if !self.restored {
            let prev = self.prev.take();
            SCOPE.with(|s| *s.borrow_mut() = prev);
            self.restored = true;
        }
    }

    /// Close the scope and take the captured records.
    pub fn finish(mut self) -> Vec<Record> {
        self.restore();
        std::mem::take(&mut self.buf.borrow_mut().records)
    }
}

impl Drop for Capture {
    fn drop(&mut self) {
        self.restore();
    }
}

/// The current scope's sink, for `Sim::new` to store (one resolution per
/// simulation, not per event).
pub(crate) fn active() -> Option<SharedSink> {
    SCOPE.with(|s| s.borrow().clone())
}

/// True when a capture scope is active on this thread.
pub fn is_active() -> bool {
    SCOPE.with(|s| s.borrow().is_some())
}

/// Append a record to the active scope, if any (used for out-of-sim
/// markers like [`Record::job_start`]).
pub fn emit(rec: Record) {
    SCOPE.with(|s| {
        if let Some(sink) = s.borrow().as_ref() {
            sink.borrow_mut().record(rec);
        }
    });
}

/// Note an LTP gather-close decision (PS/relay `check_progress`). No-op
/// unless this simulation is being traced.
pub fn note_close(
    ctx: &mut Ctx,
    worker: usize,
    flow: u64,
    iter: u64,
    reason: CloseReason,
    criticals_ok: bool,
    delivered: f64,
) {
    if !ctx.trace_on() {
        return;
    }
    let ppm = (delivered * 1_000_000.0).round() as u64;
    let rec = Record {
        t: ctx.now(),
        kind: KIND_CLOSE,
        ptype: criticals_ok as u8,
        a: worker as u32,
        flow,
        c: (iter << 8) | reason_code(reason) as u64,
        d: ppm,
    };
    ctx.trace(rec);
}

/// Note a receiver-side ACK/Stop decision about to be transmitted (the
/// PS drain sites call this just before `ctx.send`). Only LTP ACK/End
/// packets produce a record; no-op unless this simulation is traced.
pub fn note_ack(ctx: &mut Ctx, pkt: &Packet) {
    if !ctx.trace_on() {
        return;
    }
    if let PacketKind::Ltp(h) = &pkt.kind {
        if matches!(h.ty, LtpType::Ack | LtpType::End) {
            let (ptype, seq) = packet_meta(pkt);
            let rec = Record {
                t: ctx.now(),
                kind: KIND_ACK,
                ptype,
                a: ctx.me as u32,
                flow: pkt.flow,
                c: seq,
                d: 0,
            };
            ctx.trace(rec);
        }
    }
}
