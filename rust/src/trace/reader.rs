//! Trace file decoding. Every rejection names the byte offset of the
//! problem, so a truncated artifact or a non-trace file fails with
//! context instead of a silent mis-parse.

use super::writer::{TraceHeader, HEADER_BYTES, MAGIC, SCENARIO_FIELD, VERSION};
use super::{Record, KIND_MAX, KIND_MAX_V1, RECORD_BYTES};

/// A decoded trace: header + records in emission order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceFile {
    /// The decoded 64-byte header.
    pub header: TraceHeader,
    /// The record stream.
    pub records: Vec<Record>,
}

fn le_u32(bytes: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap())
}

/// Decode a trace file from its raw bytes.
pub fn decode(bytes: &[u8]) -> Result<TraceFile, String> {
    if bytes.len() < HEADER_BYTES {
        return Err(format!(
            "trace header truncated at offset {}: need {HEADER_BYTES} header bytes, found {}",
            bytes.len(),
            bytes.len()
        ));
    }
    if bytes[0..8] != MAGIC {
        let msg = "bad magic at offset 0: expected `LTPTRACE` — not an ltp trace file";
        return Err(msg.to_string());
    }
    let version = le_u32(bytes, 8);
    if version == 0 || version > VERSION {
        return Err(format!(
            "unsupported trace version {version} at offset 8 (this build reads versions 1..={VERSION})"
        ));
    }
    // v1 traces predate link-metadata records; reject kinds they can't carry.
    let kind_max = if version == 1 { KIND_MAX_V1 } else { KIND_MAX };
    let rec_size = le_u32(bytes, 12);
    if rec_size as usize != RECORD_BYTES {
        return Err(format!("record size {rec_size} at offset 12, expected {RECORD_BYTES}"));
    }
    let quick = le_u32(bytes, 16) != 0;
    let jobs = le_u32(bytes, 20);
    let name_bytes = &bytes[24..24 + SCENARIO_FIELD];
    let name_end = name_bytes.iter().position(|&b| b == 0).unwrap_or(SCENARIO_FIELD);
    let scenario = std::str::from_utf8(&name_bytes[..name_end])
        .map_err(|_| "scenario name at offset 24 is not UTF-8".to_string())?
        .to_string();
    let record_count = u64::from_le_bytes(bytes[56..64].try_into().unwrap());
    let body = &bytes[HEADER_BYTES..];
    let promised = record_count
        .checked_mul(RECORD_BYTES as u64)
        .ok_or_else(|| format!("record count {record_count} at offset 56 overflows"))?;
    if body.len() as u64 != promised {
        return Err(format!(
            "trace truncated at offset {}: header promises {record_count} records \
             ({promised} bytes after the header), found {} bytes",
            HEADER_BYTES + body.len(),
            body.len()
        ));
    }
    let mut records = Vec::with_capacity(record_count as usize);
    for (i, chunk) in body.chunks_exact(RECORD_BYTES).enumerate() {
        let arr: &[u8; RECORD_BYTES] = chunk.try_into().unwrap();
        let rec = Record::decode(arr);
        if rec.kind > kind_max {
            return Err(format!(
                "unknown record kind {} at offset {}",
                rec.kind,
                HEADER_BYTES + i * RECORD_BYTES + 8
            ));
        }
        records.push(rec);
    }
    let header = TraceHeader { version, quick, jobs, scenario, record_count };
    Ok(TraceFile { header, records })
}

/// Read and decode a trace file from `path`.
pub fn read_file(path: &str) -> Result<TraceFile, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("reading {path}: {e}"))?;
    decode(&bytes).map_err(|e| format!("{path}: {e}"))
}
