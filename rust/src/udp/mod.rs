//! Real-socket driver: the same sans-IO LTP state machines over
//! `std::net::UdpSocket`, with actual byte payloads on the wire (9-byte
//! header + gradient bytes) and an optional loss injector for testing.
//!
//! This demonstrates that the protocol core is wire-real, not a simulation
//! artifact: the simulator and this driver share every line of
//! [`crate::proto`].

use crate::proto::{EarlyCloseCfg, LtpEvent, LtpReceiver, LtpSender, SegmentMap, CTRL_SEQ};
use crate::simnet::{BufId, BufPool};
use crate::util::Pcg64;
use crate::wire::{LtpHeader, LtpType, HDR_BYTES};
use crate::Nanos;
use anyhow::{Context, Result};
use std::net::UdpSocket;
use std::time::{Duration, Instant};

/// Monotonic clock → protocol nanoseconds.
struct Clock(Instant);

impl Clock {
    fn now(&self) -> Nanos {
        self.0.elapsed().as_nanos() as Nanos
    }
}

/// First idle-poll sleep. Short enough that a datacenter-RTT ACK burst is
/// picked up promptly after a quiet socket.
const IDLE_BACKOFF_MIN: Duration = Duration::from_micros(20);
/// Idle-poll ceiling: bounds wakeup latency (retransmit/Early-Close timers
/// still fire within one RTO-scale tick) while keeping a stalled peer from
/// costing a spinning core.
const IDLE_BACKOFF_MAX: Duration = Duration::from_micros(500);

/// Bounded exponential backoff for the nonblocking-socket poll loops:
/// sleeps double from [`IDLE_BACKOFF_MIN`] to [`IDLE_BACKOFF_MAX`] across
/// consecutive idle polls and reset to the minimum as soon as any packet
/// moves.
struct IdleBackoff(Duration);

impl IdleBackoff {
    fn fresh() -> IdleBackoff {
        IdleBackoff(IDLE_BACKOFF_MIN)
    }

    fn reset(&mut self) {
        self.0 = IDLE_BACKOFF_MIN;
    }

    fn sleep(&mut self) {
        std::thread::sleep(self.0);
        self.0 = (self.0 * 2).min(IDLE_BACKOFF_MAX);
    }
}

/// Send one message over UDP with LTP; blocks until the flow completes or
/// `timeout` passes. Returns the sender stats.
pub fn send_message(
    socket: &UdpSocket,
    peer: std::net::SocketAddr,
    data: &[u8],
    map: SegmentMap,
    seed_rtprop: Nanos,
    seed_btlbw: u64,
    timeout: Duration,
) -> Result<crate::proto::SenderStats> {
    let clock = Clock(Instant::now());
    let mut sender = LtpSender::new(1, map.clone(), crate::wire::MTU);
    if seed_btlbw > 0 {
        sender.seed_cc(seed_rtprop, seed_btlbw);
    }
    socket.set_nonblocking(true)?;
    let mut buf = [0u8; 65536];
    let mut out = Vec::with_capacity(HDR_BYTES + map.seg_payload as usize);
    let mut backoff = IdleBackoff::fresh();
    while !sender.is_complete() {
        if clock.0.elapsed() > timeout {
            anyhow::bail!("LTP send timed out ({:?})", timeout);
        }
        // Transmit what the state machine allows.
        while let Some(pkt) = sender.poll_transmit(clock.now()) {
            out.clear();
            out.extend_from_slice(&pkt.hdr.encode());
            if pkt.hdr.ty == LtpType::Data {
                let (a, b) = map.byte_range(pkt.hdr.seq);
                out.extend_from_slice(&data[a as usize..b as usize]);
            }
            socket.send_to(&out, peer).context("udp send")?;
        }
        // Ingest ACKs/stops.
        let mut idle = true;
        while let Ok((n, _from)) = socket.recv_from(&mut buf) {
            idle = false;
            if let Some(hdr) = LtpHeader::decode(&buf[..n]) {
                sender.handle(clock.now(), LtpEvent { hdr, payload_len: 0 });
            }
        }
        sender.on_wakeup(clock.now());
        if idle && !sender.is_complete() {
            backoff.sleep();
        } else {
            backoff.reset();
        }
    }
    Ok(sender.stats)
}

/// Receive one message over UDP with LTP; returns the reassembled
/// (bubble-filled) buffer and the receiver stats. `drop_rate` injects
/// deterministic receive-side loss for tests.
pub fn recv_message(
    socket: &UdpSocket,
    ec: EarlyCloseCfg,
    expected_critical: Vec<u32>,
    drop_rate: f64,
    drop_seed: u64,
    timeout: Duration,
) -> Result<(Vec<u8>, crate::proto::ReceiverStats)> {
    let clock = Clock(Instant::now());
    let mut rng = Pcg64::seeded(drop_seed);
    let mut receiver = LtpReceiver::new(1, ec, expected_critical);
    socket.set_nonblocking(true)?;
    let mut buf = [0u8; 65536];
    let mut peer: Option<std::net::SocketAddr> = None;
    // Segment payload bytes arrive over the wire; stash by seq in pooled
    // buffers (recycled after reassembly — the receive loop itself does
    // zero per-segment heap allocations at steady state).
    let mut pool = BufPool::new(64);
    let mut segments: Vec<(u32, BufId)> = Vec::new();
    let mut backoff = IdleBackoff::fresh();
    loop {
        if clock.0.elapsed() > timeout {
            anyhow::bail!("LTP receive timed out");
        }
        let mut idle = true;
        while let Ok((n, from)) = socket.recv_from(&mut buf) {
            idle = false;
            let Some(hdr) = LtpHeader::decode(&buf[..n]) else { continue };
            // Injected wire loss: data packets only (never self-inflict
            // control loss — the link would drop those too, but tests want
            // determinism on the data plane).
            if hdr.ty == LtpType::Data && rng.chance(drop_rate) {
                continue;
            }
            peer = Some(from);
            if hdr.ty == LtpType::Data && !receiver.is_closed() {
                let id = pool.take();
                pool.get_mut(id).extend_from_slice(&buf[HDR_BYTES..n]);
                segments.push((hdr.seq, id));
            }
            receiver.handle(
                clock.now(),
                LtpEvent { hdr, payload_len: (n - HDR_BYTES) as u32 },
            );
        }
        receiver.on_wakeup(clock.now());
        if let Some(p) = peer {
            while let Some(hdr) = receiver.poll_transmit() {
                socket.send_to(&hdr.encode(), p)?;
            }
        }
        if receiver.is_closed() {
            break;
        }
        if idle {
            backoff.sleep();
        } else {
            backoff.reset();
        }
    }
    // Reassemble with packet bubbles (zeros) for the missing segments.
    let total = receiver.total_segs().context("flow closed before registration")? as usize;
    let stats = receiver.stats.clone();
    let seg_payload = segments
        .iter()
        .map(|(_, id)| pool.get(*id).len())
        .max()
        .unwrap_or(0);
    let mut out = vec![0u8; receiver_len(&segments, &pool, total, seg_payload)];
    for &(seq, id) in &segments {
        if seq == CTRL_SEQ {
            continue;
        }
        let bytes = pool.get(id);
        let start = seq as usize * seg_payload;
        out[start..start + bytes.len()].copy_from_slice(bytes);
    }
    for (_, id) in segments {
        pool.recycle(id);
    }
    Ok((out, stats))
}

fn receiver_len(
    segments: &[(u32, BufId)],
    pool: &BufPool,
    total: usize,
    seg_payload: usize,
) -> usize {
    // Last segment may be short; derive the exact length when we saw it,
    // otherwise assume full (bubble).
    let last = total.saturating_sub(1);
    let last_len = segments
        .iter()
        .find(|(s, _)| *s as usize == last)
        .map(|(_, id)| pool.get(*id).len())
        .unwrap_or(seg_payload);
    last * seg_payload + last_len
}
