//! Simulator adapters for single LTP flows (protocol-level experiments;
//! the PS training system embeds senders/receivers directly).

use super::{EarlyCloseCfg, LtpEvent, LtpReceiver, LtpSender, SegmentMap};
use crate::simnet::{Ctx, EntityId, Node, Packet};
use crate::wire::{LtpType, PacketKind, HDR_BYTES, UDP_IP_OVERHEAD};
use crate::Nanos;
use std::cell::RefCell;
use std::rc::Rc;

/// Wire size of an LTP packet carrying `payload_len` payload bytes.
pub fn ltp_wire_size(payload_len: u32) -> u32 {
    UDP_IP_OVERHEAD + HDR_BYTES as u32 + payload_len
}

/// Shared flow-completion log: (flow, elapsed, pct delivered at close).
pub type LtpLog = Rc<RefCell<Vec<(u16, Nanos, f64)>>>;

/// Drives one [`LtpSender`] toward a peer.
pub struct LtpSenderNode {
    pub sender: LtpSender,
    peer: EntityId,
    start_at: Nanos,
    timer_gen: u64,
    log: Option<LtpLog>,
    logged: bool,
    started: Option<Nanos>,
}

impl LtpSenderNode {
    pub fn new(sender: LtpSender, peer: EntityId) -> LtpSenderNode {
        LtpSenderNode {
            sender,
            peer,
            start_at: 0,
            timer_gen: 0,
            log: None,
            logged: false,
            started: None,
        }
    }

    pub fn with_start(mut self, at: Nanos) -> LtpSenderNode {
        self.start_at = at;
        self
    }

    pub fn with_log(mut self, log: LtpLog) -> LtpSenderNode {
        self.log = Some(log);
        self
    }

    fn drain(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        self.started.get_or_insert(now);
        while let Some(out) = self.sender.poll_transmit(now) {
            let size = ltp_wire_size(out.payload_len);
            ctx.send(Packet::new(
                ctx.me,
                self.peer,
                size,
                self.sender.flow() as u64,
                PacketKind::Ltp(out.hdr),
            ));
        }
        if self.sender.is_complete() && !self.logged {
            self.logged = true;
            if let Some(log) = &self.log {
                let done = self.sender.stats.completed_at.unwrap();
                log.borrow_mut().push((
                    self.sender.flow(),
                    done - self.started.unwrap_or(0),
                    self.sender.pct_acked(),
                ));
            }
        }
        self.timer_gen += 1;
        if let Some(w) = self.sender.next_wakeup() {
            // Strictly future: re-arming an already-due timer would livelock
            // the event loop at one simulated instant.
            ctx.set_timer(w.max(now + 1), self.timer_gen);
        }
    }
}

impl Node for LtpSenderNode {
    fn as_any(&mut self) -> &mut dyn std::any::Any { self }
    fn start(&mut self, ctx: &mut Ctx) {
        if self.start_at > 0 {
            self.timer_gen += 1;
            ctx.set_timer(self.start_at, self.timer_gen);
        } else {
            self.drain(ctx);
        }
    }

    fn on_packet(&mut self, ctx: &mut Ctx, pkt: Packet) {
        if let PacketKind::Ltp(hdr) = pkt.kind {
            self.sender.handle(ctx.now(), LtpEvent { hdr, payload_len: 0 });
        }
        self.drain(ctx);
    }

    fn on_timer(&mut self, ctx: &mut Ctx, token: u64) {
        if token != self.timer_gen {
            return;
        }
        self.sender.on_wakeup(ctx.now());
        self.drain(ctx);
    }
}

/// Drives one [`LtpReceiver`]; ACKs flow back to the sender entity.
pub struct LtpReceiverNode {
    pub receiver: LtpReceiver,
    sender_entity: Option<EntityId>,
    timer_gen: u64,
}

impl LtpReceiverNode {
    pub fn new(receiver: LtpReceiver) -> LtpReceiverNode {
        LtpReceiverNode { receiver, sender_entity: None, timer_gen: 0 }
    }

    fn drain(&mut self, ctx: &mut Ctx) {
        if let Some(peer) = self.sender_entity {
            while let Some(hdr) = self.receiver.poll_transmit() {
                ctx.send(Packet::new(
                    ctx.me,
                    peer,
                    ltp_wire_size(0),
                    self.receiver.flow() as u64,
                    PacketKind::Ltp(hdr),
                ));
            }
        }
        self.timer_gen += 1;
        if let Some(w) = self.receiver.next_wakeup(ctx.now()) {
            ctx.set_timer(w.max(ctx.now() + 1), self.timer_gen);
        }
    }
}

impl Node for LtpReceiverNode {
    fn as_any(&mut self) -> &mut dyn std::any::Any { self }
    fn on_packet(&mut self, ctx: &mut Ctx, pkt: Packet) {
        if let PacketKind::Ltp(hdr) = pkt.kind {
            if hdr.ty != LtpType::Ack {
                self.sender_entity = Some(pkt.src);
            }
            let payload_len =
                pkt.size.saturating_sub(UDP_IP_OVERHEAD + HDR_BYTES as u32);
            self.receiver.handle(ctx.now(), LtpEvent { hdr, payload_len });
        }
        self.drain(ctx);
    }

    fn on_timer(&mut self, ctx: &mut Ctx, token: u64) {
        if token != self.timer_gen {
            return;
        }
        self.receiver.on_wakeup(ctx.now());
        self.drain(ctx);
    }
}

/// Convenience: run one LTP flow of `bytes` over a single duplex link,
/// returning `(sender stats, receiver stats)`.
pub fn run_single_flow(
    bytes: u64,
    critical: Vec<u32>,
    cfg: crate::simnet::LinkCfg,
    ec: EarlyCloseCfg,
    seed: u64,
    horizon: Nanos,
) -> (super::SenderStats, super::ReceiverStats) {
    use crate::simnet::Sim;
    use crate::wire::LTP_MSS;

    let mut sim = Sim::new(seed);
    let map = SegmentMap::new(bytes, LTP_MSS, critical.clone());
    let mut sender = LtpSender::new(1, map, crate::wire::MTU);
    // Seed from link truth (as a prior epoch would have).
    sender.seed_cc(2 * cfg.delay, cfg.rate_bps / 8);
    let receiver = LtpReceiver::new(1, ec, critical);
    let a = sim.add_host(Box::new(LtpSenderNode::new(sender, 1)));
    let b = sim.add_host(Box::new(LtpReceiverNode::new(receiver)));
    sim.add_duplex(a, b, cfg);
    sim.run_until(horizon);
    let s = sim.node_as::<LtpSenderNode>(a).sender.stats;
    let r = sim.node_as::<LtpReceiverNode>(b).receiver.stats.clone();
    (s, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::CloseReason;
    use crate::simnet::{LinkCfg, LossModel};
    use crate::{MS, SEC};

    #[test]
    fn clean_link_delivers_everything() {
        let ec = EarlyCloseCfg { lt_threshold: 50 * MS, deadline: 500 * MS, pct: 0.8 };
        let (s, r) = run_single_flow(1_000_000, vec![0, 100], LinkCfg::dcn(1, 50), ec, 1, 10 * SEC);
        assert_eq!(r.reason, Some(CloseReason::Complete));
        assert!((r.pct_at_close - 1.0).abs() < 1e-9);
        assert!(s.completed_at.is_some(), "sender must learn about the close");
        assert_eq!(s.segs_unacked_at_close, 0);
    }

    #[test]
    fn lossy_link_early_closes_with_partial_data() {
        // 5 % random loss; thresholds force an early close rather than a
        // long retransmission tail.
        let cfg = LinkCfg::dcn(1, 50).with_loss(LossModel::Bernoulli { p: 0.05 });
        let ec = EarlyCloseCfg { lt_threshold: 10 * MS, deadline: 60 * MS, pct: 0.80 };
        let (s, r) = run_single_flow(1_000_000, vec![0], cfg, ec, 3, 10 * SEC);
        let reason = r.reason.expect("flow must close");
        assert_ne!(reason, CloseReason::Deadline, "80 % should be reachable: {r:?}");
        assert!(r.pct_at_close >= 0.8, "pct {}", r.pct_at_close);
        assert!(r.criticals_ok);
        assert!(s.completed_at.is_some());
    }

    #[test]
    fn deadline_caps_a_terrible_link() {
        // 40 % loss: pct threshold unreachable fast; deadline must fire.
        let cfg = LinkCfg::dcn(1, 50).with_loss(LossModel::Bernoulli { p: 0.4 });
        let ec = EarlyCloseCfg { lt_threshold: 10 * MS, deadline: 25 * MS, pct: 0.99 };
        let (_s, r) = run_single_flow(2_000_000, vec![], cfg, ec, 7, 10 * SEC);
        assert_eq!(r.reason, Some(CloseReason::Deadline));
        assert!(r.elapsed <= 26 * MS, "elapsed {} must hug the deadline", r.elapsed);
    }

    #[test]
    fn reliable_mode_completes_despite_loss() {
        let cfg = LinkCfg::dcn(1, 50).with_loss(LossModel::Bernoulli { p: 0.05 });
        let (s, r) =
            run_single_flow(500_000, vec![], cfg, EarlyCloseCfg::reliable(), 5, 30 * SEC);
        assert_eq!(r.reason, Some(CloseReason::Complete));
        assert!((r.pct_at_close - 1.0).abs() < 1e-9, "receiver must have 100 %");
        assert!(s.retransmissions > 0, "5 % loss must force retransmissions");
        // The receiver closed with 100 %; the sender may still have a few
        // segments whose ACKs were lost on the reverse path.
        assert!(
            s.segs_unacked_at_close <= 16,
            "unacked at close: {}",
            s.segs_unacked_at_close
        );
    }
}
