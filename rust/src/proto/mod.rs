//! The sans-IO LTP protocol core (paper §III).
//!
//! [`LtpSender`] and [`LtpReceiver`] are pure state machines: time comes in
//! as a parameter, packets come in via `handle`, and outgoing packets are
//! pulled with `poll_transmit` — the same surface whether the driver is the
//! deterministic simulator ([`crate::simnet`]) or real UDP sockets
//! ([`crate::udp`]).
//!
//! One **flow** is one direction of one synchronization round between one
//! worker and the PS: a registration packet announcing the segment count,
//! data segments (critical or normal), per-packet out-of-order ACKs, an
//! `End` from the sender when it believes it is done, and a `Stop` from the
//! receiver when the flow closes (possibly early — §III-B Early Close).

mod early_close;
pub mod node;
mod receiver;
mod sender;

pub use early_close::{EarlyCloseCfg, ThresholdTracker};
pub use node::{ltp_wire_size, run_single_flow, LtpReceiverNode, LtpSenderNode};
pub use receiver::{CloseReason, LtpReceiver, ReceiverStats};
pub use sender::{LtpSender, OutPkt, SenderStats};

use crate::wire::LtpHeader;

/// Sentinel sequence id for registration/end/stop control packets (the
/// 24-bit all-ones value). Data segment ids must stay below this.
pub const CTRL_SEQ: u32 = 0xFF_FFFF;

/// Maximum number of data segments per flow.
pub const MAX_SEGS: u32 = CTRL_SEQ;

/// Segmentation of one message: `n_segs` segments of `seg_payload` bytes,
/// except the last which carries `last_payload` bytes. `critical` lists
/// segment ids that must be delivered reliably (paper §III-E: tensor
/// boundary bytes and other metadata).
#[derive(Debug, Clone)]
pub struct SegmentMap {
    pub n_segs: u32,
    pub seg_payload: u32,
    pub last_payload: u32,
    /// Sorted, deduplicated critical segment ids.
    pub critical: Vec<u32>,
}

impl SegmentMap {
    /// Split `total_bytes` into MSS-sized segments with the given critical
    /// set.
    pub fn new(total_bytes: u64, seg_payload: u32, mut critical: Vec<u32>) -> SegmentMap {
        assert!(total_bytes > 0 && seg_payload > 0);
        let n_segs = total_bytes.div_ceil(seg_payload as u64);
        assert!(n_segs <= MAX_SEGS as u64, "message needs {n_segs} segments > MAX_SEGS");
        let n_segs = n_segs as u32;
        let rem = (total_bytes % seg_payload as u64) as u32;
        let last_payload = if rem == 0 { seg_payload } else { rem };
        critical.sort_unstable();
        critical.dedup();
        critical.retain(|&s| s < n_segs);
        SegmentMap { n_segs, seg_payload, last_payload, critical }
    }

    /// Payload bytes of segment `seg`.
    pub fn payload_len(&self, seg: u32) -> u32 {
        if seg + 1 == self.n_segs {
            self.last_payload
        } else {
            self.seg_payload
        }
    }

    /// Total message bytes.
    pub fn total_bytes(&self) -> u64 {
        (self.n_segs as u64 - 1) * self.seg_payload as u64 + self.last_payload as u64
    }

    /// Byte range `[start, end)` of segment `seg` within the message.
    pub fn byte_range(&self, seg: u32) -> (u64, u64) {
        let start = seg as u64 * self.seg_payload as u64;
        (start, start + self.payload_len(seg) as u64)
    }

    pub fn is_critical(&self, seg: u32) -> bool {
        self.critical.binary_search(&seg).is_ok()
    }
}

/// An incoming LTP packet as seen by the state machines: the header plus
/// the payload byte count (the simulator does not carry payload bytes; the
/// UDP driver does, and passes them alongside).
#[derive(Debug, Clone, Copy)]
pub struct LtpEvent {
    pub hdr: LtpHeader,
    pub payload_len: u32,
}

/// Convenience constructor for a bare ACK event (benches, tests).
pub fn ack_event(flow: u16, seq: u32) -> LtpEvent {
    LtpEvent { hdr: LtpHeader::ack(flow, seq), payload_len: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_map_splits_exactly() {
        let m = SegmentMap::new(10_000, 1463, vec![0, 99, 0, 3]);
        assert_eq!(m.n_segs, 7); // ceil(10000/1463)
        assert_eq!(m.payload_len(0), 1463);
        assert_eq!(m.payload_len(6), 10_000 - 6 * 1463);
        assert_eq!(m.total_bytes(), 10_000);
        assert_eq!(m.critical, vec![0, 3]); // dedup + out-of-range dropped
        assert!(m.is_critical(0));
        assert!(!m.is_critical(1));
    }

    #[test]
    fn exact_multiple_has_full_last_segment() {
        let m = SegmentMap::new(1463 * 5, 1463, vec![]);
        assert_eq!(m.n_segs, 5);
        assert_eq!(m.payload_len(4), 1463);
        assert_eq!(m.total_bytes(), 1463 * 5);
    }

    #[test]
    fn byte_ranges_tile_the_message() {
        let m = SegmentMap::new(5000, 1463, vec![]);
        let mut covered = 0;
        for s in 0..m.n_segs {
            let (a, b) = m.byte_range(s);
            assert_eq!(a, covered);
            covered = b;
        }
        assert_eq!(covered, 5000);
    }
}
