//! Early Close thresholds (paper §III-B).
//!
//! Two time thresholds bound every loss-tolerant flow: before the
//! **LT threshold** the receiver waits for 100 % of the data; between the
//! LT threshold and the **deadline** it closes once the received fraction
//! reaches `pct`; at the deadline it closes unconditionally.
//!
//! [`ThresholdTracker`] implements §III-B1's update rule: the LT threshold
//! starts at `1.5·RTprop + ModelSize/BtlBw` for the first batch of an epoch
//! and is thereafter the fastest observed 100 % transmission time of the
//! epoch; the deadline is `max(LT thresholds over links) + C`.

use crate::Nanos;

/// Per-flow Early Close configuration (times relative to flow start).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EarlyCloseCfg {
    /// Below this: wait for everything.
    pub lt_threshold: Nanos,
    /// At/after this: close unconditionally.
    pub deadline: Nanos,
    /// Fraction of data segments required to close within the window.
    pub pct: f64,
}

impl EarlyCloseCfg {
    /// A reliable flow: never close early (broadcast direction — §III-B2).
    pub fn reliable() -> EarlyCloseCfg {
        EarlyCloseCfg { lt_threshold: Nanos::MAX, deadline: Nanos::MAX, pct: 1.0 }
    }

    /// Is this config loss-tolerant at all?
    pub fn is_loss_tolerant(&self) -> bool {
        self.deadline != Nanos::MAX
    }
}

/// Tracks per-link LT thresholds across batches and epochs (lives in the
/// PS application, one tracker per receive direction).
#[derive(Debug, Clone)]
pub struct ThresholdTracker {
    /// User constant C added to the max LT threshold for the deadline
    /// (paper: 30 ms in DCN, 100 ms in WAN).
    pub deadline_slack: Nanos,
    /// Received-percentage threshold (e.g. 0.8).
    pub pct: f64,
    /// Current LT threshold per link.
    lt: Vec<Nanos>,
    /// Best (smallest) observed 100 %-transmission time per link, this
    /// epoch.
    best_full: Vec<Option<Nanos>>,
}

impl ThresholdTracker {
    pub fn new(n_links: usize, deadline_slack: Nanos, pct: f64) -> ThresholdTracker {
        ThresholdTracker {
            deadline_slack,
            pct,
            lt: vec![Nanos::MAX; n_links],
            best_full: vec![None; n_links],
        }
    }

    /// Initialize link `i` for the first batch of an epoch:
    /// `LT₀ = 1.5·RTprop + ModelSize/BtlBw` (paper §III-B1). Call with the
    /// congestion-control estimates (or path knowledge) available.
    pub fn init_link(&mut self, i: usize, rtprop: Nanos, model_bytes: u64, btlbw_bytes_per_sec: u64) {
        let transfer = if btlbw_bytes_per_sec == 0 {
            Nanos::MAX / 4
        } else {
            ((model_bytes as u128 * crate::SEC as u128) / btlbw_bytes_per_sec as u128) as Nanos
        };
        self.lt[i] = (3 * rtprop / 2).saturating_add(transfer);
    }

    /// Record a completed flow on link `i`: if it reached 100 % in
    /// `elapsed`, it is a candidate for the epoch's fastest full
    /// transmission.
    pub fn record_flow(&mut self, i: usize, elapsed: Nanos, reached_full: bool) {
        if reached_full {
            let best = self.best_full[i].get_or_insert(elapsed);
            if elapsed < *best {
                *best = elapsed;
            }
        }
    }

    /// End of epoch: LT threshold ← fastest observed full transmission
    /// (per link, where one was observed).
    pub fn end_epoch(&mut self) {
        for i in 0..self.lt.len() {
            if let Some(best) = self.best_full[i].take() {
                self.lt[i] = best;
            }
        }
    }

    /// Current LT threshold of link `i`.
    pub fn lt_threshold(&self, i: usize) -> Nanos {
        self.lt[i]
    }

    /// The shared deadline: `max(LT) + C` (paper: the deadline applies to
    /// all receiving links of one receiver at the same time).
    pub fn deadline(&self) -> Nanos {
        let max_lt = self.lt.iter().copied().max().unwrap_or(0);
        max_lt.saturating_add(self.deadline_slack)
    }

    /// Early Close config for a flow arriving on link `i`.
    pub fn cfg(&self, i: usize) -> EarlyCloseCfg {
        EarlyCloseCfg { lt_threshold: self.lt[i], deadline: self.deadline(), pct: self.pct }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::MS;

    #[test]
    fn init_formula() {
        let mut t = ThresholdTracker::new(2, 30 * MS, 0.8);
        // RTprop 2 ms, 98 MB at 1.25 GB/s (10 Gbps) → 78.4 ms transfer.
        t.init_link(0, 2 * MS, 98 * 1_000_000, 1_250_000_000);
        let lt = t.lt_threshold(0);
        assert_eq!(lt, 3 * MS + 78_400_000);
    }

    #[test]
    fn deadline_is_max_plus_slack() {
        let mut t = ThresholdTracker::new(3, 30 * MS, 0.8);
        for i in 0..3 {
            t.init_link(i, MS, 1_000_000, 125_000_000);
        }
        t.record_flow(1, 100 * MS, true);
        t.record_flow(2, 50 * MS, true);
        t.end_epoch();
        assert_eq!(t.lt_threshold(1), 100 * MS);
        assert_eq!(t.lt_threshold(2), 50 * MS);
        // link 0 saw no full transmission → keeps its init value (9.5 ms)
        assert_eq!(t.deadline(), 100 * MS + 30 * MS);
    }

    #[test]
    fn fastest_full_wins() {
        let mut t = ThresholdTracker::new(1, 30 * MS, 0.8);
        t.init_link(0, MS, 1_000_000, 125_000_000);
        t.record_flow(0, 80 * MS, true);
        t.record_flow(0, 40 * MS, true);
        t.record_flow(0, 20 * MS, false); // partial: not a candidate
        t.end_epoch();
        assert_eq!(t.lt_threshold(0), 40 * MS);
    }

    #[test]
    fn reliable_cfg_never_closes_early() {
        let cfg = EarlyCloseCfg::reliable();
        assert!(!cfg.is_loss_tolerant());
        assert_eq!(cfg.pct, 1.0);
    }
}
