//! The LTP sender state machine (paper §III-A, §III-D, §IV-B).
//!
//! Three queues order transmissions: the **Critical Queue** (CQ, reliable
//! FIFO — registration, critical segments, and re-queued lost criticals),
//! the **Normal Queue** (NQ — each normal segment exactly once), and the
//! **Retransmission Queue** (RQ — normal segments detected lost, drained
//! only after CQ and NQ are empty). Loss is detected by three out-of-order
//! ACKs against the actual transmission order; a probe timeout covers tail
//! loss. The BDP congestion controller caps packets in flight and paces
//! bursts above 20 packets. Loss never shrinks the window (§III-D).

use super::{LtpEvent, SegmentMap, CTRL_SEQ};
use crate::cc::BdpCc;
use crate::wire::{Importance, LtpHeader, LtpType};
use crate::{Nanos, MS, SEC};
use std::collections::{BTreeMap, HashMap, VecDeque};

/// Out-of-order ACK threshold for loss detection (paper: "three
/// out-of-order ACKs").
const REORDER_THRESHOLD: u64 = 3;
/// Floor for the probe timeout.
const MIN_PTO: Nanos = 1 * MS;
/// Cap on End retransmissions before the sender self-completes (covers a
/// receiver that closed and whose Stop packets were all lost).
const MAX_END_PROBES: u32 = 10;

/// Per-segment lifecycle state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SegState {
    /// Waiting in CQ/NQ/RQ.
    Queued,
    /// Exactly one transmission outstanding.
    Inflight,
    Acked,
}

#[derive(Debug, Clone, Copy)]
struct Sent {
    seg: u32,
    sent_at: Nanos,
    /// Snapshot of `delivered_bytes` when this packet left — for delivery-
    /// rate samples (BBR-style rate estimation).
    delivered_at_send: u64,
    payload_len: u32,
}

/// A packet the driver should put on the wire.
#[derive(Debug, Clone, Copy)]
pub struct OutPkt {
    pub hdr: LtpHeader,
    /// Payload bytes carried (0 for control packets). The driver combines
    /// this with the shared message buffer to build real datagrams.
    pub payload_len: u32,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct SenderStats {
    pub pkts_sent: u64,
    pub data_pkts_sent: u64,
    pub retransmissions: u64,
    pub acks_received: u64,
    pub losses_detected: u64,
    pub ptos_fired: u64,
    pub bytes_sent: u64,
    /// Set when the flow completed (Stop received or self-completed).
    pub completed_at: Option<Nanos>,
    /// Segments never acked when the flow completed (dropped by Early
    /// Close).
    pub segs_unacked_at_close: u32,
}

/// Sans-IO LTP sender for one flow.
pub struct LtpSender {
    flow: u16,
    map: SegmentMap,
    state: Vec<SegState>,
    sent_once: Vec<bool>,
    cq: VecDeque<u32>,
    nq: VecDeque<u32>,
    rq: VecDeque<u32>,
    /// Priority rank per segment (position in the scheduled NQ order),
    /// set by [`Self::set_nq_order`]. While set, lost normals re-enter
    /// the RQ in rank order instead of loss-detection order, so the
    /// retransmission pass keeps the scheduled priority too. `None` for
    /// unscheduled flows (the pre-codec behavior, byte-identical).
    rank: Option<Vec<u32>>,
    /// Registration bookkeeping (not a data segment).
    reg_acked: bool,
    reg_queued: bool,
    /// End handshake.
    end_inflight: bool,
    end_probes: u32,
    /// Outstanding transmissions by packet number (== send order).
    outstanding: BTreeMap<u64, Sent>,
    /// seg → its single outstanding packet number (CTRL_SEQ for reg/end).
    tx_of_seg: HashMap<u32, u64>,
    next_pktnum: u64,
    largest_acked_pktnum: Option<u64>,
    acked_segs: u32,
    pub cc: BdpCc,
    srtt: Nanos,
    rttvar: Nanos,
    delivered_bytes: u64,
    /// Pacing token bucket (tokens are packets).
    pace_tokens: f64,
    pace_refill_at: Nanos,
    /// PTO deadline (armed while anything is outstanding).
    pto_at: Option<Nanos>,
    started_at: Option<Nanos>,
    stop_received: bool,
    complete: bool,
    pub stats: SenderStats,
}

impl LtpSender {
    pub fn new(flow: u16, map: SegmentMap, mtu: u32) -> LtpSender {
        let n = map.n_segs as usize;
        let state = vec![SegState::Queued; n];
        let mut cq = VecDeque::new();
        let mut nq = VecDeque::with_capacity(n);
        // Registration goes first (handled out of band), then criticals in
        // CQ, then normals in NQ.
        for &c in &map.critical {
            cq.push_back(c);
        }
        for s in 0..map.n_segs {
            if !map.is_critical(s) {
                nq.push_back(s);
            }
        }
        LtpSender {
            flow,
            map,
            state,
            sent_once: vec![false; n],
            cq,
            nq,
            rq: VecDeque::new(),
            rank: None,
            reg_acked: false,
            reg_queued: true,
            end_inflight: false,
            end_probes: 0,
            outstanding: BTreeMap::new(),
            tx_of_seg: HashMap::new(),
            next_pktnum: 0,
            largest_acked_pktnum: None,
            acked_segs: 0,
            cc: BdpCc::new(mtu),
            srtt: 0,
            rttvar: 0,
            delivered_bytes: 0,
            pace_tokens: crate::cc::bdp_burst() as f64,
            pace_refill_at: 0,
            pto_at: None,
            started_at: None,
            stop_received: false,
            complete: false,
            stats: SenderStats::default(),
        }
    }

    /// Override the Normal Queue transmission order (tensor-priority
    /// scheduling, [`crate::codec::PriorityScheduler`]). Call before the
    /// first `poll_transmit`. Entries that are out of range, critical, or
    /// duplicated are ignored; normals missing from `order` are appended
    /// in ascending order so every segment still transmits exactly once.
    pub fn set_nq_order(&mut self, order: &[u32]) {
        let mut rank = vec![u32::MAX; self.map.n_segs as usize];
        self.nq.clear();
        let mut next = 0u32;
        let mut push = |nq: &mut VecDeque<u32>, rank: &mut Vec<u32>, s: u32| {
            if rank[s as usize] == u32::MAX {
                rank[s as usize] = next;
                next += 1;
                nq.push_back(s);
            }
        };
        for &s in order {
            if s < self.map.n_segs && !self.map.is_critical(s) {
                push(&mut self.nq, &mut rank, s);
            }
        }
        for s in 0..self.map.n_segs {
            if !self.map.is_critical(s) {
                push(&mut self.nq, &mut rank, s);
            }
        }
        self.rank = Some(rank);
    }

    /// Seed congestion estimates from path knowledge (previous epoch).
    pub fn seed_cc(&mut self, rtprop: Nanos, btlbw_bytes_per_sec: u64) {
        self.cc.seed(0, rtprop, btlbw_bytes_per_sec);
        // A sane initial PTO (fresh per-round flows shouldn't wait the
        // conservative 100 ms default to recover a lost registration).
        if self.srtt == 0 && rtprop > 0 {
            self.srtt = 2 * rtprop;
            self.rttvar = rtprop;
        }
    }

    pub fn flow(&self) -> u16 {
        self.flow
    }

    pub fn is_complete(&self) -> bool {
        self.complete
    }

    pub fn segment_map(&self) -> &SegmentMap {
        &self.map
    }

    /// Fraction of segments acked.
    pub fn pct_acked(&self) -> f64 {
        self.acked_segs as f64 / self.map.n_segs as f64
    }

    fn all_data_acked(&self) -> bool {
        self.acked_segs == self.map.n_segs
    }

    /// Smoothed RTT (0 until the first sample).
    pub fn srtt(&self) -> Nanos {
        self.srtt
    }

    fn pto_interval(&self) -> Nanos {
        if self.srtt == 0 {
            100 * MS // no sample yet: conservative initial PTO
        } else {
            (self.srtt + 4 * self.rttvar).max(MIN_PTO)
        }
    }

    fn update_rtt(&mut self, rtt: Nanos) {
        if self.srtt == 0 {
            self.srtt = rtt;
            self.rttvar = rtt / 2;
        } else {
            let diff = self.srtt.abs_diff(rtt);
            self.rttvar = (3 * self.rttvar + diff) / 4;
            self.srtt = (7 * self.srtt + rtt) / 8;
        }
    }

    /// Process an incoming packet (ACK or Stop).
    pub fn handle(&mut self, now: Nanos, ev: LtpEvent) {
        if self.complete {
            return;
        }
        match ev.hdr.ty {
            LtpType::Ack => self.on_ack(now, ev.hdr.seq),
            LtpType::End => {
                // Receiver's Stop broadcast: flow is over; drop everything.
                self.stop_received = true;
                self.finish(now);
            }
            _ => {} // senders ignore stray data/registration
        }
    }

    fn finish(&mut self, now: Nanos) {
        if self.complete {
            return;
        }
        self.complete = true;
        self.stats.completed_at = Some(now);
        self.stats.segs_unacked_at_close = self.map.n_segs - self.acked_segs;
        self.cq.clear();
        self.nq.clear();
        self.rq.clear();
        self.outstanding.clear();
        self.tx_of_seg.clear();
        self.pto_at = None;
    }

    fn on_ack(&mut self, now: Nanos, seq: u32) {
        self.stats.acks_received += 1;
        let is_ctrl = seq == CTRL_SEQ;
        // Mark acked.
        if is_ctrl {
            if self.end_inflight {
                // ACK of End — receiver saw it; completion comes via Stop,
                // but an acked End with everything delivered is also final.
                self.end_inflight = false;
            }
            self.reg_acked = true;
        } else {
            let seg = seq as usize;
            if seg >= self.state.len() || self.state[seg] == SegState::Acked {
                // Duplicate ACK for an already-acked segment.
                return;
            }
            self.delivered_bytes += self.map.payload_len(seq) as u64;
            self.state[seg] = SegState::Acked;
            self.acked_segs += 1;
        }
        // Attribute to the outstanding transmission, if any.
        if let Some(pktnum) = self.tx_of_seg.remove(&seq) {
            if let Some(sent) = self.outstanding.remove(&pktnum) {
                let rtt = now.saturating_sub(sent.sent_at).max(1);
                self.update_rtt(rtt);
                let dt = now.saturating_sub(sent.sent_at).max(1);
                let dbytes = self.delivered_bytes.saturating_sub(sent.delivered_at_send);
                let rate_bps = (dbytes as u128 * 8 * SEC as u128 / dt as u128) as u64;
                self.cc.on_ack(now, rtt, if dbytes > 0 { Some(rate_bps) } else { None });
                self.largest_acked_pktnum =
                    Some(self.largest_acked_pktnum.map_or(pktnum, |l| l.max(pktnum)));
            }
        }
        self.detect_losses();
        self.rearm_pto(now);
        // All data delivered?
        if self.all_data_acked() && self.reg_acked && self.outstanding.is_empty() && !self.end_inflight
        {
            // Everything acked; End will be offered by poll_transmit.
        }
    }

    /// Three-out-of-order-ACK loss detection against the actual send order.
    fn detect_losses(&mut self) {
        let Some(largest) = self.largest_acked_pktnum else { return };
        let mut lost = Vec::new();
        for (&pktnum, sent) in self.outstanding.iter() {
            if pktnum + REORDER_THRESHOLD <= largest {
                lost.push((pktnum, *sent));
            } else {
                break; // BTreeMap iterates in pktnum order
            }
        }
        for (pktnum, sent) in lost {
            self.outstanding.remove(&pktnum);
            self.tx_of_seg.remove(&sent.seg);
            self.stats.losses_detected += 1;
            self.requeue_lost(sent.seg);
        }
    }

    fn requeue_lost(&mut self, seg: u32) {
        if seg == CTRL_SEQ {
            // Registration or End lost.
            if !self.reg_acked {
                self.reg_queued = true;
            }
            // A lost End is re-offered by poll_transmit (end_inflight
            // cleared).
            self.end_inflight = false;
            return;
        }
        let s = seg as usize;
        if self.state[s] == SegState::Acked {
            return;
        }
        self.state[s] = SegState::Queued;
        if self.map.is_critical(seg) {
            // Lost criticals return to the CQ (paper Fig 11a).
            self.cq.push_back(seg);
        } else {
            // Lost normals go to the RQ, drained after CQ and NQ
            // (paper Fig 11b) — in scheduled-priority order when a
            // priority order was set, in loss-detection order otherwise.
            match &self.rank {
                Some(rank) => {
                    let r = rank[s];
                    let at = self.rq.partition_point(|&q| rank[q as usize] <= r);
                    self.rq.insert(at, seg);
                }
                None => self.rq.push_back(seg),
            }
        }
    }

    fn rearm_pto(&mut self, now: Nanos) {
        self.pto_at = if self.outstanding.is_empty() && !self.end_inflight {
            None
        } else {
            Some(now + self.pto_interval())
        };
    }

    /// Probe timeout: declare everything outstanding lost and requeue.
    /// (Covers tail loss, where no later ACKs can trigger the
    /// three-out-of-order rule.)
    fn fire_pto(&mut self, now: Nanos) {
        self.stats.ptos_fired += 1;
        let all: Vec<(u64, Sent)> = self.outstanding.iter().map(|(&k, &v)| (k, v)).collect();
        for (pktnum, sent) in all {
            self.outstanding.remove(&pktnum);
            self.tx_of_seg.remove(&sent.seg);
            self.requeue_lost(sent.seg);
        }
        if self.end_inflight {
            self.end_inflight = false;
        }
        // LTP does *not* touch the congestion window on loss (§III-D).
        self.rearm_pto(now);
    }

    /// Deadline the driver must call [`Self::on_wakeup`] at (if any):
    /// pacing release or PTO, whichever is sooner.
    pub fn next_wakeup(&self) -> Option<Nanos> {
        if self.complete {
            return None;
        }
        let pace = if self.pace_tokens < 1.0 && self.has_work() {
            self.next_token_at()
        } else {
            None
        };
        match (pace, self.pto_at) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Called by the driver when `next_wakeup` expires.
    pub fn on_wakeup(&mut self, now: Nanos) {
        if let Some(pto) = self.pto_at {
            if now >= pto {
                self.fire_pto(now);
            }
        }
        // Pacing tokens refill lazily in poll_transmit.
    }

    fn has_work(&self) -> bool {
        self.reg_queued
            || !self.cq.is_empty()
            || !self.nq.is_empty()
            || !self.rq.is_empty()
            || (self.all_data_acked() && self.reg_acked && !self.end_inflight)
    }

    fn next_token_at(&self) -> Option<Nanos> {
        let rate_bps = self.cc.pacing_rate_bps()?;
        if rate_bps == 0 {
            return None;
        }
        let need = 1.0 - self.pace_tokens;
        let ns_per_pkt = (crate::wire::MTU as f64 * 8.0 * SEC as f64) / rate_bps as f64;
        Some(self.pace_refill_at + (need * ns_per_pkt) as Nanos)
    }

    fn refill_tokens(&mut self, now: Nanos) {
        let Some(rate_bps) = self.cc.pacing_rate_bps() else {
            // No estimate yet: window-limited only.
            self.pace_tokens = crate::cc::bdp_burst() as f64;
            self.pace_refill_at = now;
            return;
        };
        let dt = now.saturating_sub(self.pace_refill_at);
        let pkts = (rate_bps as f64 / 8.0 / crate::wire::MTU as f64) * (dt as f64 / SEC as f64);
        self.pace_tokens = (self.pace_tokens + pkts).min(crate::cc::bdp_burst() as f64);
        self.pace_refill_at = now;
    }

    /// Next queued segment, skipping entries acked in the meantime.
    fn pop_next_seg(&mut self) -> Option<u32> {
        loop {
            let seg = self
                .cq
                .pop_front()
                .or_else(|| self.nq.pop_front())
                .or_else(|| self.rq.pop_front())?;
            if self.state[seg as usize] == SegState::Queued {
                return Some(seg);
            }
            // Acked while queued (e.g. spurious retransmit) — skip.
        }
    }

    /// Pull the next packet to put on the wire, if congestion control,
    /// pacing, and the queues allow one.
    pub fn poll_transmit(&mut self, now: Nanos) -> Option<OutPkt> {
        if self.complete {
            return None;
        }
        if self.started_at.is_none() {
            self.started_at = Some(now);
        }
        // Window check.
        if self.outstanding.len() as u64 >= self.cc.inflight_cap_pkts() {
            return None;
        }
        // Pacing check (paper: bursts > 20 packets wait on the pacing rate).
        self.refill_tokens(now);
        if self.pace_tokens < 1.0 {
            return None;
        }

        // 1. Registration first.
        if self.reg_queued {
            self.reg_queued = false;
            let hdr = self.stamp(LtpHeader::registration(self.flow, self.map.n_segs));
            self.record_tx(now, CTRL_SEQ, 4);
            return Some(OutPkt { hdr, payload_len: 4 });
        }
        // 2. Data: CQ → NQ → RQ.
        if let Some(seg) = self.pop_next_seg() {
            let payload = self.map.payload_len(seg);
            let importance =
                if self.map.is_critical(seg) { Importance::Critical } else { Importance::Normal };
            if self.sent_once[seg as usize] {
                self.stats.retransmissions += 1;
            } else {
                self.sent_once[seg as usize] = true;
            }
            self.state[seg as usize] = SegState::Inflight;
            let hdr = self.stamp(LtpHeader::data(self.flow, seg, importance));
            self.record_tx(now, seg, payload);
            self.stats.data_pkts_sent += 1;
            return Some(OutPkt { hdr, payload_len: payload });
        }
        // 3. End probe once everything is acked.
        if self.all_data_acked() && self.reg_acked && !self.end_inflight {
            if self.end_probes >= MAX_END_PROBES {
                // Receiver unreachable for the epilogue; everything was
                // acked, so the flow is done.
                self.finish(now);
                return None;
            }
            self.end_probes += 1;
            self.end_inflight = true;
            let hdr = self.stamp(LtpHeader::end(self.flow));
            self.record_tx(now, CTRL_SEQ, 0);
            return Some(OutPkt { hdr, payload_len: 0 });
        }
        None
    }

    /// Stamp congestion-control telemetry into an outgoing header
    /// (paper §IV-A: LTP sends RTprop/BtlBw to the receiver).
    fn stamp(&self, mut hdr: LtpHeader) -> LtpHeader {
        hdr.rtprop_us = (self.cc.rtprop_ns() / crate::US) as u32;
        hdr.btlbw_mbps = (self.cc.btlbw_bytes_per_sec() * 8 / 1_000_000) as u32;
        hdr
    }

    fn record_tx(&mut self, now: Nanos, seg: u32, payload_len: u32) {
        let pktnum = self.next_pktnum;
        self.next_pktnum += 1;
        // Replace any stale transmission record for this seg.
        if let Some(old) = self.tx_of_seg.insert(seg, pktnum) {
            self.outstanding.remove(&old);
        }
        self.outstanding.insert(
            pktnum,
            Sent { seg, sent_at: now, delivered_at_send: self.delivered_bytes, payload_len },
        );
        self.pace_tokens -= 1.0;
        self.stats.pkts_sent += 1;
        self.stats.bytes_sent +=
            (payload_len + crate::wire::UDP_IP_OVERHEAD + crate::wire::HDR_BYTES as u32) as u64;
        self.rearm_pto(now);
    }

    /// Count of packets currently in flight.
    pub fn inflight(&self) -> usize {
        self.outstanding.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::LTP_MSS;

    fn mk_sender(bytes: u64, critical: Vec<u32>) -> LtpSender {
        let map = SegmentMap::new(bytes, LTP_MSS, critical);
        let mut s = LtpSender::new(1, map, crate::wire::MTU);
        s.seed_cc(MS, 125_000_000); // 1 Gbps, 1 ms
        s
    }

    fn ack(seq: u32) -> LtpEvent {
        LtpEvent { hdr: LtpHeader::ack(1, seq), payload_len: 0 }
    }

    #[test]
    fn registration_goes_first_then_criticals() {
        let mut s = mk_sender(LTP_MSS as u64 * 10, vec![3, 7]);
        let p0 = s.poll_transmit(0).unwrap();
        assert_eq!(p0.hdr.ty, LtpType::Registration);
        assert_eq!(p0.hdr.seq, 10); // total segs rides in seq
        let p1 = s.poll_transmit(1).unwrap();
        assert_eq!(p1.hdr.ty, LtpType::Data);
        assert_eq!(p1.hdr.seq, 3);
        assert_eq!(p1.hdr.importance, Importance::Critical);
        let p2 = s.poll_transmit(2).unwrap();
        assert_eq!(p2.hdr.seq, 7);
        let p3 = s.poll_transmit(3).unwrap();
        assert_eq!(p3.hdr.seq, 0); // first normal
        assert_eq!(p3.hdr.importance, Importance::Normal);
    }

    #[test]
    fn nq_order_overrides_normal_transmission_order() {
        let mut s = mk_sender(LTP_MSS as u64 * 6, vec![0]);
        // 0 is critical, 4 is duplicated, 99 is out of range — all ignored;
        // missing normals (1, 2) append in ascending order.
        s.set_nq_order(&[5, 0, 4, 4, 99, 3]);
        let mut order = vec![];
        let mut now = 0;
        loop {
            s.refill_tokens(now);
            match s.poll_transmit(now) {
                Some(p) if p.hdr.ty == LtpType::Data => order.push(p.hdr.seq),
                Some(_) => {}
                None => break,
            }
            now += 1000;
        }
        assert_eq!(order, vec![0, 5, 4, 3, 1, 2]);
    }

    #[test]
    fn scheduled_flows_retransmit_in_priority_order() {
        let mut s = mk_sender(LTP_MSS as u64 * 6, vec![]);
        s.set_nq_order(&[5, 4, 3, 2, 1, 0]);
        let mut now = 0;
        loop {
            s.refill_tokens(now);
            if s.poll_transmit(now).is_none() {
                break;
            }
            now += 1000;
        }
        // pktnums: reg=0, then segs 5,4,3,2,1,0. Acking reg + segs 2,1,0
        // (pktnums 4,5,6) puts pktnums 1..3 three behind → segs 5,4,3 lost.
        s.handle(now, ack(CTRL_SEQ));
        for q in [2, 1, 0] {
            s.handle(now + q as u64 + 1, ack(q));
        }
        assert_eq!(s.stats.losses_detected, 3);
        let mut resent = vec![];
        let mut t = now + 100;
        loop {
            s.refill_tokens(t);
            match s.poll_transmit(t) {
                Some(p) if p.hdr.ty == LtpType::Data => resent.push(p.hdr.seq),
                Some(_) => {}
                None => break,
            }
            t += 1000;
        }
        // The RQ drains highest-priority first, not loss-detection order.
        assert_eq!(resent, vec![5, 4, 3]);
        assert_eq!(s.stats.retransmissions, 3);
    }

    #[test]
    fn window_caps_inflight() {
        let mut s = mk_sender(LTP_MSS as u64 * 10_000, vec![]);
        let cap = s.cc.inflight_cap_pkts();
        let mut sent = 0;
        while s.poll_transmit(0).is_some() {
            sent += 1;
            assert!(sent <= 10_000);
        }
        // Pacing burst or window, whichever is smaller, stops the loop.
        assert!(sent as u64 <= cap.max(1));
        assert!(sent > 0);
    }

    #[test]
    fn three_out_of_order_acks_detect_loss() {
        let mut s = mk_sender(LTP_MSS as u64 * 8, vec![]);
        // Send reg + all 8 segments.
        let mut pkts = vec![];
        let mut now = 0;
        loop {
            s.refill_tokens(now);
            match s.poll_transmit(now) {
                Some(p) => pkts.push(p),
                None => break,
            }
            now += 10_000;
        }
        assert!(pkts.len() >= 9);
        // ACK registration, then segments 1,2,3 — seg 0 (pktnum 1) becomes
        // 3 behind the largest acked pktnum (4) → lost.
        s.handle(now, ack(CTRL_SEQ));
        s.handle(now + 1, ack(1));
        s.handle(now + 2, ack(2));
        s.handle(now + 3, ack(3));
        assert_eq!(s.stats.losses_detected, 1);
        // Lost normal seg goes to RQ and is retransmitted after NQ drains.
        let mut seen0 = false;
        let mut t = now + 10;
        for _ in 0..100 {
            s.refill_tokens(t);
            if let Some(p) = s.poll_transmit(t) {
                if p.hdr.ty == LtpType::Data && p.hdr.seq == 0 {
                    seen0 = true;
                }
            }
            t += 10_000;
        }
        assert!(seen0, "lost segment 0 must be retransmitted via RQ");
    }

    #[test]
    fn lost_critical_returns_to_cq_before_rq() {
        let mut s = mk_sender(LTP_MSS as u64 * 6, vec![0]);
        let mut now = 0;
        // Drain: reg, crit 0, normals 1..5.
        let mut order = vec![];
        loop {
            s.refill_tokens(now);
            match s.poll_transmit(now) {
                Some(p) => order.push((p.hdr.ty, p.hdr.seq)),
                None => break,
            }
            now += 1000;
        }
        // Lose seg 0 (critical, pktnum 1) and seg 1 (normal, pktnum 2) via
        // OOO acks on 2,3,4,5.
        s.handle(now, ack(CTRL_SEQ));
        for q in [2, 3, 4, 5] {
            s.handle(now + q as u64, ack(q));
        }
        assert_eq!(s.stats.losses_detected, 2);
        // Next transmissions: critical 0 (from CQ) then normal 1 (RQ).
        s.refill_tokens(now + 100);
        let a = s.poll_transmit(now + 100).unwrap();
        assert_eq!((a.hdr.seq, a.hdr.importance), (0, Importance::Critical));
        let b = s.poll_transmit(now + 200).unwrap();
        assert_eq!((b.hdr.seq, b.hdr.importance), (1, Importance::Normal));
    }

    #[test]
    fn pto_requeues_tail_loss() {
        let mut s = mk_sender(LTP_MSS as u64 * 3, vec![]);
        let mut now = 0;
        while s.poll_transmit(now).is_some() {
            now += 1000;
        }
        let wake = s.next_wakeup().expect("PTO armed");
        s.on_wakeup(wake);
        assert_eq!(s.stats.ptos_fired, 1);
        assert_eq!(s.inflight(), 0);
        // Everything requeued: reg + 3 segs come out again.
        let mut resent = 0;
        let mut t = wake;
        while let Some(_p) = s.poll_transmit(t) {
            resent += 1;
            t += 1000;
        }
        assert_eq!(resent, 4);
    }

    #[test]
    fn stop_completes_and_clears() {
        let mut s = mk_sender(LTP_MSS as u64 * 100, vec![]);
        let mut now = 0;
        for _ in 0..20 {
            s.refill_tokens(now);
            let _ = s.poll_transmit(now);
            now += 1000;
        }
        s.handle(now, LtpEvent { hdr: LtpHeader::end(1), payload_len: 0 });
        assert!(s.is_complete());
        assert!(s.poll_transmit(now + 1).is_none());
        assert!(s.stats.segs_unacked_at_close > 0);
        assert!(s.next_wakeup().is_none());
    }

    #[test]
    fn full_ack_sequence_leads_to_end(){
        let mut s = mk_sender(LTP_MSS as u64 * 5, vec![]);
        let mut now = 0;
        let mut outgoing = vec![];
        loop {
            s.refill_tokens(now);
            match s.poll_transmit(now) {
                Some(p) => outgoing.push(p),
                None => break,
            }
            now += 1000;
        }
        // ACK everything.
        s.handle(now, ack(CTRL_SEQ));
        for i in 0..5 {
            s.handle(now + i as u64 + 1, ack(i));
        }
        assert!(s.pct_acked() == 1.0);
        // Next poll offers the End packet.
        s.refill_tokens(now + 10);
        let end = s.poll_transmit(now + 10).unwrap();
        assert_eq!(end.hdr.ty, LtpType::End);
        // Stop arrives → complete with zero unacked.
        s.handle(now + 20, LtpEvent { hdr: LtpHeader::end(1), payload_len: 0 });
        assert!(s.is_complete());
        assert_eq!(s.stats.segs_unacked_at_close, 0);
    }

    #[test]
    fn headers_carry_cc_telemetry() {
        let mut s = mk_sender(LTP_MSS as u64 * 2, vec![]);
        let p = s.poll_transmit(0).unwrap();
        assert!(p.hdr.rtprop_us > 0);
        assert!(p.hdr.btlbw_mbps > 0);
    }
}
