//! The LTP receiver state machine (paper §III-A, §III-B).
//!
//! Per-packet out-of-order ACKs, an arrival bitmap over the flow's
//! segments, and the Early Close double threshold: wait for 100 % before
//! the LT threshold; close at `pct` received between LT threshold and
//! deadline; close unconditionally at the deadline. On close the receiver
//! broadcasts a `Stop` so the sender abandons retransmission.

use super::{EarlyCloseCfg, LtpEvent, CTRL_SEQ};
use crate::util::Bitmap;
use crate::wire::{LtpHeader, LtpType};
use crate::Nanos;
use std::collections::VecDeque;

/// Why a flow closed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CloseReason {
    /// 100 % of segments (and all expected criticals) arrived.
    Complete,
    /// Early Close: `pct` reached between LT threshold and deadline.
    EarlyPct,
    /// Deadline exceeded — closed with whatever arrived.
    Deadline,
}

#[derive(Debug, Clone, Default)]
pub struct ReceiverStats {
    pub data_pkts: u64,
    pub dup_pkts: u64,
    pub acks_sent: u64,
    pub stops_sent: u64,
    /// Time from first packet to close.
    pub elapsed: Nanos,
    pub pct_at_close: f64,
    pub reason: Option<CloseReason>,
    pub criticals_ok: bool,
}

/// How many duplicate Stop packets are emitted on close (Stop itself rides
/// an unreliable datagram).
const STOP_REDUNDANCY: u32 = 3;


/// Sans-IO LTP receiver for one flow.
pub struct LtpReceiver {
    flow: u16,
    cfg: EarlyCloseCfg,
    /// Segment ids the application knows must arrive (from the shared
    /// tensor manifest — both ends of a DML flow know the model layout).
    expected_critical: Vec<u32>,
    t0: Option<Nanos>,
    total_segs: Option<u32>,
    received: Bitmap,
    critical_got: usize,
    closed: Option<CloseReason>,
    outgoing: VecDeque<LtpHeader>,
    pub stats: ReceiverStats,
}

impl LtpReceiver {
    pub fn new(flow: u16, cfg: EarlyCloseCfg, mut expected_critical: Vec<u32>) -> LtpReceiver {
        expected_critical.sort_unstable();
        expected_critical.dedup();
        LtpReceiver {
            flow,
            cfg,
            expected_critical,
            t0: None,
            total_segs: None,
            received: Bitmap::new(0),
            critical_got: 0,
            closed: None,
            outgoing: VecDeque::new(),
            stats: ReceiverStats::default(),
        }
    }

    pub fn flow(&self) -> u16 {
        self.flow
    }

    pub fn is_closed(&self) -> bool {
        self.closed.is_some()
    }

    pub fn close_reason(&self) -> Option<CloseReason> {
        self.closed
    }

    /// Arrival bitmap (index = segment id). Missing bits are the bubbles.
    pub fn received_bitmap(&self) -> &Bitmap {
        &self.received
    }

    pub fn total_segs(&self) -> Option<u32> {
        self.total_segs
    }

    /// Fraction of data segments received (0 until registration arrives).
    pub fn pct_received(&self) -> f64 {
        match self.total_segs {
            Some(n) if n > 0 => self.received.count_ones() as f64 / n as f64,
            _ => 0.0,
        }
    }

    fn criticals_ok(&self) -> bool {
        self.critical_got == self.expected_critical.len()
    }

    /// Process one incoming packet.
    pub fn handle(&mut self, now: Nanos, ev: LtpEvent) {
        let t0 = *self.t0.get_or_insert(now);
        let _ = t0;
        match ev.hdr.ty {
            LtpType::Registration => {
                let n = ev.hdr.seq; // total segment count rides in seq
                if self.total_segs.is_none() {
                    self.total_segs = Some(n);
                    self.received.grow(n as usize);
                }
                self.push_ack(CTRL_SEQ);
            }
            LtpType::Data => {
                let seg = ev.hdr.seq;
                self.stats.data_pkts += 1;
                if self.closed.is_some() {
                    // Late data after close: remind the sender to stop.
                    // Never capped — under bursty loss every Stop of a batch
                    // can vanish, and a silent receiver would strand the
                    // sender in a retransmission loop (each late data packet
                    // triggers at most one Stop, so this stays paced).
                    self.push_stop();
                    return;
                }
                self.received.grow(seg as usize + 1);
                if self.received.set(seg as usize) {
                    if self.expected_critical.binary_search(&seg).is_ok() {
                        self.critical_got += 1;
                    }
                } else {
                    self.stats.dup_pkts += 1;
                }
                // Per-packet ACK, duplicates included (the sender may have
                // lost the first ACK).
                self.push_ack(seg);
            }
            LtpType::End => {
                // Sender believes everything is delivered. If our bitmap
                // agrees (it must, for the End to have been sent), close.
                if self.closed.is_none() {
                    self.do_close(now, CloseReason::Complete);
                } else {
                    self.push_stop();
                }
            }
            LtpType::Ack => {} // receivers ignore stray ACKs
        }
        self.evaluate_close(now);
    }

    /// Timer callback: Early Close threshold checks.
    pub fn on_wakeup(&mut self, now: Nanos) {
        self.evaluate_close(now);
    }

    /// The next *future* instant at which a close decision could change:
    /// the LT threshold, then the deadline (relative to flow start).
    pub fn next_wakeup(&self, now: Nanos) -> Option<Nanos> {
        if self.closed.is_some() || !self.cfg.is_loss_tolerant() {
            return None;
        }
        let t0 = self.t0?;
        let lt = t0.saturating_add(self.cfg.lt_threshold);
        let dl = t0.saturating_add(self.cfg.deadline);
        if now < lt {
            Some(lt)
        } else if now < dl {
            Some(dl)
        } else {
            None
        }
    }

    fn evaluate_close(&mut self, now: Nanos) {
        if self.closed.is_some() {
            return;
        }
        let Some(t0) = self.t0 else { return };
        // 100 % complete closes at any time.
        if let Some(n) = self.total_segs {
            if self.received.count_ones() as u32 == n && self.criticals_ok() {
                self.do_close(now, CloseReason::Complete);
                return;
            }
        }
        if !self.cfg.is_loss_tolerant() {
            return;
        }
        let elapsed = now - t0;
        if elapsed >= self.cfg.deadline {
            // Paper: "after the deadline, the receiver stops receiving data
            // immediately no matter how much data is received".
            self.do_close(now, CloseReason::Deadline);
            return;
        }
        if elapsed >= self.cfg.lt_threshold
            && self.total_segs.is_some()
            && self.pct_received() >= self.cfg.pct
            && self.criticals_ok()
        {
            self.do_close(now, CloseReason::EarlyPct);
        }
    }

    fn do_close(&mut self, now: Nanos, reason: CloseReason) {
        self.closed = Some(reason);
        self.stats.reason = Some(reason);
        self.stats.elapsed = now - self.t0.unwrap_or(now);
        self.stats.pct_at_close = self.pct_received();
        self.stats.criticals_ok = self.criticals_ok();
        for _ in 0..STOP_REDUNDANCY {
            self.push_stop();
        }
    }

    fn push_ack(&mut self, seq: u32) {
        self.stats.acks_sent += 1;
        self.outgoing.push_back(LtpHeader::ack(self.flow, seq));
    }

    fn push_stop(&mut self) {
        self.stats.stops_sent += 1;
        self.outgoing.push_back(LtpHeader::end(self.flow));
    }

    /// Drain the next outgoing control packet (ACK or Stop).
    pub fn poll_transmit(&mut self) -> Option<LtpHeader> {
        self.outgoing.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::Importance;
    use crate::{MS, SEC};

    fn data(seq: u32) -> LtpEvent {
        LtpEvent { hdr: LtpHeader::data(1, seq, Importance::Normal), payload_len: 1463 }
    }

    fn reg(n: u32) -> LtpEvent {
        LtpEvent { hdr: LtpHeader::registration(1, n), payload_len: 4 }
    }

    fn lt_cfg() -> EarlyCloseCfg {
        EarlyCloseCfg { lt_threshold: 100 * MS, deadline: 200 * MS, pct: 0.8 }
    }

    fn drain(r: &mut LtpReceiver) -> Vec<LtpHeader> {
        std::iter::from_fn(|| r.poll_transmit()).collect()
    }

    #[test]
    fn acks_every_packet_including_dups() {
        let mut r = LtpReceiver::new(1, lt_cfg(), vec![]);
        r.handle(0, reg(10));
        r.handle(1, data(3));
        r.handle(2, data(3));
        let out = drain(&mut r);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].seq, CTRL_SEQ);
        assert_eq!(out[1].seq, 3);
        assert_eq!(out[2].seq, 3);
        assert_eq!(r.stats.dup_pkts, 1);
    }

    #[test]
    fn closes_complete_at_100pct() {
        let mut r = LtpReceiver::new(1, lt_cfg(), vec![]);
        r.handle(0, reg(3));
        for s in 0..3 {
            r.handle(s as u64 + 1, data(s));
        }
        assert_eq!(r.close_reason(), Some(CloseReason::Complete));
        let stops = drain(&mut r).iter().filter(|h| h.ty == LtpType::End).count();
        assert_eq!(stops, 3); // STOP_REDUNDANCY
    }

    #[test]
    fn waits_for_100pct_before_lt_threshold() {
        let mut r = LtpReceiver::new(1, lt_cfg(), vec![]);
        r.handle(0, reg(10));
        for s in 0..9 {
            r.handle(s as u64 + 1, data(s)); // 90 % received
        }
        r.on_wakeup(50 * MS); // before LT threshold
        assert!(!r.is_closed(), "must wait for 100% before the LT threshold");
    }

    #[test]
    fn early_close_between_thresholds_when_pct_met() {
        let mut r = LtpReceiver::new(1, lt_cfg(), vec![]);
        r.handle(0, reg(10));
        for s in 0..9 {
            r.handle(s as u64 + 1, data(s));
        }
        r.on_wakeup(150 * MS); // between LT (100 ms) and deadline (200 ms)
        assert_eq!(r.close_reason(), Some(CloseReason::EarlyPct));
        assert!((r.stats.pct_at_close - 0.9).abs() < 1e-9);
    }

    #[test]
    fn no_early_close_below_pct() {
        let mut r = LtpReceiver::new(1, lt_cfg(), vec![]);
        r.handle(0, reg(10));
        for s in 0..7 {
            r.handle(s as u64 + 1, data(s)); // 70 % < 80 %
        }
        r.on_wakeup(150 * MS);
        assert!(!r.is_closed());
    }

    #[test]
    fn deadline_closes_unconditionally() {
        let mut r = LtpReceiver::new(1, lt_cfg(), vec![]);
        r.handle(0, reg(10));
        r.handle(1, data(0)); // 10 %
        r.on_wakeup(200 * MS);
        assert_eq!(r.close_reason(), Some(CloseReason::Deadline));
    }

    #[test]
    fn missing_critical_blocks_early_close_but_not_deadline() {
        // Criticals 0 and 5 expected; 5 never arrives.
        let mut r = LtpReceiver::new(1, lt_cfg(), vec![0, 5]);
        r.handle(0, reg(10));
        for s in 0..10 {
            if s != 5 {
                r.handle(s as u64 + 1, data(s));
            }
        }
        r.on_wakeup(150 * MS);
        assert!(!r.is_closed(), "90% but a critical is missing: no early close");
        r.handle(160 * MS, data(5));
        assert_eq!(r.close_reason(), Some(CloseReason::Complete));
        assert!(r.stats.criticals_ok);
    }

    #[test]
    fn reliable_cfg_only_closes_at_full() {
        let mut r = LtpReceiver::new(1, EarlyCloseCfg::reliable(), vec![]);
        r.handle(0, reg(4));
        for s in 0..3 {
            r.handle(s as u64 + 1, data(s));
        }
        r.on_wakeup(10 * SEC);
        assert!(!r.is_closed());
        r.handle(11 * SEC, data(3));
        assert_eq!(r.close_reason(), Some(CloseReason::Complete));
        assert!(r.next_wakeup(11 * SEC).is_none());
    }

    #[test]
    fn late_data_after_close_triggers_stop() {
        let mut r = LtpReceiver::new(1, lt_cfg(), vec![]);
        r.handle(0, reg(2));
        r.handle(1, data(0));
        r.handle(2, data(1));
        assert!(r.is_closed());
        drain(&mut r);
        r.handle(3, data(0));
        let out = drain(&mut r);
        assert!(out.iter().any(|h| h.ty == LtpType::End));
    }

    #[test]
    fn bitmap_exposes_missing_segments() {
        let mut r = LtpReceiver::new(1, lt_cfg(), vec![]);
        r.handle(0, reg(5));
        r.handle(1, data(0));
        r.handle(2, data(2));
        r.handle(3, data(4));
        let missing: Vec<usize> = r.received_bitmap().iter_zeros().collect();
        assert_eq!(missing, vec![1, 3]);
    }

    #[test]
    fn wakeup_schedule_covers_thresholds() {
        let mut r = LtpReceiver::new(1, lt_cfg(), vec![]);
        assert!(r.next_wakeup(0).is_none(), "no wakeup before the flow starts");
        r.handle(10 * MS, reg(10));
        assert_eq!(r.next_wakeup(20 * MS), Some(10 * MS + 100 * MS));
        // Past the LT threshold: the next decision point is the deadline.
        assert_eq!(r.next_wakeup(150 * MS), Some(10 * MS + 200 * MS));
        // Past the deadline: nothing left to wake for.
        assert_eq!(r.next_wakeup(300 * MS), None);
    }

    #[test]
    fn data_before_registration_is_buffered() {
        let mut r = LtpReceiver::new(1, lt_cfg(), vec![]);
        r.handle(0, data(7)); // registration lost/late
        assert_eq!(r.pct_received(), 0.0); // unknown total
        r.handle(1, reg(10));
        assert!((r.pct_received() - 0.1).abs() < 1e-9);
        assert!(r.received_bitmap().get(7));
    }
}
