//! Configuration: the model manifest emitted by the AOT step (the contract
//! between `python/compile/model.py` and the Rust runtime), plus the
//! experiment presets used by the CLI and the figure runners.

use crate::grad::Manifest;
use crate::simnet::{LinkCfg, LossModel};
use crate::Nanos;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Parsed `artifacts/manifest_<preset>.txt`.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub preset: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub param_count: usize,
    pub padded_dim: usize,
    pub agg_workers: usize,
    pub tile_d: usize,
    pub tensors: Manifest,
}

impl ModelManifest {
    pub fn load(dir: impl AsRef<Path>, preset: &str) -> Result<ModelManifest> {
        let path = dir.as_ref().join(format!("manifest_{preset}.txt"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(preset, &text)
    }

    pub fn parse(preset: &str, text: &str) -> Result<ModelManifest> {
        let mut kv = std::collections::HashMap::new();
        let mut tensors: Vec<(String, usize)> = Vec::new();
        let mut in_tensors = false;
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line == "tensors:" {
                if in_tensors {
                    bail!("manifest line {lineno}: duplicate `tensors:` section");
                }
                in_tensors = true;
                continue;
            }
            let (key, val) = line.rsplit_once(' ').with_context(|| {
                format!("manifest line {lineno}: malformed line `{line}` (expected `<key> <value>`)")
            })?;
            let key = key.trim_end();
            let val: usize = val
                .parse()
                .with_context(|| format!("manifest line {lineno}: bad value in `{line}`"))?;
            if in_tensors {
                if tensors.iter().any(|(name, _)| name == key) {
                    bail!("manifest line {lineno}: duplicate tensor `{key}`");
                }
                tensors.push((key.to_string(), val));
            } else if kv.insert(key.to_string(), val).is_some() {
                bail!("manifest line {lineno}: duplicate key `{key}`");
            }
        }
        let get = |k: &str| -> Result<usize> {
            kv.get(k).copied().with_context(|| format!("manifest missing `{k}`"))
        };
        let m = ModelManifest {
            preset: preset.to_string(),
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            seq_len: get("seq_len")?,
            batch: get("batch")?,
            param_count: get("param_count")?,
            padded_dim: get("padded_dim")?,
            agg_workers: get("agg_workers")?,
            tile_d: get("tile_d")?,
            tensors: Manifest {
                tensors: tensors
                    .into_iter()
                    .map(|(name, numel)| crate::grad::TensorSpec { name, numel })
                    .collect(),
            },
        };
        if m.tensors.total_elems() != m.param_count {
            bail!(
                "manifest tensors sum to {} but param_count is {}",
                m.tensors.total_elems(),
                m.param_count
            );
        }
        Ok(m)
    }

    /// Gradient bytes on the wire per worker per iteration (padded flat
    /// vector).
    pub fn wire_bytes(&self) -> u64 {
        self.padded_dim as u64 * 4
    }
}

/// Network environment presets used throughout the evaluation (paper §V)
/// and by the scenario engine's registry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetEnv {
    /// In-rack DCN: 10 Gbps, ~1 ms RTT class. (Paper Fig 4 row 2.)
    Dcn10g,
    /// 1 Gbps / 40 ms WAN class. (Paper Fig 4 row 1.)
    Wan1g,
    /// The WAN class with bursty Gilbert–Elliott loss baked in (federated /
    /// edge training conditions; scenario `wan_bursty`).
    WanBursty,
    /// The testbed rack: 10 Gbps edge links behind one ToR.
    Rack,
}

impl NetEnv {
    /// Edge-link configuration for this environment.
    pub fn link(self) -> LinkCfg {
        match self {
            // 10 Gbps, 0.5 ms one-way → ~1 ms RTT.
            NetEnv::Dcn10g => LinkCfg::dcn(10, 500),
            // 1 Gbps, 20 ms one-way → 40 ms RTT; WAN-deep buffer.
            NetEnv::Wan1g => LinkCfg {
                rate_bps: 1_000_000_000,
                delay: 20 * crate::MS,
                queue_cap_bytes: 4 * 1024 * 1024,
                ecn_thresh_bytes: None,
                loss: LossModel::None,
            },
            NetEnv::WanBursty => NetEnv::Wan1g.link().with_loss(Self::bursty_loss()),
            // Testbed: 10 Gbps edge, ~0.6 ms kernel-stack RTT (the paper's
            // Fig 3 FCTs imply software RTTs well above the wire's);
            // 1 MiB switch buffer per port.
            NetEnv::Rack => LinkCfg::dcn(10, 150).with_queue(1024 * 1024),
        }
    }

    /// Early Close deadline slack C (paper §III-B1: 30 ms DCN, 100 ms WAN).
    pub fn deadline_slack(self) -> Nanos {
        match self {
            NetEnv::Dcn10g | NetEnv::Rack => 30 * crate::MS,
            NetEnv::Wan1g | NetEnv::WanBursty => 100 * crate::MS,
        }
    }

    /// The bursty-WAN loss process: long good states with rare ~2-order
    /// bursts (stationary mean rate ≈ 0.8 %), matching the
    /// `wan_federated` example's regime.
    pub fn bursty_loss() -> LossModel {
        LossModel::GilbertElliott { p_gb: 0.002, p_bg: 0.05, loss_good: 0.0005, loss_bad: 0.2 }
    }
}

/// Modeled workloads with the paper's message sizes (98 MB ResNet50,
/// 528 MB VGG16) and calibrated compute times (paper §V-B: ResNet50 is
/// computation-intensive, VGG16 communication-intensive).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    Resnet50,
    Vgg16,
    /// Small message for protocol microbenchmarks.
    Micro,
}

impl Workload {
    pub fn name(self) -> &'static str {
        match self {
            Workload::Resnet50 => "resnet50",
            Workload::Vgg16 => "vgg16",
            Workload::Micro => "micro",
        }
    }

    /// Gradient bytes per worker per iteration.
    pub fn model_bytes(self) -> u64 {
        match self {
            Workload::Resnet50 => 98 * 1_000_000,
            Workload::Vgg16 => 528 * 1_000_000,
            Workload::Micro => 4 * 1_000_000,
        }
    }

    /// Modeled compute time per batch (T4-class GPU, batch 32, CIFAR-10 —
    /// calibrated so the clean-network comm/comp ratio matches the paper's
    /// Fig 2 shape).
    pub fn compute_time(self) -> Nanos {
        match self {
            Workload::Resnet50 => 120 * crate::MS,
            Workload::Vgg16 => 90 * crate::MS,
            Workload::Micro => 10 * crate::MS,
        }
    }

    /// Images per batch (throughput accounting, paper reports images/sec).
    pub fn batch_images(self) -> u64 {
        32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# LTP model manifest: preset tiny
vocab 512
d_model 128
n_layers 2
n_heads 4
seq_len 64
batch 8
param_count 300
padded_dim 4096
agg_workers 8
tile_d 4096
tensors:
tok_embed 100
block0.wq 200
";

    #[test]
    fn parses_sample_manifest() {
        let m = ModelManifest::parse("tiny", SAMPLE).unwrap();
        assert_eq!(m.vocab, 512);
        assert_eq!(m.padded_dim, 4096);
        assert_eq!(m.tensors.tensors.len(), 2);
        assert_eq!(m.wire_bytes(), 4096 * 4);
    }

    #[test]
    fn rejects_inconsistent_counts() {
        let bad = SAMPLE.replace("param_count 300", "param_count 999");
        assert!(ModelManifest::parse("tiny", &bad).is_err());
    }

    #[test]
    fn rejects_duplicate_keys_with_line_number() {
        // A repeated header key (line 4 after the injection).
        let bad = SAMPLE.replace("d_model 128", "d_model 128\nvocab 1024");
        let err = format!("{:#}", ModelManifest::parse("tiny", &bad).unwrap_err());
        assert!(err.contains("duplicate key `vocab`"), "{err}");
        assert!(err.contains("line 4"), "must name the offending line: {err}");
        // A repeated tensor name (line 15 after the injection).
        let dup_tensor = SAMPLE.replace("block0.wq 200", "block0.wq 100\ntok_embed 100");
        let err = format!("{:#}", ModelManifest::parse("tiny", &dup_tensor).unwrap_err());
        assert!(err.contains("duplicate tensor `tok_embed`"), "{err}");
        assert!(err.contains("line 15"), "{err}");
    }

    #[test]
    fn reports_line_numbers_for_malformed_lines() {
        let bad = SAMPLE.replace("seq_len 64", "seq_len=64");
        let err = format!("{:#}", ModelManifest::parse("tiny", &bad).unwrap_err());
        assert!(err.contains("line 6"), "must name the offending line: {err}");
        assert!(err.contains("malformed line"), "{err}");

        let bad = SAMPLE.replace("batch 8", "batch eight");
        let err = format!("{:#}", ModelManifest::parse("tiny", &bad).unwrap_err());
        assert!(err.contains("line 7"), "must name the offending line: {err}");
        assert!(err.contains("bad value"), "{err}");

        let bad = format!("{SAMPLE}tensors:\n");
        let err = format!("{:#}", ModelManifest::parse("tiny", &bad).unwrap_err());
        assert!(err.contains("line 15") && err.contains("duplicate `tensors:`"), "{err}");
    }

    #[test]
    fn real_manifest_loads_if_present() {
        let dir = crate::runtime::default_artifacts_dir();
        if dir.join("manifest_tiny.txt").exists() {
            let m = ModelManifest::load(&dir, "tiny").unwrap();
            assert_eq!(m.padded_dim % m.tile_d, 0);
            assert!(m.param_count > 100_000);
        }
    }

    #[test]
    fn wan_bursty_preset_is_wan_plus_ge_loss() {
        let l = NetEnv::WanBursty.link();
        assert_eq!(l.rate_bps, 1_000_000_000);
        assert!(matches!(l.loss, LossModel::GilbertElliott { .. }));
        // Mean loss rate of the burst process ≈ 0.8 %.
        assert!((NetEnv::bursty_loss().mean_rate() - 0.0082).abs() < 0.002);
        assert_eq!(NetEnv::WanBursty.deadline_slack(), 100 * crate::MS);
        // The clean WAN preset is untouched.
        assert_eq!(NetEnv::Wan1g.link().loss, LossModel::None);
    }

    #[test]
    fn workload_sizes_match_paper() {
        assert_eq!(Workload::Resnet50.model_bytes(), 98_000_000);
        assert!(Workload::Vgg16.model_bytes() > 5 * Workload::Resnet50.model_bytes());
    }
}
