//! Sans-IO reliable, in-order byte-stream transport parameterized by a
//! [`CongestionControl`] — the baseline the paper compares LTP against
//! (kernel TCP with Cubic / New Reno / DCTCP, plus BBR).
//!
//! This models the dynamics that matter for the paper's experiments:
//! cumulative ACKs with a SACK scoreboard (RFC 6675-style pipe accounting
//! — kernel defaults have SACK on), 3-dup-ACK fast retransmit, RFC 6298
//! RTO with a Linux-like 200 ms floor and exponential backoff (go-back-N
//! after timeout), per-ACK delivery-rate samples for BBR, and ECN echo for
//! DCTCP. It is not a wire-compatible TCP.

mod node;
pub use node::{FctLog, TcpReceiverNode, TcpSenderNode};

use crate::cc::{AckSample, CongestionControl};
use crate::wire::{TcpSeg, SACK_BLOCKS};
use crate::{Nanos, MS, SEC};
use std::collections::{BTreeMap, VecDeque};

/// Linux default minimum RTO.
pub const DEFAULT_MIN_RTO: Nanos = 200 * MS;
const MAX_RTO: Nanos = 60 * SEC;
/// RFC 6675 duplicate threshold, in segments.
const DUP_THRESH: u64 = 3;

#[derive(Debug, Clone, Copy, Default)]
pub struct TcpStats {
    pub pkts_sent: u64,
    pub bytes_sent: u64,
    pub retransmissions: u64,
    pub fast_retransmits: u64,
    pub rtos: u64,
    pub tlps: u64,
    pub completed_at: Option<Nanos>,
}

#[derive(Debug, Clone, Copy)]
struct SentSeg {
    len: u32,
    sent_at: Nanos,
    delivered_at_send: u64,
    retransmitted: bool,
    sacked: bool,
    /// Marked lost by the scoreboard; not counted in pipe, queued for retx.
    lost: bool,
}

/// Bulk-transfer TCP sender for one flow of `total` bytes.
pub struct TcpSender {
    pub flow: u64,
    total: u64,
    mss: u32,
    pub cc: Box<dyn CongestionControl>,
    snd_una: u64,
    snd_nxt: u64,
    outstanding: BTreeMap<u64, SentSeg>,
    /// Unsacked, un-lost bytes in flight (RFC 6675 "pipe").
    pipe_bytes: u64,
    /// Highest byte covered by any SACK block seen.
    highest_sacked: u64,
    /// Segments marked lost, awaiting retransmission.
    retx_queue: VecDeque<u64>,
    dup_acks: u32,
    in_recovery: bool,
    recover: u64,
    srtt: Nanos,
    rttvar: Nanos,
    rto: Nanos,
    pub min_rto: Nanos,
    rto_deadline: Option<Nanos>,
    /// Tail-loss-probe deadline (kernel TLP: fires at ~2·srtt before the
    /// RTO, retransmitting the last segment to draw SACK feedback).
    tlp_deadline: Option<Nanos>,
    tlp_armed: bool,
    backoff: u32,
    delivered: u64,
    pace_tokens: f64,
    pace_refill_at: Nanos,
    started_at: Option<Nanos>,
    pub stats: TcpStats,
}

impl TcpSender {
    pub fn new(flow: u64, total: u64, mss: u32, cc: Box<dyn CongestionControl>) -> TcpSender {
        TcpSender {
            flow,
            total,
            mss,
            cc,
            snd_una: 0,
            snd_nxt: 0,
            outstanding: BTreeMap::new(),
            pipe_bytes: 0,
            highest_sacked: 0,
            retx_queue: VecDeque::new(),
            dup_acks: 0,
            in_recovery: false,
            recover: 0,
            srtt: 0,
            rttvar: 0,
            rto: SEC, // RFC 6298 initial RTO
            min_rto: DEFAULT_MIN_RTO,
            rto_deadline: None,
            tlp_deadline: None,
            tlp_armed: true,
            backoff: 0,
            delivered: 0,
            pace_tokens: 10.0,
            pace_refill_at: 0,
            started_at: None,
            stats: TcpStats::default(),
        }
    }

    pub fn is_complete(&self) -> bool {
        self.stats.completed_at.is_some()
    }

    pub fn bytes_acked(&self) -> u64 {
        self.snd_una
    }

    pub fn total_bytes(&self) -> u64 {
        self.total
    }

    /// RFC 6675 pipe: bytes believed in flight.
    pub fn pipe(&self) -> u64 {
        self.pipe_bytes
    }

    fn update_rtt(&mut self, rtt: Nanos) {
        if self.srtt == 0 {
            self.srtt = rtt;
            self.rttvar = rtt / 2;
        } else {
            let diff = self.srtt.abs_diff(rtt);
            self.rttvar = (3 * self.rttvar + diff) / 4;
            self.srtt = (7 * self.srtt + rtt) / 8;
        }
        self.rto = (self.srtt + (4 * self.rttvar).max(MS)).clamp(self.min_rto, MAX_RTO);
    }

    fn arm_rto(&mut self, now: Nanos) {
        if self.snd_nxt > self.snd_una {
            self.rto_deadline = Some(now + (self.rto << self.backoff.min(6)));
            self.tlp_deadline = if self.tlp_armed && self.srtt > 0 {
                Some(now + 2 * self.srtt)
            } else {
                None
            };
        } else {
            self.rto_deadline = None;
            self.tlp_deadline = None;
        }
    }

    /// Apply SACK blocks to the scoreboard; returns bytes newly sacked.
    fn apply_sacks(&mut self, sack: &[(u64, u64); SACK_BLOCKS]) -> u64 {
        let mut newly = 0;
        for &(start, end) in sack {
            if end <= start {
                continue;
            }
            self.highest_sacked = self.highest_sacked.max(end);
            let keys: Vec<u64> =
                self.outstanding.range(start..end).map(|(&s, _)| s).collect();
            for s in keys {
                let seg = self.outstanding.get_mut(&s).unwrap();
                if !seg.sacked && s + seg.len as u64 <= end {
                    seg.sacked = true;
                    if !seg.lost {
                        self.pipe_bytes = self.pipe_bytes.saturating_sub(seg.len as u64);
                    }
                    newly += seg.len as u64;
                }
            }
        }
        newly
    }

    /// RFC 6675 loss marking: an unsacked segment with ≥ DUP_THRESH·mss of
    /// SACKed bytes above it is lost. Marks and queues retransmissions.
    /// RACK-style guard: a retransmitted copy gets one RTT in flight before
    /// it can be re-marked lost (otherwise every ACK re-marks it and the
    /// sender storms).
    fn mark_losses(&mut self, now: Nanos) {
        if self.highest_sacked < DUP_THRESH * self.mss as u64 {
            return;
        }
        let limit = self.highest_sacked - DUP_THRESH * self.mss as u64;
        let grace = self.srtt.max(MS) * 5 / 4;
        let candidates: Vec<u64> = self
            .outstanding
            .range(..limit)
            .filter(|(_, seg)| {
                !seg.sacked
                    && !seg.lost
                    && (!seg.retransmitted || now > seg.sent_at + grace)
            })
            .map(|(&s, _)| s)
            .collect();
        for s in candidates {
            let seg = self.outstanding.get_mut(&s).unwrap();
            seg.lost = true;
            self.pipe_bytes = self.pipe_bytes.saturating_sub(seg.len as u64);
            self.retx_queue.push_back(s);
        }
    }

    /// Process a (cumulative + SACK) ACK from the receiver.
    pub fn on_ack(&mut self, now: Nanos, seg: TcpSeg) {
        if self.is_complete() {
            return;
        }
        let newly_sacked = self.apply_sacks(&seg.sack);
        // SACKed bytes count as delivered the moment they are SACKed
        // (Linux does the same); otherwise a hole-filling cumulative ACK
        // credits megabytes to one RTT and poisons BBR's rate samples.
        self.delivered += newly_sacked;
        if seg.ack > self.snd_una {
            let newly = seg.ack - self.snd_una;
            self.snd_una = seg.ack;
            // A late ACK (sent pre-timeout) can land after go-back-N reset
            // snd_nxt; never let snd_nxt trail snd_una.
            self.snd_nxt = self.snd_nxt.max(self.snd_una);
            self.backoff = 0;
            let mut rtt_sample: Option<Nanos> = None;
            let mut rate_sample: Option<u64> = None;
            let acked: Vec<u64> = self.outstanding.range(..seg.ack).map(|(&s, _)| s).collect();
            for s in acked {
                let info = self.outstanding.remove(&s).unwrap();
                if !info.sacked && !info.lost {
                    self.pipe_bytes = self.pipe_bytes.saturating_sub(info.len as u64);
                }
                if !info.sacked {
                    // Not previously credited via a SACK block.
                    self.delivered += info.len as u64;
                }
                if !info.retransmitted {
                    let rtt = now.saturating_sub(info.sent_at).max(1);
                    rtt_sample = Some(rtt);
                    let dbytes = self.delivered - info.delivered_at_send;
                    rate_sample = Some((dbytes as u128 * 8 * SEC as u128 / rtt as u128) as u64);
                }
            }
            if let Some(rtt) = rtt_sample {
                self.update_rtt(rtt);
            }
            if self.in_recovery && seg.ack >= self.recover {
                self.in_recovery = false;
                self.dup_acks = 0;
            }
            if !self.in_recovery {
                self.dup_acks = 0;
            }
            self.cc.on_ack(AckSample {
                now,
                acked_bytes: newly,
                rtt: rtt_sample.unwrap_or(self.srtt.max(MS)),
                delivery_rate_bps: rate_sample,
                ece: seg.ece,
                inflight_bytes: self.pipe_bytes,
            });
            self.tlp_armed = true;
            self.arm_rto(now);
            if self.snd_una >= self.total {
                self.stats.completed_at = Some(now);
                self.rto_deadline = None;
            }
        } else if seg.ack == self.snd_una && self.snd_nxt > self.snd_una {
            if newly_sacked > 0 {
                self.dup_acks += 1;
            }
            if self.dup_acks >= 3 && !self.in_recovery {
                self.in_recovery = true;
                self.recover = self.snd_nxt;
                self.cc.on_loss(now);
                self.stats.fast_retransmits += 1;
                // The segment at snd_una is lost by definition of 3 dupacks.
                if let Some(info) = self.outstanding.get_mut(&self.snd_una) {
                    if !info.lost {
                        info.lost = true;
                        if !info.sacked {
                            self.pipe_bytes =
                                self.pipe_bytes.saturating_sub(info.len as u64);
                        }
                        self.retx_queue.push_front(self.snd_una);
                    }
                }
            }
        }
        self.mark_losses(now);
    }

    /// RTO / pacing deadline the driver must honor.
    pub fn next_wakeup(&self) -> Option<Nanos> {
        if self.is_complete() {
            return None;
        }
        let pace = if self.pace_tokens < 1.0 && self.has_data_to_send() {
            self.next_token_at()
        } else {
            None
        };
        let timer = match (self.tlp_deadline, self.rto_deadline) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        match (pace, timer) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    pub fn on_wakeup(&mut self, now: Nanos) {
        if let Some(tlp) = self.tlp_deadline {
            if now >= tlp {
                // Tail loss probe: re-send the last outstanding segment to
                // elicit SACKs; one probe per flight, then the RTO rules.
                self.tlp_deadline = None;
                self.tlp_armed = false;
                self.stats.tlps += 1;
                // Probe the highest unsacked, un-lost segment.
                let probe = self
                    .outstanding
                    .iter()
                    .rev()
                    .find(|(_, seg)| !seg.sacked && !seg.lost)
                    .map(|(&s, _)| s);
                if let Some(seq) = probe {
                    let sg = self.outstanding.get_mut(&seq).unwrap();
                    sg.lost = true;
                    self.pipe_bytes = self.pipe_bytes.saturating_sub(sg.len as u64);
                    self.retx_queue.push_front(seq);
                }
            }
        }
        if let Some(dl) = self.rto_deadline {
            if now >= dl {
                // Timeout: go-back-N from snd_una.
                self.stats.rtos += 1;
                self.cc.on_timeout(now);
                self.outstanding.clear();
                self.retx_queue.clear();
                self.pipe_bytes = 0;
                self.highest_sacked = 0;
                self.snd_nxt = self.snd_una;
                self.dup_acks = 0;
                self.in_recovery = false;
                self.backoff += 1;
                self.rto_deadline = None;
                self.tlp_deadline = None;
            }
        }
    }

    fn has_data_to_send(&self) -> bool {
        !self.retx_queue.is_empty() || self.snd_nxt < self.total
    }

    fn next_token_at(&self) -> Option<Nanos> {
        let rate = self.cc.pacing_rate_bps()?;
        if rate == 0 {
            return None;
        }
        let need = 1.0 - self.pace_tokens;
        let ns_per_pkt = (self.mss as f64 * 8.0 * SEC as f64) / rate as f64;
        Some(self.pace_refill_at + (need * ns_per_pkt).ceil() as Nanos)
    }

    fn refill_tokens(&mut self, now: Nanos) {
        let Some(rate) = self.cc.pacing_rate_bps() else {
            self.pace_tokens = 10.0;
            self.pace_refill_at = now;
            return;
        };
        let dt = now.saturating_sub(self.pace_refill_at);
        let pkts = (rate as f64 / 8.0 / self.mss as f64) * (dt as f64 / SEC as f64);
        self.pace_tokens = (self.pace_tokens + pkts).min(10.0);
        self.pace_refill_at = now;
    }

    /// Pull the next segment to transmit, if window/pacing allow.
    pub fn poll_transmit(&mut self, now: Nanos) -> Option<TcpSeg> {
        if self.is_complete() {
            return None;
        }
        self.started_at.get_or_insert(now);
        self.refill_tokens(now);
        if self.pace_tokens < 1.0 {
            return None;
        }
        // Retransmissions first (pipe-limited).
        while let Some(&seq) = self.retx_queue.front() {
            // Skip entries that were cumulatively acked or SACKed (a "lost"
            // packet that in fact arrived late) in the meantime.
            let stale = seq < self.snd_una
                || self.outstanding.get(&seq).map(|s| s.sacked).unwrap_or(true);
            if stale {
                self.retx_queue.pop_front();
                continue;
            }
            let len = self.outstanding[&seq].len;
            if self.pipe_bytes + len as u64 > self.cc.cwnd_bytes() {
                return None;
            }
            self.retx_queue.pop_front();
            self.outstanding.insert(
                seq,
                SentSeg {
                    len,
                    sent_at: now,
                    delivered_at_send: self.delivered,
                    retransmitted: true,
                    sacked: false,
                    lost: false,
                },
            );
            self.pipe_bytes += len as u64;
            self.stats.retransmissions += 1;
            self.note_sent(now, len);
            return Some(TcpSeg::data(self.flow, seq, len));
        }
        // New data within the window.
        if self.snd_nxt < self.total {
            let len = self.seg_len_at(self.snd_nxt);
            if self.pipe_bytes + len as u64 <= self.cc.cwnd_bytes() {
                let seq = self.snd_nxt;
                self.snd_nxt += len as u64;
                self.outstanding.insert(
                    seq,
                    SentSeg {
                        len,
                        sent_at: now,
                        delivered_at_send: self.delivered,
                        retransmitted: false,
                        sacked: false,
                        lost: false,
                    },
                );
                self.pipe_bytes += len as u64;
                self.note_sent(now, len);
                return Some(TcpSeg::data(self.flow, seq, len));
            }
        }
        None
    }

    fn seg_len_at(&self, seq: u64) -> u32 {
        ((self.total - seq).min(self.mss as u64)) as u32
    }

    fn note_sent(&mut self, now: Nanos, len: u32) {
        self.pace_tokens -= 1.0;
        self.stats.pkts_sent += 1;
        self.stats.bytes_sent += len as u64 + crate::wire::TCP_IP_OVERHEAD as u64;
        if self.rto_deadline.is_none() {
            self.arm_rto(now);
        }
    }
}

/// TCP receiver: cumulative ACK + SACK-block generation from a merged
/// out-of-order range set, with per-packet ECN echo.
pub struct TcpReceiver {
    pub flow: u64,
    rcv_nxt: u64,
    /// Merged out-of-order ranges start → end.
    ooo: BTreeMap<u64, u64>,
    pub bytes_received: u64,
    pub dup_segs: u64,
}

impl TcpReceiver {
    pub fn new(flow: u64) -> TcpReceiver {
        TcpReceiver { flow, rcv_nxt: 0, ooo: BTreeMap::new(), bytes_received: 0, dup_segs: 0 }
    }

    pub fn next_expected(&self) -> u64 {
        self.rcv_nxt
    }

    fn insert_ooo(&mut self, start: u64, end: u64) -> (u64, u64) {
        // Merge [start, end) into the range set; returns the merged range.
        let (mut s, mut e) = (start, end);
        // Absorb a predecessor that overlaps/abuts.
        if let Some((&ps, &pe)) = self.ooo.range(..=s).next_back() {
            if pe >= s {
                s = ps;
                e = e.max(pe);
                self.ooo.remove(&ps);
            }
        }
        // Absorb successors.
        while let Some((&ns, &ne)) = self.ooo.range(s..).next() {
            if ns <= e {
                e = e.max(ne);
                self.ooo.remove(&ns);
            } else {
                break;
            }
        }
        self.ooo.insert(s, e);
        (s, e)
    }

    /// Process a data segment; returns the (SACK-bearing) ACK to send back.
    pub fn on_data(&mut self, seg: TcpSeg, ecn_ce: bool) -> TcpSeg {
        let mut first_block: Option<(u64, u64)> = None;
        let end = seg.seq + seg.len as u64;
        if seg.seq == self.rcv_nxt || (seg.seq < self.rcv_nxt && end > self.rcv_nxt) {
            self.bytes_received += end - self.rcv_nxt;
            self.rcv_nxt = end;
            // Merge contiguous out-of-order ranges.
            while let Some((&s, &e)) = self.ooo.first_key_value() {
                if s <= self.rcv_nxt {
                    self.ooo.pop_first();
                    if e > self.rcv_nxt {
                        self.bytes_received += e - self.rcv_nxt;
                        self.rcv_nxt = e;
                    }
                } else {
                    break;
                }
            }
        } else if seg.seq > self.rcv_nxt {
            let had = self.ooo.range(..=seg.seq).next_back().map(|(&s, &e)| (s, e));
            let covered = had.map(|(_, e)| e >= end).unwrap_or(false);
            if covered {
                self.dup_segs += 1;
                first_block = had;
            } else {
                first_block = Some(self.insert_ooo(seg.seq, end));
            }
        } else {
            self.dup_segs += 1;
        }
        let mut ack = TcpSeg::ack(self.flow, self.rcv_nxt, ecn_ce);
        // SACK blocks: the block containing this segment first, then others
        // by sequence.
        let mut n = 0;
        if let Some(b) = first_block {
            ack.sack[n] = b;
            n += 1;
        }
        for (&s, &e) in self.ooo.iter() {
            if n >= SACK_BLOCKS {
                break;
            }
            if Some((s, e)) != first_block {
                ack.sack[n] = (s, e);
                n += 1;
            }
        }
        ack
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::{CcAlgo, Reno};

    fn pipe(total: u64) -> (TcpSender, TcpReceiver) {
        (TcpSender::new(1, total, 1460, Box::new(Reno::new(1460))), TcpReceiver::new(1))
    }

    /// Drive sender→receiver with an optional per-index drop predicate;
    /// returns completion time.
    fn run_loss(total: u64, drop: impl Fn(u64) -> bool) -> (Nanos, TcpStats) {
        let (mut snd, mut rcv) = pipe(total);
        let mut now: Nanos = 0;
        let rtt = 2 * MS;
        let mut idx = 0;
        for _ in 0..2_000_000u64 {
            if snd.is_complete() {
                break;
            }
            let mut progressed = false;
            while let Some(seg) = snd.poll_transmit(now) {
                progressed = true;
                idx += 1;
                if !drop(idx) {
                    let ack = rcv.on_data(seg, false);
                    snd.on_ack(now + rtt, ack);
                }
            }
            if !progressed {
                match snd.next_wakeup() {
                    Some(w) => {
                        now = w.max(now + 1);
                        snd.on_wakeup(now);
                    }
                    None => now += MS,
                }
            } else {
                now += rtt;
            }
        }
        (snd.stats.completed_at.expect("flow must complete"), snd.stats)
    }

    #[test]
    fn lossless_transfer_completes() {
        let (t, stats) = run_loss(1_000_000, |_| false);
        assert!(t > 0);
        assert_eq!(stats.retransmissions, 0);
        assert_eq!(stats.rtos, 0);
    }

    #[test]
    fn single_loss_triggers_fast_retransmit() {
        let (_t, stats) = run_loss(2_000_000, |i| i == 50);
        assert!(stats.fast_retransmits >= 1, "expected a fast retransmit: {stats:?}");
        assert_eq!(stats.rtos, 0, "single mid-window loss should not RTO: {stats:?}");
    }

    #[test]
    fn heavy_loss_still_completes() {
        let (_t, stats) = run_loss(500_000, |i| i % 20 == 7);
        assert!(stats.retransmissions > 0);
    }

    #[test]
    fn loss_slows_completion() {
        let (t_clean, _) = run_loss(2_000_000, |_| false);
        let (t_lossy, _) = run_loss(2_000_000, |i| i % 30 == 7);
        assert!(t_lossy > t_clean, "loss must slow TCP down: {t_clean} vs {t_lossy}");
    }

    #[test]
    fn sack_recovery_handles_many_holes_in_one_window() {
        // Drop every 4th packet in a burst window; SACK recovery should
        // retransmit holes in ~1 RTT each rather than one hole per RTT.
        let (_t, stats) = run_loss(3_000_000, |i| (100..400).contains(&i) && i % 4 == 0);
        assert!(stats.retransmissions >= 70, "holes must be retransmitted: {stats:?}");
        assert_eq!(stats.rtos, 0, "SACK should avoid RTOs here: {stats:?}");
    }

    #[test]
    fn receiver_reassembles_out_of_order() {
        let mut rcv = TcpReceiver::new(1);
        let a1 = rcv.on_data(TcpSeg::data(1, 1460, 1460), false);
        assert_eq!(a1.ack, 0); // hole at 0
        assert_eq!(a1.sack[0], (1460, 2920)); // the ooo block is SACKed
        let a2 = rcv.on_data(TcpSeg::data(1, 0, 1460), false);
        assert_eq!(a2.ack, 2920); // hole filled, merged
        assert_eq!(rcv.bytes_received, 2920);
    }

    #[test]
    fn receiver_merges_adjacent_ooo_ranges() {
        let mut rcv = TcpReceiver::new(1);
        rcv.on_data(TcpSeg::data(1, 2920, 1460), false);
        let ack = rcv.on_data(TcpSeg::data(1, 1460, 1460), false);
        // Blocks [1460,2920) and [2920,4380) merge into one.
        assert_eq!(ack.sack[0], (1460, 4380));
        assert_eq!(ack.sack[1], (0, 0));
    }

    #[test]
    fn receiver_counts_duplicates() {
        let mut rcv = TcpReceiver::new(1);
        rcv.on_data(TcpSeg::data(1, 0, 1460), false);
        rcv.on_data(TcpSeg::data(1, 0, 1460), false);
        assert_eq!(rcv.dup_segs, 1);
    }

    #[test]
    fn ecn_echo_propagates() {
        let mut rcv = TcpReceiver::new(1);
        let ack = rcv.on_data(TcpSeg::data(1, 0, 1460), true);
        assert!(ack.ece);
    }

    #[test]
    fn pipe_accounting_stays_consistent() {
        let (mut snd, mut rcv) = pipe(1_000_000);
        let mut now = 0;
        let mut in_net: Vec<TcpSeg> = vec![];
        let mut i = 0u64;
        while !snd.is_complete() && now < 60 * SEC {
            while let Some(seg) = snd.poll_transmit(now) {
                i += 1;
                if i % 7 != 0 {
                    in_net.push(seg);
                }
            }
            for seg in in_net.drain(..) {
                let ack = rcv.on_data(seg, false);
                snd.on_ack(now + MS, ack);
            }
            assert!(snd.pipe() <= 1_000_000 + 1460, "pipe ran away: {}", snd.pipe());
            now += MS;
            snd.on_wakeup(now);
        }
        assert!(snd.is_complete());
        assert_eq!(snd.pipe(), 0, "pipe must drain to zero at completion");
    }

    #[test]
    fn all_ccs_complete_a_transfer() {
        for algo in CcAlgo::ALL {
            let mut snd = TcpSender::new(1, 200_000, 1460, algo.build(1460));
            let mut rcv = TcpReceiver::new(1);
            let mut now = 0;
            for _ in 0..100_000 {
                if snd.is_complete() {
                    break;
                }
                let mut sent_any = false;
                while let Some(seg) = snd.poll_transmit(now) {
                    sent_any = true;
                    let ack = rcv.on_data(seg, false);
                    snd.on_ack(now + MS, ack);
                }
                now += if sent_any { MS } else { 10 * MS };
                snd.on_wakeup(now);
            }
            assert!(snd.is_complete(), "{} did not complete", algo.name());
        }
    }
}
