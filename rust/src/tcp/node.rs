//! Simulator adapters for the sans-IO TCP machines: one bulk-flow sender
//! node and a multi-flow receiver node.

use super::{TcpReceiver, TcpSender};
use crate::simnet::{Ctx, EntityId, Node, Packet};
use crate::wire::{PacketKind, TCP_IP_OVERHEAD};
use crate::Nanos;
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// Shared completion log: (flow, completion time, bytes).
pub type FctLog = Rc<RefCell<Vec<(u64, Nanos, u64)>>>;

/// Drives one [`TcpSender`] against a peer entity.
pub struct TcpSenderNode {
    pub sender: TcpSender,
    peer: EntityId,
    /// Delay before the first byte is offered (staggered starts).
    start_at: Nanos,
    timer_gen: u64,
    log: Option<FctLog>,
    logged: bool,
}

impl TcpSenderNode {
    pub fn new(sender: TcpSender, peer: EntityId) -> TcpSenderNode {
        TcpSenderNode { sender, peer, start_at: 0, timer_gen: 0, log: None, logged: false }
    }

    pub fn with_start(mut self, at: Nanos) -> TcpSenderNode {
        self.start_at = at;
        self
    }

    pub fn with_log(mut self, log: FctLog) -> TcpSenderNode {
        self.log = Some(log);
        self
    }

    fn drain(&mut self, ctx: &mut Ctx) {
        let now = ctx.now();
        while let Some(seg) = self.sender.poll_transmit(now) {
            let size = seg.len + TCP_IP_OVERHEAD;
            ctx.send(Packet::new(ctx.me, self.peer, size, self.sender.flow, PacketKind::Tcp(seg)));
        }
        if self.sender.is_complete() && !self.logged {
            self.logged = true;
            if let Some(log) = &self.log {
                log.borrow_mut().push((
                    self.sender.flow,
                    self.sender.stats.completed_at.unwrap() - self.start_at,
                    self.sender.total_bytes(),
                ));
            }
        }
        self.timer_gen += 1;
        if let Some(w) = self.sender.next_wakeup() {
            // Strictly future: see LtpSenderNode::drain.
            ctx.set_timer(w.max(now + 1), self.timer_gen);
        }
    }
}

impl Node for TcpSenderNode {
    fn as_any(&mut self) -> &mut dyn std::any::Any { self }
    fn start(&mut self, ctx: &mut Ctx) {
        if self.start_at > 0 {
            self.timer_gen += 1;
            ctx.set_timer(self.start_at, self.timer_gen);
        } else {
            self.drain(ctx);
        }
    }

    fn on_packet(&mut self, ctx: &mut Ctx, pkt: Packet) {
        if let PacketKind::Tcp(seg) = pkt.kind {
            if seg.is_ack {
                self.sender.on_ack(ctx.now(), seg);
            }
        }
        self.drain(ctx);
    }

    fn on_timer(&mut self, ctx: &mut Ctx, token: u64) {
        if token != self.timer_gen {
            return; // stale timer
        }
        self.sender.on_wakeup(ctx.now());
        self.drain(ctx);
    }
}

/// Accepts any number of TCP flows and generates cumulative ACKs.
#[derive(Default)]
pub struct TcpReceiverNode {
    pub flows: HashMap<u64, TcpReceiver>,
}

impl TcpReceiverNode {
    pub fn new() -> TcpReceiverNode {
        TcpReceiverNode { flows: HashMap::new() }
    }

    pub fn bytes_received(&self, flow: u64) -> u64 {
        self.flows.get(&flow).map(|r| r.bytes_received).unwrap_or(0)
    }
}

impl Node for TcpReceiverNode {
    fn as_any(&mut self) -> &mut dyn std::any::Any { self }
    fn on_packet(&mut self, ctx: &mut Ctx, pkt: Packet) {
        if let PacketKind::Tcp(seg) = pkt.kind {
            if seg.is_ack {
                return;
            }
            let rcv = self.flows.entry(seg.flow).or_insert_with(|| TcpReceiver::new(seg.flow));
            let ack = rcv.on_data(seg, pkt.ecn_ce);
            ctx.send(Packet::new(ctx.me, pkt.src, TCP_IP_OVERHEAD, seg.flow, PacketKind::Tcp(ack)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::CcAlgo;
    use crate::simnet::{LinkCfg, LossModel, Sim};
    use crate::wire::TCP_MSS;
    use crate::{MS, SEC};

    /// Utility: run one bulk flow over one link, return (fct, goodput bps).
    pub fn run_bulk(
        algo: CcAlgo,
        bytes: u64,
        cfg: LinkCfg,
        seed: u64,
    ) -> (crate::Nanos, f64) {
        let log: FctLog = Rc::new(RefCell::new(vec![]));
        let mut sim = Sim::new(seed);
        let snd = TcpSender::new(1, bytes, TCP_MSS, algo.build(TCP_MSS));
        let a = sim
            .add_host(Box::new(TcpSenderNode::new(snd, 1).with_log(log.clone())));
        let b = sim.add_host(Box::new(TcpReceiverNode::new()));
        sim.add_duplex(a, b, cfg);
        sim.run_until(600 * SEC);
        let fct = log.borrow().first().map(|&(_, t, _)| t).expect("flow did not complete");
        (fct, bytes as f64 * 8.0 / (fct as f64 / SEC as f64))
    }

    #[test]
    fn bulk_flow_fills_clean_link() {
        // 1 Gbps, 5 ms RTT-ish link, 50 MB transfer (long enough for BBR's
        // startup + drain to amortize).
        let cfg = LinkCfg::wan(1000, 5);
        for algo in CcAlgo::ALL {
            let (_fct, goodput) = run_bulk(algo, 50_000_000, cfg, 42);
            // The modeled BBR converges more conservatively than kernel BBR
            // (startup plateau detection is time-based); each cc is compared
            // against its own clean-link baseline in the figures, so only a
            // sane utilization floor is asserted here.
            let floor = if algo == CcAlgo::Bbr { 0.35e9 } else { 0.5e9 };
            assert!(
                goodput > floor,
                "{}: goodput {:.2} Mbps too low on a clean 1 Gbps link",
                algo.name(),
                goodput / 1e6
            );
        }
    }

    #[test]
    fn cubic_collapses_under_random_loss_but_bbr_does_not() {
        let clean = LinkCfg::wan(1000, 5);
        let lossy = clean.with_loss(LossModel::Bernoulli { p: 0.01 });
        let (_f, cubic_clean) = run_bulk(CcAlgo::Cubic, 5_000_000, clean, 1);
        let (_f, cubic_lossy) = run_bulk(CcAlgo::Cubic, 5_000_000, lossy, 1);
        let (_f, bbr_lossy) = run_bulk(CcAlgo::Bbr, 5_000_000, lossy, 1);
        assert!(
            cubic_lossy < cubic_clean / 2.0,
            "cubic should collapse: {:.1} vs {:.1} Mbps",
            cubic_lossy / 1e6,
            cubic_clean / 1e6
        );
        assert!(
            bbr_lossy > cubic_lossy * 2.0,
            "bbr should beat cubic under loss: {:.1} vs {:.1} Mbps",
            bbr_lossy / 1e6,
            cubic_lossy / 1e6
        );
    }

    #[test]
    fn rto_recovers_from_blackout_tail_loss() {
        // Lose a burst near the end: only the RTO can recover the tail.
        let log: FctLog = Rc::new(RefCell::new(vec![]));
        let mut sim = Sim::new(9);
        let snd = TcpSender::new(1, 100_000, TCP_MSS, CcAlgo::Reno.build(TCP_MSS));
        let a = sim.add_host(Box::new(TcpSenderNode::new(snd, 1).with_log(log.clone())));
        let b = sim.add_host(Box::new(TcpReceiverNode::new()));
        // High loss makes tail RTOs near-certain at some point.
        sim.add_duplex(a, b, LinkCfg::wan(100, 5).with_loss(LossModel::Bernoulli { p: 0.2 }));
        sim.run_until(300 * SEC);
        assert_eq!(log.borrow().len(), 1, "flow must complete via RTO recovery");
    }

    #[test]
    fn incast_has_long_tail_under_reno() {
        // 8 senders → 1 receiver through a switch; shallow buffer.
        let log: FctLog = Rc::new(RefCell::new(vec![]));
        let mut sim = Sim::new(5);
        let sw = sim.add_switch(0);
        let rcv = sim.add_host(Box::new(TcpReceiverNode::new()));
        let (r_up, _) = sim.add_duplex(rcv, sw, LinkCfg::dcn(1, 10).with_queue(64 * 1024));
        sim.set_default_uplink(rcv, r_up);
        for i in 0..8 {
            let snd = TcpSender::new(i, 2_000_000, TCP_MSS, CcAlgo::Reno.build(TCP_MSS));
            let h = sim.add_host(Box::new(
                TcpSenderNode::new(snd, rcv).with_log(log.clone()),
            ));
            let (up, _) = sim.add_duplex(h, sw, LinkCfg::dcn(1, 10).with_queue(64 * 1024));
            sim.set_default_uplink(h, up);
        }
        sim.run_until(300 * SEC);
        let fcts: Vec<f64> = log.borrow().iter().map(|&(_, t, _)| t as f64).collect();
        assert_eq!(fcts.len(), 8, "all incast flows must finish");
        let s = crate::util::Summary::of(&fcts);
        // The defining long-tail property: max FCT well above the median.
        assert!(
            s.max > 1.15 * s.p50,
            "expected straggler flows: max {} vs p50 {}",
            s.max,
            s.p50
        );
        let _ = MS;
    }
}
