//! A deterministic job pool for sharding independent simulation runs
//! across cores (DESIGN.md §4.4).
//!
//! The experiment surface — `ltp scenario all`, the figure sweeps, the
//! seed sweeps — is embarrassingly parallel: every (scenario, seed) pair
//! and every figure grid point is an independent, self-contained
//! simulation whose determinism comes from its own seeded RNG streams.
//! [`run_jobs`] exploits that: jobs are enumerated up front, worker
//! threads pull them from a shared queue, and results are merged back **in
//! job order**, so the output of `--jobs N` is byte-identical to
//! `--jobs 1` for any N.
//!
//! Design constraints (and why it looks the way it does):
//!
//! * **No new dependencies.** `std::thread::scope` + `std::sync::mpsc`
//!   only; no rayon, no crossbeam. Scoped threads let jobs borrow the
//!   caller's environment (figure configs, the scenario registry) without
//!   `'static` gymnastics.
//! * **Deterministic merge.** Results are slotted by job index, never by
//!   completion order. Nothing in this module inspects wall-clock time to
//!   decide *what* to compute.
//! * **Panic propagation.** A panicking job poisons the queue (remaining
//!   jobs are abandoned), and the original panic payload is re-raised on
//!   the calling thread once every worker has drained — so `cargo test`
//!   failures point at the job that died, not at a channel hangup.
//! * **Jobs must not print.** Stdout interleaving would break the
//!   byte-identity contract; all rendering happens after the merge, on the
//!   calling thread. (The scenario/figure code upholds this: simulations
//!   are silent, tables and JSON are emitted post-merge.)

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Mutex};

/// The machine's available parallelism (≥ 1). This is what `--jobs 0`
/// resolves to.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a requested job count against the amount of work: `0` means
/// "auto" ([`default_jobs`]), and there is never a reason to spawn more
/// workers than jobs. Public so bench reports can record the worker count
/// actually used.
pub fn effective_jobs(requested: usize, n_inputs: usize) -> usize {
    let want = if requested == 0 { default_jobs() } else { requested };
    want.min(n_inputs.max(1))
}

/// Run `f` over every input on up to `jobs` worker threads and return the
/// outputs **in input order**.
///
/// * `jobs == 0` uses [`default_jobs`]; `jobs == 1` runs inline on the
///   calling thread (no threads spawned, no synchronization).
/// * `f` receives `(job_index, input)`. It must be self-contained: own
///   RNG/state per job, no printing, no shared mutable statics — the whole
///   repo's simulation stack satisfies this (state lives in `Sim`, RNGs
///   are per-run `Pcg64` streams).
/// * If any job panics, the first panic (lowest job index) is re-raised
///   here after the pool drains; queued jobs that had not started are
///   dropped.
pub fn run_jobs<I, O, F>(jobs: usize, inputs: Vec<I>, f: F) -> Vec<O>
where
    I: Send,
    O: Send,
    F: Fn(usize, I) -> O + Sync,
{
    let n = inputs.len();
    if n == 0 {
        return Vec::new();
    }
    if effective_jobs(jobs, n) <= 1 {
        return inputs.into_iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let workers = effective_jobs(jobs, n);
    let queue: Mutex<VecDeque<(usize, I)>> =
        Mutex::new(inputs.into_iter().enumerate().collect());
    let poisoned = AtomicBool::new(false);
    let (tx, rx) = mpsc::channel::<(usize, std::thread::Result<O>)>();
    let mut slots: Vec<Option<O>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    // Lowest-index panic wins, so the re-raised error is deterministic even
    // when several jobs die in one run.
    let mut first_panic: Option<(usize, Box<dyn std::any::Any + Send>)> = None;
    std::thread::scope(|s| {
        for _ in 0..workers {
            let tx = tx.clone();
            let queue = &queue;
            let poisoned = &poisoned;
            let f = &f;
            s.spawn(move || loop {
                if poisoned.load(Ordering::Relaxed) {
                    break;
                }
                let job = queue
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .pop_front();
                let Some((idx, input)) = job else { break };
                let out = catch_unwind(AssertUnwindSafe(|| f(idx, input)));
                if out.is_err() {
                    poisoned.store(true, Ordering::Relaxed);
                }
                if tx.send((idx, out)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        // Receives until every worker has exited (all senders dropped).
        for (idx, res) in rx {
            match res {
                Ok(out) => slots[idx] = Some(out),
                Err(payload) => {
                    if first_panic.as_ref().map(|(i, _)| idx < *i).unwrap_or(true) {
                        first_panic = Some((idx, payload));
                    }
                }
            }
        }
    });
    if let Some((_, payload)) = first_panic {
        resume_unwind(payload);
    }
    slots
        .into_iter()
        .map(|o| o.expect("job pool lost a result (worker exited without reporting)"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outputs_follow_input_order() {
        let out = run_jobs(4, (0u64..40).collect(), |i, x| {
            assert_eq!(i as u64, x);
            x * 10
        });
        assert_eq!(out, (0u64..40).map(|x| x * 10).collect::<Vec<_>>());
    }

    #[test]
    fn serial_parallel_and_auto_agree() {
        let inputs: Vec<u64> = (0..23).collect();
        let serial = run_jobs(1, inputs.clone(), |i, x| (i, x * x));
        let auto = run_jobs(0, inputs.clone(), |i, x| (i, x * x));
        let wide = run_jobs(128, inputs, |i, x| (i, x * x));
        assert_eq!(serial, auto);
        assert_eq!(serial, wide);
    }

    #[test]
    fn empty_input_yields_empty_output() {
        let out: Vec<u32> = run_jobs(8, Vec::<u32>::new(), |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn jobs_may_borrow_the_environment() {
        let base = vec![100u64, 200, 300];
        let out = run_jobs(3, vec![0usize, 1, 2], |_, i| base[i] + 1);
        assert_eq!(out, vec![101, 201, 301]);
    }

    #[test]
    fn panic_payload_is_preserved() {
        let caught = std::panic::catch_unwind(|| {
            run_jobs(4, (0u32..16).collect(), |_, x| {
                if x == 5 {
                    panic!("boom at five");
                }
                x
            })
        });
        let payload = caught.expect_err("pool must re-raise the job panic");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("boom at five"), "unexpected payload: {msg:?}");
    }
}
