//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them from the training hot path. Python never runs here.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes `HloModuleProto`s with
//! 64-bit instruction ids that the crate's xla_extension 0.5.1 rejects;
//! the text parser reassigns ids and round-trips cleanly (see
//! `/opt/xla-example/README.md`).

pub mod pool;

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// A compiled executable plus bookkeeping.
pub struct Artifact {
    pub name: String,
    exe: PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with literal inputs; returns the flattened tuple outputs.
    /// (All our artifacts are lowered with `return_tuple=True`.)
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let out = self.exe.execute::<Literal>(inputs)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// A PJRT CPU client with an artifact cache.
pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
}

impl Runtime {
    /// CPU client rooted at an artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = PjRtClient::cpu()?;
        Ok(Runtime { client, dir: artifacts_dir.as_ref().to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile `<dir>/<name>.hlo.txt`.
    pub fn load(&self, name: &str) -> Result<Artifact> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("loading HLO text {path:?} (run `make artifacts`)"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        Ok(Artifact { name: name.to_string(), exe })
    }

    /// Does the artifact exist on disk? (Tests skip gracefully when the
    /// Python AOT step has not run.)
    pub fn available(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let numel: i64 = dims.iter().product();
    anyhow::ensure!(numel as usize == data.len(), "shape/data mismatch");
    Ok(Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    let numel: i64 = dims.iter().product();
    anyhow::ensure!(numel as usize == data.len(), "shape/data mismatch");
    Ok(Literal::vec1(data).reshape(dims)?)
}

/// Flatten a literal back to f32.
pub fn to_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Default artifacts directory: `$LTP_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("LTP_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        assert!(literal_f32(&[1.0; 3], &[2, 2]).is_err());
    }

    // Full load-and-execute coverage lives in rust/tests/runtime_e2e.rs and
    // is skipped when `make artifacts` has not run.
}
