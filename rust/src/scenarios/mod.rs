//! The **scenario engine**: a registry of named, config-driven end-to-end
//! experiments over the LTP training stack (DESIGN.md §4.3).
//!
//! Each registered [`Scenario`] assembles a topology ([`crate::simnet`]),
//! a protocol matrix (a list of [`crate::ps::ProtoSpec`]s — the default is
//! LTP vs kernel Reno, overridable per run with `--proto` specs), loss and
//! traffic conditions ([`crate::config`], [`crate::ps::BgFlow`]), runs the
//! BSP training loop, and distills every run into a [`CaseResult`]. The
//! whole report is seed-reproducible down to the serialized bytes: the same
//! [`ScenarioParams::seed`] yields a byte-identical JSON report
//! ([`ScenarioReport::render_json`]).
//!
//! The registry doubles as a **conformance matrix**: the integration test
//! `rust/tests/scenarios.rs` iterates [`registry`] and asserts the paper's
//! invariants per scenario —
//!
//! * on incast-class scenarios, LTP's mean batch-synchronization time is
//!   no worse than the TCP baseline's (the paper's headline claim), and
//! * every non-deadline Early Close delivered all critical segments
//!   (paper §III-E).
//!
//! Adding a network condition is one registry entry (plus its builder in
//! `defs.rs`); the conformance test picks it up automatically, so protocol
//! regressions surface as named scenario failures rather than silent
//! figure drift.

mod defs;
pub mod sweep;

use crate::metrics::{Json, Table};
use crate::proto::CloseReason;
use crate::ps::RunReport;
use crate::util::Summary;
use crate::MS;

/// Engine-wide run parameters (everything else is per-scenario config).
#[derive(Debug, Clone)]
pub struct ScenarioParams {
    /// Master seed: every simulation in the scenario derives from it.
    pub seed: u64,
    /// Shrink message sizes / sweep points for interactive & CI runs.
    pub quick: bool,
    /// Protocol-matrix override (`--proto` specs, in order). `None` keeps
    /// each scenario's default matrix — LTP vs Reno for the comparison
    /// scenarios, the whole registry for `proto_matrix`.
    pub protos: Option<Vec<crate::ps::ProtoSpec>>,
    /// Aggregation-topology override (`--agg` specs, in order). `None`
    /// keeps each scenario's default — the single-PS star, whose reports
    /// are byte-identical to the pre-aggregation-API engine. Scenarios
    /// with a fixed fabric (`rack_oversub`, `coexist_ltp_tcp`) and the
    /// fixed matrices ignore the override; star scenarios skip (agg,
    /// degree) points the aggregation rejects (non-divisible workers).
    pub aggs: Option<Vec<crate::ps::AggSpec>>,
    /// Gradient-codec override (`--codec` specs, in order). `None` keeps
    /// the default identity codec, whose reports are byte-identical to
    /// the pre-codec engine. Fixed-matrix scenarios ignore the override;
    /// non-default codecs apply only to single-PS cases (the builder's
    /// topology gate), so other aggregations skip them.
    pub codecs: Option<Vec<crate::codec::CodecSpec>>,
    /// Churn-plane override (`--churn` specs, in order). `None` keeps
    /// each scenario's default — stable membership (`none`), whose
    /// reports are byte-identical to the pre-churn engine. Fixed-matrix
    /// scenarios ignore the override; link-perturbing specs apply only
    /// where the builder's fabric gate admits them, so incompatible
    /// (agg, churn) points are skipped.
    pub churns: Option<Vec<crate::churn::ChurnSpec>>,
}

impl ScenarioParams {
    pub fn new(seed: u64, quick: bool) -> ScenarioParams {
        ScenarioParams { seed, quick, protos: None, aggs: None, codecs: None, churns: None }
    }

    /// The protocol matrix this run sweeps: the `--proto` override, or the
    /// paper's LTP-vs-Reno baseline.
    pub fn matrix(&self) -> Vec<crate::ps::ProtoSpec> {
        self.protos.clone().unwrap_or_else(crate::ps::baseline_matrix)
    }

    /// The aggregation topologies this run sweeps: the `--agg` override,
    /// or the default single PS.
    pub fn aggs(&self) -> Vec<crate::ps::AggSpec> {
        self.aggs.clone().unwrap_or_else(|| vec![crate::ps::default_agg()])
    }

    /// The gradient codecs this run sweeps: the `--codec` override, or
    /// the default identity codec.
    pub fn codecs(&self) -> Vec<crate::codec::CodecSpec> {
        self.codecs.clone().unwrap_or_else(|| vec![crate::codec::default_codec()])
    }

    /// The churn specs this run sweeps: the `--churn` override, or the
    /// default stable membership.
    pub fn churns(&self) -> Vec<crate::churn::ChurnSpec> {
        self.churns.clone().unwrap_or_else(|| vec![crate::churn::default_churn()])
    }
}

impl Default for ScenarioParams {
    fn default() -> ScenarioParams {
        ScenarioParams::new(1, false)
    }
}

/// A named, registered scenario.
pub struct Scenario {
    pub name: &'static str,
    pub summary: &'static str,
    /// Incast-class scenarios must satisfy the paper invariant
    /// "LTP mean BST ≤ the TCP baseline's" (asserted by the conformance
    /// test); calibration scenarios opt out.
    pub incast_class: bool,
    cases: fn(&ScenarioParams) -> Vec<CaseResult>,
}

impl Scenario {
    pub fn run(&self, p: &ScenarioParams) -> ScenarioReport {
        ScenarioReport {
            name: self.name.to_string(),
            seed: p.seed,
            quick: p.quick,
            incast_class: self.incast_class,
            cases: (self.cases)(p),
        }
    }
}

/// The scenario registry. Append entries here (and their builders in
/// `defs.rs`); everything else — CLI, JSON, conformance tests — follows.
pub const REGISTRY: &[Scenario] = &[
    Scenario {
        name: "incast_sweep",
        summary: "N→1 incast degree sweep (2..64 workers) under light wire loss, LTP vs Reno",
        incast_class: true,
        cases: defs::incast_sweep,
    },
    Scenario {
        name: "incast_heavy_loss",
        summary: "8→1 incast at 2% non-congestion loss — the paper's headline regime",
        incast_class: true,
        cases: defs::incast_heavy_loss,
    },
    Scenario {
        name: "rack_oversub",
        summary: "two racks under one aggregation switch, 4:1 oversubscribed trunk",
        incast_class: true,
        cases: defs::rack_oversub,
    },
    Scenario {
        name: "wan_bursty",
        summary: "1 Gbps / 40 ms WAN with Gilbert–Elliott loss bursts (federated edge)",
        incast_class: true,
        cases: defs::wan_bursty,
    },
    Scenario {
        name: "cross_traffic",
        summary: "incast sharing the PS bottleneck with constant-rate background datagrams",
        incast_class: true,
        cases: defs::cross_traffic,
    },
    Scenario {
        name: "coexist_ltp_tcp",
        summary: "LTP training and a TCP bulk flow coexisting on an oversubscribed trunk",
        incast_class: true,
        cases: defs::coexist_ltp_tcp,
    },
    Scenario {
        name: "wan_clean",
        summary: "clean 1 Gbps WAN calibration run (no loss; no invariant asserted)",
        incast_class: false,
        cases: defs::wan_clean,
    },
    // Appended after the original matrix so `scenario all` reports for the
    // scenarios above keep their pre-registry byte layout.
    Scenario {
        name: "proto_matrix",
        summary: "every registered protocol spec over the incast and bursty-WAN fabrics",
        incast_class: true,
        cases: defs::proto_matrix,
    },
    Scenario {
        name: "agg_matrix",
        summary: "aggregation topologies (ps, sharded:n∈{2,4,8}, hier) × {ltp, reno, dctcp} on the 2%-loss incast fabric",
        incast_class: true,
        cases: defs::agg_matrix,
    },
    Scenario {
        name: "accuracy_matrix",
        summary: "native-backend training accuracy: {0,2,5,10}% loss × {ltp, ltp-adaptive, reno} × bubble filling on/off",
        // An accuracy scenario, not a throughput one: messages are tiny
        // (a few KB of MLP gradient), so the BST invariant is not asserted.
        incast_class: false,
        cases: defs::accuracy_matrix,
    },
    Scenario {
        name: "incast_xl",
        summary: "datacenter-scale incast: degrees 256 and 1024 at 2% loss, {ltp, reno, dctcp}",
        incast_class: true,
        cases: defs::incast_xl,
    },
    Scenario {
        name: "compression_matrix",
        summary: "gradient codecs (dense, topk:pct∈{0.1,0.01}) × {ltp, ltp-adaptive, reno} × {0,2,5}% loss, plus tensor-priority scheduling on/off under Early Close",
        // An accuracy/wire-volume scenario over tiny MLP gradients, like
        // `accuracy_matrix`: the BST invariant is not asserted.
        incast_class: false,
        cases: defs::compression_matrix,
    },
    Scenario {
        name: "churn_matrix",
        summary: "elastic membership: {0,5,10}% churn per epoch × {ltp, ltp-adaptive, reno} × stragglers on/off — native-backend accuracy plus a modeled BST part",
        // Mixed accuracy/BST scenario; its churn-specific invariants
        // (LTP vs Reno under churn, accuracy vs the stable lossless
        // baseline) live in the conformance test, not the generic
        // incast-class pairing.
        incast_class: false,
        cases: defs::churn_matrix,
    },
];

/// The registry (function form, for iteration symmetry with `find`).
pub fn registry() -> &'static [Scenario] {
    REGISTRY
}

/// Look a scenario up by name.
pub fn find(name: &str) -> Option<&'static Scenario> {
    REGISTRY.iter().find(|s| s.name == name)
}

/// One (topology, protocol, degree) run distilled for the report.
#[derive(Debug, Clone)]
pub struct CaseResult {
    /// E.g. `ltp/w8` (plus an agg prefix for non-default aggregations:
    /// `sharded:n=4/ltp/w8`).
    pub label: String,
    pub proto: String,
    /// Canonical aggregation spec the case ran under (`ps` by default).
    pub agg: String,
    /// Per-aggregator breakdown; empty for single-aggregator runs.
    pub shards: Vec<crate::ps::ShardStat>,
    pub workers: usize,
    /// BSP iterations completed within the horizon.
    pub iters: usize,
    pub mean_bst_ms: f64,
    pub p50_bst_ms: f64,
    pub p99_bst_ms: f64,
    /// Mean fraction of gradient data delivered (1.0 = lossless).
    pub mean_delivered: f64,
    pub drops_queue: u64,
    pub drops_random: u64,
    /// Gather-direction retransmitted packets, all workers.
    pub retransmits: u64,
    /// Gather-direction packets sent, all workers (retransmit-rate
    /// denominator).
    pub gather_pkts: u64,
    /// LTP gather closes that were not deadline-forced.
    pub nondeadline_closes: u64,
    pub deadline_closes: u64,
    /// True iff every non-deadline close delivered all critical segments
    /// (vacuously true for TCP).
    pub criticals_ok: bool,
    /// Bytes moved by background flows during the run (0 if none).
    pub bg_bytes: u64,
    pub total_time_ms: f64,
    /// Simulator events processed by this run (deterministic; the bench
    /// report divides these by wall-clock for events/sec).
    pub sim_events: u64,
    /// Deterministic training outcome — present only for backend-attached
    /// runs (`accuracy_matrix`), absent from every modeled-compute case so
    /// pre-compute-plane reports stay byte-identical.
    pub train: Option<crate::compute::TrainStats>,
    /// Canonical gradient-codec spec the case ran under (`dense` by
    /// default).
    pub codec: String,
    /// Gather-direction application bytes on the wire across the whole
    /// run — the codec's size claim ([`RunReport::gather_wire_bytes`]).
    pub gather_wire_bytes: u64,
    /// Mean tensor-priority-weighted delivered importance; `None` under
    /// the default codec.
    pub mean_importance: Option<f64>,
    /// Canonical churn spec the case ran under (`none` by default).
    pub churn: String,
    /// Fewest barrier members over the run (equals `workers` when stable).
    pub active_min: usize,
    /// Most barrier members over the run.
    pub active_max: usize,
}

impl CaseResult {
    /// Distill a finished training run.
    pub fn from_report(label: impl Into<String>, workers: usize, r: &RunReport) -> CaseResult {
        let bst = Summary::of(&r.bst_values_ms());
        let nondeadline =
            r.closes.iter().filter(|c| c.reason != CloseReason::Deadline).count() as u64;
        let deadline = r.closes.len() as u64 - nondeadline;
        let criticals_ok = r
            .closes
            .iter()
            .filter(|c| c.reason != CloseReason::Deadline)
            .all(|c| c.criticals_ok);
        CaseResult {
            label: label.into(),
            proto: r.proto.clone(),
            agg: r.agg.clone(),
            shards: r.shards.clone(),
            workers,
            iters: r.iters.len(),
            mean_bst_ms: bst.mean,
            p50_bst_ms: bst.p50,
            p99_bst_ms: bst.p99,
            mean_delivered: r.mean_delivered(),
            drops_queue: r.net.drops_queue,
            drops_random: r.net.drops_random,
            retransmits: r.retransmits,
            gather_pkts: r.gather_pkts,
            nondeadline_closes: nondeadline,
            deadline_closes: deadline,
            criticals_ok,
            bg_bytes: r.bg_bytes.iter().sum(),
            total_time_ms: r.total_time as f64 / MS as f64,
            sim_events: r.sim_events,
            train: r.train,
            codec: r.codec.clone(),
            gather_wire_bytes: r.gather_wire_bytes,
            mean_importance: r.mean_importance,
            churn: r.churn.clone(),
            active_min: r.active_min,
            active_max: r.active_max,
        }
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("label", self.label.as_str().into()),
            ("proto", self.proto.as_str().into()),
            ("workers", self.workers.into()),
            ("iters", self.iters.into()),
            ("mean_bst_ms", self.mean_bst_ms.into()),
            ("p50_bst_ms", self.p50_bst_ms.into()),
            ("p99_bst_ms", self.p99_bst_ms.into()),
            ("mean_delivered", self.mean_delivered.into()),
            ("drops_queue", self.drops_queue.into()),
            ("drops_random", self.drops_random.into()),
            ("retransmits", self.retransmits.into()),
            ("gather_pkts", self.gather_pkts.into()),
            ("nondeadline_closes", self.nondeadline_closes.into()),
            ("deadline_closes", self.deadline_closes.into()),
            ("criticals_ok", self.criticals_ok.into()),
            ("bg_bytes", self.bg_bytes.into()),
            ("total_time_ms", self.total_time_ms.into()),
            ("sim_events", self.sim_events.into()),
        ];
        // Backend-attached runs append their training outcome; cases
        // without a backend keep the original key set.
        if let Some(t) = &self.train {
            pairs.push((
                "train",
                Json::obj(vec![
                    ("final_loss", Json::Num(t.final_loss as f64)),
                    ("accuracy", Json::Num(t.accuracy)),
                    (
                        "iters_to_target",
                        t.iters_to_target.map(Json::from).unwrap_or(Json::Null),
                    ),
                ]),
            ));
        }
        // Codec-shaped runs append their codec block; default-`dense`
        // cases keep the original key set, so pre-codec reports stay
        // byte-identical.
        if self.codec != "dense" {
            pairs.push(("codec", self.codec.as_str().into()));
            pairs.push(("gather_wire_bytes", self.gather_wire_bytes.into()));
            pairs.push((
                "mean_importance",
                self.mean_importance.map(Json::Num).unwrap_or(Json::Null),
            ));
        }
        // Churned runs append their churn block; stable (`none`) cases
        // keep the original key set, so pre-churn reports stay
        // byte-identical.
        if self.churn != "none" {
            pairs.push(("churn", self.churn.as_str().into()));
            pairs.push(("active_min", self.active_min.into()));
            pairs.push(("active_max", self.active_max.into()));
        }
        // Multi-aggregator runs append their spec and per-aggregator
        // breakdown; single-PS cases keep the original key set, so
        // pre-aggregation-API reports stay byte-identical.
        if !self.shards.is_empty() {
            pairs.push(("agg", self.agg.as_str().into()));
            pairs.push((
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("label", s.label.as_str().into()),
                                ("bst_ns", s.bst_ns.into()),
                                ("delivered", s.delivered.into()),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        Json::obj(pairs)
    }
}

/// A scenario's full, deterministic result.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub name: String,
    pub seed: u64,
    pub quick: bool,
    pub incast_class: bool,
    pub cases: Vec<CaseResult>,
}

impl ScenarioReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", self.name.as_str().into()),
            ("seed", self.seed.into()),
            ("quick", self.quick.into()),
            ("incast_class", self.incast_class.into()),
            ("cases", Json::Arr(self.cases.iter().map(|c| c.to_json()).collect())),
        ])
    }

    /// Pretty JSON; byte-identical across runs with the same seed.
    pub fn render_json(&self) -> String {
        self.to_json().render_pretty()
    }

    /// `(loss-tolerant, reliable-baseline)` case pairs matched by worker
    /// count **and aggregation topology** — the unit the incast-class
    /// invariant is checked over (comparing protocols across different
    /// fabrics would be apples to oranges). The protocol kind comes from
    /// the registry (a case's proto is its canonical spec string), not
    /// from matching on names.
    pub fn invariant_pairs(&self) -> Vec<(&CaseResult, &CaseResult)> {
        let lt = |c: &CaseResult| {
            crate::ps::parse_proto(&c.proto).map(|s| s.is_loss_tolerant()).unwrap_or(false)
        };
        let mut out = Vec::new();
        for l in self.cases.iter().filter(|c| lt(c)) {
            if let Some(b) = self
                .cases
                .iter()
                .find(|c| !lt(c) && c.workers == l.workers && c.agg == l.agg)
            {
                out.push((l, b));
            }
        }
        out
    }

    /// Human-readable table (mirrors the JSON fields that matter). Cases
    /// that trained a backend grow a final-accuracy column.
    pub fn print_table(&self) {
        let with_train = self.cases.iter().any(|c| c.train.is_some());
        let mut headers = vec![
            "case",
            "iters",
            "mean BST(ms)",
            "p99 BST(ms)",
            "delivered",
            "drops q/r",
            "retx",
            "criticals",
        ];
        if with_train {
            headers.push("final acc");
        }
        let mut t = Table::new(headers);
        for c in &self.cases {
            let mut row = vec![
                c.label.clone(),
                c.iters.to_string(),
                format!("{:.2}", c.mean_bst_ms),
                format!("{:.2}", c.p99_bst_ms),
                format!("{:.1}%", c.mean_delivered * 100.0),
                format!("{}/{}", c.drops_queue, c.drops_random),
                c.retransmits.to_string(),
                if c.criticals_ok { "ok".to_string() } else { "LOST".to_string() },
            ];
            if with_train {
                row.push(
                    c.train
                        .map(|t| format!("{:.1}%", t.accuracy * 100.0))
                        .unwrap_or_else(|| "—".to_string()),
                );
            }
            t.row(row);
        }
        t.emit(
            &format!("scenario_{}", self.name),
            &format!("Scenario `{}` (seed {})", self.name, self.seed),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_well_formed() {
        assert!(REGISTRY.len() >= 6, "need ≥6 scenarios, have {}", REGISTRY.len());
        let mut names: Vec<&str> = REGISTRY.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), REGISTRY.len(), "scenario names must be unique");
        assert!(find("incast_sweep").is_some());
        assert!(find("no_such_scenario").is_none());
    }

    #[test]
    fn case_result_distills_report() {
        use crate::config::Workload;
        use crate::ps::{parse_proto, RunBuilder};
        use crate::simnet::LossModel;
        let r = RunBuilder::modeled(parse_proto("ltp").unwrap(), Workload::Micro, 2)
            .iters(2)
            .loss(LossModel::Bernoulli { p: 0.01 })
            .run()
            .unwrap();
        let c = CaseResult::from_report("ltp/w2", 2, &r);
        assert_eq!(c.proto, "ltp");
        assert_eq!(c.iters, 2);
        assert!(c.mean_bst_ms > 0.0);
        assert_eq!(c.nondeadline_closes + c.deadline_closes, r.closes.len() as u64);
        // JSON carries the same numbers.
        let json = c.to_json().render();
        assert!(json.contains("\"label\":\"ltp/w2\""), "{json}");
        assert!(json.contains("\"workers\":2"), "{json}");
    }
}
