//! Scenario builders: each assembles `TrainingCfg`s (topology, loss,
//! background traffic, protocol matrix), runs them, and returns the
//! distilled cases. All sizes have a `quick` variant so the CI conformance
//! matrix stays interactive.
//!
//! Conventions: every incast-class scenario runs the same condition under
//! LTP **and** TCP Reno (the kernel-default baseline the paper leads
//! with), labeled `<proto>/w<degree>`, so the conformance test can pair
//! them by worker count.

use super::{CaseResult, ScenarioParams};
use crate::cc::CcAlgo;
use crate::config::{NetEnv, Workload};
use crate::grad::Manifest;
use crate::ps::{run_training, BgFlow, Proto, Topo, TrainingCfg};
use crate::simnet::LossModel;
use crate::wire::LTP_MSS;
use crate::{Nanos, SEC};

/// The two-protocol matrix every incast-class scenario runs.
const MATRIX: [Proto; 2] = [Proto::Ltp, Proto::Tcp(CcAlgo::Reno)];

/// A modeled config with scenario-appropriate sizing: `bytes` gradient
/// bytes per worker per iteration, scenario-seeded, bounded horizon.
fn base_cfg(proto: Proto, workers: usize, bytes: u64, p: &ScenarioParams) -> TrainingCfg {
    let mut cfg = TrainingCfg::modeled(proto, Workload::Micro, workers);
    cfg.seed = p.seed;
    // ≥3 iterations so the means are not dominated by iteration 0, where
    // LTP's thresholds are still bootstrapping (reliable-mode gathers).
    cfg.iters = if p.quick { 3 } else { 4 };
    cfg.model_bytes = bytes;
    cfg.critical =
        Manifest::synthetic(bytes, 20).critical_segments(Manifest::aligned_payload(LTP_MSS));
    cfg.batches_per_epoch = 2; // exercise one epoch-threshold update
    cfg.horizon = 600 * SEC;
    cfg
}

/// Total incast volume per iteration, split across the workers — keeps the
/// degree sweep's cost flat as the degree grows.
fn per_worker_bytes(workers: usize, p: &ScenarioParams) -> u64 {
    let total: u64 = if p.quick { 8_000_000 } else { 32_000_000 };
    (total / workers as u64).max(64 * 1024)
}

fn run_case(label: String, workers: usize, cfg: &TrainingCfg) -> CaseResult {
    CaseResult::from_report(label, workers, &run_training(cfg))
}

/// `incast_sweep`: N→1 incast at degrees 2..64 under 0.5 % wire loss.
pub(super) fn incast_sweep(p: &ScenarioParams) -> Vec<CaseResult> {
    let degrees: &[usize] = if p.quick { &[2, 8, 32] } else { &[2, 4, 8, 16, 32, 64] };
    let mut out = Vec::new();
    for &w in degrees {
        for proto in MATRIX {
            let mut cfg = base_cfg(proto, w, per_worker_bytes(w, p), p);
            cfg.link = cfg.link.with_loss(LossModel::Bernoulli { p: 0.005 });
            out.push(run_case(format!("{}/w{w}", proto.name()), w, &cfg));
        }
    }
    out
}

/// `incast_heavy_loss`: the paper's headline regime — 8→1 incast with 2 %
/// non-congestion loss, where loss-based TCP collapses.
pub(super) fn incast_heavy_loss(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 8;
    let mut out = Vec::new();
    for proto in MATRIX {
        let mut cfg = base_cfg(proto, w, per_worker_bytes(w, p), p);
        cfg.link = cfg.link.with_loss(LossModel::Bernoulli { p: 0.02 });
        out.push(run_case(format!("{}/w{w}", proto.name()), w, &cfg));
    }
    out
}

/// `rack_oversub`: 8 workers split across two racks behind an aggregation
/// switch whose trunk carries rack 1's four edges at 1× edge rate (4:1
/// oversubscription), plus light wire loss.
pub(super) fn rack_oversub(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 8;
    let mut out = Vec::new();
    for proto in MATRIX {
        let mut cfg = base_cfg(proto, w, per_worker_bytes(w, p), p);
        cfg.link = cfg.link.with_loss(LossModel::Bernoulli { p: 0.002 });
        // Trunk: same rate as one edge, deeper buffer (a real agg port).
        let trunk = cfg.link.with_queue(2 * 1024 * 1024);
        cfg.topo = Topo::TwoRack { rack0_workers: 4, trunk };
        out.push(run_case(format!("{}/w{w}", proto.name()), w, &cfg));
    }
    out
}

/// `wan_bursty`: 4 edge workers on a 1 Gbps / 40 ms RTT WAN with
/// Gilbert–Elliott loss bursts (the federated-learning regime).
pub(super) fn wan_bursty(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 4;
    let bytes: u64 = if p.quick { 1_000_000 } else { 2_000_000 };
    let mut out = Vec::new();
    for proto in MATRIX {
        let mut cfg = base_cfg(proto, w, bytes, p);
        cfg.link = NetEnv::WanBursty.link();
        cfg.deadline_slack = NetEnv::WanBursty.deadline_slack();
        out.push(run_case(format!("{}/w{w}", proto.name()), w, &cfg));
    }
    out
}

/// `cross_traffic`: 8→1 incast on a clean fabric whose PS downlink also
/// carries 4 Gbps of background datagrams — congestion-only pressure.
pub(super) fn cross_traffic(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 8;
    const BG_RATE: u64 = 4_000_000_000; // 40 % of the 10 Gbps bottleneck
    const BG_STOP: Nanos = 30 * SEC;
    let mut out = Vec::new();
    for proto in MATRIX {
        let mut cfg = base_cfg(proto, w, per_worker_bytes(w, p), p);
        cfg.bg = vec![BgFlow::udp_to_ps(BG_RATE, BG_STOP)];
        out.push(run_case(format!("{}/w{w}", proto.name()), w, &cfg));
    }
    out
}

/// `coexist_ltp_tcp`: training shares an oversubscribed two-rack trunk
/// with a cubic bulk transfer — the mixed-protocol datacenter case.
pub(super) fn coexist_ltp_tcp(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 8;
    let bulk_bytes: u64 = if p.quick { 50_000_000 } else { 200_000_000 };
    let mut out = Vec::new();
    for proto in MATRIX {
        let mut cfg = base_cfg(proto, w, per_worker_bytes(w, p), p);
        cfg.link = cfg.link.with_loss(LossModel::Bernoulli { p: 0.002 });
        let trunk = cfg.link.with_queue(2 * 1024 * 1024);
        cfg.topo = Topo::TwoRack { rack0_workers: 4, trunk };
        cfg.bg = vec![BgFlow::tcp_bulk(CcAlgo::Cubic, bulk_bytes)];
        out.push(run_case(format!("{}/w{w}", proto.name()), w, &cfg));
    }
    out
}

/// `wan_clean`: lossless 1 Gbps WAN calibration — no invariant asserted,
/// this pins the baseline the lossy WAN scenarios are read against.
pub(super) fn wan_clean(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 4;
    let bytes: u64 = if p.quick { 1_000_000 } else { 2_000_000 };
    let mut out = Vec::new();
    for proto in MATRIX {
        let mut cfg = base_cfg(proto, w, bytes, p);
        cfg.link = NetEnv::Wan1g.link();
        cfg.deadline_slack = NetEnv::Wan1g.deadline_slack();
        out.push(run_case(format!("{}/w{w}", proto.name()), w, &cfg));
    }
    out
}
