//! Scenario builders: each assembles training runs through [`RunBuilder`]
//! (topology, loss, background traffic, protocol matrix), runs them, and
//! returns the distilled cases. All sizes have a `quick` variant so the CI
//! conformance matrix stays interactive.
//!
//! Conventions: every incast-class scenario runs the same condition under
//! each protocol of [`ScenarioParams::matrix`] — by default LTP **and**
//! TCP Reno (the kernel-default baseline the paper leads with), or
//! whatever `--proto` specs the caller supplied — crossed with each
//! aggregation topology of [`ScenarioParams::aggs`] (default: the single
//! PS). Cases are labeled `<proto>/w<degree>` under the default
//! aggregation (the original golden-byte layout) and
//! `<agg>/<proto>/w<degree>` otherwise, so the conformance test can pair
//! loss-tolerant cases with reliable baselines by (worker count,
//! aggregation). `proto_matrix` and `agg_matrix` instead sweep their
//! whole registries ([`crate::ps::registry_matrix`], the `--agg` spec
//! set) over fixed fabrics.

use super::{CaseResult, ScenarioParams};
use crate::cc::CcAlgo;
use crate::churn::{parse_churn, ChurnSpec};
use crate::codec::{parse_codec, CodecSpec};
use crate::compute::parse_backend;
use crate::config::{NetEnv, Workload};
use crate::ps::{parse_agg, parse_proto, AggSpec, BgFlow, ProtoSpec, RunBuilder, Topo};
use crate::simnet::LossModel;
use crate::{Nanos, SEC};

/// A modeled run with scenario-appropriate sizing: `bytes` gradient bytes
/// per worker per iteration, scenario-seeded, bounded horizon.
fn base(proto: &ProtoSpec, workers: usize, bytes: u64, p: &ScenarioParams) -> RunBuilder {
    RunBuilder::modeled(proto.clone(), Workload::Micro, workers)
        .seed(p.seed)
        // ≥3 iterations so the means are not dominated by iteration 0,
        // where LTP's thresholds are still bootstrapping (reliable-mode
        // gathers).
        .iters(if p.quick { 3 } else { 4 })
        .model_bytes(bytes)
        .critical_tensors(20)
        .batches_per_epoch(2) // exercise one epoch-threshold update
        .horizon(600 * SEC)
}

/// Total incast volume per iteration, split across the workers — keeps the
/// degree sweep's cost flat as the degree grows.
fn per_worker_bytes(workers: usize, p: &ScenarioParams) -> u64 {
    let total: u64 = if p.quick { 8_000_000 } else { 32_000_000 };
    (total / workers as u64).max(64 * 1024)
}

fn run_case(label: String, workers: usize, b: RunBuilder) -> CaseResult {
    let report = b.run().expect("scenario configurations are valid");
    CaseResult::from_report(label, workers, &report)
}

/// Case label: `<proto>/w<degree>` for the default single PS (the
/// original, golden-byte layout) and `<agg>/<proto>/w<degree>` otherwise.
fn case_label(agg: &AggSpec, proto: &ProtoSpec, w: usize) -> String {
    if agg.name() == "ps" {
        format!("{}/w{w}", proto.name())
    } else {
        format!("{}/{}/w{w}", agg.name(), proto.name())
    }
}

/// The `--agg` specs applicable to a star scenario at degree `w`: specs
/// whose divisibility/size rules the combination satisfies (an
/// `incast_sweep` degree a sharded spec cannot divide is skipped, not an
/// error — the CLI validates the spec itself up front).
fn applicable_aggs(p: &ScenarioParams, w: usize, bytes: u64) -> Vec<AggSpec> {
    p.aggs().into_iter().filter(|a| a.validate(w, bytes, &Topo::Star).is_ok()).collect()
}

/// The `--codec` specs applicable under aggregation `agg`: non-default
/// codecs require the single-PS topology (the builder's gate), so other
/// aggregations skip them rather than error.
fn applicable_codecs(p: &ScenarioParams, agg: &AggSpec) -> Vec<CodecSpec> {
    p.codecs().into_iter().filter(|c| c.is_default() || agg.name() == "ps").collect()
}

/// Case label with an optional codec prefix: non-default codecs prepend
/// their canonical spec, so `--codec`-free runs keep the golden layout.
fn codec_label(codec: &CodecSpec, label: String) -> String {
    if codec.is_default() {
        label
    } else {
        format!("{}/{label}", codec.name())
    }
}

/// The `--churn` specs applicable under aggregation `agg`: link-perturbing
/// specs need a builder-owned star fabric (the builder's gate), which the
/// `hier` aggregation does not provide, so those points are skipped rather
/// than error. Membership-only churn (and the default `none`) applies
/// everywhere.
fn applicable_churns(p: &ScenarioParams, agg: &AggSpec) -> Vec<ChurnSpec> {
    let hier = agg.name() == "hier" || agg.name().starts_with("hier:");
    p.churns().into_iter().filter(|c| !c.perturbs_links() || !hier).collect()
}

/// Case label with an optional churn prefix: non-default churn specs
/// prepend their canonical spec, so `--churn`-free runs keep the golden
/// layout.
fn churn_label(churn: &ChurnSpec, label: String) -> String {
    if churn.is_default() {
        label
    } else {
        format!("{}/{label}", churn.name())
    }
}

/// `incast_sweep`: N→1 incast at degrees 2..64 under 0.5 % wire loss.
pub(super) fn incast_sweep(p: &ScenarioParams) -> Vec<CaseResult> {
    let degrees: &[usize] = if p.quick { &[2, 8, 32] } else { &[2, 4, 8, 16, 32, 64] };
    let mut out = Vec::new();
    for &w in degrees {
        let bytes = per_worker_bytes(w, p);
        for agg in applicable_aggs(p, w, bytes) {
            for proto in p.matrix() {
                for codec in applicable_codecs(p, &agg) {
                    for churn in applicable_churns(p, &agg) {
                        let b = base(&proto, w, bytes, p)
                            .agg(agg.clone())
                            .codec(codec.clone())
                            .churn(churn.clone())
                            .loss(LossModel::Bernoulli { p: 0.005 });
                        out.push(run_case(
                            churn_label(
                                &churn,
                                codec_label(&codec, case_label(&agg, &proto, w)),
                            ),
                            w,
                            b,
                        ));
                    }
                }
            }
        }
    }
    out
}

/// `incast_heavy_loss`: the paper's headline regime — 8→1 incast with 2 %
/// non-congestion loss, where loss-based TCP collapses.
pub(super) fn incast_heavy_loss(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 8;
    let bytes = per_worker_bytes(w, p);
    let mut out = Vec::new();
    for agg in applicable_aggs(p, w, bytes) {
        for proto in p.matrix() {
            for codec in applicable_codecs(p, &agg) {
                for churn in applicable_churns(p, &agg) {
                    let b = base(&proto, w, bytes, p)
                        .agg(agg.clone())
                        .codec(codec.clone())
                        .churn(churn.clone())
                        .loss(LossModel::Bernoulli { p: 0.02 });
                    out.push(run_case(
                        churn_label(&churn, codec_label(&codec, case_label(&agg, &proto, w))),
                        w,
                        b,
                    ));
                }
            }
        }
    }
    out
}

/// `rack_oversub`: 8 workers split across two racks behind an aggregation
/// switch whose trunk carries rack 1's four edges at 1× edge rate (4:1
/// oversubscription), plus light wire loss. The fabric is fixed, so the
/// `--agg` override does not apply (compare with `agg_matrix`'s `hier`
/// cases for aggregation-aware rack deployments).
pub(super) fn rack_oversub(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 8;
    let mut out = Vec::new();
    for proto in p.matrix() {
        let b = base(&proto, w, per_worker_bytes(w, p), p)
            .loss(LossModel::Bernoulli { p: 0.002 });
        // Trunk: same rate as one edge, deeper buffer (a real agg port).
        let trunk = b.link_cfg().with_queue(2 * 1024 * 1024);
        out.push(run_case(format!("{}/w{w}", proto.name()), w, b.two_rack(4, trunk)));
    }
    out
}

/// `wan_bursty`: 4 edge workers on a 1 Gbps / 40 ms RTT WAN with
/// Gilbert–Elliott loss bursts (the federated-learning regime).
pub(super) fn wan_bursty(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 4;
    let bytes: u64 = if p.quick { 1_000_000 } else { 2_000_000 };
    let mut out = Vec::new();
    for agg in applicable_aggs(p, w, bytes) {
        for proto in p.matrix() {
            for codec in applicable_codecs(p, &agg) {
                for churn in applicable_churns(p, &agg) {
                    let b = base(&proto, w, bytes, p)
                        .agg(agg.clone())
                        .codec(codec.clone())
                        .churn(churn.clone())
                        .net_env(NetEnv::WanBursty);
                    out.push(run_case(
                        churn_label(&churn, codec_label(&codec, case_label(&agg, &proto, w))),
                        w,
                        b,
                    ));
                }
            }
        }
    }
    out
}

/// `cross_traffic`: 8→1 incast on a clean fabric whose PS downlink also
/// carries 4 Gbps of background datagrams — congestion-only pressure.
pub(super) fn cross_traffic(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 8;
    const BG_RATE: u64 = 4_000_000_000; // 40 % of the 10 Gbps bottleneck
    const BG_STOP: Nanos = 30 * SEC;
    let bytes = per_worker_bytes(w, p);
    let mut out = Vec::new();
    for agg in applicable_aggs(p, w, bytes) {
        for proto in p.matrix() {
            for codec in applicable_codecs(p, &agg) {
                for churn in applicable_churns(p, &agg) {
                    let b = base(&proto, w, bytes, p)
                        .agg(agg.clone())
                        .codec(codec.clone())
                        .churn(churn.clone())
                        .bg(BgFlow::udp_to_ps(BG_RATE, BG_STOP));
                    out.push(run_case(
                        churn_label(&churn, codec_label(&codec, case_label(&agg, &proto, w))),
                        w,
                        b,
                    ));
                }
            }
        }
    }
    out
}

/// `coexist_ltp_tcp`: training shares an oversubscribed two-rack trunk
/// with a cubic bulk transfer — the mixed-protocol datacenter case.
pub(super) fn coexist_ltp_tcp(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 8;
    let bulk_bytes: u64 = if p.quick { 50_000_000 } else { 200_000_000 };
    let mut out = Vec::new();
    for proto in p.matrix() {
        let b = base(&proto, w, per_worker_bytes(w, p), p)
            .loss(LossModel::Bernoulli { p: 0.002 });
        let trunk = b.link_cfg().with_queue(2 * 1024 * 1024);
        let b = b.two_rack(4, trunk).bg(BgFlow::tcp_bulk(CcAlgo::Cubic, bulk_bytes));
        out.push(run_case(format!("{}/w{w}", proto.name()), w, b));
    }
    out
}

/// `wan_clean`: lossless 1 Gbps WAN calibration — no invariant asserted,
/// this pins the baseline the lossy WAN scenarios are read against.
pub(super) fn wan_clean(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 4;
    let bytes: u64 = if p.quick { 1_000_000 } else { 2_000_000 };
    let mut out = Vec::new();
    for agg in applicable_aggs(p, w, bytes) {
        for proto in p.matrix() {
            for codec in applicable_codecs(p, &agg) {
                for churn in applicable_churns(p, &agg) {
                    let b = base(&proto, w, bytes, p)
                        .agg(agg.clone())
                        .codec(codec.clone())
                        .churn(churn.clone())
                        .net_env(NetEnv::Wan1g);
                    out.push(run_case(
                        churn_label(&churn, codec_label(&codec, case_label(&agg, &proto, w))),
                        w,
                        b,
                    ));
                }
            }
        }
    }
    out
}

/// `proto_matrix`: every matrix-flagged protocol in the registry — at the
/// time of writing reno, cubic, dctcp, bbr, ltp, and ltp-adaptive — over
/// two fabrics: the 8→1 heavy-loss incast and the bursty WAN. Adding a
/// protocol to [`crate::ps::PROTO_REGISTRY`] adds its column here with no
/// other code change; `--proto` overrides are deliberately ignored so the
/// scenario always reflects the whole registry.
pub(super) fn proto_matrix(p: &ScenarioParams) -> Vec<CaseResult> {
    let mut out = Vec::new();
    let w = 8;
    for proto in crate::ps::registry_matrix() {
        let b = base(&proto, w, per_worker_bytes(w, p), p)
            .loss(LossModel::Bernoulli { p: 0.02 });
        out.push(run_case(format!("incast/{}/w{w}", proto.name()), w, b));
    }
    let w = 4;
    let bytes: u64 = if p.quick { 1_000_000 } else { 2_000_000 };
    for proto in crate::ps::registry_matrix() {
        let b = base(&proto, w, bytes, p).net_env(NetEnv::WanBursty);
        out.push(run_case(format!("wan/{}/w{w}", proto.name()), w, b));
    }
    out
}

/// `accuracy_matrix`: the paper's *no-accuracy-sacrifice* claim, made
/// measurable (ISSUE 5). Real training on the `native` backend — an
/// 8-worker incast over the rack fabric — swept over {0, 2, 5, 10} %
/// wire loss × {ltp, ltp-adaptive, reno} × bubble filling {on, off}
/// (`native` vs `native:fill=off`: masked-mean denominators count only
/// delivered elements vs every contributor). Each case records the
/// deterministic `train` block (final eval loss, accuracy,
/// iters-to-target); the conformance test asserts that LTP with bubble
/// filling at 2 % loss lands within 1 % absolute accuracy of the
/// lossless reliable baseline. Reliable rows double as the lossless
/// reference at every rate (TCP delivers 100 % whatever the wire does).
/// `--proto`/`--agg` overrides are deliberately ignored so the scenario
/// always reflects the whole matrix; labels read `<bf|nobf>/<proto>/l<p>`.
///
/// Appended after the original 24-case matrix (keeping its byte layout):
/// a codec × loss × fill crossing — `topk:pct=0.1` under LTP at every
/// loss rate, bubble filling on and off, labeled
/// `topk10/<bf|nobf>/ltp/l<p>` — asserting the no-sacrifice bound
/// survives a ~10× wire reduction.
pub(super) fn accuracy_matrix(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 8;
    let iters: u64 = if p.quick { 16 } else { 28 };
    let losses: &[(u32, f64)] = &[(0, 0.0), (2, 0.02), (5, 0.05), (10, 0.10)];
    let protos: Vec<ProtoSpec> = ["ltp", "ltp-adaptive", "reno"]
        .iter()
        .map(|s| parse_proto(s).expect("accuracy_matrix protocols parse against the registry"))
        .collect();
    let backends = [
        ("bf", parse_backend("native").expect("registry default")),
        ("nobf", parse_backend("native:fill=off").expect("registry default")),
    ];
    let mut out = Vec::new();
    for (tag, backend) in &backends {
        for &(pct, rate) in losses {
            for proto in &protos {
                let mut b = RunBuilder::modeled(proto.clone(), Workload::Micro, w)
                    .seed(p.seed)
                    .iters(iters)
                    .batches_per_epoch(4)
                    .backend(backend.clone())
                    .horizon(600 * SEC);
                if rate > 0.0 {
                    b = b.loss(LossModel::Bernoulli { p: rate });
                }
                out.push(run_case(format!("{tag}/{}/l{pct}", proto.name()), w, b));
            }
        }
    }
    let topk = parse_codec("topk:pct=0.1").expect("registry codec");
    let ltp = parse_proto("ltp").expect("registry default");
    for (tag, backend) in &backends {
        for &(pct, rate) in losses {
            let mut b = RunBuilder::modeled(ltp.clone(), Workload::Micro, w)
                .seed(p.seed)
                .iters(iters)
                .batches_per_epoch(4)
                .backend(backend.clone())
                .codec(topk.clone())
                .horizon(600 * SEC);
            if rate > 0.0 {
                b = b.loss(LossModel::Bernoulli { p: rate });
            }
            out.push(run_case(format!("topk10/{tag}/ltp/l{pct}"), w, b));
        }
    }
    out
}

/// `compression_matrix`: the codec subsystem's conformance surface
/// (DESIGN.md §1.4). Two parts:
///
/// * **Part A — accuracy vs wire volume.** Native-backend training on a
///   4-worker incast, {`dense`, `topk:pct=0.1`, `topk:pct=0.01`} ×
///   {ltp, ltp-adaptive, reno} × {0, 2, 5} % wire loss. The conformance
///   test asserts `topk:pct=0.1` + LTP + bubble filling at 2 % loss lands
///   within 1 % absolute accuracy of the lossless dense baseline while
///   cutting gather bytes-on-wire ≥5×. Labels read
///   `<dense|topk10|topk1>/<proto>/l<p>`.
/// * **Part B — tensor-priority scheduling.** Modeled 8→1 incast at 2 %
///   loss under LTP, priority off/on (`dense:priority=…`) plus the
///   combined `topk:pct=0.1,priority=on`: scheduled runs must strictly
///   beat the unscheduled one on mean delivered importance (Early Close
///   sheds only the low-value head). Labels read `<sched-…>/ltp/w8`.
///
/// `--proto`/`--agg`/`--codec` overrides are deliberately ignored so the
/// scenario always reflects the whole matrix.
pub(super) fn compression_matrix(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 4;
    let iters: u64 = if p.quick { 16 } else { 28 };
    let losses: &[(u32, f64)] = &[(0, 0.0), (2, 0.02), (5, 0.05)];
    let codecs = [
        ("dense", parse_codec("dense").expect("registry default")),
        ("topk10", parse_codec("topk:pct=0.1").expect("registry codec")),
        ("topk1", parse_codec("topk:pct=0.01").expect("registry codec")),
    ];
    let protos: Vec<ProtoSpec> = ["ltp", "ltp-adaptive", "reno"]
        .iter()
        .map(|s| {
            parse_proto(s).expect("compression_matrix protocols parse against the registry")
        })
        .collect();
    let backend = parse_backend("native").expect("registry default");
    let mut out = Vec::new();
    for (tag, codec) in &codecs {
        for proto in &protos {
            for &(pct, rate) in losses {
                let mut b = RunBuilder::modeled(proto.clone(), Workload::Micro, w)
                    .seed(p.seed)
                    .iters(iters)
                    .batches_per_epoch(4)
                    .backend(backend.clone())
                    .codec(codec.clone())
                    .horizon(600 * SEC);
                if rate > 0.0 {
                    b = b.loss(LossModel::Bernoulli { p: rate });
                }
                out.push(run_case(format!("{tag}/{}/l{pct}", proto.name()), w, b));
            }
        }
    }
    // Part B: scheduling changes which segments survive Early Close, so
    // it is measured on the modeled incast (real message sizes), not the
    // tiny MLP gradient.
    let w = 8;
    let ltp = parse_proto("ltp").expect("registry default");
    let scheds = [
        ("sched-off", "dense:priority=off"),
        ("sched-on", "dense:priority=on"),
        ("topk10-sched", "topk:pct=0.1,priority=on"),
    ];
    for (tag, spec) in scheds {
        let b = base(&ltp, w, per_worker_bytes(w, p), p)
            .codec(parse_codec(spec).expect("registry codec"))
            .loss(LossModel::Bernoulli { p: 0.02 });
        out.push(run_case(format!("{tag}/ltp/w{w}"), w, b));
    }
    out
}

/// `incast_xl`: the paper's headline regime pushed to datacenter scale —
/// N→1 incast at degrees 256 and 1024 under 2 % non-congestion loss,
/// {ltp, reno, dctcp} per degree. The paper measured its 30× claim at 8
/// workers; MLFabric-class systems aggregate across hundreds to thousands
/// of participants, and this scenario is where the timer-wheel event core
/// earns its keep (a degree-1024 gather keeps ~10⁵ events in flight).
/// `--proto`/`--agg` overrides are deliberately ignored so the scenario
/// always reflects the fixed matrix; labels keep the original
/// `<proto>/w<degree>` golden-byte layout.
pub(super) fn incast_xl(p: &ScenarioParams) -> Vec<CaseResult> {
    let degrees: &[usize] = &[256, 1024];
    // Fixed per-worker volume (unlike the sweep's fixed total): at XL
    // degree the interesting cost is per-flow state and the incast burst
    // itself, and 64 KiB is already past the per-flow floor the sweep
    // would clamp to.
    let bytes: u64 = if p.quick { 64 * 1024 } else { 256 * 1024 };
    let protos: Vec<ProtoSpec> = ["ltp", "reno", "dctcp"]
        .iter()
        .map(|s| parse_proto(s).expect("incast_xl protocols parse against the registry"))
        .collect();
    let mut out = Vec::new();
    for &w in degrees {
        for proto in &protos {
            let b = base(proto, w, bytes, p).loss(LossModel::Bernoulli { p: 0.02 });
            out.push(run_case(format!("{}/w{w}", proto.name()), w, b));
        }
    }
    out
}

/// `agg_matrix`: every aggregation topology — single PS, sharding at
/// n ∈ {2, 4, 8}, and 2-rack hierarchy — under each of {ltp, reno, dctcp}
/// on the paper's headline 8→1, 2 %-loss incast fabric. This is where
/// multi-point aggregation compounds with loss tolerance: sharding
/// divides each aggregator's incast volume by N, so `sharded:n=4` + ltp
/// must beat single-PS + ltp on mean BST (asserted by the conformance
/// test). `--agg`/`--proto` overrides are deliberately ignored so the
/// scenario always reflects the whole matrix; every case is labeled
/// `<agg>/<proto>/w8`, the `ps` rows included.
pub(super) fn agg_matrix(p: &ScenarioParams) -> Vec<CaseResult> {
    let w = 8;
    let bytes = per_worker_bytes(w, p);
    let aggs: Vec<AggSpec> = ["ps", "sharded:n=2", "sharded:n=4", "sharded:n=8", "hier"]
        .iter()
        .map(|s| parse_agg(s).expect("agg_matrix specs parse against the registry"))
        .collect();
    let protos: Vec<ProtoSpec> = ["ltp", "reno", "dctcp"]
        .iter()
        .map(|s| parse_proto(s).expect("agg_matrix protocols parse against the registry"))
        .collect();
    let mut out = Vec::new();
    for agg in &aggs {
        for proto in &protos {
            let b = base(proto, w, bytes, p)
                .agg(agg.clone())
                .loss(LossModel::Bernoulli { p: 0.02 });
            out.push(run_case(format!("{}/{}/w{w}", agg.name(), proto.name()), w, b));
        }
    }
    out
}

/// `churn_matrix`: the churn plane's conformance surface (DESIGN.md §1.5).
/// Two parts:
///
/// * **Part A — accuracy under elastic membership.** Native-backend
///   training on an 8-worker incast (clean wire, bubble filling on),
///   churn at {0, 5, 10} % per epoch per worker (flap 2: departed workers
///   rejoin two iterations later) × {ltp, ltp-adaptive, reno} ×
///   per-worker straggler/Gilbert–Elliott link dynamics off/on
///   (`stragglers=0.25,slow=4`). The conformance test asserts LTP at
///   10 % churn lands within 1 % absolute accuracy of the
///   stable-membership lossless baseline (the reliable `c0` row). Labels
///   read `[sg/]bf/<proto>/c<pct>`.
/// * **Part B — BST under churn.** The paper's modeled 8→1 incast at 2 %
///   wire loss, churn {0, 10} % × {ltp, reno}: at 10 % churn LTP's mean
///   BST must stay no worse than Reno's (the headline claim survives an
///   elastic worker set). Labels read `bst/<proto>/c<pct>`.
///
/// `--proto`/`--agg`/`--churn` overrides are deliberately ignored so the
/// scenario always reflects the whole matrix.
pub(super) fn churn_matrix(p: &ScenarioParams) -> Vec<CaseResult> {
    // Part A — accuracy (native backend, clean wire).
    let w = 8;
    let iters: u64 = if p.quick { 16 } else { 28 };
    let points: &[(&str, &str)] =
        &[("c0", "rate=0"), ("c5", "rate=0.05,flap=2"), ("c10", "rate=0.1,flap=2")];
    let protos: Vec<ProtoSpec> = ["ltp", "ltp-adaptive", "reno"]
        .iter()
        .map(|s| parse_proto(s).expect("churn_matrix protocols parse against the registry"))
        .collect();
    let backend = parse_backend("native").expect("registry default");
    let mut out = Vec::new();
    for sg in [false, true] {
        for (ctag, params) in points {
            // The stable non-straggler point is the pristine baseline:
            // the default `none` spec, not a zero-rate churn plan.
            let spec = match (sg, *ctag) {
                (false, "c0") => "none".to_string(),
                (false, _) => format!("churn:{params}"),
                (true, _) => format!("churn:{params},stragglers=0.25,slow=4"),
            };
            let churn = parse_churn(&spec).expect("churn_matrix specs parse");
            for proto in &protos {
                let b = RunBuilder::modeled(proto.clone(), Workload::Micro, w)
                    .seed(p.seed)
                    .iters(iters)
                    .batches_per_epoch(4)
                    .backend(backend.clone())
                    .churn(churn.clone())
                    .horizon(600 * SEC);
                let tag = if sg { "sg/" } else { "" };
                out.push(run_case(format!("{tag}bf/{}/{ctag}", proto.name()), w, b));
            }
        }
    }
    // Part B — BST on the modeled headline incast (real message sizes).
    let bytes = per_worker_bytes(w, p);
    let bst_protos: Vec<ProtoSpec> = ["ltp", "reno"]
        .iter()
        .map(|s| parse_proto(s).expect("churn_matrix protocols parse against the registry"))
        .collect();
    for (ctag, spec) in [("c0", "none"), ("c10", "churn:rate=0.1,flap=2")] {
        let churn = parse_churn(spec).expect("churn_matrix specs parse");
        for proto in &bst_protos {
            let b = base(proto, w, bytes, p)
                .churn(churn.clone())
                .loss(LossModel::Bernoulli { p: 0.02 });
            out.push(run_case(format!("bst/{}/{ctag}", proto.name()), w, b));
        }
    }
    out
}
