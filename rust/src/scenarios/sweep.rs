//! The parallel experiment driver: enumerate (scenario × seed) jobs, shard
//! them over the [`crate::runtime::pool`], merge deterministically, and
//! distill a machine-readable bench report (`BENCH_scenarios.json`).
//!
//! **Determinism contract.** A [`SweepJob`] is a pure function of
//! `(scenario_index, seed, quick, protos, aggs, codecs, churns)`: every
//! simulation owns
//! its `Sim`, whose RNG streams derive from the job's seed, and nothing
//! is shared between jobs. Results are merged in job order, so the report list — and its
//! serialized bytes — are identical for any `--jobs N`. Wall-clock timing
//! is measured per job but confined to the [`BenchReport`], which is
//! explicitly *not* part of the deterministic surface.
//!
//! Job order is seed-major (`for seed { for scenario }`), which keeps the
//! single-seed `ltp scenario all` output ordering identical to the old
//! serial loop.

use super::{registry, ScenarioParams, ScenarioReport};
use crate::churn::ChurnSpec;
use crate::codec::CodecSpec;
use crate::metrics::Json;
use crate::ps::{AggSpec, ProtoSpec};
use crate::runtime::pool;
use crate::trace;

/// One enumerable unit of sweep work. Protocol, aggregation, codec, and
/// churn handles are cheap clones of thread-shareable specs, so a job
/// remains a pure function of
/// `(scenario_index, seed, quick, protos, aggs, codecs, churns)`.
#[derive(Debug, Clone)]
pub struct SweepJob {
    /// Index into [`registry`].
    pub scenario_index: usize,
    pub seed: u64,
    pub quick: bool,
    /// Protocol-matrix override (`--proto` specs); `None` keeps scenario
    /// defaults.
    pub protos: Option<Vec<ProtoSpec>>,
    /// Aggregation-topology override (`--agg` specs); `None` keeps the
    /// default single PS.
    pub aggs: Option<Vec<AggSpec>>,
    /// Gradient-codec override (`--codec` specs); `None` keeps the
    /// default identity codec.
    pub codecs: Option<Vec<CodecSpec>>,
    /// Churn-plane override (`--churn` specs); `None` keeps stable
    /// membership on pristine links.
    pub churns: Option<Vec<ChurnSpec>>,
}

/// Enumerate the (seed-major) job list for a set of registry indices.
pub fn sweep_jobs(
    indices: &[usize],
    seeds: &[u64],
    quick: bool,
    protos: Option<Vec<ProtoSpec>>,
    aggs: Option<Vec<AggSpec>>,
    codecs: Option<Vec<CodecSpec>>,
    churns: Option<Vec<ChurnSpec>>,
) -> Vec<SweepJob> {
    let mut out = Vec::with_capacity(indices.len() * seeds.len());
    for &seed in seeds {
        for &scenario_index in indices {
            debug_assert!(scenario_index < registry().len());
            out.push(SweepJob {
                scenario_index,
                seed,
                quick,
                protos: protos.clone(),
                aggs: aggs.clone(),
                codecs: codecs.clone(),
                churns: churns.clone(),
            });
        }
    }
    out
}

/// Deterministic training summary of one job's backend-attached cases
/// (schema ltp-bench-v7; `null` for jobs whose scenario trains nothing).
#[derive(Debug, Clone, Copy)]
pub struct BenchTrain {
    /// Cases that carried a `train` block.
    pub cases: usize,
    /// Mean final eval loss over those cases.
    pub mean_final_loss: f64,
    /// Mean final eval accuracy over those cases.
    pub mean_accuracy: f64,
}

/// Per-job bench record (wall-clock fields are non-deterministic).
#[derive(Debug, Clone)]
pub struct BenchJob {
    pub scenario: String,
    pub seed: u64,
    /// Canonical protocol spec strings the job's cases exercised, first
    /// occurrence order (the bench trajectory records *what* ran, not just
    /// how fast).
    pub protos: Vec<String>,
    /// Canonical aggregation spec strings the job's cases exercised,
    /// first-occurrence order (`["ps"]` for the default topology).
    pub aggs: Vec<String>,
    /// Canonical gradient-codec spec strings the job's cases exercised,
    /// first-occurrence order (`["dense"]` without a `--codec` override).
    pub codecs: Vec<String>,
    /// Canonical churn spec strings the job's cases exercised,
    /// first-occurrence order (`["none"]` without a `--churn` override) —
    /// schema v7.
    pub churns: Vec<String>,
    /// Minimum per-iteration active worker count over the job's cases
    /// (schema v7; equals each case's nominal degree under stable
    /// membership).
    pub active_min: usize,
    /// Maximum per-iteration active worker count over the job's cases
    /// (schema v7).
    pub active_max: usize,
    pub cases: usize,
    /// BSP iterations completed, summed over the scenario's cases.
    pub iters: usize,
    /// Mean of the cases' mean BSTs (ms) — the per-scenario perf headline.
    pub mean_bst_ms: f64,
    pub mean_delivered: f64,
    /// Gather-direction application bytes on the wire, summed over the
    /// job's cases — the codec plane's size claim (since schema v6).
    pub wire_bytes: u64,
    /// Training summary over the job's backend-attached cases, if any
    /// (the key is always present, `null` without a backend).
    pub train: Option<BenchTrain>,
    pub sim_events: u64,
    pub wall_secs: f64,
    pub events_per_sec: f64,
}

impl BenchJob {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", self.scenario.as_str().into()),
            ("seed", self.seed.into()),
            ("protos", Json::Arr(self.protos.iter().map(|p| p.as_str().into()).collect())),
            ("aggs", Json::Arr(self.aggs.iter().map(|a| a.as_str().into()).collect())),
            ("codecs", Json::Arr(self.codecs.iter().map(|c| c.as_str().into()).collect())),
            ("churns", Json::Arr(self.churns.iter().map(|c| c.as_str().into()).collect())),
            (
                "active_workers",
                Json::obj(vec![
                    ("min", self.active_min.into()),
                    ("max", self.active_max.into()),
                ]),
            ),
            ("cases", self.cases.into()),
            ("iters", self.iters.into()),
            ("mean_bst_ms", self.mean_bst_ms.into()),
            ("mean_delivered", self.mean_delivered.into()),
            ("wire_bytes", self.wire_bytes.into()),
            (
                "train",
                match &self.train {
                    None => Json::Null,
                    Some(t) => Json::obj(vec![
                        ("cases", t.cases.into()),
                        ("mean_final_loss", t.mean_final_loss.into()),
                        ("mean_accuracy", t.mean_accuracy.into()),
                    ]),
                },
            ),
            ("sim_events", self.sim_events.into()),
            ("wall_secs", self.wall_secs.into()),
            ("events_per_sec", self.events_per_sec.into()),
        ])
    }
}

/// The aggregate report behind `BENCH_scenarios.json` — the repo's
/// machine-readable perf trajectory. Schema is documented in
/// EXPERIMENTS.md (§Parallel driver).
#[derive(Debug, Clone)]
pub struct BenchReport {
    /// Worker threads requested (0 = auto).
    pub jobs_requested: usize,
    pub n_jobs: usize,
    /// Wall-clock of the whole sweep (merge included).
    pub wall_secs: f64,
    /// Sum of per-job wall-clock — the serial-equivalent cost.
    pub cpu_secs: f64,
    pub sim_events: u64,
    pub per_job: Vec<BenchJob>,
    /// Trace file the sweep was captured to, when run via
    /// `ltp trace … --bench` (regression-localization provenance:
    /// `ltp diff` the baseline and current traces).
    pub trace: Option<String>,
}

impl BenchReport {
    /// Minimum per-job events/sec — the regression-threshold headline
    /// (since schema v6). The floor, not the mean: one scenario collapsing is
    /// what a perf gate must catch, and a mean would average it away.
    pub fn events_per_sec_floor(&self) -> f64 {
        let floor =
            self.per_job.iter().map(|j| j.events_per_sec).fold(f64::INFINITY, f64::min);
        if floor.is_finite() { floor } else { 0.0 } // 0.0 when there are no jobs
    }

    pub fn to_json(&self) -> Json {
        let events_per_sec =
            if self.wall_secs > 0.0 { self.sim_events as f64 / self.wall_secs } else { 0.0 };
        let speedup = if self.wall_secs > 0.0 { self.cpu_secs / self.wall_secs } else { 1.0 };
        let mut kv: Vec<(&str, Json)> = vec![
            ("schema", "ltp-bench-v7".into()),
            // How the numbers came to be: "measured" (this process timed
            // the runs) vs "bootstrap" (a hand-committed seed snapshot —
            // see rust/BENCH_scenarios.json).
            ("provenance", "measured".into()),
        ];
        // Optional, directly after provenance: reports without a trace
        // render byte-identically to schema v7 before the field existed.
        if let Some(trace) = &self.trace {
            kv.push(("trace", trace.as_str().into()));
        }
        kv.extend([
            ("jobs_requested", self.jobs_requested.into()),
            ("n_jobs", self.n_jobs.into()),
            ("wall_secs", self.wall_secs.into()),
            ("cpu_secs", self.cpu_secs.into()),
            ("speedup", speedup.into()),
            ("sim_events", self.sim_events.into()),
            ("events_per_sec", events_per_sec.into()),
            ("events_per_sec_floor", self.events_per_sec_floor().into()),
            ("runs", Json::Arr(self.per_job.iter().map(|j| j.to_json()).collect())),
        ]);
        Json::obj(kv)
    }

    pub fn render_json(&self) -> String {
        self.to_json().render_pretty()
    }
}

// ---------------------------------------------------------------------------
// Bench-report field extraction + the perf regression gate (`ltp bench
// check`). These read only documents our own renderer wrote (compact or
// pretty [`Json`] output), so a targeted scanner is enough — no general
// JSON parser in the dependency set, none needed.
// ---------------------------------------------------------------------------

/// Byte offset of the value following `"key"` (+ colon) at or after
/// `from`, or `None` if the key does not occur.
fn value_pos(json: &str, key: &str, from: usize) -> Option<usize> {
    let pat = format!("\"{key}\"");
    let at = json[from..].find(&pat)? + from + pat.len();
    let rest = json[at..].trim_start().strip_prefix(':')?.trim_start();
    Some(json.len() - rest.len())
}

/// First string value of `"key"` in `json` (no-escape strings only —
/// which is all the bench schema emits).
pub fn bench_field_str(json: &str, key: &str) -> Option<String> {
    let v = value_pos(json, key, 0)?;
    let body = json[v..].strip_prefix('"')?;
    Some(body[..body.find('"')?].to_string())
}

/// First numeric value of `"key"` in `json`.
pub fn bench_field_num(json: &str, key: &str) -> Option<f64> {
    let v = value_pos(json, key, 0)?;
    let end = json[v..]
        .find(|c: char| !matches!(c, '0'..='9' | '-' | '+' | '.' | 'e' | 'E'))
        .unwrap_or(json.len() - v);
    json[v..v + end].parse().ok()
}

/// Best (maximum) per-job `events_per_sec` among a bench report's runs of
/// `scenario`. Max, not mean: the gate should compare each side's best
/// measurement so one scheduler hiccup in a multi-seed sweep cannot fail
/// an otherwise healthy build.
pub fn bench_scenario_events_per_sec(json: &str, scenario: &str) -> Option<f64> {
    let mut best: Option<f64> = None;
    let mut from = 0;
    while let Some(v) = value_pos(json, "scenario", from) {
        from = v + 1;
        let Some(name) = json[v..].strip_prefix('"') else { continue };
        let Some(q) = name.find('"') else { break };
        if &name[..q] != scenario {
            continue;
        }
        let eps = value_pos(json, "events_per_sec", v).and_then(|p| {
            let end = json[p..]
                .find(|c: char| !matches!(c, '0'..='9' | '-' | '+' | '.' | 'e' | 'E'))
                .unwrap_or(json.len() - p);
            json[p..p + end].parse::<f64>().ok()
        });
        if let Some(eps) = eps {
            best = Some(best.map_or(eps, |b: f64| b.max(eps)));
        }
    }
    best
}

/// Outcome of [`check_regression`] — everything the CLI prints.
#[derive(Debug)]
pub struct BenchCheck {
    pub scenario: String,
    pub baseline_eps: f64,
    pub current_eps: f64,
    /// Relative change, percent (positive = faster than baseline).
    pub delta_pct: f64,
    pub max_regress_pct: f64,
    pub ok: bool,
    /// Human-readable caveats (schema drift, bootstrap baseline, …).
    pub notes: Vec<String>,
}

/// The perf gate behind `ltp bench check`: fail if `scenario`'s best
/// events/sec in `current_json` regresses more than `max_regress_pct`
/// below the committed `baseline_json`.
pub fn check_regression(
    baseline_json: &str,
    current_json: &str,
    scenario: &str,
    max_regress_pct: f64,
) -> Result<BenchCheck, String> {
    let mut notes = Vec::new();
    for (side, json) in [("baseline", baseline_json), ("current", current_json)] {
        match bench_field_str(json, "schema") {
            Some(s) if s == "ltp-bench-v7" => {}
            Some(s) => notes.push(format!("{side} uses schema {s}, expected ltp-bench-v7")),
            None => return Err(format!("{side} is not a bench report (no schema field)")),
        }
    }
    if bench_field_str(baseline_json, "provenance").as_deref() == Some("bootstrap") {
        notes.push(
            "baseline is a bootstrap snapshot (hand-committed floor, not a measured run)"
                .to_string(),
        );
    }
    let baseline_eps = bench_scenario_events_per_sec(baseline_json, scenario)
        .ok_or_else(|| format!("baseline has no `{scenario}` run"))?;
    let current_eps = bench_scenario_events_per_sec(current_json, scenario)
        .ok_or_else(|| format!("current report has no `{scenario}` run"))?;
    let delta_pct = if baseline_eps > 0.0 {
        (current_eps - baseline_eps) / baseline_eps * 100.0
    } else {
        0.0
    };
    let ok = current_eps >= baseline_eps * (1.0 - max_regress_pct / 100.0);
    Ok(BenchCheck {
        scenario: scenario.to_string(),
        baseline_eps,
        current_eps,
        delta_pct,
        max_regress_pct,
        ok,
        notes,
    })
}

/// Scenario names appearing in a bench report's runs, first-occurrence
/// order. Drives the `ltp bench check --scenario all` enumeration.
pub fn bench_scenarios(json: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(v) = value_pos(json, "scenario", from) {
        from = v + 1;
        let Some(body) = json[v..].strip_prefix('"') else { continue };
        let Some(q) = body.find('"') else { break };
        let name = &body[..q];
        if !out.iter().any(|n| n == name) {
            out.push(name.to_string());
        }
    }
    out
}

/// Gate *every* scenario the baseline covers (`--scenario all`). The
/// enumeration comes from the baseline, so a baseline scenario that is
/// missing from `current_json` is an error naming that scenario — not a
/// silent pass, which is what per-scenario [`check_regression`] callers
/// got when they simply skipped absent names.
pub fn check_regression_all(
    baseline_json: &str,
    current_json: &str,
    max_regress_pct: f64,
) -> Result<Vec<BenchCheck>, String> {
    let scenarios = bench_scenarios(baseline_json);
    if scenarios.is_empty() {
        return Err("baseline has no scenario runs to gate against".to_string());
    }
    let mut checks = Vec::with_capacity(scenarios.len());
    let mut errs = Vec::new();
    for s in &scenarios {
        match check_regression(baseline_json, current_json, s, max_regress_pct) {
            Ok(c) => checks.push(c),
            Err(e) => errs.push(e),
        }
    }
    if errs.is_empty() {
        Ok(checks)
    } else {
        Err(errs.join("; "))
    }
}

/// A finished sweep: reports in job order plus the bench distillation.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub reports: Vec<ScenarioReport>,
    pub bench: BenchReport,
}

impl SweepResult {
    /// The deterministic JSON document for the whole sweep: one object for
    /// a single job, else an array in job order. `--jobs N` must render
    /// byte-identically to `--jobs 1` — the CI perf-smoke diff enforces it.
    pub fn render_json(&self) -> String {
        if self.reports.len() == 1 {
            self.reports[0].render_json()
        } else {
            Json::Arr(self.reports.iter().map(|r| r.to_json()).collect()).render_pretty()
        }
    }
}

/// Run a job list on `n_jobs` workers (0 = auto, 1 = inline serial).
pub fn run_sweep(jobs: Vec<SweepJob>, n_jobs: usize) -> SweepResult {
    run_sweep_traced(jobs, n_jobs, false).0
}

/// [`run_sweep`] with optional trace capture. When `traced`, each job
/// runs under its own [`crate::trace`] capture scope, prefixed by a
/// [`trace::Record::job_start`] marker carrying `(scenario_index, seed,
/// quick)`; per-job record streams are concatenated in job order, so the
/// combined stream is byte-identical for any `--jobs N` — the same merge
/// discipline that makes the report bytes jobs-invariant.
pub fn run_sweep_traced(
    jobs: Vec<SweepJob>,
    n_jobs: usize,
    traced: bool,
) -> (SweepResult, Option<Vec<trace::Record>>) {
    let n_workers = pool::effective_jobs(n_jobs, jobs.len());
    let t0 = std::time::Instant::now();
    let outcomes = pool::run_jobs(n_jobs, jobs, |_, job| {
        let scenario = &registry()[job.scenario_index];
        let cap = traced.then(|| {
            let cap = trace::capture();
            trace::emit(trace::Record::job_start(job.scenario_index, job.seed, job.quick));
            cap
        });
        let jt = std::time::Instant::now();
        let report = scenario.run(&ScenarioParams {
            seed: job.seed,
            quick: job.quick,
            protos: job.protos,
            aggs: job.aggs,
            codecs: job.codecs,
            churns: job.churns,
        });
        (report, jt.elapsed().as_secs_f64(), cap.map(trace::Capture::finish))
    });
    let wall_secs = t0.elapsed().as_secs_f64();
    let mut reports = Vec::with_capacity(outcomes.len());
    let mut per_job = Vec::with_capacity(outcomes.len());
    let mut cpu_secs = 0.0;
    let mut total_events = 0u64;
    let mut records = traced.then(Vec::new);
    for (report, job_secs, job_records) in outcomes {
        if let (Some(all), Some(mut recs)) = (records.as_mut(), job_records) {
            all.append(&mut recs);
        }
        let events: u64 = report.cases.iter().map(|c| c.sim_events).sum();
        let ncases = report.cases.len().max(1);
        let mut protos: Vec<String> = Vec::new();
        let mut aggs: Vec<String> = Vec::new();
        let mut codecs: Vec<String> = Vec::new();
        let mut churns: Vec<String> = Vec::new();
        for c in &report.cases {
            if !protos.contains(&c.proto) {
                protos.push(c.proto.clone());
            }
            if !aggs.contains(&c.agg) {
                aggs.push(c.agg.clone());
            }
            if !codecs.contains(&c.codec) {
                codecs.push(c.codec.clone());
            }
            if !churns.contains(&c.churn) {
                churns.push(c.churn.clone());
            }
        }
        let active_min = report.cases.iter().map(|c| c.active_min).min().unwrap_or(0);
        let active_max = report.cases.iter().map(|c| c.active_max).max().unwrap_or(0);
        let trained: Vec<&crate::compute::TrainStats> =
            report.cases.iter().filter_map(|c| c.train.as_ref()).collect();
        let train = if trained.is_empty() {
            None
        } else {
            let n = trained.len() as f64;
            Some(BenchTrain {
                cases: trained.len(),
                mean_final_loss: trained.iter().map(|t| t.final_loss as f64).sum::<f64>() / n,
                mean_accuracy: trained.iter().map(|t| t.accuracy).sum::<f64>() / n,
            })
        };
        per_job.push(BenchJob {
            scenario: report.name.clone(),
            seed: report.seed,
            protos,
            aggs,
            codecs,
            churns,
            active_min,
            active_max,
            cases: report.cases.len(),
            iters: report.cases.iter().map(|c| c.iters).sum(),
            mean_bst_ms: report.cases.iter().map(|c| c.mean_bst_ms).sum::<f64>()
                / ncases as f64,
            mean_delivered: report.cases.iter().map(|c| c.mean_delivered).sum::<f64>()
                / ncases as f64,
            wire_bytes: report.cases.iter().map(|c| c.gather_wire_bytes).sum(),
            train,
            sim_events: events,
            wall_secs: job_secs,
            events_per_sec: if job_secs > 0.0 { events as f64 / job_secs } else { 0.0 },
        });
        cpu_secs += job_secs;
        total_events += events;
        reports.push(report);
    }
    let result = SweepResult {
        reports,
        bench: BenchReport {
            jobs_requested: n_jobs,
            n_jobs: n_workers,
            wall_secs,
            cpu_secs,
            sim_events: total_events,
            per_job,
            trace: None,
        },
    };
    (result, records)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn index_of(name: &str) -> usize {
        registry().iter().position(|s| s.name == name).expect("scenario registered")
    }

    #[test]
    fn job_enumeration_is_seed_major() {
        let jobs = sweep_jobs(&[0, 1], &[5, 6], true, None, None, None, None);
        let key: Vec<(u64, usize)> = jobs.iter().map(|j| (j.seed, j.scenario_index)).collect();
        assert_eq!(key, vec![(5, 0), (5, 1), (6, 0), (6, 1)]);
    }

    #[test]
    fn bench_report_carries_perf_fields() {
        let jobs = sweep_jobs(&[index_of("wan_clean")], &[3], true, None, None, None, None);
        let result = run_sweep(jobs, 2);
        assert_eq!(result.reports.len(), 1);
        assert_eq!(result.bench.per_job.len(), 1);
        let j = &result.bench.per_job[0];
        assert_eq!(j.scenario, "wan_clean");
        assert_eq!(j.seed, 3);
        assert_eq!(j.protos, ["ltp", "reno"], "bench records the job's proto specs");
        assert_eq!(j.aggs, ["ps"], "bench records the job's agg specs");
        assert!(j.sim_events > 0, "a simulation processes events");
        assert!(j.mean_bst_ms > 0.0);
        let json = result.bench.to_json().render();
        for key in [
            "\"schema\":\"ltp-bench-v7\"",
            "\"provenance\":\"measured\"",
            "\"runs\":[",
            "\"events_per_sec\":",
            "\"events_per_sec_floor\":",
            "\"speedup\":",
            "\"protos\":[\"ltp\",\"reno\"]",
            "\"aggs\":[\"ps\"]",
            "\"codecs\":[\"dense\"]",
            "\"churns\":[\"none\"]",
            "\"active_workers\":{\"min\":",
            "\"wire_bytes\":",
            // No backend attached: the train block is present but null.
            "\"train\":null",
        ] {
            assert!(json.contains(key), "missing `{key}` in {json}");
        }
        // The floor is the min over per-job rates — with one job, its rate.
        assert!(
            (result.bench.events_per_sec_floor() - j.events_per_sec).abs() < 1e-9,
            "single-job floor equals that job's rate"
        );
    }

    #[test]
    fn bench_field_scanner_reads_compact_and_pretty() {
        let report = BenchReport {
            jobs_requested: 1,
            n_jobs: 1,
            wall_secs: 2.0,
            cpu_secs: 2.0,
            sim_events: 4_000_000,
            per_job: vec![BenchJob {
                scenario: "incast_sweep".to_string(),
                seed: 1,
                protos: vec!["ltp".to_string()],
                aggs: vec!["ps".to_string()],
                codecs: vec!["dense".to_string()],
                churns: vec!["none".to_string()],
                active_min: 2,
                active_max: 2,
                cases: 3,
                iters: 9,
                mean_bst_ms: 1.5,
                mean_delivered: 0.99,
                wire_bytes: 1_000_000,
                train: None,
                sim_events: 4_000_000,
                wall_secs: 2.0,
                events_per_sec: 2_000_000.0,
            }],
            trace: None,
        };
        for json in [report.to_json().render(), report.render_json()] {
            assert_eq!(bench_field_str(&json, "schema").as_deref(), Some("ltp-bench-v7"));
            assert_eq!(bench_field_num(&json, "sim_events"), Some(4_000_000.0));
            assert_eq!(
                bench_scenario_events_per_sec(&json, "incast_sweep"),
                Some(2_000_000.0),
                "{json}"
            );
            assert_eq!(bench_scenario_events_per_sec(&json, "no_such"), None);
        }
    }

    #[test]
    fn scenario_scan_takes_the_best_run_and_ignores_others() {
        let json = r#"{"schema": "ltp-bench-v7", "events_per_sec": 9.0, "runs": [
            {"scenario": "wan_clean", "events_per_sec": 50.0},
            {"scenario": "incast_sweep", "events_per_sec": 10.0},
            {"scenario": "incast_sweep", "events_per_sec": 30.0}]}"#;
        assert_eq!(bench_scenario_events_per_sec(json, "incast_sweep"), Some(30.0));
        assert_eq!(bench_scenario_events_per_sec(json, "wan_clean"), Some(50.0));
    }

    #[test]
    fn regression_gate_passes_within_threshold_and_fails_beyond() {
        let bench = |eps: f64, provenance: &str| {
            format!(
                r#"{{"schema": "ltp-bench-v7", "provenance": "{provenance}",
                     "runs": [{{"scenario": "incast_sweep", "events_per_sec": {eps}}}]}}"#
            )
        };
        let baseline = bench(1_000_000.0, "bootstrap");
        // 10% down, 20% allowed: pass (with a bootstrap-baseline note).
        let c = check_regression(&baseline, &bench(900_000.0, "measured"), "incast_sweep", 20.0)
            .unwrap();
        assert!(c.ok, "{c:?}");
        assert!(c.delta_pct < 0.0);
        assert!(c.notes.iter().any(|n| n.contains("bootstrap")), "{c:?}");
        // 30% down, 20% allowed: fail.
        let c = check_regression(&baseline, &bench(700_000.0, "measured"), "incast_sweep", 20.0)
            .unwrap();
        assert!(!c.ok, "{c:?}");
        // Missing scenario on either side is an error, not a pass.
        assert!(check_regression(&baseline, &bench(1.0, "measured"), "wan_clean", 20.0).is_err());
        assert!(check_regression("{}", &baseline, "incast_sweep", 20.0).is_err());
    }

    #[test]
    fn bench_scenarios_enumerates_first_occurrence_order() {
        let json = r#"{"schema": "ltp-bench-v7", "runs": [
            {"scenario": "incast_sweep", "events_per_sec": 10.0},
            {"scenario": "wan_clean", "events_per_sec": 50.0},
            {"scenario": "incast_sweep", "events_per_sec": 30.0}]}"#;
        assert_eq!(bench_scenarios(json), ["incast_sweep", "wan_clean"]);
        assert!(bench_scenarios("{}").is_empty());
    }

    #[test]
    fn all_mode_gate_fails_loudly_when_a_baseline_scenario_is_missing() {
        let baseline = r#"{"schema": "ltp-bench-v7", "provenance": "measured", "runs": [
            {"scenario": "incast_sweep", "events_per_sec": 1000.0},
            {"scenario": "incast_xl", "events_per_sec": 500.0}]}"#;
        // Current covers both baseline scenarios: two checks, both ok.
        let full = r#"{"schema": "ltp-bench-v7", "provenance": "measured", "runs": [
            {"scenario": "incast_sweep", "events_per_sec": 1100.0},
            {"scenario": "incast_xl", "events_per_sec": 600.0},
            {"scenario": "wan_clean", "events_per_sec": 9.0}]}"#;
        let checks = check_regression_all(baseline, full, 20.0).unwrap();
        assert_eq!(checks.len(), 2);
        assert!(checks.iter().all(|c| c.ok), "{checks:?}");
        // Current missing a baseline scenario: an error naming it — the
        // silent-pass regression this mode exists to prevent.
        let partial = r#"{"schema": "ltp-bench-v7", "provenance": "measured", "runs": [
            {"scenario": "incast_sweep", "events_per_sec": 1100.0}]}"#;
        let err = check_regression_all(baseline, partial, 20.0).unwrap_err();
        assert!(err.contains("incast_xl"), "error names the missing scenario: {err}");
        // An empty baseline cannot gate anything.
        assert!(check_regression_all("{}", full, 20.0).is_err());
    }

    #[test]
    fn traced_sweep_records_match_across_job_counts() {
        let jobs = || sweep_jobs(&[index_of("wan_clean")], &[7, 8], true, None, None, None, None);
        let (serial, recs1) = run_sweep_traced(jobs(), 1, true);
        let (pooled, recs2) = run_sweep_traced(jobs(), 2, true);
        let recs1 = recs1.expect("traced run returns records");
        let recs2 = recs2.expect("traced run returns records");
        assert!(!recs1.is_empty());
        assert_eq!(recs1, recs2, "job-order merge makes the stream jobs-invariant");
        assert_eq!(serial.render_json(), pooled.render_json());
        assert_eq!(
            recs1.iter().filter(|r| r.kind == trace::KIND_JOB_START).count(),
            2,
            "one job-start marker per sweep job"
        );
        // Untraced runs return no records and identical report bytes.
        let (untraced, none) = run_sweep_traced(jobs(), 1, false);
        assert!(none.is_none());
        assert_eq!(untraced.render_json(), serial.render_json());
    }

    #[test]
    fn accuracy_matrix_jobs_carry_the_train_block() {
        let jobs = sweep_jobs(&[index_of("accuracy_matrix")], &[3], true, None, None, None, None);
        let result = run_sweep(jobs, 1);
        let j = &result.bench.per_job[0];
        let t = j.train.expect("backend-attached scenario summarizes training");
        assert_eq!(t.cases, j.cases, "every accuracy_matrix case trains");
        assert!(t.mean_accuracy > 0.0 && t.mean_accuracy <= 1.0);
        assert!(t.mean_final_loss.is_finite());
        let json = result.bench.to_json().render();
        assert!(json.contains("\"mean_accuracy\":"), "{json}");
        // Byte-identity across job counts holds for the training scenario
        // too (the pool determinism contract).
        let again = run_sweep(
            sweep_jobs(&[index_of("accuracy_matrix")], &[3], true, None, None, None, None),
            2,
        );
        assert_eq!(result.render_json(), again.render_json());
    }

    #[test]
    fn proto_override_reaches_the_cases() {
        let protos = vec![crate::ps::parse_proto("cubic").unwrap()];
        let jobs = sweep_jobs(&[index_of("wan_clean")], &[3], true, Some(protos), None, None, None);
        let result = run_sweep(jobs, 1);
        let report = &result.reports[0];
        assert!(!report.cases.is_empty());
        assert!(report.cases.iter().all(|c| c.proto == "cubic"), "{:?}", report.cases);
        assert_eq!(result.bench.per_job[0].protos, ["cubic"]);
    }

    #[test]
    fn agg_override_reaches_the_cases_and_bench() {
        let aggs = vec![crate::ps::parse_agg("sharded:n=2").unwrap()];
        let jobs =
            sweep_jobs(&[index_of("incast_heavy_loss")], &[3], true, None, Some(aggs), None, None);
        let result = run_sweep(jobs, 1);
        let report = &result.reports[0];
        assert!(!report.cases.is_empty());
        assert!(
            report.cases.iter().all(|c| c.agg == "sharded:n=2"),
            "{:?}",
            report.cases
        );
        assert!(report.cases.iter().all(|c| c.label.starts_with("sharded:n=2/")));
        assert_eq!(result.bench.per_job[0].aggs, ["sharded:n=2"]);
    }

    #[test]
    fn codec_override_reaches_the_cases_and_bench() {
        let codecs = vec![crate::codec::parse_codec("topk:pct=0.1").unwrap()];
        let jobs =
            sweep_jobs(&[index_of("incast_heavy_loss")], &[3], true, None, None, Some(codecs), None);
        let result = run_sweep(jobs, 1);
        let report = &result.reports[0];
        assert!(!report.cases.is_empty());
        assert!(
            report.cases.iter().all(|c| c.codec == "topk:pct=0.1"),
            "{:?}",
            report.cases
        );
        assert!(report.cases.iter().all(|c| c.label.starts_with("topk:pct=0.1/")));
        assert!(report.cases.iter().all(|c| c.mean_importance.is_some()));
        assert_eq!(result.bench.per_job[0].codecs, ["topk:pct=0.1"]);
        assert!(result.bench.per_job[0].wire_bytes > 0);
        // The codec JSON block rides along, and sparsification shrinks the
        // wire relative to the dense default.
        let json = result.render_json();
        assert!(json.contains("\"codec\": \"topk:pct=0.1\""), "{json}");
        let dense = run_sweep(
            sweep_jobs(&[index_of("incast_heavy_loss")], &[3], true, None, None, None, None),
            1,
        );
        assert!(
            result.bench.per_job[0].wire_bytes * 5 <= dense.bench.per_job[0].wire_bytes,
            "topk:pct=0.1 must cut gather bytes ≥5×: {} vs {}",
            result.bench.per_job[0].wire_bytes,
            dense.bench.per_job[0].wire_bytes
        );
    }

    #[test]
    fn churn_override_reaches_the_cases_and_bench() {
        let churns = vec![crate::churn::parse_churn("churn:rate=0.9,flap=2").unwrap()];
        let jobs =
            sweep_jobs(&[index_of("incast_heavy_loss")], &[3], true, None, None, None, Some(churns));
        let result = run_sweep(jobs, 1);
        let report = &result.reports[0];
        assert!(!report.cases.is_empty());
        assert!(
            report.cases.iter().all(|c| c.churn == "churn:rate=0.9,flap=2"),
            "{:?}",
            report.cases
        );
        assert!(report.cases.iter().all(|c| c.label.starts_with("churn:rate=0.9,flap=2/")));
        // Departures shrink at least one barrier below the nominal degree,
        // and the bench record carries the churned bounds.
        assert!(report.cases.iter().all(|c| c.active_min <= c.active_max));
        assert!(report.cases.iter().any(|c| c.active_min < c.workers), "{:?}", report.cases);
        let j = &result.bench.per_job[0];
        assert_eq!(j.churns, ["churn:rate=0.9,flap=2"]);
        assert!(j.active_min <= j.active_max);
        let json = result.render_json();
        assert!(json.contains("\"churn\": \"churn:rate=0.9,flap=2\""), "{json}");
        // Byte-identity across job counts holds under churn too.
        let again = run_sweep(
            sweep_jobs(
                &[index_of("incast_heavy_loss")],
                &[3],
                true,
                None,
                None,
                None,
                Some(vec![crate::churn::parse_churn("churn:rate=0.9,flap=2").unwrap()]),
            ),
            2,
        );
        assert_eq!(result.render_json(), again.render_json());
    }

    #[test]
    fn single_report_renders_as_object_many_as_array() {
        let one = run_sweep(sweep_jobs(&[index_of("wan_clean")], &[1], true, None, None, None, None), 1);
        assert!(one.render_json().starts_with('{'));
        let two =
            run_sweep(sweep_jobs(&[index_of("wan_clean")], &[1, 2], true, None, None, None, None), 2);
        assert!(two.render_json().starts_with('['));
        assert_eq!(two.reports[0].seed, 1);
        assert_eq!(two.reports[1].seed, 2);
    }
}
