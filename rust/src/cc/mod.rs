//! Congestion controllers.
//!
//! The baselines (Reno, Cubic, DCTCP, BBR) drive the reliable in-order
//! [`crate::tcp`] transport and reproduce the kernel-TCP dynamics the paper
//! compares against (its Fig 4 table). [`BdpCc`] is LTP's own BDP-based
//! controller (§III-D): BBR-style BtlBw/RTprop probing, inflight capped at
//! the estimated BDP, packet loss **never** treated as a congestion signal.

mod bbr;
mod bdp;
mod cubic;
mod dctcp;
mod filters;
mod reno;

pub use bbr::Bbr;
pub use bdp::{BdpCc, PACING_BURST};
pub use cubic::Cubic;

/// Burst allowance before pacing kicks in (paper §III-D).
pub fn bdp_burst() -> u32 {
    PACING_BURST
}
pub use dctcp::Dctcp;
pub use filters::{WindowedMax, WindowedMin};
pub use reno::Reno;

use crate::Nanos;

/// Feedback delivered to a controller for one cumulative ACK.
#[derive(Debug, Clone, Copy)]
pub struct AckSample {
    pub now: Nanos,
    /// Newly acknowledged payload bytes.
    pub acked_bytes: u64,
    /// RTT measured for the newest acked segment.
    pub rtt: Nanos,
    /// Delivery-rate sample in bytes/sec (rate estimator in the transport),
    /// when available.
    pub delivery_rate_bps: Option<u64>,
    /// ECN-echo seen on this ACK.
    pub ece: bool,
    /// Bytes currently in flight *after* this ACK was processed.
    pub inflight_bytes: u64,
}

/// A window/rate controller for a reliable transport.
pub trait CongestionControl {
    fn name(&self) -> &'static str;

    /// Current congestion window in bytes (cap on inflight).
    fn cwnd_bytes(&self) -> u64;

    /// Pacing rate in *bits*/sec, if this controller paces (BBR-style).
    /// `None` ⇒ window-limited only.
    fn pacing_rate_bps(&self) -> Option<u64> {
        None
    }

    /// Process an ACK.
    fn on_ack(&mut self, sample: AckSample);

    /// Packet loss inferred via dup-ACK / fast retransmit.
    fn on_loss(&mut self, now: Nanos);

    /// Retransmission timeout.
    fn on_timeout(&mut self, now: Nanos);
}

/// Factory over the baseline controllers, used by experiment configs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CcAlgo {
    Reno,
    Cubic,
    Dctcp,
    Bbr,
}

impl CcAlgo {
    pub const ALL: [CcAlgo; 4] = [CcAlgo::Cubic, CcAlgo::Reno, CcAlgo::Dctcp, CcAlgo::Bbr];

    pub fn build(self, mss: u32) -> Box<dyn CongestionControl> {
        match self {
            CcAlgo::Reno => Box::new(Reno::new(mss)),
            CcAlgo::Cubic => Box::new(Cubic::new(mss)),
            CcAlgo::Dctcp => Box::new(Dctcp::new(mss)),
            CcAlgo::Bbr => Box::new(Bbr::new(mss)),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CcAlgo::Reno => "reno",
            CcAlgo::Cubic => "cubic",
            CcAlgo::Dctcp => "dctcp",
            CcAlgo::Bbr => "bbr",
        }
    }
}

impl std::str::FromStr for CcAlgo {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "reno" | "newreno" | "new-reno" => Ok(CcAlgo::Reno),
            "cubic" => Ok(CcAlgo::Cubic),
            "dctcp" => Ok(CcAlgo::Dctcp),
            "bbr" => Ok(CcAlgo::Bbr),
            other => Err(format!("unknown congestion control `{other}`")),
        }
    }
}
