//! Windowed min/max filters (the BBR "max-filter over 10 RTTs" /
//! "min-filter over 10 s" primitives), shared by [`super::Bbr`] and LTP's
//! [`super::BdpCc`].

use crate::Nanos;
use std::collections::VecDeque;

/// Windowed maximum: `get()` returns the max of all samples added within
/// the trailing `window` of time. O(1) amortized via a monotonic deque.
#[derive(Debug, Clone)]
pub struct WindowedMax {
    window: Nanos,
    /// (time, value); values strictly decreasing front→back.
    samples: VecDeque<(Nanos, u64)>,
}

impl WindowedMax {
    pub fn new(window: Nanos) -> Self {
        WindowedMax { window, samples: VecDeque::new() }
    }

    pub fn set_window(&mut self, window: Nanos) {
        self.window = window;
    }

    pub fn add(&mut self, now: Nanos, value: u64) {
        while let Some(&(_, back)) = self.samples.back() {
            if back <= value {
                self.samples.pop_back();
            } else {
                break;
            }
        }
        self.samples.push_back((now, value));
        self.expire(now);
    }

    pub fn expire(&mut self, now: Nanos) {
        while let Some(&(t, _)) = self.samples.front() {
            if now.saturating_sub(t) > self.window {
                self.samples.pop_front();
            } else {
                break;
            }
        }
    }

    pub fn get(&self) -> Option<u64> {
        self.samples.front().map(|&(_, v)| v)
    }
}

/// Windowed minimum, same structure with the comparison flipped.
#[derive(Debug, Clone)]
pub struct WindowedMin {
    window: Nanos,
    samples: VecDeque<(Nanos, u64)>,
}

impl WindowedMin {
    pub fn new(window: Nanos) -> Self {
        WindowedMin { window, samples: VecDeque::new() }
    }

    pub fn add(&mut self, now: Nanos, value: u64) {
        while let Some(&(_, back)) = self.samples.back() {
            if back >= value {
                self.samples.pop_back();
            } else {
                break;
            }
        }
        self.samples.push_back((now, value));
        self.expire(now);
    }

    pub fn expire(&mut self, now: Nanos) {
        while let Some(&(t, _)) = self.samples.front() {
            if now.saturating_sub(t) > self.window {
                self.samples.pop_front();
            } else {
                break;
            }
        }
    }

    pub fn get(&self) -> Option<u64> {
        self.samples.front().map(|&(_, v)| v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windowed_max_tracks_max_and_expires() {
        let mut f = WindowedMax::new(100);
        f.add(0, 5);
        f.add(10, 3);
        f.add(20, 8);
        assert_eq!(f.get(), Some(8));
        f.add(50, 2);
        assert_eq!(f.get(), Some(8));
        // At t=130 the sample from t=20 is 110 old > 100 → expires.
        f.add(130, 1);
        assert_eq!(f.get(), Some(2));
    }

    #[test]
    fn windowed_min_tracks_min_and_expires() {
        let mut f = WindowedMin::new(100);
        f.add(0, 5);
        f.add(10, 9);
        f.add(20, 2);
        assert_eq!(f.get(), Some(2));
        f.add(125, 7);
        assert_eq!(f.get(), Some(7)); // the 2 at t=20 expired
    }

    #[test]
    fn expiry_boundary_is_inclusive() {
        // A sample exactly `window` old is still valid; one tick older is
        // not (`now - t > window` expires).
        let mut f = WindowedMax::new(100);
        f.add(0, 9);
        f.expire(100);
        assert_eq!(f.get(), Some(9), "age == window must be kept");
        f.expire(101);
        assert_eq!(f.get(), None, "age > window must expire");

        let mut m = WindowedMin::new(100);
        m.add(0, 9);
        m.expire(100);
        assert_eq!(m.get(), Some(9));
        m.expire(101);
        assert_eq!(m.get(), None);
    }

    #[test]
    fn empty_filters_return_none() {
        assert_eq!(WindowedMax::new(10).get(), None);
        assert_eq!(WindowedMin::new(10).get(), None);
    }

    #[test]
    fn monotone_deque_keeps_later_smaller_samples() {
        // After the max expires, the answer falls back to the best of the
        // still-live (smaller, later) samples — they must not have been
        // discarded with it.
        let mut f = WindowedMax::new(100);
        f.add(0, 50);
        f.add(10, 40);
        f.add(20, 30);
        assert_eq!(f.get(), Some(50));
        f.expire(105); // the 50 at t=0 ages out
        assert_eq!(f.get(), Some(40));
        f.expire(115);
        assert_eq!(f.get(), Some(30));
    }

    #[test]
    fn set_window_shrink_applies_on_next_touch() {
        let mut f = WindowedMax::new(1000);
        f.add(0, 7);
        f.set_window(10);
        f.expire(50);
        assert_eq!(f.get(), None, "shrunk window must expire old samples");
    }

    #[test]
    fn equal_values_refresh_timestamp() {
        // add() pops back entries with back <= value, so re-adding the same
        // value later must extend its lifetime.
        let mut f = WindowedMax::new(100);
        f.add(0, 5);
        f.add(90, 5);
        f.expire(150);
        assert_eq!(f.get(), Some(5), "refreshed sample lives from t=90");
        f.expire(191);
        assert_eq!(f.get(), None);
    }

    #[test]
    fn prop_min_filter_matches_naive() {
        crate::util::proptest::check("windowed min == naive", |rng| {
            let window = 50;
            let mut f = WindowedMin::new(window);
            let mut hist: Vec<(u64, u64)> = vec![];
            let mut t = 0;
            for _ in 0..200 {
                t += rng.gen_range(10);
                let v = rng.gen_range(1000);
                f.add(t, v);
                hist.push((t, v));
                let naive =
                    hist.iter().filter(|&&(ht, _)| t - ht <= window).map(|&(_, v)| v).min();
                assert_eq!(f.get(), naive);
            }
        });
    }

    #[test]
    fn prop_max_filter_matches_naive() {
        crate::util::proptest::check("windowed max == naive", |rng| {
            let window = 50;
            let mut f = WindowedMax::new(window);
            let mut hist: Vec<(u64, u64)> = vec![];
            let mut t = 0;
            for _ in 0..200 {
                t += rng.gen_range(10);
                let v = rng.gen_range(1000);
                f.add(t, v);
                hist.push((t, v));
                let naive =
                    hist.iter().filter(|&&(ht, _)| t - ht <= window).map(|&(_, v)| v).max();
                assert_eq!(f.get(), naive);
            }
        });
    }
}
