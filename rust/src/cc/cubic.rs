//! TCP Cubic (Ha, Rhee, Xu 2008; RFC 8312): window grows as
//! `W(t) = C·(t − K)³ + W_max` since the last congestion event, with
//! standard-TCP friendliness floor and fast convergence.

use super::{AckSample, CongestionControl};
use crate::Nanos;

/// RFC 8312 constants.
const C: f64 = 0.4;
const BETA: f64 = 0.7;

#[derive(Debug, Clone)]
pub struct Cubic {
    mss: u64,
    cwnd: f64,
    ssthresh: f64,
    w_max: f64,
    /// Time of the last congestion event.
    epoch_start: Option<Nanos>,
    k: f64,
    last_rtt: Nanos,
    loss_recovery_until: Nanos,
    /// TCP-friendly region estimate.
    w_est: f64,
    /// HyStart-style delay signal: minimum RTT seen (kernel cubic exits
    /// slow start when RTTs inflate well past this, instead of blasting
    /// until loss).
    min_rtt: Nanos,
}

impl Cubic {
    pub fn new(mss: u32) -> Cubic {
        let mss = mss as f64;
        Cubic {
            mss: mss as u64,
            cwnd: 10.0 * mss,
            ssthresh: f64::MAX,
            w_max: 0.0,
            epoch_start: None,
            k: 0.0,
            last_rtt: crate::MS,
            loss_recovery_until: 0,
            w_est: 0.0,
            min_rtt: Nanos::MAX,
        }
    }

    fn mss_f(&self) -> f64 {
        self.mss as f64
    }

    /// Cubic window in *segments* as a function of time since epoch.
    fn w_cubic(&self, t_sec: f64) -> f64 {
        let w_max_seg = self.w_max / self.mss_f();
        (C * (t_sec - self.k).powi(3) + w_max_seg) * self.mss_f()
    }
}

impl CongestionControl for Cubic {
    fn name(&self) -> &'static str {
        "cubic"
    }

    fn cwnd_bytes(&self) -> u64 {
        self.cwnd.max(self.mss_f()) as u64
    }

    fn on_ack(&mut self, s: AckSample) {
        self.last_rtt = s.rtt;
        self.min_rtt = self.min_rtt.min(s.rtt);
        if self.cwnd < self.ssthresh {
            // HyStart delay exit: queues are building, stop doubling.
            if s.rtt > self.min_rtt * 2 && self.cwnd > 16.0 * self.mss_f() {
                self.ssthresh = self.cwnd;
                return;
            }
            self.cwnd += s.acked_bytes as f64;
            if self.cwnd > self.ssthresh {
                self.cwnd = self.ssthresh;
            }
            return;
        }
        let epoch = *self.epoch_start.get_or_insert(s.now);
        let t = (s.now - epoch) as f64 / crate::SEC as f64;
        let rtt_sec = (s.rtt as f64 / crate::SEC as f64).max(1e-6);
        let target = self.w_cubic(t + rtt_sec);
        // TCP-friendly region (standard AIMD estimate).
        self.w_est += 0.5 * s.acked_bytes as f64 * self.mss_f() / self.cwnd.max(1.0) * 3.0
            * (1.0 - BETA)
            / (1.0 + BETA);
        let target = target.max(self.w_est);
        if target > self.cwnd {
            // Approach the target over one RTT.
            self.cwnd += (target - self.cwnd) * (s.acked_bytes as f64 / self.cwnd.max(1.0));
        } else {
            // Slow drift upward in the concave plateau.
            self.cwnd += 0.01 * self.mss_f() * (s.acked_bytes as f64 / self.cwnd.max(1.0));
        }
    }

    fn on_loss(&mut self, now: Nanos) {
        if now < self.loss_recovery_until {
            return;
        }
        // Fast convergence.
        self.w_max = if self.cwnd < self.w_max {
            self.cwnd * (1.0 + BETA) / 2.0
        } else {
            self.cwnd
        };
        self.cwnd = (self.cwnd * BETA).max(2.0 * self.mss_f());
        self.ssthresh = self.cwnd;
        self.epoch_start = Some(now);
        let w_max_seg = self.w_max / self.mss_f();
        let cwnd_seg = self.cwnd / self.mss_f();
        self.k = ((w_max_seg - cwnd_seg) / C).cbrt();
        self.w_est = self.cwnd;
        self.loss_recovery_until = now + self.last_rtt.max(crate::MS);
    }

    fn on_timeout(&mut self, now: Nanos) {
        self.on_loss(now);
        self.cwnd = self.mss_f();
        self.loss_recovery_until = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ack(now: Nanos, bytes: u64, rtt: Nanos) -> AckSample {
        AckSample {
            now,
            acked_bytes: bytes,
            rtt,
            delivery_rate_bps: None,
            ece: false,
            inflight_bytes: 0,
        }
    }

    #[test]
    fn slow_start_then_loss_reduces_by_beta() {
        let mut cc = Cubic::new(1460);
        cc.on_ack(ack(0, 100_000, crate::MS));
        let before = cc.cwnd_bytes() as f64;
        cc.on_loss(10 * crate::MS);
        let after = cc.cwnd_bytes() as f64;
        assert!((after / before - BETA).abs() < 0.01, "ratio {}", after / before);
    }

    #[test]
    fn cubic_recovers_toward_w_max() {
        // Keep w_max modest so the cubic K = ∛(w_max·(1−β)/C) horizon is a
        // few seconds, then verify the concave re-approach to w_max.
        let mut cc = Cubic::new(1460);
        // Grow to ~512 segments (≈ 750 KB), then lose.
        for i in 0..6 {
            let w = cc.cwnd_bytes();
            cc.on_ack(ack(i * crate::MS, w, crate::MS));
        }
        let w_before_loss = cc.cwnd_bytes();
        cc.on_loss(30 * crate::MS);
        assert!(cc.cwnd_bytes() < w_before_loss);
        // K = ∛(512·0.3/0.4) ≈ 7.3 s. ACK a window every ms for 12 s.
        let mut now = 31 * crate::MS;
        for _ in 0..12_000 {
            let w = cc.cwnd_bytes();
            cc.on_ack(ack(now, w, crate::MS));
            now += crate::MS;
        }
        let w_after = cc.cwnd_bytes();
        assert!(
            w_after as f64 > 0.9 * w_before_loss as f64,
            "cubic should reapproach w_max: {w_after} vs {w_before_loss}"
        );
    }

    #[test]
    fn repeated_losses_shrink_window() {
        let mut cc = Cubic::new(1460);
        cc.on_ack(ack(0, 1_000_000, crate::MS));
        let w0 = cc.cwnd_bytes();
        for i in 0..10 {
            cc.on_loss((10 + 10 * i) * crate::MS);
        }
        assert!(cc.cwnd_bytes() < w0 / 4);
    }

    #[test]
    fn never_below_one_mss() {
        let mut cc = Cubic::new(1460);
        for i in 0..50 {
            cc.on_timeout(i * crate::SEC);
        }
        assert!(cc.cwnd_bytes() >= 1460);
    }
}
