//! BBR v1 (Cardwell et al., 2016), modeled: Startup / Drain / ProbeBW /
//! ProbeRTT, windowed-max BtlBw over ~10 RTTs, windowed-min RTprop over
//! 10 s, pacing-gain cycling, cwnd = gain·BDP. Loss is not a primary
//! congestion signal — the property that keeps BBR usable in the paper's
//! lossy-network experiments.

use super::filters::{WindowedMax, WindowedMin};
use super::{AckSample, CongestionControl};
use crate::{Nanos, MS, SEC};

const STARTUP_GAIN: f64 = 2.885; // 2/ln(2)
const DRAIN_GAIN: f64 = 1.0 / STARTUP_GAIN;
const CWND_GAIN: f64 = 2.0;
const PROBE_BW_CYCLE: [f64; 8] = [1.25, 0.75, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
const RTPROP_WINDOW: Nanos = 10 * SEC;
const PROBE_RTT_INTERVAL: Nanos = 10 * SEC;
const PROBE_RTT_DURATION: Nanos = 200 * MS;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BbrState {
    Startup,
    Drain,
    ProbeBw,
    ProbeRtt,
}

#[derive(Debug)]
pub struct Bbr {
    mss: u64,
    state: BbrState,
    /// Max filter over delivery-rate samples (bytes/sec).
    btlbw: WindowedMax,
    /// Min filter over RTT samples (ns).
    rtprop: WindowedMin,
    pacing_gain: f64,
    cwnd_gain: f64,
    cycle_index: usize,
    cycle_stamp: Nanos,
    /// Startup plateau detection.
    full_bw: u64,
    full_bw_count: u32,
    round_start: Nanos,
    probe_rtt_done: Nanos,
    last_probe_rtt: Nanos,
    prior_cwnd: u64,
}

impl Bbr {
    pub fn new(mss: u32) -> Bbr {
        Bbr {
            mss: mss as u64,
            state: BbrState::Startup,
            btlbw: WindowedMax::new(SEC), // adapted to ~10·RTprop as samples arrive
            rtprop: WindowedMin::new(RTPROP_WINDOW),
            pacing_gain: STARTUP_GAIN,
            cwnd_gain: STARTUP_GAIN,
            cycle_index: 0,
            cycle_stamp: 0,
            full_bw: 0,
            full_bw_count: 0,
            round_start: 0,
            probe_rtt_done: 0,
            last_probe_rtt: 0,
            prior_cwnd: 0,
        }
    }

    pub fn state(&self) -> BbrState {
        self.state
    }

    /// BtlBw estimate in bytes/sec (0 until the first sample).
    pub fn btlbw_bytes_per_sec(&self) -> u64 {
        self.btlbw.get().unwrap_or(0)
    }

    /// RTprop estimate in ns.
    pub fn rtprop_ns(&self) -> Nanos {
        self.rtprop.get().unwrap_or(MS)
    }

    /// BDP in bytes at the current estimates.
    pub fn bdp_bytes(&self) -> u64 {
        let bw = self.btlbw_bytes_per_sec();
        let rt = self.rtprop_ns();
        ((bw as u128 * rt as u128) / SEC as u128) as u64
    }

    fn check_full_pipe(&mut self) {
        let bw = self.btlbw_bytes_per_sec();
        if bw as f64 >= self.full_bw as f64 * 1.25 {
            self.full_bw = bw;
            self.full_bw_count = 0;
        } else {
            self.full_bw_count += 1;
        }
    }

    fn advance_cycle(&mut self, now: Nanos) {
        if now.saturating_sub(self.cycle_stamp) >= self.rtprop_ns() {
            self.cycle_index = (self.cycle_index + 1) % PROBE_BW_CYCLE.len();
            self.cycle_stamp = now;
            self.pacing_gain = PROBE_BW_CYCLE[self.cycle_index];
        }
    }
}

impl CongestionControl for Bbr {
    fn name(&self) -> &'static str {
        "bbr"
    }

    fn cwnd_bytes(&self) -> u64 {
        if self.state == BbrState::ProbeRtt {
            return 4 * self.mss;
        }
        let bdp = self.bdp_bytes();
        if bdp == 0 {
            10 * self.mss // no estimate yet: initial window
        } else {
            ((self.cwnd_gain * bdp as f64) as u64).max(4 * self.mss)
        }
    }

    fn pacing_rate_bps(&self) -> Option<u64> {
        let bw = self.btlbw_bytes_per_sec();
        if bw == 0 {
            return None;
        }
        Some((self.pacing_gain * bw as f64 * 8.0) as u64)
    }

    fn on_ack(&mut self, s: AckSample) {
        // Update filters.
        self.rtprop.add(s.now, s.rtt);
        if let Some(rate) = s.delivery_rate_bps {
            let rate_bytes = rate / 8;
            // Keep the BtlBw window at ~10 RTprop.
            self.btlbw.set_window((10 * self.rtprop_ns()).max(100 * MS));
            self.btlbw.add(s.now, rate_bytes);
        }

        // Round boundary ≈ one RTprop.
        let new_round = s.now.saturating_sub(self.round_start) >= self.rtprop_ns();
        if new_round {
            self.round_start = s.now;
        }

        match self.state {
            BbrState::Startup => {
                if new_round {
                    self.check_full_pipe();
                }
                if self.full_bw_count >= 3 {
                    self.state = BbrState::Drain;
                    self.pacing_gain = DRAIN_GAIN;
                    self.cwnd_gain = CWND_GAIN;
                }
            }
            BbrState::Drain => {
                if s.inflight_bytes <= self.bdp_bytes() {
                    self.state = BbrState::ProbeBw;
                    self.pacing_gain = PROBE_BW_CYCLE[0];
                    self.cycle_index = 0;
                    self.cycle_stamp = s.now;
                    self.last_probe_rtt = s.now;
                }
            }
            BbrState::ProbeBw => {
                self.advance_cycle(s.now);
                if s.now.saturating_sub(self.last_probe_rtt) >= PROBE_RTT_INTERVAL {
                    self.state = BbrState::ProbeRtt;
                    self.prior_cwnd = self.cwnd_bytes();
                    self.probe_rtt_done = s.now + PROBE_RTT_DURATION.max(self.rtprop_ns());
                }
            }
            BbrState::ProbeRtt => {
                if s.now >= self.probe_rtt_done {
                    self.state = BbrState::ProbeBw;
                    self.last_probe_rtt = s.now;
                    self.cycle_stamp = s.now;
                    self.pacing_gain = PROBE_BW_CYCLE[self.cycle_index];
                }
            }
        }
    }

    fn on_loss(&mut self, _now: Nanos) {
        // BBRv1: loss is not a congestion signal. (Linux caps inflight to
        // the estimate during recovery; the windowed filters already give
        // that behaviour here.)
    }

    fn on_timeout(&mut self, _now: Nanos) {
        // Conservative: restart bandwidth probing.
        self.full_bw = 0;
        self.full_bw_count = 0;
        self.state = BbrState::Startup;
        self.pacing_gain = STARTUP_GAIN;
        self.cwnd_gain = STARTUP_GAIN;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ack(now: Nanos, rtt: Nanos, rate_bps: u64, inflight: u64) -> AckSample {
        AckSample {
            now,
            acked_bytes: 1460,
            rtt,
            delivery_rate_bps: Some(rate_bps),
            ece: false,
            inflight_bytes: inflight,
        }
    }

    #[test]
    fn startup_exits_on_plateau() {
        let mut cc = Bbr::new(1460);
        // Constant delivery rate → plateau after 3 rounds.
        let mut now = 0;
        for _ in 0..20 {
            now += 2 * MS;
            cc.on_ack(ack(now, MS, 1_000_000_000, 1_000_000));
        }
        assert_ne!(cc.state(), BbrState::Startup);
    }

    #[test]
    fn estimates_converge_to_link() {
        let mut cc = Bbr::new(1460);
        let mut now = 0;
        for _ in 0..100 {
            now += MS;
            cc.on_ack(ack(now, 2 * MS, 10_000_000_000, 100_000));
        }
        assert_eq!(cc.btlbw_bytes_per_sec(), 10_000_000_000 / 8);
        assert_eq!(cc.rtprop_ns(), 2 * MS);
        // BDP = 1.25 GB/s * 2 ms = 2.5 MB
        assert_eq!(cc.bdp_bytes(), 2_500_000);
    }

    #[test]
    fn loss_does_not_collapse_window() {
        let mut cc = Bbr::new(1460);
        let mut now = 0;
        for _ in 0..100 {
            now += MS;
            cc.on_ack(ack(now, 2 * MS, 10_000_000_000, 100_000));
        }
        let w = cc.cwnd_bytes();
        for i in 0..50 {
            cc.on_loss(now + i * MS);
        }
        assert_eq!(cc.cwnd_bytes(), w, "BBR must ignore loss");
    }

    #[test]
    fn probe_rtt_shrinks_cwnd_temporarily() {
        let mut cc = Bbr::new(1460);
        let mut now = 0;
        // Reach ProbeBw, then run past the 10 s ProbeRTT interval.
        for _ in 0..50 {
            now += MS;
            cc.on_ack(ack(now, 2 * MS, 10_000_000_000, 100_000));
        }
        assert_eq!(cc.state(), BbrState::ProbeBw);
        now += 11 * SEC;
        cc.on_ack(ack(now, 2 * MS, 10_000_000_000, 100_000));
        assert_eq!(cc.state(), BbrState::ProbeRtt);
        assert_eq!(cc.cwnd_bytes(), 4 * 1460);
        now += 300 * MS;
        cc.on_ack(ack(now, 2 * MS, 10_000_000_000, 100_000));
        assert_eq!(cc.state(), BbrState::ProbeBw);
    }

    #[test]
    fn pacing_rate_tracks_btlbw_with_gain() {
        let mut cc = Bbr::new(1460);
        let mut now = 0;
        for _ in 0..100 {
            now += MS;
            cc.on_ack(ack(now, 2 * MS, 8_000_000_000, 100_000));
        }
        let rate = cc.pacing_rate_bps().unwrap();
        // In ProbeBw the gain cycles 0.75–1.25 around BtlBw.
        assert!(rate >= 8_000_000_000 * 3 / 4 && rate <= 8_000_000_000 * 5 / 4, "rate {rate}");
    }
}
