//! LTP's BDP-based congestion controller (paper §III-D).
//!
//! Like BBR it estimates BtlBw (windowed max of delivery-rate samples) and
//! RTprop (windowed min of RTTs) and caps *packets in flight* at the BDP.
//! Unlike TCP, packet-loss recognition is **never** used to adjust the
//! window. Pacing is the paper's approximation: when more than
//! [`PACING_BURST`] packets would be released back-to-back, the sender
//! waits per the computed pacing rate instead of bursting.

use super::filters::{WindowedMax, WindowedMin};
use crate::{Nanos, MS, SEC};

/// Paper §III-D: bursts above 20 packets (10 G link, MTU 1500, ≈30 KB) are
/// paced rather than sent back-to-back.
pub const PACING_BURST: u32 = 20;

const STARTUP_GAIN: f64 = 2.885;
const RTPROP_WINDOW: Nanos = 10 * SEC;

#[derive(Debug)]
pub struct BdpCc {
    mtu: u32,
    btlbw: WindowedMax,
    rtprop: WindowedMin,
    /// Startup until the bandwidth estimate plateaus.
    startup: bool,
    full_bw: u64,
    full_bw_count: u32,
    round_start: Nanos,
    /// Probe cycle for steady state (mild, BBR-like).
    cycle_index: usize,
    cycle_stamp: Nanos,
}

const CYCLE: [f64; 8] = [1.25, 0.75, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];

impl BdpCc {
    pub fn new(mtu: u32) -> BdpCc {
        BdpCc {
            mtu,
            btlbw: WindowedMax::new(SEC),
            rtprop: WindowedMin::new(RTPROP_WINDOW),
            startup: true,
            full_bw: 0,
            full_bw_count: 0,
            round_start: 0,
            cycle_index: 0,
            cycle_stamp: 0,
        }
    }

    /// Ingest a per-packet ACK: RTT plus an optional delivery-rate sample.
    pub fn on_ack(&mut self, now: Nanos, rtt: Nanos, delivery_rate_bps: Option<u64>) {
        self.rtprop.add(now, rtt);
        if let Some(rate) = delivery_rate_bps {
            self.btlbw.set_window((10 * self.rtprop_ns()).max(100 * MS));
            self.btlbw.add(now, rate / 8);
        }
        let new_round = now.saturating_sub(self.round_start) >= self.rtprop_ns();
        if new_round {
            self.round_start = now;
            if self.startup {
                let bw = self.btlbw_bytes_per_sec();
                if bw as f64 >= self.full_bw as f64 * 1.25 {
                    self.full_bw = bw;
                    self.full_bw_count = 0;
                } else {
                    self.full_bw_count += 1;
                    if self.full_bw_count >= 3 {
                        self.startup = false;
                        self.cycle_stamp = now;
                    }
                }
            }
        }
        if !self.startup && now.saturating_sub(self.cycle_stamp) >= self.rtprop_ns() {
            self.cycle_index = (self.cycle_index + 1) % CYCLE.len();
            self.cycle_stamp = now;
        }
    }

    pub fn in_startup(&self) -> bool {
        self.startup
    }

    pub fn btlbw_bytes_per_sec(&self) -> u64 {
        self.btlbw.get().unwrap_or(0)
    }

    pub fn rtprop_ns(&self) -> Nanos {
        self.rtprop.get().unwrap_or(MS)
    }

    /// Seed the estimators from a peer's advertised values (LTP headers
    /// carry RTprop/BtlBw — §IV-A) or from a previous flow on the same
    /// path. Epochs share thresholds the same way (§III-B1).
    pub fn seed(&mut self, now: Nanos, rtprop: Nanos, btlbw_bytes_per_sec: u64) {
        if rtprop > 0 {
            self.rtprop.add(now, rtprop);
        }
        if btlbw_bytes_per_sec > 0 {
            self.btlbw.add(now, btlbw_bytes_per_sec);
            // A seeded flow starts in steady state.
            self.startup = false;
        }
    }

    /// BDP in bytes.
    pub fn bdp_bytes(&self) -> u64 {
        ((self.btlbw_bytes_per_sec() as u128 * self.rtprop_ns() as u128) / SEC as u128) as u64
    }

    /// Cap on packets in flight (paper: "uses BDP as the maximum count of
    /// packets in flight"). Like BBR, the steady-state cap carries a 2x
    /// gain over the *propagation* BDP — with competing traffic the actual
    /// RTT includes queueing, and a cap of exactly 1 BDP(rtprop) would
    /// starve the flow. A floor of 10 packets keeps startup moving.
    pub fn inflight_cap_pkts(&self) -> u64 {
        let bdp = self.bdp_bytes();
        if bdp == 0 {
            return 10;
        }
        let gain = if self.startup { STARTUP_GAIN } else { 2.0 };
        (((bdp as f64 * gain) / self.mtu as f64).ceil() as u64).max(4)
    }

    /// Pacing rate in bits/sec (None until an estimate exists).
    pub fn pacing_rate_bps(&self) -> Option<u64> {
        let bw = self.btlbw_bytes_per_sec();
        if bw == 0 {
            return None;
        }
        let gain = if self.startup { STARTUP_GAIN } else { CYCLE[self.cycle_index] };
        Some((bw as f64 * 8.0 * gain) as u64)
    }

    /// Expected completion time for `bytes` on this path (paper §III-B1:
    /// `ECT = RTprop + ModelSize/BtlBw`). Returns `None` without estimates.
    pub fn expected_completion(&self, bytes: u64) -> Option<Nanos> {
        let bw = self.btlbw_bytes_per_sec();
        if bw == 0 {
            return None;
        }
        Some(self.rtprop_ns() + ((bytes as u128 * SEC as u128) / bw as u128) as Nanos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimates_and_bdp() {
        let mut cc = BdpCc::new(1500);
        let mut now = 0;
        for _ in 0..100 {
            now += MS;
            cc.on_ack(now, 2 * MS, Some(1_000_000_000)); // 1 Gbps
        }
        assert_eq!(cc.btlbw_bytes_per_sec(), 125_000_000);
        assert_eq!(cc.rtprop_ns(), 2 * MS);
        assert_eq!(cc.bdp_bytes(), 250_000);
        assert!(!cc.in_startup());
        // 2 x 250 KB / 1500 B ≈ 334 packets (2x steady-state gain)
        assert_eq!(cc.inflight_cap_pkts(), 334);
    }

    #[test]
    fn startup_cap_is_aggressive() {
        let mut cc = BdpCc::new(1500);
        cc.on_ack(MS, 2 * MS, Some(1_000_000_000));
        assert!(cc.in_startup());
        let cap = cc.inflight_cap_pkts();
        assert!(cap as f64 >= 167.0 * 2.5, "startup cap {cap} should be gained up");
    }

    #[test]
    fn ect_formula() {
        let mut cc = BdpCc::new(1500);
        cc.seed(0, 2 * MS, 125_000_000); // 1 Gbps, 2 ms
        // 12.5 MB at 125 MB/s = 100 ms (+ 2 ms RTprop)
        assert_eq!(cc.expected_completion(12_500_000), Some(102 * MS));
    }

    #[test]
    fn seeding_skips_startup() {
        let mut cc = BdpCc::new(1500);
        cc.seed(0, MS, 1_250_000_000);
        assert!(!cc.in_startup());
        assert!(cc.inflight_cap_pkts() > 100);
    }

    #[test]
    fn no_estimate_floor_cap() {
        let cc = BdpCc::new(1500);
        assert_eq!(cc.inflight_cap_pkts(), 10);
        assert_eq!(cc.pacing_rate_bps(), None);
        assert_eq!(cc.expected_completion(1000), None);
    }
}
