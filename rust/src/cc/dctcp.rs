//! DCTCP (Alizadeh et al., SIGCOMM 2010): ECN-fraction-proportional window
//! reduction. Falls back to Reno-style halving on real loss — which is what
//! makes it as loss-fragile as Reno/Cubic in the paper's Fig 4 table.

use super::{AckSample, CongestionControl};
use crate::Nanos;

const G: f64 = 1.0 / 16.0; // EWMA gain for the marked fraction

#[derive(Debug, Clone)]
pub struct Dctcp {
    mss: u64,
    cwnd: u64,
    ssthresh: u64,
    /// EWMA of the marked-byte fraction ("alpha").
    alpha: f64,
    /// Per-observation-window accounting.
    acked_bytes_epoch: u64,
    marked_bytes_epoch: u64,
    epoch_end_accum: u64,
    acked_accum: u64,
    loss_recovery_until: Nanos,
    last_rtt: Nanos,
    /// HyStart-style delay signal: minimum RTT seen (kernel TCP exits
    /// slow start when RTTs inflate well past this, instead of blasting
    /// until loss).
    min_rtt: Nanos,
}

impl Dctcp {
    pub fn new(mss: u32) -> Dctcp {
        let mss = mss as u64;
        Dctcp {
            mss,
            cwnd: 10 * mss,
            ssthresh: u64::MAX,
            alpha: 0.0,
            acked_bytes_epoch: 0,
            marked_bytes_epoch: 0,
            epoch_end_accum: 0,
            acked_accum: 0,
            loss_recovery_until: 0,
            last_rtt: crate::MS,
            min_rtt: Nanos::MAX,
        }
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }
}

impl CongestionControl for Dctcp {
    fn name(&self) -> &'static str {
        "dctcp"
    }

    fn cwnd_bytes(&self) -> u64 {
        self.cwnd
    }

    fn on_ack(&mut self, s: AckSample) {
        self.last_rtt = s.rtt;
        self.acked_bytes_epoch += s.acked_bytes;
        if s.ece {
            self.marked_bytes_epoch += s.acked_bytes;
        }
        self.epoch_end_accum += s.acked_bytes;

        // One observation window ≈ one cwnd of acked data.
        if self.epoch_end_accum >= self.cwnd {
            let f = if self.acked_bytes_epoch == 0 {
                0.0
            } else {
                self.marked_bytes_epoch as f64 / self.acked_bytes_epoch as f64
            };
            self.alpha = (1.0 - G) * self.alpha + G * f;
            if self.marked_bytes_epoch > 0 {
                // DCTCP reduction: cwnd *= (1 − α/2).
                let new = (self.cwnd as f64 * (1.0 - self.alpha / 2.0)) as u64;
                self.cwnd = new.max(2 * self.mss);
                self.ssthresh = self.cwnd;
            }
            self.acked_bytes_epoch = 0;
            self.marked_bytes_epoch = 0;
            self.epoch_end_accum = 0;
        }

        // Growth identical to Reno (with the same HyStart delay exit).
        self.min_rtt = self.min_rtt.min(s.rtt);
        if self.cwnd < self.ssthresh {
            if s.rtt > self.min_rtt * 2 && self.cwnd > 16 * self.mss {
                self.ssthresh = self.cwnd;
                return;
            }
            self.cwnd += s.acked_bytes;
            if self.cwnd > self.ssthresh {
                self.cwnd = self.ssthresh;
            }
        } else {
            self.acked_accum += s.acked_bytes;
            if self.acked_accum >= self.cwnd {
                self.acked_accum -= self.cwnd;
                self.cwnd += self.mss;
            }
        }
    }

    fn on_loss(&mut self, now: Nanos) {
        if now < self.loss_recovery_until {
            return;
        }
        self.ssthresh = (self.cwnd / 2).max(2 * self.mss);
        self.cwnd = self.ssthresh;
        self.loss_recovery_until = now + self.last_rtt.max(crate::MS);
    }

    fn on_timeout(&mut self, _now: Nanos) {
        self.ssthresh = (self.cwnd / 2).max(2 * self.mss);
        self.cwnd = self.mss;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ack(now: Nanos, bytes: u64, ece: bool) -> AckSample {
        AckSample {
            now,
            acked_bytes: bytes,
            rtt: crate::MS,
            delivery_rate_bps: None,
            ece,
            inflight_bytes: 0,
        }
    }

    #[test]
    fn no_marks_no_reduction() {
        let mut cc = Dctcp::new(1460);
        let w0 = cc.cwnd_bytes();
        for i in 0..20 {
            cc.on_ack(ack(i * crate::MS, 14600, false));
        }
        assert!(cc.cwnd_bytes() > w0);
        assert_eq!(cc.alpha(), 0.0);
    }

    #[test]
    fn full_marking_converges_alpha_to_one() {
        let mut cc = Dctcp::new(1460);
        for i in 0..2000 {
            let w = cc.cwnd_bytes();
            cc.on_ack(ack(i * crate::MS, w, true));
        }
        assert!(cc.alpha() > 0.9, "alpha {}", cc.alpha());
    }

    #[test]
    fn proportional_reduction_is_gentler_than_halving() {
        // Light marking: alpha stays small → reductions ≪ 50 %.
        let mut cc = Dctcp::new(1460);
        // leave slow start
        cc.on_loss(0);
        let mut reductions = vec![];
        let mut prev = cc.cwnd_bytes();
        for i in 0..200 {
            let w = cc.cwnd_bytes();
            // 5 % of ACKs marked
            cc.on_ack(ack((i + 10) * crate::MS, w, i % 20 == 0));
            if cc.cwnd_bytes() < prev {
                reductions.push(prev as f64 / cc.cwnd_bytes() as f64);
            }
            prev = cc.cwnd_bytes();
        }
        for r in reductions {
            assert!(r < 1.5, "reduction factor {r} too sharp for light marking");
        }
    }

    #[test]
    fn loss_still_halves() {
        let mut cc = Dctcp::new(1460);
        cc.on_ack(ack(0, 100_000, false));
        let w = cc.cwnd_bytes();
        cc.on_loss(crate::MS);
        assert_eq!(cc.cwnd_bytes(), w / 2);
    }
}
