//! TCP New Reno: slow start, AIMD congestion avoidance, halving on fast
//! retransmit, collapse to one MSS on timeout (RFC 5681/6582 dynamics at
//! the granularity the simulator models).

use super::{AckSample, CongestionControl};
use crate::Nanos;

#[derive(Debug, Clone)]
pub struct Reno {
    mss: u64,
    cwnd: u64,
    ssthresh: u64,
    /// Byte accumulator for congestion-avoidance growth (cwnd += mss per
    /// cwnd bytes acked).
    acked_accum: u64,
    /// Ignore further loss signals until `now` passes this point (one
    /// reaction per window, approximating NewReno's recovery epoch).
    loss_recovery_until: Nanos,
    last_rtt: Nanos,
    /// HyStart-style delay signal: minimum RTT seen (kernel TCP exits
    /// slow start when RTTs inflate well past this, instead of blasting
    /// until loss).
    min_rtt: Nanos,
}

impl Reno {
    pub fn new(mss: u32) -> Reno {
        let mss = mss as u64;
        Reno {
            mss,
            cwnd: 10 * mss, // RFC 6928 initial window
            ssthresh: u64::MAX,
            acked_accum: 0,
            loss_recovery_until: 0,
            last_rtt: 0,
            min_rtt: Nanos::MAX,
        }
    }

    pub fn in_slow_start(&self) -> bool {
        self.cwnd < self.ssthresh
    }
}

impl CongestionControl for Reno {
    fn name(&self) -> &'static str {
        "reno"
    }

    fn cwnd_bytes(&self) -> u64 {
        self.cwnd
    }

    fn on_ack(&mut self, s: AckSample) {
        self.last_rtt = s.rtt;
        self.min_rtt = self.min_rtt.min(s.rtt);
        if self.in_slow_start() {
            // HyStart delay exit: queues are building, stop doubling.
            if s.rtt > self.min_rtt * 2 && self.cwnd > 16 * self.mss {
                self.ssthresh = self.cwnd;
                return;
            }
            self.cwnd += s.acked_bytes; // exponential growth
            if self.cwnd > self.ssthresh {
                self.cwnd = self.ssthresh;
            }
        } else {
            // cwnd += mss per cwnd acked bytes.
            self.acked_accum += s.acked_bytes;
            if self.acked_accum >= self.cwnd {
                self.acked_accum -= self.cwnd;
                self.cwnd += self.mss;
            }
        }
    }

    fn on_loss(&mut self, now: Nanos) {
        if now < self.loss_recovery_until {
            return; // already reacted this window
        }
        self.ssthresh = (self.cwnd / 2).max(2 * self.mss);
        self.cwnd = self.ssthresh;
        self.acked_accum = 0;
        // One reaction per RTT-ish epoch.
        self.loss_recovery_until = now + self.last_rtt.max(crate::MS);
    }

    fn on_timeout(&mut self, now: Nanos) {
        self.ssthresh = (self.cwnd / 2).max(2 * self.mss);
        self.cwnd = self.mss;
        self.acked_accum = 0;
        self.loss_recovery_until = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ack(now: Nanos, bytes: u64) -> AckSample {
        AckSample {
            now,
            acked_bytes: bytes,
            rtt: crate::MS,
            delivery_rate_bps: None,
            ece: false,
            inflight_bytes: 0,
        }
    }

    #[test]
    fn slow_start_doubles_per_rtt() {
        let mut cc = Reno::new(1460);
        let w0 = cc.cwnd_bytes();
        cc.on_ack(ack(0, w0)); // ack a whole window
        assert_eq!(cc.cwnd_bytes(), 2 * w0);
    }

    #[test]
    fn loss_halves_and_exits_slow_start() {
        let mut cc = Reno::new(1460);
        for i in 0..10 {
            let w = cc.cwnd_bytes();
            cc.on_ack(ack(i * crate::MS, w));
        }
        let before = cc.cwnd_bytes();
        cc.on_loss(100 * crate::MS);
        assert_eq!(cc.cwnd_bytes(), before / 2);
        assert!(!cc.in_slow_start());
    }

    #[test]
    fn one_reaction_per_window() {
        let mut cc = Reno::new(1460);
        cc.on_ack(ack(0, 100 * 1460));
        let w = cc.cwnd_bytes();
        cc.on_loss(crate::MS);
        cc.on_loss(crate::MS + 10); // same recovery epoch: ignored
        assert_eq!(cc.cwnd_bytes(), w / 2);
    }

    #[test]
    fn congestion_avoidance_is_linear() {
        let mut cc = Reno::new(1000);
        cc.on_loss(0); // force out of slow start
        let w = cc.cwnd_bytes();
        cc.on_ack(ack(crate::SEC, w)); // one window acked → +1 mss
        assert_eq!(cc.cwnd_bytes(), w + 1000);
    }

    #[test]
    fn timeout_collapses_to_one_mss() {
        let mut cc = Reno::new(1460);
        cc.on_ack(ack(0, 100_000));
        cc.on_timeout(crate::MS);
        assert_eq!(cc.cwnd_bytes(), 1460);
    }
}
