//! PCG-XSH-RR 64/32 pseudo-random generator (O'Neill 2014), plus the
//! handful of distributions the simulator needs.
//!
//! Deterministic and seedable: every experiment in `figures/` is exactly
//! reproducible from its seed, which is what lets the paper-figure benches
//! assert on shapes rather than flaking.

/// A 64-bit-state PCG generator producing 32-bit outputs (combined into
/// 64-bit values on demand).
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different stream ids
    /// yield independent sequences for the same seed — used to give every
    /// link/node its own stream so adding a node does not perturb others.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1 };
        let _ = rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        let _ = rng.next_u32();
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire's method, unbiased enough
    /// for simulation purposes via rejection).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        // Rejection sampling on the top bits to stay unbiased.
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        p > 0.0 && self.next_f64() < p
    }

    /// Exponentially distributed value with the given mean.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices out of `n` (k ≤ n), order unspecified.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm: O(k) expected.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_range(j as u64 + 1) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "independent streams should rarely collide");
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg64::seeded(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn chance_matches_probability() {
        let mut rng = Pcg64::seeded(9);
        let n = 100_000;
        let hits = (0..n).filter(|_| rng.chance(0.01)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.01).abs() < 0.002, "rate {rate}");
    }

    #[test]
    fn gen_range_bounds() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..1000 {
            let v = rng.gen_range(17);
            assert!(v < 17);
        }
    }

    #[test]
    fn exp_mean() {
        let mut rng = Pcg64::seeded(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.exp(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::seeded(5);
        let idx = rng.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(6);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
