//! A minimal randomized property-test harness (the vendored dependency set
//! has no `proptest`). Properties run a fixed number of deterministic,
//! seeded cases; on failure the failing seed is printed so the case can be
//! replayed exactly.

use super::pcg::Pcg64;

/// Number of cases per property (overridable via `LTP_PROPTEST_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("LTP_PROPTEST_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(128)
}

/// Run `prop` against `default_cases()` seeded RNGs. The property should
/// panic (e.g. via `assert!`) on violation. The failing case's seed is
/// attached to the panic message via a wrapper panic.
pub fn check<F: Fn(&mut Pcg64)>(name: &str, prop: F) {
    check_seeded(name, 0xC0FFEE, prop)
}

/// Like [`check`] but with an explicit base seed (replay a failure by
/// passing the printed seed and setting `LTP_PROPTEST_CASES=1`).
pub fn check_seeded<F: Fn(&mut Pcg64)>(name: &str, base_seed: u64, prop: F) {
    let cases = default_cases();
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Pcg64::new(seed, case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u32 roundtrip", |rng| {
            let x = rng.next_u32();
            let bytes = x.to_le_bytes();
            assert_eq!(u32::from_le_bytes(bytes), x);
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_reports_seed() {
        check("always fails", |rng| {
            let v = rng.gen_range(10);
            assert!(v > 100, "v={v} is small");
        });
    }
}
