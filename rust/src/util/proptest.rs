//! A minimal randomized property-test harness (the vendored dependency set
//! has no `proptest`). Properties run a fixed number of deterministic,
//! seeded cases; on failure the failing case's **seed and iteration** are
//! printed to stderr *and* embedded in the panic message, together with the
//! exact environment variables that replay just that case — so a property
//! failure in a CI log is reproducible locally with one command.
//!
//! Environment knobs:
//!
//! * `LTP_PROPTEST_CASES=N` — cases per property (default 128).
//! * `LTP_PROPTEST_BASE_SEED=0xHEX|N` — override the base seed for every
//!   property (shift the whole exploration).
//! * `LTP_PROPTEST_REPLAY=<seed>:<case>` — run exactly one case with the
//!   given derived seed and case index (what a failure report tells you to
//!   set).
//! * `LTP_PROPTEST_REPLAY_NAME=<property>` — scope the replay to one
//!   property; all others run their normal case sweep (set this when the
//!   test binary hosts several properties, as the failure report does).

use super::pcg::Pcg64;

/// Number of cases per property (overridable via `LTP_PROPTEST_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("LTP_PROPTEST_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(128)
}

/// Parse a decimal or `0x`-prefixed hex u64.
fn parse_u64(s: &str) -> Option<u64> {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// `LTP_PROPTEST_REPLAY=<seed>:<case>` — a single (seed, case) to replay.
fn replay_target() -> Option<(u64, u64)> {
    let v = std::env::var("LTP_PROPTEST_REPLAY").ok()?;
    let (seed, case) = v.split_once(':')?;
    Some((parse_u64(seed)?, parse_u64(case)?))
}

/// Run `prop` against `default_cases()` seeded RNGs. The property should
/// panic (e.g. via `assert!`) on violation; the failing case's seed and
/// iteration are reported on stderr and in the wrapping panic.
pub fn check<F: Fn(&mut Pcg64)>(name: &str, prop: F) {
    let base = std::env::var("LTP_PROPTEST_BASE_SEED")
        .ok()
        .and_then(|s| parse_u64(&s))
        .unwrap_or(0xC0FFEE);
    check_seeded(name, base, prop)
}

/// Like [`check`] but with an explicit base seed.
pub fn check_seeded<F: Fn(&mut Pcg64)>(name: &str, base_seed: u64, prop: F) {
    let replay_applies = match std::env::var("LTP_PROPTEST_REPLAY_NAME") {
        Ok(target) => target == name,
        Err(_) => true, // unscoped replay applies everywhere
    };
    if replay_applies {
        if let Some((seed, case)) = replay_target() {
            eprintln!("proptest `{name}`: replaying case {case} (seed {seed:#x})");
            run_case(name, &prop, seed, case);
            return;
        }
    }
    let cases = default_cases();
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        run_case(name, &prop, seed, case);
    }
}

fn run_case<F: Fn(&mut Pcg64)>(name: &str, prop: &F, seed: u64, case: u64) {
    let mut rng = Pcg64::new(seed, case);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        prop(&mut rng);
    }));
    if let Err(e) = result {
        let msg = e
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_else(|| "<non-string panic>".to_string());
        // The CI-log breadcrumb: everything needed to replay this exact
        // case, independent of the (possibly truncated) panic message.
        eprintln!(
            "\nproptest FAILED: property `{name}` at case {case} (seed {seed:#x})\n\
             replay with: LTP_PROPTEST_REPLAY={seed:#x}:{case} \
             LTP_PROPTEST_REPLAY_NAME='{name}' cargo test\n\
             assertion: {msg}\n"
        );
        panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u32 roundtrip", |rng| {
            let x = rng.next_u32();
            let bytes = x.to_le_bytes();
            assert_eq!(u32::from_le_bytes(bytes), x);
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_reports_seed() {
        check("always fails", |rng| {
            let v = rng.gen_range(10);
            assert!(v > 100, "v={v} is small");
        });
    }

    #[test]
    fn failure_message_carries_seed_and_case() {
        let result = std::panic::catch_unwind(|| {
            check_seeded("seeded failure", 0xABCD, |rng| {
                let _ = rng.next_u32();
                panic!("boom");
            })
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Case 0: derived seed == base seed.
        assert!(msg.contains("case 0"), "{msg}");
        assert!(msg.contains("seed 0xabcd"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn derived_seed_is_replayable() {
        // The seed printed for case N must reproduce that case's RNG stream
        // via Pcg64::new(seed, N) — the exact recipe run_case uses.
        let base = 0xC0FFEEu64;
        let case = 5u64;
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut a = Pcg64::new(seed, case);
        let mut b = Pcg64::new(seed, case);
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
