//! Fixed-capacity bitset used for per-segment bookkeeping (arrival bitmaps,
//! ACK tracking). Hot path: `set`/`get` are O(1), `count_ones` is cached.

#[derive(Debug, Clone, PartialEq)]
pub struct Bitmap {
    words: Vec<u64>,
    len: usize,
    ones: usize,
}

impl Bitmap {
    pub fn new(len: usize) -> Bitmap {
        Bitmap { words: vec![0; len.div_ceil(64)], len, ones: 0 }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Grow capacity to at least `len` (new bits are 0).
    pub fn grow(&mut self, len: usize) {
        if len > self.len {
            self.len = len;
            self.words.resize(len.div_ceil(64), 0);
        }
    }

    /// Set bit `i`; returns true if it was previously clear.
    #[inline]
    pub fn set(&mut self, i: usize) -> bool {
        assert!(i < self.len, "bit {i} out of range {}", self.len);
        let (w, b) = (i / 64, i % 64);
        let mask = 1u64 << b;
        if self.words[w] & mask == 0 {
            self.words[w] |= mask;
            self.ones += 1;
            true
        } else {
            false
        }
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        if i >= self.len {
            return false;
        }
        self.words[i / 64] & (1u64 << (i % 64)) != 0
    }

    #[inline]
    pub fn count_ones(&self) -> usize {
        self.ones
    }

    pub fn all_set(&self) -> bool {
        self.ones == self.len
    }

    /// Iterator over clear bit indices (the "missing segments").
    pub fn iter_zeros(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.len).filter(move |&i| !self.get(i))
    }

    /// Iterator over set bit indices.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.len).filter(move |&i| self.get(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_count() {
        let mut b = Bitmap::new(130);
        assert!(b.set(0));
        assert!(b.set(64));
        assert!(b.set(129));
        assert!(!b.set(64)); // already set
        assert_eq!(b.count_ones(), 3);
        assert!(b.get(129) && !b.get(128));
        assert!(!b.all_set());
    }

    #[test]
    fn all_set_detection() {
        let mut b = Bitmap::new(5);
        for i in 0..5 {
            b.set(i);
        }
        assert!(b.all_set());
    }

    #[test]
    fn grow_preserves_bits() {
        let mut b = Bitmap::new(10);
        b.set(7);
        b.grow(100);
        assert!(b.get(7));
        assert_eq!(b.len(), 100);
        assert_eq!(b.count_ones(), 1);
    }

    #[test]
    fn zeros_iterator() {
        let mut b = Bitmap::new(6);
        b.set(1);
        b.set(3);
        assert_eq!(b.iter_zeros().collect::<Vec<_>>(), vec![0, 2, 4, 5]);
        assert_eq!(b.iter_ones().collect::<Vec<_>>(), vec![1, 3]);
    }

    #[test]
    fn out_of_range_get_is_false() {
        let b = Bitmap::new(4);
        assert!(!b.get(1000));
    }

    #[test]
    fn empty_bitmap_edge_cases() {
        let b = Bitmap::new(0);
        assert!(b.is_empty());
        assert_eq!(b.len(), 0);
        assert_eq!(b.count_ones(), 0);
        // Vacuously full: zero of zero bits are set.
        assert!(b.all_set());
        assert_eq!(b.iter_zeros().count(), 0);
        assert_eq!(b.iter_ones().count(), 0);
        assert!(!b.get(0));
    }

    #[test]
    fn word_boundary_last_word_masks() {
        // Lengths straddling the 64-bit word edges: the last word is
        // partially used and its mask must not leak phantom bits.
        for len in [63usize, 64, 65, 127, 128, 129] {
            let mut b = Bitmap::new(len);
            for i in 0..len {
                assert!(b.set(i), "bit {i} of {len} set twice");
            }
            assert!(b.all_set(), "len {len} must report full");
            assert_eq!(b.count_ones(), len);
            assert_eq!(b.iter_zeros().count(), 0, "len {len} has phantom zeros");
            // Bits just past the end read as clear, never as set.
            assert!(!b.get(len));
            assert!(!b.get(len + 63));
        }
    }

    #[test]
    fn boundary_bits_are_independent() {
        let mut b = Bitmap::new(130);
        b.set(63);
        b.set(64);
        assert!(b.get(63) && b.get(64));
        assert!(!b.get(62) && !b.get(65));
        assert_eq!(b.count_ones(), 2);
        assert_eq!(b.iter_ones().collect::<Vec<_>>(), vec![63, 64]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn set_out_of_range_panics() {
        Bitmap::new(10).set(10);
    }

    #[test]
    fn grow_across_word_boundary_keeps_count() {
        let mut b = Bitmap::new(64);
        for i in 0..64 {
            b.set(i);
        }
        assert!(b.all_set());
        b.grow(65);
        assert!(!b.all_set(), "growing a full map must unfill it");
        assert_eq!(b.count_ones(), 64);
        assert_eq!(b.iter_zeros().collect::<Vec<_>>(), vec![64]);
        // Growing to a smaller/equal length is a no-op.
        b.grow(10);
        assert_eq!(b.len(), 65);
    }

    #[test]
    fn prop_count_matches_naive() {
        crate::util::proptest::check("bitmap count", |rng| {
            let n = 1 + rng.gen_range(300) as usize;
            let mut b = Bitmap::new(n);
            let mut naive = std::collections::HashSet::new();
            for _ in 0..rng.gen_range(500) {
                let i = rng.gen_range(n as u64) as usize;
                b.set(i);
                naive.insert(i);
            }
            assert_eq!(b.count_ones(), naive.len());
        });
    }
}
