//! Small self-contained utilities: deterministic RNG, statistics helpers,
//! and a tiny randomized-property-test harness.
//!
//! The crate builds fully offline against a vendored dependency set that
//! does not include `rand`/`proptest`/`criterion`, so the pieces of those
//! crates we actually need are implemented here (and unit-tested).

pub mod bitmap;
pub mod pcg;
pub mod proptest;
pub mod stats;

pub use bitmap::Bitmap;
pub use pcg::Pcg64;
pub use stats::{jain_fairness, Histogram, Summary};

/// Format a byte count in human units (`12.3 MB`).
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let b = b as f64;
    if b >= K * K * K {
        format!("{:.2} GiB", b / K / K / K)
    } else if b >= K * K {
        format!("{:.2} MiB", b / K / K)
    } else if b >= K {
        format!("{:.2} KiB", b / K)
    } else {
        format!("{b} B")
    }
}

/// Format nanoseconds in human units (`1.234 ms`).
pub fn fmt_nanos(ns: crate::Nanos) -> String {
    if ns >= crate::SEC {
        format!("{:.3} s", ns as f64 / crate::SEC as f64)
    } else if ns >= crate::MS {
        format!("{:.3} ms", ns as f64 / crate::MS as f64)
    } else if ns >= crate::US {
        format!("{:.3} us", ns as f64 / crate::US as f64)
    } else {
        format!("{ns} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(98 * 1024 * 1024), "98.00 MiB");
    }

    #[test]
    fn nanos_formatting() {
        assert_eq!(fmt_nanos(10), "10 ns");
        assert_eq!(fmt_nanos(1_500), "1.500 us");
        assert_eq!(fmt_nanos(30_000_000), "30.000 ms");
        assert_eq!(fmt_nanos(2_000_000_000), "2.000 s");
    }
}
