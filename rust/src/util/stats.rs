//! Summary statistics and streaming histograms used by the metrics layer
//! and the figure runners (FCT/BST distributions, fairness indices, …).

/// Five-number-style summary over a sample of `f64` values.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p25: f64,
    pub p50: f64,
    pub p75: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary. Returns a zeroed summary for an empty slice.
    pub fn of(values: &[f64]) -> Summary {
        if values.is_empty() {
            return Summary {
                count: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p25: 0.0,
                p50: 0.0,
                p75: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let mut v: Vec<f64> = values.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            count: n,
            mean,
            std: var.sqrt(),
            min: v[0],
            p25: percentile_sorted(&v, 0.25),
            p50: percentile_sorted(&v, 0.50),
            p75: percentile_sorted(&v, 0.75),
            p90: percentile_sorted(&v, 0.90),
            p99: percentile_sorted(&v, 0.99),
            max: v[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice, `q ∈ [0,1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Jain's fairness index: `(Σx)² / (n·Σx²)`. 1.0 = perfectly fair.
pub fn jain_fairness(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 == 0.0 {
        1.0
    } else {
        s * s / (xs.len() as f64 * s2)
    }
}

/// A fixed-bin histogram over `[lo, hi)`; out-of-range values clamp to the
/// edge bins. Used for FCT/BST probability-density plots (paper Fig 3, 14).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Histogram {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], total: 0 }
    }

    pub fn add(&mut self, x: f64) {
        let n = self.bins.len();
        let idx = if x <= self.lo {
            0
        } else if x >= self.hi {
            n - 1
        } else {
            (((x - self.lo) / (self.hi - self.lo)) * n as f64) as usize
        };
        self.bins[idx.min(n - 1)] += 1;
        self.total += 1;
    }

    /// Probability density per bin (sums to 1 over bins).
    pub fn density(&self) -> Vec<f64> {
        if self.total == 0 {
            return vec![0.0; self.bins.len()];
        }
        self.bins.iter().map(|&c| c as f64 / self.total as f64).collect()
    }

    /// Bin center for index `i`.
    pub fn center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + (i as f64 + 0.5) * w
    }
}

/// Exact running mean/variance (Welford) for streaming metrics.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty_is_zero() {
        let s = Summary::of(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 1.0), 10.0);
    }

    #[test]
    fn jain_index_bounds() {
        assert!((jain_fairness(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        let unfair = jain_fairness(&[1.0, 0.0, 0.0]);
        assert!((unfair - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_density_sums_to_one() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..100 {
            h.add(i as f64 / 10.0);
        }
        let d: f64 = h.density().iter().sum();
        assert!((d - 1.0).abs() < 1e-12);
        // clamping
        h.add(-5.0);
        h.add(50.0);
        assert_eq!(h.total, 102);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.add(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.std() - 2.0).abs() < 1e-12);
    }
}
