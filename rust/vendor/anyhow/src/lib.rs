//! Minimal vendored stand-in for the `anyhow` crate.
//!
//! The `ltp` build is fully offline (no crates.io), so the subset of
//! `anyhow` the crate actually uses is implemented here: [`Error`],
//! [`Result`], the [`Context`] extension trait for `Result`/`Option`, and
//! the `anyhow!` / `bail!` / `ensure!` macros. Semantics follow the real
//! crate where they matter: `{:#}` renders the full context chain,
//! `?` converts any `std::error::Error`, and context wraps rather than
//! replaces the underlying error.

use std::fmt;

/// An error message chain (outermost context first).
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), cause: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), cause: Some(Box::new(self)) }
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.cause.as_deref();
            Some(cur.msg.as_str())
        })
    }

    /// The innermost message in the chain.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }

    fn from_std(err: &(dyn std::error::Error + 'static)) -> Error {
        Error {
            msg: err.to_string(),
            cause: err.source().map(|s| Box::new(Error::from_std(s))),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, colon-separated (anyhow-compatible).
            for (i, msg) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` (and the
// blanket `IntoError` below) coherent alongside the `Error`-specific impls.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        Error::from_std(&err)
    }
}

/// `anyhow::Result`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    /// Conversion into [`crate::Error`] for context attachment. Implemented
    /// for every `std::error::Error` and for `Error` itself (the latter is
    /// coherent because `Error` does not implement `std::error::Error`).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait attaching context to `Result` and `Option` (mirror of
/// `anyhow::Context`).
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: ext::IntoError,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| ext::IntoError::into_error(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| ext::IntoError::into_error(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: `",
                ::std::stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "file missing");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
        let o: Option<u32> = None;
        assert_eq!(o.context("absent").unwrap_err().to_string(), "absent");
    }

    #[test]
    fn macros_compile_in_all_forms() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x != 0);
            ensure!(x != 1, "one is not allowed: {x}");
            if x == 2 {
                bail!("two is right out");
            }
            Err(anyhow!(String::from("opaque")))
        }
        assert!(f(0).unwrap_err().to_string().contains("condition failed"));
        assert_eq!(f(1).unwrap_err().to_string(), "one is not allowed: 1");
        assert_eq!(f(2).unwrap_err().to_string(), "two is right out");
        assert_eq!(f(3).unwrap_err().to_string(), "opaque");
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e = Error::msg("inner").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by") && dbg.contains("inner"));
    }
}
