//! Offline stub of the `xla` PJRT binding used by `ltp::runtime`.
//!
//! The real binding links libxla and executes AOT-compiled HLO; this build
//! environment has no network and no libxla, so this crate provides the
//! same API surface with:
//!
//! * **working host-side literals** ([`Literal::vec1`] / [`Literal::reshape`]
//!   / [`Literal::to_vec`]) — enough for the runtime's literal plumbing and
//!   its unit tests, and
//! * **unavailable execution**: [`PjRtClient::cpu`] and friends return a
//!   descriptive [`Error`], so every modeled-compute path (the scenario
//!   engine, figures 2–4/12/14/15, protocol benches) runs normally while
//!   real-compute paths fail fast with an actionable message.
//!
//! Swapping in a real PJRT backend is a one-line change in
//! `rust/Cargo.toml` (point the `xla` path dependency elsewhere).

use std::fmt;

/// Stub error type (implements `std::error::Error` so `?` converts into
/// `anyhow::Error` at the call sites).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the XLA/PJRT backend is not vendored in this offline build \
         (modeled-compute paths — `ltp scenario`, `ltp bench-ltp`, figures \
         2/3/4/12/14/15 — run without it)"
    ))
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Element types the stub [`Literal`] can hold.
pub trait NativeType: Copy + sealed::Sealed {
    fn literal(data: Vec<Self>) -> Literal;
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn literal(data: Vec<Self>) -> Literal {
        let dims = vec![data.len() as i64];
        Literal::F32 { data, dims }
    }
    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => Err(Error(format!("literal is not f32: {other:?}"))),
        }
    }
}

impl NativeType for i32 {
    fn literal(data: Vec<Self>) -> Literal {
        let dims = vec![data.len() as i64];
        Literal::I32 { data, dims }
    }
    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            other => Err(Error(format!("literal is not i32: {other:?}"))),
        }
    }
}

/// A host-side literal: flat data plus a shape.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build a rank-1 literal from a flat slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::literal(data.to_vec())
    }

    /// Reshape without moving data; the element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        match self {
            Literal::F32 { data, .. } => {
                if data.len() as i64 != numel {
                    return Err(Error(format!(
                        "reshape: {} elements do not fit {dims:?}",
                        data.len()
                    )));
                }
                Ok(Literal::F32 { data: data.clone(), dims: dims.to_vec() })
            }
            Literal::I32 { data, .. } => {
                if data.len() as i64 != numel {
                    return Err(Error(format!(
                        "reshape: {} elements do not fit {dims:?}",
                        data.len()
                    )));
                }
                Ok(Literal::I32 { data: data.clone(), dims: dims.to_vec() })
            }
            Literal::Tuple(_) => Err(Error("cannot reshape a tuple literal".to_string())),
        }
    }

    /// Flatten back to a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    /// Destructure a tuple literal; a non-tuple is returned as a singleton
    /// (matching the lenient behavior the runtime relies on).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(v) => Ok(v),
            other => Ok(vec![other]),
        }
    }

    /// The literal's shape.
    pub fn dims(&self) -> &[i64] {
        match self {
            Literal::F32 { dims, .. } | Literal::I32 { dims, .. } => dims,
            Literal::Tuple(_) => &[],
        }
    }
}

/// Stub PJRT client: construction reports the backend as unavailable.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stub compiled executable (unreachable through the stub client).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub device buffer (unreachable through the stub client).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub HLO module handle.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error(format!(
            "cannot load HLO text {path:?}: XLA backend not vendored in this offline build"
        )))
    }
}

/// Stub computation handle.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let l = Literal::vec1(&[5i32, 6, 7]).reshape(&[3, 1]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![5, 6, 7]);
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn reshape_rejects_bad_shape() {
        assert!(Literal::vec1(&[1.0f32; 3]).reshape(&[2, 2]).is_err());
    }

    #[test]
    fn tuple_destructures() {
        let t = Literal::Tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
        assert_eq!(Literal::vec1(&[1.0f32]).to_tuple().unwrap().len(), 1);
    }

    #[test]
    fn execution_is_unavailable_with_clear_message() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("not vendored"), "{e}");
    }
}
