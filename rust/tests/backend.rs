//! Compute-backend registry conformance (DESIGN.md §1.3): the spec
//! grammar mirrors `ltp proto` / `ltp agg` (same `key[:name=value,...]`
//! rules, same error classes), preconditions fail fast with actionable
//! messages, and the `Backend` surface holds its determinism contract.

use ltp::compute::{backend_registry, parse_backend};
use ltp::ps::EndpointRole;

#[test]
fn registry_lists_native_and_xla() {
    let keys: Vec<&str> = backend_registry().iter().map(|d| d.key).collect();
    assert!(keys.contains(&"native"), "{keys:?}");
    assert!(keys.contains(&"xla"), "{keys:?}");
    for d in backend_registry() {
        assert!(!d.summary.is_empty(), "{}: empty summary", d.key);
        // Every registered key parses at defaults with a canonical name
        // that is a fixed point of the grammar.
        let b = parse_backend(d.key).unwrap_or_else(|e| panic!("{}: {e:#}", d.key));
        assert_eq!(b.name(), d.key);
        assert_eq!(parse_backend(b.name()).unwrap().name(), d.key);
    }
}

#[test]
fn spec_grammar_errors_are_actionable() {
    // The same error classes `ltp proto parse` / `ltp agg parse` report:
    // unknown key, unknown/malformed/duplicate parameter, bad value.
    for (bad, needle) in [
        ("torch", "unknown backend"),
        ("native:window=3", "unknown parameter"),
        ("native:dim", "malformed parameter"),
        ("native:dim=", "empty value"),
        ("native:dim=0", "at least one"),
        ("native:dim=x", "bad value"),
        ("native:dim=8,dim=9", "duplicate parameter"),
        ("native:lr=-1", "out of range"),
        ("native:fill=maybe", "expected on|off"),
        ("native:", "empty parameter list"),
        ("xla:foo=1", "unknown parameter"),
        ("xla:lr=zero", "bad value"),
    ] {
        let err = format!("{:#}", parse_backend(bad).expect_err(bad));
        assert!(err.contains(needle), "`{bad}`: error `{err}` lacks `{needle}`");
        // Errors carry the offending spec, like the proto/agg registries.
        assert!(err.contains(bad.trim_end_matches(':')) || err.contains("backend spec"), "{err}");
    }
}

#[test]
fn canonical_names_order_parameters() {
    for (spec, canon) in [
        ("native:lr=0.2,dim=32", "native:dim=32,lr=0.2"),
        ("native:fill=OFF,hidden=16", "native:hidden=16,fill=off"),
        ("native:target=0.5,classes=4,layers=3", "native:layers=3,classes=4,target=0.5"),
        ("xla:target=5,preset=tiny", "xla:preset=tiny,target=5"),
    ] {
        let b = parse_backend(spec).unwrap_or_else(|e| panic!("{spec}: {e:#}"));
        assert_eq!(b.name(), canon, "{spec}");
    }
}

#[test]
fn native_is_ready_and_sized_deterministically() {
    let b = parse_backend("native").unwrap();
    b.check_ready().expect("the native backend needs nothing");
    let info = b.model().unwrap();
    assert!(info.wire_bytes > 0 && info.wire_bytes % 4 == 0, "f32-flat gradient");
    assert!(!info.critical.is_empty(), "tensor boundaries yield critical segments");
    // Model info is a pure function of the spec.
    let again = parse_backend("native").unwrap().model().unwrap();
    assert_eq!(info.wire_bytes, again.wire_bytes);
    assert_eq!(info.critical, again.critical);
    // Spec parameters change the wire size.
    let bigger = parse_backend("native:hidden=128").unwrap().model().unwrap();
    assert!(bigger.wire_bytes > info.wire_bytes);
}

#[test]
fn native_serves_every_topology_xla_only_single_ps() {
    let native = parse_backend("native").unwrap();
    let xla = parse_backend("xla").unwrap();
    let info = native.model().unwrap();
    let single = [EndpointRole::Final { byte_offset: 0, bytes: info.wire_bytes }];
    let sharded = [
        EndpointRole::Final { byte_offset: 0, bytes: info.wire_bytes / 2 },
        EndpointRole::Final { byte_offset: info.wire_bytes / 2, bytes: info.wire_bytes / 2 },
    ];
    let hier = [
        EndpointRole::Relay { first_worker: 0, n_workers: 4 },
        EndpointRole::Relay { first_worker: 4, n_workers: 4 },
        EndpointRole::Root { racks: 2 },
    ];
    assert!(native.supports(8, &single).is_ok());
    assert!(native.supports(8, &sharded).is_ok());
    assert!(native.supports(8, &hier).is_ok());
    assert!(xla.supports(8, &single).is_ok());
    let err = format!("{:#}", xla.supports(8, &sharded).unwrap_err());
    assert!(err.contains("single PS"), "{err}");
    assert!(xla.supports(8, &hier).is_err());
}

#[test]
fn xla_fails_fast_naming_the_artifacts() {
    // Without `make artifacts` the xla backend's precondition must name
    // the dependency (satellite: no more generic "run make artifacts"
    // from call sites that do not need them). Skip when a local build
    // actually has the artifacts.
    if ltp::runtime::default_artifacts_dir().join("manifest_tiny.txt").exists() {
        eprintln!("skipping: artifacts present in this checkout");
        return;
    }
    let b = parse_backend("xla").unwrap();
    let err = format!("{:#}", b.check_ready().expect_err("no artifacts"));
    assert!(err.contains("make artifacts"), "{err}");
    assert!(err.contains("xla"), "{err}");
    assert!(
        err.contains("native"),
        "the error should point at the zero-dependency alternative: {err}"
    );
    // model() routes through the same precondition.
    assert!(b.model().is_err());
}
