//! Transport conformance matrix: every registered protocol (at default
//! parameters) is driven through the pluggable [`Transport`] API twice —
//! once raw, as a single tx/rx flow pair shuttling packets through a lossy
//! in-memory "wire", and once end-to-end through a small training gather —
//! and must uphold the API's invariants:
//!
//! * reliable transports deliver 100 % of every message, always;
//! * loss-tolerant transports close every gather exactly once, at or above
//!   their percentage threshold for non-deadline closes, with every
//!   critical segment present;
//! * close events fire exactly once per flow (`is_done` latches).

use ltp::config::Workload;
use ltp::proto::{CloseReason, EarlyCloseCfg};
use ltp::ps::{registry_matrix, ProtoSpec, RunBuilder, RxCfg, TxCfg};
use ltp::simnet::LossModel;
use ltp::{Nanos, MS};

/// The lowest Early-Close percentage any default-parameter registry
/// protocol may use (ltp-adaptive's anneal start).
const MIN_PCT: f64 = 0.7;

/// Drive one tx/rx pair of `proto` over an in-memory wire that drops every
/// `drop_every`-th sender→receiver packet (0 = lossless). Returns
/// `(delivered_fraction, close_info, done_transitions)`.
fn drive_pair(
    proto: &ProtoSpec,
    drop_every: u64,
) -> (f64, Option<(CloseReason, bool, f64)>, u32) {
    let bytes: u64 = 300_000;
    let critical = vec![0, 3, 7];
    let ec = if proto.is_loss_tolerant() {
        EarlyCloseCfg { lt_threshold: 5 * MS, deadline: 400 * MS, pct: 0.8 }
    } else {
        EarlyCloseCfg::reliable()
    };
    let flow = proto.wire_flow(9);
    let mut tx = proto.make_tx(TxCfg {
        flow,
        bytes,
        critical: critical.clone(),
        seed_rtprop: 0,
        seed_btlbw_bytes: 0,
        nq_order: None,
    });
    let mut rx = proto.make_rx(RxCfg { flow, bytes, ec, critical, iter: 1 });
    assert!(tx.flow_matches(flow) && rx.flow_matches(flow));

    let rtt = 2 * MS;
    let mut now: Nanos = 0;
    let mut sent = 0u64;
    let mut done_transitions = 0u32;
    let mut was_done = false;
    for _ in 0..2_000_000u64 {
        if tx.is_complete() && rx.is_done() {
            break;
        }
        let mut progressed = false;
        while let Some(pkt) = tx.poll(now, 0, 1) {
            progressed = true;
            sent += 1;
            if drop_every > 0 && sent % drop_every == 0 {
                continue; // the wire ate it
            }
            let mut back = Vec::new();
            rx.handle(now + rtt / 2, &pkt, 1, &mut |p| back.push(p));
            for p in back {
                tx.handle(now + rtt, &p);
            }
        }
        if !was_done && rx.is_done() {
            done_transitions += 1;
            was_done = true;
        }
        if progressed {
            now += rtt;
        } else {
            let wake = [tx.next_wakeup(), rx.next_wakeup(now)].into_iter().flatten().min();
            now = wake.map(|w| w.max(now + 1)).unwrap_or(now + MS);
            tx.on_wakeup(now);
            rx.on_wakeup(now);
            let mut back = Vec::new();
            rx.drain(1, 0, &mut |p| back.push(p));
            for p in back {
                tx.handle(now, &p);
            }
        }
    }
    assert!(tx.is_complete(), "{}: sender never completed", proto.name());
    assert!(rx.is_done(), "{}: receiver never closed", proto.name());
    assert!(tx.pkts_sent() > 0);
    (rx.delivered_fraction(), rx.close_info(), done_transitions)
}

#[test]
fn every_registered_protocol_completes_a_lossless_flow() {
    for proto in registry_matrix() {
        let (delivered, _, transitions) = drive_pair(&proto, 0);
        assert!(
            (delivered - 1.0).abs() < 1e-9,
            "{}: lossless wire must deliver 100%, got {delivered}",
            proto.name()
        );
        assert_eq!(transitions, 1, "{}: close must fire exactly once", proto.name());
    }
}

#[test]
fn every_registered_protocol_survives_forward_loss() {
    for proto in registry_matrix() {
        // ~8% of sender→receiver packets vanish.
        let (delivered, close, transitions) = drive_pair(&proto, 13);
        assert_eq!(transitions, 1, "{}: close must fire exactly once", proto.name());
        if proto.is_loss_tolerant() {
            let (reason, criticals_ok, pct_at_close) =
                close.unwrap_or_else(|| panic!("{}: no close record", proto.name()));
            if reason != CloseReason::Deadline {
                assert!(criticals_ok, "{}: criticals lost on {reason:?}", proto.name());
                assert!(
                    pct_at_close >= MIN_PCT - 1e-9,
                    "{}: closed {reason:?} below threshold: {pct_at_close}",
                    proto.name()
                );
            }
        } else {
            assert!(
                (delivered - 1.0).abs() < 1e-9,
                "{}: reliable transport must deliver 100% under loss, got {delivered}",
                proto.name()
            );
            assert!(close.is_none(), "{}: reliable flows have no Early Close", proto.name());
        }
    }
}

#[test]
fn every_registered_protocol_trains_end_to_end() {
    let workers = 4;
    let iters = 3;
    for proto in registry_matrix() {
        let loss_tolerant = proto.is_loss_tolerant();
        let name = proto.name().to_string();
        let report = RunBuilder::modeled(proto, Workload::Micro, workers)
            .iters(iters)
            .model_bytes(1_000_000)
            .critical_tensors(20)
            .loss(LossModel::Bernoulli { p: 0.01 })
            .run()
            .expect("conformance configuration is valid");
        assert_eq!(report.iters.len(), iters as usize, "{name}: all iterations must finish");
        assert_eq!(report.proto, name, "the report carries the canonical spec");
        if loss_tolerant {
            // Exactly one close record per (worker, iteration) gather flow
            // — a double close or a silent one would break this count.
            assert_eq!(
                report.closes.len(),
                (workers as u64 * iters) as usize,
                "{name}: close records: {:?}",
                report.closes
            );
            for c in &report.closes {
                if c.reason != CloseReason::Deadline {
                    assert!(c.criticals_ok, "{name}: criticals lost: {c:?}");
                }
                if c.reason == CloseReason::EarlyPct {
                    assert!(
                        c.delivered >= MIN_PCT - 1e-9,
                        "{name}: early close below threshold: {c:?}"
                    );
                }
            }
        } else {
            assert!(
                (report.mean_delivered() - 1.0).abs() < 1e-9,
                "{name}: reliable transports deliver 100%, got {}",
                report.mean_delivered()
            );
            assert!(report.closes.is_empty(), "{name}: unexpected close records");
        }
    }
}

#[test]
fn spec_tuning_overrides_reach_the_run() {
    // `ltp:pct=...` must change Early Close behavior relative to plain ltp
    // under identical conditions: a lower threshold closes earlier (lower
    // delivered fraction), and both stay above their respective floors.
    let run = |spec: &str| {
        RunBuilder::modeled(ltp::ps::parse_proto(spec).unwrap(), Workload::Micro, 4)
            .iters(4)
            .model_bytes(1_000_000)
            .loss(LossModel::Bernoulli { p: 0.02 })
            .run()
            .unwrap()
    };
    let strict = run("ltp:pct=0.99");
    let lax = run("ltp:pct=0.75");
    assert!(
        strict.mean_delivered() >= lax.mean_delivered() - 1e-9,
        "pct=0.99 ({}) must deliver at least as much as pct=0.75 ({})",
        strict.mean_delivered(),
        lax.mean_delivered()
    );
    for c in &lax.closes {
        if c.reason == CloseReason::EarlyPct {
            assert!(c.delivered >= 0.75 - 1e-9, "{c:?}");
        }
    }
}
