//! The LTP protocol over *real* UDP sockets on loopback: the same sans-IO
//! core as the simulator, with actual bytes on the wire.

use ltp::proto::{CloseReason, EarlyCloseCfg, SegmentMap};
use ltp::udp::{recv_message, send_message};
use ltp::wire::LTP_MSS;
use ltp::MS;
use std::net::UdpSocket;
use std::time::Duration;

fn pair() -> (UdpSocket, UdpSocket) {
    let a = UdpSocket::bind("127.0.0.1:0").unwrap();
    let b = UdpSocket::bind("127.0.0.1:0").unwrap();
    (a, b)
}

fn payload(n: usize) -> Vec<u8> {
    (0..n).map(|i| (i % 251) as u8).collect()
}

#[test]
fn lossless_transfer_delivers_bytes_exactly() {
    let (snd_sock, rcv_sock) = pair();
    let rcv_addr = rcv_sock.local_addr().unwrap();
    let data = payload(300_000);
    let map = SegmentMap::new(data.len() as u64, (LTP_MSS / 4) * 4, vec![0]);
    let data2 = data.clone();
    let rx = std::thread::spawn(move || {
        recv_message(
            &rcv_sock,
            EarlyCloseCfg::reliable(),
            vec![0],
            0.0,
            1,
            Duration::from_secs(30),
        )
        .unwrap()
    });
    let stats =
        send_message(&snd_sock, rcv_addr, &data2, map, MS, 125_000_000, Duration::from_secs(30))
            .unwrap();
    let (bytes, rstats) = rx.join().unwrap();
    assert_eq!(rstats.reason, Some(CloseReason::Complete));
    assert_eq!(bytes, data);
    assert!(stats.completed_at.is_some());
}

#[test]
fn lossy_transfer_early_closes_with_bubbles() {
    let (snd_sock, rcv_sock) = pair();
    let rcv_addr = rcv_sock.local_addr().unwrap();
    let data = payload(400_000);
    let seg = (LTP_MSS / 4) * 4;
    let map = SegmentMap::new(data.len() as u64, seg, vec![0]);
    let ec = EarlyCloseCfg { lt_threshold: 40 * MS, deadline: 400 * MS, pct: 0.85 };
    let rx = std::thread::spawn(move || {
        // 5 % injected data-packet loss at the receiver.
        recv_message(&rcv_sock, ec, vec![0], 0.05, 7, Duration::from_secs(30)).unwrap()
    });
    let data2 = data.clone();
    let stats = send_message(
        &snd_sock,
        rcv_addr,
        &data2,
        map.clone(),
        MS,
        125_000_000,
        Duration::from_secs(30),
    )
    .unwrap();
    let (bytes, rstats) = rx.join().unwrap();
    assert!(stats.completed_at.is_some());
    assert!(rstats.pct_at_close >= 0.85, "pct {}", rstats.pct_at_close);
    assert!(rstats.criticals_ok);
    assert_eq!(bytes.len(), data.len());
    // Every segment is either intact or a zero bubble — never garbled.
    let segn = map.n_segs;
    let mut intact = 0;
    for s in 0..segn {
        let (a, b) = map.byte_range(s);
        let (a, b) = (a as usize, b as usize);
        if bytes[a..b] == data[a..b] {
            intact += 1;
        } else {
            assert!(
                bytes[a..b].iter().all(|&x| x == 0),
                "segment {s} is garbled, not a bubble"
            );
        }
    }
    assert!(intact as f64 / segn as f64 >= 0.85);
}
