//! End-to-end integration over the real artifacts: PJRT loads the AOT
//! HLO, the Pallas aggregation kernel matches the Rust-side reference, and
//! a full BSP training run over the simulated network reduces the loss.
//!
//! All tests skip (pass trivially) when `make artifacts` has not run.

use ltp::config::ModelManifest;
use ltp::ps::{run_with, Corpus, RealCompute, RealTraining, RunBuilder, XlaAggregate};
use ltp::runtime::{default_artifacts_dir, literal_f32, literal_i32, to_f32, Runtime};
use ltp::simnet::LossModel;
use ltp::{MS, SEC};

fn runtime() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest_tiny.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::cpu(dir).expect("PJRT CPU client"))
}

#[test]
fn train_step_artifact_runs_and_produces_gradients() {
    let Some(rt) = runtime() else { return };
    let m = ModelManifest::load(default_artifacts_dir(), "tiny").unwrap();
    let init = rt.load("init_tiny").unwrap();
    let params = to_f32(&init.run(&[]).unwrap()[0]).unwrap();
    assert_eq!(params.len(), m.padded_dim);

    let step = rt.load("train_step_tiny").unwrap();
    let mut corpus = Corpus::new(m.vocab, 7);
    let tokens = corpus.next_batch(m.batch, m.seq_len + 1);
    let out = step
        .run(&[
            literal_f32(&params, &[m.padded_dim as i64]).unwrap(),
            literal_i32(&tokens, &[m.batch as i64, m.seq_len as i64 + 1]).unwrap(),
        ])
        .unwrap();
    let grads = to_f32(&out[0]).unwrap();
    let loss = to_f32(&out[1]).unwrap()[0];
    assert_eq!(grads.len(), m.padded_dim);
    // Initial loss ≈ ln(vocab) for a fresh model.
    let expect = (m.vocab as f32).ln();
    assert!((loss - expect).abs() < 1.5, "loss {loss} vs ln(V) {expect}");
    let gnorm: f32 = grads.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(gnorm > 0.1, "gradients must be non-trivial: {gnorm}");
    // Padding tail carries zero gradient.
    assert!(grads[m.param_count..].iter().all(|&g| g == 0.0));
}

#[test]
fn aggregate_artifact_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    let m = ModelManifest::load(default_artifacts_dir(), "tiny").unwrap();
    let agg = rt.load("aggregate_tiny").unwrap();
    let d = m.padded_dim;
    let w = m.agg_workers;
    let mut rng = ltp::util::Pcg64::seeded(3);
    let p: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.1).collect();
    let g: Vec<f32> = (0..w * d).map(|_| rng.normal() as f32).collect();
    let mask: Vec<f32> = (0..w * d).map(|_| if rng.chance(0.7) { 1.0 } else { 0.0 }).collect();
    let lr = 0.05f32;
    let out = agg
        .run(&[
            literal_f32(&p, &[d as i64]).unwrap(),
            literal_f32(&v, &[d as i64]).unwrap(),
            literal_f32(&g, &[w as i64, d as i64]).unwrap(),
            literal_f32(&mask, &[w as i64, d as i64]).unwrap(),
            literal_f32(&[lr], &[1]).unwrap(),
        ])
        .unwrap();
    let p2 = to_f32(&out[0]).unwrap();
    let v2 = to_f32(&out[1]).unwrap();
    // Rust-side oracle of the bubble-filling masked mean + momentum SGD.
    for i in 0..d {
        let mut s = 0.0f64;
        let mut cnt = 0.0f64;
        for k in 0..w {
            s += (g[k * d + i] * mask[k * d + i]) as f64;
            cnt += mask[k * d + i] as f64;
        }
        let mean = s / cnt.max(1.0);
        let vv = 0.9 * v[i] as f64 + mean;
        let pp = p[i] as f64 - lr as f64 * vv;
        assert!(
            (v2[i] as f64 - vv).abs() < 1e-4,
            "v mismatch at {i}: {} vs {vv}",
            v2[i]
        );
        assert!(
            (p2[i] as f64 - pp).abs() < 1e-4,
            "p mismatch at {i}: {} vs {pp}",
            p2[i]
        );
    }
}

#[test]
fn topk_artifact_keeps_expected_fraction() {
    let Some(rt) = runtime() else { return };
    let m = ModelManifest::load(default_artifacts_dir(), "tiny").unwrap();
    let topk = rt.load("topk_tiny_k20").unwrap();
    let d = m.padded_dim;
    let mut rng = ltp::util::Pcg64::seeded(5);
    let g: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let out = topk.run(&[literal_f32(&g, &[d as i64]).unwrap()]).unwrap();
    let sparse = to_f32(&out[0]).unwrap();
    let kept = sparse.iter().filter(|&&x| x != 0.0).count() as f64 / d as f64;
    assert!((kept - 0.20).abs() < 0.02, "top-20% kept {kept}");
    // Every kept element must equal its original value.
    for (a, b) in sparse.iter().zip(&g) {
        assert!(*a == 0.0 || a == b);
    }
}

/// The headline integration: real transformer training, gradients over
/// LTP through a lossy simulated incast fabric, Pallas aggregation on the
/// PS, reliable broadcast back — loss must drop.
#[test]
fn full_training_over_lossy_ltp_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let shared = RealTraining::new(&rt, "tiny", 0.08).unwrap();
    let n_workers = 4;
    let cfg = RunBuilder::modeled(
        ltp::ps::parse_proto("ltp").unwrap(),
        ltp::config::Workload::Micro,
        n_workers,
    )
    .model_bytes(shared.manifest.wire_bytes())
    .critical(
        shared
            .manifest
            .tensors
            .critical_segments(ltp::grad::Manifest::aligned_payload(ltp::wire::LTP_MSS)),
    )
    .iters(25)
    .compute_time(50 * MS)
    .loss(LossModel::Bernoulli { p: 0.01 })
    .horizon(600 * SEC)
    .build()
    .unwrap();

    let shared2 = shared.clone();
    let shared_agg = shared.clone();
    let report = run_with(
        &cfg,
        move |w, _| {
            Box::new(RealCompute {
                shared: shared2.clone(),
                corpus: Corpus::new(shared2.manifest.vocab, 1000 + w as u64),
            })
        },
        move |_| Box::new(XlaAggregate { shared: shared_agg.clone(), n_workers }),
    );
    assert_eq!(report.iters.len(), 25, "all BSP iterations must complete");
    let losses: Vec<f32> = report.iters.iter().filter_map(|i| i.loss).collect();
    assert!(losses.len() >= 20, "losses recorded: {losses:?}");
    let first = losses.first().copied().unwrap();
    let last = losses.last().copied().unwrap();
    assert!(
        last < first - 0.3,
        "loss must drop under lossy LTP training: {first} → {last} ({losses:?})"
    );
    // Loss tolerance engaged: some gradient data was dropped, yet training
    // still converged.
    assert!(report.mean_delivered() <= 1.0);
}
