//! End-to-end integration of real compute over the simulated network.
//!
//! The **native backend** tests always run (pure Rust, no artifacts): a
//! full BSP training run over a lossy fabric must reduce the loss, reach
//! high eval accuracy, replay bit-identically per seed, and work across
//! aggregation topologies. Only the **`xla`-specific** cases — PJRT
//! loading the AOT HLO, the Pallas kernels matching the Rust reference —
//! still skip (pass trivially) when `make artifacts` has not run.

use ltp::compute::parse_backend;
use ltp::config::ModelManifest;
use ltp::ps::{
    parse_agg, parse_proto, run_with, Corpus, RealCompute, RealTraining, RunBuilder,
    RunReport, XlaAggregate,
};
use ltp::runtime::{default_artifacts_dir, literal_f32, literal_i32, to_f32, Runtime};
use ltp::simnet::LossModel;
use ltp::{MS, SEC};

fn runtime() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest_tiny.txt").exists() {
        eprintln!("skipping xla-specific case: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::cpu(dir).expect("PJRT CPU client"))
}

// ---------------------------------------------------------------------------
// Native backend (always runs — DESIGN.md §1.3).
// ---------------------------------------------------------------------------

/// A short native-backend training run over a lossy LTP incast fabric.
fn native_run(proto: &str, agg: &str, loss: f64, iters: u64, seed: u64) -> RunReport {
    let mut b = RunBuilder::modeled(
        parse_proto(proto).unwrap(),
        ltp::config::Workload::Micro,
        4,
    )
    .backend(parse_backend("native").unwrap())
    .agg(parse_agg(agg).unwrap())
    .iters(iters)
    .seed(seed)
    .batches_per_epoch(4)
    .horizon(600 * SEC);
    if loss > 0.0 {
        b = b.loss(LossModel::Bernoulli { p: loss });
    }
    b.run().unwrap_or_else(|e| panic!("{proto}/{agg}: {e:#}"))
}

/// The headline integration, un-skipped: real (native) training, gradients
/// over lossy LTP, masked-mean aggregation of the delivered bytes, reliable
/// broadcast back — loss must drop and eval accuracy must be high.
#[test]
fn native_training_over_lossy_ltp_reduces_loss() {
    let report = native_run("ltp", "ps", 0.01, 16, 1);
    assert_eq!(report.iters.len(), 16, "all BSP iterations must complete");
    let losses: Vec<f32> = report.iters.iter().filter_map(|i| i.loss).collect();
    assert_eq!(losses.len(), 16, "every iteration records a training loss");
    let first = losses.first().copied().unwrap();
    let last = losses.last().copied().unwrap();
    assert!(
        last < first * 0.5,
        "loss must drop under lossy LTP training: {first} → {last} ({losses:?})"
    );
    let train = report.train.expect("backend attached ⇒ train block");
    assert!(train.accuracy > 0.95, "eval accuracy {}", train.accuracy);
    assert!(train.final_loss < 0.5, "eval loss {}", train.final_loss);
    assert!(train.iters_to_target.is_some(), "target must be reached: {train:?}");
    // Loss tolerance engaged: some gradient data was dropped, yet training
    // still converged.
    assert!(report.mean_delivered() < 1.0, "1% wire loss must drop data");
    assert!(report.mean_delivered() > 0.8);
}

#[test]
fn native_training_is_deterministic_per_seed() {
    let a = native_run("ltp", "ps", 0.02, 6, 9);
    let b = native_run("ltp", "ps", 0.02, 6, 9);
    assert_eq!(a.train, b.train, "same seed ⇒ bit-identical training outcome");
    let la: Vec<Option<f32>> = a.iters.iter().map(|i| i.loss).collect();
    let lb: Vec<Option<f32>> = b.iters.iter().map(|i| i.loss).collect();
    assert_eq!(la, lb);
    let c = native_run("ltp", "ps", 0.02, 6, 10);
    assert_ne!(a.train, c.train, "a different seed must change the run");
}

#[test]
fn native_training_runs_on_sharded_and_hier_topologies() {
    for agg in ["sharded:n=2", "hier"] {
        let report = native_run("ltp", agg, 0.01, 6, 3);
        assert_eq!(report.iters.len(), 6, "{agg}");
        let train = report.train.expect("train block");
        assert!(train.final_loss.is_finite(), "{agg}: {train:?}");
        assert!(
            report.iters.iter().all(|i| i.loss.is_some()),
            "{agg}: every iteration reports the mean worker loss"
        );
    }
}

#[test]
fn native_training_over_reliable_tcp_matches_lossless_delivery() {
    let report = native_run("reno", "ps", 0.02, 6, 4);
    assert_eq!(report.iters.len(), 6);
    assert!(
        (report.mean_delivered() - 1.0).abs() < 1e-9,
        "TCP delivers 100% whatever the wire does"
    );
    report.train.expect("train block");
}

#[test]
fn train_step_artifact_runs_and_produces_gradients() {
    let Some(rt) = runtime() else { return };
    let m = ModelManifest::load(default_artifacts_dir(), "tiny").unwrap();
    let init = rt.load("init_tiny").unwrap();
    let params = to_f32(&init.run(&[]).unwrap()[0]).unwrap();
    assert_eq!(params.len(), m.padded_dim);

    let step = rt.load("train_step_tiny").unwrap();
    let mut corpus = Corpus::new(m.vocab, 7);
    let tokens = corpus.next_batch(m.batch, m.seq_len + 1);
    let out = step
        .run(&[
            literal_f32(&params, &[m.padded_dim as i64]).unwrap(),
            literal_i32(&tokens, &[m.batch as i64, m.seq_len as i64 + 1]).unwrap(),
        ])
        .unwrap();
    let grads = to_f32(&out[0]).unwrap();
    let loss = to_f32(&out[1]).unwrap()[0];
    assert_eq!(grads.len(), m.padded_dim);
    // Initial loss ≈ ln(vocab) for a fresh model.
    let expect = (m.vocab as f32).ln();
    assert!((loss - expect).abs() < 1.5, "loss {loss} vs ln(V) {expect}");
    let gnorm: f32 = grads.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(gnorm > 0.1, "gradients must be non-trivial: {gnorm}");
    // Padding tail carries zero gradient.
    assert!(grads[m.param_count..].iter().all(|&g| g == 0.0));
}

#[test]
fn aggregate_artifact_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    let m = ModelManifest::load(default_artifacts_dir(), "tiny").unwrap();
    let agg = rt.load("aggregate_tiny").unwrap();
    let d = m.padded_dim;
    let w = m.agg_workers;
    let mut rng = ltp::util::Pcg64::seeded(3);
    let p: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.1).collect();
    let g: Vec<f32> = (0..w * d).map(|_| rng.normal() as f32).collect();
    let mask: Vec<f32> = (0..w * d).map(|_| if rng.chance(0.7) { 1.0 } else { 0.0 }).collect();
    let lr = 0.05f32;
    let out = agg
        .run(&[
            literal_f32(&p, &[d as i64]).unwrap(),
            literal_f32(&v, &[d as i64]).unwrap(),
            literal_f32(&g, &[w as i64, d as i64]).unwrap(),
            literal_f32(&mask, &[w as i64, d as i64]).unwrap(),
            literal_f32(&[lr], &[1]).unwrap(),
        ])
        .unwrap();
    let p2 = to_f32(&out[0]).unwrap();
    let v2 = to_f32(&out[1]).unwrap();
    // Rust-side oracle of the bubble-filling masked mean + momentum SGD.
    for i in 0..d {
        let mut s = 0.0f64;
        let mut cnt = 0.0f64;
        for k in 0..w {
            s += (g[k * d + i] * mask[k * d + i]) as f64;
            cnt += mask[k * d + i] as f64;
        }
        let mean = s / cnt.max(1.0);
        let vv = 0.9 * v[i] as f64 + mean;
        let pp = p[i] as f64 - lr as f64 * vv;
        assert!(
            (v2[i] as f64 - vv).abs() < 1e-4,
            "v mismatch at {i}: {} vs {vv}",
            v2[i]
        );
        assert!(
            (p2[i] as f64 - pp).abs() < 1e-4,
            "p mismatch at {i}: {} vs {pp}",
            p2[i]
        );
    }
}

#[test]
fn topk_artifact_keeps_expected_fraction() {
    let Some(rt) = runtime() else { return };
    let m = ModelManifest::load(default_artifacts_dir(), "tiny").unwrap();
    let topk = rt.load("topk_tiny_k20").unwrap();
    let d = m.padded_dim;
    let mut rng = ltp::util::Pcg64::seeded(5);
    let g: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let out = topk.run(&[literal_f32(&g, &[d as i64]).unwrap()]).unwrap();
    let sparse = to_f32(&out[0]).unwrap();
    let kept = sparse.iter().filter(|&&x| x != 0.0).count() as f64 / d as f64;
    assert!((kept - 0.20).abs() < 0.02, "top-20% kept {kept}");
    // Every kept element must equal its original value.
    for (a, b) in sparse.iter().zip(&g) {
        assert!(*a == 0.0 || a == b);
    }
}

/// The headline integration: real transformer training, gradients over
/// LTP through a lossy simulated incast fabric, Pallas aggregation on the
/// PS, reliable broadcast back — loss must drop.
#[test]
fn full_training_over_lossy_ltp_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let shared = RealTraining::new(&rt, "tiny", 0.08).unwrap();
    let n_workers = 4;
    let cfg = RunBuilder::modeled(
        ltp::ps::parse_proto("ltp").unwrap(),
        ltp::config::Workload::Micro,
        n_workers,
    )
    .model_bytes(shared.manifest.wire_bytes())
    .critical(
        shared
            .manifest
            .tensors
            .critical_segments(ltp::grad::Manifest::aligned_payload(ltp::wire::LTP_MSS)),
    )
    .iters(25)
    .compute_time(50 * MS)
    .loss(LossModel::Bernoulli { p: 0.01 })
    .horizon(600 * SEC)
    .build()
    .unwrap();

    let shared2 = shared.clone();
    let shared_agg = shared.clone();
    let report = run_with(
        &cfg,
        move |w, _| {
            Box::new(RealCompute {
                shared: shared2.clone(),
                corpus: Corpus::new(shared2.manifest.vocab, 1000 + w as u64),
            })
        },
        move |_| Box::new(XlaAggregate { shared: shared_agg.clone(), n_workers }),
    );
    assert_eq!(report.iters.len(), 25, "all BSP iterations must complete");
    let losses: Vec<f32> = report.iters.iter().filter_map(|i| i.loss).collect();
    assert!(losses.len() >= 20, "losses recorded: {losses:?}");
    let first = losses.first().copied().unwrap();
    let last = losses.last().copied().unwrap();
    assert!(
        last < first - 0.3,
        "loss must drop under lossy LTP training: {first} → {last} ({losses:?})"
    );
    // Loss tolerance engaged: some gradient data was dropped, yet training
    // still converged.
    assert!(report.mean_delivered() <= 1.0);
}
