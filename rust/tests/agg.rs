//! Aggregation conformance matrix (DESIGN.md §1.2): every registered
//! aggregation topology — single PS, sharded multi-PS, hierarchical
//! rack-local — is driven end-to-end through small training runs and
//! must uphold the API's invariants:
//!
//! * under a reliable transport, every topology delivers 100 % of every
//!   gradient, always (zero-loss delivered fraction ≡ single-PS);
//! * under a lossy loss-tolerant transport, every aggregator endpoint
//!   closes **exactly one** gather flow per (source, iteration) and no
//!   non-deadline close loses a critical segment — per shard, per rack,
//!   and at the `hier` root;
//! * `sharded:n=1` degenerates to the single-PS run byte-for-byte;
//! * sharding divides the per-aggregator incast volume, so on the 2 %
//!   loss incast fabric `sharded:n=4` + ltp beats single-PS + ltp on
//!   mean BST (the repo's acceptance criterion);
//! * malformed specs and inconsistent (workers, agg) combinations fail
//!   fast with actionable messages.

use ltp::compute::parse_backend;
use ltp::config::Workload;
use ltp::proto::CloseReason;
use ltp::ps::{parse_agg, parse_proto, run_training_session, RunBuilder, RunReport};
use ltp::scenarios::CaseResult;
use ltp::simnet::LossModel;
use ltp::SEC;

const WORKERS: usize = 8;
const ITERS: u64 = 3;

/// A small 8-worker incast run: 1 MB per worker per iteration, scenario
/// sizing, fixed seed.
fn run(agg: &str, proto: &str, loss: f64) -> RunReport {
    let mut b = RunBuilder::modeled(parse_proto(proto).unwrap(), Workload::Micro, WORKERS)
        .agg(parse_agg(agg).unwrap())
        .iters(ITERS)
        .model_bytes(1_000_000)
        .critical_tensors(20)
        .batches_per_epoch(2)
        .seed(11)
        .horizon(600 * SEC);
    if loss > 0.0 {
        b = b.loss(LossModel::Bernoulli { p: loss });
    }
    b.run().unwrap_or_else(|e| panic!("{agg}/{proto}: {e:#}"))
}

#[test]
fn reliable_transport_delivers_fully_on_every_topology() {
    // Zero-loss invariant: a reliable transport's delivered fraction is
    // identically 1.0 whatever the aggregation topology — sharded and
    // hierarchical runs behave exactly like the single PS.
    for agg in ["ps", "sharded:n=4", "hier"] {
        let r = run(agg, "reno", 0.0);
        assert_eq!(r.iters.len(), ITERS as usize, "{agg}: all iterations must finish");
        assert!(
            (r.mean_delivered() - 1.0).abs() < 1e-9,
            "{agg}: reliable transport must deliver 100%, got {}",
            r.mean_delivered()
        );
        assert!(r.closes.is_empty(), "{agg}: TCP runs produce no LTP close records");
        assert!(r.mean_bst() > 0);
    }
}

#[test]
fn ltp_zero_loss_delivery_is_high_on_every_topology() {
    // LTP may legitimately early-close congestion tails even without wire
    // loss; the multi-point topologies must not make that materially
    // worse than the single PS's documented floor.
    for agg in ["ps", "sharded:n=4", "hier"] {
        let r = run(agg, "ltp", 0.0);
        assert_eq!(r.iters.len(), ITERS as usize, "{agg}");
        assert!(
            r.mean_delivered() > 0.85,
            "{agg}: zero-loss LTP delivered only {}",
            r.mean_delivered()
        );
    }
}

#[test]
fn lossy_ltp_closes_exactly_once_per_aggregator_flow_sharded() {
    let shards = 2;
    let r = run("sharded:n=2", "ltp", 0.02);
    assert_eq!(r.iters.len(), ITERS as usize);
    // Exactly one close per (shard, worker, iteration) gather flow.
    assert_eq!(
        r.closes.len(),
        shards * WORKERS * ITERS as usize,
        "one close per aggregator flow: {:?}",
        r.closes
    );
    // Every (worker, iteration) pair closes once per shard.
    let mut counts = std::collections::BTreeMap::new();
    for c in &r.closes {
        *counts.entry((c.iter, c.worker)).or_insert(0usize) += 1;
        if c.reason != CloseReason::Deadline {
            assert!(
                c.criticals_ok,
                "criticals must be held per shard on a non-deadline close: {c:?}"
            );
        }
    }
    assert_eq!(counts.len(), WORKERS * ITERS as usize);
    assert!(counts.values().all(|&v| v == shards), "{counts:?}");
    // The per-shard breakdown is populated and deterministic.
    assert_eq!(r.shards.len(), shards);
    assert_eq!(r.shards[0].label, "shard0");
    assert_eq!(r.shards[1].label, "shard1");
    for s in &r.shards {
        assert!(s.bst_ns > 0, "{}: zero BST", s.label);
        assert!(s.delivered > 0.5 && s.delivered <= 1.0 + 1e-9, "{}", s.label);
    }
    assert!(r.mean_delivered() < 1.0, "2% loss must trigger early closes");
    assert!(r.mean_delivered() > 0.7);
}

#[test]
fn lossy_ltp_closes_exactly_once_per_aggregator_flow_hier() {
    let racks = 2;
    let r = run("hier:racks=2", "ltp", 0.02);
    assert_eq!(r.iters.len(), ITERS as usize);
    // Rack aggregators close one flow per (worker, iteration); the root
    // closes one per (rack, iteration), indexed after the workers
    // (`W + rack`) so the merged close list is one unambiguous namespace.
    assert_eq!(
        r.closes.len(),
        (WORKERS + racks) * ITERS as usize,
        "one close per aggregator flow: {:?}",
        r.closes
    );
    let mut seen = std::collections::BTreeSet::new();
    for c in &r.closes {
        assert!(c.worker < WORKERS + racks, "{c:?}");
        assert!(seen.insert((c.iter, c.worker)), "duplicate close source: {c:?}");
        if c.reason != CloseReason::Deadline {
            assert!(c.criticals_ok, "criticals held per aggregator flow: {c:?}");
        }
    }
    // Breakdown: racks in iteration order, then the root.
    let labels: Vec<&str> = r.shards.iter().map(|s| s.label.as_str()).collect();
    assert_eq!(labels, ["rack0", "rack1", "root"]);
    for s in &r.shards {
        assert!(s.bst_ns > 0, "{}: zero BST", s.label);
    }
}

#[test]
fn sharded_n1_report_is_byte_identical_to_ps() {
    let ps = run("ps", "ltp", 0.02);
    let n1 = run("sharded:n=1", "ltp", 0.02);
    // The degenerate single-shard run takes the sharded code path yet
    // must reproduce the single-PS simulation exactly: same iteration
    // records, close records, counters — and the same serialized bytes
    // (the breakdown stays empty for a single aggregator).
    assert!(n1.shards.is_empty(), "single aggregator keeps the legacy report shape");
    assert_eq!(ps.closes, n1.closes);
    assert_eq!(ps.mean_bst(), n1.mean_bst());
    assert_eq!(ps.sim_events, n1.sim_events);
    let case = |r: &RunReport| CaseResult::from_report("x/w8", WORKERS, r);
    let (a, b) = (case(&ps), case(&n1));
    // Serialize through the scenario JSON layer with the same label: the
    // canonical agg names differ (`ps` vs `sharded:n=1`), but neither is
    // emitted for single-aggregator cases, so the bytes must match.
    let render = |c: &CaseResult| {
        ltp::scenarios::ScenarioReport {
            name: "golden".to_string(),
            seed: 11,
            quick: true,
            incast_class: false,
            cases: vec![c.clone()],
        }
        .render_json()
    };
    assert_eq!(render(&a), render(&b), "sharded:n=1 must be byte-identical to ps");
}

#[test]
fn sharded_n4_beats_single_ps_on_lossy_incast() {
    // The acceptance criterion: dividing the incast volume per
    // aggregation point by 4 must strictly lower mean BST under LTP on
    // the 2%-loss incast fabric at equal worker count.
    let ps = run("ps", "ltp", 0.02);
    let sharded = run("sharded:n=4", "ltp", 0.02);
    assert_eq!(ps.iters.len(), sharded.iters.len());
    assert!(
        sharded.mean_bst() < ps.mean_bst(),
        "sharded:n=4 mean BST {} must be strictly below single-PS {}",
        sharded.mean_bst(),
        ps.mean_bst()
    );
}

/// Run the native backend through a full simulation on the given
/// aggregation topology at zero wire loss under a reliable transport and
/// return the final flat parameters — via the production
/// `run_training_session` wiring, not a test-local re-implementation.
fn native_final_params(agg: &str) -> Vec<f32> {
    let cfg = RunBuilder::modeled(parse_proto("reno").unwrap(), Workload::Micro, WORKERS)
        .backend(parse_backend("native").unwrap())
        .agg(parse_agg(agg).unwrap())
        .iters(ITERS)
        .seed(5)
        .batches_per_epoch(2)
        .horizon(600 * SEC)
        .build()
        .unwrap_or_else(|e| panic!("{agg}: {e:#}"));
    let (report, session) = run_training_session(&cfg);
    assert_eq!(report.iters.len(), ITERS as usize, "{agg}: all iterations must finish");
    assert!(
        (report.mean_delivered() - 1.0).abs() < 1e-9,
        "{agg}: the reliable zero-loss run delivers everything"
    );
    assert!(report.train.is_some(), "{agg}: backend-attached run carries a train block");
    session.params()
}

#[test]
fn native_backend_aggregation_is_bit_identical_across_topologies() {
    // At 0% loss every element mask is all-ones and every endpoint sums in
    // global worker order, so sharded and hierarchical aggregation must
    // reproduce the single-PS parameter trajectory *bit for bit* — the
    // compute-plane counterpart of `sharded_n1_report_is_byte_identical`.
    let ps = native_final_params("ps");
    assert!(ps.iter().any(|&p| p != 0.0), "training must move the parameters");
    assert!(ps.iter().all(|p| p.is_finite()));
    let sharded = native_final_params("sharded:n=2");
    assert_eq!(ps, sharded, "sharded:n=2 must aggregate bit-identically to ps");
    let hier = native_final_params("hier");
    assert_eq!(ps, hier, "hier must aggregate bit-identically to ps");
}

#[test]
fn spec_grammar_errors_are_actionable() {
    for (bad, needle) in [
        ("mesh", "unknown aggregation"),
        ("sharded", "needs a shard count"),
        ("sharded:n=0", "at least one shard"),
        ("sharded:k=2", "unknown parameter"),
        ("hier:racks=0", "at least one rack"),
        ("ps:n=2", "unknown parameter"),
    ] {
        let err = format!("{:#}", parse_agg(bad).expect_err(bad));
        assert!(err.contains(needle), "`{bad}`: error `{err}` lacks `{needle}`");
    }
    // Non-divisible worker counts fail at build time, before simulating.
    let b = RunBuilder::modeled(parse_proto("ltp").unwrap(), Workload::Micro, 6)
        .agg(parse_agg("hier:racks=4").unwrap());
    let err = format!("{:#}", b.build().expect_err("6 workers over 4 racks"));
    assert!(err.contains("not divisible"), "{err}");
}
